#!/usr/bin/env python3
"""theseus-lint: toolchain-free static analysis over rust/src.

Theseus's value proposition is trustworthy DSE at scale — byte-identical
campaign artifacts, bit-identical dispatch paths (serial == pooled ==
batched), position-independent seeds behind --shard/--merge, and a loud
error contract (no silent fallbacks, no panics in library paths). Those
contracts are enforced here, statically, because this is the one
correctness tool that runs in every build container (several ship no
cargo/rustc — see CHANGES.md). ci_check.sh runs this unconditionally in
its always-on Python leg.

Rules (full detail in --help and python/theseus_lint/rules.py):

  panic          no unwrap()/expect()/panic!/unreachable!/todo!/
                 unimplemented! in non-test library code. Exempt: main.rs
                 (CLI exit-1 paths), noc_sim/reference.rs (frozen oracle),
                 test code.
  determinism    no wall-clock (Instant::now/SystemTime/UNIX_EPOCH) or
                 nondeterministic RNG sources in library code; no
                 HashMap/HashSet in artifact-writing modules (util/json,
                 coordinator/, figures/).
  loud-failure   no raw env::var outside util/cli.rs; no bare eprintln!
                 outside util/warn.rs — fallbacks report via warn_once.
  stub-coverage  runtime/stub.rs mirrors every pub fn / pub type of
                 runtime/pjrt.rs; positive #[cfg(theseus_pjrt)] gates need
                 a not() sibling in the same file.

Suppression syntax (reason mandatory, parsed by the linter):

    // lint: allow(panic) ranked_strategies is non-empty here: guarded above

Baseline-ratchet workflow (scripts/lint_baseline.json):

  * The scan must match the committed baseline exactly. New violations
    fail with a listing; counts *below* baseline fail too ("improvement
    not locked in") so old headroom can never hide new debt.
  * After fixing violations or adding justified suppressions, run
    `scripts/lint_theseus.py --update-baseline` and commit the shrunken
    baseline. The update refuses to grow any entry.
  * `--list` prints every current violation including baselined ones —
    the burn-down worklist.

The scanner is string/char/comment/raw-string aware and skips
#[cfg(test)] / mod tests / #[test] regions — not a naive grep; see
python/theseus_lint/tokenizer.py.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "python"))

from theseus_lint.cli import run  # noqa: E402

if __name__ == "__main__":
    sys.exit(run())
