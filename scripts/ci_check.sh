#!/usr/bin/env bash
# Repo-wide CI gate (documented in ROADMAP.md):
#
#   scripts/ci_check.sh
#
# Always runs the Python test suite (pytest). When a Rust toolchain is
# present it additionally runs tier-1 (`THESEUS_TEST_FAST=1 cargo test -q`)
# and the perf gate (`scripts/bench_check.sh`); otherwise those steps are
# skipped with a loud note — some build containers ship no cargo/rustc
# (see CHANGES.md), and a silent skip would read as a pass.
set -euo pipefail

cd "$(dirname "$0")/.."

PY=python3
command -v "$PY" >/dev/null 2>&1 || PY=python
echo "== ci_check: python tests =="
"$PY" -m pytest python/tests -q

if command -v cargo >/dev/null 2>&1; then
    echo "== ci_check: rust tier-1 (THESEUS_TEST_FAST=${THESEUS_TEST_FAST:-1}) =="
    THESEUS_TEST_FAST="${THESEUS_TEST_FAST:-1}" cargo test -q
    echo "== ci_check: perf gate =="
    scripts/bench_check.sh
else
    echo "ci_check: *** SKIPPED rust tier-1 + perf gate — no cargo toolchain on this machine ***" >&2
    echo "ci_check: run 'cargo test -q' and scripts/bench_check.sh on a toolchain-equipped host before merging" >&2
fi

echo "ci_check: done"
