#!/usr/bin/env bash
# Repo-wide CI gate (documented in ROADMAP.md):
#
#   scripts/ci_check.sh
#
# Always runs the Python test suite (pytest) and theseus-lint
# (scripts/lint_theseus.py — the toolchain-free static gate on the
# panic/determinism/loud-failure/stub-coverage contracts, against the
# checked-in ratchet baseline). When a Rust toolchain is
# present it additionally runs tier-1 (`THESEUS_TEST_FAST=1 cargo test -q`),
# the perf gate (`scripts/bench_check.sh`), a 3-scenario `theseus campaign`
# smoke leg (custom JSON through the fidelity registry, incl. a gnn-test
# decode scenario and a fault-injection row exercising the degradation
# digest), a 2-shard campaign leg (--shard 1/2 + --shard 2/2 + --merge,
# gated on the merged campaign.json matching the unsharded run's bytes
# modulo resumed markers), a `--suite wafer-sweep` smoke leg (the
# wafer-count scaling matrix, gated on the scaling-efficiency digest
# appearing and the artifacts being byte-identical across a re-run), a
# `--suite serving` smoke leg (the request-traffic matrix run twice with
# --progress, gated on the TTFT/goodput digests appearing and the
# artifacts being byte-identical across the re-run — progress lines must
# never leak into artifact bytes), and
# `cargo fmt --check` when rustfmt is installed;
# otherwise those steps are skipped with a loud note — some build
# containers ship no cargo/rustc (see CHANGES.md), and a silent skip would
# read as a pass.
set -euo pipefail

cd "$(dirname "$0")/.."

PY=python3
command -v "$PY" >/dev/null 2>&1 || PY=python
echo "== ci_check: python tests =="
"$PY" -m pytest python/tests -q

echo "== ci_check: theseus-lint (static contracts, ratchet baseline) =="
"$PY" scripts/lint_theseus.py

if command -v cargo >/dev/null 2>&1; then
    echo "== ci_check: rust tier-1 (THESEUS_TEST_FAST=${THESEUS_TEST_FAST:-1}) =="
    THESEUS_TEST_FAST="${THESEUS_TEST_FAST:-1}" cargo test -q
    echo "== ci_check: perf gate =="
    scripts/bench_check.sh

    echo "== ci_check: campaign smoke (3 scenarios, THESEUS_TEST_FAST=1) =="
    SMOKE_DIR="$(mktemp -d "${TMPDIR:-/tmp}/theseus-ci-campaign.XXXXXX")"
    trap 'rm -rf "$SMOKE_DIR"' EXIT
    cat > "$SMOKE_DIR/scenarios.json" <<'EOF'
{"scenarios": [
  {"model": "GPT-1.7B", "phase": "training", "explorer": "random",
   "iters": 1, "init": 1, "pool": 8, "mc": 8, "n1": 0, "k": 0},
  {"model": "GPT-1.7B", "phase": "decode", "explorer": "mobo",
   "fidelity": "gnn-test", "batch": 4,
   "iters": 1, "init": 1, "pool": 8, "mc": 8, "n1": 0, "k": 0},
  {"model": "GPT-1.7B", "phase": "training", "explorer": "random",
   "fault_defect": 2.0, "fault_spares": 0,
   "iters": 1, "init": 1, "pool": 8, "mc": 8, "n1": 0, "k": 0}
]}
EOF
    THESEUS_TEST_FAST=1 cargo run -q --release --bin theseus -- campaign \
        --scenarios "$SMOKE_DIR/scenarios.json" \
        --out "$SMOKE_DIR/out" --seed 1 --jobs 2
    for f in "$SMOKE_DIR/out/campaign.json"; do
        [ -s "$f" ] || { echo "ci_check: campaign smoke wrote no $f" >&2; exit 1; }
    done
    if grep -q '"status": "error"' "$SMOKE_DIR/out/campaign.json"; then
        echo "ci_check: campaign smoke recorded error rows:" >&2
        cat "$SMOKE_DIR/out/campaign.json" >&2
        exit 1
    fi
    # The fault-injection row must digest a degradation curve (retained
    # throughput fraction) into the summary — its absence means the fault
    # path silently fell back to the pristine evaluation.
    if ! grep -q '"retained_fraction"' "$SMOKE_DIR/out/campaign.json"; then
        echo "ci_check: campaign smoke fault row produced no degradation digest:" >&2
        cat "$SMOKE_DIR/out/campaign.json" >&2
        exit 1
    fi

    echo "== ci_check: campaign shard+merge smoke (--shard 1/2 + 2/2 + --merge) =="
    for k in 1 2; do
        THESEUS_TEST_FAST=1 cargo run -q --release --bin theseus -- campaign \
            --scenarios "$SMOKE_DIR/scenarios.json" \
            --out "$SMOKE_DIR/shard$k" --seed 1 --jobs 2 --shard "$k/2"
    done
    THESEUS_TEST_FAST=1 cargo run -q --release --bin theseus -- campaign \
        --scenarios "$SMOKE_DIR/scenarios.json" \
        --out "$SMOKE_DIR/merged" --seed 1 --jobs 2 \
        --merge "$SMOKE_DIR/shard1,$SMOKE_DIR/shard2"
    # The merge contract: modulo the "resumed" status markers, the merged
    # campaign.json is byte-identical to the unsharded run's.
    sed 's/"status": "resumed"/"status": "ok"/' "$SMOKE_DIR/merged/campaign.json" \
        > "$SMOKE_DIR/merged-normalized.json"
    if ! cmp -s "$SMOKE_DIR/out/campaign.json" "$SMOKE_DIR/merged-normalized.json"; then
        echo "ci_check: merged campaign.json diverged from the unsharded run:" >&2
        diff "$SMOKE_DIR/out/campaign.json" "$SMOKE_DIR/merged-normalized.json" >&2 || true
        exit 1
    fi
    # And every scenario artifact matches byte for byte.
    for f in "$SMOKE_DIR"/out/scenarios/*.json; do
        if ! cmp -s "$f" "$SMOKE_DIR/merged/scenarios/$(basename "$f")"; then
            echo "ci_check: merged scenario artifact $(basename "$f") diverged" >&2
            exit 1
        fi
    done

    echo "== ci_check: wafer-sweep suite smoke (--suite wafer-sweep, twice, byte-identity) =="
    for d in sweep1 sweep2; do
        THESEUS_TEST_FAST=1 cargo run -q --release --bin theseus -- campaign \
            --suite wafer-sweep \
            --out "$SMOKE_DIR/$d" --seed 1 --jobs 2
    done
    if grep -q '"status": "error"' "$SMOKE_DIR/sweep1/campaign.json"; then
        echo "ci_check: wafer-sweep smoke recorded error rows:" >&2
        cat "$SMOKE_DIR/sweep1/campaign.json" >&2
        exit 1
    fi
    # Fixed-wafer rows must digest scaling efficiency into the summary —
    # its absence means the sweep silently lost its scale-out readout.
    if ! grep -q '"scaling_efficiency"' "$SMOKE_DIR/sweep1/campaign.json"; then
        echo "ci_check: wafer-sweep smoke produced no scaling digest:" >&2
        cat "$SMOKE_DIR/sweep1/campaign.json" >&2
        exit 1
    fi
    # The determinism contract: a same-seed re-run writes the same bytes.
    if ! cmp -s "$SMOKE_DIR/sweep1/campaign.json" "$SMOKE_DIR/sweep2/campaign.json"; then
        echo "ci_check: wafer-sweep campaign.json diverged between same-seed runs" >&2
        diff "$SMOKE_DIR/sweep1/campaign.json" "$SMOKE_DIR/sweep2/campaign.json" >&2 || true
        exit 1
    fi
    for f in "$SMOKE_DIR"/sweep1/scenarios/*.json; do
        if ! cmp -s "$f" "$SMOKE_DIR/sweep2/scenarios/$(basename "$f")"; then
            echo "ci_check: wafer-sweep scenario artifact $(basename "$f") diverged between same-seed runs" >&2
            exit 1
        fi
    done

    echo "== ci_check: serving suite smoke (--suite serving --progress, twice, byte-identity) =="
    for d in serve1 serve2; do
        THESEUS_TEST_FAST=1 cargo run -q --release --bin theseus -- campaign \
            --suite serving --progress \
            --out "$SMOKE_DIR/$d" --seed 1 --jobs 2
    done
    if grep -q '"status": "error"' "$SMOKE_DIR/serve1/campaign.json"; then
        echo "ci_check: serving smoke recorded error rows:" >&2
        cat "$SMOKE_DIR/serve1/campaign.json" >&2
        exit 1
    fi
    # Serving rows must digest tail latency and goodput into the summary —
    # their absence means the traffic replay silently fell out of the row.
    for key in '"serving_ttft_p99"' '"serving_goodput"'; do
        if ! grep -q "$key" "$SMOKE_DIR/serve1/campaign.json"; then
            echo "ci_check: serving smoke produced no $key digest:" >&2
            cat "$SMOKE_DIR/serve1/campaign.json" >&2
            exit 1
        fi
    done
    # The determinism contract: a same-seed re-run (both with --progress)
    # writes the same bytes — progress output is stderr-only.
    if ! cmp -s "$SMOKE_DIR/serve1/campaign.json" "$SMOKE_DIR/serve2/campaign.json"; then
        echo "ci_check: serving campaign.json diverged between same-seed runs" >&2
        diff "$SMOKE_DIR/serve1/campaign.json" "$SMOKE_DIR/serve2/campaign.json" >&2 || true
        exit 1
    fi
    for f in "$SMOKE_DIR"/serve1/scenarios/*.json; do
        if ! cmp -s "$f" "$SMOKE_DIR/serve2/scenarios/$(basename "$f")"; then
            echo "ci_check: serving scenario artifact $(basename "$f") diverged between same-seed runs" >&2
            exit 1
        fi
    done

    if command -v rustfmt >/dev/null 2>&1; then
        echo "== ci_check: cargo fmt --check =="
        cargo fmt --check
    else
        echo "ci_check: *** SKIPPED cargo fmt --check — no rustfmt on this machine ***" >&2
    fi

    if cargo clippy --version >/dev/null 2>&1; then
        echo "== ci_check: cargo clippy -D warnings =="
        cargo clippy --all-targets -q -- -D warnings
    else
        echo "ci_check: *** SKIPPED cargo clippy — clippy not installed on this machine ***" >&2
    fi
else
    echo "ci_check: *** SKIPPED rust tier-1 + perf gate + campaign/wafer-sweep/serving smoke + fmt + clippy — no cargo toolchain on this machine ***" >&2
    echo "ci_check: run 'cargo test -q', scripts/bench_check.sh, the campaign + wafer-sweep + serving smokes and 'cargo clippy -- -D warnings' on a toolchain-equipped host before merging" >&2
fi

echo "ci_check: done"
