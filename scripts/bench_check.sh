#!/usr/bin/env bash
# Pre-merge perf gate for the DSE hot path (documented in ROADMAP.md).
#
#   scripts/bench_check.sh            build + run perf_hotpath, gate vs baseline
#   scripts/bench_check.sh --update   additionally rewrite the baseline
#
# The gate compares every timing row (unit starting ms/us/Mcyc) of
# artifacts/bench/perf_hotpath.json against BENCH_perf_hotpath.json and
# fails on a >±30% drift. A baseline marked "unpopulated" (the committed
# bootstrap state — this repo has no canonical bench machine yet) is
# populated from the current run instead of gating.
set -euo pipefail

cd "$(dirname "$0")/.."
BASELINE=BENCH_perf_hotpath.json
UPDATE="${1:-}"

echo "== bench_check: building release =="
cargo build --release

# Tier-1 tests with the fast knob: THESEUS_TEST_FAST=1 shrinks the
# CA-sim-backed configs (analytical_tracks_ca_sim_ordering,
# analytical_and_ca_fidelities_agree_on_ordering and the noc_sim
# equivalence suite) — the slowest tier-1 items in debug builds. Export
# THESEUS_TEST_FAST=0 to force the full configs.
echo "== bench_check: tier-1 tests (THESEUS_TEST_FAST=${THESEUS_TEST_FAST:-1}) =="
THESEUS_TEST_FAST="${THESEUS_TEST_FAST:-1}" cargo test -q

echo "== bench_check: running perf_hotpath =="
cargo bench --bench perf_hotpath

# Cargo runs bench binaries with cwd = the package dir (rust/), so the
# artifact normally lands in rust/artifacts/; accept the repo root too in
# case the bench was invoked directly.
CURRENT=""
for c in rust/artifacts/bench/perf_hotpath.json artifacts/bench/perf_hotpath.json; do
    if [ -f "$c" ]; then CURRENT="$c"; break; fi
done
if [ -z "$CURRENT" ]; then
    echo "bench_check: FAIL — bench did not produce artifacts/bench/perf_hotpath.json" >&2
    exit 1
fi

if ! command -v python3 >/dev/null 2>&1; then
    echo "bench_check: SKIP gate (python3 unavailable); bench ran and asserted its own invariants"
    exit 0
fi

python3 - "$BASELINE" "$CURRENT" "$UPDATE" <<'EOF'
import json, shutil, sys

baseline_path, current_path = sys.argv[1], sys.argv[2]
update = len(sys.argv) > 3 and sys.argv[3] == "--update"
TOLERANCE = 0.30  # ±30%

with open(current_path) as f:
    current = json.load(f)

def rows_by_path(doc):
    out = {}
    for row in doc.get("rows", []):
        unit = str(row.get("unit", ""))
        # Gate only timing/throughput rows; ratio and error rows are
        # asserted by the bench itself.
        if unit.startswith(("ms", "us", "Mcyc")):
            try:
                out[row["path"]] = float(row["median"])
            except (KeyError, TypeError, ValueError):
                pass
    return out

try:
    with open(baseline_path) as f:
        baseline = json.load(f)
except FileNotFoundError:
    baseline = {"unpopulated": True}

if baseline.get("unpopulated") or update:
    shutil.copy(current_path, baseline_path)
    why = "--update" if update else "baseline was unpopulated"
    print(f"bench_check: baseline written from this run ({why}); commit {baseline_path}")
    sys.exit(0)

base_rows, cur_rows = rows_by_path(baseline), rows_by_path(current)
failures, checked = [], 0
for path, base in sorted(base_rows.items()):
    cur = cur_rows.get(path)
    if cur is None:
        # Environment-conditional rows (e.g. gnn_predict exists only when
        # PJRT artifacts are built) must not fail machines without them.
        print(f"  {path}: not emitted by this run (environment-conditional) — skipped")
        continue
    if base <= 0 or cur <= 0:
        continue
    checked += 1
    ratio = cur / base
    drift = ratio - 1.0
    status = "ok"
    # Mcyc/s is higher-better; ms/us are lower-better. Gate symmetric
    # drift either way: a 30% improvement is worth re-baselining too,
    # but only regressions fail.
    higher_better = path == "ca_simulator"
    regressed = ratio < 1 - TOLERANCE if higher_better else ratio > 1 + TOLERANCE
    if regressed:
        status = "REGRESSION"
        failures.append(f"  {path}: {base:g} -> {cur:g} ({drift:+.0%})")
    print(f"  {path}: {base:g} -> {cur:g} ({drift:+.0%}) {status}")

if failures:
    print(f"bench_check: FAIL — {len(failures)} gated row(s) regressed >{TOLERANCE:.0%}:")
    print("\n".join(failures))
    sys.exit(1)
print(f"bench_check: PASS ({checked} rows within ±{TOLERANCE:.0%})")
EOF
