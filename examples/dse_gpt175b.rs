//! Domain example: explore the WSC design space for GPT-175B training with
//! MFMOBO (paper Algo. 1) and compare the searched Pareto set against the
//! H100 / WSE2-like / Dojo-like baselines (paper §IX-F).
//!
//!     cargo run --release --example dse_gpt175b -- --iters 20 --n1 20
//!
//! Scale knobs: --iters (high-fidelity evals), --n1 (low-fidelity trials),
//! --seed, --fidelity (registry name for MFMOBO's high fidelity; the low
//! fidelity is always analytical).

use theseus::coordinator::{ref_power_for, run, DseRun, Explorer};
use theseus::eval::engine::Fidelity;
use theseus::explorer::BoConfig;
use theseus::util::cli::Args;
use theseus::util::table::Table;
use theseus::workload::{models, Phase};

fn main() {
    let args = Args::from_env();
    let spec = models::find("175b").unwrap();
    let fidelity = Fidelity::parse_or_usage(&args.str("fidelity", "analytical"))
        .unwrap_or_else(|e| {
            eprintln!("dse_gpt175b: {e}");
            std::process::exit(1);
        });
    let cfg = BoConfig {
        iters: args.usize("iters", 16),
        init: 6,
        pool: args.usize("pool", 48),
        mc_samples: 32,
        ref_power: ref_power_for(&spec),
        seed: args.u64("seed", 0),
        sample_tries: 4000,
    };
    let dse = DseRun {
        spec: spec.clone(),
        phase: Phase::Training,
        batch: 0,
        mqa: false,
        wafers: None,
        fidelity,
        explorer: Explorer::Mfmobo,
        cfg,
        n1: args.usize("n1", 16),
        k: 4,
    };

    println!("exploring WSC designs for {} training (MFMOBO)...", spec.name);
    let t0 = std::time::Instant::now();
    let trace = run(&dse).unwrap_or_else(|e| {
        eprintln!("dse_gpt175b: {e}");
        std::process::exit(1);
    });
    println!(
        "{} evaluations in {:.1}s, hypervolume {:.3e}",
        trace.points.len(),
        t0.elapsed().as_secs_f64(),
        trace.final_hv()
    );

    let mut table = Table::new(
        "searched Pareto set vs baselines (GPT-175B training)",
        &["entry", "tokens/s", "power (kW)", "config"],
    );
    let mut front = trace.pareto();
    front.sort_by(|a, b| {
        b.objective
            .throughput
            .partial_cmp(&a.objective.throughput)
            .unwrap()
    });
    for p in front.iter().take(6) {
        table.row(&[
            "pareto".into(),
            format!("{:.0}", p.objective.throughput),
            format!("{:.0}", p.objective.power_w / 1e3),
            p.point.wsc.summary(),
        ]);
    }

    // Baselines under equal area (§IX-F).
    if let Some(g) = theseus::baselines::h100_train_eval(&spec, spec.gpu_num) {
        table.row(&[
            "H100 cluster".into(),
            format!("{:.0}", g.tokens_per_sec),
            format!("{:.0}", g.power_w / 1e3),
            format!("{} x H100 (Megatron 3D parallel)", spec.gpu_num),
        ]);
    }
    for (name, p) in [
        ("WSE2-like", theseus::baselines::wse2_like()),
        ("Dojo-like", theseus::baselines::dojo_like()),
    ] {
        let v = theseus::baselines::force_validate(&p);
        let sys = theseus::eval::SystemConfig::area_matched(v, spec.gpu_num);
        if let Some(r) = theseus::eval::eval_training(&spec, &sys, &theseus::eval::Analytical) {
            table.row(&[
                name.into(),
                format!("{:.0}", r.tokens_per_sec),
                format!("{:.0}", r.power_w / 1e3),
                format!("{} wafers", sys.n_wafers),
            ]);
        }
    }
    table.print();
}
