//! Quickstart: validate a WSC design point, evaluate LLM training and
//! inference on it, and print the numbers.
//!
//!     cargo run --release --example quickstart

use theseus::design_space::{reference_point, validate};
use theseus::eval::{eval_inference, eval_training, Analytical, SystemConfig};
use theseus::workload::models;

fn main() {
    // 1. A design point: the paper's Fig. 13 best-performing shape
    //    (1 TFLOPS cores, 128 KB SRAM, 12x12 cores/reticle, stacked DRAM,
    //    InFO-SoW).
    let point = reference_point();
    println!("design point: {}", point.wsc.summary());

    // 2. Validate against the §V-E constraints (area, power, yield with
    //    redundancy, SRAM feasibility, TSV stress).
    let v = validate(&point).expect("reference point satisfies all constraints");
    println!(
        "validated: {:.1} PFLOPS peak, {:.0} mm2 silicon, wafer yield {:.3}, \
         redundancy {} spare core(s)/row, peak power {:.1} kW",
        v.phys.peak_flops / 1e15,
        v.phys.area_mm2,
        v.phys.wafer_yield,
        v.phys.reticle.red_per_row,
        v.phys.peak_power_w / 1e3,
    );

    // 3. Evaluate GPT-1.7B training on one wafer.
    let spec = models::find("1.7").unwrap();
    let sys = SystemConfig {
        validated: v.clone(),
        n_wafers: 1,
    };
    let train = eval_training(&spec, &sys, &Analytical).expect("feasible strategy");
    println!(
        "\n{} training on 1 wafer:\n  {:.0} tokens/s  (step {:.3}s, strategy tp{} pp{} dp{} mb{})\n  \
         avg power {:.2} kW, {:.2} mJ/token",
        spec.name,
        train.tokens_per_sec,
        train.step_time_s,
        train.strategy.tp,
        train.strategy.pp,
        train.strategy.dp,
        train.strategy.microbatch,
        train.power_w / 1e3,
        train.energy_per_token_j * 1e3,
    );

    // 4. Inference at batch 32 (paper §VIII-A setup).
    let infer = eval_inference(&spec, &sys, 32, false, &Analytical).expect("fits");
    println!(
        "\n{} inference (batch 32):\n  prefill {:.1} ms, decode {:.3} ms/token, {:.0} tokens/s \
         [weights+KV in {}]",
        spec.name,
        infer.prefill_s * 1e3,
        infer.decode_step_s * 1e3,
        infer.tokens_per_sec,
        infer.residency,
    );

    // 5. If `make artifacts` has been run, the GNN congestion model is
    //    available as the high-fidelity NoC estimator.
    match theseus::runtime::GnnModel::load_default() {
        Ok(gnn) => {
            let t = eval_training(&spec, &sys, &gnn).expect("feasible");
            println!(
                "\nwith GNN NoC estimation: {:.0} tokens/s (analytical said {:.0})",
                t.tokens_per_sec, train.tokens_per_sec
            );
        }
        Err(e) => println!("\n(GNN fidelity unavailable: {e})"),
    }
}
