//! END-TO-END DRIVER: exercises every layer of the stack on a real small
//! workload (GPT-1.7B training DSE) and reports the paper's headline
//! metrics. This is the run recorded in EXPERIMENTS.md.
//!
//! Pipeline exercised:
//!   1. design-space sampling + §V-E validation        (L3)
//!   2. workload compiler -> chunk flows               (L3)
//!   3. AOT GNN congestion model over PJRT             (L1+L2 artifacts)
//!   4. random / MOBO / MFMOBO explorers (Algo. 1)     (L3)
//!   5. CA-simulator cross-check of the winning design (L3 ground truth)
//!   6. baseline comparison (H100 / WSE2-like / Dojo-like)
//!
//!     cargo run --release --example end_to_end_dse -- --iters 16 --n1 16

use theseus::coordinator::{ref_power_for, run, DseRun, Explorer};
use theseus::eval::engine::Fidelity;
use theseus::eval::{eval_training, Analytical, SystemConfig};
use theseus::explorer::BoConfig;
use theseus::util::cli::Args;
use theseus::util::json::Json;
use theseus::util::table::Table;
use theseus::workload::{models, Phase};

fn main() {
    let args = Args::from_env();
    let spec = models::find(&args.str("model", "1.7")).unwrap();
    let iters = args.usize("iters", 16);
    let n1 = args.usize("n1", 16);
    let seed = args.u64("seed", 0);
    // High fidelity from the registry; `gnn` degrades to analytical with
    // a note (this driver should run artifact-less containers end to end).
    let requested = Fidelity::parse_or_usage(&args.str("fidelity", "gnn")).unwrap_or_else(|e| {
        eprintln!("end_to_end_dse: {e}");
        std::process::exit(1);
    });
    let fidelity = match theseus::eval::engine::Engine::new(
        theseus::eval::engine::EvalSpec::training(spec.clone()).with_fidelity(requested),
    ) {
        Ok(_) => requested,
        Err(e) => {
            println!("high fidelity {}: {e}; falling back to analytical", requested.name());
            Fidelity::Analytical
        }
    };

    println!("=== Theseus end-to-end DSE: {} training ===", spec.name);
    println!("high fidelity: {}", fidelity.name());

    // --- explorers ---
    let mut results = Vec::new();
    for explorer in [Explorer::Random, Explorer::Mobo, Explorer::Mfmobo] {
        let cfg = BoConfig {
            iters,
            init: 6,
            pool: 48,
            mc_samples: 32,
            ref_power: ref_power_for(&spec),
            seed,
            sample_tries: 4000,
        };
        let dse = DseRun {
            spec: spec.clone(),
            phase: Phase::Training,
            batch: 0,
            mqa: false,
            wafers: None,
            fidelity,
            explorer,
            cfg,
            n1,
            k: 4,
        };
        let t0 = std::time::Instant::now();
        let trace = run(&dse).unwrap_or_else(|e| {
            eprintln!("end_to_end_dse: {e}");
            std::process::exit(1);
        });
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "{:8}: {:3} evals in {:6.1}s -> hypervolume {:.4e}",
            explorer.name(),
            trace.points.len(),
            dt,
            trace.final_hv()
        );
        results.push((explorer, trace, dt));
    }

    // Headline 1: MFMOBO convergence vs MOBO (paper: 2.1x / +42 % HV).
    let hv_mobo = results[1].1.final_hv();
    let hv_mf = results[2].1.final_hv();
    let mf_to_mobo_target = results[2].1.iters_to_hv(hv_mobo);
    println!(
        "\nMFMOBO vs MOBO: HV {:+.1}%, reaches MOBO's final HV after {} evals (MOBO used {})",
        (hv_mf / hv_mobo - 1.0) * 100.0,
        mf_to_mobo_target
            .map(|i| i.to_string())
            .unwrap_or_else(|| "n/a".into()),
        results[1].1.hv_history.len(),
    );

    // --- best searched design, cross-checked against the CA simulator ---
    let best = results
        .iter()
        .flat_map(|(_, t, _)| t.pareto().into_iter().cloned().collect::<Vec<_>>())
        .max_by(|a, b| a.objective.throughput.partial_cmp(&b.objective.throughput).unwrap())
        .expect("at least one evaluated point");
    println!("\nbest design: {}", best.point.wsc.summary());
    let v = theseus::design_space::validate(&best.point).expect("pareto point validates");
    let sys = SystemConfig::area_matched(v, spec.gpu_num);
    let ana = eval_training(&spec, &sys, &Analytical).unwrap();
    // CA cross-check on a representative slice: same design + strategy,
    // reduced sequence so the cycle-accurate run stays seconds-scale.
    let mut ca_spec = spec.clone();
    ca_spec.seq_len = 128;
    ca_spec.batch_size = spec.batch_size.min(64);
    let ana_slice = theseus::eval::chunk::eval_training_with(&ca_spec, &sys, ana.strategy, &Analytical)
        .expect("analytical slice");
    let ca = theseus::eval::chunk::eval_training_with(
        &ca_spec,
        &sys,
        ana.strategy,
        &theseus::eval::CycleAccurate { max_cycles: 400_000_000 },
    );
    println!(
        "cross-check (seq-128 slice): analytical {:.0} tokens/s, CA-simulated {} — agreement within {}",
        ana_slice.tokens_per_sec,
        ca.as_ref()
            .map(|c| format!("{:.0} tokens/s", c.tokens_per_sec))
            .unwrap_or_else(|| "n/a".into()),
        ca.as_ref()
            .map(|c| format!(
                "{:.0}%",
                ((ana_slice.tokens_per_sec / c.tokens_per_sec) - 1.0).abs() * 100.0
            ))
            .unwrap_or_else(|| "-".into()),
    );

    // --- headline 2: WSC vs baselines at equal area (§IX-F) ---
    let mut table = Table::new(
        &format!("{} training: searched WSC vs baselines", spec.name),
        &["system", "tokens/s", "power (kW)", "perf vs H100", "energy/token (mJ)"],
    );
    let gpu = theseus::baselines::h100_train_eval(&spec, spec.gpu_num).expect("gpu baseline");
    table.row(&[
        "H100 cluster".into(),
        format!("{:.0}", gpu.tokens_per_sec),
        format!("{:.0}", gpu.power_w / 1e3),
        "1.00x".into(),
        format!("{:.2}", gpu.energy_per_token_j * 1e3),
    ]);
    table.row(&[
        "Theseus best WSC".into(),
        format!("{:.0}", best.objective.throughput),
        format!("{:.0}", best.objective.power_w / 1e3),
        format!("{:.2}x", best.objective.throughput / gpu.tokens_per_sec),
        format!("{:.2}", ana.energy_per_token_j * 1e3),
    ]);
    for (name, p) in [
        ("WSE2-like", theseus::baselines::wse2_like()),
        ("Dojo-like", theseus::baselines::dojo_like()),
    ] {
        let v = theseus::baselines::force_validate(&p);
        let sys = SystemConfig::area_matched(v, spec.gpu_num);
        if let Some(r) = eval_training(&spec, &sys, &Analytical) {
            table.row(&[
                name.into(),
                format!("{:.0}", r.tokens_per_sec),
                format!("{:.0}", r.power_w / 1e3),
                format!("{:.2}x", r.tokens_per_sec / gpu.tokens_per_sec),
                format!("{:.2}", r.energy_per_token_j * 1e3),
            ]);
        }
    }
    table.print();

    // Persist the run record for EXPERIMENTS.md.
    let mut doc = Json::obj();
    doc.set("model", Json::Str(spec.name.clone()))
        .set("iters", Json::Num(iters as f64))
        .set("hv_random", Json::Num(results[0].1.final_hv()))
        .set("hv_mobo", Json::Num(hv_mobo))
        .set("hv_mfmobo", Json::Num(hv_mf))
        .set("best_tokens_per_sec", Json::Num(best.objective.throughput))
        .set("best_power_w", Json::Num(best.objective.power_w))
        .set("gpu_tokens_per_sec", Json::Num(gpu.tokens_per_sec))
        .set(
            "speedup_vs_h100",
            Json::Num(best.objective.throughput / gpu.tokens_per_sec),
        );
    let _ = std::fs::create_dir_all("artifacts/bench");
    let _ = std::fs::write("artifacts/bench/end_to_end_dse.json", doc.to_pretty());
    println!("\nrun record -> artifacts/bench/end_to_end_dse.json");
}
