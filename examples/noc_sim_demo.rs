//! Substrate example: drive the cycle-accurate NoC simulator directly —
//! the classic load-latency curve under uniform random traffic, plus one
//! compiled transformer chunk with its per-link waiting profile.
//!
//!     cargo run --release --example noc_sim_demo

use theseus::arch::{CoreConfig, Dataflow};
use theseus::compiler::compile_chunk;
use theseus::noc_sim::{naive_compute_cycles, simulate_chunk_result, CoreProgram, Instr, Simulator};
use theseus::util::rng::Rng;
use theseus::util::table::Table;
use theseus::workload::models::benchmarks;
use theseus::workload::{OpGraph, Phase};

fn uniform_traffic(h: usize, w: usize, pkts_per_core: usize, seed: u64) -> Vec<CoreProgram> {
    let mut rng = Rng::new(seed);
    let mut progs: Vec<Vec<Instr>> = (0..h * w).map(|_| Vec::new()).collect();
    let mut expected = vec![0u32; h * w];
    for core in 0..h * w {
        for _ in 0..pkts_per_core {
            let dst = (rng.below(h), rng.below(w));
            let dc = dst.0 * w + dst.1;
            if dc == core {
                continue;
            }
            progs[core].push(Instr::Send {
                dst,
                bytes: 4.0 * 64.0,
                tag: 0,
            });
            expected[dc] += 1;
        }
    }
    for core in 0..h * w {
        if expected[core] > 0 {
            progs[core].push(Instr::Recv {
                tag: 0,
                packets: expected[core],
            });
        }
    }
    progs
        .into_iter()
        .map(|instrs| CoreProgram {
            instrs,
            flit_bytes: 64.0,
        })
        .collect()
}

fn main() -> Result<(), theseus::noc_sim::SimError> {
    // 1. Load-latency curve on an 8x8 mesh (the canonical router check).
    let mut t = Table::new(
        "uniform random traffic, 8x8 mesh, 4-flit packets",
        &["pkts/core", "avg latency (cyc)", "drain cycles", "flits moved"],
    );
    for &load in &[1usize, 4, 8, 16, 32, 64] {
        let stats = Simulator::new(8, 8, uniform_traffic(8, 8, load, 1)).try_run(50_000_000)?;
        t.row(&[
            load.to_string(),
            format!("{:.1}", stats.avg_packet_latency()),
            stats.cycles.to_string(),
            stats.link_flits.iter().sum::<u64>().to_string(),
        ]);
    }
    t.print();

    // 2. A real transformer chunk: compile GPT-1.7B's layer onto a 6x6
    //    region and simulate it cycle-accurately.
    let mut spec = benchmarks()[0].clone();
    spec.seq_len = 128;
    let core = CoreConfig {
        dataflow: Dataflow::WS,
        mac_num: 512,
        buffer_kb: 128,
        buffer_bw_bits: 256,
        noc_bw_bits: 512,
    };
    let g = OpGraph::transformer_chunk(&spec, 1, 1, 8, Phase::Prefill, false);
    let chunk = compile_chunk(&g, 6, 6, &core);
    println!(
        "\ncompiled chunk: {} ops, {} flows, {:.1} MB NoC traffic",
        chunk.assignments.len(),
        chunk.flows.len(),
        chunk.total_flow_bytes() / 1e6
    );
    let stats = simulate_chunk_result(
        &chunk,
        core.noc_bw_bits,
        &|op| naive_compute_cycles(chunk.assignments[op].flops_per_core, core.mac_num),
        500_000_000,
    )?;
    println!(
        "cycle-accurate: {} cycles, {} packets, avg packet latency {:.1} cyc",
        stats.cycles,
        stats.packets_done,
        stats.avg_packet_latency()
    );
    let waits = stats.link_wait_mean();
    let busiest = waits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap();
    println!(
        "most congested link: dense index {} with mean wait {:.2} cyc/flit",
        busiest.0, busiest.1
    );
    Ok(())
}
