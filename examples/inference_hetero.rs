//! Domain example: heterogeneous WSC design for LLM inference (paper §V-B
//! and §IX-E) — sweep prefill/decode resource splits at each heterogeneity
//! granularity and report the best configuration per level.
//!
//!     cargo run --release --example inference_hetero -- --model 175b

use theseus::arch::{HeteroConfig, HeteroGranularity, MemoryKind};
use theseus::design_space::{self, stack_capacity_gb};
use theseus::eval::{eval_inference, Analytical, SystemConfig};
use theseus::util::cli::Args;
use theseus::util::rng::Rng;
use theseus::util::table::Table;
use theseus::workload::models;

fn main() {
    let args = Args::from_env();
    let spec = models::find(&args.str("model", "175b")).expect("unknown model");
    let batch = args.usize("batch", 32);
    let mut rng = Rng::new(args.u64("seed", 3));

    // A stacked-memory base design (decode needs the bandwidth).
    let base = loop {
        let mut p = design_space::sample_raw(&mut rng);
        p.wsc.reticle.memory = MemoryKind::Stacking {
            bw_tbps_per_100mm2: 1.0,
            capacity_gb: stack_capacity_gb(1.0),
        };
        if let Ok(v) = design_space::validate(&p) {
            break v;
        }
    };
    println!("base design: {}", base.point.wsc.summary());

    let mut table = Table::new(
        &format!("{} inference: heterogeneity sweep (batch {batch})", spec.name),
        &["granularity", "prefill ratio", "decode bw", "tokens/s", "prefill ms", "decode ms/tok"],
    );

    let mut best: Option<(HeteroGranularity, f64, f64)> = None;
    for gran in HeteroGranularity::ALL {
        for &ratio in &[0.3, 0.5, 0.7] {
            for &bw in &[1.0, 2.0, 4.0] {
                let mut point = base.point;
                point.hetero = HeteroConfig {
                    granularity: gran,
                    prefill_ratio: ratio,
                    decode_stack_bw: bw,
                };
                let Ok(v) = design_space::validate(&point) else { continue };
                let sys = SystemConfig::area_matched(v, spec.gpu_num);
                let Some(r) = eval_inference(&spec, &sys, batch, false, &Analytical) else {
                    continue;
                };
                table.row(&[
                    gran.name().into(),
                    format!("{ratio:.1}"),
                    format!("{bw:.1}"),
                    format!("{:.0}", r.tokens_per_sec),
                    format!("{:.1}", r.prefill_s * 1e3),
                    format!("{:.3}", r.decode_step_s * 1e3),
                ]);
                if best.map(|b| r.tokens_per_sec > b.2).unwrap_or(true) {
                    best = Some((gran, ratio, r.tokens_per_sec));
                }
                if gran == HeteroGranularity::None {
                    break; // ratio/bw don't apply
                }
            }
            if gran == HeteroGranularity::None {
                break;
            }
        }
    }
    table.print();
    if let Some((g, r, t)) = best {
        println!(
            "\nbest: {} granularity at prefill ratio {:.1} -> {:.0} tokens/s \
             (paper takeaway 5 expects reticle-level to win)",
            g.name(),
            r,
            t
        );
    }
}
