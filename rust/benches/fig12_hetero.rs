//! Bench: regenerate Fig. 12 — GPT-175B inference speedup across
//! heterogeneity granularities (takeaway 5: reticle-level wins).
use theseus::bench;

fn main() {
    let (table, rows) = theseus::figures::fig12_hetero_speedup(42).unwrap_or_else(|e| {
        eprintln!("fig12_hetero: {e}");
        std::process::exit(1);
    });
    table.print();
    if let Some(best) = rows
        .iter()
        .max_by(|a, b| a.tokens_per_sec.total_cmp(&b.tokens_per_sec))
    {
        println!(
            "best heterogeneity level: {} (paper expects reticle)",
            best.granularity.name()
        );
    }
    bench::save_json("fig12_hetero", &table.to_json());
}
