//! Bench: regenerate Fig. 13 — the GPT-175B training design space with
//! Pareto frontiers (stacked vs off-chip DRAM) and §IX-F baseline
//! comparisons. THESEUS_BENCH_SCALE scales the sample count.
use theseus::bench;

fn main() {
    let samples = 40 * bench::scale();
    let (table, result) = theseus::figures::fig13_design_space(7, samples, 42);
    table.print();
    for (name, gain, saving) in &result.comparisons {
        println!(
            "vs {name}: best perf gain {:+.1}% at <= power; best power saving {:+.1}% at >= perf",
            gain * 100.0,
            saving * 100.0
        );
    }
    bench::save_json("fig13_design_space", &table.to_json());
}
