//! Bench: regenerate Fig. 9 — training throughput and EDP vs core compute
//! granularity, split by integration style (die stitching vs InFO-SoW).
use theseus::bench;

fn main() {
    let per_grid = 6 * bench::scale();
    for bi in [0usize, 7] {
        let (table, rows) = theseus::figures::fig9_core_granularity(bi, per_grid, 42);
        table.print();
        // Takeaway-1 summary: where does the optimum land?
        let best = rows
            .iter()
            .max_by(|a, b| a.best_throughput.partial_cmp(&b.best_throughput).unwrap())
            .unwrap();
        println!(
            "optimal core granularity: {:.0} GFLOPS ({}) — paper finds 512G-1T FLOPS",
            best.core_gflops,
            best.style.name()
        );
        bench::save_json(&format!("fig9_core_granularity_b{bi}"), &table.to_json());
    }
}
