//! Bench: regenerate Fig. 7 — evaluation speedup (a) and accuracy (b) of
//! CA simulation vs the analytical model vs the GNN.
//! Scale: THESEUS_BENCH_SCALE multiplies benchmarks/configs covered.
use theseus::bench;

fn main() {
    let scale = bench::scale();
    // Per-chunk timing: prefer the --batch 1 sibling artifact so the
    // Fig. 7 per-evaluation numbers don't pay the batched executable's
    // full slot count per prediction.
    let gnn = theseus::runtime::GnnModel::load_per_chunk_default().ok();
    let gnn_ref: Option<&dyn theseus::eval::NocEstimator> =
        gnn.as_ref().map(|g| g as &dyn theseus::eval::NocEstimator);
    if gnn_ref.is_none() {
        eprintln!("note: GNN artifact missing; run `make artifacts` for full Fig. 7");
    }
    let (table, _rows) =
        theseus::figures::fig7_eval_comparison(3 * scale.min(2) + 1, 4 * scale, gnn_ref, 42)
            .expect("CA simulation exceeded its cycle budget");
    table.print();
    bench::save_json("fig7_eval", &table.to_json());
}
