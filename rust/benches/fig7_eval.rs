//! Bench: regenerate Fig. 7 — evaluation speedup (a) and accuracy (b) of
//! CA simulation vs the analytical model vs the GNN.
//! Scale: THESEUS_BENCH_SCALE multiplies benchmarks/configs covered.
use theseus::bench;

fn main() {
    let scale = bench::scale();
    // The high-fidelity column comes from the Fidelity registry
    // (THESEUS_FIG7_FIDELITY, default `gnn` — the per-chunk --batch 1
    // artifact; `gnn-test` exercises the column without artifacts). An
    // unavailable backend degrades to analytical-only rows with a note.
    let name = std::env::var("THESEUS_FIG7_FIDELITY").unwrap_or_else(|_| "gnn".to_string());
    let fidelity = theseus::eval::engine::Fidelity::parse_or_usage(&name).unwrap_or_else(|e| {
        eprintln!("fig7: {e}");
        std::process::exit(1);
    });
    let (table, _rows) = theseus::figures::fig7_eval_comparison(
        3 * scale.min(2) + 1,
        4 * scale,
        Some(fidelity),
        42,
    )
    .expect("CA simulation exceeded its cycle budget");
    table.print();
    bench::save_json("fig7_eval", &table.to_json());
}
