//! Bench: regenerate Fig. 11 — inference speedup over H100 at equal area:
//! (a) SRAM-resident GPT-1.7B vs SRAM bandwidth, (b) GPT-175B vs stacked
//! DRAM bandwidth, both with/without MQA.
use theseus::bench;

fn main() {
    for part_b in [false, true] {
        let (table, rows) = theseus::figures::fig11_inference_speedup(part_b, 42);
        table.print();
        let best = rows
            .iter()
            .max_by(|a, b| a.speedup.partial_cmp(&b.speedup).unwrap());
        if let Some(b) = best {
            println!(
                "max speedup {}: {:.1}x (paper: up to {} without MQA)",
                if part_b { "fig11b" } else { "fig11a" },
                b.speedup,
                if part_b { "9.8x" } else { "16.9x" }
            );
        }
        bench::save_json(
            if part_b { "fig11b_inference" } else { "fig11a_inference" },
            &table.to_json(),
        );
    }
}
