//! Bench: regenerate Fig. 8 — random vs MOBO vs MFMOBO hypervolume curves
//! (GPT-1.7B / 175B / 530B), with the convergence-speedup summary.
//! Scale knobs: THESEUS_BENCH_SCALE, THESEUS_BO_ITERS, THESEUS_BO_REPEATS.
use theseus::bench;
use theseus::util::cli::env_usize;

fn main() {
    let iters = env_usize("THESEUS_BO_ITERS", 16 * bench::scale());
    let repeats = env_usize("THESEUS_BO_REPEATS", 2 * bench::scale());
    // High fidelity from the registry (THESEUS_FIG8_FIDELITY, default
    // `gnn`; falls back to analytical with a note when unavailable).
    let name = std::env::var("THESEUS_FIG8_FIDELITY").unwrap_or_else(|_| "gnn".to_string());
    let fidelity = theseus::eval::engine::Fidelity::parse_or_usage(&name).unwrap_or_else(|e| {
        eprintln!("fig8: {e}");
        std::process::exit(1);
    });
    // Benchmarks 0/7/9 = GPT-1.7B / GPT-175B / GPT-529.6B (Fig. 8's trio).
    let (table, results) =
        theseus::figures::fig8_explorer_comparison(&[0, 7, 9], iters, repeats, fidelity);
    table.print();
    let speedups: Vec<f64> = results.iter().map(|r| r.convergence_speedup).collect();
    println!(
        "mean MFMOBO convergence speedup: {:.2}x (paper reports 2.1x)",
        theseus::util::stats::mean(&speedups)
    );
    bench::save_json("fig8_explorer", &table.to_json());
}
