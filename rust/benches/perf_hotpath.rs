//! Bench: hot-path microbenchmarks for the §Perf optimization pass —
//! op-level evaluation throughput, CA-sim cycle rate, GP fit/predict,
//! validator throughput and (if built) GNN inference latency.
use theseus::arch::{CoreConfig, Dataflow};
use theseus::bench;
use theseus::compiler::compile_chunk;
use theseus::eval::op_level::{chunk_latency, NocModel};
use theseus::util::rng::Rng;
use theseus::util::table::Table;
use theseus::workload::models::benchmarks;
use theseus::workload::{OpGraph, Phase};

fn main() {
    let mut t = Table::new(
        "perf hot paths",
        &["path", "median", "unit"],
    );

    // 1. Op-level analytical evaluation (the DSE inner loop).
    let mut spec = benchmarks()[0].clone();
    spec.seq_len = 256;
    let core = CoreConfig {
        dataflow: Dataflow::WS,
        mac_num: 512,
        buffer_kb: 128,
        buffer_bw_bits: 256,
        noc_bw_bits: 512,
    };
    let g = OpGraph::transformer_chunk(&spec, 2, 1, 8, Phase::Training, false);
    let chunk = compile_chunk(&g, 12, 12, &core);
    let tm = bench::time("op_level_analytical", 2, 20, || {
        std::hint::black_box(chunk_latency(&chunk, &core, 1.0, NocModel::Analytical));
    });
    t.row(&["op-level analytical (12x12, 2-layer bwd)".into(), format!("{:.3} ms", tm.median_s * 1e3), "per chunk".into()]);

    // 2. Full training evaluation of one design point.
    let v = theseus::design_space::validate(&theseus::design_space::reference_point()).unwrap();
    let full_spec = benchmarks()[0].clone();
    let tm = bench::time("eval_training", 1, 5, || {
        let sys = theseus::eval::SystemConfig { validated: v.clone(), n_wafers: 1 };
        std::hint::black_box(theseus::eval::eval_training(&full_spec, &sys, &theseus::eval::Analytical));
    });
    t.row(&["eval_training (strategy search)".into(), format!("{:.1} ms", tm.median_s * 1e3), "per design point".into()]);

    // 3. Design point validation (yield + floorplan + power).
    let mut rng = Rng::new(1);
    let pts: Vec<_> = (0..64).map(|_| theseus::design_space::sample_raw(&mut rng)).collect();
    let tm = bench::time("validate", 1, 10, || {
        for p in &pts {
            std::hint::black_box(theseus::design_space::validate(p).ok());
        }
    });
    t.row(&["validator".into(), format!("{:.1} us", tm.median_s / 64.0 * 1e6), "per raw point".into()]);

    // 4. CA simulator cycle rate.
    let mut small = benchmarks()[0].clone();
    small.seq_len = 64;
    let g = OpGraph::transformer_chunk(&small, 1, 1, 8, Phase::Prefill, false);
    let ch = compile_chunk(&g, 6, 6, &core);
    let (stats, wall) = bench::time_once(|| {
        theseus::noc_sim::simulate_chunk(
            &ch, 512,
            &|op| theseus::noc_sim::naive_compute_cycles(ch.assignments[op].flops_per_core, 512),
            500_000_000,
        )
    });
    t.row(&["CA simulator".into(), format!("{:.2} Mcyc/s", stats.cycles as f64 / wall / 1e6), "6x6 mesh".into()]);

    // 5. GP fit + predict at n=100.
    let mut rng = Rng::new(2);
    let xs: Vec<Vec<f64>> = (0..100).map(|_| (0..12).map(|_| rng.f64()).collect()).collect();
    let ys: Vec<f64> = xs.iter().map(|x| x.iter().sum()).collect();
    let tm = bench::time("gp_fit", 1, 5, || {
        std::hint::black_box(theseus::explorer::gp::Gp::fit(&xs, &ys));
    });
    t.row(&["GP fit (n=100, d=12)".into(), format!("{:.1} ms", tm.median_s * 1e3), "per refit".into()]);

    // 6. GNN inference via PJRT (if artifacts exist).
    if let Ok(gnn) = theseus::runtime::GnnModel::load_default() {
        let inp = theseus::runtime::features::build(&ch, &core).unwrap();
        let tm = bench::time("gnn_predict", 2, 10, || {
            std::hint::black_box(gnn.predict_padded(&inp).unwrap());
        });
        t.row(&["GNN inference (PJRT, padded 256/1024)".into(), format!("{:.2} ms", tm.median_s * 1e3), "per chunk".into()]);
    }

    t.print();
    bench::save_json("perf_hotpath", &t.to_json());
}
