//! Bench: hot-path microbenchmarks for the §Perf optimization pass —
//! op-level evaluation throughput, compile-cache behavior, cold-vs-warm
//! design-point evaluation, the batched analytical sweep and incremental
//! (delta-cache) re-evaluation, CA-sim cycle rate, GP fit/incremental-
//! update, validator throughput and (if built) GNN inference latency.
//!
//! The `median` column is numeric (unit in the `unit` column) so
//! `scripts/bench_check.sh` can diff this run against the committed
//! baseline `BENCH_perf_hotpath.json` with a regression gate.
use theseus::arch::{CoreConfig, Dataflow};
use theseus::bench;
use theseus::compiler::cache::ChunkCache;
use theseus::compiler::compile_chunk;
use theseus::eval::op_level::{chunk_latency, chunk_latency_with_topo, ChunkTopology, NocModel};
use theseus::eval::engine::{Engine, EvalSpec};
use theseus::eval::{eval_training, Analytical, SystemConfig};
use theseus::noc_sim::{reference, CoreProgram, Instr, Simulator};
use theseus::util::rng::Rng;
use theseus::util::table::Table;
use theseus::workload::models::benchmarks;
use theseus::workload::{OpGraph, Phase};

/// Hand-built mesh programs for the event-vs-reference simulator rows.
fn mesh_programs(h: usize, w: usize, per_core: Vec<(usize, Vec<Instr>)>) -> Vec<CoreProgram> {
    let mut progs = vec![
        CoreProgram {
            instrs: Vec::new(),
            flit_bytes: 64.0,
        };
        h * w
    ];
    for (core, instrs) in per_core {
        progs[core].instrs = instrs;
    }
    progs
}

fn main() {
    let mut t = Table::new(
        "perf hot paths",
        &["path", "median", "unit"],
    );

    // 1. Op-level analytical evaluation (the DSE inner loop), with and
    //    without a pre-built (cache-resident) topology.
    let mut spec = benchmarks()[0].clone();
    spec.seq_len = 256;
    let core = CoreConfig {
        dataflow: Dataflow::WS,
        mac_num: 512,
        buffer_kb: 128,
        buffer_bw_bits: 256,
        noc_bw_bits: 512,
    };
    let g = OpGraph::transformer_chunk(&spec, 2, 1, 8, Phase::Training, false);
    let chunk = compile_chunk(&g, 12, 12, &core);
    let tm = bench::time("op_level_analytical", 2, 20, || {
        std::hint::black_box(chunk_latency(&chunk, &core, 1.0, NocModel::Analytical));
    });
    t.row(&["op_level_analytical".into(), format!("{:.4}", tm.median_s * 1e3), "ms per chunk (12x12, 2-layer bwd)".into()]);
    let topo = ChunkTopology::new(&chunk);
    let tm = bench::time("op_level_cached_topo", 2, 20, || {
        std::hint::black_box(chunk_latency_with_topo(
            &chunk,
            &topo,
            &core,
            1.0,
            NocModel::Analytical,
        ));
    });
    t.row(&["op_level_cached_topo".into(), format!("{:.4}", tm.median_s * 1e3), "ms per chunk (topology reused)".into()]);

    // 2. Compile-chunk cache: cold compile vs warm (hit-path) fetch.
    let cache = ChunkCache::new(64);
    let tm = bench::time("compile_chunk_cold", 1, 10, || {
        cache.clear();
        std::hint::black_box(cache.get_or_compile(&g, 12, 12, &core));
    });
    t.row(&["compile_chunk_cold".into(), format!("{:.4}", tm.median_s * 1e3), "ms (compile + index)".into()]);
    cache.clear();
    cache.get_or_compile(&g, 12, 12, &core);
    let tm = bench::time("compile_chunk_warm", 2, 20, || {
        std::hint::black_box(cache.get_or_compile(&g, 12, 12, &core));
    });
    t.row(&["compile_chunk_warm".into(), format!("{:.5}", tm.median_s * 1e3), "ms (memo hit)".into()]);

    // 3. Full training evaluation of one design point: cold serial vs
    //    warm pooled, plus the numeric-equivalence guard and the cache
    //    hit rate of a steady-state sweep.
    let v = theseus::design_space::validate(&theseus::design_space::reference_point()).unwrap();
    let full_spec = benchmarks()[0].clone();
    let sys = SystemConfig { validated: v.clone(), n_wafers: 1, faults: None };
    let global = theseus::compiler::cache::global();
    let cold = bench::time("eval_training_cold", 0, 5, || {
        global.clear();
        std::hint::black_box(eval_training(&full_spec, &sys, &Analytical));
    });
    t.row(&["eval_training_cold".into(), format!("{:.3}", cold.median_s * 1e3), "ms per design point (serial, cache cleared)".into()]);
    global.clear();
    let r_serial = eval_training(&full_spec, &sys, &Analytical); // prime cache
    // The engine's analytical backend dispatches the pooled strategy
    // sweep (the warm-path row measures that dispatch).
    let engine = Engine::new(EvalSpec::training(full_spec.clone())).expect("analytical engine");
    let before = global.stats();
    let tiles_before = theseus::eval::tile::tile_cache_stats();
    let warm = bench::time("eval_training_warm_par", 1, 5, || {
        std::hint::black_box(engine.eval_train_system(&sys));
    });
    let after = global.stats();
    let tiles_after = theseus::eval::tile::tile_cache_stats();
    t.row(&["eval_training_warm_par".into(), format!("{:.3}", warm.median_s * 1e3), "ms per design point (pooled, warm cache)".into()]);
    t.row(&["eval_training_speedup".into(), format!("{:.2}", cold.median_s / warm.median_s.max(1e-12)), "x cold-serial / warm-pooled".into()]);
    let swept = (after.hits + after.misses) - (before.hits + before.misses);
    let hit_rate = if swept == 0 {
        0.0
    } else {
        (after.hits - before.hits) as f64 / swept as f64
    };
    t.row(&["compile_cache_hit_rate".into(), format!("{:.4}", hit_rate), "fraction (warm strategy sweep)".into()]);
    let tile_lookups =
        (tiles_after.hits + tiles_after.misses) - (tiles_before.hits + tiles_before.misses);
    let tile_hit_rate = if tile_lookups == 0 {
        0.0
    } else {
        (tiles_after.hits - tiles_before.hits) as f64 / tile_lookups as f64
    };
    t.row(&["tile_cache_hit_rate".into(), format!("{:.4}", tile_hit_rate), "fraction (warm strategy sweep)".into()]);
    // Equivalence guard: pooled + cached must match serial + cold.
    let r_par = engine.eval_train_system(&sys);
    let rel = match (&r_serial, &r_par) {
        (Some(a), Some(b)) => {
            (a.tokens_per_sec - b.tokens_per_sec).abs() / a.tokens_per_sec.abs().max(1e-300)
        }
        (None, None) => 0.0,
        _ => f64::INFINITY,
    };
    assert!(rel <= 1e-9, "parallel/cached evaluation diverged: rel={rel}");
    t.row(&["eval_match_rel_err".into(), format!("{rel:.2e}"), "serial vs pooled relative diff".into()]);

    // 3b. Batched analytical sweep (ISSUE 7): a candidate slice through
    //     one fused cross-point strategy sweep (`eval_batch`) vs the
    //     per-point pooled loop, plus the incremental (delta-cache)
    //     re-evaluation of an already-seen point. Both optimizations are
    //     gated on bit-identity right here, not just in the test suite.
    {
        use theseus::eval::{delta_cache_clear, delta_cache_stats};
        use theseus::explorer::DesignEval;
        let mut rng = Rng::new(7);
        let mut pts = vec![v.clone()];
        for _ in 0..500 {
            if pts.len() >= 6 {
                break;
            }
            if let Some(p) = theseus::design_space::sample_valid(&mut rng, 64) {
                pts.push(p);
            }
        }
        assert!(pts.len() >= 2, "could not sample a candidate slice");
        let serial = bench::time("analytical_batch_sweep_serial", 1, 5, || {
            delta_cache_clear();
            for p in &pts {
                std::hint::black_box(engine.eval(p));
            }
        });
        let batched = bench::time("analytical_batch_sweep_batched", 1, 5, || {
            delta_cache_clear();
            std::hint::black_box(engine.eval_batch(&pts));
        });
        t.row(&["analytical_batch_sweep_serial".into(), format!("{:.3}", serial.median_s * 1e3), format!("ms per {}-point slice (per-point pooled loop)", pts.len())]);
        t.row(&["analytical_batch_sweep_batched".into(), format!("{:.3}", batched.median_s * 1e3), "ms per slice (fused cross-point sweep)".into()]);
        t.row(&["analytical_batch_sweep_speedup".into(), format!("{:.2}", serial.median_s / batched.median_s.max(1e-12)), "x per-point / batched".into()]);
        delta_cache_clear();
        let per_point: Vec<_> = pts.iter().map(|p| engine.eval(p)).collect();
        delta_cache_clear();
        let in_batch = engine.eval_batch(&pts);
        for (i, (a, b)) in per_point.iter().zip(&in_batch).enumerate() {
            match (a, b) {
                (Some(a), Some(b)) => assert!(
                    a.throughput.to_bits() == b.throughput.to_bits()
                        && a.power_w.to_bits() == b.power_w.to_bits(),
                    "batched sweep diverged from per-point eval at point {i}"
                ),
                (None, None) => {}
                _ => panic!("batched sweep feasibility diverged at point {i}"),
            }
        }

        let target = &pts[1];
        let cold = bench::time("incremental_reeval_cold", 1, 5, || {
            delta_cache_clear();
            std::hint::black_box(engine.eval(target));
        });
        delta_cache_clear();
        let r_cold = engine.eval(target); // prime the delta cache
        let before = delta_cache_stats();
        let warm = bench::time("incremental_reeval_warm", 1, 10, || {
            std::hint::black_box(engine.eval(target));
        });
        let after = delta_cache_stats();
        if before.capacity > 0 {
            assert!(after.hits > before.hits, "warm re-evaluation must hit the delta cache");
        }
        let r_warm = engine.eval(target);
        match (&r_cold, &r_warm) {
            (Some(a), Some(b)) => assert!(
                a.throughput.to_bits() == b.throughput.to_bits()
                    && a.power_w.to_bits() == b.power_w.to_bits(),
                "incremental re-evaluation diverged from cold"
            ),
            (None, None) => {}
            _ => panic!("incremental re-evaluation feasibility diverged from cold"),
        }
        t.row(&["incremental_reeval_cold".into(), format!("{:.3}", cold.median_s * 1e3), "ms per design point (delta cache cleared)".into()]);
        t.row(&["incremental_reeval_warm".into(), format!("{:.3}", warm.median_s * 1e3), "ms per design point (delta-cache hits)".into()]);
        t.row(&["incremental_reeval_speedup".into(), format!("{:.2}", cold.median_s / warm.median_s.max(1e-12)), "x cold / warm re-evaluation".into()]);
    }

    // 4. Design point validation (yield + floorplan + power).
    let mut rng = Rng::new(1);
    let pts: Vec<_> = (0..64).map(|_| theseus::design_space::sample_raw(&mut rng)).collect();
    let tm = bench::time("validate", 1, 10, || {
        for p in &pts {
            std::hint::black_box(theseus::design_space::validate(p).ok());
        }
    });
    t.row(&["validate".into(), format!("{:.2}", tm.median_s / 64.0 * 1e6), "us per raw point".into()]);

    // 5. CA simulator cycle rate.
    let mut small = benchmarks()[0].clone();
    small.seq_len = 64;
    let g = OpGraph::transformer_chunk(&small, 1, 1, 8, Phase::Prefill, false);
    let ch = compile_chunk(&g, 6, 6, &core);
    let (stats, wall) = bench::time_once(|| {
        theseus::noc_sim::simulate_chunk_result(
            &ch, 512,
            &|op| theseus::noc_sim::naive_compute_cycles(ch.assignments[op].flops_per_core, 512),
            500_000_000,
        )
        .expect("CA simulation within budget")
    });
    t.row(&["ca_simulator".into(), format!("{:.2}", stats.cycles as f64 / wall / 1e6), "Mcyc/s (6x6 mesh)".into()]);

    // 5b. Event-driven vs frozen per-cycle reference stepper.
    //
    // Sparse: a corner-to-corner exchange with long compute gaps on a
    // 40x40 mesh that is otherwise idle — the event-driven fast path
    // (ISSUE 2 target: >= 5x; the receiver blocks on RECV, so the old
    // all-or-nothing skip never fires and the reference pays O(cores)
    // every cycle). Congested: all-to-hotspot on 12x12 — every router
    // active, the event-driven engine's worst case (recorded so drift in
    // its constant factor is gated too).
    {
        let (h, w) = (40usize, 40usize);
        let rounds = 40u32;
        let mut tx = Vec::new();
        for _ in 0..rounds {
            tx.push(Instr::Compute { cycles: 300 });
            tx.push(Instr::Send { dst: (h - 1, w - 1), bytes: 16.0 * 64.0, tag: 0 });
        }
        let sparse = vec![
            (0, tx),
            (h * w - 1, vec![Instr::Recv { tag: 0, packets: rounds }]),
        ];
        let budget = 50_000_000;
        let (ev_stats, _) = bench::time_once(|| {
            Simulator::new(h, w, mesh_programs(h, w, sparse.clone()))
                .try_run(budget)
                .expect("completes within budget")
        });
        let (ref_stats, _) = bench::time_once(|| {
            reference::Simulator::new(h, w, mesh_programs(h, w, sparse.clone())).run(budget)
        });
        assert_eq!(ev_stats, ref_stats, "event-driven sim diverged from reference oracle");
        let ev = bench::time("noc_sim_sparse_event", 1, 10, || {
            std::hint::black_box(
                Simulator::new(h, w, mesh_programs(h, w, sparse.clone()))
                    .try_run(budget)
                    .expect("completes within budget"),
            );
        });
        let rf = bench::time("noc_sim_sparse_ref", 1, 5, || {
            std::hint::black_box(
                reference::Simulator::new(h, w, mesh_programs(h, w, sparse.clone())).run(budget),
            );
        });
        t.row(&["noc_sim_sparse_event".into(), format!("{:.4}", ev.median_s * 1e3), "ms (40x40 mesh, 2 active cores)".into()]);
        t.row(&["noc_sim_sparse_ref".into(), format!("{:.4}", rf.median_s * 1e3), "ms (reference per-cycle stepper)".into()]);
        let speedup = rf.median_s / ev.median_s.max(1e-12);
        t.row(&["noc_sim_sparse_speedup".into(), format!("{:.1}", speedup), "x event-driven / reference".into()]);
        assert!(
            speedup >= 5.0,
            "sparse-traffic event-driven speedup below the 5x floor: {speedup:.1}x"
        );

        let (gh, gw) = (12usize, 12usize);
        let hotspot = (gh / 2, gw / 2);
        let hot_core = hotspot.0 * gw + hotspot.1;
        let mut congested = Vec::new();
        let mut expected = 0u32;
        for core in 0..gh * gw {
            if core == hot_core {
                continue;
            }
            let mut instrs = Vec::new();
            for _ in 0..6 {
                instrs.push(Instr::Send { dst: hotspot, bytes: 16.0 * 64.0, tag: 0 });
                expected += 1;
            }
            congested.push((core, instrs));
        }
        congested.push((hot_core, vec![Instr::Recv { tag: 0, packets: expected }]));
        let (evc_stats, _) = bench::time_once(|| {
            Simulator::new(gh, gw, mesh_programs(gh, gw, congested.clone()))
                .try_run(budget)
                .expect("completes within budget")
        });
        let (refc_stats, _) = bench::time_once(|| {
            reference::Simulator::new(gh, gw, mesh_programs(gh, gw, congested.clone())).run(budget)
        });
        assert_eq!(evc_stats, refc_stats, "congested case diverged from reference oracle");
        let evc = bench::time("noc_sim_congested_event", 1, 5, || {
            std::hint::black_box(
                Simulator::new(gh, gw, mesh_programs(gh, gw, congested.clone()))
                    .try_run(budget)
                    .expect("completes within budget"),
            );
        });
        let rfc = bench::time("noc_sim_congested_ref", 1, 5, || {
            std::hint::black_box(
                reference::Simulator::new(gh, gw, mesh_programs(gh, gw, congested.clone())).run(budget),
            );
        });
        t.row(&["noc_sim_congested_event".into(), format!("{:.4}", evc.median_s * 1e3), "ms (12x12 all-to-hotspot)".into()]);
        t.row(&["noc_sim_congested_ref".into(), format!("{:.4}", rfc.median_s * 1e3), "ms (reference per-cycle stepper)".into()]);
        t.row(&["noc_sim_congested_ratio".into(), format!("{:.2}", rfc.median_s / evc.median_s.max(1e-12)), "x event-driven / reference".into()]);
    }

    // 5c. Ground-truth dataset generation: serial loop vs pooled fan-out
    // (each sample is an independent CA sim; ISSUE 2 target: >= 2x on a
    // multi-core reference machine — the ratio approaches the worker
    // count as samples per worker grow).
    {
        let n_samples = 8;
        let (doc_serial, t_serial) =
            bench::time_once(|| theseus::noc_sim::dataset::gen_dataset_serial(n_samples, 42));
        let (doc_par, t_par) =
            bench::time_once(|| theseus::noc_sim::dataset::gen_dataset(n_samples, 42));
        let doc_serial = doc_serial.expect("serial dataset generation within budget");
        let doc_par = doc_par.expect("pooled dataset generation within budget");
        assert_eq!(
            doc_serial.to_string(),
            doc_par.to_string(),
            "pooled dataset generation must be byte-identical to serial"
        );
        t.row(&["noc_dataset_serial".into(), format!("{:.2}", t_serial * 1e3), format!("ms ({n_samples} samples, serial)")]);
        t.row(&["noc_dataset_par".into(), format!("{:.2}", t_par * 1e3), format!("ms ({n_samples} samples, {} workers)", theseus::util::pool::num_threads())]);
        t.row(&["noc_dataset_par_speedup".into(), format!("{:.2}", t_serial / t_par.max(1e-12)), "x serial / pooled".into()]);
    }

    // 6. GP fit vs incremental rank-1 update at n=100.
    let mut rng = Rng::new(2);
    let xs: Vec<Vec<f64>> = (0..100).map(|_| (0..12).map(|_| rng.f64()).collect()).collect();
    let ys: Vec<f64> = xs.iter().map(|x| x.iter().sum()).collect();
    let fit = bench::time("gp_fit", 1, 5, || {
        std::hint::black_box(theseus::explorer::gp::Gp::fit(&xs, &ys));
    });
    t.row(&["gp_fit_n100".into(), format!("{:.3}", fit.median_s * 1e3), "ms per refit (n=100, d=12)".into()]);
    let mut gp = theseus::explorer::gp::Gp::fit(&xs, &ys);
    let mut add_rng = Rng::new(3);
    // < GP_REFIT_EVERY timed adds, so every one is a rank-1 border.
    let add = bench::time("gp_add", 0, 10, || {
        let x: Vec<f64> = (0..12).map(|_| add_rng.f64()).collect();
        let y: f64 = x.iter().sum();
        gp.add(&x, y);
    });
    t.row(&["gp_add_n100".into(), format!("{:.4}", add.median_s * 1e3), "ms per incremental update (n~100)".into()]);
    t.row(&["gp_update_speedup".into(), format!("{:.2}", fit.median_s / add.median_s.max(1e-12)), "x full refit / rank-1 add".into()]);

    // 7. Batched GNN link-wait inference over a sweep-like mixed chunk
    //    set. On the default build only the TestBackend exists: its rows
    //    gate the batcher's packing/scatter overhead — the pseudo-GNN has
    //    no per-call dispatch cost, so its batch-1/batch-8 ratio is
    //    expected ~1x (the *dispatch amortization* the batcher exists for
    //    is only measurable on the PJRT rows below, when artifacts exist).
    let mut sweep_spec = benchmarks()[0].clone();
    sweep_spec.seq_len = 64;
    let sg = OpGraph::transformer_chunk(&sweep_spec, 1, 1, 8, Phase::Prefill, false);
    let sweep_sizes: [(usize, usize); 8] =
        [(3, 3), (4, 4), (4, 5), (5, 5), (6, 6), (3, 5), (5, 4), (6, 4)];
    let sweep_chunks: Vec<(theseus::compiler::CompiledChunk, CoreConfig)> = sweep_sizes
        .iter()
        .map(|&(h, w)| (compile_chunk(&sg, h, w, &core), core))
        .collect();
    let sweep_reqs: Vec<(&theseus::compiler::CompiledChunk, &CoreConfig)> =
        sweep_chunks.iter().map(|(c, k)| (c, k)).collect();
    {
        use theseus::runtime::batch::GnnBatcher;
        use theseus::runtime::TestBackend;
        let backend = TestBackend::new();
        let b1 = GnnBatcher::new(&backend, 1);
        let b8 = GnnBatcher::new(&backend, 8);
        assert_eq!(
            b1.link_waits_many(&sweep_reqs),
            b8.link_waits_many(&sweep_reqs),
            "batched GNN inference diverged from per-chunk"
        );
        let t1 = bench::time("gnn_batch_infer_b1", 1, 10, || {
            std::hint::black_box(b1.link_waits_many(&sweep_reqs));
        });
        let t8 = bench::time("gnn_batch_infer_b8", 1, 10, || {
            std::hint::black_box(b8.link_waits_many(&sweep_reqs));
        });
        t.row(&["gnn_batch_infer_b1".into(), format!("{:.4}", t1.median_s * 1e3), "ms per 8-chunk sweep (batch=1, TestBackend)".into()]);
        t.row(&["gnn_batch_infer_b8".into(), format!("{:.4}", t8.median_s * 1e3), "ms per 8-chunk sweep (batch=8, TestBackend)".into()]);
        t.row(&["gnn_batch_infer_speedup".into(), format!("{:.2}", t1.median_s / t8.median_s.max(1e-12)), "x batch-1 / batch-8 (TestBackend: packing overhead only, ~1x expected)".into()]);
    }

    // 7b. GNN inference via PJRT (if artifacts exist): per-chunk latency
    //     (on the --batch 1 sibling artifact, so the row keeps measuring
    //     one chunk's cost) plus the real dispatch-amortization ratio of
    //     the batcher on the default (batched) artifact.
    if let Ok(gnn_chunk) = theseus::runtime::GnnModel::load_per_chunk_default() {
        let inp = theseus::runtime::features::build(&ch, &core).unwrap();
        let tm = bench::time("gnn_predict", 2, 10, || {
            std::hint::black_box(gnn_chunk.predict_padded(&inp).unwrap());
        });
        t.row(&["gnn_predict".into(), format!("{:.3}", tm.median_s * 1e3), "ms per chunk (PJRT, padded 256/1024)".into()]);

        if let Ok(gnn) = theseus::runtime::GnnModel::load_default() {
            use theseus::runtime::batch::GnnBatcher;
            // Fair baseline: the batch-1 row drives the per-chunk sibling
            // executable, so the ratio isolates dispatch amortization
            // rather than the padded-slot waste a batched artifact pays
            // per single prediction. (Without a sibling on disk both
            // loaders return the same artifact and the ratio degrades to
            // the confounded measurement — export with --batch > 1 to get
            // the sibling.)
            let b1 = GnnBatcher::new(&gnn_chunk, 1);
            let b8 = GnnBatcher::new(&gnn, 8);
            let t1 = bench::time("gnn_batch_infer_pjrt_b1", 1, 5, || {
                std::hint::black_box(b1.link_waits_many(&sweep_reqs));
            });
            let t8 = bench::time("gnn_batch_infer_pjrt_b8", 1, 5, || {
                std::hint::black_box(b8.link_waits_many(&sweep_reqs));
            });
            t.row(&["gnn_batch_infer_pjrt_b1".into(), format!("{:.3}", t1.median_s * 1e3), "ms per 8-chunk sweep (batch=1, sibling artifact)".into()]);
            t.row(&["gnn_batch_infer_pjrt_b8".into(), format!("{:.3}", t8.median_s * 1e3), "ms per 8-chunk sweep (batch=8, PJRT)".into()]);
            t.row(&["gnn_batch_infer_pjrt_speedup".into(), format!("{:.2}", t1.median_s / t8.median_s.max(1e-12)), "x batch-1 / batch-8 (PJRT dispatch amortization)".into()]);
        }
    }

    t.print();
    bench::save_json("perf_hotpath", &t.to_json());
}
