//! Bench: regenerate Fig. 10 — training throughput vs reticle granularity
//! for GPT-3, with the reticle-area fraction of the optima (paper: best
//! designs occupy 50-60% of the reticle limit).
use theseus::bench;

fn main() {
    let (table, rows) = theseus::figures::fig10_reticle_granularity(7, 42);
    table.print();
    if let Some(best) = rows
        .iter()
        .max_by(|a, b| a.throughput.partial_cmp(&b.throughput).unwrap())
    {
        println!(
            "best reticle: {:.0} TFLOPS at {:.0}% of the reticle area limit \
             (paper: 144 TFLOPS at 50-60%)",
            best.reticle_tflops,
            best.area_fraction * 100.0
        );
    }
    bench::save_json("fig10_reticle_granularity", &table.to_json());
}
