//! Tile-level evaluation (paper §VI-B): latency of one operator tile on one
//! core with a fixed dataflow — loop unrolling/tiling over the MAC array,
//! SRAM-capacity-limited reuse, and bandwidth-limited operand feeds.
//!
//! The DSE hot path re-evaluates the same (assignment, core, scale) tiles
//! across every strategy probe and NoC-model swap once compile + topology
//! are memoized, so [`eval_tile_cached`] memoizes results in a process-wide
//! [`Memo`] keyed by every input the model reads (bounded by
//! `THESEUS_TILE_CACHE`, default 65536 entries, 0 disables).

use std::sync::OnceLock;

use crate::arch::{constants as k, CoreConfig, Dataflow};
use crate::compiler::OpAssignment;
use crate::util::memo::{Memo, MemoStats};
use crate::workload::OpKind;

/// Tile-level result for one op on one core.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TileEval {
    /// Core-cycles to execute the tile.
    pub cycles: f64,
    /// MAC-array utilization achieved (0–1].
    pub utilization: f64,
    /// SRAM bytes moved (for power accounting), including reload traffic.
    pub sram_bytes: f64,
    /// MAC operations executed (for power accounting).
    pub mac_ops: f64,
}

/// Dataflow utilization: fraction of the MAC array kept busy by a tile of
/// the given GEMM dims. The stationary tensor's two dims map onto the
/// array; dims smaller than the array waste lanes (§IX-A "Utilization").
fn gemm_utilization(df: Dataflow, m: f64, kk: f64, n: f64, rows: usize, cols: usize) -> f64 {
    let (a, b) = match df {
        Dataflow::WS => (kk, n),
        Dataflow::IS => (m, kk),
        Dataflow::OS => (m, n),
    };
    let ua = (a / rows as f64).min(1.0);
    let ub = (b / cols as f64).min(1.0);
    (ua * ub).max(1e-3)
}

/// Evaluate one op assignment on `core`. `scale` divides the per-core tile
/// further when the op actually spreads over more cores than the compiled
/// region (hierarchical evaluation: the region is a representative slice).
pub fn eval_tile(a: &OpAssignment, core: &CoreConfig, scale: f64) -> TileEval {
    let scale = scale.max(1e-12);
    let flops = a.flops_per_core / scale;
    let in_bytes = a.in_bytes_per_core / scale;
    let out_bytes = a.out_bytes_per_core / scale;
    let ws = a.working_set_bytes / scale;

    let (rows, cols) = core.array_dims();
    let util = match a.kind {
        OpKind::Matmul { m, k: kk, n } => gemm_utilization(
            core.dataflow,
            m as f64 / a.placement.grid_h as f64,
            kk as f64,
            n as f64 / a.placement.grid_w as f64,
            rows,
            cols,
        ),
        OpKind::BatchMatmul { m, k: kk, n, .. } => {
            gemm_utilization(core.dataflow, m as f64, kk as f64, n as f64, rows, cols)
        }
        // Vector ops run on one row of the array (lane-parallel).
        _ => (cols as f64 / core.mac_num as f64).min(1.0),
    };

    // SRAM-capacity-limited reuse (§VI-B): if the stationary working set
    // exceeds the buffer, operands stream multiple times.
    let buffer_bytes = core.buffer_kb as f64 * 1024.0;
    let reload = (ws / buffer_bytes).max(1.0);
    let sram_bytes = (in_bytes * reload) + out_bytes;

    let mac_ops = flops / k::FLOPS_PER_MAC;
    let compute_cycles = mac_ops / (core.mac_num as f64 * util);
    let sram_cycles = sram_bytes / (core.buffer_bw_bits as f64 / 8.0);
    let feed_cycles = (in_bytes * reload) / (core.noc_bw_bits as f64 / 8.0);

    TileEval {
        cycles: compute_cycles.max(sram_cycles).max(feed_cycles).max(1.0),
        utilization: util,
        sram_bytes,
        mac_ops,
    }
}

/// Memo key covering *every* input [`eval_tile`] reads: op kind + exact
/// dims, placement grid (Matmul utilization divides by it), the per-core
/// byte/flop loads (IEEE bit patterns — equal bits iff equal inputs), the
/// full core config and the evaluation scale.
type TileKey = (
    (u8, u64, u64, u64, u64), // kind discriminant + dims (bits for KvRead)
    (u64, u64),               // placement grid_h, grid_w
    (u64, u64, u64, u64),     // flops/in/out/working-set per core, as bits
    (u8, u64, u64, u64, u64), // core: dataflow, mac, buf_kb, buf_bw, noc_bw
    u64,                      // scale bits
);

fn tile_key(a: &OpAssignment, core: &CoreConfig, scale: f64) -> TileKey {
    let kind = match a.kind {
        OpKind::Matmul { m, k: kk, n } => (0u8, m as u64, kk as u64, n as u64, 0u64),
        OpKind::BatchMatmul { batch, m, k: kk, n } => (1, batch as u64, m as u64, kk as u64, n as u64),
        OpKind::Softmax { rows, cols } => (2, rows as u64, cols as u64, 0, 0),
        OpKind::LayerNorm { rows, cols } => (3, rows as u64, cols as u64, 0, 0),
        OpKind::Elementwise { elems } => (4, elems as u64, 0, 0, 0),
        OpKind::KvRead { bytes } => (5, bytes.to_bits(), 0, 0, 0),
    };
    (
        kind,
        (a.placement.grid_h as u64, a.placement.grid_w as u64),
        (
            a.flops_per_core.to_bits(),
            a.in_bytes_per_core.to_bits(),
            a.out_bytes_per_core.to_bits(),
            a.working_set_bytes.to_bits(),
        ),
        (
            core.dataflow as u8,
            core.mac_num as u64,
            core.buffer_kb as u64,
            core.buffer_bw_bits as u64,
            core.noc_bw_bits as u64,
        ),
        scale.to_bits(),
    )
}

static TILE_CACHE: OnceLock<Memo<TileKey, TileEval>> = OnceLock::new();

fn tile_cache() -> &'static Memo<TileKey, TileEval> {
    TILE_CACHE
        .get_or_init(|| Memo::new(crate::util::cli::env_usize("THESEUS_TILE_CACHE", 1 << 16)))
}

/// Memoized [`eval_tile`] — bit-identical results (the cached value *is*
/// the computed one; the key captures every model input). Use on the DSE
/// hot path; plain [`eval_tile`] stays for one-off evaluations.
pub fn eval_tile_cached(a: &OpAssignment, core: &CoreConfig, scale: f64) -> TileEval {
    tile_cache().get_or_insert_with(tile_key(a, core, scale), || eval_tile(a, core, scale))
}

/// Tile-memo counters (bench/diagnostics).
pub fn tile_cache_stats() -> MemoStats {
    tile_cache().stats()
}

/// Clear the tile memo (bench isolation).
pub fn clear_tile_cache() {
    tile_cache().clear();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::OpPlacement;

    fn core(df: Dataflow, mac: usize, kb: usize, sbw: usize, nbw: usize) -> CoreConfig {
        CoreConfig {
            dataflow: df,
            mac_num: mac,
            buffer_kb: kb,
            buffer_bw_bits: sbw,
            noc_bw_bits: nbw,
        }
    }

    fn gemm_assignment(m: usize, kk: usize, n: usize, gh: usize, gw: usize) -> OpAssignment {
        let cores = (gh * gw) as f64;
        let bpe = k::BYTES_PER_ELEM;
        OpAssignment {
            op: 0,
            kind: OpKind::Matmul { m, k: kk, n },
            placement: OpPlacement {
                off_h: 0,
                off_w: 0,
                grid_h: gh,
                grid_w: gw,
            },
            flops_per_core: 2.0 * (m * kk * n) as f64 / cores,
            in_bytes_per_core: ((m / gh * kk) as f64 + (kk * n / gw) as f64) * bpe,
            out_bytes_per_core: (m / gh * n / gw) as f64 * bpe,
            working_set_bytes: ((kk * n / gw) as f64 + (m / gh * n / gw) as f64) * bpe,
        }
    }

    #[test]
    fn big_gemm_is_compute_bound_at_full_util() {
        // Large dims on a small array: near-full utilization.
        let c = core(Dataflow::WS, 256, 512, 2048, 1024);
        let a = gemm_assignment(2048, 2048, 2048, 4, 4);
        let t = eval_tile(&a, &c, 1.0);
        assert!(t.utilization > 0.9, "util={}", t.utilization);
        // cycles ≈ macs / (mac_num · util)
        let ideal = (a.flops_per_core / 2.0) / 256.0;
        assert!(t.cycles >= ideal * 0.99);
        assert!(t.cycles <= ideal * 2.0, "cycles={} ideal={ideal}", t.cycles);
    }

    #[test]
    fn small_dims_underutilize() {
        // k=4 on a WS array with 16+ rows wastes most lanes.
        let c = core(Dataflow::WS, 1024, 512, 2048, 1024);
        let a = gemm_assignment(1024, 4, 1024, 2, 2);
        let t = eval_tile(&a, &c, 1.0);
        assert!(t.utilization < 0.3, "util={}", t.utilization);
    }

    #[test]
    fn dataflow_changes_utilization() {
        // Tall-skinny GEMM: m huge, k tiny -> OS/IS beat WS.
        let c_ws = core(Dataflow::WS, 1024, 512, 2048, 1024);
        let c_os = core(Dataflow::OS, 1024, 512, 2048, 1024);
        let a = gemm_assignment(4096, 8, 4096, 2, 2);
        let ws = eval_tile(&a, &c_ws, 1.0);
        let os = eval_tile(&a, &c_os, 1.0);
        assert!(os.utilization > ws.utilization);
        assert!(os.cycles < ws.cycles);
    }

    #[test]
    fn tiny_buffer_forces_reload() {
        let big = core(Dataflow::WS, 256, 2048, 512, 512);
        let small = core(Dataflow::WS, 256, 32, 512, 512);
        let a = gemm_assignment(512, 512, 512, 2, 2);
        let t_big = eval_tile(&a, &big, 1.0);
        let t_small = eval_tile(&a, &small, 1.0);
        assert!(t_small.sram_bytes > t_big.sram_bytes * 2.0);
    }

    #[test]
    fn bandwidth_bound_when_starved() {
        // 32-bit SRAM port can't feed 4096 MACs.
        let c = core(Dataflow::WS, 4096, 2048, 32, 32);
        let a = gemm_assignment(1024, 1024, 1024, 2, 2);
        let t = eval_tile(&a, &c, 1.0);
        let compute_only = (a.flops_per_core / 2.0) / 4096.0;
        assert!(t.cycles > compute_only * 3.0, "not bw-bound");
    }

    #[test]
    fn scale_divides_work() {
        let c = core(Dataflow::WS, 256, 512, 1024, 512);
        let a = gemm_assignment(2048, 2048, 2048, 4, 4);
        let t1 = eval_tile(&a, &c, 1.0);
        let t4 = eval_tile(&a, &c, 4.0);
        assert!(t4.cycles < t1.cycles / 2.0);
    }

    #[test]
    fn cached_eval_is_bit_identical_and_hits() {
        crate::util::prop::check(
            "eval_tile_cached == eval_tile on random tiles",
            |r| {
                let mac = 1usize << r.range(4, 11);
                let m = 1 << r.range(4, 10);
                let kk = 1 << r.range(4, 10);
                let n = 1 << r.range(4, 10);
                let scale = [1.0, 2.0, 4.0][r.below(3)];
                (mac, m, kk, n, scale)
            },
            |&(mac, m, kk, n, scale)| {
                let c = core(Dataflow::WS, mac, 512, 2048, 1024);
                let a = gemm_assignment(m, kk, n, 2, 2);
                let fresh = eval_tile(&a, &c, scale);
                let cached = eval_tile_cached(&a, &c, scale);
                let again = eval_tile_cached(&a, &c, scale);
                if fresh != cached || fresh != again {
                    return Err(format!("diverged: {fresh:?} vs {cached:?}"));
                }
                Ok(())
            },
        );
        // Repeated keys must actually hit (counters are process-global, so
        // only assert hits grew).
        let before = tile_cache_stats();
        let c = core(Dataflow::WS, 256, 512, 2048, 1024);
        let a = gemm_assignment(512, 512, 512, 2, 2);
        eval_tile_cached(&a, &c, 1.0);
        eval_tile_cached(&a, &c, 1.0);
        let after = tile_cache_stats();
        assert!(after.hits > before.hits, "second lookup must hit");
    }

    #[test]
    fn prop_cycles_positive_and_monotone_in_macs() {
        crate::util::prop::check(
            "tile cycles positive; more MACs never slower",
            |r| {
                let mac = 1usize << r.range(3, 12);
                let m = 1 << r.range(4, 11);
                let kk = 1 << r.range(4, 11);
                let n = 1 << r.range(4, 11);
                (mac, m, kk, n)
            },
            |&(mac, m, kk, n)| {
                let c1 = core(Dataflow::WS, mac, 512, 2048, 1024);
                let c2 = core(Dataflow::WS, (mac * 2).min(4096), 512, 2048, 1024);
                let a = gemm_assignment(m, kk, n, 1, 1);
                let t1 = eval_tile(&a, &c1, 1.0);
                let t2 = eval_tile(&a, &c2, 1.0);
                if t1.cycles <= 0.0 {
                    return Err("non-positive cycles".into());
                }
                if t2.cycles > t1.cycles * 1.001 {
                    return Err(format!("more MACs slower: {} -> {}", t1.cycles, t2.cycles));
                }
                Ok(())
            },
        );
    }
}
