//! Power estimation (paper §VI-E): Aladdin-style action counting. The
//! evaluator accumulates action counts into an [`EnergyLedger`]; energy is
//! counts × per-action energies from the component estimator, plus static
//! power × runtime.

use crate::arch::constants as k;
use crate::components::{CoreGeom, ReticlePhys};

/// Action counts for one evaluated workload interval.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EnergyLedger {
    /// MAC operations executed.
    pub mac_ops: f64,
    /// SRAM bytes moved (reads + writes).
    pub sram_bytes: f64,
    /// NoC traffic volume × hops traversed (byte-hops).
    pub noc_byte_hops: f64,
    /// Bytes crossing reticle boundaries.
    pub inter_reticle_bytes: f64,
    /// Bytes crossing wafer boundaries (NIC SerDes, GRS-class energy ×4).
    pub inter_wafer_bytes: f64,
    /// DRAM bytes, by tier.
    pub dram_stacked_bytes: f64,
    pub dram_offchip_bytes: f64,
    /// Interval wall-clock, seconds.
    pub time_s: f64,
    /// Total static (leakage) power of the committed silicon, W.
    pub static_w: f64,
}

impl EnergyLedger {
    pub fn add(&mut self, other: &EnergyLedger) {
        self.mac_ops += other.mac_ops;
        self.sram_bytes += other.sram_bytes;
        self.noc_byte_hops += other.noc_byte_hops;
        self.inter_reticle_bytes += other.inter_reticle_bytes;
        self.inter_wafer_bytes += other.inter_wafer_bytes;
        self.dram_stacked_bytes += other.dram_stacked_bytes;
        self.dram_offchip_bytes += other.dram_offchip_bytes;
    }

    /// Dynamic energy in joules for a given core geometry and reticle PHY.
    pub fn dynamic_energy_j(&self, core: &CoreGeom, ret: &ReticlePhys) -> f64 {
        let pj = self.mac_ops * core.e_mac_pj
            + self.sram_bytes * 8.0 * core.e_sram_pj_per_bit
            + self.noc_byte_hops * 8.0 * core.e_noc_router_pj_per_bit
            + self.inter_reticle_bytes * 8.0 * ret.phy.energy_pj_per_bit
            + self.inter_wafer_bytes * 8.0 * (4.0 * k::PHY_ENERGY_PJ_PER_BIT_RDL)
            + self.dram_stacked_bytes * 8.0 * k::DRAM_ENERGY_PJ_PER_BIT_STACKED
            + self.dram_offchip_bytes * 8.0 * k::DRAM_ENERGY_PJ_PER_BIT_OFFCHIP;
        pj * 1e-12
    }

    pub fn total_energy_j(&self, core: &CoreGeom, ret: &ReticlePhys) -> f64 {
        self.dynamic_energy_j(core, ret) + self.static_w * self.time_s
    }

    /// Average power over the interval, W.
    pub fn avg_power_w(&self, core: &CoreGeom, ret: &ReticlePhys) -> f64 {
        if self.time_s <= 0.0 {
            return self.static_w;
        }
        self.total_energy_j(core, ret) / self.time_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{CoreConfig, Dataflow, IntegrationStyle, MemoryKind, ReticleConfig};
    use crate::components::reticle_phys;

    fn fixtures() -> (CoreGeom, ReticlePhys) {
        let ret = ReticleConfig {
            core: CoreConfig {
                dataflow: Dataflow::WS,
                mac_num: 512,
                buffer_kb: 128,
                buffer_bw_bits: 256,
                noc_bw_bits: 512,
            },
            array_h: 10,
            array_w: 10,
            inter_reticle_bw_ratio: 1.0,
            memory: MemoryKind::Stacking {
                bw_tbps_per_100mm2: 1.0,
                capacity_gb: 16.0,
            },
        };
        let phys = reticle_phys(&ret, IntegrationStyle::InfoSoW, 54).unwrap();
        (phys.core, phys.clone())
    }

    #[test]
    fn energy_accumulates_linearly() {
        let (core, ret) = fixtures();
        let mut a = EnergyLedger {
            mac_ops: 1e12,
            sram_bytes: 1e9,
            time_s: 1.0,
            static_w: 100.0,
            ..Default::default()
        };
        let e1 = a.total_energy_j(&core, &ret);
        let b = a;
        a.add(&b);
        let e2 = a.total_energy_j(&core, &ret);
        // Dynamic doubles, static unchanged (same interval).
        let dyn1 = e1 - 100.0;
        assert!((e2 - (100.0 + 2.0 * dyn1)).abs() < 1e-9);
    }

    #[test]
    fn offchip_dram_costs_more() {
        let (core, ret) = fixtures();
        let stacked = EnergyLedger {
            dram_stacked_bytes: 1e9,
            ..Default::default()
        };
        let off = EnergyLedger {
            dram_offchip_bytes: 1e9,
            ..Default::default()
        };
        assert!(off.dynamic_energy_j(&core, &ret) > stacked.dynamic_energy_j(&core, &ret) * 2.0);
    }

    #[test]
    fn avg_power_includes_static() {
        let (core, ret) = fixtures();
        let l = EnergyLedger {
            time_s: 2.0,
            static_w: 500.0,
            ..Default::default()
        };
        assert!((l.avg_power_w(&core, &ret) - 500.0).abs() < 1e-9);
    }

    #[test]
    fn mac_energy_magnitude() {
        // 1e12 MACs at ~0.5 pJ ≈ 0.5 J.
        let (core, ret) = fixtures();
        let l = EnergyLedger {
            mac_ops: 1e12,
            ..Default::default()
        };
        let e = l.dynamic_energy_j(&core, &ret);
        assert!(e > 0.3 && e < 1.5, "e={e}");
    }
}
