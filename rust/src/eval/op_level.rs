//! Op-level evaluation (paper §VI-C): latency of one chunk's operator DAG
//! on the NoC-based core array.
//!
//! Two fidelities share one critical-path traversal:
//! * **Analytical** — per-link sharing counts give each flow an equivalent
//!   bandwidth (`link_bw / max-sharers-on-path`);
//! * **GNN** — per-link predicted mean waiting times ŷ_l reconstruct packet
//!   latency via Eq. 6: `t(k) = k + Σ ŷ_l` (plus pipeline hops).
//!
//! The traversal is a true O(V+E) sweep over a [`ChunkTopology`] — a CSR
//! predecessor adjacency with dense edge-delay slots built once per chunk.
//! The topology depends only on the chunk structure, so the compile cache
//! ([`crate::compiler::cache`]) stores it alongside the compiled chunk and
//! repeated evaluations (strategy sweeps, BO probes, NoC-model swaps) skip
//! the build entirely.
//!
//! **Purity contract.** [`chunk_latency_with_topo`] is a pure function of
//! `(chunk, topo, core, scale, model)`: no hidden state, no randomness,
//! deterministic float evaluation order. The delta cache
//! ([`crate::eval::chunk::delta_cache_stats`]) leans on this — it memoizes
//! whole [`OpLevelResult`]s under `(chunk signature, scale bits, estimator
//! cache key)` and replays them across evaluations of neighboring design
//! points, which is sound only because re-running this sweep on the same
//! inputs reproduces the same bits. Keep any future nondeterminism (e.g. a
//! parallel traversal with order-dependent float accumulation) off this
//! path, or gate it behind a `None` estimator cache key.

use std::collections::HashMap;

use crate::arch::CoreConfig;
use crate::compiler::routing::link_index;
use crate::compiler::CompiledChunk;
use crate::eval::tile::eval_tile_cached;
use crate::noc_sim::MAX_PACKET_FLITS;

/// Result of op-level evaluation.
#[derive(Debug, Clone)]
pub struct OpLevelResult {
    /// Critical-path latency of the chunk, in core cycles.
    pub cycles: f64,
    /// Sum of per-op compute (tile) cycles along the critical path.
    pub compute_cycles: f64,
    /// Communication contribution along the critical path.
    pub comm_cycles: f64,
    /// Aggregate SRAM traffic (power accounting), bytes.
    pub sram_bytes: f64,
    /// Aggregate MAC ops (power accounting).
    pub mac_ops: f64,
    /// NoC traffic volume × hops (power accounting), byte-hops.
    pub byte_hops: f64,
}

/// Link-wait source for Eq. 6. `None` selects the analytical
/// sharing-count model.
pub enum NocModel<'a> {
    Analytical,
    /// Predicted mean waiting time per link (dense `link_index` order).
    LinkWaits(&'a [f64]),
}

/// Sentinel for flows whose (src_op, dst_op) pair has no dependency edge
/// (their delay cannot land on the critical path; the old hashmap-based
/// code accumulated and then never read them).
const SLOT_NONE: u32 = u32::MAX;

/// Structure-only index of a compiled chunk's DAG, built once and reused
/// across every evaluation of the chunk:
/// * a CSR predecessor adjacency over the op DAG, each incoming edge
///   carrying a dense *delay slot* (index into `chunk.deps`);
/// * a per-flow map onto those slots (intra-op flows are recognized by
///   `src_op == dst_op` at evaluation time);
/// * flow indices grouped by consuming phase for the analytical
///   link-sharing pass.
#[derive(Debug, Clone)]
pub struct ChunkTopology {
    /// CSR offsets into `pred`; length `n_ops + 1`.
    pred_off: Vec<u32>,
    /// `(pred_op, delay_slot)` per incoming dep edge, in `chunk.deps`
    /// order within each destination (preserves the legacy tie-breaks).
    pred: Vec<(u32, u32)>,
    /// Per-flow delay slot (`SLOT_NONE` for intra-op / unmatched flows).
    flow_slot: Vec<u32>,
    /// Flow indices sorted by consuming op (stable), i.e. phase order.
    phase_order: Vec<u32>,
    /// Number of dense delay slots (`chunk.deps.len()`).
    n_slots: usize,
}

impl ChunkTopology {
    pub fn new(chunk: &CompiledChunk) -> ChunkTopology {
        let n_ops = chunk.assignments.len();
        let n_slots = chunk.deps.len();

        // CSR over predecessor edges.
        let mut pred_off = vec![0u32; n_ops + 1];
        for &(_, d) in &chunk.deps {
            pred_off[d + 1] += 1;
        }
        for i in 0..n_ops {
            pred_off[i + 1] += pred_off[i];
        }
        let mut cursor: Vec<u32> = pred_off[..n_ops].to_vec();
        let mut pred = vec![(0u32, 0u32); n_slots];
        // Duplicate (src, dst) pairs share the first slot, matching the
        // old single-key hashmap semantics.
        let mut slot_of: HashMap<(usize, usize), u32> = HashMap::with_capacity(n_slots);
        for (ei, &(s, d)) in chunk.deps.iter().enumerate() {
            pred[cursor[d] as usize] = (s as u32, ei as u32);
            cursor[d] += 1;
            slot_of.entry((s, d)).or_insert(ei as u32);
        }

        let flow_slot: Vec<u32> = chunk
            .flows
            .iter()
            .map(|f| {
                if f.src_op == f.dst_op {
                    SLOT_NONE
                } else {
                    slot_of.get(&(f.src_op, f.dst_op)).copied().unwrap_or(SLOT_NONE)
                }
            })
            .collect();

        let mut phase_order: Vec<u32> = (0..chunk.flows.len() as u32).collect();
        phase_order.sort_by_key(|&i| chunk.flows[i as usize].dst_op);

        ChunkTopology {
            pred_off,
            pred,
            flow_slot,
            phase_order,
            n_slots,
        }
    }

    /// Incoming `(pred_op, delay_slot)` edges of op `i`.
    #[inline]
    fn preds(&self, i: usize) -> &[(u32, u32)] {
        &self.pred[self.pred_off[i] as usize..self.pred_off[i + 1] as usize]
    }
}

/// Evaluate a compiled chunk, building its topology on the fly. Prefer
/// [`chunk_latency_with_topo`] with a cached [`ChunkTopology`] on the DSE
/// hot path.
pub fn chunk_latency(
    chunk: &CompiledChunk,
    core: &CoreConfig,
    scale: f64,
    model: NocModel<'_>,
) -> OpLevelResult {
    let topo = ChunkTopology::new(chunk);
    chunk_latency_with_topo(chunk, &topo, core, scale, model)
}

/// Evaluate a compiled chunk. `scale` spreads each op over `scale`× more
/// cores than the compiled region holds (hierarchical evaluation — the
/// region is a representative reticle-sized slice of the chunk). `topo`
/// must be [`ChunkTopology::new`] of the same chunk.
pub fn chunk_latency_with_topo(
    chunk: &CompiledChunk,
    topo: &ChunkTopology,
    core: &CoreConfig,
    scale: f64,
    model: NocModel<'_>,
) -> OpLevelResult {
    let n_ops = chunk.assignments.len();
    let flit_bytes = core.noc_bw_bits as f64 / 8.0;

    // Tile-level compute per op (§VI-B feeding §VI-C) — memoized per
    // (assignment, core, scale): strategy sweeps and NoC-model swaps
    // re-evaluate identical tiles constantly once compiles are cached.
    let mut tile_cycles = vec![0.0f64; n_ops];
    let mut sram_bytes = 0.0;
    let mut mac_ops = 0.0;
    for (i, a) in chunk.assignments.iter().enumerate() {
        let t = eval_tile_cached(a, core, scale);
        tile_cycles[i] = t.cycles;
        sram_bytes += t.sram_bytes * a.placement.num_cores() as f64;
        mac_ops += t.mac_ops * a.placement.num_cores() as f64;
    }

    // Per-phase link sharing (analytical model): flows that feed the same
    // consumer op are concurrent. One dense per-link counter is reset at
    // phase boundaries; the phase grouping comes precomputed from `topo`.
    let n_links = chunk.region_h * chunk.region_w * crate::compiler::routing::NUM_DIRS;
    let mut share = vec![0u32; n_links];
    // Per-flow max sharing, filled in phase order (only analytical mode).
    let mut flow_share: Vec<u32> = Vec::new();
    if matches!(model, NocModel::Analytical) {
        let order = &topo.phase_order;
        flow_share = vec![1; chunk.flows.len()];
        let mut i = 0;
        while i < order.len() {
            let phase = chunk.flows[order[i] as usize].dst_op;
            let start = i;
            while i < order.len() && chunk.flows[order[i] as usize].dst_op == phase {
                i += 1;
            }
            // Count sharers on each link for this phase (fault-aware
            // dispatch: table detours on degraded meshes, XY otherwise).
            for &fi in &order[start..i] {
                let f = &chunk.flows[fi as usize];
                chunk.for_each_route_link(f.src, f.dst, |l| {
                    share[link_index(l, chunk.region_w)] += 1;
                });
            }
            // Per-flow max over its path, then reset the touched counters.
            for &fi in &order[start..i] {
                let f = &chunk.flows[fi as usize];
                let mut m = 1u32;
                chunk.for_each_route_link(f.src, f.dst, |l| {
                    m = m.max(share[link_index(l, chunk.region_w)]);
                });
                flow_share[fi as usize] = m;
            }
            for &fi in &order[start..i] {
                let f = &chunk.flows[fi as usize];
                chunk.for_each_route_link(f.src, f.dst, |l| {
                    share[link_index(l, chunk.region_w)] = 0;
                });
            }
        }
    }

    // Flow latency -> dense edge-delay slots (max per dependency edge) and
    // per-op intra-op feed delays.
    let mut edge_delay = vec![0.0f64; topo.n_slots];
    let mut intra_delay = vec![0.0f64; n_ops];
    let mut byte_hops = 0.0;
    for (fi, f) in chunk.flows.iter().enumerate() {
        let h = chunk.route_hops(f.src, f.dst) as f64;
        byte_hops += f.bytes * h;
        let flits = (f.bytes / flit_bytes).max(1.0);
        let t = match model {
            NocModel::Analytical => {
                let max_share = flow_share[fi] as f64;
                h + flits * max_share
            }
            NocModel::LinkWaits(waits) => {
                // Eq. 6 per packet, amortized over the flow's packets: each
                // packet pays k + Σŷ; packets pipeline, so the flow pays
                // serialization once plus per-packet queueing on the path.
                let mut path_wait = 0.0;
                chunk.for_each_route_link(f.src, f.dst, |l| {
                    path_wait += waits
                        .get(link_index(l, chunk.region_w))
                        .copied()
                        .unwrap_or(0.0);
                });
                let packets = (flits / MAX_PACKET_FLITS as f64).ceil().max(1.0);
                h + flits + packets * path_wait
            }
        };
        if f.src_op == f.dst_op {
            if t > intra_delay[f.dst_op] {
                intra_delay[f.dst_op] = t;
            }
        } else {
            let slot = topo.flow_slot[fi];
            if slot != SLOT_NONE {
                let cur = &mut edge_delay[slot as usize];
                if t > *cur {
                    *cur = t;
                }
            }
        }
    }

    // Critical path over the op DAG (ops are topologically ordered): one
    // O(V+E) sweep over the CSR predecessor lists.
    let mut finish = vec![0.0f64; n_ops];
    let mut comm_at = vec![0.0f64; n_ops];
    let mut compute_at = vec![0.0f64; n_ops];
    for i in 0..n_ops {
        // Intra-op feeds overlap with compute: take the max.
        let intra = intra_delay[i];
        let op_lat = tile_cycles[i].max(intra);
        let mut start = 0.0;
        let mut best_pred: Option<usize> = None;
        let mut best_comm = 0.0;
        for &(s, slot) in topo.preds(i) {
            let delay = edge_delay[slot as usize];
            let t = finish[s as usize] + delay;
            if t > start {
                start = t;
                best_pred = Some(s as usize);
                best_comm = delay;
            }
        }
        finish[i] = start + op_lat;
        let (pc, cc) = match best_pred {
            Some(p) => (comm_at[p] + best_comm, compute_at[p]),
            None => (0.0, 0.0),
        };
        comm_at[i] = pc + intra.max(0.0).min(op_lat - tile_cycles[i]).max(0.0);
        compute_at[i] = cc + tile_cycles[i];
    }

    let (end, cycles) = finish
        .iter()
        .enumerate()
        .fold((0usize, 0.0f64), |acc, (i, &f)| {
            if f > acc.1 {
                (i, f)
            } else {
                acc
            }
        });

    OpLevelResult {
        cycles,
        compute_cycles: compute_at.get(end).copied().unwrap_or(0.0),
        comm_cycles: comm_at
            .get(end)
            .copied()
            .unwrap_or(0.0)
            .max(cycles - compute_at.get(end).copied().unwrap_or(0.0)),
        sram_bytes,
        mac_ops,
        byte_hops,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Dataflow;
    use crate::compiler::compile_chunk;
    use crate::workload::models::benchmarks;
    use crate::workload::{OpGraph, Phase};

    fn core(noc_bw: usize) -> CoreConfig {
        CoreConfig {
            dataflow: Dataflow::WS,
            mac_num: 512,
            buffer_kb: 128,
            buffer_bw_bits: 256,
            noc_bw_bits: noc_bw,
        }
    }

    fn chunk(seq: usize, region: usize, noc_bw: usize) -> (CompiledChunk, CoreConfig) {
        let mut spec = benchmarks()[0].clone();
        spec.seq_len = seq;
        let g = OpGraph::transformer_chunk(&spec, 1, 1, 8, Phase::Prefill, false);
        let c = core(noc_bw);
        (compile_chunk(&g, region, region, &c), c)
    }

    #[test]
    fn latency_positive_and_dominated_by_compute_when_fast_noc() {
        let (ch, c) = chunk(128, 4, 4096);
        let r = chunk_latency(&ch, &c, 1.0, NocModel::Analytical);
        assert!(r.cycles > 0.0);
        assert!(r.compute_cycles > 0.0);
        assert!(r.cycles >= r.compute_cycles * 0.99);
    }

    #[test]
    fn narrow_noc_slower() {
        let (ch_w, c_w) = chunk(128, 4, 2048);
        let (ch_n, c_n) = chunk(128, 4, 32);
        let wide = chunk_latency(&ch_w, &c_w, 1.0, NocModel::Analytical);
        let narrow = chunk_latency(&ch_n, &c_n, 1.0, NocModel::Analytical);
        assert!(
            narrow.cycles > wide.cycles,
            "narrow={} wide={}",
            narrow.cycles,
            wide.cycles
        );
    }

    #[test]
    fn gnn_mode_with_zero_waits_is_lower_bound() {
        let (ch, c) = chunk(64, 4, 512);
        let zeros = vec![0.0; ch.region_h * ch.region_w * 4];
        let gnn = chunk_latency(&ch, &c, 1.0, NocModel::LinkWaits(&zeros));
        let ana = chunk_latency(&ch, &c, 1.0, NocModel::Analytical);
        // Zero predicted waiting = no congestion = must not exceed the
        // sharing-count analytical estimate.
        assert!(gnn.cycles <= ana.cycles * 1.0001, "gnn={} ana={}", gnn.cycles, ana.cycles);
    }

    #[test]
    fn positive_waits_increase_latency() {
        let (ch, c) = chunk(64, 4, 512);
        let zeros = vec![0.0; ch.region_h * ch.region_w * 4];
        let heavy = vec![50.0; ch.region_h * ch.region_w * 4];
        let lo = chunk_latency(&ch, &c, 1.0, NocModel::LinkWaits(&zeros));
        let hi = chunk_latency(&ch, &c, 1.0, NocModel::LinkWaits(&heavy));
        assert!(hi.cycles > lo.cycles);
    }

    #[test]
    fn scale_speeds_up_compute() {
        let (ch, c) = chunk(128, 4, 1024);
        let r1 = chunk_latency(&ch, &c, 1.0, NocModel::Analytical);
        let r8 = chunk_latency(&ch, &c, 8.0, NocModel::Analytical);
        assert!(r8.cycles < r1.cycles);
    }

    #[test]
    fn cached_topology_matches_fresh_build() {
        // Reusing one ChunkTopology across evaluations must be
        // bit-identical to rebuilding it, in both NoC models.
        for (seq, region, bw) in [(64usize, 4usize, 512usize), (128, 5, 256), (32, 3, 1024)] {
            let (ch, c) = chunk(seq, region, bw);
            let topo = ChunkTopology::new(&ch);
            let fresh = chunk_latency(&ch, &c, 1.0, NocModel::Analytical);
            let cached = chunk_latency_with_topo(&ch, &topo, &c, 1.0, NocModel::Analytical);
            assert_eq!(fresh.cycles, cached.cycles);
            assert_eq!(fresh.compute_cycles, cached.compute_cycles);
            assert_eq!(fresh.comm_cycles, cached.comm_cycles);
            assert_eq!(fresh.byte_hops, cached.byte_hops);

            let waits = vec![3.0; ch.region_h * ch.region_w * 4];
            let fresh_w = chunk_latency(&ch, &c, 2.0, NocModel::LinkWaits(&waits));
            let cached_w =
                chunk_latency_with_topo(&ch, &topo, &c, 2.0, NocModel::LinkWaits(&waits));
            assert_eq!(fresh_w.cycles, cached_w.cycles);
        }
    }

    #[test]
    fn topology_csr_covers_all_deps() {
        let (ch, _) = chunk(64, 4, 512);
        let topo = ChunkTopology::new(&ch);
        let n_ops = ch.assignments.len();
        // Every dep edge appears exactly once in some predecessor list.
        let total: usize = (0..n_ops).map(|i| topo.preds(i).len()).sum();
        assert_eq!(total, ch.deps.len());
        for i in 0..n_ops {
            for &(s, slot) in topo.preds(i) {
                assert_eq!(ch.deps[slot as usize], (s as usize, i));
            }
        }
    }

    #[test]
    fn analytical_tracks_ca_sim_ordering() {
        // Kendall-τ sanity on a handful of configs: the analytical
        // estimate must rank chunk latencies consistently with the CA
        // simulator (the Fig. 7b claim, miniaturized).
        // THESEUS_TEST_FAST=1 drops the two most expensive configs — this
        // is among the slowest tier-1 items in debug builds.
        use crate::noc_sim::{naive_compute_cycles, simulate_chunk_result};
        let configs: &[(usize, usize, usize)] = if crate::util::cli::env_flag("THESEUS_TEST_FAST") {
            &[(32, 3, 256), (64, 3, 128), (32, 5, 512)]
        } else {
            &[(32, 3, 256), (64, 4, 256), (64, 3, 128), (32, 5, 512)]
        };
        let mut ana = Vec::new();
        let mut ca = Vec::new();
        for &(seq, region, bw) in configs {
            let (ch, c) = chunk(seq, region, bw);
            let r = chunk_latency(&ch, &c, 1.0, NocModel::Analytical);
            ana.push(r.cycles);
            let stats = simulate_chunk_result(
                &ch,
                bw,
                &|op| naive_compute_cycles(ch.assignments[op].flops_per_core, c.mac_num),
                200_000_000,
            )
            .expect("CA simulation within budget");
            ca.push(stats.cycles as f64);
        }
        let tau = crate::util::stats::kendall_tau(&ana, &ca);
        assert!(tau > 0.3, "tau={tau} ana={ana:?} ca={ca:?}");
    }
}
