//! Hierarchical evaluation (paper §VI, Fig. 6): tile-level ([`tile`]),
//! op-level ([`op_level`] — analytical or GNN-backed), and chunk-level
//! ([`chunk`]) evaluation, with Aladdin-style power accounting
//! ([`power`]) — unified behind the [`engine`] subsystem.
//!
//! [`engine`] is the one entry point every consumer goes through: an
//! [`engine::EvalSpec`] (model × phase × batch × mqa × wafers ×
//! fidelity) builds an [`engine::Engine`] implementing
//! [`crate::explorer::DesignEval`] for **any** (phase × fidelity) pair.
//! The [`engine::Fidelity`] registry (`analytical`, `ca`, `gnn`,
//! `gnn-test`) is the single source of truth for fidelity names across
//! `theseus dse --fidelity`, campaign scenario JSON, and `mfmobo`'s
//! low/high pair; see the engine docs for the three-level dispatch rule
//! (serial / pooled / batched) and the checklist for adding a fidelity.
//!
//! The layers below the engine stay independently usable:
//! [`eval_training`] is the serial reference sweep any [`NocEstimator`]
//! can drive, and [`eval_inference`] evaluates one prefill/decode
//! configuration at any fidelity.

pub mod chunk;
pub mod engine;
pub mod op_level;
pub mod power;
pub mod tile;

pub use chunk::{
    delta_cache_clear, delta_cache_stats, eval_inference, eval_training, InferEval, SystemConfig,
    TrainEval,
};
pub use engine::{Engine, EvalSpec, Fidelity, SyncEngine};
pub use op_level::{
    chunk_latency, chunk_latency_with_topo, ChunkTopology, NocModel, OpLevelResult,
};

use crate::arch::CoreConfig;
use crate::compiler::CompiledChunk;

/// Source of per-link waiting-time estimates for op-level evaluation.
///
/// * Returning `None` selects the closed-form analytical model
///   (low fidelity, §VI-C "Analytical Model").
/// * The GNN runtime ([`crate::runtime`]) returns Eq. 5 predictions
///   (high fidelity, §VI-C "GNN-based Evaluation").
///
/// Not `Sync`: the PJRT executable handle is thread-confined. The
/// evaluation engine ([`engine`]) turns that distinction into its
/// dispatch rule — `Sync` estimators fan the strategy sweep over the
/// thread pool, thread-confined ones batch link-wait inference instead.
pub trait NocEstimator {
    fn link_waits(&self, chunk: &CompiledChunk, core: &CoreConfig) -> Option<Vec<f64>>;

    /// Display name for logs/benches.
    fn name(&self) -> &'static str {
        "noc-estimator"
    }

    /// Identity for the delta cache ([`chunk::delta_cache_stats`]):
    /// `Some(k)` promises `link_waits` is a **pure function** of
    /// `(chunk, core)` — two calls on structurally identical inputs
    /// return identical waits — with `k` distinguishing this estimator
    /// (and its configuration) from every other cacheable one. Per-chunk
    /// results may then be memoized across evaluations of neighboring
    /// design points. The default is `None` (uncacheable); estimators
    /// whose output varies per call — e.g. the engine's precomputed-waits
    /// adapter over batched GNN output — must keep it that way.
    fn cache_key(&self) -> Option<u64> {
        None
    }
}

/// The low-fidelity analytical estimator (link-sharing equivalent
/// bandwidth).
pub struct Analytical;

impl NocEstimator for Analytical {
    fn link_waits(&self, _chunk: &CompiledChunk, _core: &CoreConfig) -> Option<Vec<f64>> {
        None
    }

    fn name(&self) -> &'static str {
        "analytical"
    }

    fn cache_key(&self) -> Option<u64> {
        // Stateless and closed-form: one process-wide identity.
        Some(0xA7A1_0000_0000_0001)
    }
}

/// Ground-truth estimator: runs the cycle-accurate simulator and feeds the
/// measured per-link waits back through Eq. 6 (used for Fig. 7 validation
/// and as the `ca` fidelity of the evaluation engine).
#[derive(Debug, Clone)]
pub struct CycleAccurate {
    /// Simulation budget per chunk.
    pub max_cycles: u64,
}

impl Default for CycleAccurate {
    fn default() -> Self {
        CycleAccurate {
            max_cycles: 300_000_000,
        }
    }
}

impl CycleAccurate {
    /// Budget from the `THESEUS_CA_BUDGET` env knob (cycles per chunk),
    /// else the default. The engine's `ca` fidelity reads this so long
    /// campaigns (and fast CI smokes) can tune the simulation budget
    /// without a rebuild.
    pub fn from_env() -> CycleAccurate {
        CycleAccurate {
            max_cycles: crate::util::cli::env_u64(
                "THESEUS_CA_BUDGET",
                CycleAccurate::default().max_cycles,
            ),
        }
    }
}

impl NocEstimator for CycleAccurate {
    fn link_waits(&self, chunk: &CompiledChunk, core: &CoreConfig) -> Option<Vec<f64>> {
        // A budget overrun (deadlock or undersized `max_cycles`) is a
        // recoverable condition at this fidelity: report it (once — a DSE
        // sweep calls this per strategy per design point, and a repeated
        // identical warning would bury real output) and fall back to the
        // analytical model instead of panicking the whole DSE run.
        match crate::noc_sim::simulate_chunk_result(
            chunk,
            core.noc_bw_bits,
            &|op| {
                let a = &chunk.assignments[op];
                crate::eval::tile::eval_tile_cached(a, core, 1.0).cycles.ceil() as u64
            },
            self.max_cycles,
        ) {
            Ok(stats) => Some(stats.link_wait_mean()),
            Err(e) => {
                crate::util::warn::warn_once(
                    "ca-overrun",
                    &format!("cycle-accurate estimator: {e}; analytical fallback"),
                );
                None
            }
        }
    }

    fn name(&self) -> &'static str {
        "cycle-accurate"
    }

    fn cache_key(&self) -> Option<u64> {
        // The simulation is deterministic in (chunk, core) at a fixed
        // budget; a different budget can change the waits, so it keys.
        Some(0xCA00_0000_0000_0000 ^ self.max_cycles)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Dataflow;
    use crate::compiler::compile_chunk;
    use crate::workload::models::benchmarks;
    use crate::workload::{OpGraph, Phase};

    #[test]
    fn estimator_names() {
        assert_eq!(Analytical.name(), "analytical");
        assert_eq!(CycleAccurate::default().name(), "cycle-accurate");
    }

    #[test]
    fn cycle_accurate_estimator_produces_waits() {
        let mut spec = benchmarks()[0].clone();
        spec.seq_len = 32;
        let g = OpGraph::transformer_chunk(&spec, 1, 1, 8, Phase::Prefill, false);
        let core = CoreConfig {
            dataflow: Dataflow::WS,
            mac_num: 512,
            buffer_kb: 128,
            buffer_bw_bits: 256,
            noc_bw_bits: 512,
        };
        let chunk = compile_chunk(&g, 3, 3, &core);
        let waits = CycleAccurate::default().link_waits(&chunk, &core).unwrap();
        assert_eq!(waits.len(), 9 * 4);
        assert!(waits.iter().all(|&w| w >= 0.0));
    }
}
