//! The unified evaluation engine: one [`Engine`] type implementing
//! [`DesignEval`] for **any** (workload phase × fidelity) pair, behind a
//! first-class [`Fidelity`] registry.
//!
//! Every evaluation entry point — `theseus dse --fidelity`, campaign
//! scenario JSON, `mfmobo`'s low/high pair, figures and benches — builds
//! an [`EvalSpec`] (model × phase × batch × mqa × wafers × fidelity) and
//! hands it to [`Engine::new`]. Fidelity selection, estimator
//! construction, and sweep dispatch live here and nowhere else.
//!
//! # Dispatch rule (serial / pooled / batched)
//!
//! How an evaluation fans out is a *capability of the backend*, decided
//! here and nowhere else, at three levels:
//!
//! * **Serial** — [`SyncEngine::eval`] sweeps one point's §VI-A strategy
//!   list serially. This is the per-point view for callers that already
//!   fan whole design points over the pool, so parallelism never nests.
//! * **Pooled** — [`Engine::eval`] with a `Sync` per-chunk estimator
//!   (analytical, cycle-accurate) fans one point's strategy sweep over
//!   the scoped thread pool ([`crate::util::pool`]).
//! * **Batched** — [`DesignEval::eval_batch`] over a whole candidate
//!   slice. `Sync` training backends run **one fused sweep** over the
//!   flattened (point × strategy) work list
//!   ([`eval_training_batch_fused`]), first deduping structurally
//!   identical region compiles across the batch by
//!   [`crate::compiler::cache::chunk_signature`]; inference and
//!   pseudo-GNN batches fan whole points over the pool. GNN-shaped
//!   backends (`gnn`, `gnn-test`) additionally amortize per-call
//!   dispatch by batching link-wait inference across each point's sweep
//!   ([`crate::runtime::batch::GnnBatcher`]) — forced for the PJRT GNN,
//!   whose executable handle cannot cross threads.
//!
//! Parallelism lives at exactly one level: explorers either fan points
//! out themselves over a [`SyncEngine`] (whose per-point sweep is
//! serial) or hand the whole batch to `eval_batch` (which owns the
//! fan-out). All three levels produce bit-identical numbers — each
//! strategy's evaluation is deterministic and independent, region
//! compiles are deterministic in their structural signature, and ties
//! resolve by the same last-max rule (pinned by the tests below and by
//! `benches/perf_hotpath.rs`). A backend that cannot take a batched
//! path degrades to the per-point serial loop and reports it through
//! [`crate::util::warn::warn_once`] — never silently (the same
//! contract as the [`GnnBatcher`] fallback).
//!
//! # Adding a fidelity
//!
//! 1. Add a variant to [`Fidelity`] and list it in [`Fidelity::ALL`] with
//!    a `name()` arm — `parse`/usage errors and every CLI listing pick it
//!    up from there.
//! 2. Add a [`Backend`] arm in [`Engine::new`] constructing its
//!    estimator, and extend [`Engine::to_sync`] if the estimator is
//!    `Sync` (pooled sweep) or leave it confined (batched sweep).
//! 3. Add a [`Fidelity::per_chunk_estimator`] arm so figure/bench code
//!    (Fig. 7) can drive it chunk-at-a-time.
//! 4. If the estimator is a pure function of `(chunk, core)`, give it a
//!    [`crate::eval::NocEstimator::cache_key`] so neighbor re-evaluation
//!    can reuse its per-chunk results through the delta cache.

use std::sync::Arc;

use crate::arch::{HeteroConfig, InterWaferNet};
use crate::compiler::cache::{chunk_signature, compile_chunk_cached, CachedChunk};
use crate::design_space::Validated;
use crate::eval::chunk::{
    best_eval, eval_inference, eval_training, eval_training_on_region, eval_training_with,
    ranked_strategies, region_input, strategy_region, InferEval, SystemConfig, TrainEval,
};
use crate::workload::{OpGraph, ParallelStrategy};
use crate::eval::{Analytical, CycleAccurate as CaEstimator, NocEstimator};
use crate::explorer::{DesignEval, Objective};
use crate::runtime::batch::{gnn_batch_size, GnnBackend, GnnBatcher};
use crate::runtime::{GnnModel, TestBackend};
use crate::workload::{LlmSpec, Phase};
use crate::yield_model::faults::FaultSpec;

/// Evaluation fidelity registry — the single source of truth for the
/// fidelity names accepted by `theseus dse --fidelity`, campaign scenario
/// JSON, and `mfmobo`'s low/high pair.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fidelity {
    /// Closed-form NoC model (§VI-C "Analytical Model", low fidelity).
    Analytical,
    /// Cycle-accurate NoC simulation (ground truth; expensive — budget
    /// per chunk via `THESEUS_CA_BUDGET`, overruns fall back to the
    /// analytical model with a one-time warning).
    CycleAccurate,
    /// GNN link-wait prediction over PJRT (§VI-C "GNN-based Evaluation",
    /// high fidelity). Needs the AOT artifacts; [`Engine::new`] errors
    /// loudly when they are unavailable.
    Gnn,
    /// Deterministic in-process pseudo-GNN ([`TestBackend`]) through the
    /// same batched inference path — the high-fidelity stand-in in builds
    /// without PJRT artifacts.
    GnnTest,
}

impl Fidelity {
    /// Registry order is listing order in usage errors.
    pub const ALL: [Fidelity; 4] = [
        Fidelity::Analytical,
        Fidelity::CycleAccurate,
        Fidelity::Gnn,
        Fidelity::GnnTest,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Fidelity::Analytical => "analytical",
            Fidelity::CycleAccurate => "ca",
            Fidelity::Gnn => "gnn",
            Fidelity::GnnTest => "gnn-test",
        }
    }

    /// Comma-joined registry listing — every "valid: ..." usage error
    /// derives from this one list.
    pub fn names() -> String {
        Fidelity::ALL
            .iter()
            .map(Fidelity::name)
            .collect::<Vec<_>>()
            .join(", ")
    }

    pub fn parse(s: &str) -> Option<Fidelity> {
        match s {
            // Alias from the pre-registry campaign schema.
            "cycle-accurate" => Some(Fidelity::CycleAccurate),
            _ => Fidelity::ALL.into_iter().find(|f| f.name() == s),
        }
    }

    /// [`Fidelity::parse`] with a human-oriented error listing the valid
    /// names — CLI and scenario-JSON call sites print this and exit 1
    /// instead of silently falling back.
    pub fn parse_or_usage(s: &str) -> Result<Fidelity, String> {
        Fidelity::parse(s)
            .ok_or_else(|| format!("unknown fidelity '{s}' — valid: {}", Fidelity::names()))
    }

    /// A chunk-at-a-time estimator for figure/bench code that compares
    /// fidelities outside a DSE sweep (Fig. 7). The GNN arm loads the
    /// per-chunk (`--batch 1`) artifact so per-evaluation timings don't
    /// pay the batched executable's full slot count.
    pub fn per_chunk_estimator(self) -> Result<Box<dyn NocEstimator>, String> {
        match self {
            Fidelity::Analytical => Ok(Box::new(Analytical)),
            Fidelity::CycleAccurate => Ok(Box::new(CaEstimator::from_env())),
            Fidelity::GnnTest => Ok(Box::new(TestBackend::new())),
            Fidelity::Gnn => match GnnModel::load_per_chunk_default() {
                Ok(m) => Ok(Box::new(m)),
                Err(e) => Err(format!("fidelity 'gnn' unavailable: {e}")),
            },
        }
    }
}

/// Hypervolume reference power (paper §VII: "the peak power threshold of
/// the WSC system"): 15 kW per wafer × the largest plausible area-matched
/// wafer count (smallest committed wafer area we accept ≈ 15 000 mm²).
pub fn ref_power_for(spec: &LlmSpec) -> f64 {
    let gpu_area = spec.gpu_num as f64 * crate::baselines::H100_DIE_MM2;
    let wafers = (gpu_area / 15_000.0).ceil().max(1.0);
    crate::arch::constants::WAFER_POWER_LIMIT_W * wafers
}

/// System sizing shared by every evaluation: a fixed wafer count when the
/// spec pins one (multi-wafer sweeps), else area-matched to the model's
/// GPU-cluster baseline (§VIII-A).
pub fn system_for(v: &Validated, gpu_num: usize, wafers: Option<usize>) -> SystemConfig {
    match wafers {
        Some(n) => SystemConfig {
            validated: v.clone(),
            n_wafers: n.max(1),
            faults: None,
        },
        None => SystemConfig::area_matched(v.clone(), gpu_num),
    }
}

/// What to evaluate: one workload phase of one model at one fidelity.
#[derive(Debug, Clone)]
pub struct EvalSpec {
    pub model: LlmSpec,
    pub phase: Phase,
    /// Inference batch (sequences in flight); ignored for training (the
    /// training batch comes from the model spec).
    pub batch: usize,
    /// Multi-query attention for the inference phases (§IX-D).
    pub mqa: bool,
    /// Fixed wafer count; `None` = area-matched (§VIII-A).
    pub wafers: Option<usize>,
    pub fidelity: Fidelity,
    /// Fault injection: evaluate every design on a yield-realistic
    /// defective wafer ([`crate::yield_model::faults`]). `None` keeps the
    /// bit-identical pristine path.
    pub faults: Option<FaultSpec>,
    /// Prefill/decode heterogeneity override (§V-B) applied to every
    /// design point; `None` keeps each point's own setting.
    pub hetero: Option<HeteroConfig>,
    /// Inter-wafer network override ([`crate::arch::interwafer`]) applied
    /// to every design point; `None` keeps each point's own net. Inert at
    /// `wafers: 1` — single-wafer evaluations never consult the net.
    pub interwafer: Option<InterWaferNet>,
}

impl EvalSpec {
    /// Training at the analytical fidelity, area-matched — the baseline
    /// spec every entry point starts from.
    pub fn training(model: LlmSpec) -> EvalSpec {
        EvalSpec {
            model,
            phase: Phase::Training,
            batch: 0,
            mqa: false,
            wafers: None,
            fidelity: Fidelity::Analytical,
            faults: None,
            hetero: None,
            interwafer: None,
        }
    }

    /// An inference phase (prefill or decode) at `batch` sequences.
    pub fn inference(model: LlmSpec, phase: Phase, batch: usize) -> EvalSpec {
        EvalSpec {
            model,
            phase,
            batch: batch.max(1),
            mqa: false,
            wafers: None,
            fidelity: Fidelity::Analytical,
            faults: None,
            hetero: None,
            interwafer: None,
        }
    }

    pub fn with_fidelity(mut self, fidelity: Fidelity) -> EvalSpec {
        self.fidelity = fidelity;
        self
    }

    pub fn with_wafers(mut self, wafers: Option<usize>) -> EvalSpec {
        self.wafers = wafers;
        self
    }

    pub fn with_mqa(mut self, mqa: bool) -> EvalSpec {
        self.mqa = mqa;
        self
    }

    pub fn with_faults(mut self, faults: Option<FaultSpec>) -> EvalSpec {
        self.faults = faults;
        self
    }

    pub fn with_hetero(mut self, hetero: Option<HeteroConfig>) -> EvalSpec {
        self.hetero = hetero;
        self
    }

    pub fn with_interwafer(mut self, interwafer: Option<InterWaferNet>) -> EvalSpec {
        self.interwafer = interwafer;
        self
    }

    /// Size and configure the system for one design point: the wafer
    /// policy via [`system_for`], then the spec's fault-injection,
    /// heterogeneity and inter-wafer-network overrides (all no-ops when
    /// `None`).
    pub(crate) fn system(&self, v: &Validated) -> SystemConfig {
        let mut sys = system_for(v, self.model.gpu_num, self.wafers);
        sys.faults = self.faults;
        if let Some(h) = self.hetero {
            sys.validated.point.hetero = h;
        }
        if let Some(n) = self.interwafer {
            sys.validated.point.interwafer = n;
        }
        sys
    }
}

/// The estimator a fidelity resolved to. Which arm a fidelity lands in
/// decides its sweep dispatch (see the module docs): `Sync` arms pool,
/// the thread-confined GNN batches.
enum Backend {
    Analytical(Analytical),
    CycleAccurate(CaEstimator),
    PseudoGnn(TestBackend),
    /// Shared so figure code evaluating many specs loads (and PJRT-
    /// compiles) the artifact once — see [`Engine::with_gnn_model`].
    Gnn(Arc<GnnModel>),
}

/// The unified evaluation engine: [`DesignEval`] for any (phase ×
/// fidelity) pair. Construction resolves the fidelity to a backend once;
/// an unavailable backend (the GNN without artifacts) is a loud
/// construction error, never a silent mid-run fallback to another
/// fidelity.
pub struct Engine {
    spec: EvalSpec,
    backend: Backend,
}

impl Engine {
    pub fn new(spec: EvalSpec) -> Result<Engine, String> {
        let backend = match spec.fidelity {
            Fidelity::Analytical => Backend::Analytical(Analytical),
            Fidelity::CycleAccurate => Backend::CycleAccurate(CaEstimator::from_env()),
            Fidelity::GnnTest => Backend::PseudoGnn(TestBackend::new()),
            Fidelity::Gnn => match GnnModel::load_default() {
                Ok(m) => Backend::Gnn(Arc::new(m)),
                Err(e) => return Err(format!("fidelity 'gnn' unavailable: {e}")),
            },
        };
        Ok(Engine { spec, backend })
    }

    /// Engine at the `gnn` fidelity around an **already-loaded** model
    /// (the spec's fidelity field is overridden to `gnn`). Figure/bench
    /// code evaluating many specs shares one `Arc` so the AOT artifact
    /// is loaded and PJRT-compiled once, not per spec.
    pub fn with_gnn_model(mut spec: EvalSpec, model: Arc<GnnModel>) -> Engine {
        spec.fidelity = Fidelity::Gnn;
        Engine {
            spec,
            backend: Backend::Gnn(model),
        }
    }

    /// Infallible convenience: analytical training (the low fidelity of
    /// every `mfmobo` pair).
    pub fn analytical_training(model: LlmSpec) -> Engine {
        // lint: allow(panic) Engine::new only errs for Fidelity::Gnn without a model; training() is analytical
        Engine::new(EvalSpec::training(model)).expect("analytical backend is always available")
    }

    pub fn spec(&self) -> &EvalSpec {
        &self.spec
    }

    pub fn fidelity(&self) -> Fidelity {
        self.spec.fidelity
    }

    /// Size the system for a design point per the spec's wafer policy,
    /// with the spec's fault/heterogeneity overrides applied.
    pub fn system_for(&self, v: &Validated) -> SystemConfig {
        self.spec.system(v)
    }

    /// Capability query: a `Sync` view of this engine for explorers that
    /// fan design-point evaluations over the thread pool. `None` when the
    /// backend is thread-confined (the PJRT GNN) — those explorers fall
    /// back to their serial drive of [`Engine`]. The view's per-point
    /// strategy sweep is serial, so pool fan-out is never nested.
    pub fn to_sync(&self) -> Option<SyncEngine> {
        let backend = match &self.backend {
            Backend::Analytical(_) => SyncBackend::Analytical(Analytical),
            Backend::CycleAccurate(ca) => SyncBackend::CycleAccurate(ca.clone()),
            Backend::PseudoGnn(_) => SyncBackend::PseudoGnn(TestBackend::new()),
            Backend::Gnn(_) => return None,
        };
        Some(SyncEngine {
            spec: self.spec.clone(),
            backend,
        })
    }

    /// Training evaluation on an explicit system (bench/figure entry;
    /// [`DesignEval::eval`] wraps this with spec-driven system sizing).
    /// Pooled strategy sweep for `Sync` backends, batched link-wait
    /// inference for the thread-confined GNN.
    pub fn eval_train_system(&self, sys: &SystemConfig) -> Option<TrainEval> {
        match &self.backend {
            Backend::Analytical(a) => eval_training_pooled(&self.spec.model, sys, a),
            Backend::CycleAccurate(ca) => eval_training_pooled(&self.spec.model, sys, ca),
            Backend::PseudoGnn(b) => {
                eval_training_batched(&self.spec.model, sys, b, gnn_batch_size())
            }
            Backend::Gnn(m) => {
                eval_training_batched(&self.spec.model, sys, m.as_ref(), gnn_batch_size())
            }
        }
    }

    /// Inference evaluation on an explicit system: the spec's phase chunk
    /// rides the backend's per-chunk estimator — any fidelity, including
    /// the CA simulator and the (pseudo-)GNN.
    pub fn eval_infer_system(&self, sys: &SystemConfig) -> Option<InferEval> {
        self.eval_infer_system_at_batch(sys, self.spec.batch)
    }

    /// Inference evaluation at an explicit batch size, overriding the
    /// spec's. The serving simulator ([`crate::serving`]) drives this with
    /// the per-round in-flight count so continuous batching re-prices each
    /// round at its actual occupancy instead of the spec's static batch.
    pub fn eval_infer_system_at_batch(
        &self,
        sys: &SystemConfig,
        batch: usize,
    ) -> Option<InferEval> {
        let noc: &dyn NocEstimator = match &self.backend {
            Backend::Analytical(a) => a,
            Backend::CycleAccurate(ca) => ca,
            Backend::PseudoGnn(b) => b,
            Backend::Gnn(m) => m.as_ref(),
        };
        eval_inference(&self.spec.model, sys, batch.max(1), self.spec.mqa, noc)
    }
}

impl DesignEval for Engine {
    fn eval(&self, v: &Validated) -> Option<Objective> {
        let sys = self.system_for(v);
        match self.spec.phase {
            Phase::Training => self.eval_train_system(&sys).map(|r| train_objective(&r)),
            _ => self
                .eval_infer_system(&sys)
                .and_then(|r| infer_objective(&self.spec, &r)),
        }
    }

    fn eval_batch(&self, vs: &[Validated]) -> Vec<Option<Objective>> {
        // Sync backends hand the batch to the Sync view's fused/pooled
        // dispatch (same spec, bit-identical numbers).
        if let Some(sync) = self.to_sync() {
            return sync.eval_batch(vs);
        }
        // Thread-confined backend (the PJRT GNN): neither the fused
        // analytical sweep nor a pool fan-out applies — degrade to the
        // per-point loop (each point still batches link-wait inference
        // internally) and say so once, per the dispatch-failure contract.
        if vs.len() > 1 {
            crate::util::warn::warn_once(
                "engine-batch-serial",
                &format!(
                    "batched evaluation unavailable at fidelity '{}' \
                     (thread-confined backend); falling back to the per-point serial loop",
                    self.spec.fidelity.name()
                ),
            );
        }
        vs.iter().map(|v| self.eval(v)).collect()
    }

    fn name(&self) -> &'static str {
        self.spec.fidelity.name()
    }
}

/// `Sync` backends only — see [`Engine::to_sync`].
enum SyncBackend {
    Analytical(Analytical),
    CycleAccurate(CaEstimator),
    PseudoGnn(TestBackend),
}

/// The `Sync` view of an [`Engine`]: same spec, same numbers, but the
/// per-point strategy sweep is serial — pooled explorers fan whole design
/// points out instead, keeping the thread fan-out at exactly one level.
pub struct SyncEngine {
    spec: EvalSpec,
    backend: SyncBackend,
}

impl SyncEngine {
    /// The batched training dispatch for a `Sync` per-chunk estimator:
    /// size every candidate's system, then run one fused sweep over the
    /// whole batch ([`eval_training_batch_fused`]).
    fn batch_training(
        &self,
        vs: &[Validated],
        noc: &(dyn NocEstimator + Sync),
    ) -> Vec<Option<Objective>> {
        let systems: Vec<SystemConfig> = vs.iter().map(|v| self.spec.system(v)).collect();
        eval_training_batch_fused(&self.spec.model, &systems, noc)
            .into_iter()
            .map(|r| r.map(|r| train_objective(&r)))
            .collect()
    }
}

impl DesignEval for SyncEngine {
    fn eval(&self, v: &Validated) -> Option<Objective> {
        let sys = self.spec.system(v);
        match self.spec.phase {
            Phase::Training => {
                let r = match &self.backend {
                    SyncBackend::Analytical(a) => eval_training(&self.spec.model, &sys, a),
                    SyncBackend::CycleAccurate(ca) => eval_training(&self.spec.model, &sys, ca),
                    SyncBackend::PseudoGnn(b) => {
                        eval_training_batched(&self.spec.model, &sys, b, gnn_batch_size())
                    }
                };
                r.map(|r| train_objective(&r))
            }
            _ => {
                let noc: &dyn NocEstimator = match &self.backend {
                    SyncBackend::Analytical(a) => a,
                    SyncBackend::CycleAccurate(ca) => ca,
                    SyncBackend::PseudoGnn(b) => b,
                };
                eval_inference(
                    &self.spec.model,
                    &sys,
                    self.spec.batch.max(1),
                    self.spec.mqa,
                    noc,
                )
                .and_then(|r| infer_objective(&self.spec, &r))
            }
        }
    }

    fn eval_batch(&self, vs: &[Validated]) -> Vec<Option<Objective>> {
        match (&self.backend, self.spec.phase) {
            // The fused batched analytical sweep (and its CA twin).
            (SyncBackend::Analytical(a), Phase::Training) => self.batch_training(vs, a),
            (SyncBackend::CycleAccurate(ca), Phase::Training) => self.batch_training(vs, ca),
            // No cross-point strategy sweep to fuse (inference evaluates
            // one configuration per point; the pseudo-GNN sweep batches
            // link-wait inference internally): fan whole points over the
            // pool instead — still one level of parallelism, and each
            // point takes exactly the per-point serial path.
            _ => crate::util::pool::par_map(vs, |v| self.eval(v)),
        }
    }

    fn name(&self) -> &'static str {
        self.spec.fidelity.name()
    }
}

fn train_objective(r: &TrainEval) -> Objective {
    Objective {
        throughput: r.tokens_per_sec,
        power_w: r.power_w,
    }
}

/// Phase-aware inference objective: throughput is the phase's serving
/// metric — prompt tokens/s for prefill, generated tokens/s across the
/// batch for decode (the §IX-D serving metric) — power the steady-state
/// draw.
fn infer_objective(spec: &EvalSpec, r: &InferEval) -> Option<Objective> {
    let batch = spec.batch.max(1);
    let throughput = match spec.phase {
        Phase::Prefill => (batch * spec.model.seq_len) as f64 / r.prefill_s,
        _ => batch as f64 / r.decode_step_s,
    };
    if !throughput.is_finite() {
        return None;
    }
    Some(Objective {
        throughput,
        power_w: r.power_w,
    })
}

/// [`eval_training`] with the per-strategy sweep fanned out over the
/// scoped thread pool ([`crate::util::pool::par_map`]). Requires a `Sync`
/// NoC estimator — the analytical and cycle-accurate fidelities qualify.
///
/// Numerically identical to the serial path: the same ranked strategy
/// list is evaluated (each strategy's evaluation is deterministic and
/// independent) and ties resolve by the same last-max rule.
pub(crate) fn eval_training_pooled(
    spec: &LlmSpec,
    sys: &SystemConfig,
    noc: &(dyn NocEstimator + Sync),
) -> Option<TrainEval> {
    let strategies = ranked_strategies(spec, sys);
    if strategies.is_empty() {
        return None;
    }
    let evals =
        crate::util::pool::par_map(&strategies, |s| eval_training_with(spec, sys, *s, noc));
    best_eval(evals.into_iter())
}

/// Fixed per-strategy link-wait table produced by the batched GNN pass.
/// `None` (chunk exceeded padding, or the backend is unavailable) selects
/// the analytical model — the same per-chunk fallback contract as direct
/// GNN inference. The dimension guard keeps a stale table from leaking
/// into a chunk it was not predicted for.
struct PrecomputedWaits(Option<Vec<f64>>);

impl NocEstimator for PrecomputedWaits {
    fn link_waits(
        &self,
        chunk: &crate::compiler::CompiledChunk,
        _core: &crate::arch::CoreConfig,
    ) -> Option<Vec<f64>> {
        let n_links = chunk.region_h * chunk.region_w * crate::compiler::routing::NUM_DIRS;
        match &self.0 {
            Some(w) if w.len() == n_links => Some(w.clone()),
            _ => None,
        }
    }

    fn name(&self) -> &'static str {
        "gnn-batched"
    }
}

/// [`eval_training`] at a GNN-shaped fidelity with **batched** link-wait
/// inference: the representative chunk of every ranked strategy is
/// compiled (cache-served) up front, their padded features are packed
/// `batch` chunks per execute call through [`GnnBatcher`], and the sweep
/// then scores each strategy against its precomputed link waits.
///
/// The PJRT executable handle is thread-confined, so unlike the `Sync`
/// fidelities ([`eval_training_pooled`]) the win here is amortizing
/// per-call dispatch across the sweep, not thread fan-out. Strategies
/// whose region exceeds the GNN padding fall back to the analytical model
/// individually (hierarchical scale reduction per §VI), and an
/// unavailable backend degrades the whole sweep to the analytical model —
/// both exactly as with per-chunk inference. For a deterministic backend
/// the sweep is bit-identical to the serial per-chunk GNN sweep (proven
/// on the [`TestBackend`]); the PJRT batch executable may differ in the
/// last float bit where XLA reassociates reductions under `vmap`.
pub(crate) fn eval_training_batched(
    spec: &LlmSpec,
    sys: &SystemConfig,
    backend: &dyn GnnBackend,
    batch: usize,
) -> Option<TrainEval> {
    let strategies = ranked_strategies(spec, sys);
    if strategies.is_empty() {
        return None;
    }
    let core = sys.validated.point.wsc.reticle.core;
    // Strategies whose region the fault map disconnects have no chunk to
    // predict on — they drop out of the sweep here, exactly as the serial
    // path's per-strategy `None` drops them.
    let viable: Vec<_> = strategies
        .iter()
        .filter_map(|s| strategy_region(spec, sys, *s).map(|r| (*s, r)))
        .collect();
    if viable.is_empty() {
        return None;
    }
    let reqs: Vec<(&crate::compiler::CompiledChunk, &crate::arch::CoreConfig)> =
        viable.iter().map(|(_, r)| (&r.chunk, &core)).collect();
    let waits = GnnBatcher::new(backend, batch).link_waits_many(&reqs);
    best_eval(
        viable
            .iter()
            .zip(waits)
            .map(|((s, _), w)| eval_training_with(spec, sys, *s, &PrecomputedWaits(w))),
    )
}

/// The fused batched analytical sweep: evaluate a whole slice of candidate
/// systems with **one** flattened (point × strategy) fan-out over the
/// thread pool, deduping structurally identical region compiles across the
/// batch first.
///
/// Neighboring design points (a BO proposal pool, a random-search round)
/// frequently rank strategies whose representative regions compile to the
/// same chunk — same graph, same region dims, same core. Per-point
/// dispatch ([`eval_training_pooled`]) rediscovers that only through the
/// LRU chunk cache, point by point; here every job is signatured up front
/// ([`chunk_signature`]) so each unique compile runs exactly once and its
/// `Arc` is shared by every job that needs it, and the pool sees one long
/// work list instead of `|vs|` short ones (no fork/join barrier per
/// point).
///
/// Bit-identical to mapping [`eval_training_pooled`] over the slice: region
/// compiles are deterministic in their signature, each job's evaluation
/// ([`eval_training_on_region`]) is pure, jobs regroup in ranked-strategy
/// order, and per-point selection uses the same last-max tie rule
/// ([`best_eval`]). Fault-injected systems are excluded from the dedup —
/// their sampled fault maps are invisible to the signature — and take the
/// full per-job path ([`eval_training_with`]), reported once through the
/// shared dispatch-failure helper since the batch loses its compile
/// sharing there.
pub(crate) fn eval_training_batch_fused(
    spec: &LlmSpec,
    systems: &[SystemConfig],
    noc: &(dyn NocEstimator + Sync),
) -> Vec<Option<TrainEval>> {
    use std::collections::HashMap;

    let ranked: Vec<Vec<ParallelStrategy>> = systems
        .iter()
        .map(|sys| ranked_strategies(spec, sys))
        .collect();
    // One job per (candidate, ranked strategy), in per-point sweep order.
    let jobs: Vec<(usize, ParallelStrategy)> = ranked
        .iter()
        .enumerate()
        .flat_map(|(i, ss)| ss.iter().map(move |s| (i, *s)))
        .collect();

    // Stage 1: compile inputs + structural signatures, fault-free systems
    // only. A fault-injected system's compile depends on its sampled
    // fault map, which the signature does not cover — those jobs stay
    // `None` here and compile per job in stage 3.
    if systems.iter().any(|sys| sys.faults.is_some()) {
        crate::util::warn::warn_once(
            "batch-fused-faults",
            "batched sweep: fault-injected candidates compile per job \
             (fault maps are invisible to the dedup signature)",
        );
    }
    let inputs: Vec<Option<(OpGraph, usize, usize, u64)>> =
        crate::util::pool::par_map(&jobs, |(i, s)| {
            let sys = &systems[*i];
            if sys.faults.is_some() {
                return None;
            }
            let (graph, rh, rw) = region_input(spec, sys, *s);
            let sig = chunk_signature(&graph, rh, rw, &sys.validated.point.wsc.reticle.core);
            Some((graph, rh, rw, sig))
        });

    // Stage 2: compile each unique signature exactly once, through the
    // shared LRU chunk cache (so repeats across *batches* still hit).
    let mut first_of_sig: HashMap<u64, usize> = HashMap::new();
    for (j, inp) in inputs.iter().enumerate() {
        if let Some((_, _, _, sig)) = inp {
            first_of_sig.entry(*sig).or_insert(j);
        }
    }
    let unique: Vec<usize> = {
        let mut u: Vec<usize> = first_of_sig.into_values().collect();
        u.sort_unstable();
        u
    };
    let compiled: Vec<(u64, Arc<CachedChunk>)> = crate::util::pool::par_map(&unique, |&j| {
        // lint: allow(panic) `unique` indexes come from first_of_sig, built only over Some(_) inputs
        let (graph, rh, rw, sig) = inputs[j].as_ref().expect("unique job is signatured");
        let core = &systems[jobs[j].0].validated.point.wsc.reticle.core;
        (*sig, compile_chunk_cached(graph, *rh, *rw, core))
    });
    let chunk_of: HashMap<u64, Arc<CachedChunk>> = compiled.into_iter().collect();

    // Stage 3: one fused fan-out over the whole work list.
    let evals: Vec<Option<TrainEval>> = crate::util::pool::par_map_idx(jobs.len(), |j| {
        let (i, s) = jobs[j];
        let sys = &systems[i];
        match &inputs[j] {
            Some((_, _, _, sig)) => {
                eval_training_on_region(spec, sys, s, &chunk_of[sig], noc)
            }
            None => eval_training_with(spec, sys, s, noc),
        }
    });

    // Stage 4: regroup per candidate in ranked order — the same last-max
    // tie rule as every per-point sweep.
    let mut out: Vec<Option<TrainEval>> = Vec::with_capacity(systems.len());
    let mut cursor = 0;
    for ss in &ranked {
        let point_evals = evals[cursor..cursor + ss.len()].iter().cloned();
        cursor += ss.len();
        out.push(best_eval(point_evals));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design_space::{reference_point, validate};
    use crate::workload::models::benchmarks;

    fn _assert_sync<T: Sync>() {}
    #[allow(dead_code)]
    fn sync_engine_is_sync() {
        _assert_sync::<SyncEngine>();
    }

    #[test]
    fn fidelity_registry_round_trips() {
        for f in Fidelity::ALL {
            assert_eq!(Fidelity::parse(f.name()), Some(f));
        }
        assert_eq!(Fidelity::names(), "analytical, ca, gnn, gnn-test");
        // The pre-registry campaign schema name still parses.
        assert_eq!(Fidelity::parse("cycle-accurate"), Some(Fidelity::CycleAccurate));
        assert_eq!(Fidelity::parse("oracle"), None);
        let e = Fidelity::parse_or_usage("oracle").unwrap_err();
        assert!(e.contains("unknown fidelity 'oracle'"), "{e}");
        assert!(e.contains("analytical, ca, gnn, gnn-test"), "{e}");
    }

    #[test]
    fn analytical_training_engine_evaluates_reference() {
        let spec = benchmarks()[0].clone();
        let engine = Engine::analytical_training(spec);
        assert_eq!(engine.name(), "analytical");
        let v = validate(&reference_point()).unwrap();
        let o = engine.eval(&v).expect("reference point evaluable");
        assert!(o.throughput > 0.0);
        assert!(o.power_w > 0.0);
    }

    #[test]
    fn wafer_override_pins_system_sizing() {
        let spec = benchmarks()[0].clone();
        let v = validate(&reference_point()).unwrap();
        assert_eq!(system_for(&v, spec.gpu_num, Some(3)).n_wafers, 3);
        assert_eq!(system_for(&v, spec.gpu_num, Some(0)).n_wafers, 1);
        let auto = system_for(&v, spec.gpu_num, None);
        assert_eq!(
            auto.n_wafers,
            SystemConfig::area_matched(v.clone(), spec.gpu_num).n_wafers
        );
        // And the engine rides the override end to end.
        let engine =
            Engine::new(EvalSpec::training(spec).with_wafers(Some(1))).unwrap();
        let o = engine.eval(&v).expect("single-wafer point evaluable");
        assert!(o.throughput > 0.0 && o.power_w > 0.0);
    }

    #[test]
    fn ref_power_scales_with_model() {
        let small = ref_power_for(&benchmarks()[0]);
        let big = ref_power_for(&benchmarks()[9]);
        assert!(big > small * 10.0);
    }

    #[test]
    fn pseudo_gnn_engine_evaluates_reference() {
        // The batched GNN-fidelity sweep end to end on the default build
        // (TestBackend — no PJRT artifacts needed).
        let spec = benchmarks()[0].clone();
        let engine =
            Engine::new(EvalSpec::training(spec).with_fidelity(Fidelity::GnnTest)).unwrap();
        assert_eq!(engine.name(), "gnn-test");
        let v = validate(&reference_point()).unwrap();
        let o = engine.eval(&v).expect("reference point evaluable");
        assert!(o.throughput > 0.0);
        assert!(o.power_w > 0.0);
    }

    #[cfg(not(theseus_pjrt))]
    #[test]
    fn gnn_fidelity_errors_loudly_without_artifacts() {
        let spec = benchmarks()[0].clone();
        let e = Engine::new(EvalSpec::training(spec).with_fidelity(Fidelity::Gnn)).unwrap_err();
        assert!(e.contains("fidelity 'gnn' unavailable"), "{e}");
    }

    #[test]
    fn pooled_sweep_matches_serial_sweep() {
        // Engine::eval (pooled strategy sweep) and the serial reference
        // path must agree to strict tolerance (in practice bit-identical:
        // the per-strategy math is deterministic).
        let spec = &benchmarks()[0];
        let v = validate(&reference_point()).unwrap();
        let sys = SystemConfig {
            validated: v,
            n_wafers: 2,
            faults: None,
        };
        let engine = Engine::analytical_training(spec.clone());
        let serial = eval_training(spec, &sys, &Analytical);
        let pooled = engine.eval_train_system(&sys);
        match (serial, pooled) {
            (Some(a), Some(b)) => {
                assert_eq!(a.strategy, b.strategy);
                let rel = |x: f64, y: f64| (x - y).abs() / x.abs().max(1e-300);
                assert!(rel(a.tokens_per_sec, b.tokens_per_sec) <= 1e-9);
                assert!(rel(a.step_time_s, b.step_time_s) <= 1e-9);
                assert!(rel(a.power_w, b.power_w) <= 1e-9);
                assert!(rel(a.energy_per_token_j, b.energy_per_token_j) <= 1e-9);
            }
            (None, None) => {}
            (a, b) => panic!(
                "serial/pooled feasibility disagree: {:?} vs {:?}",
                a.map(|r| r.tokens_per_sec),
                b.map(|r| r.tokens_per_sec)
            ),
        }
    }

    #[test]
    fn batched_gnn_sweep_matches_per_chunk_sweep() {
        // The batched strategy sweep must select the same strategy and
        // produce bit-identical numbers as (a) the per-chunk batcher and
        // (b) the plain serial sweep driving the TestBackend as a
        // per-chunk NocEstimator — the batching is a pure amortization.
        let spec = &benchmarks()[0];
        let v = validate(&reference_point()).unwrap();
        let sys = SystemConfig {
            validated: v,
            n_wafers: 2,
            faults: None,
        };
        let backend = TestBackend::new();
        let batched = eval_training_batched(spec, &sys, &backend, 8);
        let per_chunk = eval_training_batched(spec, &sys, &backend, 1);
        let serial = eval_training(spec, &sys, &backend);
        match (batched, per_chunk, serial) {
            (Some(a), Some(b), Some(c)) => {
                assert_eq!(a.strategy, c.strategy);
                assert_eq!(a.tokens_per_sec, c.tokens_per_sec);
                assert_eq!(a.step_time_s, c.step_time_s);
                assert_eq!(a.power_w, c.power_w);
                assert_eq!(a.energy_per_token_j, c.energy_per_token_j);
                assert_eq!(b.strategy, c.strategy);
                assert_eq!(b.tokens_per_sec, c.tokens_per_sec);
            }
            (None, None, None) => {}
            (a, b, c) => panic!(
                "feasibility disagrees: batched={:?} per_chunk={:?} serial={:?}",
                a.map(|r| r.tokens_per_sec),
                b.map(|r| r.tokens_per_sec),
                c.map(|r| r.tokens_per_sec)
            ),
        }
    }

    #[test]
    fn sync_view_matches_engine_bitwise() {
        // The capability query's serial per-point path must produce the
        // exact numbers of the pooled Engine path, at every Sync fidelity
        // and phase — the dispatch level must never leak into results.
        let spec = benchmarks()[0].clone();
        let v = validate(&reference_point()).unwrap();
        for fidelity in [Fidelity::Analytical, Fidelity::GnnTest] {
            for (phase, batch) in [(Phase::Training, 0), (Phase::Prefill, 8), (Phase::Decode, 8)] {
                let es = EvalSpec {
                    model: spec.clone(),
                    phase,
                    batch,
                    mqa: false,
                    wafers: Some(2),
                    fidelity,
                    faults: None,
                    hetero: None,
                    interwafer: None,
                };
                let engine = Engine::new(es).unwrap();
                let sync = engine.to_sync().expect("Sync backend has a sync view");
                let a = engine.eval(&v);
                let b = sync.eval(&v);
                match (a, b) {
                    (Some(a), Some(b)) => {
                        assert_eq!(a.throughput, b.throughput, "{fidelity:?} {phase:?}");
                        assert_eq!(a.power_w, b.power_w, "{fidelity:?} {phase:?}");
                    }
                    (None, None) => {}
                    (a, b) => panic!("{fidelity:?} {phase:?}: {a:?} vs {b:?}"),
                }
            }
        }
    }

    #[test]
    fn gnn_backend_has_no_sync_view_offline() {
        // In the default build the Gnn engine cannot be constructed at
        // all; pin the capability contract on the ones that can.
        let spec = benchmarks()[0].clone();
        let engine = Engine::analytical_training(spec);
        assert!(engine.to_sync().is_some());
    }

    #[test]
    fn inference_phases_use_phase_metrics() {
        // Decode throughput = generated tokens/s across the batch;
        // prefill throughput = prompt tokens/s — both derived from the
        // same eval_inference call the engine makes.
        let spec = benchmarks()[0].clone();
        let v = validate(&reference_point()).unwrap();
        let decode = Engine::new(EvalSpec::inference(spec.clone(), Phase::Decode, 8)
            .with_wafers(Some(4)))
        .unwrap();
        let prefill = Engine::new(EvalSpec::inference(spec.clone(), Phase::Prefill, 8)
            .with_wafers(Some(4)))
        .unwrap();
        let sys = decode.system_for(&v);
        let r = decode.eval_infer_system(&sys).expect("evaluates");
        let od = decode.eval(&v).expect("decode objective");
        let op = prefill.eval(&v).expect("prefill objective");
        assert_eq!(od.throughput, 8.0 / r.decode_step_s);
        assert_eq!(op.throughput, (8 * spec.seq_len) as f64 / r.prefill_s);
        assert!(od.power_w > 0.0 && op.power_w > 0.0);
    }

    #[test]
    fn inference_rides_the_pseudo_gnn_estimator() {
        // The decode/prefill path accepts any NocEstimator now: the
        // pseudo-GNN fidelity must produce a valid, finite objective
        // (the §IX inference results at high fidelity).
        let spec = benchmarks()[0].clone();
        let v = validate(&reference_point()).unwrap();
        let engine = Engine::new(
            EvalSpec::inference(spec, Phase::Decode, 8)
                .with_fidelity(Fidelity::GnnTest)
                .with_wafers(Some(2)),
        )
        .unwrap();
        let o = engine.eval(&v).expect("gnn-test decode evaluates");
        assert!(o.throughput > 0.0 && o.throughput.is_finite());
        assert!(o.power_w > 0.0);
        assert_eq!(engine.name(), "gnn-test");
    }

    #[test]
    fn fault_spec_threads_through_every_dispatch() {
        // Faults on the EvalSpec must reach the evaluation (degraded or
        // equal objective, never better), identically through the pooled
        // Engine, the Sync view, and the batched GNN sweep.
        use crate::yield_model::faults::FaultSpec;
        let spec = benchmarks()[0].clone();
        let v = validate(&reference_point()).unwrap();
        let faults = Some(FaultSpec {
            defect_multiplier: 6.0,
            spares: Some(0),
            seed: 11,
        });
        for fidelity in [Fidelity::Analytical, Fidelity::GnnTest] {
            let base = Engine::new(
                EvalSpec::training(spec.clone())
                    .with_fidelity(fidelity)
                    .with_wafers(Some(1)),
            )
            .unwrap();
            let faulted = Engine::new(
                EvalSpec::training(spec.clone())
                    .with_fidelity(fidelity)
                    .with_wafers(Some(1))
                    .with_faults(faults),
            )
            .unwrap();
            let ob = base.eval(&v).expect("pristine point evaluable");
            let of = faulted.eval(&v).map_or(0.0, |o| o.throughput);
            assert!(
                of <= ob.throughput,
                "{fidelity:?}: faults improved throughput ({of} vs {})",
                ob.throughput
            );
            // Sync view sees the identical faulted system.
            if let Some(sync) = faulted.to_sync() {
                let os = sync.eval(&v).map_or(0.0, |o| o.throughput);
                assert_eq!(os.to_bits(), of.to_bits(), "{fidelity:?} sync/pooled drift");
            }
        }
    }

    #[test]
    fn hetero_override_reaches_inference() {
        use crate::arch::{HeteroConfig, HeteroGranularity};
        let spec = benchmarks()[0].clone();
        let v = validate(&reference_point()).unwrap();
        let hetero = HeteroConfig {
            granularity: HeteroGranularity::Reticle,
            prefill_ratio: 0.5,
            decode_stack_bw: 2.0,
        };
        let engine = Engine::new(
            EvalSpec::inference(spec, Phase::Decode, 8)
                .with_wafers(Some(4))
                .with_hetero(Some(hetero)),
        )
        .unwrap();
        assert_eq!(engine.system_for(&v).validated.point.hetero, hetero);
        let o = engine.eval(&v).expect("hetero decode evaluates");
        assert!(o.throughput > 0.0 && o.power_w > 0.0);
    }

    #[test]
    fn interwafer_override_reaches_multiwafer_eval() {
        use crate::arch::{InterWaferNet, InterWaferTopology};
        let spec = benchmarks()[0].clone();
        let v = validate(&reference_point()).unwrap();
        let slow = InterWaferNet {
            topology: InterWaferTopology::Ring,
            links_per_wafer: 2,
            link_bandwidth: 1.0e9,
            link_latency: 1.0e-6,
        };
        let base = Engine::new(EvalSpec::training(spec.clone()).with_wafers(Some(4))).unwrap();
        let slowed = Engine::new(
            EvalSpec::training(spec)
                .with_wafers(Some(4))
                .with_interwafer(Some(slow)),
        )
        .unwrap();
        assert_eq!(slowed.system_for(&v).validated.point.interwafer, slow);
        let ob = base.eval(&v).expect("base multi-wafer point evaluable");
        let os = slowed.eval(&v).expect("slow-net point evaluable");
        assert!(
            os.throughput <= ob.throughput,
            "crippling the inter-wafer net must not help ({} vs {})",
            os.throughput,
            ob.throughput
        );
    }

    #[test]
    fn mfmobo_high_fidelity_rides_the_batched_gnn_sweep() {
        // Miniature MFMOBO with the pseudo-GNN as f0: the high-fidelity
        // stage must produce trace points tagged with the batched GNN
        // fidelity (the Algo. 1 handoff runs through GnnBatcher).
        use crate::explorer::{mfmobo, BoConfig, MfConfig};
        let spec = benchmarks()[0].clone();
        let hi = Engine::new(EvalSpec::training(spec.clone()).with_fidelity(Fidelity::GnnTest))
            .unwrap();
        let lo = Engine::analytical_training(spec.clone());
        let mf = MfConfig {
            base: BoConfig {
                iters: 2,
                init: 1,
                pool: 8,
                mc_samples: 8,
                ref_power: ref_power_for(&spec),
                seed: 9,
                sample_tries: 2000,
            },
            n1: 1,
            d0: 1,
            d1: 1,
            k: 1,
        };
        let t = mfmobo(&hi, &lo, &mf);
        assert!(
            t.points.iter().any(|p| p.fidelity == "gnn-test"),
            "no high-fidelity (batched GNN) evaluations in the trace"
        );
        assert!(t.points.iter().any(|p| p.fidelity == "analytical"));
    }

    /// Reference point plus randomized valid design points — the batch
    /// shape every bit-identity contract below is pinned on.
    fn random_points(seed: u64, n: usize) -> Vec<Validated> {
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut vs = vec![validate(&reference_point()).unwrap()];
        for _ in 0..500 {
            if vs.len() >= n {
                break;
            }
            if let Some(v) = crate::design_space::sample_valid(&mut rng, 64) {
                vs.push(v);
            }
        }
        assert!(vs.len() >= 2, "need at least two valid sampled points");
        vs
    }

    fn assert_bitwise(a: &Option<Objective>, b: &Option<Objective>, ctx: &str) {
        match (a, b) {
            (Some(a), Some(b)) => {
                assert_eq!(a.throughput.to_bits(), b.throughput.to_bits(), "{ctx}");
                assert_eq!(a.power_w.to_bits(), b.power_w.to_bits(), "{ctx}");
            }
            (None, None) => {}
            (a, b) => panic!("{ctx}: feasibility disagrees ({a:?} vs {b:?})"),
        }
    }

    #[test]
    fn batched_analytical_sweep_is_bit_identical_to_pooled() {
        // The tentpole contract: one fused eval_batch over randomized
        // design points (including an exact duplicate, exercising the
        // cross-candidate compile dedup) must reproduce the per-point
        // pooled path bit for bit.
        let spec = benchmarks()[0].clone();
        let engine = Engine::analytical_training(spec);
        let mut vs = random_points(42, 5);
        vs.push(vs[0].clone()); // duplicate: shares every compile via dedup
        let batched = engine.eval_batch(&vs);
        assert_eq!(batched.len(), vs.len());
        for (i, v) in vs.iter().enumerate() {
            assert_bitwise(&batched[i], &engine.eval(v), &format!("point {i}"));
        }
        // The duplicate's result is the first point's, exactly.
        assert_bitwise(&batched[vs.len() - 1], &batched[0], "duplicate point");
    }

    #[test]
    fn eval_batch_matches_eval_across_phases_and_fidelities() {
        // Every (phase × Sync fidelity) pair: the batched dispatch — fused
        // sweep for analytical training, pool fan-out otherwise — must be
        // bit-identical to the per-point path.
        let spec = benchmarks()[0].clone();
        let vs = random_points(7, 4);
        for fidelity in [Fidelity::Analytical, Fidelity::GnnTest] {
            for (phase, batch) in [(Phase::Training, 0), (Phase::Prefill, 8), (Phase::Decode, 8)] {
                let es = EvalSpec {
                    model: spec.clone(),
                    phase,
                    batch,
                    mqa: false,
                    wafers: Some(2),
                    fidelity,
                    faults: None,
                    hetero: None,
                    interwafer: None,
                };
                let engine = Engine::new(es).unwrap();
                let batched = engine.eval_batch(&vs);
                assert_eq!(batched.len(), vs.len());
                for (i, v) in vs.iter().enumerate() {
                    assert_bitwise(
                        &batched[i],
                        &engine.eval(v),
                        &format!("{fidelity:?} {phase:?} point {i}"),
                    );
                }
            }
        }
    }

    #[test]
    fn faulted_batch_takes_the_per_job_path_bit_identically() {
        // Fault-injected candidates are excluded from the compile dedup
        // (their sampled maps are invisible to the signature) and must
        // still match the per-point path exactly.
        use crate::yield_model::faults::FaultSpec;
        let spec = benchmarks()[0].clone();
        let engine = Engine::new(
            EvalSpec::training(spec)
                .with_wafers(Some(1))
                .with_faults(Some(FaultSpec {
                    defect_multiplier: 6.0,
                    spares: Some(0),
                    seed: 11,
                })),
        )
        .unwrap();
        let vs = random_points(13, 3);
        let batched = engine.eval_batch(&vs);
        for (i, v) in vs.iter().enumerate() {
            assert_bitwise(&batched[i], &engine.eval(v), &format!("faulted point {i}"));
        }
    }

    #[test]
    fn incremental_reevaluation_is_exact() {
        // The delta-cache contract: re-evaluating a design point (or a
        // neighbor sharing its compiled chunks) serves memoized per-chunk
        // estimator results that are *exactly* the cold computation.
        use crate::eval::chunk::{delta_cache_clear, delta_cache_stats};
        let mut spec = benchmarks()[0].clone();
        spec.seq_len = 1234; // unique shape: entries cannot pre-exist
        let engine = Engine::analytical_training(spec);
        let v = validate(&reference_point()).unwrap();
        delta_cache_clear();
        let cold = engine.eval(&v).expect("reference point evaluable");
        let s0 = delta_cache_stats();
        let warm = engine.eval(&v).expect("reference point evaluable");
        let s1 = delta_cache_stats();
        assert_eq!(cold.throughput.to_bits(), warm.throughput.to_bits());
        assert_eq!(cold.power_w.to_bits(), warm.power_w.to_bits());
        if s1.capacity > 0 {
            assert!(
                s1.hits > s0.hits,
                "warm re-evaluation must hit the delta cache ({s0:?} -> {s1:?})"
            );
        }
        // And the batched path rides the same cache to the same bits.
        let batched = engine.eval_batch(std::slice::from_ref(&v));
        assert_bitwise(&batched[0], &Some(warm), "warm batched vs per-point");
    }
}
