//! Chunk-level evaluation (paper §VI-D): inter-chunk communication (TP
//! collectives, PP stage boundaries, DP weight updates), DRAM access, and
//! 1F1B pipeline efficiency — composing op-level results into end-to-end
//! training throughput and inference latency, with Aladdin-style power.

use std::sync::Arc;

use crate::arch::constants as k;
use crate::arch::{HeteroGranularity, MemoryKind};
use crate::compiler::cache::{compile_chunk_cached, CachedChunk};
use crate::compiler::{compile_chunk_faulted, FaultTopo, RouteError};
use crate::design_space::Validated;
use crate::eval::op_level::{chunk_latency_with_topo, NocModel, OpLevelResult};
use crate::eval::power::EnergyLedger;
use crate::eval::NocEstimator;
use crate::workload::parallel::{enumerate_strategies, train_chunk_bytes, SystemMemory};
use crate::workload::{LlmSpec, OpGraph, ParallelStrategy, Phase};
use crate::yield_model::faults::{region_seed, FaultMap, FaultSpec};
use crate::yield_model::{yield_grid, YieldInputs};

/// The system under evaluation: one validated WSC design replicated over
/// `n_wafers` wafers (§VIII-A: WSC area matched to the GPU-cluster area).
#[derive(Debug, Clone)]
pub struct SystemConfig {
    pub validated: Validated,
    pub n_wafers: usize,
    /// Optional fault injection: evaluate the design on a yield-realistic
    /// defective wafer instead of the ideal one. `None` (and a spec whose
    /// sampled map is pristine, e.g. defect multiplier 0) takes the
    /// bit-identical fault-free path.
    pub faults: Option<FaultSpec>,
}

impl SystemConfig {
    /// Wafer count matching the total area of `gpu_num` H100s (§VIII-A).
    pub fn area_matched(validated: Validated, gpu_num: usize) -> SystemConfig {
        let gpu_area = gpu_num as f64 * crate::baselines::H100_DIE_MM2;
        let n = (gpu_area / validated.phys.area_mm2).round().max(1.0) as usize;
        SystemConfig {
            validated,
            n_wafers: n,
            faults: None,
        }
    }

    pub fn total_cores(&self) -> usize {
        self.n_wafers
            * self.validated.point.wsc.num_reticles()
            * self.validated.phys.reticle.operational_cores()
    }

    pub fn total_reticles(&self) -> usize {
        self.n_wafers * self.validated.point.wsc.num_reticles()
    }

    pub fn memory(&self) -> SystemMemory {
        let wsc = &self.validated.point.wsc;
        SystemMemory {
            sram_bytes: self.n_wafers as f64 * wsc.total_sram_bytes(),
            stacking_bytes: self.n_wafers as f64 * wsc.total_stacking_bytes(),
            offchip_bytes: self.n_wafers as f64
                * wsc.mem_ctrl_count as f64
                * crate::baselines::OFFCHIP_GB_PER_CTRL
                * 1e9,
            total_cores: self.total_cores(),
        }
    }

    /// Aggregate DRAM bandwidth (bytes/s) per wafer, and its energy tier.
    /// Off-chip bandwidth is additionally bounded by the wafer-edge
    /// inter-reticle ring (§IX-F: "long-range DRAM-access-induced data
    /// transfer from the WSC edge can become the performance bottleneck").
    pub fn wafer_dram_bw(&self) -> (f64, bool) {
        let wsc = &self.validated.point.wsc;
        let phys = &self.validated.phys;
        match wsc.reticle.memory {
            MemoryKind::Stacking { .. } => (
                wsc.num_reticles() as f64 * phys.reticle.stack_bytes_per_sec,
                true,
            ),
            MemoryKind::OffChip => {
                let ctrl = wsc.off_chip_bytes_per_sec();
                let edge_links = 2.0 * (wsc.reticle_h + wsc.reticle_w) as f64;
                let ring = edge_links * wsc.reticle.inter_reticle_bytes_per_sec() / 2.0;
                (ctrl.min(ring), false)
            }
        }
    }
}

/// Time breakdown of one training step (seconds).
#[derive(Debug, Clone, Copy, Default)]
pub struct Breakdown {
    pub compute_s: f64,
    pub noc_s: f64,
    pub tp_s: f64,
    pub pp_s: f64,
    pub dp_s: f64,
    pub dram_s: f64,
}

/// Training evaluation result.
#[derive(Debug, Clone)]
pub struct TrainEval {
    pub strategy: ParallelStrategy,
    pub step_time_s: f64,
    pub tokens_per_sec: f64,
    pub power_w: f64,
    pub energy_per_token_j: f64,
    /// Energy-delay product per step (J·s) — the Fig. 9 metric.
    pub edp: f64,
    pub breakdown: Breakdown,
}

/// Cap on strategies fully evaluated per design point (the paper iterates
/// all; we rank by a cheap heuristic first and evaluate the best few —
/// env `THESEUS_STRATEGY_CAP` overrides).
fn strategy_cap() -> usize {
    crate::util::cli::env_usize("THESEUS_STRATEGY_CAP", 16)
}

/// Rank feasible strategies by the cheap heuristic and keep the best few
/// (shared by the serial, pooled and batched evaluation paths —
/// [`crate::eval::engine`] — so every dispatch sweeps the exact same
/// candidate list).
pub(crate) fn ranked_strategies(spec: &LlmSpec, sys: &SystemConfig) -> Vec<ParallelStrategy> {
    let mem = sys.memory();
    let mut strategies = enumerate_strategies(spec, &mem);
    // Heuristic rank: chunks close to the reticle count (one chunk per
    // reticle neighborhood), high pipeline efficiency, moderate TP.
    let n_ret = sys.total_reticles() as f64;
    strategies.sort_by(|a, b| {
        let score = |s: &ParallelStrategy| {
            let chunk_ratio = ((s.num_chunks() as f64 / n_ret).ln()).abs();
            let eff = s.pipeline_efficiency(spec);
            let tp_pen = (s.tp as f64).ln() * 0.1;
            chunk_ratio - eff + tp_pen
        };
        score(a).partial_cmp(&score(b)).unwrap()
    });
    strategies.truncate(strategy_cap());
    strategies
}

pub(crate) fn best_eval(evals: impl Iterator<Item = Option<TrainEval>>) -> Option<TrainEval> {
    evals
        .flatten()
        .max_by(|a, b| a.tokens_per_sec.partial_cmp(&b.tokens_per_sec).unwrap())
}

/// Sample the full-array fault map for this system: per-core yield grid
/// reconstructed from the converged physical reticle (the same
/// [`YieldInputs`] the redundancy search used), threshold-sampled at the
/// spec's defect multiplier, then spare-row-repaired (`spares` from the
/// spec, defaulting to the per-row allocation the design's own redundancy
/// plan converged on).
fn sampled_array_map(sys: &SystemConfig, spec: &FaultSpec) -> FaultMap {
    let ret = &sys.validated.phys.reticle;
    let inp = YieldInputs {
        array_h: ret.array_h,
        array_w: ret.array_w,
        core_w_mm: ret.core.width_mm,
        core_h_mm: ret.core.height_mm,
        core_area_cm2: ret.core.area_mm2 / 100.0,
        reticle_w_mm: ret.width_mm,
        reticle_h_mm: ret.height_mm,
        tsv_stress_utilization: ret.tsv.stress_utilization,
    };
    let grid = yield_grid(&inp);
    let seed = region_seed(spec.seed, ret.array_h, ret.array_w);
    let mut map = FaultMap::sample(&grid, spec.defect_multiplier, seed);
    map.repair_rows(spec.spares.unwrap_or(ret.red_per_row));
    map
}

/// Fraction of operational cores that survive fault sampling + spare-row
/// repair across the full array. Exactly `1.0` when no fault spec is set,
/// so multiplying capacities/bandwidths by it keeps the fault-free path
/// bit-identical.
pub(crate) fn system_live_fraction(sys: &SystemConfig) -> f64 {
    let Some(spec) = sys.faults else {
        return 1.0;
    };
    let ret = &sys.validated.phys.reticle;
    let map = sampled_array_map(sys, &spec);
    map.live_cores() as f64 / (ret.array_h * ret.array_w).max(1) as f64
}

/// Degraded topology for an `rh × rw` evaluation region of this system:
/// `Ok(None)` on the bit-identical fault-free path (no spec, or the sampled
/// + repaired map is pristine over the region), `Err` — loudly — when the
/// sampled faults disconnect the region's mesh.
pub(crate) fn fault_topo_for_region(
    sys: &SystemConfig,
    rh: usize,
    rw: usize,
) -> Result<Option<Arc<FaultTopo>>, RouteError> {
    let Some(spec) = sys.faults else {
        return Ok(None);
    };
    let map = sampled_array_map(sys, &spec);
    let (ah, aw) = map.dims();
    let map = map.crop(rh.min(ah), rw.min(aw));
    if map.is_pristine() {
        return Ok(None);
    }
    FaultTopo::new(map).map(|t| Some(Arc::new(t)))
}

/// The compile input of one strategy's representative region: the op
/// graph plus region dims. Split out of [`strategy_region`] so the fused
/// batched sweep ([`crate::eval::engine`]) can signature the input and
/// dedupe structurally identical compiles across a whole candidate batch
/// before fanning the evaluations out.
pub(crate) fn region_input(
    spec: &LlmSpec,
    sys: &SystemConfig,
    s: ParallelStrategy,
) -> (OpGraph, usize, usize) {
    let wsc = &sys.validated.point.wsc;
    let chunks = s.num_chunks() as f64;
    let cores_per_chunk = (sys.total_cores() as f64 / chunks).max(1.0);
    let graph_layers = s.layers_per_stage(spec).min(2).max(1);
    let graph =
        OpGraph::transformer_chunk(spec, graph_layers, s.microbatch, s.tp, Phase::Training, false);
    let (rh, rw) = region_dims(cores_per_chunk, wsc.reticle.array_h, wsc.reticle.array_w);
    (graph, rh, rw)
}

/// Compile (cache-served) the representative region of one strategy — the
/// §VI hierarchical-evaluation slice that `eval_training_with` scores.
/// Shared by the serial sweep and the engine's batched GNN sweep so both
/// evaluate byte-identical chunks. Under a fault spec the region compiles
/// onto the degraded mesh (bypassing the memo, whose signature does not
/// cover fault maps — the chunk stays unkeyed, so the delta cache skips
/// it too); `None` means the sampled faults disconnect the region — the
/// design is infeasible on this defective wafer.
pub(crate) fn strategy_region(
    spec: &LlmSpec,
    sys: &SystemConfig,
    s: ParallelStrategy,
) -> Option<Arc<CachedChunk>> {
    let wsc = &sys.validated.point.wsc;
    let (graph, rh, rw) = region_input(spec, sys, s);
    match fault_topo_for_region(sys, rh, rw) {
        Ok(None) => Some(compile_chunk_cached(&graph, rh, rw, &wsc.reticle.core)),
        Ok(Some(topo)) => {
            let chunk = compile_chunk_faulted(&graph, &wsc.reticle.core, topo);
            Some(Arc::new(CachedChunk::unkeyed(chunk)))
        }
        Err(_) => None,
    }
}

/// Evaluate LLM training on the system (§VI-D + §VI-A strategy search),
/// serially, with any per-chunk estimator. This is the reference sweep;
/// the engine's pooled and batched dispatches
/// ([`crate::eval::engine::Engine`]) are proven equivalent against it.
/// Returns `None` when no parallel strategy fits memory.
pub fn eval_training(
    spec: &LlmSpec,
    sys: &SystemConfig,
    noc: &dyn NocEstimator,
) -> Option<TrainEval> {
    let strategies = ranked_strategies(spec, sys);
    best_eval(strategies.iter().map(|s| eval_training_with(spec, sys, *s, noc)))
}

/// Evaluate one specific strategy.
pub fn eval_training_with(
    spec: &LlmSpec,
    sys: &SystemConfig,
    s: ParallelStrategy,
    noc: &dyn NocEstimator,
) -> Option<TrainEval> {
    // None: the sampled fault map disconnects the region (infeasible on
    // this defective wafer). Degradation within a connected region shows
    // up through the compile itself — fewer logical cores, longer routes.
    let cached = strategy_region(spec, sys, s)?;
    eval_training_on_region(spec, sys, s, &cached, noc)
}

/// Score one strategy on its already-compiled representative region. The
/// tail of [`eval_training_with`], split out so the fused batched sweep
/// can hand in a signature-deduped chunk shared across the batch; pure in
/// its inputs, so both entry points are bit-identical by construction.
pub(crate) fn eval_training_on_region(
    spec: &LlmSpec,
    sys: &SystemConfig,
    s: ParallelStrategy,
    cached: &CachedChunk,
    noc: &dyn NocEstimator,
) -> Option<TrainEval> {
    let wsc = &sys.validated.point.wsc;
    let phys = &sys.validated.phys;
    let core_cfg = &wsc.reticle.core;
    let chunks = s.num_chunks() as f64;
    let cores_per_chunk = (sys.total_cores() as f64 / chunks).max(1.0);

    // --- op level on a representative region ([`strategy_region`]) ---
    let graph_layers = s.layers_per_stage(spec).min(2).max(1);
    let layer_scale = s.layers_per_stage(spec) as f64 / graph_layers as f64;
    let region_cores = (cached.chunk.region_h * cached.chunk.region_w) as f64;
    let scale = (cores_per_chunk / region_cores).max(1.0);
    let op = op_result(&cached, core_cfg, scale, noc);
    let t_op = op.cycles * layer_scale / k::CLOCK_HZ;

    // --- chunk-level communications ---
    let bpe = k::BYTES_PER_ELEM;
    let msh = s.microbatch as f64 * spec.seq_len as f64 * spec.hidden as f64 * bpe;

    // TP ring all-reduce: 2 per layer fwd + 2 bwd.
    let reticles_per_chunk = (cores_per_chunk / phys.reticle.operational_cores() as f64).max(1e-9);
    let bw_tp = if s.tp == 1 {
        f64::INFINITY
    } else if reticles_per_chunk <= 1.0 {
        wsc.reticle.bisection_bytes_per_sec()
    } else {
        let border = reticles_per_chunk.sqrt().ceil();
        border * wsc.reticle.inter_reticle_bytes_per_sec()
    };
    let ar_bytes = 2.0 * (s.tp as f64 - 1.0) / s.tp as f64 * msh;
    let t_tp = 4.0 * s.layers_per_stage(spec) as f64 * ar_bytes / bw_tp;

    // PP boundary: activations + their gradients cross once per microbatch.
    let wafers = sys.n_wafers as f64;
    let pp_bytes = 2.0 * msh / s.tp as f64;
    let cross_wafer_frac = if s.pp > 1 {
        ((wafers - 1.0).max(0.0) / (s.pp as f64 - 1.0)).min(1.0)
    } else {
        0.0
    };
    let bw_pp_on = wsc.reticle.inter_reticle_bytes_per_sec()
        * (wsc.reticle_h.min(wsc.reticle_w) as f64).max(1.0);
    // Cross-wafer stage boundaries go through the inter-wafer network's
    // point-to-point model; everything stays on-wafer at wafers == 1
    // (cross_wafer_frac is exactly 0 there, keeping the single-wafer
    // result bit-identical to the pre-topology model).
    let net = &sys.validated.point.interwafer;
    let t_pp = if s.pp == 1 {
        0.0
    } else if wafers <= 1.0 {
        pp_bytes * ((1.0 - cross_wafer_frac) / bw_pp_on)
    } else {
        pp_bytes * ((1.0 - cross_wafer_frac) / bw_pp_on)
            + net.p2p_s(pp_bytes * cross_wafer_frac, sys.n_wafers)
    };

    // DRAM: weight streaming when the chunk state exceeds its SRAM share.
    // Dead cores take their SRAM with them (× 1.0 exactly when fault-free).
    let live_frac = system_live_fraction(sys);
    let sram_per_chunk = mem_share(sys.memory().sram_bytes * live_frac, chunks);
    let state_bytes = train_chunk_bytes(spec, &s);
    let stage_weights = spec.param_bytes() / (s.tp * s.pp) as f64;
    let (wafer_dram_bw, stacked) = sys.wafer_dram_bw();
    let chunk_dram_bw = wafer_dram_bw * wafers / chunks;
    let (t_dram_mb, dram_bytes_mb) = if state_bytes <= sram_per_chunk {
        (0.0, 0.0)
    } else {
        (stage_weights / chunk_dram_bw, stage_weights)
    };

    // DP weight update: gradient all-reduce once per step, plus optimizer
    // state read+write from wherever it lives. Replicas co-resident on a
    // single wafer ride the on-wafer fabric (the pre-PR-9 condition
    // `dp_on_wafer && wafers <= 1.0` was unreachable — `dp <= wafers` with
    // one wafer forces dp == 1 — so single-wafer DP was mischarged
    // inter-wafer bandwidth); across wafers the inter-wafer network prices
    // the collective. `allreduce_s` takes the *raw* sharded weight bytes —
    // it applies its own ring-factor — where `grad_bytes` pre-bakes the
    // `2(dp-1)/dp` volume for the flat on-wafer path and the energy ledger.
    let grad_bytes = 2.0 * (s.dp as f64 - 1.0) / s.dp as f64 * stage_weights;
    let t_dp = if s.dp == 1 {
        0.0
    } else if wafers <= 1.0 {
        grad_bytes / bw_pp_on
    } else {
        net.allreduce_s(stage_weights, s.dp, sys.n_wafers, bw_pp_on)
    };
    let opt_bytes = if state_bytes <= sram_per_chunk {
        0.0
    } else {
        2.0 * spec.train_state_bytes() / (s.tp * s.pp) as f64
    };
    let t_opt = opt_bytes / chunk_dram_bw;

    // --- 1F1B pipeline composition ---
    let mb_count = s.microbatches_per_step(spec) as f64;
    let t_mb = t_op + t_tp + t_pp + t_dram_mb;
    let slots = mb_count + s.pp as f64 - 1.0;
    let step_time = slots * t_mb + t_dp + t_opt;
    if !step_time.is_finite() || step_time <= 0.0 {
        return None;
    }
    let tokens = (spec.batch_size * spec.seq_len) as f64;

    // --- energy ledger (action counts for the whole step) ---
    let per_chunk_runs = mb_count; // each chunk executes every microbatch
    let ledger_scale = chunks * per_chunk_runs * layer_scale;
    let mut ledger = EnergyLedger {
        mac_ops: op.mac_ops * scale * ledger_scale,
        sram_bytes: op.sram_bytes * scale * ledger_scale,
        noc_byte_hops: op.byte_hops * scale * ledger_scale,
        inter_reticle_bytes: (ar_bytes * 4.0 * s.layers_per_stage(spec) as f64
            * (s.tp > 1) as u64 as f64
            + pp_bytes * (1.0 - cross_wafer_frac))
            * chunks
            * per_chunk_runs
            + if wafers <= 1.0 { grad_bytes * chunks } else { 0.0 },
        // Gradient traffic only leaves the wafer when replicas span wafers
        // (the single-wafer share moves to the inter-reticle line above —
        // the same mischarge the t_dp fix corrects).
        inter_wafer_bytes: (pp_bytes * cross_wafer_frac * per_chunk_runs
            + if wafers > 1.0 { grad_bytes } else { 0.0 })
            * chunks,
        dram_stacked_bytes: 0.0,
        dram_offchip_bytes: 0.0,
        time_s: step_time,
        static_w: total_static_w(sys),
    };
    let dram_total = (dram_bytes_mb * per_chunk_runs + opt_bytes) * chunks;
    if stacked {
        ledger.dram_stacked_bytes = dram_total;
    } else {
        ledger.dram_offchip_bytes = dram_total;
    }
    let power = ledger.avg_power_w(&phys.reticle.core, &phys.reticle);
    let energy = ledger.total_energy_j(&phys.reticle.core, &phys.reticle);

    Some(TrainEval {
        strategy: s,
        step_time_s: step_time,
        tokens_per_sec: tokens / step_time,
        power_w: power,
        energy_per_token_j: energy / tokens,
        edp: energy * step_time,
        breakdown: Breakdown {
            compute_s: op.compute_cycles * layer_scale / k::CLOCK_HZ * slots,
            noc_s: op.comm_cycles * layer_scale / k::CLOCK_HZ * slots,
            tp_s: t_tp * slots,
            pp_s: t_pp * slots,
            dp_s: t_dp,
            dram_s: t_dram_mb * slots + t_opt,
        },
    })
}

fn region_dims(cores: f64, max_h: usize, max_w: usize) -> (usize, usize) {
    let side = cores.sqrt().ceil() as usize;
    let rh = side.clamp(1, max_h);
    let rw = ((cores / rh as f64).ceil() as usize).clamp(1, max_w);
    (rh, rw)
}

fn mem_share(total: f64, chunks: f64) -> f64 {
    total / chunks
}

fn total_static_w(sys: &SystemConfig) -> f64 {
    sys.n_wafers as f64
        * sys.validated.point.wsc.num_reticles() as f64
        * sys.validated.phys.reticle.leak_w
}

/// Delta cache (incremental neighbor re-evaluation): per-chunk estimator
/// results memoized under `(chunk signature, scale bits, estimator cache
/// key)`. When a BO proposal differs from an already-evaluated neighbor in
/// a subset of design genes, the strategies whose representative regions
/// are structurally unchanged re-serve their [`OpLevelResult`] instead of
/// re-running the critical-path sweep (or the CA simulator). Exactness:
/// the chunk signature covers every compile input, `scale` is keyed by
/// IEEE bits, and [`NocEstimator::cache_key`] is only `Some` for
/// estimators that are pure functions of `(chunk, core)` — so a hit
/// returns the bit-identical result a cold evaluation would compute
/// (asserted in `eval::engine` tests and `benches/perf_hotpath.rs`).
/// Unkeyed chunks (`sig` 0: fault-injected regions) always miss through
/// to a fresh computation. Bounded by `THESEUS_DELTA_CACHE` (entries,
/// default 4096; 0 disables).
fn delta_cache() -> &'static crate::util::memo::Memo<(u64, u64, u64), OpLevelResult> {
    static CACHE: std::sync::OnceLock<crate::util::memo::Memo<(u64, u64, u64), OpLevelResult>> =
        std::sync::OnceLock::new();
    CACHE.get_or_init(|| {
        crate::util::memo::Memo::new(crate::util::cli::env_usize("THESEUS_DELTA_CACHE", 4096))
    })
}

/// Point-in-time delta-cache counters (benches and tests).
pub fn delta_cache_stats() -> crate::util::memo::MemoStats {
    delta_cache().stats()
}

/// Drop all delta-cache entries and zero the counters (bench isolation).
pub fn delta_cache_clear() {
    delta_cache().clear()
}

fn op_result(
    cached: &CachedChunk,
    core: &crate::arch::CoreConfig,
    scale: f64,
    noc: &dyn NocEstimator,
) -> OpLevelResult {
    let compute = || {
        let (chunk, topo) = (&cached.chunk, &cached.topo);
        match noc.link_waits(chunk, core) {
            Some(waits) => {
                chunk_latency_with_topo(chunk, topo, core, scale, NocModel::LinkWaits(&waits))
            }
            None => chunk_latency_with_topo(chunk, topo, core, scale, NocModel::Analytical),
        }
    };
    // The signature covers (graph, region, core) — `core` is always the
    // compile core — so (sig, scale, estimator) determines the result.
    match (cached.sig, noc.cache_key()) {
        (0, _) | (_, None) => compute(),
        (sig, Some(noc_key)) => {
            delta_cache().get_or_insert_with((sig, scale.to_bits(), noc_key), compute)
        }
    }
}

// ---------------------------------------------------------------------
// Inference (§V-B, §IX-D/E): prefill + decode with optional heterogeneity.
// ---------------------------------------------------------------------

/// Inference evaluation result (per wafer-system).
#[derive(Debug, Clone)]
pub struct InferEval {
    /// Prefill latency for one batch, seconds.
    pub prefill_s: f64,
    /// Per-token decode step latency, seconds.
    pub decode_step_s: f64,
    /// End-to-end tokens/s generating `seq_len` output tokens at `batch`.
    pub tokens_per_sec: f64,
    pub power_w: f64,
    /// Where weights+KV live: "sram" / "stacked" / "offchip".
    pub residency: &'static str,
}

/// Evaluate inference at `batch` with optional MQA (§VIII-A: in/out
/// sequence 2048, batch 32).
pub fn eval_inference(
    spec: &LlmSpec,
    sys: &SystemConfig,
    batch: usize,
    mqa: bool,
    noc: &dyn NocEstimator,
) -> Option<InferEval> {
    let wsc = &sys.validated.point.wsc;
    let phys = &sys.validated.phys;
    let hetero = sys.validated.point.hetero;
    let split = hetero.split(wsc);
    // Fault derating for the analytic decode path: dead cores surrender
    // their SRAM capacity and bandwidth, and their compute. (The compiled
    // prefill region degrades through the compile instead; × 1.0 exactly
    // on the fault-free path.)
    let live_frac = system_live_fraction(sys);

    // Memory residency for weights + KV cache.
    let mut mem = sys.memory();
    mem.sram_bytes *= live_frac;
    let weights = spec.param_bytes();
    let kv = spec.kv_cache_bytes_per_seq(mqa) * batch as f64;
    let need = weights + kv;
    let (residency, mem_bw_total, stacked) = if need <= mem.sram_bytes {
        // SRAM-resident: aggregate on-core SRAM bandwidth.
        let bw = sys.total_cores() as f64 * live_frac * wsc.reticle.core.sram_bytes_per_sec();
        ("sram", bw, false)
    } else if need <= mem.sram_bytes + mem.stacking_bytes && mem.stacking_bytes > 0.0 {
        let decode_bw_scale = if split.shared {
            1.0
        } else {
            // Reticle/wafer hetero: decode reticles carry their own
            // (possibly boosted) stacking bandwidth.
            (split.decode_stack_bw.max(0.01))
                / stack_bw_of(wsc).max(0.01)
                * (split.decode_reticles as f64 / wsc.num_reticles() as f64)
        };
        let (bw, _) = sys.wafer_dram_bw();
        ("stacked", bw * sys.n_wafers as f64 * decode_bw_scale.max(1e-3), true)
    } else if need <= mem.total_bytes() {
        let (bw, _) = sys.wafer_dram_bw();
        let bw = if matches!(wsc.reticle.memory, MemoryKind::OffChip) {
            bw
        } else {
            wsc.off_chip_bytes_per_sec()
        };
        ("offchip", bw * sys.n_wafers as f64, false)
    } else {
        return None; // doesn't fit at all
    };

    // --- decode: memory-bound streaming of weights (shared by the batch)
    // + KV (per sequence), plus the small GEMV compute ---
    let tp = pick_infer_tp(spec, sys);
    let decode_flops = spec.fwd_flops_per_token() * batch as f64;
    let prefill_frac = if split.shared { 1.0 } else { hetero.prefill_ratio };
    let decode_cores = (sys.total_cores() as f64
        * live_frac
        * if split.shared { 1.0 } else { 1.0 - prefill_frac })
    .max(1.0);
    let decode_compute_s = decode_flops
        / (decode_cores * wsc.reticle.core.peak_flops() * 0.3); // GEMV ~30 % util
    let decode_mem_bytes = weights + spec.kv_cache_bytes_per_seq(mqa) * batch as f64;
    let decode_mem_s = decode_mem_bytes / mem_bw_total;
    // Multi-wafer decode: weights are sharded across wafers, so every
    // step ends with a partial-sum all-reduce of the batch's activations
    // over the inter-wafer network. Exactly zero at one wafer — the
    // single-wafer path stays bit-identical to the pre-topology model.
    let net = &sys.validated.point.interwafer;
    let decode_act_bytes = batch as f64 * spec.hidden as f64 * k::BYTES_PER_ELEM;
    let decode_net_s = if sys.n_wafers > 1 {
        net.allreduce_s(decode_act_bytes, sys.n_wafers, sys.n_wafers, f64::INFINITY)
    } else {
        0.0
    };
    let decode_step_s =
        (decode_compute_s.max(decode_mem_s) + decode_net_s) * split.sched_overhead;

    // --- prefill: compute-bound, refined by the op-level NoC model ---
    let prefill_cores = (sys.total_cores() as f64 * prefill_frac).max(1.0);
    let graph = OpGraph::transformer_chunk(spec, 1, batch.min(4), tp, Phase::Prefill, mqa);
    let (rh, rw) = region_dims(
        prefill_cores / spec.layers as f64,
        wsc.reticle.array_h,
        wsc.reticle.array_w,
    );
    let cached = match fault_topo_for_region(sys, rh, rw) {
        Ok(None) => compile_chunk_cached(&graph, rh, rw, &wsc.reticle.core),
        Ok(Some(topo)) => {
            let chunk = compile_chunk_faulted(&graph, &wsc.reticle.core, topo);
            Arc::new(CachedChunk::unkeyed(chunk))
        }
        // Faults disconnect the prefill region: infeasible on this wafer.
        Err(_) => return None,
    };
    let scale = (prefill_cores / spec.layers as f64 / (rh * rw) as f64).max(1.0);
    let op = op_result(&cached, &wsc.reticle.core, scale, noc);
    // One layer evaluated at batch min(4): scale to full batch × layers
    // (layers pipeline across the wafer, so latency ≈ layers × per-layer).
    let batch_scale = batch as f64 / batch.min(4) as f64;
    // Multi-wafer prefill: the layer pipeline spans wafers, so the full
    // batch's boundary activations cross the inter-wafer network once per
    // wafer boundary. Zero at one wafer (bit-identical single-wafer path).
    let prefill_net_s = if sys.n_wafers > 1 {
        let boundary_bytes =
            batch as f64 * spec.seq_len as f64 * spec.hidden as f64 * k::BYTES_PER_ELEM;
        (sys.n_wafers as f64 - 1.0) * net.p2p_s(boundary_bytes, sys.n_wafers)
    } else {
        0.0
    };
    let prefill_s = op.cycles * spec.layers as f64 * batch_scale / k::CLOCK_HZ + prefill_net_s;

    // KV handoff between stages (hetero §IX-E).
    let kv_handoff_s = if split.shared {
        0.0
    } else {
        kv / split.kv_transfer_bw.max(1.0)
    };

    // Generate seq_len output tokens.
    let out_tokens = spec.seq_len as f64;
    let total_s = if split.shared {
        prefill_s + kv_handoff_s + out_tokens * decode_step_s
    } else {
        // Stages pipeline across requests: throughput set by the slower
        // stage; latency still sums.
        (prefill_s + kv_handoff_s).max(out_tokens * decode_step_s)
    };
    let tokens_per_sec = batch as f64 * out_tokens / total_s;

    // --- power ---
    let mut ledger = EnergyLedger {
        mac_ops: (spec.fwd_flops_per_token() * (spec.seq_len as f64 + out_tokens) * batch as f64)
            / k::FLOPS_PER_MAC,
        sram_bytes: need * out_tokens * 0.5, // streaming reuse estimate
        noc_byte_hops: op.byte_hops * scale * spec.layers as f64 * batch_scale,
        inter_reticle_bytes: kv,
        inter_wafer_bytes: {
            let hetero_kv = if hetero.granularity == HeteroGranularity::Wafer {
                kv
            } else {
                0.0
            };
            if sys.n_wafers > 1 {
                hetero_kv
                    + decode_act_bytes * out_tokens
                    + batch as f64
                        * spec.seq_len as f64
                        * spec.hidden as f64
                        * k::BYTES_PER_ELEM
                        * (sys.n_wafers as f64 - 1.0)
            } else {
                hetero_kv
            }
        },
        dram_stacked_bytes: if stacked { decode_mem_bytes * out_tokens } else { 0.0 },
        dram_offchip_bytes: if residency == "offchip" {
            decode_mem_bytes * out_tokens
        } else {
            0.0
        },
        time_s: total_s,
        static_w: total_static_w(sys),
    };
    if residency == "sram" {
        ledger.sram_bytes += decode_mem_bytes * out_tokens;
    }
    let power = ledger.avg_power_w(&phys.reticle.core, &phys.reticle);

    Some(InferEval {
        prefill_s,
        decode_step_s,
        tokens_per_sec,
        power_w: power,
        residency,
    })
}

fn stack_bw_of(wsc: &crate::arch::WscConfig) -> f64 {
    match wsc.reticle.memory {
        MemoryKind::OffChip => 0.0,
        MemoryKind::Stacking {
            bw_tbps_per_100mm2, ..
        } => bw_tbps_per_100mm2,
    }
}

fn pick_infer_tp(spec: &LlmSpec, sys: &SystemConfig) -> usize {
    let mut tp = 1;
    while tp * 2 <= spec.heads.min(64) && spec.heads % (tp * 2) == 0 && tp * 2 <= sys.total_reticles()
    {
        tp *= 2;
    }
    tp
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design_space::{reference_point, validate};
    use crate::eval::Analytical;
    use crate::workload::models::benchmarks;

    fn sys(n_wafers: usize) -> SystemConfig {
        SystemConfig {
            validated: validate(&reference_point()).unwrap(),
            n_wafers,
            faults: None,
        }
    }

    fn sys_faulted(n_wafers: usize, mult: f64, spares: Option<usize>) -> SystemConfig {
        SystemConfig {
            validated: validate(&reference_point()).unwrap(),
            n_wafers,
            faults: Some(FaultSpec {
                defect_multiplier: mult,
                spares,
                seed: 11,
            }),
        }
    }

    #[test]
    fn warm_cache_reproduces_cold_results() {
        // Two identical evaluations — the second fully cache-served —
        // must produce identical numbers.
        let spec = &benchmarks()[0];
        let s = sys(1);
        let cold = eval_training(spec, &s, &Analytical).expect("evaluates");
        let warm = eval_training(spec, &s, &Analytical).expect("evaluates");
        assert_eq!(cold.tokens_per_sec, warm.tokens_per_sec);
        assert_eq!(cold.strategy, warm.strategy);
        // Memoization itself is asserted via Arc identity on a graph
        // unique to this test: the global hit/miss counters are shared
        // with concurrently running tests and cannot be compared exactly.
        let global = crate::compiler::cache::global();
        if global.capacity() > 0 {
            let mut uniq = spec.clone();
            uniq.seq_len = 77; // signature no other test produces
            let g = crate::workload::OpGraph::transformer_chunk(
                &uniq,
                1,
                1,
                4,
                crate::workload::Phase::Training,
                false,
            );
            let core = s.validated.point.wsc.reticle.core;
            let a = global.get_or_compile(&g, 7, 9, &core);
            let b = global.get_or_compile(&g, 7, 9, &core);
            assert!(
                std::sync::Arc::ptr_eq(&a, &b),
                "second fetch must be served from the memo"
            );
        }
    }

    #[test]
    fn serial_sweep_rides_the_pseudo_gnn_estimator() {
        // The serial reference sweep accepts any estimator: the pseudo-GNN
        // drives it per chunk and yields a finite, positive result
        // alongside the analytical one (equivalence with the batched
        // dispatch is pinned in eval::engine's tests).
        use crate::runtime::TestBackend;
        let spec = &benchmarks()[0];
        let s = sys(1);
        let gnn = eval_training(spec, &s, &TestBackend::new()).expect("evaluates");
        let ana = eval_training(spec, &s, &Analytical).expect("evaluates");
        assert!(gnn.tokens_per_sec > 0.0 && gnn.tokens_per_sec.is_finite());
        assert!(gnn.power_w > 0.0);
        assert!(ana.tokens_per_sec > 0.0);
    }

    #[test]
    fn training_gpt17b_single_wafer() {
        let spec = &benchmarks()[0];
        let r = eval_training(spec, &sys(1), &Analytical).expect("should evaluate");
        assert!(r.tokens_per_sec > 0.0);
        assert!(r.power_w > 100.0, "power={}", r.power_w);
        assert!(r.power_w < 40_000.0, "power={}", r.power_w);
        assert!(r.step_time_s > 0.0);
        // Throughput sanity: bounded by peak flops.
        let peak = sys(1).validated.phys.peak_flops;
        let max_tokens = peak / spec.train_flops_per_token();
        assert!(
            r.tokens_per_sec <= max_tokens * 1.01,
            "tokens/s {} exceeds roofline {max_tokens}",
            r.tokens_per_sec
        );
        // And achieves a sane fraction of it.
        assert!(
            r.tokens_per_sec >= max_tokens * 0.02,
            "tokens/s {} under 2% of roofline {max_tokens}",
            r.tokens_per_sec
        );
    }

    #[test]
    fn more_wafers_more_throughput() {
        let spec = &benchmarks()[3]; // 18.4B
        let t1 = eval_training(spec, &sys(2), &Analytical).unwrap();
        let t4 = eval_training(spec, &sys(8), &Analytical).unwrap();
        assert!(t4.tokens_per_sec > t1.tokens_per_sec * 1.5);
    }

    #[test]
    fn huge_model_needs_memory() {
        // 530B on a single wafer without enough memory -> None or tiny.
        let spec = &benchmarks()[9];
        let r = eval_training(spec, &sys(1), &Analytical);
        if let Some(r) = r {
            assert!(r.tokens_per_sec >= 0.0);
        } // None is acceptable: memory constraint
    }

    #[test]
    fn inference_sram_beats_offchip_residency() {
        let spec = &benchmarks()[0]; // 1.7B fits on-wafer SRAM? 3.4 GB bf16 — no (SRAM ~ MBs×cores)
        let r = eval_inference(spec, &sys(4), 32, false, &Analytical).unwrap();
        assert!(r.tokens_per_sec > 0.0);
        assert!(r.decode_step_s > 0.0);
    }

    #[test]
    fn mqa_speeds_decode() {
        let spec = &benchmarks()[7];
        let s = sys(8);
        let full = eval_inference(spec, &s, 32, false, &Analytical).unwrap();
        let mqa = eval_inference(spec, &s, 32, true, &Analytical).unwrap();
        assert!(
            mqa.decode_step_s < full.decode_step_s,
            "mqa {} vs {}",
            mqa.decode_step_s,
            full.decode_step_s
        );
    }

    #[test]
    fn fault_free_spec_is_bit_identical_to_no_spec() {
        // The graceful-degradation contract: faults: None and a fault spec
        // whose sampled map is pristine (defect multiplier 0) must take the
        // exact same code path — every output bit equal.
        let spec = &benchmarks()[0];
        let base = eval_training(spec, &sys(1), &Analytical).expect("evaluates");
        let zero = eval_training(spec, &sys_faulted(1, 0.0, None), &Analytical).expect("evaluates");
        assert_eq!(base.strategy, zero.strategy);
        assert_eq!(base.tokens_per_sec.to_bits(), zero.tokens_per_sec.to_bits());
        assert_eq!(base.power_w.to_bits(), zero.power_w.to_bits());
        assert_eq!(base.energy_per_token_j.to_bits(), zero.energy_per_token_j.to_bits());
        let ib = eval_inference(spec, &sys(4), 32, false, &Analytical).expect("evaluates");
        let iz = eval_inference(spec, &sys_faulted(4, 0.0, None), 32, false, &Analytical)
            .expect("evaluates");
        assert_eq!(ib.tokens_per_sec.to_bits(), iz.tokens_per_sec.to_bits());
        assert_eq!(ib.power_w.to_bits(), iz.power_w.to_bits());
    }

    #[test]
    fn degradation_is_monotone_in_defect_rate() {
        // Threshold sampling nests the dead sets across multipliers at a
        // fixed seed, so throughput must be non-increasing in the defect
        // rate (a disconnected wafer counts as zero throughput).
        let spec = &benchmarks()[0];
        let tps = |mult: f64| {
            eval_training(spec, &sys_faulted(1, mult, Some(0)), &Analytical)
                .map_or(0.0, |r| r.tokens_per_sec)
        };
        let t0 = tps(0.0);
        let t1 = tps(2.0);
        let t2 = tps(6.0);
        assert!(t0 > 0.0);
        assert!(t1 <= t0, "defects must not improve throughput: {t1} vs {t0}");
        assert!(t2 <= t1, "higher defect rate must not outperform: {t2} vs {t1}");
        // And the sampling is real: at a high multiplier with no spares,
        // some cores must actually be dead.
        assert!(system_live_fraction(&sys_faulted(1, 25.0, Some(0))) < 1.0);
        // Same seed, same spec: byte-identical reruns.
        assert_eq!(tps(2.0).to_bits(), t1.to_bits());
    }

    #[test]
    fn spare_rows_recover_throughput() {
        let spec = &benchmarks()[0];
        let tps = |spares: usize| {
            eval_training(spec, &sys_faulted(1, 6.0, Some(spares)), &Analytical)
                .map_or(0.0, |r| r.tokens_per_sec)
        };
        assert!(tps(4) >= tps(0), "spare rows must not hurt throughput");
        // Repair only ever revives cores.
        let lf0 = system_live_fraction(&sys_faulted(1, 6.0, Some(0)));
        let lf4 = system_live_fraction(&sys_faulted(1, 6.0, Some(4)));
        assert!(lf4 >= lf0, "live fraction {lf4} < {lf0} with more spares");
    }

    #[test]
    fn inference_on_defective_wafer_degrades_gracefully() {
        let spec = &benchmarks()[0];
        let base = eval_inference(spec, &sys(4), 32, false, &Analytical).expect("evaluates");
        if let Some(f) =
            eval_inference(spec, &sys_faulted(4, 6.0, Some(0)), 32, false, &Analytical)
        {
            assert!(f.tokens_per_sec > 0.0 && f.tokens_per_sec.is_finite());
            assert!(
                f.tokens_per_sec <= base.tokens_per_sec * (1.0 + 1e-9),
                "faulted {} vs pristine {}",
                f.tokens_per_sec,
                base.tokens_per_sec
            );
        } // None = disconnected region: acceptable graceful failure.
    }

    #[test]
    fn prefill_compute_bound_decode_memory_bound() {
        let spec = &benchmarks()[7];
        let r = eval_inference(spec, &sys(8), 32, false, &Analytical).unwrap();
        // Prefill processes 2048x more tokens per invocation; decode step
        // must be far cheaper than prefill.
        assert!(r.decode_step_s < r.prefill_s);
    }

    #[test]
    fn single_wafer_dp_uses_on_wafer_bandwidth() {
        // Regression (PR 9 satellite): dp > 1 on a single wafer must price
        // the gradient all-reduce on the on-wafer fabric — the pre-fix
        // `dp_on_wafer && wafers <= 1.0` arm was unreachable, so these
        // replicas were mischarged NIC bandwidth.
        let spec = &benchmarks()[0];
        let s1 = sys(1);
        let strat = ParallelStrategy { tp: 1, pp: 1, dp: 2, microbatch: 1 };
        let r = eval_training_with(spec, &s1, strat, &Analytical).expect("evaluates");
        let wsc = &s1.validated.point.wsc;
        let bw_on = wsc.reticle.inter_reticle_bytes_per_sec()
            * (wsc.reticle_h.min(wsc.reticle_w) as f64).max(1.0);
        let stage_weights = spec.param_bytes(); // tp * pp == 1
        let grad_bytes = 2.0 * (2.0f64 - 1.0) / 2.0 * stage_weights;
        assert_eq!(r.breakdown.dp_s.to_bits(), (grad_bytes / bw_on).to_bits());
        // The two bandwidths differ, so the assertion discriminates the
        // fixed path from the old mischarge.
        assert!((bw_on - wsc.inter_wafer_bytes_per_sec()).abs() > 1.0);
    }

    #[test]
    fn single_wafer_ignores_interwafer_net() {
        // Bit-identity contract: at wafers == 1 the inter-wafer network is
        // never consulted, so even an absurd net leaves every output bit
        // unchanged.
        use crate::arch::{InterWaferNet, InterWaferTopology};
        let spec = &benchmarks()[0];
        let base_t = eval_training(spec, &sys(1), &Analytical).expect("evaluates");
        let base_i = eval_inference(spec, &sys(1), 32, false, &Analytical);
        for topology in InterWaferTopology::ALL {
            let mut s = sys(1);
            s.validated.point.interwafer = InterWaferNet {
                topology,
                links_per_wafer: 1,
                link_bandwidth: 1.0,
                link_latency: 10.0,
            };
            let t = eval_training(spec, &s, &Analytical).expect("evaluates");
            assert_eq!(t.strategy, base_t.strategy);
            assert_eq!(t.tokens_per_sec.to_bits(), base_t.tokens_per_sec.to_bits());
            assert_eq!(t.power_w.to_bits(), base_t.power_w.to_bits());
            assert_eq!(
                t.energy_per_token_j.to_bits(),
                base_t.energy_per_token_j.to_bits()
            );
            if let Some(bi) = &base_i {
                let i = eval_inference(spec, &s, 32, false, &Analytical).expect("evaluates");
                assert_eq!(i.tokens_per_sec.to_bits(), bi.tokens_per_sec.to_bits());
                assert_eq!(i.decode_step_s.to_bits(), bi.decode_step_s.to_bits());
                assert_eq!(i.prefill_s.to_bits(), bi.prefill_s.to_bits());
                assert_eq!(i.power_w.to_bits(), bi.power_w.to_bits());
            }
        }
    }

    #[test]
    fn throughput_monotone_in_interwafer_bandwidth() {
        // Shrinking the per-link bandwidth can only slow multi-wafer
        // training: per-strategy step time is monotone in the link rate
        // (bandwidth appears only in denominators of the collective
        // models), and the best-over-strategies inherits it.
        let spec = &benchmarks()[3];
        let tps = |bw: f64| {
            let mut s = sys(4);
            s.validated.point.interwafer.link_bandwidth = bw;
            eval_training(spec, &s, &Analytical)
                .expect("evaluates")
                .tokens_per_sec
        };
        let lo = tps(1.0e9);
        let mid = tps(25.0e9);
        let hi = tps(400.0e9);
        assert!(lo > 0.0);
        assert!(mid >= lo, "mid {mid} < lo {lo}");
        assert!(hi >= mid, "hi {hi} < mid {mid}");
    }

    #[test]
    fn multiwafer_decode_pays_interwafer_cost() {
        // At n_wafers > 1 the decode step carries the cross-wafer
        // activation all-reduce: a slower net must not speed decode up.
        let spec = &benchmarks()[7];
        let step = |bw: f64| {
            let mut s = sys(8);
            s.validated.point.interwafer.link_bandwidth = bw;
            eval_inference(spec, &s, 32, false, &Analytical)
                .expect("evaluates")
                .decode_step_s
        };
        assert!(step(1.0e9) >= step(100.0e9));
    }
}
