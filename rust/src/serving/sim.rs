//! Discrete-event serving simulator: a request stream scheduled onto one
//! evaluated wafer design.
//!
//! ## Model
//!
//! The simulator advances a virtual clock in *rounds* of continuous
//! batching. Each round, the scheduler picks a set of waiting requests to
//! prefill and a set of in-flight requests to decode one token each; the
//! round's duration comes from the [`Engine`]'s inference evaluation at
//! the round's actual occupancy ([`StepCosts`], below). Requests enter
//! when their KV-cache footprint fits and the in-flight count is under
//! the spec batch; they leave when their last output token decodes,
//! freeing their KV bytes. Per the repo's convention, prefill emits no
//! token — the first output token is the first *decode* step — so a
//! single queue-free request's latency is exactly
//! `prefill_s(1) + N·decode_step_s(1)` (pinned by a closed-form test).
//!
//! ## Step costs from the Engine
//!
//! Per-phase step costs are *not* re-derived here: [`StepCosts`] asks
//! [`Engine::eval_infer_system_at_batch`] for `(prefill_s,
//! decode_step_s)` at each occupancy the rounds actually reach, memoized
//! via [`Memo`] (occupancies repeat heavily under continuous batching,
//! so a handful of Engine evaluations price an entire trace at any
//! fidelity). A design that cannot hold `batch` sequences at the model's
//! full context is a loud error, not a silent skip.
//!
//! ## Scheduler contract
//!
//! A [`SchedulerKind`] decides, given non-empty admit and decode-ready
//! sets, what runs this round:
//!
//! - `fcfs` — fused rounds: admitted prefills and ready decodes share
//!   the round (duration = prefill cost + decode cost); nothing stalls.
//! - `prefill-priority` — when any request is admissible the round is
//!   prefill-only and decodes stall, minimizing time-to-first-token at
//!   the cost of per-token latency for in-flight requests.
//!
//! Schedulers may only reorder *work within a round*; admission itself
//! is always arrival-ordered (no starvation), and both schedulers are
//! pure functions of the simulator state — no randomness, no wall clock.
//!
//! ## Multi-wafer placement
//!
//! On an `n_wafers > 1` system, request `id` is pinned round-robin to
//! wafer `id % n`. A request whose prefill ran on a different wafer than
//! its decode home (`(id / n) % n`, the round-robin prefill slot) pays a
//! one-time KV hand-off — its prompt's KV bytes shipped point-to-point
//! through the design's [`InterWaferNet`] — before its first decode.
//! Single-wafer systems never consult the net (hand-off is exactly 0).

use crate::eval::chunk::SystemConfig;
use crate::eval::engine::Engine;
use crate::serving::metrics::RequestOutcome;
use crate::serving::trace::Request;
use crate::util::memo::Memo;

/// Round-scheduler registry: `ALL` / `name` / `parse` keep CLI flags,
/// scenario JSON and error messages in sync.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerKind {
    /// Fused rounds: prefills and decodes share every round.
    Fcfs,
    /// Prefill-only rounds whenever a request is admissible.
    PrefillPriority,
}

impl SchedulerKind {
    pub const ALL: [SchedulerKind; 2] = [SchedulerKind::Fcfs, SchedulerKind::PrefillPriority];

    pub fn name(&self) -> &'static str {
        match self {
            SchedulerKind::Fcfs => "fcfs",
            SchedulerKind::PrefillPriority => "prefill-priority",
        }
    }

    pub fn parse(s: &str) -> Option<SchedulerKind> {
        SchedulerKind::ALL.into_iter().find(|k| k.name() == s)
    }

    /// [`parse`](SchedulerKind::parse) with a usage error naming every
    /// valid scheduler.
    pub fn parse_or_usage(s: &str) -> Result<SchedulerKind, String> {
        SchedulerKind::parse(s).ok_or_else(|| {
            let names: Vec<&str> = SchedulerKind::ALL.iter().map(|k| k.name()).collect();
            format!("unknown scheduler '{s}' — valid: {}", names.join(", "))
        })
    }
}

/// Memoized `(prefill_s, decode_step_s)` lookup per round occupancy,
/// priced by the Engine on the concrete system under evaluation.
pub struct StepCosts<'a> {
    engine: &'a Engine,
    sys: &'a SystemConfig,
    memo: Memo<usize, Option<(f64, f64)>>,
}

impl<'a> StepCosts<'a> {
    pub fn new(engine: &'a Engine, sys: &'a SystemConfig) -> StepCosts<'a> {
        StepCosts {
            engine,
            sys,
            // Occupancies are bounded by the spec batch; 64 distinct
            // entries covers every batch the built-in suites reach.
            memo: Memo::new(64),
        }
    }

    /// `(prefill_s, decode_step_s)` at `batch` sequences in flight. A
    /// design the Engine rejects at this occupancy (weights + full-context
    /// KV exceed device memory) is a loud error.
    pub fn costs(&self, batch: usize) -> Result<(f64, f64), String> {
        let b = batch.max(1);
        self.memo
            .get_or_insert_with(b, || {
                self.engine
                    .eval_infer_system_at_batch(self.sys, b)
                    .map(|e| (e.prefill_s, e.decode_step_s))
            })
            .ok_or_else(|| {
                format!(
                    "design cannot serve a batch of {b}: weights + KV cache exceed device memory"
                )
            })
    }
}

/// One in-flight request.
struct Active {
    id: usize,
    arrival_s: f64,
    /// Earliest time this request may decode (prefill end + any
    /// cross-wafer KV hand-off).
    ready_s: f64,
    remaining: usize,
    first_token_s: Option<f64>,
    kv_bytes: f64,
    output_tokens: usize,
}

/// Backstop against a wedged round loop (a healthy trace of `n` requests
/// finishes in well under `n · (1 + max output length)` rounds).
const MAX_ROUNDS: usize = 10_000_000;

/// Simulate `trace` on `sys` as evaluated by `engine`, returning one
/// outcome per request (sorted by request id). Pure function of its
/// arguments — same inputs, byte-identical outcomes.
pub fn simulate(
    engine: &Engine,
    sys: &SystemConfig,
    trace: &[Request],
    scheduler: SchedulerKind,
) -> Result<Vec<RequestOutcome>, String> {
    if trace.is_empty() {
        return Err("serving simulator: empty trace — nothing to serve".to_string());
    }
    for w in trace.windows(2) {
        if w[1].arrival_s < w[0].arrival_s {
            return Err(format!(
                "serving simulator: trace arrivals must be non-decreasing (request {} at {} after {})",
                w[1].id, w[1].arrival_s, w[0].arrival_s
            ));
        }
    }
    let spec = engine.spec();
    let model = &spec.model;
    // Per-token KV footprint; a request holds KV for prompt + generated
    // tokens for its whole residency.
    let kv_per_token = model.kv_cache_bytes_per_seq(spec.mqa) / model.seq_len.max(1) as f64;
    let capacity = (sys.memory().total_bytes() - model.param_bytes()).max(0.0);
    let max_batch = spec.batch.max(1);
    let n_wafers = sys.n_wafers;
    let net = sys.validated.point.interwafer;

    let costs = StepCosts::new(engine, sys);
    let mut waiting: std::collections::VecDeque<Request> = std::collections::VecDeque::new();
    let mut active: Vec<Active> = Vec::new();
    let mut outcomes: Vec<RequestOutcome> = Vec::with_capacity(trace.len());
    let mut next_idx = 0usize;
    let mut t = 0.0f64;
    let mut rounds = 0usize;

    while outcomes.len() < trace.len() {
        rounds += 1;
        if rounds > MAX_ROUNDS {
            return Err(format!(
                "serving simulator: exceeded {MAX_ROUNDS} rounds with {} of {} requests \
                 completed — the schedule is wedged",
                outcomes.len(),
                trace.len()
            ));
        }
        while next_idx < trace.len() && trace[next_idx].arrival_s <= t {
            waiting.push_back(trace[next_idx]);
            next_idx += 1;
        }
        // Arrival-ordered admission under the KV-capacity and in-flight
        // limits. KV usage is recomputed from the in-flight set so
        // floating-point residue from freed requests never blocks an
        // admissible one.
        let mut kv_used: f64 = active.iter().map(|a| a.kv_bytes).sum();
        let mut admits: Vec<Request> = Vec::new();
        while let Some(&r) = waiting.front() {
            let kv = kv_per_token * (r.prompt_tokens + r.output_tokens) as f64;
            if kv > capacity {
                return Err(format!(
                    "serving simulator: request {} needs {:.3e} B of KV cache but the design \
                     has {:.3e} B free after weights — it can never be served",
                    r.id, kv, capacity
                ));
            }
            if active.len() + admits.len() >= max_batch || kv_used + kv > capacity {
                break;
            }
            kv_used += kv;
            admits.push(r);
            waiting.pop_front();
        }
        let decode_ready: Vec<usize> = (0..active.len())
            .filter(|&i| active[i].ready_s <= t)
            .collect();

        if admits.is_empty() && decode_ready.is_empty() {
            // Idle: jump to the next event (an arrival or a hand-off
            // completing). No event = a wedged schedule; fail loudly.
            let next_arrival = trace.get(next_idx).map(|r| r.arrival_s);
            let next_ready = active
                .iter()
                .map(|a| a.ready_s)
                .fold(f64::INFINITY, f64::min);
            let target = match next_arrival {
                Some(a) => a.min(next_ready),
                None => next_ready,
            };
            if !target.is_finite() || target <= t {
                return Err(format!(
                    "serving simulator: no schedulable work at t={t} with {} waiting and {} \
                     in flight — the schedule is wedged",
                    waiting.len(),
                    active.len()
                ));
            }
            t = target;
            continue;
        }

        let (prefills, decodes) = match scheduler {
            SchedulerKind::Fcfs => (admits, decode_ready),
            SchedulerKind::PrefillPriority => {
                if admits.is_empty() {
                    (admits, decode_ready)
                } else {
                    (admits, Vec::new())
                }
            }
        };
        let mut round_s = 0.0;
        if !prefills.is_empty() {
            round_s += costs.costs(prefills.len())?.0;
        }
        if !decodes.is_empty() {
            round_s += costs.costs(decodes.len())?.1;
        }
        let end = t + round_s;

        let mut finished: Vec<usize> = Vec::new();
        for &i in &decodes {
            let a = &mut active[i];
            if a.first_token_s.is_none() {
                a.first_token_s = Some(end);
            }
            a.remaining -= 1;
            if a.remaining == 0 {
                finished.push(i);
            }
        }
        // Descending order so each swap_remove leaves lower indices valid.
        finished.sort_unstable_by(|x, y| y.cmp(x));
        for i in finished {
            let a = active.swap_remove(i);
            outcomes.push(RequestOutcome {
                id: a.id,
                arrival_s: a.arrival_s,
                first_token_s: a.first_token_s.unwrap_or(end),
                finish_s: end,
                output_tokens: a.output_tokens,
            });
        }
        for r in prefills {
            let decode_home = r.id % n_wafers.max(1);
            let prefill_slot = (r.id / n_wafers.max(1)) % n_wafers.max(1);
            let handoff = if n_wafers > 1 && decode_home != prefill_slot {
                net.p2p_s(kv_per_token * r.prompt_tokens as f64, n_wafers)
            } else {
                0.0
            };
            active.push(Active {
                id: r.id,
                arrival_s: r.arrival_s,
                ready_s: end + handoff,
                remaining: r.output_tokens,
                first_token_s: None,
                kv_bytes: kv_per_token * (r.prompt_tokens + r.output_tokens) as f64,
                output_tokens: r.output_tokens,
            });
        }
        t = end;
    }
    outcomes.sort_unstable_by_key(|o| o.id);
    Ok(outcomes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheduler_registry_roundtrip() {
        for k in SchedulerKind::ALL {
            assert_eq!(SchedulerKind::parse(k.name()), Some(k));
        }
        let e = SchedulerKind::parse_or_usage("lifo").unwrap_err();
        assert!(e.contains("fcfs, prefill-priority"), "{e}");
    }
}
