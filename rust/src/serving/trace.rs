//! Deterministic request-trace generation and loading.
//!
//! A trace is the serving workload: a time-ordered list of [`Request`]s
//! (arrival time, prompt length, output length). Two sources:
//!
//! - [`generate`] — a seeded synthetic generator. Arrivals follow a
//!   registry [`ArrivalProcess`] (`poisson`: independent exponential
//!   gaps; `bursty`: geometric-size bursts of simultaneous arrivals with
//!   exponential gaps between bursts, scaled so the *long-run* rate
//!   matches `rate_per_s` either way). Prompt/output lengths are
//!   exponentially distributed around their configured means, rounded
//!   and clamped to `[1, 4·mean]` so one pathological sample cannot
//!   dominate a short trace.
//! - [`load`] — a JSON trace-file loader for replaying recorded traffic.
//!   Per the campaign contract it fails loudly: unknown request fields,
//!   non-monotone arrivals, non-positive token counts and empty traces
//!   are all typed errors naming the offending request, never silent
//!   repairs.
//!
//! Determinism: generation is a pure function of `(spec-ish params,
//! seed)` via forked [`Rng`] streams (stream 1 = arrivals, stream 2 =
//! lengths), so the same seed yields a byte-identical trace regardless
//! of call site or thread — the property the campaign's byte-identical
//! artifact contract rests on.

use crate::util::json::Json;
use crate::util::rng::Rng;

/// One serving request: arrives at `arrival_s`, carries `prompt_tokens`
/// to prefill, then wants `output_tokens` decoded.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Request {
    /// Position in the trace (also the placement key for multi-wafer
    /// routing in the simulator).
    pub id: usize,
    pub arrival_s: f64,
    pub prompt_tokens: usize,
    pub output_tokens: usize,
}

/// Arrival process registry: `ALL` / `name` / `parse` keep CLI flags,
/// scenario JSON and error messages in sync (same convention as
/// [`crate::eval::Fidelity`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalProcess {
    /// Independent arrivals: exponential inter-arrival gaps at
    /// `rate_per_s`.
    Poisson,
    /// Bursts of simultaneous arrivals (mean size
    /// [`BURST_MEAN`]) separated by exponential gaps stretched by the
    /// burst size, so the long-run rate still equals `rate_per_s`.
    Bursty,
}

/// Mean burst size for [`ArrivalProcess::Bursty`] (uniform on
/// `1..=2·mean−1`).
pub const BURST_MEAN: usize = 4;

impl ArrivalProcess {
    pub const ALL: [ArrivalProcess; 2] = [ArrivalProcess::Poisson, ArrivalProcess::Bursty];

    pub fn name(&self) -> &'static str {
        match self {
            ArrivalProcess::Poisson => "poisson",
            ArrivalProcess::Bursty => "bursty",
        }
    }

    pub fn parse(s: &str) -> Option<ArrivalProcess> {
        ArrivalProcess::ALL.into_iter().find(|a| a.name() == s)
    }

    /// [`parse`](ArrivalProcess::parse) with a usage error naming every
    /// valid process.
    pub fn parse_or_usage(s: &str) -> Result<ArrivalProcess, String> {
        ArrivalProcess::parse(s).ok_or_else(|| {
            let names: Vec<&str> = ArrivalProcess::ALL.iter().map(|a| a.name()).collect();
            format!("unknown arrival process '{s}' — valid: {}", names.join(", "))
        })
    }
}

/// Draw an exponential token count around `mean`, rounded and clamped to
/// `[1, 4·mean]`.
fn sample_len(rng: &mut Rng, mean: usize) -> usize {
    let mean = mean.max(1);
    let x = rng.exponential(mean as f64).round() as usize;
    x.clamp(1, 4 * mean)
}

/// Generate `n` requests at long-run rate `rate_per_s` with the given
/// arrival process and mean prompt/output lengths. Pure function of its
/// arguments (stream-forked RNG, no wall clock).
pub fn generate(
    arrival: ArrivalProcess,
    rate_per_s: f64,
    n: usize,
    mean_prompt: usize,
    mean_output: usize,
    seed: u64,
) -> Vec<Request> {
    let mut root = Rng::new(seed);
    let mut arrivals = root.fork(1);
    let mut lengths = root.fork(2);
    let rate = rate_per_s.max(1e-12);
    let mut out = Vec::with_capacity(n);
    let mut t = 0.0f64;
    let mut id = 0usize;
    while id < n {
        let burst = match arrival {
            ArrivalProcess::Poisson => 1,
            ArrivalProcess::Bursty => arrivals.range(1, 2 * BURST_MEAN - 1),
        };
        // Gap scales with burst size so bursty traffic keeps the same
        // long-run rate as poisson at the same `rate_per_s`.
        t += arrivals.exponential(burst as f64 / rate);
        for _ in 0..burst {
            if id >= n {
                break;
            }
            out.push(Request {
                id,
                arrival_s: t,
                prompt_tokens: sample_len(&mut lengths, mean_prompt),
                output_tokens: sample_len(&mut lengths, mean_output),
            });
            id += 1;
        }
    }
    out
}

/// The fields a trace-file request may carry (alphabetical, quoted in
/// unknown-field errors). `id` is optional but must equal the request's
/// position when present.
const REQUEST_FIELDS: [&str; 4] = ["arrival_s", "id", "output_tokens", "prompt_tokens"];

fn req_usize(obj: &Json, i: usize, key: &str) -> Result<usize, String> {
    obj.get(key)
        .ok_or_else(|| format!("trace request {i}: missing required field '{key}'"))?
        .as_usize()
        .ok_or_else(|| format!("trace request {i}: '{key}' must be a non-negative integer"))
}

/// Parse a `{"requests": [...]}` trace document, validating loudly.
pub fn from_json(doc: &Json) -> Result<Vec<Request>, String> {
    let reqs = doc
        .get("requests")
        .and_then(Json::as_arr)
        .ok_or_else(|| "trace file must be an object with a 'requests' array".to_string())?;
    if reqs.is_empty() {
        return Err("trace file has an empty 'requests' array — nothing to serve".to_string());
    }
    let mut out = Vec::with_capacity(reqs.len());
    let mut prev_arrival = f64::NEG_INFINITY;
    for (i, r) in reqs.iter().enumerate() {
        let obj = r
            .as_obj()
            .ok_or_else(|| format!("trace request {i}: must be an object"))?;
        for key in obj.keys() {
            if !REQUEST_FIELDS.contains(&key.as_str()) {
                return Err(format!(
                    "trace request {i}: unknown field '{key}' — valid: {}",
                    REQUEST_FIELDS.join(", ")
                ));
            }
        }
        let arrival_s = r
            .get("arrival_s")
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("trace request {i}: missing numeric 'arrival_s'"))?;
        if !arrival_s.is_finite() || arrival_s < 0.0 {
            return Err(format!(
                "trace request {i}: 'arrival_s' must be finite and non-negative, got {arrival_s}"
            ));
        }
        if arrival_s < prev_arrival {
            return Err(format!(
                "trace request {i}: arrivals must be non-decreasing ({arrival_s} after {prev_arrival})"
            ));
        }
        prev_arrival = arrival_s;
        let prompt_tokens = req_usize(r, i, "prompt_tokens")?;
        let output_tokens = req_usize(r, i, "output_tokens")?;
        if prompt_tokens == 0 || output_tokens == 0 {
            return Err(format!(
                "trace request {i}: prompt_tokens and output_tokens must be positive"
            ));
        }
        if let Some(id) = r.get("id") {
            let id = id
                .as_usize()
                .ok_or_else(|| format!("trace request {i}: 'id' must be a non-negative integer"))?;
            if id != i {
                return Err(format!(
                    "trace request {i}: 'id' {id} must equal the request's position"
                ));
            }
        }
        out.push(Request {
            id: i,
            arrival_s,
            prompt_tokens,
            output_tokens,
        });
    }
    Ok(out)
}

/// Load and validate a JSON trace file from disk.
pub fn load(path: &str) -> Result<Vec<Request>, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("cannot read trace file '{path}': {e}"))?;
    let doc = Json::parse(&text).map_err(|e| format!("trace file '{path}': {e}"))?;
    from_json(&doc).map_err(|e| format!("trace file '{path}': {e}"))
}

/// Serialize a trace as the `{"requests": [...]}` document [`from_json`]
/// accepts (round-trip partner, used by tests and `serve-sim --dump`).
pub fn to_json(trace: &[Request]) -> Json {
    let mut reqs = Vec::with_capacity(trace.len());
    for r in trace {
        let mut obj = Json::obj();
        obj.set("arrival_s", Json::Num(r.arrival_s))
            .set("id", Json::Num(r.id as f64))
            .set("output_tokens", Json::Num(r.output_tokens as f64))
            .set("prompt_tokens", Json::Num(r.prompt_tokens as f64));
        reqs.push(obj);
    }
    let mut doc = Json::obj();
    doc.set("requests", Json::Arr(reqs));
    doc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_roundtrip() {
        for a in ArrivalProcess::ALL {
            assert_eq!(ArrivalProcess::parse(a.name()), Some(a));
        }
        let e = ArrivalProcess::parse_or_usage("nope").unwrap_err();
        assert!(e.contains("poisson, bursty"), "{e}");
    }

    #[test]
    fn generation_is_seed_deterministic() {
        let a = generate(ArrivalProcess::Bursty, 8.0, 64, 128, 32, 7);
        let b = generate(ArrivalProcess::Bursty, 8.0, 64, 128, 32, 7);
        assert_eq!(a, b);
        let c = generate(ArrivalProcess::Bursty, 8.0, 64, 128, 32, 8);
        assert_ne!(a, c, "different seeds must differ");
    }

    #[test]
    fn generated_traces_are_valid_and_rate_matched() {
        for arrival in ArrivalProcess::ALL {
            let n = 2000;
            let rate = 10.0;
            let trace = generate(arrival, rate, n, 256, 64, 3);
            assert_eq!(trace.len(), n);
            let mut prev = 0.0;
            for (i, r) in trace.iter().enumerate() {
                assert_eq!(r.id, i);
                assert!(r.arrival_s >= prev);
                assert!(r.prompt_tokens >= 1 && r.prompt_tokens <= 4 * 256);
                assert!(r.output_tokens >= 1 && r.output_tokens <= 4 * 64);
                prev = r.arrival_s;
            }
            // Long-run rate within 15% of nominal for both processes.
            let span = trace.last().unwrap().arrival_s;
            let empirical = n as f64 / span;
            assert!(
                (empirical / rate - 1.0).abs() < 0.15,
                "{}: empirical rate {empirical} vs nominal {rate}",
                arrival.name()
            );
        }
    }

    #[test]
    fn json_roundtrip() {
        let trace = generate(ArrivalProcess::Poisson, 4.0, 16, 64, 16, 11);
        let back = from_json(&to_json(&trace)).unwrap();
        assert_eq!(back, trace);
    }

    #[test]
    fn loader_rejects_malformed_traces_loudly() {
        let parse = |s: &str| from_json(&Json::parse(s).unwrap());
        let e = parse(r#"{"requests": []}"#).unwrap_err();
        assert!(e.contains("empty"), "{e}");
        let e = parse(r#"{"requests": [{"arrival_s": 0, "prompt_tokens": 4, "output_tokens": 2, "bogus": 1}]}"#)
            .unwrap_err();
        assert!(e.contains("unknown field 'bogus'"), "{e}");
        assert!(e.contains("arrival_s, id, output_tokens, prompt_tokens"), "{e}");
        let e = parse(
            r#"{"requests": [{"arrival_s": 1, "prompt_tokens": 4, "output_tokens": 2},
                             {"arrival_s": 0.5, "prompt_tokens": 4, "output_tokens": 2}]}"#,
        )
        .unwrap_err();
        assert!(e.contains("non-decreasing"), "{e}");
        let e = parse(r#"{"requests": [{"arrival_s": 0, "prompt_tokens": 0, "output_tokens": 2}]}"#)
            .unwrap_err();
        assert!(e.contains("must be positive"), "{e}");
        let e = parse(r#"{"requests": [{"arrival_s": 0, "prompt_tokens": 4, "output_tokens": 2, "id": 3}]}"#)
            .unwrap_err();
        assert!(e.contains("must equal the request's position"), "{e}");
        let e = parse(r#"{"requests": [{"arrival_s": 0, "output_tokens": 2}]}"#).unwrap_err();
        assert!(e.contains("prompt_tokens"), "{e}");
    }
}
