//! Serving-traffic simulation: from static design points to request
//! streams (ROADMAP item 1).
//!
//! The [`crate::eval::Engine`] prices one `(model, phase, batch)` point;
//! production inference is a *request stream* — continuous batching,
//! prefill/decode interleaving, queueing, tail-latency SLOs. This module
//! turns a design evaluation into a serving evaluation:
//!
//! - [`trace`] — deterministic request-trace generation (seeded
//!   Poisson/bursty arrivals, exponential prompt/output lengths) and a
//!   loudly-validating JSON trace-file loader.
//! - [`sim`] — the discrete-event simulator: continuous batching under a
//!   KV-cache capacity constraint, pluggable round schedulers (`fcfs`,
//!   `prefill-priority`), and multi-wafer KV hand-off priced through the
//!   design's [`crate::arch::InterWaferNet`]. Step costs are sourced
//!   from [`crate::eval::Engine::eval_infer_system_at_batch`] at each
//!   round's actual occupancy, memoized per batch size — the simulator
//!   never re-derives hardware costs.
//! - [`metrics`] — the serving digest (aggregate tok/s, TTFT and latency
//!   P50/P99, goodput under an SLO) the campaign serializes per row.
//!
//! [`ServingSpec`] is the scenario-level knob set: it rides
//! [`crate::coordinator::campaign::Scenario`] the way
//! [`crate::arch::HeteroConfig`] and [`crate::arch::InterWaferNet`] do
//! (emitted only when present, so pre-serving artifacts stay
//! byte-identical), and [`evaluate`] is the one entry point the campaign,
//! the `serve-sim` CLI and the figures all share.
//!
//! Everything here honors the determinism contract: no wall clock, seeded
//! `SplitMix64` streams, and byte-identical outcomes for identical
//! inputs.

pub mod metrics;
pub mod sim;
pub mod trace;

pub use metrics::{RequestOutcome, ServingMetrics};
pub use sim::{simulate, SchedulerKind, StepCosts};
pub use trace::{ArrivalProcess, Request};

use crate::eval::chunk::SystemConfig;
use crate::eval::engine::Engine;

/// Scenario-level serving workload description: how the trace is
/// generated and how the simulator schedules it. Rides the campaign
/// [`Scenario`](crate::coordinator::campaign::Scenario) as an optional
/// axis (inference phases only).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServingSpec {
    pub arrival: ArrivalProcess,
    /// Long-run request arrival rate (requests/s); must be positive.
    pub rate_per_s: f64,
    /// Trace length in requests.
    pub requests: usize,
    /// Mean prompt length, tokens (exponential, clamped to 4× mean).
    pub mean_prompt: usize,
    /// Mean output length, tokens (exponential, clamped to 4× mean).
    pub mean_output: usize,
    /// TTFT SLO the goodput digest is measured against; must be positive.
    pub slo_s: f64,
    pub scheduler: SchedulerKind,
}

impl ServingSpec {
    /// Generate this spec's trace at `seed` (pure function — the campaign
    /// derives `seed` from the scenario key, so traces are
    /// position-independent like every other scenario input).
    pub fn trace(&self, seed: u64) -> Vec<Request> {
        trace::generate(
            self.arrival,
            self.rate_per_s,
            self.requests,
            self.mean_prompt,
            self.mean_output,
            seed,
        )
    }
}

/// Evaluate one serving workload end to end: simulate `trace` on `sys`
/// as priced by `engine`, then digest the outcomes against `slo_s`. The
/// shared entry point for campaign rows, `theseus serve-sim` and the
/// figures.
pub fn evaluate(
    engine: &Engine,
    sys: &SystemConfig,
    trace: &[Request],
    scheduler: SchedulerKind,
    slo_s: f64,
) -> Result<ServingMetrics, String> {
    let outcomes = simulate(engine, sys, trace, scheduler)?;
    ServingMetrics::digest(&outcomes, slo_s)
}
