//! Serving-level objectives digested from per-request outcomes.
//!
//! The simulator ([`super::sim`]) reports one [`RequestOutcome`] per
//! completed request; [`ServingMetrics::digest`] folds them into the
//! serving objectives the campaign serializes per row: aggregate output
//! token throughput, time-to-first-token (TTFT) and end-to-end latency
//! percentiles, and goodput — requests per second whose TTFT met the
//! SLO. Percentiles use the nearest-rank method on `total_cmp`-sorted
//! values (no interpolation), so digests are exact functions of the
//! outcome set and byte-stable across platforms.

use crate::util::json::Json;

/// Per-request timing as observed by the simulator (all seconds on the
/// simulated clock).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RequestOutcome {
    pub id: usize,
    pub arrival_s: f64,
    /// When the request's first output token was produced.
    pub first_token_s: f64,
    /// When its last output token was produced.
    pub finish_s: f64,
    pub output_tokens: usize,
}

impl RequestOutcome {
    /// Time to first token: queueing + prefill (+ hand-off).
    pub fn ttft_s(&self) -> f64 {
        self.first_token_s - self.arrival_s
    }

    /// End-to-end request latency.
    pub fn latency_s(&self) -> f64 {
        self.finish_s - self.arrival_s
    }
}

/// Nearest-rank percentile of `sorted` (ascending): the value at rank
/// `⌈p/100 · n⌉`, clamped to `[1, n]`.
fn percentile(sorted: &[f64], p: f64) -> f64 {
    let n = sorted.len();
    let rank = ((p / 100.0 * n as f64).ceil() as usize).clamp(1, n);
    sorted[rank - 1]
}

/// The serving digest: first-class campaign metrics for one simulated
/// trace on one design.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServingMetrics {
    pub completed: usize,
    /// Aggregate output tokens per second over the makespan.
    pub tokens_per_sec: f64,
    pub ttft_p50_s: f64,
    pub ttft_p99_s: f64,
    pub latency_p50_s: f64,
    pub latency_p99_s: f64,
    /// Requests per second whose TTFT met the SLO.
    pub goodput_per_sec: f64,
    /// The TTFT SLO the goodput was measured against.
    pub slo_s: f64,
    /// First arrival to last token.
    pub makespan_s: f64,
}

impl ServingMetrics {
    /// Fold outcomes into the digest. An empty outcome set is a loud
    /// error — a simulation that completed nothing has no metrics, and
    /// silently digesting zeros would read as a (terrible) real design.
    pub fn digest(outcomes: &[RequestOutcome], slo_s: f64) -> Result<ServingMetrics, String> {
        if outcomes.is_empty() {
            return Err("serving digest: no completed requests to digest".to_string());
        }
        let mut ttfts: Vec<f64> = outcomes.iter().map(RequestOutcome::ttft_s).collect();
        let mut lats: Vec<f64> = outcomes.iter().map(RequestOutcome::latency_s).collect();
        ttfts.sort_by(f64::total_cmp);
        lats.sort_by(f64::total_cmp);
        let first_arrival = outcomes
            .iter()
            .map(|o| o.arrival_s)
            .fold(f64::INFINITY, f64::min);
        let last_finish = outcomes.iter().map(|o| o.finish_s).fold(0.0f64, f64::max);
        // The makespan is positive for any non-degenerate trace; guard a
        // single instantaneous request so the rates stay finite.
        let makespan = (last_finish - first_arrival).max(1e-12);
        let total_tokens: usize = outcomes.iter().map(|o| o.output_tokens).sum();
        let met_slo = ttfts.iter().filter(|&&t| t <= slo_s).count();
        Ok(ServingMetrics {
            completed: outcomes.len(),
            tokens_per_sec: total_tokens as f64 / makespan,
            ttft_p50_s: percentile(&ttfts, 50.0),
            ttft_p99_s: percentile(&ttfts, 99.0),
            latency_p50_s: percentile(&lats, 50.0),
            latency_p99_s: percentile(&lats, 99.0),
            goodput_per_sec: met_slo as f64 / makespan,
            slo_s,
            makespan_s: makespan,
        })
    }

    /// The artifact form (alphabetical keys, matching the campaign's
    /// serialization convention).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("completed", Json::Num(self.completed as f64))
            .set("goodput_per_sec", Json::Num(self.goodput_per_sec))
            .set("latency_p50_s", Json::Num(self.latency_p50_s))
            .set("latency_p99_s", Json::Num(self.latency_p99_s))
            .set("makespan_s", Json::Num(self.makespan_s))
            .set("slo_s", Json::Num(self.slo_s))
            .set("tokens_per_sec", Json::Num(self.tokens_per_sec))
            .set("ttft_p50_s", Json::Num(self.ttft_p50_s))
            .set("ttft_p99_s", Json::Num(self.ttft_p99_s));
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(id: usize, arrival: f64, first: f64, finish: f64, tokens: usize) -> RequestOutcome {
        RequestOutcome {
            id,
            arrival_s: arrival,
            first_token_s: first,
            finish_s: finish,
            output_tokens: tokens,
        }
    }

    #[test]
    fn empty_digest_is_a_loud_error() {
        let e = ServingMetrics::digest(&[], 1.0).unwrap_err();
        assert!(e.contains("no completed requests"), "{e}");
    }

    #[test]
    fn nearest_rank_percentiles() {
        // 100 requests with TTFT = 0.01·(i+1): p50 is the 50th value
        // (0.50), p99 the 99th (0.99).
        let outcomes: Vec<RequestOutcome> = (0..100)
            .map(|i| outcome(i, 0.0, 0.01 * (i + 1) as f64, 1.0 + i as f64, 1))
            .collect();
        let m = ServingMetrics::digest(&outcomes, 0.5).unwrap();
        assert!((m.ttft_p50_s - 0.50).abs() < 1e-12);
        assert!((m.ttft_p99_s - 0.99).abs() < 1e-12);
        // Exactly 50 of 100 TTFTs are ≤ 0.5.
        let expect_goodput = 50.0 / m.makespan_s;
        assert!((m.goodput_per_sec - expect_goodput).abs() < 1e-12);
    }

    #[test]
    fn throughput_is_tokens_over_makespan() {
        let outcomes = vec![
            outcome(0, 0.0, 0.5, 2.0, 10),
            outcome(1, 1.0, 1.5, 4.0, 30),
        ];
        let m = ServingMetrics::digest(&outcomes, 1.0).unwrap();
        assert!((m.makespan_s - 4.0).abs() < 1e-12);
        assert!((m.tokens_per_sec - 10.0).abs() < 1e-12);
        assert_eq!(m.completed, 2);
    }

    #[test]
    fn json_has_all_digest_fields() {
        let m = ServingMetrics::digest(&[outcome(0, 0.0, 0.5, 2.0, 8)], 1.0).unwrap();
        let j = m.to_json();
        for key in [
            "completed",
            "goodput_per_sec",
            "latency_p50_s",
            "latency_p99_s",
            "makespan_s",
            "slo_s",
            "tokens_per_sec",
            "ttft_p50_s",
            "ttft_p99_s",
        ] {
            assert!(j.get(key).and_then(Json::as_f64).is_some(), "missing {key}");
        }
    }
}
