//! PJRT runtime: load the AOT-compiled GNN (HLO text produced by
//! `python/compile/aot.py`) and execute it from the DSE hot path.
//!
//! Python never runs at DSE time — the Rust coordinator feeds padded
//! feature tensors (built by [`features`], mirroring the Python schema) to
//! the compiled executable via the PJRT C API (`xla` crate).
//!
//! The PJRT path needs crates unavailable in the offline build, so the real
//! implementation ([`pjrt`]) is gated behind `--cfg theseus_pjrt`; the
//! default build substitutes [`stub`], whose `GnnModel::load_default`
//! reports the runtime as unavailable and lets every caller fall back to
//! the analytical NoC model. Both expose the same `GnnModel` API.

pub mod features;

#[cfg(theseus_pjrt)]
mod pjrt;
#[cfg(theseus_pjrt)]
pub use pjrt::GnnModel;

#[cfg(not(theseus_pjrt))]
mod stub;
#[cfg(not(theseus_pjrt))]
pub use stub::{GnnModel, GnnUnavailable};

/// Schema sidecar written by `compile.aot` — checked at load.
#[derive(Debug, Clone, PartialEq)]
pub struct GnnMeta {
    pub n_max: usize,
    pub e_max: usize,
    pub f_n: usize,
    pub f_e: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Integration tests that need the built artifact live in
    /// rust/tests/runtime_gnn.rs (skipped when artifacts/ is absent).
    #[test]
    fn meta_defaults_match_feature_constants() {
        let m = GnnMeta {
            n_max: features::N_MAX,
            e_max: features::E_MAX,
            f_n: features::F_N,
            f_e: features::F_E,
        };
        assert_eq!(m.n_max, 256);
        assert_eq!(m.e_max, 1024);
    }

    #[cfg(not(theseus_pjrt))]
    #[test]
    fn stub_loader_reports_unavailable() {
        let err = GnnModel::load_default().err().expect("stub cannot load");
        let msg = err.to_string();
        assert!(msg.contains("theseus_pjrt"), "{msg}");
    }
}
