//! PJRT runtime: load the AOT-compiled GNN (HLO text produced by
//! `python/compile/aot.py`) and execute it from the DSE hot path.
//!
//! Python never runs at DSE time — the Rust coordinator feeds padded
//! feature tensors (built by [`features`], mirroring the Python schema) to
//! the compiled executable via the PJRT C API (`xla` crate).
//!
//! The PJRT path needs crates unavailable in the offline build, so the real
//! implementation ([`pjrt`]) is gated behind `--cfg theseus_pjrt`; the
//! default build substitutes [`stub`], whose `GnnModel::load_default`
//! reports the runtime as unavailable and lets every caller fall back to
//! the analytical NoC model. Both expose the same `GnnModel` API.
//!
//! # Batched inference (§Perf)
//!
//! The PJRT executable handle is thread-confined, so the GNN fidelity
//! amortizes its per-call dispatch cost by *batching* instead of thread
//! fan-out: [`batch::GnnBatcher`] packs several chunks' padded features
//! into `[B, N_MAX, F_N]` / `[B, E_MAX, F_E]` tensors
//! ([`features::build_batch`]) and runs one execute call per batch — the
//! evaluation engine's batched sweep dispatch (`eval::engine`, the `gnn`
//! and `gnn-test` fidelities) and thus the `mfmobo` high-fidelity stage
//! ride on it. `python -m compile.aot --batch B` bakes
//! the leading batch dimension into the HLO export and records it in the
//! `gnn_noc.meta.json` sidecar ([`GnnMeta::batch`]); artifacts exported
//! with `--batch 1` keep the legacy per-chunk signature and the batcher
//! degrades to slot-at-a-time calls. [`TestBackend`] (a deterministic
//! closed-form pseudo-GNN behind the same API) keeps the packing/scatter
//! logic and the batched-vs-per-chunk equivalence contract testable in the
//! default build.

pub mod batch;
pub mod features;
pub mod test_backend;

pub use test_backend::TestBackend;

#[cfg(theseus_pjrt)]
mod pjrt;
#[cfg(theseus_pjrt)]
pub use pjrt::GnnModel;

#[cfg(not(theseus_pjrt))]
mod stub;
#[cfg(not(theseus_pjrt))]
pub use stub::{GnnModel, GnnUnavailable};

/// Schema sidecar written by `compile.aot` — checked at load.
#[derive(Debug, Clone, PartialEq)]
pub struct GnnMeta {
    pub n_max: usize,
    pub e_max: usize,
    pub f_n: usize,
    pub f_e: usize,
    /// Leading batch dimension of the AOT export (1 = legacy per-chunk
    /// executable; `compile.aot --batch B` bakes `B` padded slots per
    /// execute call).
    pub batch: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Integration tests that need the built artifact live in
    /// rust/tests/runtime_gnn.rs (skipped when artifacts/ is absent).
    #[test]
    fn meta_defaults_match_feature_constants() {
        let m = GnnMeta {
            n_max: features::N_MAX,
            e_max: features::E_MAX,
            f_n: features::F_N,
            f_e: features::F_E,
            batch: 1,
        };
        assert_eq!(m.n_max, 256);
        assert_eq!(m.e_max, 1024);
        assert_eq!(m.batch, 1);
    }

    #[cfg(not(theseus_pjrt))]
    #[test]
    fn stub_loader_reports_unavailable() {
        let err = GnnModel::load_default().err().expect("stub cannot load");
        let msg = err.to_string();
        assert!(msg.contains("theseus_pjrt"), "{msg}");
    }
}
