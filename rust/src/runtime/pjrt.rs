//! Real PJRT execution path — compiled only under `--cfg theseus_pjrt`
//! because its dependencies (`xla`, `anyhow`, `log`) are unavailable in the
//! offline build (see rust/Cargo.toml for how to enable).

use std::path::Path;
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::arch::CoreConfig;
use crate::compiler::routing::NUM_DIRS;
use crate::compiler::CompiledChunk;
use crate::eval::NocEstimator;
use crate::util::json::Json;

use super::{features, GnnMeta};

/// The GNN NoC-congestion model, compiled for the CPU PJRT backend.
pub struct GnnModel {
    exe: Mutex<xla::PjRtLoadedExecutable>,
    pub meta: GnnMeta,
}

impl GnnModel {
    /// Load + compile `artifacts/gnn_noc.hlo.txt` (path to the `.hlo.txt`).
    pub fn load(path: &Path) -> Result<GnnModel> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(path.to_str().context("utf8 path")?)
            .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("PJRT compile")?;

        let meta_path = path
            .to_str()
            .unwrap()
            .replace(".hlo.txt", ".meta.json");
        let meta = match std::fs::read_to_string(&meta_path) {
            Ok(text) => {
                let j = Json::parse(&text).context("parse gnn meta json")?;
                GnnMeta {
                    n_max: j.get("n_max").and_then(|v| v.as_usize()).unwrap_or(features::N_MAX),
                    e_max: j.get("e_max").and_then(|v| v.as_usize()).unwrap_or(features::E_MAX),
                    f_n: j.get("f_n").and_then(|v| v.as_usize()).unwrap_or(features::F_N),
                    f_e: j.get("f_e").and_then(|v| v.as_usize()).unwrap_or(features::F_E),
                }
            }
            Err(_) => GnnMeta {
                n_max: features::N_MAX,
                e_max: features::E_MAX,
                f_n: features::F_N,
                f_e: features::F_E,
            },
        };
        anyhow::ensure!(
            meta.n_max == features::N_MAX
                && meta.e_max == features::E_MAX
                && meta.f_n == features::F_N
                && meta.f_e == features::F_E,
            "gnn meta schema mismatch: {meta:?} vs runtime constants"
        );
        Ok(GnnModel {
            exe: Mutex::new(exe),
            meta,
        })
    }

    /// Load from the conventional artifacts location, if present.
    pub fn load_default() -> Result<GnnModel> {
        let candidates = [
            "artifacts/gnn_noc.hlo.txt",
            "../artifacts/gnn_noc.hlo.txt",
        ];
        for c in candidates {
            if Path::new(c).exists() {
                return GnnModel::load(Path::new(c));
            }
        }
        anyhow::bail!("no gnn_noc.hlo.txt found (run `make artifacts`)")
    }

    /// Predict per-edge mean waiting times for padded inputs; returns the
    /// raw padded vector of length `E_MAX`.
    pub fn predict_padded(&self, inp: &features::GnnInputs) -> Result<Vec<f32>> {
        let node = xla::Literal::vec1(&inp.node_feat)
            .reshape(&[features::N_MAX as i64, features::F_N as i64])?;
        let edge = xla::Literal::vec1(&inp.edge_feat)
            .reshape(&[features::E_MAX as i64, features::F_E as i64])?;
        let src = xla::Literal::vec1(&inp.src_idx);
        let dst = xla::Literal::vec1(&inp.dst_idx);
        let mask = xla::Literal::vec1(&inp.edge_mask);
        let exe = self.exe.lock().unwrap();
        let result = exe.execute::<xla::Literal>(&[node, edge, src, dst, mask])?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Predict and scatter back into dense `link_index` order.
    pub fn predict_link_waits(
        &self,
        chunk: &CompiledChunk,
        core: &CoreConfig,
    ) -> Result<Option<Vec<f64>>> {
        let Some(inp) = features::build(chunk, core) else {
            return Ok(None); // region exceeds padding: analytical fallback
        };
        let y = self.predict_padded(&inp)?;
        let mut waits = vec![0.0f64; chunk.region_h * chunk.region_w * NUM_DIRS];
        for (e, &dense) in inp.dense_of_edge.iter().enumerate() {
            if inp.edge_mask[e] > 0.0 {
                waits[dense] = y[e].max(0.0) as f64;
            }
        }
        Ok(Some(waits))
    }
}

impl NocEstimator for GnnModel {
    fn link_waits(&self, chunk: &CompiledChunk, core: &CoreConfig) -> Option<Vec<f64>> {
        match self.predict_link_waits(chunk, core) {
            Ok(w) => w,
            Err(e) => {
                log::warn!("gnn predict failed ({e}); analytical fallback");
                None
            }
        }
    }

    fn name(&self) -> &'static str {
        "gnn"
    }
}
