//! Real PJRT execution path — compiled only under `--cfg theseus_pjrt`
//! because its dependencies (`xla`, `anyhow`, `log`) are unavailable in the
//! offline build (see rust/Cargo.toml for how to enable).

use std::path::Path;
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::arch::CoreConfig;
use crate::compiler::routing::NUM_DIRS;
use crate::compiler::CompiledChunk;
use crate::eval::NocEstimator;
use crate::util::json::Json;

use super::batch::GnnBackend;
use super::features::{self, GnnBatch};
use super::GnnMeta;

/// The GNN NoC-congestion model, compiled for the CPU PJRT backend.
pub struct GnnModel {
    exe: Mutex<xla::PjRtLoadedExecutable>,
    pub meta: GnnMeta,
}

impl GnnModel {
    /// Load + compile `artifacts/gnn_noc.hlo.txt` (path to the `.hlo.txt`).
    pub fn load(path: &Path) -> Result<GnnModel> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(path.to_str().context("utf8 path")?)
            .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("PJRT compile")?;

        let meta_path = path
            .to_str()
            .unwrap()
            .replace(".hlo.txt", ".meta.json");
        let meta = match std::fs::read_to_string(&meta_path) {
            Ok(text) => {
                let j = Json::parse(&text).context("parse gnn meta json")?;
                GnnMeta {
                    n_max: j.get("n_max").and_then(|v| v.as_usize()).unwrap_or(features::N_MAX),
                    e_max: j.get("e_max").and_then(|v| v.as_usize()).unwrap_or(features::E_MAX),
                    f_n: j.get("f_n").and_then(|v| v.as_usize()).unwrap_or(features::F_N),
                    f_e: j.get("f_e").and_then(|v| v.as_usize()).unwrap_or(features::F_E),
                    // Artifacts from before the batched export carry no
                    // `batch` key: they have the legacy per-chunk signature.
                    batch: j.get("batch").and_then(|v| v.as_usize()).unwrap_or(1),
                }
            }
            Err(_) => GnnMeta {
                n_max: features::N_MAX,
                e_max: features::E_MAX,
                f_n: features::F_N,
                f_e: features::F_E,
                batch: 1,
            },
        };
        anyhow::ensure!(
            meta.n_max == features::N_MAX
                && meta.e_max == features::E_MAX
                && meta.f_n == features::F_N
                && meta.f_e == features::F_E
                && meta.batch >= 1,
            "gnn meta schema mismatch: {meta:?} vs runtime constants"
        );
        Ok(GnnModel {
            exe: Mutex::new(exe),
            meta,
        })
    }

    /// Load from the conventional artifacts location, if present.
    pub fn load_default() -> Result<GnnModel> {
        let candidates = [
            "artifacts/gnn_noc.hlo.txt",
            "../artifacts/gnn_noc.hlo.txt",
        ];
        for c in candidates {
            if Path::new(c).exists() {
                return GnnModel::load(Path::new(c));
            }
        }
        anyhow::bail!("no gnn_noc.hlo.txt found (run `make artifacts`)")
    }

    /// Load the per-chunk (`--batch 1`) sibling artifact when one exists,
    /// else fall back to [`GnnModel::load_default`]. Per-chunk-dominated
    /// callers (figure benches) use this so a batched default artifact
    /// does not make every single prediction pay the full batch-slot
    /// program (see [`GnnModel::predict_padded`]).
    pub fn load_per_chunk_default() -> Result<GnnModel> {
        let candidates = [
            "artifacts/gnn_noc.chunk.hlo.txt",
            "../artifacts/gnn_noc.chunk.hlo.txt",
        ];
        for c in candidates {
            if Path::new(c).exists() {
                return GnnModel::load(Path::new(c));
            }
        }
        GnnModel::load_default()
    }

    /// Execute the legacy per-chunk signature (`meta.batch == 1` exports:
    /// no leading batch dimension).
    fn execute_single(&self, slot: usize, b: &GnnBatch) -> Result<Vec<f32>> {
        let n = features::N_MAX * features::F_N;
        let m = features::E_MAX * features::F_E;
        let e = features::E_MAX;
        let node = xla::Literal::vec1(&b.node_feat[slot * n..(slot + 1) * n])
            .reshape(&[features::N_MAX as i64, features::F_N as i64])?;
        let edge = xla::Literal::vec1(&b.edge_feat[slot * m..(slot + 1) * m])
            .reshape(&[features::E_MAX as i64, features::F_E as i64])?;
        let src = xla::Literal::vec1(&b.src_idx[slot * e..(slot + 1) * e]);
        let dst = xla::Literal::vec1(&b.dst_idx[slot * e..(slot + 1) * e]);
        let mask = xla::Literal::vec1(&b.edge_mask[slot * e..(slot + 1) * e]);
        let exe = self.exe.lock().unwrap();
        let result = exe.execute::<xla::Literal>(&[node, edge, src, dst, mask])?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// One execute call over the whole packed batch (`meta.batch > 1`
    /// exports). Short batches are zero-padded up to the executable's
    /// static slot count; the zero slots are masked out and discarded.
    fn execute_batched(&self, b: &GnnBatch) -> Result<Vec<f32>> {
        let cap = self.meta.batch;
        anyhow::ensure!(
            b.batch <= cap,
            "batch {} exceeds executable capacity {cap}",
            b.batch
        );
        let n = features::N_MAX * features::F_N;
        let m = features::E_MAX * features::F_E;
        let e = features::E_MAX;
        let pad = |v: &[f32], per_slot: usize| -> Vec<f32> {
            let mut full = Vec::with_capacity(cap * per_slot);
            full.extend_from_slice(v);
            full.resize(cap * per_slot, 0.0);
            full
        };
        let pad_i = |v: &[i32], per_slot: usize| -> Vec<i32> {
            let mut full = Vec::with_capacity(cap * per_slot);
            full.extend_from_slice(v);
            full.resize(cap * per_slot, 0);
            full
        };
        let node = xla::Literal::vec1(&pad(&b.node_feat, n)).reshape(&[
            cap as i64,
            features::N_MAX as i64,
            features::F_N as i64,
        ])?;
        let edge = xla::Literal::vec1(&pad(&b.edge_feat, m)).reshape(&[
            cap as i64,
            features::E_MAX as i64,
            features::F_E as i64,
        ])?;
        let src = xla::Literal::vec1(&pad_i(&b.src_idx, e))
            .reshape(&[cap as i64, features::E_MAX as i64])?;
        let dst = xla::Literal::vec1(&pad_i(&b.dst_idx, e))
            .reshape(&[cap as i64, features::E_MAX as i64])?;
        let mask = xla::Literal::vec1(&pad(&b.edge_mask, e))
            .reshape(&[cap as i64, features::E_MAX as i64])?;
        let exe = self.exe.lock().unwrap();
        let result = exe.execute::<xla::Literal>(&[node, edge, src, dst, mask])?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        let mut y = out.to_vec::<f32>()?;
        y.truncate(b.batch * features::E_MAX);
        Ok(y)
    }

    /// Predict padded per-edge mean waiting times for a packed batch
    /// (slot-major, `batch.batch * E_MAX` values).
    pub fn predict_padded_batch(&self, b: &GnnBatch) -> Result<Vec<f32>> {
        if self.meta.batch <= 1 {
            // Legacy artifact: per-chunk signature, loop slot by slot.
            let mut y = Vec::with_capacity(b.batch * features::E_MAX);
            for slot in 0..b.batch {
                y.extend(self.execute_single(slot, b)?);
            }
            return Ok(y);
        }
        self.execute_batched(b)
    }

    /// Predict per-edge mean waiting times for padded inputs; returns the
    /// raw padded vector of length `E_MAX`.
    ///
    /// NOTE: on a batched artifact (`meta.batch > 1`) the executable's
    /// shapes are static, so a single prediction still runs the full
    /// `meta.batch`-slot program (~`batch`× the per-chunk cost of a
    /// `--batch 1` export). Hot paths should batch through
    /// [`super::batch::GnnBatcher`]; per-chunk callers that dominate a
    /// profile (e.g. figure benches) can load a `--batch 1` sibling
    /// artifact instead.
    pub fn predict_padded(&self, inp: &features::GnnInputs) -> Result<Vec<f32>> {
        let b = features::build_batch(&[inp]);
        let mut y = self.predict_padded_batch(&b)?;
        y.truncate(features::E_MAX);
        Ok(y)
    }

    /// Predict and scatter back into dense `link_index` order.
    pub fn predict_link_waits(
        &self,
        chunk: &CompiledChunk,
        core: &CoreConfig,
    ) -> Result<Option<Vec<f64>>> {
        let Some(inp) = features::build(chunk, core) else {
            return Ok(None); // region exceeds padding: analytical fallback
        };
        let y = self.predict_padded(&inp)?;
        Ok(Some(features::scatter_link_waits(
            &inp,
            &y,
            chunk.region_h * chunk.region_w * NUM_DIRS,
        )))
    }
}

impl GnnBackend for GnnModel {
    fn max_batch(&self) -> usize {
        self.meta.batch.max(1)
    }

    fn predict_batch(&self, batch: &GnnBatch) -> Result<Vec<f32>, String> {
        self.predict_padded_batch(batch).map_err(|e| e.to_string())
    }
}

impl NocEstimator for GnnModel {
    fn link_waits(&self, chunk: &CompiledChunk, core: &CoreConfig) -> Option<Vec<f64>> {
        match self.predict_link_waits(chunk, core) {
            Ok(w) => w,
            Err(e) => {
                log::warn!("gnn predict failed ({e}); analytical fallback");
                None
            }
        }
    }

    fn name(&self) -> &'static str {
        "gnn"
    }
}
