//! Batched GNN link-wait inference for the strategy sweep.
//!
//! The PJRT executable handle is thread-confined, so the GNN fidelity
//! cannot use the thread fan-out that accelerates the analytical strategy
//! sweep (the evaluation engine's pooled dispatch — see
//! `eval::engine`). The win here is *batching*: the
//! [`GnnBatcher`] collects the per-chunk [`features::GnnInputs`] of a whole
//! sweep, packs them into `[B, N_MAX, F_N]` / `[B, E_MAX, F_E]` tensors
//! ([`features::build_batch`]) and runs **one execute call per batch**,
//! amortizing the per-call dispatch overhead across `B` candidate chunks —
//! then scatters each slot's predictions back through `dense_of_edge` into
//! dense `link_index` order.
//!
//! The batcher is backend-agnostic via [`GnnBackend`]: the PJRT
//! [`super::GnnModel`] (batched executable from
//! `python -m compile.aot --batch B`), its stub twin, and the deterministic
//! in-process [`super::TestBackend`] all implement it, so the packing and
//! scatter logic — and the batched-vs-per-chunk equivalence contract — are
//! testable in the default (non-PJRT) build.

use crate::arch::CoreConfig;
use crate::compiler::routing::NUM_DIRS;
use crate::compiler::CompiledChunk;

use super::features::{self, GnnBatch, GnnInputs};

/// A GNN execution backend the [`GnnBatcher`] can drive.
///
/// Errors are stringly-typed so the trait stays object-safe across the
/// PJRT build (`anyhow::Error`), the stub (`GnnUnavailable`) and the test
/// backend (infallible); callers treat any error as "fall back to the
/// analytical model".
pub trait GnnBackend {
    /// Largest batch one execute call accepts (1 = per-chunk executable).
    fn max_batch(&self) -> usize;

    /// Predict padded per-edge mean waiting times for a packed batch;
    /// returns `batch.batch * E_MAX` values, slot-major.
    fn predict_batch(&self, batch: &GnnBatch) -> Result<Vec<f32>, String>;
}

/// Batch size for GNN link-wait inference (env `THESEUS_GNN_BATCH`), the
/// default slot count of the batched AOT export.
pub fn gnn_batch_size() -> usize {
    crate::util::cli::env_usize("THESEUS_GNN_BATCH", 8).max(1)
}

/// Collects per-chunk feature tensors and serves link-wait predictions
/// with one backend execute call per `batch_size` chunks.
pub struct GnnBatcher<'a> {
    backend: &'a dyn GnnBackend,
    batch_size: usize,
}

impl<'a> GnnBatcher<'a> {
    /// `batch_size` is clamped to the backend's executable capacity.
    pub fn new(backend: &'a dyn GnnBackend, batch_size: usize) -> GnnBatcher<'a> {
        let cap = backend.max_batch().max(1);
        GnnBatcher {
            backend,
            batch_size: batch_size.clamp(1, cap),
        }
    }

    pub fn batch_size(&self) -> usize {
        self.batch_size
    }

    /// Predict per-link mean waiting times for many chunks. Returns one
    /// entry per request, in order: `None` means the chunk exceeds the
    /// GNN padding (hierarchical scale reduction per §VI) or the backend
    /// is unavailable — the caller falls back to the analytical model for
    /// that chunk, exactly as with per-chunk inference.
    pub fn link_waits_many(
        &self,
        reqs: &[(&CompiledChunk, &CoreConfig)],
    ) -> Vec<Option<Vec<f64>>> {
        let mut out: Vec<Option<Vec<f64>>> = vec![None; reqs.len()];
        // Stage 1: per-chunk features. Oversize chunks yield None here and
        // simply never occupy a batch slot (analytical fallback mid-batch).
        let inputs: Vec<Option<GnnInputs>> =
            reqs.iter().map(|(c, k)| features::build(c, k)).collect();
        let packable: Vec<usize> = inputs
            .iter()
            .enumerate()
            .filter_map(|(i, inp)| inp.as_ref().map(|_| i))
            .collect();
        // Stage 2: one execute call per `batch_size` packable chunks.
        for group in packable.chunks(self.batch_size) {
            let slots: Vec<&GnnInputs> = group
                .iter()
                .map(|&i| inputs[i].as_ref().expect("packable index"))
                .collect();
            let packed = features::build_batch(&slots);
            let y = match self.backend.predict_batch(&packed) {
                Ok(y) if y.len() >= packed.batch * features::E_MAX => y,
                // Unavailable backend or short output: leave every slot of
                // this group on the analytical fallback — but say so once,
                // or a persistent PJRT failure would silently relabel
                // analytical numbers as GNN fidelity for the whole run.
                res => {
                    let why = match res {
                        Err(e) => e,
                        Ok(y) => format!(
                            "short output: {} values for {} slots",
                            y.len(),
                            packed.batch
                        ),
                    };
                    crate::util::warn::warn_once(
                        "gnn-batch-fallback",
                        &format!("gnn batch predict failed ({why}); analytical fallback"),
                    );
                    continue;
                }
            };
            // Stage 3: scatter each slot back into link_index order.
            for (slot, &i) in group.iter().enumerate() {
                let ys = &y[slot * features::E_MAX..(slot + 1) * features::E_MAX];
                let (chunk, _) = reqs[i];
                let n_links = chunk.region_h * chunk.region_w * NUM_DIRS;
                let inp = inputs[i].as_ref().expect("packable index");
                out[i] = Some(features::scatter_link_waits(inp, ys, n_links));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Dataflow;
    use crate::compiler::compile_chunk;
    use crate::eval::NocEstimator;
    use crate::runtime::TestBackend;
    use crate::workload::models::benchmarks;
    use crate::workload::{OpGraph, Phase};

    fn chunk(h: usize, w: usize) -> (CompiledChunk, CoreConfig) {
        let mut spec = benchmarks()[0].clone();
        spec.seq_len = 64;
        let g = OpGraph::transformer_chunk(&spec, 1, 1, 8, Phase::Prefill, false);
        let core = CoreConfig {
            dataflow: Dataflow::WS,
            mac_num: 512,
            buffer_kb: 128,
            buffer_bw_bits: 256,
            noc_bw_bits: 512,
        };
        (compile_chunk(&g, h, w, &core), core)
    }

    #[test]
    fn batched_matches_per_chunk_bitwise_on_mixed_sizes() {
        // The batched-vs-per-chunk equivalence contract (acceptance
        // criterion): identical predictions for a mixed-size chunk set,
        // including an oversize chunk that must fall back analytically
        // mid-batch while its neighbors still batch.
        let backend = TestBackend::new();
        let built = [
            chunk(2, 2),
            chunk(3, 4),
            chunk(17, 17), // exceeds N_MAX: analytical fallback mid-batch
            chunk(4, 4),
            chunk(2, 5),
        ];
        let reqs: Vec<(&CompiledChunk, &CoreConfig)> =
            built.iter().map(|(c, k)| (c, k)).collect();

        let batched = GnnBatcher::new(&backend, 8).link_waits_many(&reqs);
        let per_chunk = GnnBatcher::new(&backend, 1).link_waits_many(&reqs);
        let split = GnnBatcher::new(&backend, 2).link_waits_many(&reqs);

        assert_eq!(batched.len(), reqs.len());
        assert!(batched[2].is_none(), "oversize chunk must fall back");
        assert!(
            batched[0].is_some() && batched[1].is_some() && batched[3].is_some(),
            "in-padding chunks must predict"
        );
        // Bit-identical across batch sizes (f64 Vec equality is exact).
        assert_eq!(batched, per_chunk);
        assert_eq!(batched, split);
        // And identical to the serial per-chunk estimator path.
        for (i, (c, k)) in reqs.iter().enumerate() {
            assert_eq!(batched[i], backend.link_waits(c, k), "chunk {i}");
        }
    }

    #[test]
    fn waits_have_chunk_local_shape_and_sign() {
        let backend = TestBackend::new();
        let (c, k) = chunk(3, 5);
        let reqs = [(&c, &k)];
        let out = GnnBatcher::new(&backend, 4).link_waits_many(&reqs);
        let waits = out[0].as_ref().expect("within padding");
        assert_eq!(waits.len(), 3 * 5 * NUM_DIRS);
        assert!(waits.iter().all(|&w| w.is_finite() && w >= 0.0));
        assert!(
            waits.iter().any(|&w| w > 0.0),
            "pseudo-GNN should predict some waiting under load"
        );
    }

    #[test]
    fn batcher_clamps_to_backend_capacity() {
        let backend = TestBackend::new();
        let cap = backend.max_batch();
        assert_eq!(GnnBatcher::new(&backend, 0).batch_size(), 1);
        assert_eq!(GnnBatcher::new(&backend, cap + 100).batch_size(), cap);
    }

    #[test]
    fn unavailable_backend_falls_back_everywhere() {
        // The stub GnnModel cannot be constructed, so model the
        // unavailable case with a failing backend directly.
        struct Failing;
        impl GnnBackend for Failing {
            fn max_batch(&self) -> usize {
                4
            }
            fn predict_batch(&self, _b: &GnnBatch) -> Result<Vec<f32>, String> {
                Err("backend offline".to_string())
            }
        }
        let (c, k) = chunk(3, 3);
        let reqs = [(&c, &k), (&c, &k)];
        let out = GnnBatcher::new(&Failing, 4).link_waits_many(&reqs);
        assert!(out.iter().all(|w| w.is_none()));
    }
}
