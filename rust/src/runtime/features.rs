//! GNN feature construction — EXACT mirror of `python/compile/features.py`
//! (the single source of truth; see its module docstring). Any change on
//! either side must be made on both; the schema is pinned by
//! `artifacts/gnn_noc.meta.json` and the tests below.

use crate::arch::CoreConfig;
use crate::compiler::routing::NUM_DIRS;
use crate::compiler::CompiledChunk;
use crate::eval::op_level::{chunk_latency, NocModel};

pub const N_MAX: usize = 256;
pub const E_MAX: usize = 1024;
pub const F_N: usize = 5;
pub const F_E: usize = 4;

/// (drow, dcol) per direction — must match `Dir` (E, W, S, N) and the
/// Python `DIR_OFFSETS`.
const DIR_OFFSETS: [(isize, isize); 4] = [(0, 1), (0, -1), (1, 0), (-1, 0)];

/// Valid directed mesh links in dense `link_index` order:
/// (src_node, dst_node, dense_index).
pub fn mesh_edges(h: usize, w: usize) -> Vec<(usize, usize, usize)> {
    let mut edges = Vec::new();
    for r in 0..h {
        for c in 0..w {
            let node = r * w + c;
            for (d, (dr, dc)) in DIR_OFFSETS.iter().enumerate() {
                let rr = r as isize + dr;
                let cc = c as isize + dc;
                if rr >= 0 && (rr as usize) < h && cc >= 0 && (cc as usize) < w {
                    edges.push((node, rr as usize * w + cc as usize, node * NUM_DIRS + d));
                }
            }
        }
    }
    edges
}

/// Coordinate normalization shared with `python/compile/features.py`
/// (`max(h - 1, 1)` there — one expression on both sides so a 1×N strip,
/// where the divisor degenerates, cannot drift between the mirrors).
#[inline]
pub fn coord_norm(i: usize, extent: usize) -> f32 {
    i as f32 / extent.saturating_sub(1).max(1) as f32
}

/// Padded GNN inputs for one compiled chunk.
pub struct GnnInputs {
    pub node_feat: Vec<f32>, // [N_MAX * F_N] row-major
    pub edge_feat: Vec<f32>, // [E_MAX * F_E]
    pub src_idx: Vec<i32>,
    pub dst_idx: Vec<i32>,
    pub edge_mask: Vec<f32>,
    /// Dense link index per padded edge slot (for scattering predictions
    /// back into `link_index` order).
    pub dense_of_edge: Vec<usize>,
    pub t0_cycles: f64,
}

/// Build features. Returns `None` when the region exceeds the padded
/// shapes (the caller falls back to the analytical model — hierarchical
/// scale reduction per §VI).
pub fn build(chunk: &CompiledChunk, core: &CoreConfig) -> Option<GnnInputs> {
    let h = chunk.region_h;
    let w = chunk.region_w;
    let n = h * w;
    if n > N_MAX {
        return None;
    }
    let edges = mesh_edges(h, w);
    if edges.len() > E_MAX {
        return None;
    }

    // Zero-load normalizer T0: identical to the dataset generator.
    let zeros = vec![0.0; n * NUM_DIRS];
    let t0 = chunk_latency(chunk, core, 1.0, NocModel::LinkWaits(&zeros))
        .cycles
        .max(1.0);
    let flit_bytes = (core.noc_bw_bits as f64 / 8.0).max(1.0);

    let node_bytes = chunk.node_injected_bytes();
    let mut node_feat = vec![0.0f32; N_MAX * F_N];
    for r in 0..h {
        for c in 0..w {
            let i = r * w + c;
            let inject = node_bytes[i] / flit_bytes / t0;
            let f = &mut node_feat[i * F_N..(i + 1) * F_N];
            f[0] = inject as f32;
            f[1] = 1.0;
            f[2] = coord_norm(r, h);
            f[3] = coord_norm(c, w);
            f[4] = 1.0;
        }
    }

    let link_bytes = chunk.link_loads();
    let bw_norm = ((core.noc_bw_bits.max(32) as f64 / 32.0).log2() / 7.0) as f32;
    let mut edge_feat = vec![0.0f32; E_MAX * F_E];
    let mut src_idx = vec![0i32; E_MAX];
    let mut dst_idx = vec![0i32; E_MAX];
    let mut edge_mask = vec![0.0f32; E_MAX];
    let mut dense_of_edge = vec![0usize; E_MAX];
    for (e, &(s, d, dense)) in edges.iter().enumerate() {
        let rho = link_bytes[dense] / flit_bytes / t0;
        let f = &mut edge_feat[e * F_E..(e + 1) * F_E];
        f[0] = rho as f32;
        f[1] = bw_norm;
        f[2] = 1.0;
        f[3] = 1.0;
        src_idx[e] = s as i32;
        dst_idx[e] = d as i32;
        edge_mask[e] = 1.0;
        dense_of_edge[e] = dense;
    }

    Some(GnnInputs {
        node_feat,
        edge_feat,
        src_idx,
        dst_idx,
        edge_mask,
        dense_of_edge,
        t0_cycles: t0,
    })
}

/// Packed multi-chunk tensors for one batched execute call:
/// `[B, N_MAX, F_N]` / `[B, E_MAX, F_E]` (row-major, slot-major), matching
/// the `--batch` AOT export signature of `python/compile/aot.py`.
pub struct GnnBatch {
    pub batch: usize,
    pub node_feat: Vec<f32>, // [batch * N_MAX * F_N]
    pub edge_feat: Vec<f32>, // [batch * E_MAX * F_E]
    pub src_idx: Vec<i32>,   // [batch * E_MAX]
    pub dst_idx: Vec<i32>,   // [batch * E_MAX]
    pub edge_mask: Vec<f32>, // [batch * E_MAX]
}

/// Pack per-chunk [`GnnInputs`] into one [`GnnBatch`], slot `i` holding
/// `inputs[i]` verbatim (all inputs are already padded to the static
/// shapes, so packing is a straight concatenation).
pub fn build_batch(inputs: &[&GnnInputs]) -> GnnBatch {
    let b = inputs.len();
    let mut batch = GnnBatch {
        batch: b,
        node_feat: Vec::with_capacity(b * N_MAX * F_N),
        edge_feat: Vec::with_capacity(b * E_MAX * F_E),
        src_idx: Vec::with_capacity(b * E_MAX),
        dst_idx: Vec::with_capacity(b * E_MAX),
        edge_mask: Vec::with_capacity(b * E_MAX),
    };
    for inp in inputs {
        batch.node_feat.extend_from_slice(&inp.node_feat);
        batch.edge_feat.extend_from_slice(&inp.edge_feat);
        batch.src_idx.extend_from_slice(&inp.src_idx);
        batch.dst_idx.extend_from_slice(&inp.dst_idx);
        batch.edge_mask.extend_from_slice(&inp.edge_mask);
    }
    batch
}

/// Scatter one slot's padded per-edge predictions (`y`, length `E_MAX`)
/// back through `dense_of_edge` into dense `link_index` order.
pub fn scatter_link_waits(inp: &GnnInputs, y: &[f32], n_links: usize) -> Vec<f64> {
    let mut waits = vec![0.0f64; n_links];
    for (e, &dense) in inp.dense_of_edge.iter().enumerate() {
        if inp.edge_mask[e] > 0.0 {
            waits[dense] = y[e].max(0.0) as f64;
        }
    }
    waits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Dataflow;
    use crate::compiler::compile_chunk;
    use crate::workload::models::benchmarks;
    use crate::workload::{OpGraph, Phase};

    fn chunk(h: usize, w: usize) -> (CompiledChunk, CoreConfig) {
        let mut spec = benchmarks()[0].clone();
        spec.seq_len = 64;
        let g = OpGraph::transformer_chunk(&spec, 1, 1, 8, Phase::Prefill, false);
        let core = CoreConfig {
            dataflow: Dataflow::WS,
            mac_num: 512,
            buffer_kb: 128,
            buffer_bw_bits: 256,
            noc_bw_bits: 512,
        };
        (compile_chunk(&g, h, w, &core), core)
    }

    #[test]
    fn mesh_edges_count_matches_formula() {
        // h x w mesh: 2*(2hw - h - w) directed links.
        for (h, w) in [(3usize, 3usize), (4, 7), (16, 16), (1, 5)] {
            let expect = 2 * (2 * h * w - h - w);
            assert_eq!(mesh_edges(h, w).len(), expect, "{h}x{w}");
        }
    }

    #[test]
    fn sixteen_square_fits_padding() {
        assert!(mesh_edges(16, 16).len() <= E_MAX);
        assert_eq!(mesh_edges(16, 16).len(), 960);
    }

    #[test]
    fn build_shapes_and_mask() {
        let (ch, core) = chunk(4, 5);
        let f = build(&ch, &core).unwrap();
        assert_eq!(f.node_feat.len(), N_MAX * F_N);
        assert_eq!(f.edge_feat.len(), E_MAX * F_E);
        let active: f32 = f.edge_mask.iter().sum();
        assert_eq!(active as usize, mesh_edges(4, 5).len());
        assert!(f.t0_cycles > 0.0);
        // Node 0 active flag set, padded node inactive.
        assert_eq!(f.node_feat[1], 1.0);
        assert_eq!(f.node_feat[(4 * 5) * F_N + 1], 0.0);
    }

    #[test]
    fn oversize_region_returns_none() {
        let (ch, core) = chunk(17, 17);
        assert!(build(&ch, &core).is_none());
    }

    #[test]
    fn golden_matches_python_schema() {
        // Pin the exact feature values for a tiny deterministic case so a
        // drift on either side of the Rust/Python mirror fails loudly.
        // (python/tests/test_features.py pins the same numbers.)
        let h = 2;
        let w = 2;
        let edges = mesh_edges(h, w);
        assert_eq!(
            edges,
            vec![(0, 1, 0), (0, 2, 2), (1, 0, 5), (1, 3, 6), (2, 3, 8), (2, 0, 11), (3, 2, 13), (3, 1, 15)]
        );
        // 2x2 coordinates normalize over extent-1 = 1.
        assert_eq!(coord_norm(0, 2), 0.0);
        assert_eq!(coord_norm(1, 2), 1.0);

        // 1xN strip mesh — the degenerate case where the normalizer is
        // most fragile (extent-1 = 0): both sides use max(h-1, 1), so the
        // row coordinate pins to exactly 0 for every node.
        assert_eq!(
            mesh_edges(1, 5),
            vec![
                (0, 1, 0),
                (1, 2, 4),
                (1, 0, 5),
                (2, 3, 8),
                (2, 1, 9),
                (3, 4, 12),
                (3, 2, 13),
                (4, 3, 17)
            ]
        );
        assert_eq!(coord_norm(0, 1), 0.0);
        for c in 0..5 {
            assert_eq!(coord_norm(c, 5), c as f32 / 4.0);
        }
    }

    #[test]
    fn build_batch_packs_slots_in_order() {
        let (c1, k1) = chunk(3, 3);
        let (c2, k2) = chunk(4, 5);
        let i1 = build(&c1, &k1).unwrap();
        let i2 = build(&c2, &k2).unwrap();
        let b = build_batch(&[&i1, &i2]);
        assert_eq!(b.batch, 2);
        assert_eq!(b.node_feat.len(), 2 * N_MAX * F_N);
        assert_eq!(b.edge_feat.len(), 2 * E_MAX * F_E);
        assert_eq!(b.src_idx.len(), 2 * E_MAX);
        // Slot 0 holds the first chunk verbatim, slot 1 the second.
        assert_eq!(&b.node_feat[..N_MAX * F_N], &i1.node_feat[..]);
        assert_eq!(&b.node_feat[N_MAX * F_N..], &i2.node_feat[..]);
        assert_eq!(&b.edge_mask[..E_MAX], &i1.edge_mask[..]);
        assert_eq!(&b.edge_mask[E_MAX..], &i2.edge_mask[..]);
        assert_eq!(&b.src_idx[E_MAX..], &i2.src_idx[..]);
        assert_eq!(&b.dst_idx[..E_MAX], &i1.dst_idx[..]);
    }

    #[test]
    fn scatter_restores_link_index_order() {
        let (ch, core) = chunk(3, 3);
        let inp = build(&ch, &core).unwrap();
        let mut y = vec![0.0f32; E_MAX];
        for e in 0..E_MAX {
            y[e] = (e + 1) as f32;
        }
        let n_links = 3 * 3 * NUM_DIRS;
        let waits = scatter_link_waits(&inp, &y, n_links);
        assert_eq!(waits.len(), n_links);
        for (e, &(_, _, dense)) in mesh_edges(3, 3).iter().enumerate() {
            assert_eq!(waits[dense], (e + 1) as f64);
        }
        // Links with no edge slot (none on a full mesh interior edge set)
        // and negative predictions clamp at zero.
        let y_neg = vec![-1.0f32; E_MAX];
        let w_neg = scatter_link_waits(&inp, &y_neg, n_links);
        assert!(w_neg.iter().all(|&v| v == 0.0));
    }
}
