//! API-compatible stand-in for the PJRT runtime, compiled when
//! `--cfg theseus_pjrt` is absent (the default offline build).
//!
//! `load`/`load_default` always fail with a [`GnnUnavailable`] error whose
//! `Display` explains how to enable the real runtime, so every call site
//! (coordinator, figures, benches, examples) takes its documented
//! analytical-fallback path. The prediction methods exist only so code
//! guarded by a successful load still type-checks; they are unreachable in
//! practice because no `GnnModel` value can be constructed.

use std::path::Path;

use crate::arch::CoreConfig;
use crate::compiler::CompiledChunk;
use crate::eval::NocEstimator;

use super::batch::GnnBackend;
use super::features::{self, GnnBatch};
use super::GnnMeta;

/// The GNN runtime was compiled out of this build.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GnnUnavailable;

impl std::fmt::Display for GnnUnavailable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "PJRT runtime not compiled in \
             (build with RUSTFLAGS=\"--cfg theseus_pjrt\" and add the \
             xla/anyhow/log dependencies listed in rust/Cargo.toml)"
        )
    }
}

impl std::error::Error for GnnUnavailable {}

/// Stub model: carries the schema metadata but can never be loaded.
pub struct GnnModel {
    pub meta: GnnMeta,
}

impl GnnModel {
    pub fn load(_path: &Path) -> Result<GnnModel, GnnUnavailable> {
        Err(GnnUnavailable)
    }

    pub fn load_default() -> Result<GnnModel, GnnUnavailable> {
        Err(GnnUnavailable)
    }

    /// Per-chunk sibling loader (see the pjrt twin) — equally unavailable.
    pub fn load_per_chunk_default() -> Result<GnnModel, GnnUnavailable> {
        Err(GnnUnavailable)
    }

    pub fn predict_padded(&self, _inp: &features::GnnInputs) -> Result<Vec<f32>, GnnUnavailable> {
        Err(GnnUnavailable)
    }

    /// Batched sibling of [`GnnModel::predict_padded`] (see the pjrt twin).
    pub fn predict_padded_batch(&self, _batch: &GnnBatch) -> Result<Vec<f32>, GnnUnavailable> {
        Err(GnnUnavailable)
    }

    pub fn predict_link_waits(
        &self,
        _chunk: &CompiledChunk,
        _core: &CoreConfig,
    ) -> Result<Option<Vec<f64>>, GnnUnavailable> {
        Err(GnnUnavailable)
    }
}

impl GnnBackend for GnnModel {
    fn max_batch(&self) -> usize {
        self.meta.batch.max(1)
    }

    /// Unreachable in practice (no stub model can be constructed); exists
    /// so the batched sweep type-checks against either build.
    fn predict_batch(&self, _batch: &GnnBatch) -> Result<Vec<f32>, String> {
        Err(GnnUnavailable.to_string())
    }
}

impl NocEstimator for GnnModel {
    /// Always defers to the analytical model.
    fn link_waits(&self, _chunk: &CompiledChunk, _core: &CoreConfig) -> Option<Vec<f64>> {
        None
    }

    fn name(&self) -> &'static str {
        "gnn"
    }
}
