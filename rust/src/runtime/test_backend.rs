//! Deterministic in-process pseudo-GNN behind the same `GnnModel`-shaped
//! API as [`super::pjrt`]/[`super::stub`].
//!
//! The real PJRT path is compiled out of the default build, so the batched
//! inference subsystem ([`super::batch`]) would otherwise be dead code
//! there. [`TestBackend`] is a closed-form stand-in: two rounds of demand
//! aggregation over the mesh graph followed by a per-edge readout — pure
//! f32 arithmetic over exactly the tensors the real GNN consumes
//! (`node_feat`, `edge_feat`, `src_idx`, `dst_idx`, `edge_mask`). It
//! evaluates one *slot* at a time whether that slot arrives alone or packed
//! inside a batch, so batched and per-chunk predictions are bit-identical
//! by construction and any packing/scatter bug in the batcher surfaces as
//! a mismatch. It also implements [`NocEstimator`], which makes the full
//! GNN-fidelity strategy sweep exercisable end to end without artifacts.

use crate::arch::CoreConfig;
use crate::compiler::routing::NUM_DIRS;
use crate::compiler::CompiledChunk;
use crate::eval::NocEstimator;

use super::batch::GnnBackend;
use super::features::{self, GnnBatch, GnnInputs, E_MAX, F_E, F_N, N_MAX};
use super::GnnMeta;

/// Default slot count mirroring `python -m compile.aot --batch 8`.
pub const TEST_BATCH: usize = 8;

/// Closed-form pseudo-GNN forward pass over one padded slot.
///
/// Round 1 accumulates a per-node demand potential from incident edge
/// utilizations; round 2 smooths it one hop along the graph (a miniature
/// message-passing step); the readout scales each edge's utilization by
/// its endpoints' congestion and the source's injection rate. Outputs are
/// non-negative, finite, and zero on masked slots — the same contract as
/// the trained model.
pub fn pseudo_forward(
    node_feat: &[f32],
    edge_feat: &[f32],
    src_idx: &[i32],
    dst_idx: &[i32],
    edge_mask: &[f32],
) -> Vec<f32> {
    debug_assert_eq!(node_feat.len(), N_MAX * F_N);
    debug_assert_eq!(edge_feat.len(), E_MAX * F_E);
    debug_assert_eq!(src_idx.len(), E_MAX);
    debug_assert_eq!(dst_idx.len(), E_MAX);
    debug_assert_eq!(edge_mask.len(), E_MAX);

    let mut pot = vec![0.0f32; N_MAX];
    for e in 0..E_MAX {
        if edge_mask[e] == 0.0 {
            continue;
        }
        let rho = edge_feat[e * F_E];
        pot[src_idx[e] as usize] += rho;
        pot[dst_idx[e] as usize] += 0.5 * rho;
    }
    let mut pot2 = pot.clone();
    for e in 0..E_MAX {
        if edge_mask[e] == 0.0 {
            continue;
        }
        pot2[dst_idx[e] as usize] += 0.25 * pot[src_idx[e] as usize];
    }
    (0..E_MAX)
        .map(|e| {
            if edge_mask[e] == 0.0 {
                return 0.0;
            }
            let rho = edge_feat[e * F_E];
            let bw = edge_feat[e * F_E + 1];
            let s = src_idx[e] as usize;
            let d = dst_idx[e] as usize;
            let inject = node_feat[s * F_N];
            rho * (1.0 + pot2[s] + pot2[d]) * (1.0 + 0.25 * inject) / (1.0 + bw)
        })
        .collect()
}

/// The in-process pseudo-GNN backend (always constructible — no artifact).
pub struct TestBackend {
    pub meta: GnnMeta,
}

impl TestBackend {
    pub fn new() -> TestBackend {
        TestBackend {
            meta: GnnMeta {
                n_max: N_MAX,
                e_max: E_MAX,
                f_n: F_N,
                f_e: F_E,
                batch: TEST_BATCH,
            },
        }
    }

    /// Mirror of `GnnModel::predict_padded`: one slot, padded output.
    pub fn predict_padded(&self, inp: &GnnInputs) -> Vec<f32> {
        pseudo_forward(
            &inp.node_feat,
            &inp.edge_feat,
            &inp.src_idx,
            &inp.dst_idx,
            &inp.edge_mask,
        )
    }

    /// Mirror of `GnnModel::predict_link_waits`: `None` when the region
    /// exceeds the padded shapes (analytical fallback).
    pub fn predict_link_waits(
        &self,
        chunk: &CompiledChunk,
        core: &CoreConfig,
    ) -> Option<Vec<f64>> {
        let inp = features::build(chunk, core)?;
        let y = self.predict_padded(&inp);
        Some(features::scatter_link_waits(
            &inp,
            &y,
            chunk.region_h * chunk.region_w * NUM_DIRS,
        ))
    }
}

impl Default for TestBackend {
    fn default() -> Self {
        TestBackend::new()
    }
}

impl GnnBackend for TestBackend {
    fn max_batch(&self) -> usize {
        self.meta.batch
    }

    fn predict_batch(&self, batch: &GnnBatch) -> Result<Vec<f32>, String> {
        let mut out = Vec::with_capacity(batch.batch * E_MAX);
        for s in 0..batch.batch {
            out.extend(pseudo_forward(
                &batch.node_feat[s * N_MAX * F_N..(s + 1) * N_MAX * F_N],
                &batch.edge_feat[s * E_MAX * F_E..(s + 1) * E_MAX * F_E],
                &batch.src_idx[s * E_MAX..(s + 1) * E_MAX],
                &batch.dst_idx[s * E_MAX..(s + 1) * E_MAX],
                &batch.edge_mask[s * E_MAX..(s + 1) * E_MAX],
            ));
        }
        Ok(out)
    }
}

impl NocEstimator for TestBackend {
    fn link_waits(&self, chunk: &CompiledChunk, core: &CoreConfig) -> Option<Vec<f64>> {
        self.predict_link_waits(chunk, core)
    }

    fn name(&self) -> &'static str {
        "gnn-test"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pseudo_forward_zero_on_masked_slots() {
        let node = vec![1.0f32; N_MAX * F_N];
        let edge = vec![1.0f32; E_MAX * F_E];
        let src = vec![0i32; E_MAX];
        let dst = vec![1i32; E_MAX];
        let mut mask = vec![0.0f32; E_MAX];
        mask[0] = 1.0;
        let y = pseudo_forward(&node, &edge, &src, &dst, &mask);
        assert!(y[0] > 0.0);
        assert!(y[1..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn pseudo_forward_is_deterministic() {
        let node = vec![0.5f32; N_MAX * F_N];
        let edge = vec![0.25f32; E_MAX * F_E];
        let src = vec![2i32; E_MAX];
        let dst = vec![3i32; E_MAX];
        let mask = vec![1.0f32; E_MAX];
        let a = pseudo_forward(&node, &edge, &src, &dst, &mask);
        let b = pseudo_forward(&node, &edge, &src, &dst, &mask);
        assert_eq!(a, b);
        assert!(a.iter().all(|&v| v.is_finite() && v >= 0.0));
    }
}
