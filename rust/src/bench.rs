//! Bench harness substrate (no `criterion` offline): warmup + repeated
//! timing with median/p10/p90 reporting, plus JSON row output under
//! `artifacts/bench/` so EXPERIMENTS.md numbers are reproducible.

use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats;

/// Timing result for one benchmark case.
#[derive(Debug, Clone)]
pub struct Timing {
    pub name: String,
    pub median_s: f64,
    pub p10_s: f64,
    pub p90_s: f64,
    pub iters: usize,
}

impl Timing {
    pub fn per_sec(&self) -> f64 {
        if self.median_s > 0.0 {
            1.0 / self.median_s
        } else {
            f64::INFINITY
        }
    }
}

/// Time `f` with `warmup` throwaway runs and `iters` measured runs.
pub fn time<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Timing {
        name: name.to_string(),
        median_s: stats::median(&samples),
        p10_s: stats::percentile(&samples, 10.0),
        p90_s: stats::percentile(&samples, 90.0),
        iters,
    }
}

/// Time a single long-running invocation (no repeats).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Persist a bench table under artifacts/bench/<name>.json (best effort —
/// benches must run even in a read-only checkout).
pub fn save_json(name: &str, doc: &Json) {
    let dir = std::path::Path::new("artifacts/bench");
    if std::fs::create_dir_all(dir).is_ok() {
        let path = dir.join(format!("{name}.json"));
        if let Err(e) = std::fs::write(&path, doc.to_pretty()) {
            eprintln!("note: could not save {}: {e}", path.display());
        } else {
            eprintln!("saved {}", path.display());
        }
    }
}

/// Scale knob shared by all figure benches: `THESEUS_BENCH_SCALE=2` doubles
/// sweep sizes / repeats (default 1 keeps `cargo bench` minutes-scale on
/// one core).
pub fn scale() -> usize {
    crate::util::cli::env_usize("THESEUS_BENCH_SCALE", 1).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_measures_something() {
        let t = time("spin", 1, 5, || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert!(t.median_s > 0.0);
        assert!(t.p10_s <= t.median_s && t.median_s <= t.p90_s);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, s) = time_once(|| 42);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }
}
