//! Space Explorer (paper §VII): Gaussian-process surrogates ([`gp`]),
//! Pareto/hypervolume/EHVI machinery ([`pareto`]), the evaluation contract
//! explorers drive ([`traits`]), and the explorers themselves — random
//! search, MOBO, and the paper's multi-fidelity MFMOBO ([`mobo`]).
//!
//! Explorers are fidelity-agnostic: they see design evaluation only
//! through [`DesignEval`], implemented for any (phase × fidelity) pair by
//! [`crate::eval::engine::Engine`].

pub mod gp;
pub mod mobo;
pub mod pareto;
pub mod traits;

pub use mobo::{mfmobo, mobo, random_search, random_search_par, BoConfig, MfConfig};
pub use pareto::{hypervolume, pareto_indices, Objective};
pub use traits::{DesignEval, Trace, TracePoint};
