//! Space Explorer (paper §VII): Gaussian-process surrogates ([`gp`]),
//! Pareto/hypervolume/EHVI machinery ([`pareto`]), and the explorers —
//! random search, MOBO, and the paper's multi-fidelity MFMOBO ([`mobo`]).

pub mod gp;
pub mod mobo;
pub mod pareto;

pub use mobo::{
    mfmobo, mobo, random_search, random_search_par, BoConfig, DesignEval, MfConfig, Trace,
    TracePoint,
};
pub use pareto::{hypervolume, pareto_indices, Objective};
