//! Gaussian-process surrogate (paper §VII: "we utilize the Gaussian
//! Process as the surrogate model").
//!
//! Zero-mean GP with an isotropic RBF kernel over the unit-cube encoding,
//! jittered Cholesky, and a small log-marginal-likelihood grid search for
//! the length-scale. Targets are standardized internally.
//!
//! BO adds one observation per iteration, so [`Gp::add`] extends the
//! Cholesky factor by a rank-1 border in O(n²) instead of refitting from
//! scratch (O(n³) × the length-scale grid). Hyperparameters (length-scale,
//! target standardization) stay frozen during incremental updates; a full
//! refit re-selects them (the numerical-hygiene fallback) after
//! [`GP_REFIT_EVERY`] adds, when the dataset grows ~50% beyond its last
//! fit (so small models — where refits are cheap — refresh quickly), or on
//! any numerical failure of the bordered update.

/// Hard cap on incremental adds between full refits.
pub const GP_REFIT_EVERY: usize = 16;

/// Symmetric positive-definite solve via Cholesky. Matrices are dense
/// row-major `n × n`.
pub fn cholesky(a: &[f64], n: usize) -> Option<Vec<f64>> {
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[i * n + j];
            for k in 0..j {
                sum -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l[i * n + i] = sum.sqrt();
            } else {
                l[i * n + j] = sum / l[j * n + j];
            }
        }
    }
    Some(l)
}

/// Solve L y = b (forward) then L^T x = y (backward).
pub fn chol_solve(l: &[f64], n: usize, b: &[f64]) -> Vec<f64> {
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[i * n + k] * y[k];
        }
        y[i] = s / l[i * n + i];
    }
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in (i + 1)..n {
            s -= l[k * n + i] * x[k];
        }
        x[i] = s / l[i * n + i];
    }
    x
}

fn rbf(x: &[f64], y: &[f64], len: f64) -> f64 {
    let d2: f64 = x.iter().zip(y).map(|(a, b)| (a - b) * (a - b)).sum();
    (-0.5 * d2 / (len * len)).exp()
}

/// Fitted GP over one scalar objective.
pub struct Gp {
    xs: Vec<Vec<f64>>,
    /// Raw (unstandardized) targets — kept for refits.
    ys_raw: Vec<f64>,
    alpha: Vec<f64>,
    l: Vec<f64>,
    n: usize,
    len: f64,
    y_mean: f64,
    y_std: f64,
    noise: f64,
    /// Incremental adds since the last full refit.
    since_refit: usize,
    /// Dataset size at the last full (hyperparameter-selecting) fit.
    fit_n: usize,
}

impl Gp {
    /// Fit with length-scale selected from a small grid by LML.
    pub fn fit(xs: &[Vec<f64>], ys: &[f64]) -> Gp {
        assert_eq!(xs.len(), ys.len());
        assert!(!xs.is_empty());
        let n = xs.len();
        let y_mean = crate::util::stats::mean(ys);
        let y_std = crate::util::stats::std(ys).max(1e-9);
        let yn: Vec<f64> = ys.iter().map(|y| (y - y_mean) / y_std).collect();
        let noise = 1e-4;

        let mut best: Option<(f64, f64, Vec<f64>, Vec<f64>)> = None;
        for &len in &[0.2, 0.4, 0.8, 1.6] {
            let mut kmat = vec![0.0; n * n];
            for i in 0..n {
                for j in 0..n {
                    kmat[i * n + j] = rbf(&xs[i], &xs[j], len);
                }
                kmat[i * n + i] += noise;
            }
            let Some(l) = cholesky(&kmat, n) else { continue };
            let alpha = chol_solve(&l, n, &yn);
            // LML = -0.5 yᵀα − Σ log L_ii − n/2 log 2π
            let fit_term: f64 = yn.iter().zip(&alpha).map(|(y, a)| y * a).sum::<f64>();
            let logdet: f64 = (0..n).map(|i| l[i * n + i].ln()).sum();
            let lml = -0.5 * fit_term - logdet;
            if best.as_ref().map(|b| lml > b.0).unwrap_or(true) {
                best = Some((lml, len, l, alpha));
            }
        }
        let (_, len, l, alpha) = best.expect("at least one length-scale must factor");
        Gp {
            xs: xs.to_vec(),
            ys_raw: ys.to_vec(),
            alpha,
            l,
            n,
            len,
            y_mean,
            y_std,
            noise,
            since_refit: 0,
            fit_n: n,
        }
    }

    /// Fit with *given* hyperparameters (no grid search, no
    /// re-standardization). This is the ground truth that incremental
    /// updates must reproduce; returns `None` if the kernel matrix fails
    /// to factor.
    pub fn fit_frozen(
        xs: &[Vec<f64>],
        ys: &[f64],
        len: f64,
        noise: f64,
        y_mean: f64,
        y_std: f64,
    ) -> Option<Gp> {
        assert_eq!(xs.len(), ys.len());
        assert!(!xs.is_empty());
        let n = xs.len();
        let yn: Vec<f64> = ys.iter().map(|y| (y - y_mean) / y_std).collect();
        let mut kmat = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                kmat[i * n + j] = rbf(&xs[i], &xs[j], len);
            }
            kmat[i * n + i] += noise;
        }
        let l = cholesky(&kmat, n)?;
        let alpha = chol_solve(&l, n, &yn);
        Some(Gp {
            xs: xs.to_vec(),
            ys_raw: ys.to_vec(),
            alpha,
            l,
            n,
            len,
            y_mean,
            y_std,
            noise,
            since_refit: 0,
            fit_n: n,
        })
    }

    /// Number of observations the model currently holds.
    pub fn n_points(&self) -> usize {
        self.n
    }

    /// Add one observation. Extends the Cholesky factor by a rank-1
    /// border in O(n²) with hyperparameters frozen; falls back to a full
    /// [`Gp::fit`] (fresh hyperparameters) on the refresh policy described
    /// in the module docs or when the bordered diagonal loses
    /// positive-definiteness.
    pub fn add(&mut self, x: &[f64], y: f64) {
        self.xs.push(x.to_vec());
        self.ys_raw.push(y);
        let grown = self.n + 1 > self.fit_n + (self.fit_n / 2).max(4);
        let ok = !grown && self.since_refit + 1 < GP_REFIT_EVERY && self.rank1_extend();
        if ok {
            self.since_refit += 1;
        } else {
            let xs = std::mem::take(&mut self.xs);
            let ys = std::mem::take(&mut self.ys_raw);
            *self = Gp::fit(&xs, &ys);
        }
    }

    /// Border the factorization with the newest point in `xs`. Returns
    /// false when the Schur complement is not safely positive.
    fn rank1_extend(&mut self) -> bool {
        let n = self.n;
        let x_new = self.xs[n].clone();
        // k* against the existing points.
        let kvec: Vec<f64> = self.xs[..n].iter().map(|xi| rbf(xi, &x_new, self.len)).collect();
        // Forward solve L · l12 = k*.
        let mut l12 = vec![0.0; n];
        for i in 0..n {
            let mut s = kvec[i];
            for k in 0..i {
                s -= self.l[i * n + k] * l12[k];
            }
            l12[i] = s / self.l[i * n + i];
        }
        // Schur complement: k(x,x) + noise − l12ᵀl12 (RBF ⇒ k(x,x) = 1).
        let d = 1.0 + self.noise - l12.iter().map(|v| v * v).sum::<f64>();
        if !(d > 1e-10) || !d.is_finite() {
            return false;
        }
        let l22 = d.sqrt();

        // Re-lay the factor into its (n+1)-stride matrix.
        let m = n + 1;
        let mut l = vec![0.0; m * m];
        for i in 0..n {
            for j in 0..=i {
                l[i * m + j] = self.l[i * n + j];
            }
        }
        l[n * m..n * m + n].copy_from_slice(&l12);
        l[n * m + n] = l22;
        self.l = l;
        self.n = m;

        // α = K⁻¹ yn via two O(n²) triangular solves, with the original
        // standardization (frozen until the next full refit).
        let yn: Vec<f64> = self
            .ys_raw
            .iter()
            .map(|y| (y - self.y_mean) / self.y_std)
            .collect();
        self.alpha = chol_solve(&self.l, m, &yn);
        true
    }

    /// Posterior mean and standard deviation at `x`.
    pub fn predict(&self, x: &[f64]) -> (f64, f64) {
        let kstar: Vec<f64> = self.xs.iter().map(|xi| rbf(xi, x, self.len)).collect();
        let mean_n: f64 = kstar.iter().zip(&self.alpha).map(|(k, a)| k * a).sum();
        // var = k(x,x) − vᵀv with v = L⁻¹ k*
        let mut v = vec![0.0; self.n];
        for i in 0..self.n {
            let mut s = kstar[i];
            for k in 0..i {
                s -= self.l[i * self.n + k] * v[k];
            }
            v[i] = s / self.l[i * self.n + i];
        }
        let var_n = (1.0 + self.noise - v.iter().map(|x| x * x).sum::<f64>()).max(1e-12);
        (
            mean_n * self.y_std + self.y_mean,
            var_n.sqrt() * self.y_std,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn cholesky_identity() {
        let a = vec![1.0, 0.0, 0.0, 1.0];
        let l = cholesky(&a, 2).unwrap();
        assert_eq!(l, vec![1.0, 0.0, 0.0, 1.0]);
        let x = chol_solve(&l, 2, &[3.0, 4.0]);
        assert_eq!(x, vec![3.0, 4.0]);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(cholesky(&a, 2).is_none());
    }

    #[test]
    fn chol_solve_matches_direct() {
        // A = [[4,2],[2,3]], b = [2, 5] -> x = A⁻¹b = [-0.5, 2.0]
        let a = vec![4.0, 2.0, 2.0, 3.0];
        let l = cholesky(&a, 2).unwrap();
        let x = chol_solve(&l, 2, &[2.0, 5.0]);
        assert!((x[0] + 0.5).abs() < 1e-12);
        assert!((x[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn gp_interpolates_training_points() {
        let xs: Vec<Vec<f64>> = vec![vec![0.0, 0.0], vec![0.5, 0.5], vec![1.0, 0.2]];
        let ys = vec![1.0, 3.0, 2.0];
        let gp = Gp::fit(&xs, &ys);
        for (x, y) in xs.iter().zip(&ys) {
            let (m, s) = gp.predict(x);
            assert!((m - y).abs() < 0.1, "mean {m} vs {y}");
            assert!(s < 0.2, "std {s} at training point");
        }
    }

    #[test]
    fn gp_uncertainty_grows_away_from_data() {
        let xs: Vec<Vec<f64>> = vec![vec![0.0; 4], vec![0.1; 4]];
        let ys = vec![0.0, 0.1];
        let gp = Gp::fit(&xs, &ys);
        let (_, s_near) = gp.predict(&[0.05; 4]);
        let (_, s_far) = gp.predict(&[0.9; 4]);
        assert!(s_far > s_near);
    }

    #[test]
    fn gp_learns_smooth_function() {
        let mut rng = Rng::new(5);
        let xs: Vec<Vec<f64>> = (0..40)
            .map(|_| (0..3).map(|_| rng.f64()).collect())
            .collect();
        let f = |x: &[f64]| (2.0 * x[0] - x[1]).sin() + x[2];
        let ys: Vec<f64> = xs.iter().map(|x| f(x)).collect();
        let gp = Gp::fit(&xs, &ys);
        let mut err = 0.0;
        for _ in 0..50 {
            let x: Vec<f64> = (0..3).map(|_| rng.f64()).collect();
            let (m, _) = gp.predict(&x);
            err += (m - f(&x)).abs();
        }
        assert!(err / 50.0 < 0.25, "avg err {}", err / 50.0);
    }

    #[test]
    fn incremental_add_matches_full_refit() {
        // Rank-1 bordered updates must reproduce a from-scratch Cholesky
        // of the same kernel (same frozen hyperparameters) to 1e-8, over
        // randomized sequences of added points.
        for seed in [3u64, 17, 99] {
            let mut rng = Rng::new(seed);
            let d = 5;
            // Base set large enough that neither the add-count cap nor the
            // growth trigger forces a refit during the adds below.
            let mut xs: Vec<Vec<f64>> = (0..30)
                .map(|_| (0..d).map(|_| rng.f64()).collect())
                .collect();
            let f = |x: &[f64]| (3.0 * x[0]).sin() + x[1] * x[2] - 0.5 * x[3];
            let mut ys: Vec<f64> = xs.iter().map(|x| f(x)).collect();
            let mut gp = Gp::fit(&xs, &ys);
            let (len, noise, y_mean, y_std) = (gp.len, gp.noise, gp.y_mean, gp.y_std);

            for _ in 0..(GP_REFIT_EVERY - 2) {
                let x: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
                let y = f(&x);
                xs.push(x.clone());
                ys.push(y);
                gp.add(&x, y);

                let full = Gp::fit_frozen(&xs, &ys, len, noise, y_mean, y_std)
                    .expect("frozen refit factors");
                for _ in 0..5 {
                    let q: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
                    let (mi, si) = gp.predict(&q);
                    let (mf, sf) = full.predict(&q);
                    assert!((mi - mf).abs() < 1e-8, "mean {mi} vs {mf}");
                    assert!((si - sf).abs() < 1e-8, "std {si} vs {sf}");
                }
            }
            assert_eq!(gp.n_points(), xs.len());
        }
    }

    #[test]
    fn periodic_refit_refreshes_hyperparameters() {
        let mut rng = Rng::new(12);
        let d = 3;
        let xs: Vec<Vec<f64>> = (0..6).map(|_| (0..d).map(|_| rng.f64()).collect()).collect();
        let ys: Vec<f64> = xs.iter().map(|x| x[0] + x[1]).collect();
        let mut gp = Gp::fit(&xs, &ys);
        // Push past the refit cadence; the model must stay numerically
        // sound and keep interpolating its data.
        for i in 0..(2 * GP_REFIT_EVERY + 3) {
            let x: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
            gp.add(&x, x[0] + x[1] + 1e-3 * (i as f64));
        }
        assert_eq!(gp.n_points(), 6 + 2 * GP_REFIT_EVERY + 3);
        let (m, s) = gp.predict(&[0.5; 3]);
        assert!(m.is_finite() && s.is_finite() && s >= 0.0);
        assert!((m - 1.0).abs() < 0.5, "mean {m} should track x0+x1");
    }

    #[test]
    fn duplicate_points_stay_stable() {
        // Adding a near-duplicate drives the Schur complement toward the
        // noise floor; the update must either border safely or refit, and
        // predictions must stay finite.
        let xs: Vec<Vec<f64>> = vec![vec![0.2, 0.8], vec![0.7, 0.1], vec![0.4, 0.4]];
        let ys = vec![1.0, 2.0, 1.5];
        let mut gp = Gp::fit(&xs, &ys);
        gp.add(&[0.2, 0.8], 1.0); // exact duplicate
        gp.add(&[0.2 + 1e-12, 0.8], 1.0); // near-duplicate
        let (m, s) = gp.predict(&[0.2, 0.8]);
        assert!(m.is_finite() && s.is_finite());
        assert!((m - 1.0).abs() < 0.2, "mean {m}");
    }
}
