//! Gaussian-process surrogate (paper §VII: "we utilize the Gaussian
//! Process as the surrogate model").
//!
//! Zero-mean GP with an isotropic RBF kernel over the unit-cube encoding,
//! jittered Cholesky, and a small log-marginal-likelihood grid search for
//! the length-scale. Targets are standardized internally.

/// Symmetric positive-definite solve via Cholesky. Matrices are dense
/// row-major `n × n`.
pub fn cholesky(a: &[f64], n: usize) -> Option<Vec<f64>> {
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[i * n + j];
            for k in 0..j {
                sum -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l[i * n + i] = sum.sqrt();
            } else {
                l[i * n + j] = sum / l[j * n + j];
            }
        }
    }
    Some(l)
}

/// Solve L y = b (forward) then L^T x = y (backward).
pub fn chol_solve(l: &[f64], n: usize, b: &[f64]) -> Vec<f64> {
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[i * n + k] * y[k];
        }
        y[i] = s / l[i * n + i];
    }
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in (i + 1)..n {
            s -= l[k * n + i] * x[k];
        }
        x[i] = s / l[i * n + i];
    }
    x
}

fn rbf(x: &[f64], y: &[f64], len: f64) -> f64 {
    let d2: f64 = x.iter().zip(y).map(|(a, b)| (a - b) * (a - b)).sum();
    (-0.5 * d2 / (len * len)).exp()
}

/// Fitted GP over one scalar objective.
pub struct Gp {
    xs: Vec<Vec<f64>>,
    alpha: Vec<f64>,
    l: Vec<f64>,
    n: usize,
    len: f64,
    y_mean: f64,
    y_std: f64,
    noise: f64,
}

impl Gp {
    /// Fit with length-scale selected from a small grid by LML.
    pub fn fit(xs: &[Vec<f64>], ys: &[f64]) -> Gp {
        assert_eq!(xs.len(), ys.len());
        assert!(!xs.is_empty());
        let n = xs.len();
        let y_mean = crate::util::stats::mean(ys);
        let y_std = crate::util::stats::std(ys).max(1e-9);
        let yn: Vec<f64> = ys.iter().map(|y| (y - y_mean) / y_std).collect();
        let noise = 1e-4;

        let mut best: Option<(f64, f64, Vec<f64>, Vec<f64>)> = None;
        for &len in &[0.2, 0.4, 0.8, 1.6] {
            let mut kmat = vec![0.0; n * n];
            for i in 0..n {
                for j in 0..n {
                    kmat[i * n + j] = rbf(&xs[i], &xs[j], len);
                }
                kmat[i * n + i] += noise;
            }
            let Some(l) = cholesky(&kmat, n) else { continue };
            let alpha = chol_solve(&l, n, &yn);
            // LML = -0.5 yᵀα − Σ log L_ii − n/2 log 2π
            let fit_term: f64 = yn.iter().zip(&alpha).map(|(y, a)| y * a).sum::<f64>();
            let logdet: f64 = (0..n).map(|i| l[i * n + i].ln()).sum();
            let lml = -0.5 * fit_term - logdet;
            if best.as_ref().map(|b| lml > b.0).unwrap_or(true) {
                best = Some((lml, len, l, alpha));
            }
        }
        let (_, len, l, alpha) = best.expect("at least one length-scale must factor");
        Gp {
            xs: xs.to_vec(),
            alpha,
            l,
            n,
            len,
            y_mean,
            y_std,
            noise,
        }
    }

    /// Posterior mean and standard deviation at `x`.
    pub fn predict(&self, x: &[f64]) -> (f64, f64) {
        let kstar: Vec<f64> = self.xs.iter().map(|xi| rbf(xi, x, self.len)).collect();
        let mean_n: f64 = kstar.iter().zip(&self.alpha).map(|(k, a)| k * a).sum();
        // var = k(x,x) − vᵀv with v = L⁻¹ k*
        let mut v = vec![0.0; self.n];
        for i in 0..self.n {
            let mut s = kstar[i];
            for k in 0..i {
                s -= self.l[i * self.n + k] * v[k];
            }
            v[i] = s / self.l[i * self.n + i];
        }
        let var_n = (1.0 + self.noise - v.iter().map(|x| x * x).sum::<f64>()).max(1e-12);
        (
            mean_n * self.y_std + self.y_mean,
            var_n.sqrt() * self.y_std,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn cholesky_identity() {
        let a = vec![1.0, 0.0, 0.0, 1.0];
        let l = cholesky(&a, 2).unwrap();
        assert_eq!(l, vec![1.0, 0.0, 0.0, 1.0]);
        let x = chol_solve(&l, 2, &[3.0, 4.0]);
        assert_eq!(x, vec![3.0, 4.0]);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(cholesky(&a, 2).is_none());
    }

    #[test]
    fn chol_solve_matches_direct() {
        // A = [[4,2],[2,3]], b = [2, 5] -> x = A⁻¹b = [-0.5, 2.0]
        let a = vec![4.0, 2.0, 2.0, 3.0];
        let l = cholesky(&a, 2).unwrap();
        let x = chol_solve(&l, 2, &[2.0, 5.0]);
        assert!((x[0] + 0.5).abs() < 1e-12);
        assert!((x[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn gp_interpolates_training_points() {
        let xs: Vec<Vec<f64>> = vec![vec![0.0, 0.0], vec![0.5, 0.5], vec![1.0, 0.2]];
        let ys = vec![1.0, 3.0, 2.0];
        let gp = Gp::fit(&xs, &ys);
        for (x, y) in xs.iter().zip(&ys) {
            let (m, s) = gp.predict(x);
            assert!((m - y).abs() < 0.1, "mean {m} vs {y}");
            assert!(s < 0.2, "std {s} at training point");
        }
    }

    #[test]
    fn gp_uncertainty_grows_away_from_data() {
        let xs: Vec<Vec<f64>> = vec![vec![0.0; 4], vec![0.1; 4]];
        let ys = vec![0.0, 0.1];
        let gp = Gp::fit(&xs, &ys);
        let (_, s_near) = gp.predict(&[0.05; 4]);
        let (_, s_far) = gp.predict(&[0.9; 4]);
        assert!(s_far > s_near);
    }

    #[test]
    fn gp_learns_smooth_function() {
        let mut rng = Rng::new(5);
        let xs: Vec<Vec<f64>> = (0..40)
            .map(|_| (0..3).map(|_| rng.f64()).collect())
            .collect();
        let f = |x: &[f64]| (2.0 * x[0] - x[1]).sin() + x[2];
        let ys: Vec<f64> = xs.iter().map(|x| f(x)).collect();
        let gp = Gp::fit(&xs, &ys);
        let mut err = 0.0;
        for _ in 0..50 {
            let x: Vec<f64> = (0..3).map(|_| rng.f64()).collect();
            let (m, _) = gp.predict(&x);
            err += (m - f(&x)).abs();
        }
        assert!(err / 50.0 < 0.25, "avg err {}", err / 50.0);
    }
}
