//! Pareto utilities for the 2-objective (throughput ↑, power ↓) problem:
//! non-dominated filtering, 2-D hypervolume, and Monte-Carlo EHVI (paper
//! §VII: EHVI acquisition with reference point (throughput 0, power =
//! peak power threshold)).

use crate::util::rng::Rng;

/// One objective vector: maximize `throughput`, minimize `power_w`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Objective {
    pub throughput: f64,
    pub power_w: f64,
}

impl Objective {
    /// `self` dominates `other` (≥ throughput, ≤ power, strict somewhere).
    pub fn dominates(&self, other: &Objective) -> bool {
        self.throughput >= other.throughput
            && self.power_w <= other.power_w
            && (self.throughput > other.throughput || self.power_w < other.power_w)
    }
}

/// Indices of the non-dominated subset.
pub fn pareto_indices(objs: &[Objective]) -> Vec<usize> {
    (0..objs.len())
        .filter(|&i| {
            !objs
                .iter()
                .enumerate()
                .any(|(j, o)| j != i && o.dominates(&objs[i]))
        })
        .collect()
}

/// 2-D hypervolume dominated w.r.t. reference `(0 throughput, ref_power)`:
/// the area between the staircase and the reference corner. Points with
/// power above `ref_power` or non-positive throughput contribute nothing.
pub fn hypervolume(objs: &[Objective], ref_power: f64) -> f64 {
    let mut front: Vec<Objective> = pareto_indices(objs)
        .into_iter()
        .map(|i| objs[i])
        .filter(|o| o.throughput > 0.0 && o.power_w < ref_power)
        .collect();
    // Sort by power ascending; throughput then descends along the front.
    front.sort_by(|a, b| a.power_w.partial_cmp(&b.power_w).unwrap());
    let mut hv = 0.0;
    let mut prev_t = 0.0;
    // Sweep from the lowest-power point: each point adds a rectangle of
    // width (ref_power - power) and height (throughput - prev best).
    for o in &front {
        if o.throughput > prev_t {
            hv += (ref_power - o.power_w) * (o.throughput - prev_t);
            prev_t = o.throughput;
        }
    }
    hv
}

/// Monte-Carlo Expected Hypervolume Improvement for a candidate with
/// independent Gaussian posteriors on both objectives. Fixed-seed common
/// random numbers keep the acquisition deterministic within an iteration.
pub struct EhviEstimator {
    /// Standard-normal draws shared by all candidates of one iteration.
    draws: Vec<(f64, f64)>,
}

impl EhviEstimator {
    pub fn new(samples: usize, rng: &mut Rng) -> EhviEstimator {
        EhviEstimator {
            draws: (0..samples).map(|_| (rng.normal(), rng.normal())).collect(),
        }
    }

    /// EHVI of a candidate N(μ_t, σ_t) × N(μ_p, σ_p) against the current
    /// front. `base_hv` = hypervolume(front) (precomputed by the caller).
    pub fn ehvi(
        &self,
        front: &[Objective],
        base_hv: f64,
        ref_power: f64,
        mu_t: f64,
        sigma_t: f64,
        mu_p: f64,
        sigma_p: f64,
    ) -> f64 {
        let mut total = 0.0;
        let mut buf: Vec<Objective> = Vec::with_capacity(front.len() + 1);
        for &(z1, z2) in &self.draws {
            let cand = Objective {
                throughput: mu_t + sigma_t * z1,
                power_w: mu_p + sigma_p * z2,
            };
            buf.clear();
            buf.extend_from_slice(front);
            buf.push(cand);
            let hv = hypervolume(&buf, ref_power);
            total += (hv - base_hv).max(0.0);
        }
        total / self.draws.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn o(t: f64, p: f64) -> Objective {
        Objective {
            throughput: t,
            power_w: p,
        }
    }

    #[test]
    fn dominance() {
        assert!(o(2.0, 1.0).dominates(&o(1.0, 2.0)));
        assert!(!o(1.0, 1.0).dominates(&o(1.0, 1.0)));
        assert!(!o(2.0, 2.0).dominates(&o(1.0, 1.0)));
    }

    #[test]
    fn pareto_filtering() {
        let objs = vec![o(1.0, 1.0), o(2.0, 2.0), o(0.5, 0.5), o(1.5, 3.0)];
        let idx = pareto_indices(&objs);
        // (1,1),(2,2),(0.5,0.5) are mutually non-dominated; (1.5,3) is
        // dominated by (2,2).
        assert_eq!(idx, vec![0, 1, 2]);
    }

    #[test]
    fn hypervolume_single_point() {
        // Point (t=2, p=4) vs ref power 10: rect (10-4)*2 = 12.
        assert!((hypervolume(&[o(2.0, 4.0)], 10.0) - 12.0).abs() < 1e-12);
    }

    #[test]
    fn hypervolume_staircase() {
        // Two points: (3, 8) and (1, 2), ref 10.
        // Sweep: (1,2): (10-2)*1 = 8; (3,8): (10-8)*(3-1) = 4. Total 12.
        let hv = hypervolume(&[o(3.0, 8.0), o(1.0, 2.0)], 10.0);
        assert!((hv - 12.0).abs() < 1e-12);
    }

    #[test]
    fn hypervolume_monotone_in_points() {
        let base = vec![o(2.0, 5.0)];
        let more = vec![o(2.0, 5.0), o(1.0, 1.0)];
        assert!(hypervolume(&more, 10.0) > hypervolume(&base, 10.0));
        // Dominated points add nothing.
        let dominated = vec![o(2.0, 5.0), o(1.0, 6.0)];
        assert_eq!(hypervolume(&dominated, 10.0), hypervolume(&base, 10.0));
    }

    #[test]
    fn out_of_reference_ignored() {
        assert_eq!(hypervolume(&[o(2.0, 12.0)], 10.0), 0.0);
        assert_eq!(hypervolume(&[o(-1.0, 5.0)], 10.0), 0.0);
    }

    #[test]
    fn ehvi_prefers_promising_candidates() {
        let mut rng = crate::util::rng::Rng::new(7);
        let est = EhviEstimator::new(128, &mut rng);
        let front = vec![o(2.0, 5.0)];
        let base = hypervolume(&front, 10.0);
        // Candidate clearly beyond the front vs clearly dominated.
        let good = est.ehvi(&front, base, 10.0, 4.0, 0.1, 3.0, 0.1);
        let bad = est.ehvi(&front, base, 10.0, 1.0, 0.1, 8.0, 0.1);
        assert!(good > bad * 10.0, "good={good} bad={bad}");
    }

    #[test]
    fn ehvi_values_uncertainty() {
        let mut rng = crate::util::rng::Rng::new(9);
        let est = EhviEstimator::new(256, &mut rng);
        let front = vec![o(2.0, 5.0)];
        let base = hypervolume(&front, 10.0);
        // Same mean as an existing point: only σ creates improvement mass.
        let certain = est.ehvi(&front, base, 10.0, 2.0, 1e-6, 5.0, 1e-6);
        let uncertain = est.ehvi(&front, base, 10.0, 2.0, 1.0, 5.0, 1.0);
        assert!(uncertain > certain + 1e-9);
    }

    /// Random objective sets with deliberate exact duplicates (dominance
    /// is non-strict on ties, so duplicates are the sharp edge case).
    fn gen_objs(r: &mut crate::util::rng::Rng) -> Vec<Objective> {
        let n = r.range(1, 16);
        let mut v: Vec<Objective> = Vec::with_capacity(n);
        for _ in 0..n {
            if !v.is_empty() && r.bool(0.25) {
                let dup = *r.choose(&v);
                v.push(dup);
            } else {
                v.push(o(r.uniform(0.1, 5.0), r.uniform(0.0, 9.0)));
            }
        }
        v
    }

    #[test]
    fn prop_pareto_indices_exactly_nondominated() {
        // Membership is by the definition itself: i is returned iff no
        // other point dominates it — pinned so a future faster
        // implementation (sort-based sweep) cannot drift on ties.
        crate::util::prop::check("pareto_indices = non-dominated set", gen_objs, |objs| {
            let front: std::collections::BTreeSet<usize> =
                pareto_indices(objs).into_iter().collect();
            for i in 0..objs.len() {
                let dominated = objs
                    .iter()
                    .enumerate()
                    .any(|(j, q)| j != i && q.dominates(&objs[i]));
                if front.contains(&i) == dominated {
                    return Err(format!(
                        "index {i}: dominated={dominated} but in-front={}",
                        front.contains(&i)
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_hv_monotone_under_insertion() {
        crate::util::prop::check(
            "hypervolume monotone under point insertion",
            |r| (gen_objs(r), o(r.uniform(0.1, 5.0), r.uniform(0.0, 9.0))),
            |(objs, extra)| {
                let base = hypervolume(objs, 10.0);
                let mut more = objs.clone();
                more.push(*extra);
                let grown = hypervolume(&more, 10.0);
                if grown + 1e-9 * (1.0 + base) < base {
                    return Err(format!("hv shrank: {base} -> {grown} adding {extra:?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_hv_invariant_under_duplicates() {
        crate::util::prop::check(
            "hypervolume invariant under duplicated points",
            |r| {
                let objs = gen_objs(r);
                let dup = objs[r.below(objs.len())];
                (objs, dup)
            },
            |(objs, dup)| {
                let base = hypervolume(objs, 10.0);
                let mut with_dup = objs.clone();
                with_dup.push(*dup);
                let hv = hypervolume(&with_dup, 10.0);
                // An exact copy contributes the exact same staircase: the
                // sweep's float sequence is unchanged, so equality is exact.
                if hv != base {
                    return Err(format!("duplicate changed hv: {base} -> {hv}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_hv_invariant_under_permutation() {
        crate::util::prop::check(
            "hypervolume invariant under permutation",
            |r| {
                let objs = gen_objs(r);
                let mut shuffled = objs.clone();
                r.shuffle(&mut shuffled);
                (objs, shuffled)
            },
            |(objs, shuffled)| {
                let a = hypervolume(objs, 10.0);
                let b = hypervolume(shuffled, 10.0);
                if (a - b).abs() > 1e-9 * (1.0 + a.abs()) {
                    return Err(format!("permutation changed hv: {a} vs {b}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_ehvi_nonnegative() {
        crate::util::prop::check(
            "ehvi is non-negative",
            |r| {
                (
                    gen_objs(r),
                    r.uniform(-1.0, 6.0),
                    r.uniform(0.0, 2.0),
                    r.uniform(0.0, 12.0),
                    r.uniform(0.0, 2.0),
                )
            },
            |(objs, mu_t, sigma_t, mu_p, sigma_p)| {
                let front: Vec<Objective> =
                    pareto_indices(objs).into_iter().map(|i| objs[i]).collect();
                let base = hypervolume(&front, 10.0);
                let mut rng = crate::util::rng::Rng::new(42);
                let est = EhviEstimator::new(32, &mut rng);
                let v = est.ehvi(&front, base, 10.0, *mu_t, *sigma_t, *mu_p, *sigma_p);
                if v < 0.0 {
                    return Err(format!("negative ehvi {v}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_ehvi_zero_for_fully_dominated_candidate() {
        crate::util::prop::check(
            "ehvi of a strictly dominated certain candidate is exactly 0",
            |r| {
                let objs = gen_objs(r);
                let front: Vec<Objective> =
                    pareto_indices(&objs).into_iter().map(|i| objs[i]).collect();
                let anchor = front[r.below(front.len())];
                // Strictly worse on both axes; σ = 0 puts every MC draw
                // exactly there, so the front is unchanged draw-by-draw.
                let cand = o(
                    anchor.throughput - r.uniform(1e-6, 0.5),
                    anchor.power_w + r.uniform(1e-6, 0.5),
                );
                (front, cand)
            },
            |(front, cand)| {
                let base = hypervolume(front, 10.0);
                let mut rng = crate::util::rng::Rng::new(9);
                let est = EhviEstimator::new(64, &mut rng);
                let v = est.ehvi(front, base, 10.0, cand.throughput, 0.0, cand.power_w, 0.0);
                if v != 0.0 {
                    return Err(format!("dominated candidate got ehvi {v}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_hv_nonnegative_and_bounded() {
        crate::util::prop::check(
            "hypervolume bounded by ref box",
            |r| {
                let n = r.range(1, 10);
                (0..n)
                    .map(|_| o(r.uniform(0.0, 5.0), r.uniform(0.0, 12.0)))
                    .collect::<Vec<_>>()
            },
            |objs| {
                let hv = hypervolume(objs, 10.0);
                let tmax = objs.iter().fold(0.0f64, |m, o| m.max(o.throughput));
                if hv < 0.0 {
                    return Err("negative".into());
                }
                if hv > 10.0 * tmax + 1e-9 {
                    return Err(format!("hv {hv} exceeds box {}", 10.0 * tmax));
                }
                Ok(())
            },
        );
    }
}
