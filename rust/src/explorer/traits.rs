//! Explorer-facing evaluation contract: the [`DesignEval`] trait every
//! evaluation engine implements, and the [`Trace`]/[`TracePoint`] record
//! of an exploration run.
//!
//! This is the seam between Layer 3's Space Explorer and the evaluation
//! engine: explorers see *only* this trait — one call per design point,
//! one `(throughput, power)` objective back, a fidelity label for the
//! trace. The canonical implementation is [`crate::eval::engine::Engine`],
//! which builds the trait for any (phase × fidelity) pair; tests supply
//! synthetic evaluators.

use crate::design_space::{DesignPoint, Validated};
use crate::explorer::pareto::{hypervolume, pareto_indices};

pub use crate::explorer::pareto::Objective;

/// A design evaluation function (one workload phase at one fidelity).
///
/// Deliberately not `Sync`: GNN-backed engines hold a thread-confined
/// PJRT executable. Explorers that fan design-point evaluations over the
/// thread pool require `DesignEval + Sync` explicitly
/// ([`crate::explorer::random_search_par`]) and obtain it from the
/// engine's capability query ([`crate::eval::engine::Engine::to_sync`]).
pub trait DesignEval {
    fn eval(&self, v: &Validated) -> Option<Objective>;

    /// Evaluate a whole candidate slice, one entry per input in order.
    ///
    /// The default maps [`DesignEval::eval`] serially — correct for any
    /// implementation. Engines with a batched dispatch override it to
    /// own the fan-out (one fused strategy sweep with cross-candidate
    /// compile dedup, or a pool fan-out over whole points — see the
    /// dispatch rule in `eval::engine`); the contract either way is
    /// bit-identical results to calling `eval` per point.
    fn eval_batch(&self, vs: &[Validated]) -> Vec<Option<Objective>> {
        vs.iter().map(|v| self.eval(v)).collect()
    }

    /// Fidelity label recorded in the trace ("analytical", "ca", ...).
    fn name(&self) -> &'static str;
}

/// One evaluated point in an exploration trace.
#[derive(Debug, Clone)]
pub struct TracePoint {
    pub point: DesignPoint,
    pub objective: Objective,
    /// Which fidelity produced the objective ("analytical", "gnn", ...).
    pub fidelity: &'static str,
}

/// Full exploration trace with per-evaluation hypervolume history.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub points: Vec<TracePoint>,
    pub hv_history: Vec<f64>,
}

impl Trace {
    pub(crate) fn push(
        &mut self,
        point: DesignPoint,
        objective: Objective,
        fidelity: &'static str,
        ref_power: f64,
    ) {
        self.points.push(TracePoint {
            point,
            objective,
            fidelity,
        });
        let objs: Vec<Objective> = self.points.iter().map(|p| p.objective).collect();
        self.hv_history.push(hypervolume(&objs, ref_power));
    }

    pub fn pareto(&self) -> Vec<&TracePoint> {
        let objs: Vec<Objective> = self.points.iter().map(|p| p.objective).collect();
        pareto_indices(&objs)
            .into_iter()
            .map(|i| &self.points[i])
            .collect()
    }

    pub fn final_hv(&self) -> f64 {
        self.hv_history.last().copied().unwrap_or(0.0)
    }

    /// Evaluations needed to first reach `target` hypervolume.
    pub fn iters_to_hv(&self, target: f64) -> Option<usize> {
        self.hv_history.iter().position(|&h| h >= target).map(|i| i + 1)
    }
}
