//! Explorers (paper §VII): random search, multi-objective Bayesian
//! optimization (MOBO), and the paper's multi-fidelity MFMOBO (Algo. 1).
//!
//! All three share the candidate machinery: validated design points are
//! encoded onto the unit cube, two independent GPs model (throughput,
//! power), and the next point maximizes EHVI over a freshly sampled
//! candidate pool.

use crate::design_space::{self, encode, DesignPoint, Validated, DIMS};
use crate::explorer::gp::Gp;
use crate::explorer::pareto::{hypervolume, pareto_indices, EhviEstimator, Objective};
use crate::explorer::traits::{DesignEval, Trace};
use crate::util::rng::Rng;

/// Explorer configuration.
#[derive(Debug, Clone)]
pub struct BoConfig {
    /// Evaluations after initialization.
    pub iters: usize,
    /// Initial design set size (paper §VIII-C: 6).
    pub init: usize,
    /// Candidate pool per iteration.
    pub pool: usize,
    /// Monte-Carlo EHVI samples.
    pub mc_samples: usize,
    /// Hypervolume reference power (W) — throughput ref is 0 (paper §VII).
    pub ref_power: f64,
    pub seed: u64,
    /// Rejection-sampling budget per candidate.
    pub sample_tries: usize,
}

impl Default for BoConfig {
    fn default() -> Self {
        BoConfig {
            iters: 60,
            init: 6,
            pool: 128,
            mc_samples: 64,
            ref_power: 60_000.0,
            seed: 0,
            sample_tries: 4000,
        }
    }
}

/// Sample a validated point that evaluates successfully; returns the point
/// and objective. Skips points the evaluator rejects (no feasible
/// strategy).
fn sample_evaluated(
    rng: &mut Rng,
    eval: &dyn DesignEval,
    tries: usize,
) -> Option<(Validated, Objective)> {
    for _ in 0..tries {
        if let Some(v) = design_space::sample_valid(rng, 64) {
            if let Some(o) = eval.eval(&v) {
                return Some((v, o));
            }
        }
    }
    None
}

/// Random search baseline (§VIII-C): `init + iters` random evaluations.
pub fn random_search(eval: &dyn DesignEval, cfg: &BoConfig) -> Trace {
    let mut rng = Rng::new(cfg.seed);
    let mut trace = Trace::default();
    for _ in 0..(cfg.init + cfg.iters) {
        if let Some((v, o)) = sample_evaluated(&mut rng, eval, cfg.sample_tries) {
            trace.push(v.point, o, eval.name(), cfg.ref_power);
        }
    }
    trace
}

/// [`random_search`] driven through the engine's batched dispatch
/// ([`DesignEval::eval_batch`]). Each evaluation slot gets an independent
/// forked RNG stream, so the trace is deterministic in `cfg.seed`
/// regardless of worker interleaving (though it differs from the serial
/// stream). Sampling runs round-based: every live slot advances its own
/// stream to its next valid candidate — consuming the stream exactly as
/// the per-slot sample-eval loop would — then one `eval_batch` call
/// evaluates the whole round (the fused cross-candidate sweep for `Sync`
/// training backends). Slots whose candidate the evaluator rejects retry
/// on their remaining tries budget in the next round, so the per-slot
/// results are bit-identical to the former per-slot pool fan-out.
/// Requires a `Sync` evaluator — the GNN-backed one stays on
/// [`random_search`].
pub fn random_search_par(eval: &(dyn DesignEval + Sync), cfg: &BoConfig) -> Trace {
    let mut rng = Rng::new(cfg.seed);
    let n = cfg.init + cfg.iters;
    let mut streams: Vec<Rng> = (0..n).map(|i| rng.fork(i as u64)).collect();
    let mut tries_left: Vec<usize> = vec![cfg.sample_tries; n];
    let mut results: Vec<Option<(Validated, Objective)>> = vec![None; n];
    let mut live: Vec<usize> = (0..n).collect();
    while !live.is_empty() {
        let mut round: Vec<(usize, Validated)> = Vec::new();
        for &slot in &live {
            let stream = &mut streams[slot];
            let mut cand = None;
            while tries_left[slot] > 0 {
                tries_left[slot] -= 1;
                if let Some(v) = design_space::sample_valid(stream, 64) {
                    cand = Some(v);
                    break;
                }
            }
            // Slots that exhaust their budget without a valid candidate
            // drop out here, exactly as `sample_evaluated` returns None.
            if let Some(v) = cand {
                round.push((slot, v));
            }
        }
        if round.is_empty() {
            break;
        }
        let vs: Vec<Validated> = round.iter().map(|(_, v)| v.clone()).collect();
        let objs = eval.eval_batch(&vs);
        let mut next_live = Vec::new();
        for ((slot, v), o) in round.into_iter().zip(objs) {
            match o {
                Some(o) => results[slot] = Some((v, o)),
                None if tries_left[slot] > 0 => next_live.push(slot),
                None => {}
            }
        }
        live = next_live;
    }
    let mut trace = Trace::default();
    for (v, o) in results.into_iter().flatten() {
        trace.push(v.point, o, eval.name(), cfg.ref_power);
    }
    trace
}

/// Surrogate dataset state shared by MOBO/MFMOBO. The GP pair is kept
/// fitted incrementally: `add` extends both models via rank-1 Cholesky
/// borders ([`Gp::add`]) instead of refitting from scratch every
/// iteration.
struct Surrogate {
    xs: Vec<Vec<f64>>,
    t: Vec<f64>,
    p: Vec<f64>,
    objs: Vec<Objective>,
    models: Option<(Gp, Gp)>,
}

impl Surrogate {
    fn new() -> Surrogate {
        Surrogate {
            xs: Vec::new(),
            t: Vec::new(),
            p: Vec::new(),
            objs: Vec::new(),
            models: None,
        }
    }

    fn add(&mut self, point: &DesignPoint, o: Objective) {
        let x = encode(point).to_vec();
        if let Some((gp_t, gp_p)) = &mut self.models {
            gp_t.add(&x, o.throughput);
            gp_p.add(&x, o.power_w);
        }
        self.xs.push(x);
        self.t.push(o.throughput);
        self.p.push(o.power_w);
        self.objs.push(o);
    }

    /// Fit the initial GP pair once enough data exists; afterwards `add`
    /// keeps it current.
    fn ensure_models(&mut self) {
        if self.models.is_none() && self.xs.len() >= 2 {
            self.models = Some((Gp::fit(&self.xs, &self.t), Gp::fit(&self.xs, &self.p)));
        }
    }
}

/// Pick the EHVI-argmax candidate from a random validated pool, using
/// models `(gp_t, gp_p)` and the front from `front_objs`. The pool is
/// sampled serially (the RNG is shared state) and scored through the
/// thread pool — GP posteriors and the common-random-number EHVI draws
/// are read-only, so pooled scoring selects exactly the candidate the
/// serial loop would.
fn propose(
    rng: &mut Rng,
    gp_t: &Gp,
    gp_p: &Gp,
    front_objs: &[Objective],
    cfg: &BoConfig,
) -> Option<Validated> {
    let est = EhviEstimator::new(cfg.mc_samples, rng);
    let front: Vec<Objective> = pareto_indices(front_objs)
        .into_iter()
        .map(|i| front_objs[i])
        .collect();
    let base_hv = hypervolume(&front, cfg.ref_power);
    let mut cands: Vec<Validated> = (0..cfg.pool)
        .filter_map(|_| design_space::sample_valid(rng, 64))
        .collect();
    if cands.is_empty() {
        return None;
    }
    let scores = crate::util::pool::par_map(&cands, |v| {
        let x: [f64; DIMS] = encode(&v.point);
        let (mt, st) = gp_t.predict(&x);
        let (mp, sp) = gp_p.predict(&x);
        est.ehvi(&front, base_hv, cfg.ref_power, mt, st, mp, sp)
    });
    // First-max wins, matching the serial `a > best` scan.
    let mut best = 0usize;
    for i in 1..scores.len() {
        if scores[i] > scores[best] {
            best = i;
        }
    }
    Some(cands.swap_remove(best))
}

/// Vanilla MOBO (§VIII-C comparison): GP + EHVI on a single fidelity.
pub fn mobo(eval: &dyn DesignEval, cfg: &BoConfig) -> Trace {
    let mut rng = Rng::new(cfg.seed);
    let mut trace = Trace::default();
    let mut data = Surrogate::new();

    for _ in 0..cfg.init {
        if let Some((v, o)) = sample_evaluated(&mut rng, eval, cfg.sample_tries) {
            data.add(&v.point, o);
            trace.push(v.point, o, eval.name(), cfg.ref_power);
        }
    }
    for _ in 0..cfg.iters {
        data.ensure_models();
        let proposal = match &data.models {
            Some((gp_t, gp_p)) => propose(&mut rng, gp_t, gp_p, &data.objs, cfg),
            None => design_space::sample_valid(&mut rng, cfg.sample_tries),
        };
        let Some(v) = proposal else { continue };
        // One-element batch: the engine's batched dispatch (and thereby
        // the compile/delta caches warmed by earlier iterations — BO
        // proposals are neighbors) — bit-identical to `eval.eval(&v)`.
        if let Some(o) = eval.eval_batch(std::slice::from_ref(&v)).pop().flatten() {
            data.add(&v.point, o);
            trace.push(v.point, o, eval.name(), cfg.ref_power);
        }
    }
    trace
}

/// MFMOBO (paper Algo. 1). `f0` is the high-fidelity evaluator (GNN), `f1`
/// the low-fidelity one (analytical). `n1` low-fidelity trials build the
/// cheap surrogate M1; the first `kk` high-fidelity picks are still guided
/// by M1; the remaining iterations use the high-fidelity surrogate M0.
pub struct MfConfig {
    pub base: BoConfig,
    /// Low-fidelity trials (paper fig. 8 setup: 100).
    pub n1: usize,
    /// Initial samples for each fidelity (paper: 6).
    pub d0: usize,
    pub d1: usize,
    /// Guided handoff iterations.
    pub k: usize,
}

pub fn mfmobo(f0: &dyn DesignEval, f1: &dyn DesignEval, cfg: &MfConfig) -> Trace {
    let mut rng = Rng::new(cfg.base.seed);
    let mut trace = Trace::default();
    let mut d1 = Surrogate::new(); // low fidelity
    let mut d0 = Surrogate::new(); // high fidelity

    // Init priors D0, D1 (Algo. 1 lines 1-2).
    for _ in 0..cfg.d1 {
        if let Some((v, o)) = sample_evaluated(&mut rng, f1, cfg.base.sample_tries) {
            d1.add(&v.point, o);
            trace.push(v.point, o, f1.name(), cfg.base.ref_power);
        }
    }
    for _ in 0..cfg.d0 {
        if let Some((v, o)) = sample_evaluated(&mut rng, f0, cfg.base.sample_tries) {
            d0.add(&v.point, o);
            trace.push(v.point, o, f0.name(), cfg.base.ref_power);
        }
    }

    let total = cfg.n1 + cfg.base.iters;
    for i in 0..total {
        let low_phase = i < cfg.n1;
        let guided = !low_phase && i < cfg.n1 + cfg.k;
        // Keep BOTH surrogate pairs warm: once fitted, `Surrogate::add`
        // extends them via rank-1 Cholesky borders ([`Gp::add`]), so the
        // fidelity handoff (M1 -> M0 at i = n1 + k) switches to a model
        // that has been updated incrementally all along instead of paying
        // a from-scratch refit of the until-then-inactive pair.
        d1.ensure_models();
        d0.ensure_models();
        // Model selection (Algo. 1 lines 5-8): the guided phase still uses
        // the low-fidelity surrogate M1 while evaluating with f0.
        let model_data = if low_phase || guided { &d1 } else { &d0 };
        let proposal = match &model_data.models {
            Some((gp_t, gp_p)) => {
                // The front for EHVI is computed on the dataset in use.
                propose(&mut rng, gp_t, gp_p, &model_data.objs, &cfg.base)
            }
            None => design_space::sample_valid(&mut rng, cfg.base.sample_tries),
        };
        let Some(v) = proposal else { continue };
        let (eval, dst): (&dyn DesignEval, &mut Surrogate) = if low_phase {
            (f1, &mut d1)
        } else {
            (f0, &mut d0)
        };
        if let Some(o) = eval.eval_batch(std::slice::from_ref(&v)).pop().flatten() {
            dst.add(&v.point, o);
            trace.push(v.point, o, eval.name(), cfg.base.ref_power);
        }
    }
    trace
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthetic evaluator: a smooth function of the encoding, so BO can
    /// actually learn it. Throughput peaks at mid-size cores, power grows
    /// with mac count — creating a real tradeoff.
    struct Synthetic {
        flip: f64,
    }

    impl DesignEval for Synthetic {
        fn eval(&self, v: &Validated) -> Option<Objective> {
            let x = encode(&v.point);
            let t = 100.0 * (1.0 - (x[1] - 0.6).powi(2)) * (0.5 + 0.5 * x[8])
                + self.flip * 3.0 * x[4];
            let p = 20_000.0 * (0.2 + x[1]) * (0.5 + 0.5 * x[9]);
            Some(Objective {
                throughput: t,
                power_w: p,
            })
        }

        fn name(&self) -> &'static str {
            "synthetic"
        }
    }

    fn cfg(iters: usize) -> BoConfig {
        BoConfig {
            iters,
            init: 4,
            pool: 24,
            mc_samples: 24,
            ref_power: 30_000.0,
            seed: 11,
            sample_tries: 2000,
        }
    }

    #[test]
    fn random_search_accumulates_hv() {
        let t = random_search(&Synthetic { flip: 0.0 }, &cfg(8));
        assert!(t.points.len() >= 8);
        // HV history is monotone non-decreasing.
        for w in t.hv_history.windows(2) {
            assert!(w[1] >= w[0] - 1e-9);
        }
        assert!(t.final_hv() > 0.0);
    }

    #[test]
    fn random_search_par_is_deterministic_and_comparable() {
        let e = Synthetic { flip: 0.0 };
        let a = random_search_par(&e, &cfg(10));
        let b = random_search_par(&e, &cfg(10));
        // Deterministic in the seed regardless of worker interleaving.
        assert_eq!(a.points.len(), b.points.len());
        for (x, y) in a.points.iter().zip(&b.points) {
            assert_eq!(x.objective, y.objective);
        }
        assert_eq!(a.hv_history, b.hv_history);
        // Explores about as well as the serial baseline.
        let serial = random_search(&e, &cfg(10));
        assert!(a.points.len() >= 10);
        assert!(a.final_hv() > 0.3 * serial.final_hv());
    }

    #[test]
    fn mobo_beats_or_matches_random_on_synthetic() {
        let e = Synthetic { flip: 0.0 };
        let r = random_search(&e, &cfg(14));
        let m = mobo(&e, &cfg(14));
        // With a learnable objective, MOBO should not be behind by much;
        // typically it is ahead. Allow slack for small-sample noise.
        assert!(
            m.final_hv() >= 0.7 * r.final_hv(),
            "mobo {} vs random {}",
            m.final_hv(),
            r.final_hv()
        );
    }

    #[test]
    fn surrogate_incremental_adds_track_full_refit() {
        // The warm-handoff contract: a Surrogate whose models were fitted
        // early and then extended point-by-point via Gp::add must predict
        // like a from-scratch fit on the full dataset (the state mfmobo's
        // previously-inactive pair lands in at the fidelity handoff).
        let mut rng = crate::util::rng::Rng::new(3);
        let mut warm = Surrogate::new();
        let mut points = Vec::new();
        for _ in 0..12 {
            if let Some(v) = design_space::sample_valid(&mut rng, 200) {
                let x = encode(&v.point);
                let o = Objective {
                    throughput: 10.0 + x[1] * 5.0 + x[8],
                    power_w: 1000.0 * (1.0 + x[2]),
                };
                points.push((v, o));
            }
        }
        assert!(points.len() >= 6, "need enough valid samples");
        for (i, (v, o)) in points.iter().enumerate() {
            warm.add(&v.point, *o);
            if i == 2 {
                warm.ensure_models(); // fit early; later adds are rank-1
            }
        }
        let (gp_t, gp_p) = warm.models.as_ref().unwrap();
        // The handoff property: every point landed in the warm models
        // incrementally — the pair was never stale (n_points counts what
        // the GP actually holds, not what the dataset holds).
        assert_eq!(gp_t.n_points(), points.len());
        assert_eq!(gp_p.n_points(), points.len());
        // And the warm model still *predicts* like a full refit. Exact
        // equality is not expected (Gp::fit re-standardizes and re-selects
        // the lengthscale; Gp::add keeps them frozen between refresh
        // points — see gp.rs, which pins the frozen-hyperparameter path at
        // 1e-8 against fit_frozen), so assert loose tracking only.
        let cold_t = Gp::fit(&warm.xs, &warm.t);
        for (v, _) in points.iter().take(4) {
            let x = encode(&v.point);
            let (mw, _) = gp_t.predict(&x);
            let (mc, _) = cold_t.predict(&x);
            assert!(
                (mw - mc).abs() <= 0.25 * mc.abs().max(1.0),
                "warm {mw} diverged from cold {mc}"
            );
        }
    }

    #[test]
    fn mfmobo_runs_both_fidelities() {
        let hi = Synthetic { flip: 0.0 };
        let lo = Synthetic { flip: 1.0 }; // slightly-off approximation
        let mf = MfConfig {
            base: cfg(6),
            n1: 6,
            d0: 2,
            d1: 2,
            k: 2,
        };
        let t = mfmobo(&hi, &lo, &mf);
        let lows = t.points.iter().filter(|p| p.fidelity == "synthetic").count();
        assert!(lows > 0);
        assert!(t.points.len() >= 10);
        assert!(t.final_hv() > 0.0);
    }

    #[test]
    fn pareto_of_trace_nondominated() {
        let t = random_search(&Synthetic { flip: 0.0 }, &cfg(10));
        let front = t.pareto();
        for a in &front {
            for b in &front {
                assert!(!a.objective.dominates(&b.objective) || std::ptr::eq(a, b) || a.objective == b.objective);
            }
        }
    }

    #[test]
    fn iters_to_hv_semantics() {
        let t = random_search(&Synthetic { flip: 0.0 }, &cfg(8));
        let target = t.final_hv() * 0.5;
        let i = t.iters_to_hv(target).unwrap();
        assert!(i <= t.hv_history.len());
        assert!(t.hv_history[i - 1] >= target);
        assert!(t.iters_to_hv(t.final_hv() * 10.0).is_none());
    }
}
