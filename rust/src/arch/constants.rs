//! Physical and technology constants, all at the paper's 14 nm reference
//! node (§VIII-A: "All the area and power data are scaled to 14nm according
//! to the scaling factors in [68]"). Where the paper states a number we use
//! it verbatim; remaining per-action energies are drawn from the sources the
//! paper cites (Aladdin, Orion 3.0, GRS, CACTI-class SRAM models) and only
//! their *relative* magnitudes matter for DSE ordering.

/// Core clock (paper §VIII-A).
pub const CLOCK_HZ: f64 = 1.0e9;

/// Reticle (lithography field) limit: 26 mm × 33 mm = 858 mm² (paper §I).
pub const RETICLE_W_MM: f64 = 26.0;
pub const RETICLE_H_MM: f64 = 33.0;
pub const RETICLE_AREA_MM2: f64 = RETICLE_W_MM * RETICLE_H_MM;

/// Usable square on a 12-inch wafer: 215 mm × 215 mm (paper §VIII-A).
pub const WAFER_EDGE_MM: f64 = 215.0;
pub const WAFER_AREA_MM2: f64 = WAFER_EDGE_MM * WAFER_EDGE_MM;

/// Wafer power ceiling: 15 kW (paper §VIII-A, citing [49]).
pub const WAFER_POWER_LIMIT_W: f64 = 15_000.0;

/// Yield target and Murphy-model defect density (paper §VIII-A).
pub const YIELD_TARGET: f64 = 0.9;
pub const DEFECT_DENSITY_PER_CM2: f64 = 0.1;

/// Screw-hole stress model (paper §V-C / §VIII-A): linear yield loss, 10 %
/// at the hole center, fading to zero at 1 mm.
pub const STRESS_LOSS: f64 = 0.1;
pub const STRESS_MAX_DIST_MM: f64 = 1.0;

/// TSV stress parameters mirror the screw-hole model (paper §V-C).
pub const TSV_LOSS: f64 = 0.1;
pub const TSV_MAX_DIST_MM: f64 = 1.0;

/// TSV geometry (paper §VIII-A, citing [57]): 5 µm via, 15 µm pitch,
/// 1 Gbps of stacked-DRAM bandwidth per TSV. The §V-E stress cap applies
/// to the *hole* (via) area; the pitch-sized cell is the floorplan
/// footprint that displaces compute.
pub const TSV_VIA_UM: f64 = 5.0;
pub const TSV_PITCH_UM: f64 = 15.0;
pub const TSV_BW_BITS_PER_SEC: f64 = 1.0e9;

/// Stress constraint: TSV hole field ≤ 1.5 % of reticle area (paper §V-E).
pub const TSV_AREA_RATIO_MAX: f64 = 0.015;

/// Inter-reticle PHY area overhead (paper §VIII-A):
/// RDL/SerDes (InFO-SoW): 3900 µm²/Gbps; offset exposure: 1300 µm²/Gbps.
pub const PHY_AREA_UM2_PER_GBPS_RDL: f64 = 3900.0;
pub const PHY_AREA_UM2_PER_GBPS_STITCH: f64 = 1300.0;

/// Inter-reticle signalling energy (pJ/bit). Offset exposure is nearly
/// on-die wiring (Cerebras quotes ~0.1 pJ/bit-class fabric); RDL SerDes is
/// GRS-class (~1 pJ/bit, Turner et al. [67]).
pub const PHY_ENERGY_PJ_PER_BIT_STITCH: f64 = 0.15;
pub const PHY_ENERGY_PJ_PER_BIT_RDL: f64 = 1.0;

/// Wafer-edge interfaces (Table I).
pub const INTER_WAFER_BW_PER_NIC: f64 = 100.0e9; // bytes/s per network interface
pub const OFF_CHIP_BW_PER_CTRL: f64 = 160.0e9; // bytes/s per memory controller

/// Per-hop latency of an inter-wafer link (serialization + switch/transit,
/// NIC/SerDes-class — not paper-stated; used by [`crate::arch::interwafer`]).
pub const INTER_WAFER_LINK_LATENCY_S: f64 = 1.0e-6;

/// DRAM access energy (pJ/bit): stacked TSV DRAM ≈ HBM-class, off-chip
/// DDR/edge access pricier (CACTI-3DD-class numbers).
pub const DRAM_ENERGY_PJ_PER_BIT_STACKED: f64 = 4.0;
pub const DRAM_ENERGY_PJ_PER_BIT_OFFCHIP: f64 = 15.0;

/// MAC datapath at 14 nm, bf16 multiply-accumulate.
/// Energy ≈ 0.5 pJ/op (Aladdin/Horowitz-class), area ≈ 600 µm² incl. local
/// pipeline registers and control amortization.
pub const MAC_ENERGY_PJ: f64 = 0.5;
pub const MAC_AREA_UM2: f64 = 600.0;

/// SRAM at 14 nm (ssg, 0.9 V — paper §VIII-A): effective macro density
/// ≈ 1.2 mm²/MB including peripheral overhead; dynamic ≈ 0.015 pJ/bit
/// access; leakage ≈ 1.5 mW/MB.
pub const SRAM_MM2_PER_MB: f64 = 1.2;
pub const SRAM_ENERGY_PJ_PER_BIT: f64 = 0.015;
pub const SRAM_LEAK_W_PER_MB: f64 = 1.5e-3;

/// NoC router (Orion 3.0-class, 14 nm, 1 V, 8 VCs × 4 buffers — §VIII-A):
/// per-flit-bit energy through a router ≈ 0.04 pJ plus 0.02 pJ/bit/mm of
/// link traversal; router area scales with flit width × VC buffering.
pub const NOC_ROUTER_ENERGY_PJ_PER_BIT: f64 = 0.04;
pub const NOC_LINK_ENERGY_PJ_PER_BIT_MM: f64 = 0.02;
pub const NOC_VCS: usize = 8;
pub const NOC_BUFS_PER_VC: usize = 4;
/// Router buffer+crossbar area per bit of flit width per VC-buffer entry.
pub const NOC_AREA_UM2_PER_BIT_ENTRY: f64 = 1.1;

/// RISC-V control core + misc per-core overhead (Chisel/Purlin-class
/// scalar core at 14 nm): area and static power floor of every core.
pub const CTRL_AREA_UM2: f64 = 0.05e6; // 0.05 mm²
pub const CTRL_STATIC_W: f64 = 5e-3;

/// Static (leakage) power as a fraction of peak dynamic for logic blocks.
pub const LOGIC_LEAK_FRAC: f64 = 0.08;

/// Stacked-DRAM background power per GB (refresh + periphery).
pub const DRAM_STATIC_W_PER_GB: f64 = 0.125;

/// Bytes per element for activations/weights (bf16 everywhere, matching
/// Megatron-LM mixed-precision training the paper benchmarks against).
pub const BYTES_PER_ELEM: f64 = 2.0;

/// FLOPs per MAC.
pub const FLOPS_PER_MAC: f64 = 2.0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_stated_values() {
        // Constants the paper states explicitly must not drift.
        assert_eq!(RETICLE_AREA_MM2, 858.0);
        assert_eq!(WAFER_EDGE_MM, 215.0);
        assert_eq!(WAFER_POWER_LIMIT_W, 15_000.0);
        assert_eq!(DEFECT_DENSITY_PER_CM2, 0.1);
        assert_eq!(STRESS_LOSS, 0.1);
        assert_eq!(STRESS_MAX_DIST_MM, 1.0);
        assert_eq!(TSV_PITCH_UM, 15.0);
        assert_eq!(PHY_AREA_UM2_PER_GBPS_RDL, 3900.0);
        assert_eq!(PHY_AREA_UM2_PER_GBPS_STITCH, 1300.0);
        assert_eq!(TSV_AREA_RATIO_MAX, 0.015);
        assert_eq!(INTER_WAFER_BW_PER_NIC, 100.0e9);
        assert_eq!(OFF_CHIP_BW_PER_CTRL, 160.0e9);
    }

    #[test]
    fn sane_orderings() {
        // Relative magnitudes that the DSE conclusions depend on.
        assert!(PHY_AREA_UM2_PER_GBPS_RDL > PHY_AREA_UM2_PER_GBPS_STITCH);
        assert!(PHY_ENERGY_PJ_PER_BIT_RDL > PHY_ENERGY_PJ_PER_BIT_STITCH);
        assert!(DRAM_ENERGY_PJ_PER_BIT_OFFCHIP > DRAM_ENERGY_PJ_PER_BIT_STACKED);
        assert!(DRAM_ENERGY_PJ_PER_BIT_STACKED > SRAM_ENERGY_PJ_PER_BIT);
        assert!(NOC_ROUTER_ENERGY_PJ_PER_BIT < PHY_ENERGY_PJ_PER_BIT_RDL);
    }
}
