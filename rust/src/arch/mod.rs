//! WSC architecture description (paper §V-A, Fig. 3, Table I).
//!
//! Pure *description* types — deriving area/power/yield from them is the
//! job of [`crate::components`] and [`crate::yield_model`]. A design point
//! in the DSE space is a [`WscConfig`] (plus heterogeneity options in
//! [`hetero`]).

pub mod constants;
pub mod hetero;
pub mod interwafer;

pub use hetero::{HeteroConfig, HeteroGranularity};
pub use interwafer::{InterWaferNet, InterWaferTopology};

/// Intra-core dataflow of the fixed-datapath MAC array (Table I).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dataflow {
    /// Weight-stationary.
    WS,
    /// Input-stationary.
    IS,
    /// Output-stationary.
    OS,
}

impl Dataflow {
    pub const ALL: [Dataflow; 3] = [Dataflow::WS, Dataflow::IS, Dataflow::OS];

    pub fn name(&self) -> &'static str {
        match self {
            Dataflow::WS => "WS",
            Dataflow::IS => "IS",
            Dataflow::OS => "OS",
        }
    }

    pub fn parse(s: &str) -> Option<Dataflow> {
        match s {
            "WS" => Some(Dataflow::WS),
            "IS" => Some(Dataflow::IS),
            "OS" => Some(Dataflow::OS),
            _ => None,
        }
    }
}

/// Wafer-level integration technology (paper §II-B, §V-D, Table I).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum IntegrationStyle {
    /// Cerebras-style offset exposure / die stitching: cheap on-wafer links,
    /// but no known-good-die screening — wafer yield multiplies reticle
    /// yields.
    DieStitching,
    /// Tesla Dojo-style InFO-SoW with RDL interconnect: pricier links, but
    /// KGD screening means wafer yield equals (tested) reticle yield.
    InfoSoW,
}

impl IntegrationStyle {
    pub const ALL: [IntegrationStyle; 2] =
        [IntegrationStyle::DieStitching, IntegrationStyle::InfoSoW];

    pub fn name(&self) -> &'static str {
        match self {
            IntegrationStyle::DieStitching => "DieStitching",
            IntegrationStyle::InfoSoW => "InfoSoW",
        }
    }

    pub fn supports_kgd(&self) -> bool {
        matches!(self, IntegrationStyle::InfoSoW)
    }
}

/// Reticle memory system: traditional off-chip DRAM at the wafer edge, or
/// 3D-stacked DRAM over TSVs on each reticle (paper §V-A, Fig. 13).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MemoryKind {
    /// Off-chip DRAM through wafer-edge memory controllers.
    OffChip,
    /// Stacked DRAM: `bw_tbps_per_100mm2` ∈ 0.25–4 TB/s per 100 mm² of
    /// reticle area, `capacity_gb` ∈ 8–40 GB per reticle. Capacity and
    /// bandwidth trade off (linear fit over existing parts, §VIII-A).
    Stacking {
        bw_tbps_per_100mm2: f64,
        capacity_gb: f64,
    },
}

impl MemoryKind {
    pub fn is_stacking(&self) -> bool {
        matches!(self, MemoryKind::Stacking { .. })
    }
}

/// Core-level parameters (Table I, "Core" column).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CoreConfig {
    pub dataflow: Dataflow,
    /// Number of MAC units, 8–4096.
    pub mac_num: usize,
    /// On-core SRAM capacity in KB, 32–2048.
    pub buffer_kb: usize,
    /// SRAM bandwidth in bits/cycle, 32–4096.
    pub buffer_bw_bits: usize,
    /// NoC link bandwidth in bits/cycle, 32–4096.
    pub noc_bw_bits: usize,
}

impl CoreConfig {
    /// Peak tensor throughput in FLOP/s at [`constants::CLOCK_HZ`]
    /// (2 FLOPs per MAC per cycle).
    pub fn peak_flops(&self) -> f64 {
        2.0 * self.mac_num as f64 * constants::CLOCK_HZ
    }

    /// MAC array edge lengths used by the dataflow model: the array is
    /// organized as rows×cols with rows ≈ cols (square-ish systolic array).
    pub fn array_dims(&self) -> (usize, usize) {
        let mut rows = (self.mac_num as f64).sqrt() as usize;
        while rows > 1 && self.mac_num % rows != 0 {
            rows -= 1;
        }
        (rows.max(1), self.mac_num / rows.max(1))
    }

    /// NoC link bandwidth in bytes/s.
    pub fn noc_bytes_per_sec(&self) -> f64 {
        self.noc_bw_bits as f64 / 8.0 * constants::CLOCK_HZ
    }

    /// SRAM bandwidth in bytes/s.
    pub fn sram_bytes_per_sec(&self) -> f64 {
        self.buffer_bw_bits as f64 / 8.0 * constants::CLOCK_HZ
    }
}

/// Reticle-level parameters (Table I, "Reticle" column).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReticleConfig {
    pub core: CoreConfig,
    /// Core array height (rows of cores).
    pub array_h: usize,
    /// Core array width (cols of cores).
    pub array_w: usize,
    /// Inter-reticle bandwidth as a multiple of the reticle's NoC bisection
    /// bandwidth, 0.2–2.0 (Table I).
    pub inter_reticle_bw_ratio: f64,
    pub memory: MemoryKind,
}

impl ReticleConfig {
    pub fn num_cores(&self) -> usize {
        self.array_h * self.array_w
    }

    /// Peak FLOP/s of all (operational) cores in the reticle.
    pub fn peak_flops(&self) -> f64 {
        self.num_cores() as f64 * self.core.peak_flops()
    }

    /// NoC bisection bandwidth (bytes/s): cutting the core mesh down the
    /// middle crosses `array_h` links (for a vertical cut of a h×w mesh).
    pub fn bisection_bytes_per_sec(&self) -> f64 {
        self.array_h.min(self.array_w) as f64 * self.core.noc_bytes_per_sec()
    }

    /// Total inter-reticle bandwidth per edge of the reticle (bytes/s).
    /// The paper expresses it as a ratio of bisection bandwidth; we treat
    /// the resulting number as the bandwidth available across each reticle
    /// boundary (N/S/E/W all symmetric).
    pub fn inter_reticle_bytes_per_sec(&self) -> f64 {
        self.inter_reticle_bw_ratio * self.bisection_bytes_per_sec()
    }

    /// Stacked-DRAM bandwidth for this reticle in bytes/s (0 if off-chip),
    /// proportional to reticle *area*; needs the reticle area in mm² from
    /// the component estimator.
    pub fn stacking_bytes_per_sec(&self, reticle_area_mm2: f64) -> f64 {
        match self.memory {
            MemoryKind::OffChip => 0.0,
            MemoryKind::Stacking {
                bw_tbps_per_100mm2, ..
            } => bw_tbps_per_100mm2 * 1e12 * (reticle_area_mm2 / 100.0),
        }
    }

    pub fn stacking_capacity_bytes(&self) -> f64 {
        match self.memory {
            MemoryKind::OffChip => 0.0,
            MemoryKind::Stacking { capacity_gb, .. } => capacity_gb * 1e9,
        }
    }
}

/// Wafer-level parameters (Table I, "Wafer" column).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WscConfig {
    pub reticle: ReticleConfig,
    /// Reticle array height on the wafer.
    pub reticle_h: usize,
    /// Reticle array width on the wafer.
    pub reticle_w: usize,
    pub integration: IntegrationStyle,
    /// Memory controllers around the wafer edge (off-chip DRAM access),
    /// each providing [`constants::OFF_CHIP_BW_PER_CTRL`].
    pub mem_ctrl_count: usize,
    /// Network interfaces for WSC-to-WSC scale-out, each providing
    /// [`constants::INTER_WAFER_BW_PER_NIC`].
    pub nic_count: usize,
}

impl WscConfig {
    pub fn num_reticles(&self) -> usize {
        self.reticle_h * self.reticle_w
    }

    pub fn num_cores(&self) -> usize {
        self.num_reticles() * self.reticle.num_cores()
    }

    /// Peak FLOP/s of the whole wafer (before redundancy derating).
    pub fn peak_flops(&self) -> f64 {
        self.num_reticles() as f64 * self.reticle.peak_flops()
    }

    /// Total on-wafer SRAM in bytes.
    pub fn total_sram_bytes(&self) -> f64 {
        self.num_cores() as f64 * self.reticle.core.buffer_kb as f64 * 1024.0
    }

    /// Total stacked DRAM capacity (bytes), 0 for off-chip designs.
    pub fn total_stacking_bytes(&self) -> f64 {
        self.num_reticles() as f64 * self.reticle.stacking_capacity_bytes()
    }

    /// Aggregate off-chip DRAM bandwidth (bytes/s).
    pub fn off_chip_bytes_per_sec(&self) -> f64 {
        self.mem_ctrl_count as f64 * constants::OFF_CHIP_BW_PER_CTRL
    }

    /// Aggregate inter-wafer bandwidth (bytes/s).
    pub fn inter_wafer_bytes_per_sec(&self) -> f64 {
        self.nic_count as f64 * constants::INTER_WAFER_BW_PER_NIC
    }

    /// One-line human summary, used by the CLI and bench output.
    pub fn summary(&self) -> String {
        let mem = match self.reticle.memory {
            MemoryKind::OffChip => "offchip".to_string(),
            MemoryKind::Stacking {
                bw_tbps_per_100mm2,
                capacity_gb,
            } => format!("stack({bw_tbps_per_100mm2:.2}TB/s/100mm2,{capacity_gb:.0}GB)"),
        };
        format!(
            "{}x{} reticles of {}x{} cores [{} mac={} sram={}KB sbw={} nbw={}] irbw={:.2}xBi {} {}",
            self.reticle_h,
            self.reticle_w,
            self.reticle.array_h,
            self.reticle.array_w,
            self.reticle.core.dataflow.name(),
            self.reticle.core.mac_num,
            self.reticle.core.buffer_kb,
            self.reticle.core.buffer_bw_bits,
            self.reticle.core.noc_bw_bits,
            self.reticle.inter_reticle_bw_ratio,
            mem,
            self.integration.name(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub fn test_core() -> CoreConfig {
        CoreConfig {
            dataflow: Dataflow::WS,
            mac_num: 512,
            buffer_kb: 128,
            buffer_bw_bits: 1024,
            noc_bw_bits: 512,
        }
    }

    #[test]
    fn peak_flops_core() {
        let c = test_core();
        // 512 MACs * 2 flops * 1 GHz = 1.024 TFLOPS
        assert!((c.peak_flops() - 1.024e12).abs() < 1.0);
    }

    #[test]
    fn array_dims_factor() {
        for mac in [8usize, 16, 64, 512, 1000, 4096] {
            let c = CoreConfig { mac_num: mac, ..test_core() };
            let (r, k) = c.array_dims();
            assert_eq!(r * k, mac, "mac={mac}");
            assert!(r <= k);
        }
    }

    #[test]
    fn reticle_aggregates() {
        let r = ReticleConfig {
            core: test_core(),
            array_h: 12,
            array_w: 12,
            inter_reticle_bw_ratio: 1.0,
            memory: MemoryKind::Stacking {
                bw_tbps_per_100mm2: 1.0,
                capacity_gb: 16.0,
            },
        };
        assert_eq!(r.num_cores(), 144);
        assert!((r.peak_flops() - 144.0 * 1.024e12).abs() < 1e6);
        // bisection: 12 links * 512 bits / 8 * 1e9
        assert!((r.bisection_bytes_per_sec() - 12.0 * 64.0 * 1e9).abs() < 1.0);
        assert!((r.stacking_bytes_per_sec(200.0) - 2e12).abs() < 1.0);
        assert_eq!(r.stacking_capacity_bytes(), 16e9);
    }

    #[test]
    fn wafer_aggregates() {
        let w = WscConfig {
            reticle: ReticleConfig {
                core: test_core(),
                array_h: 10,
                array_w: 10,
                inter_reticle_bw_ratio: 0.5,
                memory: MemoryKind::OffChip,
            },
            reticle_h: 8,
            reticle_w: 7,
            integration: IntegrationStyle::DieStitching,
            mem_ctrl_count: 16,
            nic_count: 8,
        };
        assert_eq!(w.num_reticles(), 56);
        assert_eq!(w.num_cores(), 5600);
        assert_eq!(w.total_stacking_bytes(), 0.0);
        assert!((w.off_chip_bytes_per_sec() - 16.0 * 160e9).abs() < 1.0);
        assert!((w.inter_wafer_bytes_per_sec() - 8.0 * 100e9).abs() < 1.0);
        assert!(w.summary().contains("8x7 reticles"));
    }

    #[test]
    fn dataflow_roundtrip() {
        for d in Dataflow::ALL {
            assert_eq!(Dataflow::parse(d.name()), Some(d));
        }
        assert_eq!(Dataflow::parse("XX"), None);
    }
}
