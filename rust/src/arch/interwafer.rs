//! Inter-wafer network model (§VIII-A scale-out): topology, links and
//! closed-form collective costs for traffic that leaves the wafer.
//!
//! The on-wafer fabric (NoC + inter-reticle links) is modeled in
//! [`crate::arch`]'s reticle/wafer configs; this module prices the hop
//! *between* wafers. A multi-wafer system is `n_wafers` wafers, each with
//! `links_per_wafer` external links of `link_bandwidth` bytes/s, joined by
//! one of three topologies:
//!
//! - **ring** — each wafer talks to two neighbors; injection is limited to
//!   2 links, average point-to-point distance ≈ n/4 hops.
//! - **2d-mesh** (`mesh2d`) — wafers tile a near-square grid; up to 4 links
//!   inject concurrently, average distance ≈ ⅔·√n hops (Manhattan).
//! - **switched** — an external switch fabric; all links inject and any
//!   wafer is 2 hops away (wafer→switch→wafer).
//!
//! Collective cost formulas (bytes `B`, effective injection bandwidth `b`,
//! per-hop latency `l`, participants `p`, wafers `n`):
//!
//! - point-to-point: `B/b + hops·l`
//! - ring all-reduce over `p` ranks: `2(p−1)/p · B/b + 2(p−1)·l`
//! - tree all-reduce over `g` groups: `2⌈log₂ g⌉ · (B/b + l)`
//! - hierarchical: reduce the ≤`⌈p/n⌉` co-resident ranks over the on-wafer
//!   fabric first (`2B/b_on`), then ring over the `n` wafers
//!
//! [`InterWaferNet::allreduce_s`] takes the best (minimum) of the three
//! schedules — the runtime would pick the cheapest algorithm per tensor.
//!
//! Mapping onto [`crate::workload::parallel::ParallelStrategy`] dimensions
//! (how `eval/chunk.rs` uses this): **TP** shards are placed within a
//! wafer by the partitioner, so TP all-reduce stays on the wafer
//! bisection; **DP** replicas span wafers once `dp > 1` on a multi-wafer
//! system, so the per-step gradient all-reduce is priced here (the raw
//! sharded weight bytes go in — the collective applies its own `2(p−1)/p`
//! style volume factor); **PP** stage boundaries cross wafers for a
//! `(n−1)/(pp−1)` fraction of stages, priced as point-to-point transfers.
//!
//! The default network ([`InterWaferNet::default_for`]) is a switched
//! fabric with one link per NIC at the paper-stated 100 GB/s per NIC, so
//! its aggregate equals the flat `WscConfig::inter_wafer_bytes_per_sec()`
//! this layer replaced — single-number continuity with the pre-topology
//! model. Everything here is consulted only when `n_wafers > 1`;
//! single-wafer evaluations never touch this module.

use crate::arch::constants::{INTER_WAFER_BW_PER_NIC, INTER_WAFER_LINK_LATENCY_S};

/// How the wafers are joined. Registry enum: `ALL` / `name` / `parse`
/// keep CLI flags, scenario JSON and errors in sync.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InterWaferTopology {
    Ring,
    Mesh2d,
    Switched,
}

impl InterWaferTopology {
    pub const ALL: [InterWaferTopology; 3] = [
        InterWaferTopology::Ring,
        InterWaferTopology::Mesh2d,
        InterWaferTopology::Switched,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            InterWaferTopology::Ring => "ring",
            InterWaferTopology::Mesh2d => "mesh2d",
            InterWaferTopology::Switched => "switched",
        }
    }

    /// Accepts the canonical names plus the paper's "2d-mesh" spelling.
    pub fn parse(s: &str) -> Option<InterWaferTopology> {
        match s {
            "ring" => Some(InterWaferTopology::Ring),
            "mesh2d" | "2d-mesh" => Some(InterWaferTopology::Mesh2d),
            "switched" => Some(InterWaferTopology::Switched),
            _ => None,
        }
    }
}

/// The inter-wafer network of a multi-wafer system. Carried on
/// [`crate::design_space::DesignPoint`] so the scale-out axes are
/// searched alongside the on-wafer ones.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InterWaferNet {
    pub topology: InterWaferTopology,
    /// External links per wafer (physically: NIC/SerDes bundles).
    pub links_per_wafer: usize,
    /// Bytes per second per link, one direction.
    pub link_bandwidth: f64,
    /// Per-hop latency in seconds (serialization + switch/transit).
    pub link_latency: f64,
}

impl InterWaferNet {
    /// The continuity default: a switched fabric with one link per NIC at
    /// the paper-stated per-NIC bandwidth, so the aggregate equals the
    /// flat `inter_wafer_bytes_per_sec()` scalar this model replaced.
    pub fn default_for(nic_count: usize) -> InterWaferNet {
        InterWaferNet {
            topology: InterWaferTopology::Switched,
            links_per_wafer: nic_count,
            link_bandwidth: INTER_WAFER_BW_PER_NIC,
            link_latency: INTER_WAFER_LINK_LATENCY_S,
        }
    }

    /// Sum of all link bandwidth out of one wafer.
    pub fn aggregate_bytes_per_sec(&self) -> f64 {
        self.links_per_wafer.max(1) as f64 * self.link_bandwidth
    }

    /// Injection bandwidth a wafer can actually use concurrently: the
    /// topology caps how many links carry a collective at once (ring: 2
    /// neighbors, mesh: 4, switched: all).
    pub fn effective_bytes_per_sec(&self) -> f64 {
        let links = self.links_per_wafer.max(1);
        let usable = match self.topology {
            InterWaferTopology::Ring => links.min(2),
            InterWaferTopology::Mesh2d => links.min(4),
            InterWaferTopology::Switched => links,
        };
        usable as f64 * self.link_bandwidth
    }

    /// Average point-to-point hop count between two wafers.
    fn avg_hops(&self, n_wafers: usize) -> f64 {
        let n = n_wafers.max(1) as f64;
        match self.topology {
            InterWaferTopology::Ring => (n / 4.0).max(1.0),
            InterWaferTopology::Mesh2d => (2.0 / 3.0 * n.sqrt()).max(1.0),
            InterWaferTopology::Switched => 2.0,
        }
    }

    /// Point-to-point transfer of `bytes` between two wafers of an
    /// `n_wafers` system (PP stage boundaries). Zero when everything is
    /// on one wafer.
    pub fn p2p_s(&self, bytes: f64, n_wafers: usize) -> f64 {
        if n_wafers <= 1 || bytes <= 0.0 {
            return 0.0;
        }
        bytes / self.effective_bytes_per_sec() + self.avg_hops(n_wafers) * self.link_latency
    }

    /// Flat ring all-reduce over `participants` ranks, every step on
    /// inter-wafer links: `2(p−1)/p · B/b + 2(p−1)·l`.
    pub fn ring_allreduce_s(&self, bytes: f64, participants: usize) -> f64 {
        if participants <= 1 || bytes < 0.0 {
            return 0.0;
        }
        let p = participants as f64;
        2.0 * (p - 1.0) / p * bytes / self.effective_bytes_per_sec()
            + 2.0 * (p - 1.0) * self.link_latency
    }

    /// Recursive-doubling/tree all-reduce over `groups` wafer groups:
    /// `2⌈log₂ g⌉` latency-bound rounds, full volume each round.
    pub fn tree_allreduce_s(&self, bytes: f64, groups: usize) -> f64 {
        if groups <= 1 || bytes < 0.0 {
            return 0.0;
        }
        let rounds = (groups as f64).log2().ceil();
        2.0 * rounds * (bytes / self.effective_bytes_per_sec() + self.link_latency)
    }

    /// Hierarchical all-reduce: co-resident ranks reduce over the on-wafer
    /// fabric (`on_wafer_bw` bytes/s) first, then one inter-wafer ring
    /// over the wafers, then an on-wafer broadcast.
    pub fn hierarchical_allreduce_s(
        &self,
        bytes: f64,
        participants: usize,
        n_wafers: usize,
        on_wafer_bw: f64,
    ) -> f64 {
        let groups = participants.min(n_wafers.max(1));
        let local = if participants > groups && on_wafer_bw > 0.0 {
            2.0 * bytes / on_wafer_bw
        } else {
            0.0
        };
        local + self.ring_allreduce_s(bytes, groups)
    }

    /// Best-schedule all-reduce of `bytes` across `participants` ranks
    /// spread over `n_wafers` wafers: minimum of flat ring, hierarchical
    /// (local + inter-wafer ring) and tree-over-wafers schedules. Zero
    /// when the system is a single wafer — callers keep single-wafer
    /// traffic on the on-wafer fabric.
    pub fn allreduce_s(
        &self,
        bytes: f64,
        participants: usize,
        n_wafers: usize,
        on_wafer_bw: f64,
    ) -> f64 {
        if n_wafers <= 1 || participants <= 1 || bytes <= 0.0 {
            return 0.0;
        }
        let groups = participants.min(n_wafers);
        let local = if participants > groups && on_wafer_bw > 0.0 {
            2.0 * bytes / on_wafer_bw
        } else {
            0.0
        };
        let flat = self.ring_allreduce_s(bytes, participants);
        let hier = self.hierarchical_allreduce_s(bytes, participants, n_wafers, on_wafer_bw);
        let tree = local + self.tree_allreduce_s(bytes, groups);
        flat.min(hier).min(tree)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn net(topology: InterWaferTopology, links: usize, bw: f64, lat: f64) -> InterWaferNet {
        InterWaferNet {
            topology,
            links_per_wafer: links,
            link_bandwidth: bw,
            link_latency: lat,
        }
    }

    #[test]
    fn topology_names_roundtrip() {
        for t in InterWaferTopology::ALL {
            assert_eq!(InterWaferTopology::parse(t.name()), Some(t));
        }
        assert_eq!(
            InterWaferTopology::parse("2d-mesh"),
            Some(InterWaferTopology::Mesh2d)
        );
        assert_eq!(InterWaferTopology::parse("torus"), None);
    }

    #[test]
    fn default_aggregate_matches_flat_nic_model() {
        let n = InterWaferNet::default_for(16);
        assert_eq!(n.aggregate_bytes_per_sec(), 16.0 * INTER_WAFER_BW_PER_NIC);
        // Switched: every link usable, so effective == aggregate.
        assert_eq!(n.effective_bytes_per_sec(), n.aggregate_bytes_per_sec());
    }

    #[test]
    fn topology_caps_effective_bandwidth() {
        let bw = 100e9;
        assert_eq!(
            net(InterWaferTopology::Ring, 16, bw, 1e-6).effective_bytes_per_sec(),
            2.0 * bw
        );
        assert_eq!(
            net(InterWaferTopology::Mesh2d, 16, bw, 1e-6).effective_bytes_per_sec(),
            4.0 * bw
        );
        assert_eq!(
            net(InterWaferTopology::Switched, 16, bw, 1e-6).effective_bytes_per_sec(),
            16.0 * bw
        );
    }

    #[test]
    fn single_wafer_or_single_rank_costs_nothing() {
        let n = InterWaferNet::default_for(16);
        assert_eq!(n.p2p_s(1e9, 1), 0.0);
        assert_eq!(n.allreduce_s(1e9, 1, 8, 1e12), 0.0);
        assert_eq!(n.allreduce_s(1e9, 8, 1, 1e12), 0.0);
        assert_eq!(n.ring_allreduce_s(1e9, 1), 0.0);
        assert_eq!(n.tree_allreduce_s(1e9, 1), 0.0);
    }

    #[test]
    fn prop_collectives_monotone_in_link_bandwidth() {
        crate::util::prop::check(
            "all-reduce and p2p time non-increasing as link bandwidth grows",
            |r| {
                let t = InterWaferTopology::ALL[r.below(3)];
                let links = r.range(1, 64);
                let lo = r.uniform(1e9, 100e9);
                let hi = lo * r.uniform(1.0, 32.0);
                let bytes = r.uniform(1e3, 1e12);
                let p = r.range(2, 128);
                let n = r.range(2, 64);
                (t, links, lo, hi, bytes, p, n)
            },
            |&(t, links, lo, hi, bytes, p, n)| {
                let slow = net(t, links, lo, 1e-6);
                let fast = net(t, links, hi, 1e-6);
                let on_bw = 1e13;
                if fast.allreduce_s(bytes, p, n, on_bw) > slow.allreduce_s(bytes, p, n, on_bw) {
                    return Err("allreduce not monotone in link bandwidth".to_string());
                }
                if fast.p2p_s(bytes, n) > slow.p2p_s(bytes, n) {
                    return Err("p2p not monotone in link bandwidth".to_string());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn hierarchical_beats_flat_ring_when_replicas_share_wafers() {
        // 32 DP replicas on 4 wafers with a fast on-wafer fabric: the
        // local reduce collapses 8 replicas per wafer, so the inter-wafer
        // ring runs over 4 ranks instead of 32.
        let n = net(InterWaferTopology::Ring, 8, 50e9, 1e-6);
        let bytes = 1e9;
        let hier = n.hierarchical_allreduce_s(bytes, 32, 4, 1e13);
        let flat = n.ring_allreduce_s(bytes, 32);
        assert!(hier < flat, "hier={hier} flat={flat}");
        // And allreduce_s picks the winner.
        assert!(n.allreduce_s(bytes, 32, 4, 1e13) <= hier);
    }

    #[test]
    fn tree_wins_in_latency_dominated_regime() {
        // Tiny message over many wafers with a slow per-hop latency: the
        // ring pays 2(n-1) latencies, the tree only 2·log2(n).
        let n = net(InterWaferTopology::Switched, 16, 100e9, 1e-3);
        let bytes = 1e3;
        let wafers = 64;
        let tree = n.tree_allreduce_s(bytes, wafers);
        let ring = n.ring_allreduce_s(bytes, wafers);
        assert!(tree < ring, "tree={tree} ring={ring}");
        assert!(n.allreduce_s(bytes, wafers, wafers, 1e13) <= tree);
    }

    #[test]
    fn allreduce_is_min_of_schedules() {
        crate::util::prop::check(
            "allreduce_s equals the cheapest of its candidate schedules",
            |r| {
                let t = InterWaferTopology::ALL[r.below(3)];
                let links = r.range(1, 64);
                let bw = r.uniform(1e9, 1e12);
                let lat = r.uniform(1e-7, 1e-3);
                let bytes = r.uniform(1.0, 1e11);
                let p = r.range(2, 256);
                let n = r.range(2, 64);
                (t, links, bw, lat, bytes, p, n)
            },
            |&(t, links, bw, lat, bytes, p, n)| {
                let w = net(t, links, bw, lat);
                let on_bw = 1e13;
                let got = w.allreduce_s(bytes, p, n, on_bw);
                let flat = w.ring_allreduce_s(bytes, p);
                let hier = w.hierarchical_allreduce_s(bytes, p, n, on_bw);
                if got > flat + 1e-12 || got > hier + 1e-12 {
                    return Err(format!("allreduce {got} exceeds flat {flat} / hier {hier}"));
                }
                if got <= 0.0 {
                    return Err("allreduce of positive bytes must cost time".to_string());
                }
                Ok(())
            },
        );
    }
}
