//! Heterogeneous WSC modeling for LLM inference (paper §V-B, Fig. 4).
//!
//! Two knobs characterize heterogeneity:
//! * **prefill ratio** — fraction of compute resources allocated to the
//!   prefill stage (the rest serves decode);
//! * **granularity** — the architecture level at which the two stages'
//!   resources diverge (core / reticle / wafer), which determines where the
//!   KV-cache handoff traffic travels and how much scheduling overhead the
//!   split incurs.

use super::{MemoryKind, WscConfig};

/// Level of the architecture hierarchy at which prefill/decode resources
/// are differentiated (Fig. 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum HeteroGranularity {
    /// Homogeneous design: both stages run on identical resources.
    None,
    /// Software scheduling inside a reticle: prefill/decode cores share the
    /// reticle, stacked-memory bandwidth is partitioned by scheduling.
    Core,
    /// Heterogeneous reticles (different stacking bandwidth) on one wafer.
    Reticle,
    /// Separate wafers for prefill and decode; KV cache crosses the
    /// inter-wafer network.
    Wafer,
}

impl HeteroGranularity {
    pub const ALL: [HeteroGranularity; 4] = [
        HeteroGranularity::None,
        HeteroGranularity::Core,
        HeteroGranularity::Reticle,
        HeteroGranularity::Wafer,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            HeteroGranularity::None => "none",
            HeteroGranularity::Core => "core",
            HeteroGranularity::Reticle => "reticle",
            HeteroGranularity::Wafer => "wafer",
        }
    }

    /// Inverse of [`Self::name`] — the parser campaign scenario JSON and
    /// CLI flags share.
    pub fn parse(s: &str) -> Option<HeteroGranularity> {
        HeteroGranularity::ALL.into_iter().find(|g| g.name() == s)
    }
}

/// Heterogeneity configuration attached to a [`WscConfig`] for inference
/// exploration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HeteroConfig {
    pub granularity: HeteroGranularity,
    /// Fraction of compute resources assigned to the prefill stage, (0, 1).
    pub prefill_ratio: f64,
    /// Stacked-DRAM bandwidth override for decode-stage resources
    /// (TB/s/100 mm²); prefill-stage resources keep the base config's
    /// bandwidth. Ignored for `None` granularity.
    pub decode_stack_bw: f64,
}

impl HeteroConfig {
    pub fn homogeneous() -> HeteroConfig {
        HeteroConfig {
            granularity: HeteroGranularity::None,
            prefill_ratio: 0.5,
            decode_stack_bw: 0.0,
        }
    }

    /// Split a wafer config into (prefill, decode) resource views.
    ///
    /// Returns per-stage reticle counts and the effective stacking
    /// bandwidth for each stage. At core granularity the *bandwidth* is
    /// partitioned by scheduling rather than the reticle count; we model
    /// that as both stages seeing all reticles but sharing each reticle's
    /// bandwidth in proportion to the ratio, with a utilization bonus for
    /// flexible scheduling and a transmission-overhead penalty (paper
    /// §IX-E discussion).
    pub fn split(&self, wsc: &WscConfig) -> HeteroSplit {
        let total = wsc.num_reticles();
        match self.granularity {
            HeteroGranularity::None => HeteroSplit {
                prefill_reticles: total,
                decode_reticles: total,
                shared: true,
                prefill_stack_bw: stack_bw(wsc),
                decode_stack_bw: stack_bw(wsc),
                // Homogeneous: stages time-share the full machine.
                kv_transfer_bw: f64::INFINITY,
                sched_overhead: 1.0,
            },
            HeteroGranularity::Core => HeteroSplit {
                prefill_reticles: total,
                decode_reticles: total,
                shared: true,
                prefill_stack_bw: stack_bw(wsc) * self.prefill_ratio,
                decode_stack_bw: self.decode_stack_bw.max(stack_bw(wsc)) * (1.0 - self.prefill_ratio),
                // KV moves over each reticle's own NoC: the aggregate
                // handoff bandwidth scales with the reticle count.
                kv_transfer_bw: wsc.reticle.bisection_bytes_per_sec()
                    * wsc.num_reticles() as f64,
                // Compilation/control overhead of fine-grain sharing
                // (paper: "overhead in compilation and control").
                sched_overhead: 1.06,
            },
            HeteroGranularity::Reticle => {
                let prefill = ((total as f64) * self.prefill_ratio).round().max(1.0) as usize;
                let prefill = prefill.min(total - 1);
                HeteroSplit {
                    prefill_reticles: prefill,
                    decode_reticles: total - prefill,
                    shared: false,
                    prefill_stack_bw: stack_bw(wsc),
                    decode_stack_bw: self.decode_stack_bw,
                    // KV crosses inter-reticle links along the stage border.
                    kv_transfer_bw: wsc.reticle.inter_reticle_bytes_per_sec()
                        * wsc.reticle_h.min(wsc.reticle_w) as f64,
                    sched_overhead: 1.0,
                }
            }
            HeteroGranularity::Wafer => {
                // Whole wafers per stage: the ratio picks how many wafers
                // of the pod serve prefill; KV rides the inter-wafer NICs.
                let prefill = ((total as f64) * self.prefill_ratio).round().max(1.0) as usize;
                let prefill = prefill.min(total - 1).max(1);
                HeteroSplit {
                    prefill_reticles: prefill,
                    decode_reticles: total - prefill,
                    shared: false,
                    prefill_stack_bw: stack_bw(wsc),
                    decode_stack_bw: self.decode_stack_bw,
                    kv_transfer_bw: wsc.inter_wafer_bytes_per_sec(),
                    sched_overhead: 1.0,
                }
            }
        }
    }
}

fn stack_bw(wsc: &WscConfig) -> f64 {
    match wsc.reticle.memory {
        MemoryKind::OffChip => 0.0,
        MemoryKind::Stacking {
            bw_tbps_per_100mm2, ..
        } => bw_tbps_per_100mm2,
    }
}

/// Resource view of one prefill/decode partition.
#[derive(Debug, Clone, Copy)]
pub struct HeteroSplit {
    pub prefill_reticles: usize,
    pub decode_reticles: usize,
    /// True if both stages time-share the same physical resources.
    pub shared: bool,
    /// Effective stacking bandwidth (TB/s/100 mm²) seen by each stage.
    pub prefill_stack_bw: f64,
    pub decode_stack_bw: f64,
    /// Bandwidth available for the prefill→decode KV-cache handoff (bytes/s).
    pub kv_transfer_bw: f64,
    /// Multiplicative latency overhead from scheduling/control complexity.
    pub sched_overhead: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{CoreConfig, Dataflow, IntegrationStyle, ReticleConfig};

    fn wsc() -> WscConfig {
        WscConfig {
            reticle: ReticleConfig {
                core: CoreConfig {
                    dataflow: Dataflow::WS,
                    mac_num: 256,
                    buffer_kb: 64,
                    buffer_bw_bits: 512,
                    noc_bw_bits: 512,
                },
                array_h: 9,
                array_w: 9,
                inter_reticle_bw_ratio: 0.6,
                memory: MemoryKind::Stacking {
                    bw_tbps_per_100mm2: 1.0,
                    capacity_gb: 16.0,
                },
            },
            reticle_h: 10,
            reticle_w: 7,
            integration: IntegrationStyle::InfoSoW,
            mem_ctrl_count: 8,
            nic_count: 8,
        }
    }

    #[test]
    fn reticle_split_partitions() {
        let h = HeteroConfig {
            granularity: HeteroGranularity::Reticle,
            prefill_ratio: 0.6,
            decode_stack_bw: 4.0,
        };
        let s = h.split(&wsc());
        assert_eq!(s.prefill_reticles + s.decode_reticles, 70);
        assert_eq!(s.prefill_reticles, 42);
        assert!(!s.shared);
        assert_eq!(s.decode_stack_bw, 4.0);
        assert!(s.kv_transfer_bw > 0.0);
    }

    #[test]
    fn reticle_split_never_empty() {
        for ratio in [0.01, 0.5, 0.99] {
            let h = HeteroConfig {
                granularity: HeteroGranularity::Reticle,
                prefill_ratio: ratio,
                decode_stack_bw: 2.0,
            };
            let s = h.split(&wsc());
            assert!(s.prefill_reticles >= 1);
            assert!(s.decode_reticles >= 1);
        }
    }

    #[test]
    fn wafer_split_uses_nic_bandwidth() {
        let h = HeteroConfig {
            granularity: HeteroGranularity::Wafer,
            prefill_ratio: 0.5,
            decode_stack_bw: 2.0,
        };
        let s = h.split(&wsc());
        assert_eq!(s.kv_transfer_bw, 8.0 * 100e9);
    }

    #[test]
    fn core_split_has_sched_overhead_and_cheap_kv() {
        let h = HeteroConfig {
            granularity: HeteroGranularity::Core,
            prefill_ratio: 0.5,
            decode_stack_bw: 2.0,
        };
        let s = h.split(&wsc());
        assert!(s.sched_overhead > 1.0);
        let hw = HeteroConfig {
            granularity: HeteroGranularity::Wafer,
            prefill_ratio: 0.5,
            decode_stack_bw: 2.0,
        };
        assert!(s.kv_transfer_bw > hw.split(&wsc()).kv_transfer_bw);
    }

    #[test]
    fn granularity_names_round_trip() {
        for g in HeteroGranularity::ALL {
            assert_eq!(HeteroGranularity::parse(g.name()), Some(g));
        }
        assert_eq!(HeteroGranularity::parse("chiplet"), None);
    }

    #[test]
    fn homogeneous_is_neutral() {
        let s = HeteroConfig::homogeneous().split(&wsc());
        assert!(s.shared);
        assert_eq!(s.sched_overhead, 1.0);
        assert!(s.kv_transfer_bw.is_infinite());
    }
}
