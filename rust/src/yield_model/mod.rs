//! Defective-core yield modeling (paper §V-C, Eq. 1–3, Fig. 5).
//!
//! A core's yield combines:
//! * the **Murphy model** (Eq. 1) — area × process defect density;
//! * **screw-hole stress** (Eq. 2) — holes at reticle-grid intersections
//!   linearly degrade yield of cores whose nearest vertex is within
//!   `d_str_max`;
//! * **TSV proximity** — same linear model around the TSV field that feeds
//!   stacked DRAM.
//!
//! [`redundancy`] lifts per-core yields to reticle/wafer level (Eq. 4).

pub mod faults;
pub mod redundancy;

use crate::arch::constants as k;

/// Murphy yield model (Eq. 1): `area_cm2` is the core area in cm²,
/// `d0` the average defect density per cm².
pub fn murphy(area_cm2: f64, d0: f64) -> f64 {
    let ad = area_cm2 * d0;
    if ad < 1e-12 {
        return 1.0;
    }
    let t = (1.0 - (-ad).exp()) / ad;
    t * t
}

/// Stress-hole yield factor (Eq. 2): `ds_mm` = distance from the hole to
/// the nearest vertex of the core. Loss fades linearly from `loss` at the
/// hole to zero at `d_max`.
pub fn stress_factor(ds_mm: f64, loss: f64, d_max: f64) -> f64 {
    if ds_mm >= d_max {
        1.0
    } else {
        (loss / d_max) * ds_mm + 1.0 - loss
    }
}

/// Per-core yield grid for one reticle (Eq. 3).
///
/// Cores are laid out as an `array_h × array_w` grid of `core_w × core_h`
/// mm cells anchored at the reticle origin. Screw holes sit at the four
/// corners of the reticle (reticle-grid intersections on the wafer —
/// every interior corner of the reticle array carries a screw, so each
/// reticle sees holes at all four of its corners). The TSV field degrades
/// every core in proportion to how much of the stress budget it consumes.
pub struct YieldInputs {
    pub array_h: usize,
    pub array_w: usize,
    pub core_w_mm: f64,
    pub core_h_mm: f64,
    pub core_area_cm2: f64,
    /// Reticle extent in mm (screw holes at its corners).
    pub reticle_w_mm: f64,
    pub reticle_h_mm: f64,
    /// TSV field area as a fraction of the stress cap
    /// [`k::TSV_AREA_RATIO_MAX`] (0 for off-chip designs, ≤1 after the
    /// validator's stress check).
    pub tsv_stress_utilization: f64,
}

/// Yield of the core at grid position (row, col).
pub fn core_yield_at(inp: &YieldInputs, row: usize, col: usize) -> f64 {
    let base = murphy(inp.core_area_cm2, k::DEFECT_DENSITY_PER_CM2);

    // Core corner coordinates (mm).
    let x0 = col as f64 * inp.core_w_mm;
    let y0 = row as f64 * inp.core_h_mm;
    let corners = [
        (x0, y0),
        (x0 + inp.core_w_mm, y0),
        (x0, y0 + inp.core_h_mm),
        (x0 + inp.core_w_mm, y0 + inp.core_h_mm),
    ];
    let holes = [
        (0.0, 0.0),
        (inp.reticle_w_mm, 0.0),
        (0.0, inp.reticle_h_mm),
        (inp.reticle_w_mm, inp.reticle_h_mm),
    ];
    // Nearest core-vertex-to-hole distance (Eq. 2 uses the nearest vertex).
    let mut y_str: f64 = 1.0;
    for &(hx, hy) in &holes {
        let ds = corners
            .iter()
            .map(|&(cx, cy)| ((cx - hx).powi(2) + (cy - hy).powi(2)).sqrt())
            .fold(f64::INFINITY, f64::min);
        y_str *= stress_factor(ds, k::STRESS_LOSS, k::STRESS_MAX_DIST_MM);
    }

    // TSV field: distributed between core rows; we charge every core a loss
    // proportional to the consumed fraction of the 1.5 % stress budget
    // (more stacked-DRAM bandwidth -> more TSVs -> lower yield), which is
    // the trend the DSE needs (paper Fig. 11b discussion).
    let y_tsv = 1.0 - k::TSV_LOSS * inp.tsv_stress_utilization.clamp(0.0, 1.0);

    base * y_str * y_tsv
}

/// Full per-core yield grid, row-major.
pub fn yield_grid(inp: &YieldInputs) -> Vec<Vec<f64>> {
    (0..inp.array_h)
        .map(|r| (0..inp.array_w).map(|c| core_yield_at(inp, r, c)).collect())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn murphy_limits() {
        // Zero area -> perfect yield; Murphy(1 cm², 0.1/cm²) ≈ 0.9056.
        assert!((murphy(0.0, 0.1) - 1.0).abs() < 1e-9);
        let y = murphy(1.0, 0.1);
        assert!((y - 0.9056).abs() < 1e-3, "y={y}");
        // Monotone decreasing in area.
        assert!(murphy(2.0, 0.1) < y);
        assert!(murphy(1.0, 0.2) < y);
    }

    #[test]
    fn stress_linear_fade() {
        assert!((stress_factor(0.0, 0.1, 1.0) - 0.9).abs() < 1e-12);
        assert!((stress_factor(0.5, 0.1, 1.0) - 0.95).abs() < 1e-12);
        assert_eq!(stress_factor(1.0, 0.1, 1.0), 1.0);
        assert_eq!(stress_factor(5.0, 0.1, 1.0), 1.0);
    }

    fn inputs() -> YieldInputs {
        YieldInputs {
            array_h: 10,
            array_w: 10,
            core_w_mm: 2.0,
            core_h_mm: 2.0,
            core_area_cm2: 0.04,
            reticle_w_mm: 26.0,
            reticle_h_mm: 33.0,
            tsv_stress_utilization: 0.0,
        }
    }

    #[test]
    fn corner_cores_yield_less() {
        let inp = inputs();
        let corner = core_yield_at(&inp, 0, 0);
        let center = core_yield_at(&inp, 5, 5);
        assert!(corner < center, "corner={corner} center={center}");
        // Center core is far from all holes: pure Murphy.
        assert!((center - murphy(0.04, 0.1)).abs() < 1e-12);
    }

    #[test]
    fn tsv_utilization_degrades_everywhere() {
        let mut inp = inputs();
        let before = core_yield_at(&inp, 5, 5);
        inp.tsv_stress_utilization = 1.0;
        let after = core_yield_at(&inp, 5, 5);
        assert!((after / before - 0.9).abs() < 1e-9);
    }

    #[test]
    fn grid_shape_and_symmetry() {
        let inp = inputs();
        let g = yield_grid(&inp);
        assert_eq!(g.len(), 10);
        assert_eq!(g[0].len(), 10);
        for row in &g {
            for &y in row {
                assert!(y > 0.0 && y <= 1.0);
            }
        }
        // Left-right symmetry of hole placement: when the reticle width
        // equals the array span (10 cores × 2 mm), the corner holes mirror
        // exactly, so every row must read the same left-to-right as
        // right-to-left. (The default fixture's 26 mm reticle offsets the
        // right-hand holes past the array, which is *not* symmetric — the
        // old assertion `sym || g[0][0] > 0.0` was vacuously true.)
        let mut sym = inputs();
        sym.reticle_w_mm = 10.0 * sym.core_w_mm;
        let g = yield_grid(&sym);
        for (r, row) in g.iter().enumerate() {
            for c in 0..row.len() {
                let mirrored = row[row.len() - 1 - c];
                assert!(
                    (row[c] - mirrored).abs() < 1e-9,
                    "row {r} col {c}: {} vs {}",
                    row[c],
                    mirrored
                );
            }
        }
    }

    #[test]
    fn prop_yield_in_unit_interval() {
        crate::util::prop::check(
            "core yield ∈ (0,1]",
            |r| YieldInputs {
                array_h: r.range(1, 20),
                array_w: r.range(1, 20),
                core_w_mm: r.uniform(0.2, 3.0),
                core_h_mm: r.uniform(0.2, 3.0),
                core_area_cm2: r.uniform(0.001, 0.2),
                reticle_w_mm: 26.0,
                reticle_h_mm: 33.0,
                tsv_stress_utilization: r.f64(),
            },
            |inp| {
                let y = core_yield_at(inp, 0, 0);
                if y > 0.0 && y <= 1.0 {
                    Ok(())
                } else {
                    Err(format!("yield {y} out of range"))
                }
            },
        );
    }
}

impl std::fmt::Debug for YieldInputs {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "YieldInputs({}x{} cores {:.2}x{:.2}mm, A={:.4}cm2, tsv={:.2})",
            self.array_h,
            self.array_w,
            self.core_w_mm,
            self.core_h_mm,
            self.core_area_cm2,
            self.tsv_stress_utilization
        )
    }
}
