//! Redundancy-based yield enhancement (paper §V-D, Eq. 4).
//!
//! Cerebras-style row redundancy: each row of the core array carries `n`
//! spare cores plus reroute connections, so a row survives if at most `n`
//! of its `p + n` cores are defective. With per-core yields varying by
//! position (stress holes), the row survival probability is a
//! Poisson-binomial tail, computed exactly by dynamic programming; a
//! Monte-Carlo estimator cross-checks the DP (the paper uses Monte Carlo).
//!
//! Wafer level (§V-D): die stitching multiplies reticle yields (no test
//! before integration), while InFO-SoW with known-good-die screening takes
//! the (post-sort) reticle yield directly.

use crate::arch::IntegrationStyle;
use crate::util::rng::Rng;

/// P(at most `max_defects` failures) among independent cores with the given
/// per-core yields — exact Poisson-binomial tail via DP over defect counts.
pub fn prob_at_most_defects(yields: &[f64], max_defects: usize) -> f64 {
    prob_at_most_defects_with_overflow(yields, max_defects).0
}

/// Same tail plus the tracked overflow mass (probability of *more than*
/// `max_defects` failures). The two must partition the probability space:
/// `tail + overflow == 1` up to float error — pinned by
/// `tail_and_overflow_partition_unity`.
pub fn prob_at_most_defects_with_overflow(yields: &[f64], max_defects: usize) -> (f64, f64) {
    // dp[d] = probability of exactly d defects so far.
    let cap = max_defects.min(yields.len());
    let mut dp = vec![0.0f64; cap + 2];
    dp[0] = 1.0;
    let mut overflow = 0.0f64; // probability mass with > cap defects
    for &y in yields {
        let q = 1.0 - y; // defect probability
        let spill = dp[cap] * q;
        for d in (1..=cap).rev() {
            dp[d] = dp[d] * y + dp[d - 1] * q;
        }
        dp[0] *= y;
        overflow = overflow + spill; // mass that exceeded cap stays failed
    }
    (dp[..=cap].iter().sum(), overflow)
}

/// Reticle yield with `n_red` redundant cores per row (Eq. 4 applied
/// per redundancy group = row). `grid[r][c]` = yield of core (r, c)
/// including the redundant positions (the grid passed in must already be
/// the *physical* grid of p + n cores per row).
pub fn reticle_yield_rows(grid: &[Vec<f64>], n_red: usize) -> f64 {
    grid.iter()
        .map(|row| prob_at_most_defects(row, n_red))
        .product()
}

/// Monte-Carlo estimate of the same quantity (validation path; the paper
/// §VIII-A uses MC sampling for reticles with redundancy).
pub fn reticle_yield_monte_carlo(
    grid: &[Vec<f64>],
    n_red: usize,
    trials: usize,
    rng: &mut Rng,
) -> f64 {
    let mut ok = 0usize;
    'trial: for _ in 0..trials {
        for row in grid {
            let defects = row.iter().filter(|&&y| rng.f64() >= y).count();
            if defects > n_red {
                continue 'trial;
            }
        }
        ok += 1;
    }
    ok as f64 / trials as f64
}

/// Wafer-level yield from reticle yield (§V-D): KGD screening (InFO-SoW)
/// sorts out bad reticles before integration; die stitching cannot, so all
/// `num_reticles` exposures must succeed together.
pub fn wafer_yield(reticle_yield: f64, num_reticles: usize, style: IntegrationStyle) -> f64 {
    match style {
        IntegrationStyle::InfoSoW => reticle_yield,
        IntegrationStyle::DieStitching => reticle_yield.powi(num_reticles as i32),
    }
}

/// Result of redundancy selection.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RedundancyPlan {
    /// Redundant cores added per row.
    pub per_row: usize,
    /// Achieved reticle yield (operational rows survive).
    pub reticle_yield: f64,
    /// Achieved wafer yield under the given integration style.
    pub wafer_yield: f64,
}

/// Choose the minimum per-row redundancy such that the *wafer* yield meets
/// `target`, given the physical yield grid builder.
///
/// `grid_for(n_red)` must return the physical yield grid when each row is
/// extended by `n_red` spare cores (spares occupy area, shifting positions
/// and possibly the reticle floorplan — the component estimator owns that).
/// Returns `None` if even `max_red` spares per row can't reach the target.
pub fn choose_redundancy<F>(
    target: f64,
    num_reticles: usize,
    style: IntegrationStyle,
    max_red: usize,
    mut grid_for: F,
) -> Option<RedundancyPlan>
where
    F: FnMut(usize) -> Option<Vec<Vec<f64>>>,
{
    for n_red in 0..=max_red {
        let Some(grid) = grid_for(n_red) else {
            // Floorplan no longer fits with this many spares.
            return None;
        };
        let ry = reticle_yield_rows(&grid, n_red);
        let wy = wafer_yield(ry, num_reticles, style);
        if wy >= target {
            return Some(RedundancyPlan {
                per_row: n_red,
                reticle_yield: ry,
                wafer_yield: wy,
            });
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binomial_matches_closed_form() {
        // Uniform yields -> plain binomial tail (Eq. 4 with p=3 working
        // cores, n=1 spare: row of 4, survive with <=1 defect).
        let y = 0.95f64;
        let row = vec![y; 4];
        let dp = prob_at_most_defects(&row, 1);
        let closed = y.powi(4) + 4.0 * y.powi(3) * (1.0 - y);
        assert!((dp - closed).abs() < 1e-12);
    }

    #[test]
    fn zero_redundancy_is_product() {
        let row = vec![0.9, 0.8, 0.99];
        let dp = prob_at_most_defects(&row, 0);
        assert!((dp - 0.9 * 0.8 * 0.99).abs() < 1e-12);
    }

    #[test]
    fn monotone_in_redundancy() {
        let row = vec![0.9; 12];
        let mut prev = 0.0;
        for n in 0..5 {
            let p = prob_at_most_defects(&row, n);
            assert!(p >= prev);
            prev = p;
        }
    }

    #[test]
    fn monte_carlo_agrees_with_dp() {
        let grid: Vec<Vec<f64>> = (0..6)
            .map(|r| (0..10).map(|c| 0.92 + 0.005 * ((r + c) % 3) as f64).collect())
            .collect();
        let exact = reticle_yield_rows(&grid, 1);
        let mut rng = Rng::new(123);
        let mc = reticle_yield_monte_carlo(&grid, 1, 40_000, &mut rng);
        assert!((exact - mc).abs() < 0.01, "exact={exact} mc={mc}");
    }

    #[test]
    fn kgd_beats_die_stitching() {
        let ry = 0.97;
        let kgd = wafer_yield(ry, 70, IntegrationStyle::InfoSoW);
        let stitch = wafer_yield(ry, 70, IntegrationStyle::DieStitching);
        assert_eq!(kgd, ry);
        assert!(stitch < 0.2, "stitch={stitch}");
    }

    #[test]
    fn choose_redundancy_finds_minimum() {
        // 8x8 grid of 0.97-yield cores; InfoSoW needs reticle yield >= 0.9.
        let plan = choose_redundancy(0.9, 64, IntegrationStyle::InfoSoW, 8, |n| {
            Some(vec![vec![0.97; 8 + n]; 8])
        })
        .unwrap();
        // n=0: 0.97^64 ≈ 0.14 — insufficient; plan must add spares.
        assert!(plan.per_row >= 1);
        assert!(plan.wafer_yield >= 0.9);
        // Minimality: one fewer spare must miss the target.
        if plan.per_row > 0 {
            let smaller_grid = vec![vec![0.97; 8 + plan.per_row - 1]; 8];
            let ry = reticle_yield_rows(&smaller_grid, plan.per_row - 1);
            assert!(wafer_yield(ry, 64, IntegrationStyle::InfoSoW) < 0.9);
        }
    }

    #[test]
    fn choose_redundancy_gives_up() {
        // Terrible cores: even max spares can't reach target.
        let got = choose_redundancy(0.9, 64, IntegrationStyle::DieStitching, 3, |n| {
            Some(vec![vec![0.5; 8 + n]; 8])
        });
        assert!(got.is_none());
    }

    #[test]
    fn tail_and_overflow_partition_unity() {
        // The DP's overflow accumulator is real bookkeeping, not dead code:
        // tail + overflow must partition the probability space exactly.
        let cases: &[(Vec<f64>, usize)] = &[
            (vec![0.9; 12], 0),
            (vec![0.9; 12], 2),
            (vec![0.5, 0.7, 0.99, 0.8], 1),
            (vec![0.97; 20], 5),
            (vec![0.6; 3], 10), // cap > len
        ];
        for (ys, n) in cases {
            let (tail, overflow) = prob_at_most_defects_with_overflow(ys, *n);
            assert!(
                (tail + overflow - 1.0).abs() < 1e-12,
                "tail={tail} overflow={overflow} for n={n}"
            );
            assert_eq!(tail, prob_at_most_defects(ys, *n));
        }
    }

    #[test]
    fn prop_dp_bounded_and_monotone_in_yield() {
        crate::util::prop::check(
            "poisson-binomial tail bounded, monotone",
            |r| {
                let len = r.range(1, 20);
                let ys: Vec<f64> = (0..len).map(|_| r.uniform(0.5, 1.0)).collect();
                let n_red = r.below(4);
                (ys, n_red)
            },
            |(ys, n_red)| {
                let p = prob_at_most_defects(ys, *n_red);
                if !(0.0..=1.0 + 1e-12).contains(&p) {
                    return Err(format!("p={p}"));
                }
                // Raising every core's yield can't lower the tail.
                let better: Vec<f64> = ys.iter().map(|y| (y + 0.01).min(1.0)).collect();
                let p2 = prob_at_most_defects(&better, *n_red);
                if p2 + 1e-12 < p {
                    return Err(format!("not monotone: {p} -> {p2}"));
                }
                Ok(())
            },
        );
    }
}
