//! Fault injection: deterministic dead-core/dead-link maps sampled from the
//! per-core yield grid (§V-C), plus Cerebras-style spare-row repair.
//!
//! # Sampling & determinism contract
//!
//! A [`FaultMap`] is sampled by drawing one uniform *u-value* per core and
//! per outgoing link, in a fixed order (nodes row-major; per node: the core
//! first, then its four links in [`Dir`](crate::compiler::routing::Dir)
//! order), from a [`Rng`] seeded with the spec's seed. An element is dead
//! iff `u < p_dead`, where `p_dead = clamp((1 - yield) * defect_multiplier)`
//! for cores and `p_dead * LINK_FAULT_FRACTION` for links. Because the
//! u-values depend only on the seed and the draw order — never on the
//! multiplier — the dead sets are *nested*: at a fixed seed, raising the
//! defect multiplier only ever adds faults, which makes degradation curves
//! structurally monotone. A multiplier of 0 yields a pristine map and the
//! evaluation layer takes the bit-identical fault-free path.
//!
//! # Spare-row repair
//!
//! [`FaultMap::repair_rows`] models the row-redundancy scheme that
//! [`redundancy::RedundancyPlan`](super::redundancy::RedundancyPlan) costs
//! out: each row carries `spares` spare cores that can be remapped in place
//! of dead ones (left-to-right, a fixed order that preserves nesting).
//! Dead *links* are not repairable — spare cores reuse the mesh wiring.
//!
//! The evaluation layer builds fault maps via
//! [`eval::chunk`](crate::eval::chunk)'s fault plumbing; campaign scenarios
//! add a fault spec per row (see `coordinator::campaign::fault_suite`).

use crate::util::rng::Rng;

/// Number of outgoing link directions per node (matches
/// [`crate::compiler::routing::NUM_DIRS`]; duplicated to keep this module
/// below the compiler in the dependency order).
const NUM_DIRS: usize = 4;

/// Fraction of a core's defect probability attributed to each of its
/// outgoing mesh links (wires + repeaters are far smaller than the core).
pub const LINK_FAULT_FRACTION: f64 = 0.25;

/// Declarative fault-injection request, threaded through `EvalSpec`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    /// Scales each core's defect probability `1 - yield`; 0 = pristine,
    /// 1 = the yield model's nominal defect rate.
    pub defect_multiplier: f64,
    /// Spare cores available per row for repair; `None` uses the design's
    /// own `RedundancyPlan::per_row`.
    pub spares: Option<usize>,
    /// Sampling seed (see the module docs for the determinism contract).
    pub seed: u64,
}

/// Mix a base seed with the sampled grid's dimensions, so maps of different
/// region shapes decorrelate while staying reproducible (SplitMix64 over
/// the packed inputs — no ambient randomness).
pub fn region_seed(seed: u64, h: usize, w: usize) -> u64 {
    let mut z = seed
        .wrapping_mul(0x9e37_79b9_7f4a_7c15)
        .wrapping_add((h as u64) << 32 | w as u64);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Sampled fault state of an `h × w` core mesh: per-core and per-directed-
/// link death flags (links indexed like [`crate::compiler::routing::link_index`]).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultMap {
    h: usize,
    w: usize,
    dead_core: Vec<bool>,
    dead_link: Vec<bool>,
}

impl FaultMap {
    /// Sample a map from the per-core yield grid (`grid[r][c]` ∈ (0, 1]).
    /// See the module docs for the draw order and nesting guarantee.
    pub fn sample(grid: &[Vec<f64>], defect_multiplier: f64, seed: u64) -> FaultMap {
        let h = grid.len();
        let w = grid.first().map_or(0, |r| r.len());
        let mut rng = Rng::new(seed);
        let mut dead_core = vec![false; h * w];
        let mut dead_link = vec![false; h * w * NUM_DIRS];
        for r in 0..h {
            for c in 0..w {
                let p_core = ((1.0 - grid[r][c]) * defect_multiplier).clamp(0.0, 1.0);
                let p_link = (p_core * LINK_FAULT_FRACTION).clamp(0.0, 1.0);
                // Threshold sampling: the u-values never depend on the
                // multiplier, so higher rates strictly grow the dead set.
                dead_core[r * w + c] = rng.f64() < p_core;
                for d in 0..NUM_DIRS {
                    dead_link[(r * w + c) * NUM_DIRS + d] = rng.f64() < p_link;
                }
            }
        }
        FaultMap {
            h,
            w,
            dead_core,
            dead_link,
        }
    }

    /// An all-alive map (defect rate 0).
    pub fn pristine(h: usize, w: usize) -> FaultMap {
        FaultMap {
            h,
            w,
            dead_core: vec![false; h * w],
            dead_link: vec![false; h * w * NUM_DIRS],
        }
    }

    pub fn dims(&self) -> (usize, usize) {
        (self.h, self.w)
    }

    /// Spare-row repair: revive up to `spares` dead cores per row, left to
    /// right (fixed order — preserves dead-set nesting across multipliers
    /// and spare counts). Dead links stay dead.
    pub fn repair_rows(&mut self, spares: usize) {
        for r in 0..self.h {
            let mut left = spares;
            for c in 0..self.w {
                if left == 0 {
                    break;
                }
                if self.dead_core[r * self.w + c] {
                    self.dead_core[r * self.w + c] = false;
                    left -= 1;
                }
            }
        }
    }

    /// Restrict to the top-left `h × w` sub-mesh (evaluation regions are
    /// slices of the physical array; a crop of nested maps stays nested).
    pub fn crop(&self, h: usize, w: usize) -> FaultMap {
        assert!(h <= self.h && w <= self.w, "crop larger than map");
        let mut out = FaultMap::pristine(h, w);
        for r in 0..h {
            for c in 0..w {
                out.dead_core[r * w + c] = self.dead_core[r * self.w + c];
                for d in 0..NUM_DIRS {
                    out.dead_link[(r * w + c) * NUM_DIRS + d] =
                        self.dead_link[(r * self.w + c) * NUM_DIRS + d];
                }
            }
        }
        out
    }

    pub fn core_ok(&self, r: usize, c: usize) -> bool {
        !self.dead_core[r * self.w + c]
    }

    /// Is the directed link out of `(r, c)` toward direction `dir`
    /// physically intact? (Endpoint liveness is the router's concern —
    /// routing additionally refuses links into or out of dead cores.)
    pub fn link_intact(&self, r: usize, c: usize, dir: usize) -> bool {
        !self.dead_link[(r * self.w + c) * NUM_DIRS + dir]
    }

    pub fn is_pristine(&self) -> bool {
        self.dead_core.iter().all(|&d| !d) && self.dead_link.iter().all(|&d| !d)
    }

    pub fn live_cores(&self) -> usize {
        self.dead_core.iter().filter(|&&d| !d).count()
    }

    pub fn dead_links(&self) -> usize {
        self.dead_link.iter().filter(|&&d| d).count()
    }

    /// Kill one core (test / what-if hook).
    pub fn kill_core(&mut self, r: usize, c: usize) {
        self.dead_core[r * self.w + c] = true;
    }

    /// Kill one directed link (test / what-if hook).
    pub fn kill_link(&mut self, r: usize, c: usize, dir: usize) {
        self.dead_link[(r * self.w + c) * NUM_DIRS + dir] = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid(h: usize, w: usize, y: f64) -> Vec<Vec<f64>> {
        vec![vec![y; w]; h]
    }

    #[test]
    fn zero_multiplier_is_pristine() {
        let m = FaultMap::sample(&grid(8, 8, 0.9), 0.0, 7);
        assert!(m.is_pristine());
        assert_eq!(m.live_cores(), 64);
    }

    #[test]
    fn sampling_is_deterministic_per_seed() {
        let g = grid(10, 10, 0.92);
        let a = FaultMap::sample(&g, 1.5, 42);
        let b = FaultMap::sample(&g, 1.5, 42);
        assert_eq!(a, b);
        let c = FaultMap::sample(&g, 1.5, 43);
        assert_ne!(a, c, "different seeds should differ at this defect rate");
    }

    #[test]
    fn dead_sets_nest_across_multipliers() {
        // Threshold sampling: at a fixed seed, every fault present at a low
        // multiplier must also be present at any higher multiplier.
        let g = grid(12, 12, 0.9);
        for seed in [1u64, 9, 77] {
            let lo = FaultMap::sample(&g, 0.5, seed);
            let hi = FaultMap::sample(&g, 2.0, seed);
            for i in 0..lo.dead_core.len() {
                assert!(!lo.dead_core[i] || hi.dead_core[i], "core nesting violated");
            }
            for i in 0..lo.dead_link.len() {
                assert!(!lo.dead_link[i] || hi.dead_link[i], "link nesting violated");
            }
            assert!(hi.live_cores() <= lo.live_cores());
        }
    }

    #[test]
    fn repair_revives_per_row_and_nests() {
        let g = grid(10, 10, 0.7);
        let base = FaultMap::sample(&g, 1.0, 5);
        assert!(base.live_cores() < 100, "want some faults at yield 0.7");
        let mut r1 = base.clone();
        r1.repair_rows(1);
        let mut r3 = base.clone();
        r3.repair_rows(3);
        assert!(r1.live_cores() >= base.live_cores());
        assert!(r3.live_cores() >= r1.live_cores());
        // More spares revive a superset of cores.
        for i in 0..base.dead_core.len() {
            assert!(!r1.dead_core[i] || r3.dead_core[i] || !r3.dead_core[i]);
            if !r1.dead_core[i] {
                assert!(!r3.dead_core[i], "spare nesting violated");
            }
        }
        // Links are untouched by repair.
        assert_eq!(base.dead_link, r1.dead_link);
    }

    #[test]
    fn crop_preserves_flags() {
        let g = grid(9, 9, 0.8);
        let m = FaultMap::sample(&g, 1.0, 11);
        let c = m.crop(5, 6);
        assert_eq!(c.dims(), (5, 6));
        for r in 0..5 {
            for col in 0..6 {
                assert_eq!(c.core_ok(r, col), m.core_ok(r, col));
                for d in 0..NUM_DIRS {
                    assert_eq!(c.link_intact(r, col, d), m.link_intact(r, col, d));
                }
            }
        }
    }

    #[test]
    fn region_seed_is_stable_and_shape_sensitive() {
        assert_eq!(region_seed(42, 8, 8), region_seed(42, 8, 8));
        assert_ne!(region_seed(42, 8, 8), region_seed(42, 8, 9));
        assert_ne!(region_seed(42, 8, 8), region_seed(43, 8, 8));
    }
}
