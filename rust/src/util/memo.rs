//! Bounded, thread-safe memoization substrate (§Perf): a mutex-guarded
//! hash map with hit/miss counters and *epoch* eviction — when the map
//! reaches capacity it is cleared wholesale rather than tracking recency.
//!
//! Epoch eviction is the right trade for the caches built on this type
//! (tile-level evaluations, core geometry): entries are cheap to recompute
//! (sub-microsecond closed-form models), so LRU bookkeeping on every hit
//! would cost more than the occasional cold re-fill after a clear. The
//! compile-chunk cache ([`crate::compiler::cache`]) keeps its own LRU
//! because compiles are milliseconds-scale.
//!
//! Thread-safety contract mirrors the chunk cache: lookups/inserts take the
//! mutex, **the compute closure runs outside it**, so concurrent misses on
//! one key may compute twice (last insert wins — harmless for pure
//! functions) but never serialize the pool on compute time.

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Point-in-time counters for one memo.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemoStats {
    pub hits: u64,
    pub misses: u64,
    pub len: usize,
    pub capacity: usize,
}

impl MemoStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// The memo itself. `capacity` 0 disables caching (every call computes).
pub struct Memo<K, V> {
    map: Mutex<HashMap<K, V>>,
    capacity: usize,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl<K: Eq + Hash, V: Clone> Memo<K, V> {
    pub fn new(capacity: usize) -> Memo<K, V> {
        Memo {
            map: Mutex::new(HashMap::new()),
            capacity,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Fetch the memoized value for `key`, computing with `f` on a miss.
    /// `f` must be a pure function of `key` for the memo to be transparent.
    pub fn get_or_insert_with<F: FnOnce() -> V>(&self, key: K, f: F) -> V {
        if self.capacity == 0 {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return f();
        }
        // A panicking compute closure never runs under the lock, so a
        // poisoned mutex only means another thread died mid-insert on a
        // pure-value map — recover the map rather than cascading.
        if let Some(v) = self
            .map
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .get(&key)
        {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return v.clone();
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let v = f(); // compute outside the lock
        let mut m = self.map.lock().unwrap_or_else(|p| p.into_inner());
        if m.len() >= self.capacity {
            m.clear(); // epoch eviction (see module docs)
        }
        m.insert(key, v.clone());
        v
    }

    pub fn stats(&self) -> MemoStats {
        MemoStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            len: self.map.lock().unwrap_or_else(|p| p.into_inner()).len(),
            capacity: self.capacity,
        }
    }

    /// Drop all entries and zero the counters (test/bench isolation).
    pub fn clear(&self) {
        self.map.lock().unwrap_or_else(|p| p.into_inner()).clear();
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn memoizes_and_counts() {
        let calls = AtomicUsize::new(0);
        let m: Memo<u64, u64> = Memo::new(16);
        let f = |k: u64| {
            calls.fetch_add(1, Ordering::Relaxed);
            k * 2
        };
        assert_eq!(m.get_or_insert_with(3, || f(3)), 6);
        assert_eq!(m.get_or_insert_with(3, || f(3)), 6);
        assert_eq!(calls.load(Ordering::Relaxed), 1);
        let s = m.stats();
        assert_eq!((s.hits, s.misses, s.len), (1, 1, 1));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn epoch_eviction_bounds_len() {
        let m: Memo<u64, u64> = Memo::new(4);
        for k in 0..100 {
            m.get_or_insert_with(k, || k);
        }
        assert!(m.stats().len <= 4);
    }

    #[test]
    fn zero_capacity_disables() {
        let m: Memo<u64, u64> = Memo::new(0);
        m.get_or_insert_with(1, || 1);
        m.get_or_insert_with(1, || 1);
        let s = m.stats();
        assert_eq!((s.hits, s.misses, s.len), (0, 2, 0));
    }

    #[test]
    fn concurrent_use_is_consistent() {
        let m: Memo<usize, usize> = Memo::new(64);
        let vals = crate::util::pool::par_map_idx(256, |i| m.get_or_insert_with(i % 8, || (i % 8) * 10));
        for (i, v) in vals.iter().enumerate() {
            assert_eq!(*v, (i % 8) * 10);
        }
    }
}
