//! Process-wide warn-once registry for dispatch-fallback reporting.
//!
//! Hot evaluation paths degrade gracefully (batched GNN inference falls
//! back to the analytical NoC model, the batched analytical sweep falls
//! back to the per-point pooled path, the CA simulator falls back on
//! budget overrun), and each degradation must be reported **loudly but
//! once**: per-call warnings would flood a campaign's stderr, while a
//! local `static Once` per call site means every new fallback path
//! reinvents — or forgets — the reporting. [`warn_once`] is the single
//! shared helper: the first message per `key` prints to stderr (tagged
//! so later occurrences are known to be silent), subsequent ones are
//! dropped.

/// Keys that already warned, so each fallback path reports at most once
/// per process (mirrors `util::cli`'s malformed-env registry).
fn warned_keys() -> &'static std::sync::Mutex<std::collections::BTreeSet<String>> {
    static WARNED: std::sync::OnceLock<std::sync::Mutex<std::collections::BTreeSet<String>>> =
        std::sync::OnceLock::new();
    WARNED.get_or_init(|| std::sync::Mutex::new(std::collections::BTreeSet::new()))
}

/// Print `msg` to stderr the first time `key` is seen; drop repeats.
/// Returns whether this call was the one that printed (so callers can
/// attach extra diagnostics to the first occurrence only).
pub fn warn_once(key: &str, msg: &str) -> bool {
    // A panicked holder only leaves a fully-inserted set behind; keep
    // warning rather than poisoning every later fallback report.
    let first = warned_keys()
        .lock()
        .unwrap_or_else(|p| p.into_inner())
        .insert(key.to_string());
    if first {
        eprintln!("{msg} (further occurrences are silent)");
    }
    first
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warns_exactly_once_per_key() {
        assert!(warn_once("test-key-a", "first"));
        assert!(!warn_once("test-key-a", "second"));
        assert!(warn_once("test-key-b", "other key still warns"));
    }
}
