//! Deterministic PRNG substrate (no `rand` crate offline).
//!
//! xoshiro256** seeded via SplitMix64 — the standard construction. All
//! stochastic components of Theseus (design-point sampling, Monte-Carlo
//! yield, CA-sim traffic jitter, BO candidate pools) draw from this so every
//! experiment is reproducible from a single `u64` seed.

/// xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the 256-bit state.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    /// Derive an independent stream (for per-thread / per-repeat use).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Unbiased via rejection.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(1e-300); // avoid log(0)
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from 0..n (k <= n), uniform without
    /// replacement (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Exponential with mean `mean` (CA-sim packet inter-arrival jitter).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.f64()).max(1e-300).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 5];
        let n = 100_000;
        for _ in 0..n {
            counts[r.below(5)] += 1;
        }
        for c in counts {
            let p = c as f64 / n as f64;
            assert!((p - 0.2).abs() < 0.01, "p={p}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        let s = r.sample_indices(100, 30);
        let mut d = s.clone();
        d.sort();
        d.dedup();
        assert_eq!(d.len(), 30);
    }

    #[test]
    fn fork_independent() {
        let mut base = Rng::new(1);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let m = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((m - 4.0).abs() < 0.1, "mean={m}");
    }
}
