//! Scoped thread-pool parallel map substrate (no `rayon`/`tokio` offline).
//!
//! The DSE coordinator evaluates candidate pools and Monte-Carlo yield
//! batches in parallel; a plain `std::thread::scope` work-stealing-by-chunks
//! map is all that's needed — tasks are coarse (whole design-point
//! evaluations) so stealing granularity doesn't matter.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads: `THESEUS_THREADS` env override, else
/// available_parallelism, else 4.
pub fn num_threads() -> usize {
    let default = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4);
    super::cli::env_usize("THESEUS_THREADS", default).max(1)
}

/// Parallel map over `items`, preserving order. `f` must be `Sync` and is
/// shared by reference across workers; items are claimed via an atomic
/// cursor so uneven task costs balance out.
pub fn par_map<T, U, F>(items: &[T], f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    par_map_workers(items, 0, f)
}

/// [`par_map`] with an explicit worker cap (`0` = the [`num_threads`]
/// default). Callers whose tasks are themselves parallel — the campaign
/// runner fans scenarios out here while each scenario's evaluation fans
/// strategies over its own `par_map` — use this to bound oversubscription
/// (`theseus campaign --jobs N`).
pub fn par_map_workers<T, U, F>(items: &[T], workers: usize, f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = if workers == 0 { num_threads() } else { workers }.min(n);
    if workers <= 1 {
        return items.iter().map(|x| f(x)).collect();
    }
    let cursor = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<U>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = f(&items[i]);
                // Each slot is claimed by exactly one worker via the
                // cursor, so a poisoned slot only means that worker's `f`
                // panicked mid-store — the value is still ours to write.
                *results[i].lock().unwrap_or_else(|p| p.into_inner()) = Some(out);
            });
        }
    });
    results
        .into_iter()
        // lint: allow(panic) the scope joins all workers and the cursor covers 0..n: every slot was written
        .map(|m| m.into_inner().unwrap_or_else(|p| p.into_inner()).expect("worker completed"))
        .collect()
}

/// Parallel map over an index range (for Monte-Carlo style loops where the
/// input is just a trial number).
pub fn par_map_idx<U, F>(n: usize, f: F) -> Vec<U>
where
    U: Send,
    F: Fn(usize) -> U + Sync,
{
    let idx: Vec<usize> = (0..n).collect();
    par_map(&idx, |&i| f(i))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let xs: Vec<usize> = (0..1000).collect();
        let ys = par_map(&xs, |&x| x * 2);
        assert_eq!(ys, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_input() {
        let xs: Vec<usize> = vec![];
        let ys: Vec<usize> = par_map(&xs, |&x| x);
        assert!(ys.is_empty());
    }

    #[test]
    fn uneven_costs() {
        // Items with wildly different costs still all complete, in order.
        let xs: Vec<usize> = (0..64).collect();
        let ys = par_map(&xs, |&x| {
            let mut acc = 0u64;
            for i in 0..(x * 1000) {
                acc = acc.wrapping_add(i as u64);
            }
            (x, acc)
        });
        for (i, (x, _)) in ys.iter().enumerate() {
            assert_eq!(i, *x);
        }
    }

    #[test]
    fn idx_variant() {
        let ys = par_map_idx(10, |i| i * i);
        assert_eq!(ys, vec![0, 1, 4, 9, 16, 25, 36, 49, 64, 81]);
    }

    #[test]
    fn explicit_worker_cap_preserves_results() {
        let xs: Vec<usize> = (0..100).collect();
        for workers in [1, 2, 7, 0] {
            let ys = par_map_workers(&xs, workers, |&x| x + 1);
            assert_eq!(ys, (1..=100).collect::<Vec<_>>(), "workers={workers}");
        }
    }
}
