//! Small statistics helpers shared by the evaluator, explorer and bench
//! harness: summary stats, percentiles, Kendall's τ (Fig. 7b), and the
//! standard-normal pdf/cdf used by EHVI.

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100), linear interpolation, sorted copy internally.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Mean absolute percentage error of `pred` against `truth` (Fig. 7b
/// "error rate"). Entries with |truth| < eps are skipped.
pub fn mape(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    let mut total = 0.0;
    let mut n = 0usize;
    for (&p, &t) in pred.iter().zip(truth) {
        if t.abs() > 1e-12 {
            total += ((p - t) / t).abs();
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        total / n as f64
    }
}

/// Kendall's τ-b rank correlation. O(n²) pair counting — fine for the
/// dataset sizes we validate on (≤ a few thousand).
///
/// τ-b handles ties in either ranking, matching how the paper compares
/// evaluator orderings against CA-sim ground truth.
pub fn kendall_tau(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    if n < 2 {
        return 1.0;
    }
    let (mut concordant, mut discordant) = (0i64, 0i64);
    let (mut ties_a, mut ties_b) = (0i64, 0i64);
    for i in 0..n {
        for j in (i + 1)..n {
            let da = a[i] - a[j];
            let db = b[i] - b[j];
            if da == 0.0 && db == 0.0 {
                // tied in both: counted in neither
            } else if da == 0.0 {
                ties_a += 1;
            } else if db == 0.0 {
                ties_b += 1;
            } else if (da > 0.0) == (db > 0.0) {
                concordant += 1;
            } else {
                discordant += 1;
            }
        }
    }
    let n0 = (n * (n - 1) / 2) as i64;
    let denom = (((n0 - ties_a) as f64) * ((n0 - ties_b) as f64)).sqrt();
    if denom == 0.0 {
        return 0.0;
    }
    (concordant - discordant) as f64 / denom
}

/// Standard normal PDF.
#[inline]
pub fn norm_pdf(x: f64) -> f64 {
    (-0.5 * x * x).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Standard normal CDF via erf (Abramowitz–Stegun 7.1.26 rational
/// approximation; |err| < 1.5e-7, plenty for EHVI acquisition ranking).
#[inline]
pub fn norm_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

#[inline]
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Geometric mean (used for cross-benchmark summary rows).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.max(1e-300).ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((std(&xs) - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(median(&xs), 2.5);
    }

    #[test]
    fn kendall_perfect() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [10.0, 20.0, 30.0, 40.0];
        assert!((kendall_tau(&a, &b) - 1.0).abs() < 1e-12);
        let c = [40.0, 30.0, 20.0, 10.0];
        assert!((kendall_tau(&a, &c) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn kendall_with_ties() {
        let a = [1.0, 1.0, 2.0, 3.0];
        let b = [1.0, 2.0, 3.0, 4.0];
        let t = kendall_tau(&a, &b);
        assert!(t > 0.8 && t < 1.0, "tau={t}");
    }

    #[test]
    fn kendall_uncorrelated_near_zero() {
        let mut r = crate::util::rng::Rng::new(17);
        let a: Vec<f64> = (0..500).map(|_| r.f64()).collect();
        let b: Vec<f64> = (0..500).map(|_| r.f64()).collect();
        assert!(kendall_tau(&a, &b).abs() < 0.1);
    }

    #[test]
    fn mape_basic() {
        let truth = [100.0, 200.0];
        let pred = [110.0, 180.0];
        assert!((mape(&pred, &truth) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn norm_cdf_sanity() {
        assert!((norm_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!((norm_cdf(1.96) - 0.975).abs() < 1e-3);
        assert!(norm_cdf(-8.0) < 1e-10);
        assert!((norm_pdf(0.0) - 0.39894228).abs() < 1e-6);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }
}
