//! Fixed-width ASCII table printer for the bench harness — every figure
//! regenerator prints "the same rows/series the paper reports" as a table
//! plus machine-readable JSON rows.

use crate::util::json::Json;

pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience row builder from display values.
    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| format!("{c}")).collect();
        self.row(&cells)
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let line = |out: &mut String| {
            for w in &widths {
                out.push('+');
                out.push_str(&"-".repeat(w + 2));
            }
            out.push_str("+\n");
        };
        let render_row = |out: &mut String, cells: &[String]| {
            for (c, w) in cells.iter().zip(&widths) {
                let pad = w - c.chars().count();
                out.push_str(&format!("| {}{} ", c, " ".repeat(pad)));
            }
            out.push_str("|\n");
        };
        line(&mut out);
        render_row(&mut out, &self.headers);
        line(&mut out);
        for row in &self.rows {
            render_row(&mut out, row);
        }
        line(&mut out);
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// Machine-readable form: {"title": ..., "rows": [{hdr: cell, ...}]}.
    /// Cells that parse as f64 are emitted as numbers.
    pub fn to_json(&self) -> Json {
        let mut rows = Vec::new();
        for row in &self.rows {
            let mut obj = Json::obj();
            for (h, c) in self.headers.iter().zip(row) {
                let v = match c.parse::<f64>() {
                    Ok(x) => Json::Num(x),
                    Err(_) => Json::Str(c.clone()),
                };
                obj.set(h, v);
            }
            rows.push(obj);
        }
        let mut out = Json::obj();
        out.set("title", Json::Str(self.title.clone()))
            .set("rows", Json::Arr(rows));
        out
    }
}

/// Human-friendly engineering formatter: 1234567 -> "1.23M".
pub fn eng(x: f64) -> String {
    let ax = x.abs();
    let (scale, suffix) = if ax >= 1e15 {
        (1e15, "P")
    } else if ax >= 1e12 {
        (1e12, "T")
    } else if ax >= 1e9 {
        (1e9, "G")
    } else if ax >= 1e6 {
        (1e6, "M")
    } else if ax >= 1e3 {
        (1e3, "K")
    } else {
        (1.0, "")
    };
    if suffix.is_empty() {
        format!("{:.3}", x)
    } else {
        format!("{:.2}{}", x / scale, suffix)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("| longer | 2.5   |"));
    }

    #[test]
    fn json_rows_typed() {
        let mut t = Table::new("demo", &["k", "v"]);
        t.row(&["x".into(), "3.5".into()]);
        let j = t.to_json();
        let rows = j.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows[0].get("v").unwrap().as_f64(), Some(3.5));
        assert_eq!(rows[0].get("k").unwrap().as_str(), Some("x"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(&["only one".into()]);
    }

    #[test]
    fn eng_format() {
        assert_eq!(eng(1_230_000.0), "1.23M");
        assert_eq!(eng(1.5e12), "1.50T");
        assert_eq!(eng(12.0), "12.000");
    }
}
