//! Tiny CLI argument parser substrate (no `clap` offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args;
//! typed getters with defaults; collects unknown-flag errors so binaries
//! can print usage. Used by the `theseus` binary, examples, and benches.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    seen: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Args {
        let mut args = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                    args.seen.push(k.to_string());
                } else {
                    // `--key value` unless the next token is another flag.
                    let takes_value = it
                        .peek()
                        .map(|n| !n.starts_with("--"))
                        .unwrap_or(false);
                    let v = if takes_value {
                        // lint: allow(panic) takes_value means peek() just saw the next token
                        it.next().unwrap()
                    } else {
                        "true".to_string()
                    };
                    args.flags.insert(name.to_string(), v);
                    args.seen.push(name.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.flags.get(key).cloned()
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn bool(&self, key: &str, default: bool) -> bool {
        match self.flags.get(key).map(|s| s.as_str()) {
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(_) => default,
            None => default,
        }
    }

    /// Subcommand = first positional arg.
    pub fn command(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

/// Registry of env vars that already triggered a malformed-value warning,
/// so each variable warns at most once per process (knobs like
/// `THESEUS_TILE_CACHE` are read on hot paths).
fn warned_env_vars() -> &'static std::sync::Mutex<std::collections::BTreeSet<String>> {
    static WARNED: std::sync::OnceLock<std::sync::Mutex<std::collections::BTreeSet<String>>> =
        std::sync::OnceLock::new();
    WARNED.get_or_init(|| std::sync::Mutex::new(std::collections::BTreeSet::new()))
}

/// Typed env-var reader shared by [`env_usize`]/[`env_u64`]/[`env_f64`]:
/// unset (or empty) falls back silently, but a *set-and-malformed* value
/// (e.g. `THESEUS_TILE_CACHE=64k`) emits a one-time stderr warning naming
/// the variable and the rejected value instead of silently ignoring it.
fn env_parse<T>(key: &str, default: T) -> T
where
    T: std::str::FromStr + std::fmt::Display,
{
    env_parse_raw(key, std::env::var(key).ok().as_deref(), default)
}

/// [`env_parse`] with the raw lookup result injected — the testable core
/// (tests feed values directly instead of mutating the process
/// environment, which is unsound under `cargo test`'s thread pool: setenv
/// racing getenv in another thread is UB on glibc).
fn env_parse_raw<T>(key: &str, raw: Option<&str>, default: T) -> T
where
    T: std::str::FromStr + std::fmt::Display,
{
    match raw {
        Some(raw) if !raw.is_empty() => match raw.parse() {
            Ok(v) => v,
            Err(_) => {
                let mut warned = warned_env_vars()
                    .lock()
                    .unwrap_or_else(|p| p.into_inner());
                if warned.insert(key.to_string()) {
                    eprintln!(
                        "warning: ignoring malformed env {key}={raw:?} (using default {default})"
                    );
                }
                default
            }
        },
        _ => default,
    }
}

/// Env-var override helper: benches read scale knobs like
/// `THESEUS_BO_ITERS` so `cargo bench` stays fast by default.
pub fn env_usize(key: &str, default: usize) -> usize {
    env_parse(key, default)
}

pub fn env_u64(key: &str, default: u64) -> u64 {
    env_parse(key, default)
}

pub fn env_f64(key: &str, default: f64) -> f64 {
    env_parse(key, default)
}

/// Boolean env knob (e.g. `THESEUS_TEST_FAST=1`): set and not
/// empty/`0`/`false` (case-insensitive) means on; unset means off.
pub fn env_flag(key: &str) -> bool {
    match std::env::var(key) {
        Ok(v) => {
            let v = v.trim();
            !(v.is_empty() || v == "0" || v.eq_ignore_ascii_case("false"))
        }
        Err(_) => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn key_value_forms() {
        let a = parse(&["run", "--iters", "10", "--model=gpt175b", "--verbose"]);
        assert_eq!(a.command(), Some("run"));
        assert_eq!(a.usize("iters", 0), 10);
        assert_eq!(a.str("model", ""), "gpt175b");
        assert!(a.bool("verbose", false));
        assert!(!a.has("absent"));
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.usize("n", 7), 7);
        assert_eq!(a.f64("x", 1.5), 1.5);
        assert_eq!(a.str("s", "d"), "d");
        assert_eq!(a.command(), None);
    }

    #[test]
    fn flag_before_positional() {
        let a = parse(&["--seed", "9", "eval"]);
        // "eval" is consumed as the value of --seed? No: 9 parses, eval is positional.
        assert_eq!(a.u64("seed", 0), 9);
        assert_eq!(a.command(), Some("eval"));
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = parse(&["cmd", "--fast"]);
        assert!(a.bool("fast", false));
    }

    #[test]
    fn env_malformed_value_warns_once_and_falls_back() {
        // Set-but-malformed values must fall back to the default AND land
        // in the one-time warning registry (previously they fell back
        // silently, hiding typos like `THESEUS_TILE_CACHE=64k`). The test
        // drives env_parse_raw directly — mutating the real process
        // environment would race getenv in concurrently running tests.
        assert_eq!(env_parse_raw("THESEUS_TEST_MALFORMED_USIZE", Some("64k"), 7usize), 7);
        // Second read: same fallback, and the registry already holds the
        // key so no duplicate warning is emitted.
        assert_eq!(env_parse_raw("THESEUS_TEST_MALFORMED_USIZE", Some("64k"), 9usize), 9);
        assert!(warned_env_vars()
            .lock()
            .unwrap()
            .contains("THESEUS_TEST_MALFORMED_USIZE"));

        assert_eq!(env_parse_raw("THESEUS_TEST_MALFORMED_U64", Some("12 months"), 3u64), 3);
        assert!(warned_env_vars()
            .lock()
            .unwrap()
            .contains("THESEUS_TEST_MALFORMED_U64"));

        assert_eq!(env_parse_raw("THESEUS_TEST_MALFORMED_F64", Some("fast"), 1.5f64), 1.5);

        // Valid values still parse; unset and empty stay silent defaults.
        assert_eq!(env_parse_raw("THESEUS_TEST_VALID_USIZE", Some("42"), 0usize), 42);
        assert_eq!(env_parse_raw("THESEUS_TEST_EMPTY_USIZE", Some(""), 5usize), 5);
        assert_eq!(env_parse_raw("THESEUS_TEST_UNSET_U64", None, 11u64), 11);
        assert!(!warned_env_vars()
            .lock()
            .unwrap()
            .contains("THESEUS_TEST_EMPTY_USIZE"));

        // And the public wrappers read the (untouched) real environment:
        // unset vars silently fall back.
        assert_eq!(env_usize("THESEUS_TEST_UNSET_NOBODY_SETS", 13), 13);
        assert_eq!(env_u64("THESEUS_TEST_UNSET_NOBODY_SETS", 17), 17);
        assert_eq!(env_f64("THESEUS_TEST_UNSET_NOBODY_SETS", 2.5), 2.5);
    }
}
