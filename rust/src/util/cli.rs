//! Tiny CLI argument parser substrate (no `clap` offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args;
//! typed getters with defaults; collects unknown-flag errors so binaries
//! can print usage. Used by the `theseus` binary, examples, and benches.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    seen: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Args {
        let mut args = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                    args.seen.push(k.to_string());
                } else {
                    // `--key value` unless the next token is another flag.
                    let takes_value = it
                        .peek()
                        .map(|n| !n.starts_with("--"))
                        .unwrap_or(false);
                    let v = if takes_value {
                        it.next().unwrap()
                    } else {
                        "true".to_string()
                    };
                    args.flags.insert(name.to_string(), v);
                    args.seen.push(name.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.flags.get(key).cloned()
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn bool(&self, key: &str, default: bool) -> bool {
        match self.flags.get(key).map(|s| s.as_str()) {
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(_) => default,
            None => default,
        }
    }

    /// Subcommand = first positional arg.
    pub fn command(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

/// Env-var override helper: benches read scale knobs like
/// `THESEUS_BO_ITERS` so `cargo bench` stays fast by default.
pub fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

pub fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Boolean env knob (e.g. `THESEUS_TEST_FAST=1`): set and not
/// empty/`0`/`false` (case-insensitive) means on; unset means off.
pub fn env_flag(key: &str) -> bool {
    match std::env::var(key) {
        Ok(v) => {
            let v = v.trim();
            !(v.is_empty() || v == "0" || v.eq_ignore_ascii_case("false"))
        }
        Err(_) => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn key_value_forms() {
        let a = parse(&["run", "--iters", "10", "--model=gpt175b", "--verbose"]);
        assert_eq!(a.command(), Some("run"));
        assert_eq!(a.usize("iters", 0), 10);
        assert_eq!(a.str("model", ""), "gpt175b");
        assert!(a.bool("verbose", false));
        assert!(!a.has("absent"));
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.usize("n", 7), 7);
        assert_eq!(a.f64("x", 1.5), 1.5);
        assert_eq!(a.str("s", "d"), "d");
        assert_eq!(a.command(), None);
    }

    #[test]
    fn flag_before_positional() {
        let a = parse(&["--seed", "9", "eval"]);
        // "eval" is consumed as the value of --seed? No: 9 parses, eval is positional.
        assert_eq!(a.u64("seed", 0), 9);
        assert_eq!(a.command(), Some("eval"));
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = parse(&["cmd", "--fast"]);
        assert!(a.bool("fast", false));
    }
}
