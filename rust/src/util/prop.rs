//! Lightweight property-testing substrate (no `proptest` offline).
//!
//! Mirrors the proptest methodology we'd otherwise use on coordinator
//! invariants: generate many random cases from a seeded [`Rng`], run the
//! property, and on failure report the case number + seed so the exact
//! input reproduces with `THESEUS_PROP_SEED=<seed>`. A simple numeric
//! shrink (halve toward a floor) is provided for integer-tuple cases.

use crate::util::rng::Rng;

/// Number of cases per property: `THESEUS_PROP_CASES` override, default 64
/// (fast enough that every module can afford several properties).
pub fn cases() -> usize {
    super::cli::env_usize("THESEUS_PROP_CASES", 64)
}

fn seed() -> u64 {
    super::cli::env_u64("THESEUS_PROP_SEED", 0xC0FFEE)
}

/// Run `prop` against `cases()` random inputs produced by `gen`.
/// `prop` returns `Err(msg)` to fail; the failing input's `Debug` form,
/// case index and seed are included in the panic message.
pub fn check<T: std::fmt::Debug, G, P>(name: &str, mut gen: G, prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    let base = seed();
    let mut rng = Rng::new(base);
    for case in 0..cases() {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            // lint: allow(panic) property-test substrate: panicking IS the failure report under #[test]
            panic!(
                "property '{name}' failed at case {case} (seed {base}):\n  input: {input:?}\n  {msg}"
            );
        }
    }
}

/// Shrinking variant for inputs that support `try_shrink`: on failure,
/// repeatedly ask the input for smaller candidates that still fail, and
/// report the minimal one found.
pub fn check_shrink<T, G, P, S>(name: &str, mut gen: G, prop: P, shrink: S)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
    S: Fn(&T) -> Vec<T>,
{
    let base = seed();
    let mut rng = Rng::new(base);
    for case in 0..cases() {
        let input = gen(&mut rng);
        if let Err(first_msg) = prop(&input) {
            // Greedy shrink loop, capped to avoid pathological generators.
            let mut best = input.clone();
            let mut best_msg = first_msg;
            let mut budget = 200;
            'outer: while budget > 0 {
                for cand in shrink(&best) {
                    budget -= 1;
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            // lint: allow(panic) property-test substrate: panicking IS the failure report under #[test]
            panic!(
                "property '{name}' failed at case {case} (seed {base}):\n  minimal input: {best:?}\n  {best_msg}"
            );
        }
    }
}

/// Standard shrinker for a vec of usizes: drop elements / halve values.
pub fn shrink_usizes(xs: &Vec<usize>) -> Vec<Vec<usize>> {
    let mut out = Vec::new();
    if xs.len() > 1 {
        let mut d = xs.clone();
        d.pop();
        out.push(d);
    }
    for i in 0..xs.len() {
        if xs[i] > 1 {
            let mut h = xs.clone();
            h[i] /= 2;
            out.push(h);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        check(
            "addition commutes",
            |r| (r.below(100), r.below(100)),
            |&(a, b)| {
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("no".into())
                }
            },
        );
        count += 1; // reached without panic
        assert_eq!(count, 1);
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_input() {
        check("always fails", |r| r.below(10), |_| Err("boom".into()));
    }

    #[test]
    #[should_panic(expected = "minimal input")]
    fn shrink_reports_minimal() {
        check_shrink(
            "len < 3",
            |r| (0..r.range(5, 10)).map(|i| i + 1).collect::<Vec<usize>>(),
            |v| {
                if v.len() < 3 {
                    Ok(())
                } else {
                    Err(format!("len={}", v.len()))
                }
            },
            shrink_usizes,
        );
    }
}
