//! Minimal JSON value type, writer and recursive-descent parser.
//!
//! Substrate module: the offline environment has no `serde`/`serde_json`,
//! and Theseus needs JSON for three interchange points — the NoC dataset
//! consumed by the Python GNN trainer, DSE result checkpoints, and bench
//! harness rows. This implements exactly the JSON subset those need
//! (no surrogate-pair escapes beyond \uXXXX decoding, numbers as f64).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a `BTreeMap` so output is deterministic —
/// important for reproducible artifacts and golden tests.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics if `self` is not an object
    /// (programming error, not data error).
    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Convenience: array of f64s.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()
            .map(|v| v.iter().filter_map(|x| x.as_f64()).collect())
    }

    pub fn from_f64_slice(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn from_usize_slice(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Pretty serialization with 2-space indent (for human-read artifacts).
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => write_num(*x, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        let pad = |out: &mut String, n: usize| {
            for _ in 0..n {
                out.push_str("  ");
            }
        };
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    pad(out, indent + 1);
                    x.write_pretty(out, indent + 1);
                }
                out.push('\n');
                pad(out, indent);
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    pad(out, indent + 1);
                    write_str(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                pad(out, indent);
                out.push('}');
            }
            _ => self.write(out),
        }
    }

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }
}

fn write_num(x: f64, out: &mut String) {
    if x.is_finite() {
        if x == x.trunc() && x.abs() < 1e15 {
            out.push_str(&format!("{}", x as i64));
        } else {
            out.push_str(&format!("{}", x));
        }
    } else {
        // JSON has no inf/nan; encode as null like most writers do.
        out.push_str("null");
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", word)))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 codepoint.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let Some(c) = rest.chars().next() else {
                        return Err(self.err("unterminated string"));
                    };
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1", "3.5", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2, {"b": "x\ny", "c": null}], "d": -2.5e3}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
        assert_eq!(v.get("d").unwrap().as_f64().unwrap(), -2500.0);
        assert_eq!(
            v.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("x\ny")
        );
    }

    #[test]
    fn escapes() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".to_string());
        let round = Json::parse(&v.to_string()).unwrap();
        assert_eq!(round, v);
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""é中""#).unwrap();
        assert_eq!(v.as_str(), Some("é中"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn pretty_parses_back() {
        let mut o = Json::obj();
        o.set("xs", Json::from_f64_slice(&[1.0, 2.0, 3.0]))
            .set("name", Json::Str("theseus".into()));
        let pretty = o.to_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), o);
    }

    #[test]
    fn as_obj_accessor() {
        let mut o = Json::obj();
        o.set("a", Json::Num(1.0));
        let m = o.as_obj().unwrap();
        assert_eq!(m.len(), 1);
        assert!(m.contains_key("a"));
        assert!(Json::Num(1.0).as_obj().is_none());
    }

    #[test]
    fn deterministic_object_order() {
        let mut o = Json::obj();
        o.set("z", Json::Num(1.0)).set("a", Json::Num(2.0));
        assert_eq!(o.to_string(), r#"{"a":2,"z":1}"#);
    }

    #[test]
    fn nonfinite_encodes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }
}
