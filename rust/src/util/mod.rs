//! Substrate utilities built in-repo because the offline environment has no
//! `serde`/`rand`/`clap`/`rayon`/`proptest` (see DESIGN.md §2): JSON, PRNG,
//! statistics, CLI parsing, a scoped thread pool, property-test helpers and
//! fixed-width table printing for the bench harness.

pub mod cli;
pub mod json;
pub mod memo;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
pub mod warn;
