//! Baseline designs (paper §IX-F): an H100 DGX cluster modeled with a
//! roofline + collectives model, and Cerebras WSE2 / Tesla Dojo
//! approximated as WSC configurations evaluated through the same pipeline,
//! all scaled to 14 nm as in §VIII-A ("All comparisons are made under the
//! same area").

pub mod gpu;

use crate::arch::{
    CoreConfig, Dataflow, IntegrationStyle, MemoryKind, ReticleConfig, WscConfig,
};
use crate::design_space::DesignPoint;

pub use gpu::{h100_train_eval, h100_infer_eval, GpuSpec};

/// H100 die area, mm² (used for the paper's equal-area system sizing; the
/// paper ignores yield and NVLink SerDes area for the GPU baseline).
pub const H100_DIE_MM2: f64 = 814.0;

/// Off-chip DRAM capacity provisioned per wafer-edge memory controller
/// (GB) — DDR-class DIMM per channel.
pub const OFFCHIP_GB_PER_CTRL: f64 = 128.0;

/// Cerebras WSE2 approximated on our grids (§II-B: 850 000 tiny cores,
/// 40 GB SRAM, die-stitched, no DRAM). With 84 reticle-scale exposures,
/// per-reticle ≈ 10 000 cores of ~48 KB SRAM; our reticle floorplan fits
/// 900 cores/reticle of 8 MACs + 64 KB (totals match within an order, and the
/// *structure* — sea of small SRAM-rich cores, stitched fabric, SRAM-only
/// memory — is what drives its evaluation behaviour).
pub fn wse2_like() -> DesignPoint {
    DesignPoint::homogeneous(WscConfig {
        reticle: ReticleConfig {
            core: CoreConfig {
                dataflow: Dataflow::WS,
                mac_num: 8,
                buffer_kb: 64,
                buffer_bw_bits: 128,
                noc_bw_bits: 256,
            },
            array_h: 30,
            array_w: 30,
            inter_reticle_bw_ratio: 1.0,
            memory: MemoryKind::OffChip,
        },
        reticle_h: 9,
        reticle_w: 9,
        integration: IntegrationStyle::DieStitching,
        mem_ctrl_count: 12, // MemoryX-style edge streaming
        nic_count: 12,
    })
}

/// Tesla Dojo approximated on our grids (§II-B: 25 D1 dies, 1.25 MB
/// SRAM/core, ~1 TFLOP bf16/core, InFO-SoW with KGD, HBM at the wafer
/// edge). Our 14 nm component models fit 225 such cores per reticle
/// (D1 packs 354 at a denser custom layout); the structure — few big
/// SRAM-heavy cores, KGD, RDL SerDes, edge DRAM — is preserved.
pub fn dojo_like() -> DesignPoint {
    DesignPoint::homogeneous(WscConfig {
        reticle: ReticleConfig {
            core: CoreConfig {
                dataflow: Dataflow::OS,
                mac_num: 512,
                buffer_kb: 1024,
                buffer_bw_bits: 2048,
                noc_bw_bits: 1024,
            },
            array_h: 15,
            array_w: 15,
            inter_reticle_bw_ratio: 0.6,
            memory: MemoryKind::OffChip,
        },
        reticle_h: 5,
        reticle_w: 5,
        integration: IntegrationStyle::InfoSoW,
        mem_ctrl_count: 20, // edge HBM
        nic_count: 16,
    })
}

/// Validate a baseline, relaxing the yield/power gates the way the paper
/// does for existing designs (they shipped, after all): on a yield or
/// power violation we keep the physical characterization anyway.
pub fn force_validate(p: &DesignPoint) -> crate::design_space::Validated {
    match crate::design_space::validate(p) {
        Ok(v) => v,
        Err(_) => {
            // Rebuild phys with the maximum redundancy the floorplan
            // allows, accepting whatever yield results.
            let phys = crate::components::estimator::wafer_phys_relaxed(&p.wsc)
                .expect("baseline must at least floorplan");
            crate::design_space::Validated { point: *p, phys }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baselines_characterize() {
        for p in [wse2_like(), dojo_like()] {
            let v = force_validate(&p);
            assert!(v.phys.peak_flops > 1e15, "peak={:.3e}", v.phys.peak_flops);
            assert!(v.phys.area_mm2 > 10_000.0);
        }
    }

    #[test]
    fn wse2_structure() {
        let p = wse2_like();
        // Sea of tiny SRAM-rich cores, no DRAM, stitched.
        assert!(p.wsc.num_cores() > 50_000);
        assert_eq!(p.wsc.total_stacking_bytes(), 0.0);
        assert_eq!(p.wsc.integration, IntegrationStyle::DieStitching);
        // Total SRAM within 2x of 40 GB.
        let sram_gb = p.wsc.total_sram_bytes() / 1e9;
        assert!(sram_gb > 4.0 && sram_gb < 80.0, "sram={sram_gb}GB");
    }

    #[test]
    fn dojo_structure() {
        let p = dojo_like();
        // 25 big-core dies with KGD.
        assert_eq!(p.wsc.num_reticles(), 25);
        assert_eq!(p.wsc.integration, IntegrationStyle::InfoSoW);
        // ~230 TFLOP/reticle, same order as D1's 362 TFLOPS bf16 (see
        // the doc comment on the density approximation).
        let tflops = p.wsc.reticle.peak_flops() / 1e12;
        assert!(tflops > 150.0 && tflops < 400.0, "reticle={tflops}TF");
    }
}
