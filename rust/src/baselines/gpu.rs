//! GPU-cluster baseline (paper Fig. 11/13: "H100 baseline with the same
//! area"). A roofline + collectives model in the same output terms as the
//! WSC evaluator, with datasheet parameters scaled to the paper's 14 nm
//! reference where that matters (area, power ordering).

use crate::arch::constants as k;
use crate::eval::chunk::{Breakdown, InferEval, TrainEval};
use crate::workload::parallel::{enumerate_strategies, SystemMemory};
use crate::workload::{LlmSpec, ParallelStrategy};

/// GPU device parameters.
#[derive(Debug, Clone, Copy)]
pub struct GpuSpec {
    pub name: &'static str,
    /// Peak dense bf16, FLOP/s.
    pub peak_flops: f64,
    /// HBM bandwidth, bytes/s.
    pub hbm_bw: f64,
    /// HBM capacity, bytes.
    pub hbm_cap: f64,
    /// NVLink bandwidth per GPU (aggregate, one direction), bytes/s.
    pub nvlink_bw: f64,
    /// Inter-node network bandwidth per GPU (InfiniBand NDR class),
    /// bytes/s — PP/DP collectives beyond the 8-GPU NVLink island pay this.
    pub internode_bw: f64,
    /// Board power, W.
    pub tdp_w: f64,
    /// Die area, mm².
    pub die_mm2: f64,
    /// Achievable MFU for dense training at scale (Megatron-class).
    pub train_mfu: f64,
}

/// NVIDIA H100 SXM (DGX), per §IX-F's baseline, **scaled to the paper's
/// 14 nm reference node** (§VIII-A/§IX-F: "both area and power values for
/// existing designs scaled to 14nm"): the 4 nm die's compute is derated by
/// the ~4x logic-density gap (two node generations, Villa et al. scaling)
/// so the equal-area comparison is apples-to-apples. HBM (external DRAM)
/// keeps its datasheet bandwidth; the paper's 0.2 TB/s/100 mm² density
/// note already reflects the die area.
pub fn h100() -> GpuSpec {
    GpuSpec {
        name: "H100",
        peak_flops: 989e12 / 4.0,
        hbm_bw: 3.35e12,
        hbm_cap: 80e9,
        nvlink_bw: 450e9,
        internode_bw: 50e9,
        tdp_w: 700.0,
        die_mm2: super::H100_DIE_MM2,
        train_mfu: 0.45,
    }
}

/// Training throughput of an `n_gpus` cluster (Megatron-style 3-D
/// parallelism, same strategy space as the WSC evaluator).
pub fn h100_train_eval(spec: &LlmSpec, n_gpus: usize) -> Option<TrainEval> {
    let g = h100();
    let mem = SystemMemory {
        sram_bytes: 0.0,
        stacking_bytes: n_gpus as f64 * g.hbm_cap, // weights live in HBM
        offchip_bytes: 0.0,
        total_cores: n_gpus,
    };
    let strategies = enumerate_strategies(spec, &mem);
    let best = strategies
        .into_iter()
        .filter(|s| s.num_chunks() <= n_gpus)
        .filter_map(|s| step_time(spec, &g, n_gpus, s))
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())?;
    let (s, step) = best;
    let tokens = (spec.batch_size * spec.seq_len) as f64;
    // Energy: flops at e_mac-equivalent (GPU 14nm-scaled ≈ 0.8 pJ/flop
    // effective incl. datapath overheads) + HBM traffic + static fraction.
    let flops_step = spec.train_flops_per_token() * tokens;
    let hbm_bytes = flops_step / g.peak_flops * g.hbm_bw * 0.5 * n_gpus as f64 / n_gpus as f64;
    let e_dyn = flops_step * 0.4e-12 + hbm_bytes * 8.0 * 7.0e-12;
    let e_static = 0.35 * g.tdp_w * n_gpus as f64 * step;
    let energy = e_dyn + e_static;
    Some(TrainEval {
        strategy: s,
        step_time_s: step,
        tokens_per_sec: tokens / step,
        power_w: energy / step,
        energy_per_token_j: energy / tokens,
        edp: energy * step,
        breakdown: Breakdown::default(),
    })
}

fn step_time(
    spec: &LlmSpec,
    g: &GpuSpec,
    n_gpus: usize,
    s: ParallelStrategy,
) -> Option<(ParallelStrategy, f64)> {
    let tokens_mb = (s.microbatch * spec.seq_len) as f64;
    let flops_mb_stage =
        spec.train_flops_per_token() * tokens_mb / (s.pp as f64 * s.tp as f64);
    let gpus_per_chunk = (n_gpus as f64 / s.num_chunks() as f64).max(1.0);
    let t_compute = flops_mb_stage / (g.peak_flops * g.train_mfu * gpus_per_chunk);

    let bpe = k::BYTES_PER_ELEM;
    let msh = tokens_mb * spec.hidden as f64 * bpe;
    // TP all-reduce over NVLink: 4/layer.
    let t_tp = if s.tp == 1 {
        0.0
    } else {
        4.0 * s.layers_per_stage(spec) as f64
            * (2.0 * (s.tp as f64 - 1.0) / s.tp as f64 * msh)
            / g.nvlink_bw
    };
    // PP boundaries and DP rings cross NVLink islands (8 GPUs) at scale.
    let cross_node = s.num_chunks() > 8;
    let net_bw = if cross_node { g.internode_bw } else { g.nvlink_bw };
    let t_pp = if s.pp == 1 { 0.0 } else { 2.0 * msh / s.tp as f64 / net_bw };
    // HBM weight streaming per microbatch (weights don't fit in SRAM).
    let stage_weights = spec.param_bytes() / (s.tp * s.pp) as f64;
    let t_hbm = stage_weights / (g.hbm_bw * gpus_per_chunk);
    let t_mb = t_compute.max(t_hbm) + t_tp + t_pp;

    let mb = s.microbatches_per_step(spec) as f64;
    let grad_bytes = 2.0 * (s.dp as f64 - 1.0) / s.dp as f64 * stage_weights;
    let t_dp = if s.dp == 1 { 0.0 } else { grad_bytes / (net_bw * 0.5) };
    let step = (mb + s.pp as f64 - 1.0) * t_mb + t_dp;
    if step.is_finite() && step > 0.0 {
        Some((s, step))
    } else {
        None
    }
}

/// Inference on the GPU cluster: prefill compute-bound at high MFU, decode
/// HBM-bound streaming weights + KV per token (the §IX-D observation that
/// decode under small batch under-utilizes GPU compute).
pub fn h100_infer_eval(spec: &LlmSpec, n_gpus: usize, batch: usize, mqa: bool) -> Option<InferEval> {
    let g = h100();
    let weights = spec.param_bytes();
    let kv = spec.kv_cache_bytes_per_seq(mqa) * batch as f64;
    if weights + kv > n_gpus as f64 * g.hbm_cap {
        return None;
    }
    let prefill_flops = spec.fwd_flops_per_token() * (batch * spec.seq_len) as f64;
    let prefill_s = prefill_flops / (g.peak_flops * 0.55 * n_gpus as f64);

    let decode_bytes = weights + kv;
    let decode_mem_s = decode_bytes / (g.hbm_bw * n_gpus as f64);
    let decode_flops = spec.fwd_flops_per_token() * batch as f64;
    // Batched GEMV achieves moderate utilization; decode stays HBM-bound
    // (the §IX-D premise).
    let decode_compute_s = decode_flops / (g.peak_flops * 0.3 * n_gpus as f64);
    let decode_step_s = decode_mem_s.max(decode_compute_s);

    let out_tokens = spec.seq_len as f64;
    let total_s = prefill_s + out_tokens * decode_step_s;
    let energy = 0.5 * g.tdp_w * n_gpus as f64 * total_s; // ~50 % of TDP at decode
    Some(InferEval {
        prefill_s,
        decode_step_s,
        tokens_per_sec: batch as f64 * out_tokens / total_s,
        power_w: energy / total_s,
        residency: "hbm",
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::benchmarks;

    #[test]
    fn h100_cluster_trains_gpt3() {
        let spec = &benchmarks()[7];
        let r = h100_train_eval(spec, 1000).expect("gpt3 on 1000 H100s");
        // Sane MFU-bounded throughput: tokens/s under cluster roofline.
        let roofline = 1000.0 * 989e12 / spec.train_flops_per_token();
        assert!(r.tokens_per_sec < roofline);
        assert!(r.tokens_per_sec > roofline * 0.03);
    }

    #[test]
    fn decode_is_memory_bound() {
        let spec = &benchmarks()[7];
        let r = h100_infer_eval(spec, 16, 32, false).unwrap();
        let mem_s = (spec.param_bytes() + spec.kv_cache_bytes_per_seq(false) * 32.0)
            / (3.35e12 * 16.0);
        assert!((r.decode_step_s - mem_s).abs() / mem_s < 0.5);
    }

    #[test]
    fn infer_requires_capacity() {
        let spec = &benchmarks()[9]; // 530B needs > 8 H100s even for weights
        assert!(h100_infer_eval(spec, 8, 32, false).is_none());
    }

    #[test]
    fn mqa_helps_gpu_decode_too() {
        let spec = &benchmarks()[7];
        let a = h100_infer_eval(spec, 16, 32, false).unwrap();
        let b = h100_infer_eval(spec, 16, 32, true).unwrap();
        assert!(b.decode_step_s < a.decode_step_s);
    }
}
