//! Dimension-order (XY) routing on the 2-D core mesh (paper §VI-A step 4),
//! plus fault-aware table routing for degraded meshes.
//!
//! Links are identified by their *upstream* router and direction, giving a
//! dense index space `core_count × 4` shared by the analytical model, the
//! GNN feature builder and the CA simulator.
//!
//! Pristine meshes route XY ([`for_each_link_xy`]). When a
//! [`FaultMap`](crate::yield_model::faults::FaultMap) kills routers or
//! links, a precomputed [`RouteTable`] supplies deterministic shortest
//! paths over the live subgraph (reverse BFS per destination, fixed
//! direction-order tie-break), detouring around faults; disconnected pairs
//! surface as a loud [`RouteError`] instead of silently wrong routes.

use std::collections::VecDeque;

use crate::yield_model::faults::FaultMap;

/// Link direction out of a router.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dir {
    East = 0,
    West = 1,
    South = 2,
    North = 3,
}

pub const NUM_DIRS: usize = 4;

/// A directed mesh link: from router `(row, col)` toward `dir`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LinkId {
    pub row: usize,
    pub col: usize,
    pub dir: Dir,
}

impl LinkId {
    /// The router this link feeds into.
    pub fn downstream(&self) -> (usize, usize) {
        match self.dir {
            Dir::East => (self.row, self.col + 1),
            Dir::West => (self.row, self.col - 1),
            Dir::South => (self.row + 1, self.col),
            Dir::North => (self.row - 1, self.col),
        }
    }
}

/// Dense index of a link for a mesh of `width` columns.
#[inline]
pub fn link_index(l: LinkId, width: usize) -> usize {
    (l.row * width + l.col) * NUM_DIRS + l.dir as usize
}

/// XY route: traverse X (columns) first, then Y (rows). Returns the ordered
/// list of links; empty when src == dst.
pub fn route_xy(src: (usize, usize), dst: (usize, usize)) -> Vec<LinkId> {
    let mut links = Vec::with_capacity(hops(src, dst));
    for_each_link_xy(src, dst, |l| links.push(l));
    links
}

/// Allocation-free XY route traversal — the op-level evaluator calls this
/// hundreds of thousands of times per DSE iteration (§Perf hot path).
#[inline]
pub fn for_each_link_xy(src: (usize, usize), dst: (usize, usize), mut f: impl FnMut(LinkId)) {
    let (mut r, mut c) = src;
    while c != dst.1 {
        let dir = if dst.1 > c { Dir::East } else { Dir::West };
        f(LinkId { row: r, col: c, dir });
        c = if dst.1 > c { c + 1 } else { c - 1 };
    }
    while r != dst.0 {
        let dir = if dst.0 > r { Dir::South } else { Dir::North };
        f(LinkId { row: r, col: c, dir });
        r = if dst.0 > r { r + 1 } else { r - 1 };
    }
}

/// Manhattan hop count.
pub fn hops(src: (usize, usize), dst: (usize, usize)) -> usize {
    src.0.abs_diff(dst.0) + src.1.abs_diff(dst.1)
}

/// Routing failure on a degraded mesh.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouteError {
    /// No live path connects `src` to `dst` — the fault map partitioned
    /// the mesh (or an endpoint is itself dead).
    Disconnected {
        src: (usize, usize),
        dst: (usize, usize),
    },
}

impl std::fmt::Display for RouteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            RouteError::Disconnected { src, dst } => write!(
                f,
                "no live route from core ({}, {}) to core ({}, {}): the fault map \
                 disconnects the mesh",
                src.0, src.1, dst.0, dst.1
            ),
        }
    }
}

impl std::error::Error for RouteError {}

/// Port code: the packet has arrived (maps onto the simulator's LOCAL port).
const PORT_ARRIVED: u8 = 4;
/// Port code: destination unreachable from this node.
const PORT_NONE: u8 = u8::MAX;

/// Deterministic fault-aware routing table for one `h × w` mesh.
///
/// Built once per fault map by a reverse BFS from every live destination
/// over the live subgraph (a link is usable iff the link itself and both
/// endpoint routers are alive). BFS explores upstream neighbors in fixed
/// [`Dir`] order, so ties between equal-length detours resolve identically
/// on every run — the bit-identical `SimStats` contract extends to
/// irregular meshes. Lookup is O(1) per hop: `next[dst * n + at]` holds
/// the output direction at router `at` for packets bound for `dst`.
pub struct RouteTable {
    h: usize,
    w: usize,
    next: Vec<u8>,
    dist: Vec<u32>,
}

impl RouteTable {
    /// Build the table for a fault map (O(n²) space, O(n²) time).
    pub fn build(map: &FaultMap) -> RouteTable {
        let (h, w) = map.dims();
        let n = h * w;
        let mut next = vec![PORT_NONE; n * n];
        let mut dist = vec![u32::MAX; n * n];
        let mut queue = VecDeque::new();
        for dst in 0..n {
            if !map.core_ok(dst / w, dst % w) {
                continue;
            }
            let base = dst * n;
            next[base + dst] = PORT_ARRIVED;
            dist[base + dst] = 0;
            queue.clear();
            queue.push_back(dst);
            while let Some(u) = queue.pop_front() {
                let (ur, uc) = (u / w, u % w);
                let du = dist[base + u];
                // Upstream neighbors v whose link v --dir--> u is usable,
                // explored in fixed Dir order (deterministic tie-break).
                for dir in [Dir::East, Dir::West, Dir::South, Dir::North] {
                    // v sits opposite `dir` relative to u.
                    let (vr, vc) = match dir {
                        Dir::East if uc > 0 => (ur, uc - 1),
                        Dir::West if uc + 1 < w => (ur, uc + 1),
                        Dir::South if ur > 0 => (ur - 1, uc),
                        Dir::North if ur + 1 < h => (ur + 1, uc),
                        _ => continue,
                    };
                    if !map.core_ok(vr, vc) || !map.link_intact(vr, vc, dir as usize) {
                        continue;
                    }
                    let v = vr * w + vc;
                    if dist[base + v] == u32::MAX {
                        dist[base + v] = du + 1;
                        next[base + v] = dir as u8;
                        queue.push_back(v);
                    }
                }
            }
        }
        RouteTable { h, w, next, dist }
    }

    pub fn dims(&self) -> (usize, usize) {
        (self.h, self.w)
    }

    /// Output port index at `at` for a packet bound for `dst`: a `Dir`
    /// value in 0..4, or 4 ("local", matches the simulators' LOCAL port)
    /// when `at == dst`. Must only be called on reachable pairs.
    #[inline]
    pub fn port_index(&self, at: (usize, usize), dst: (usize, usize)) -> usize {
        let n = self.h * self.w;
        let code = self.next[(dst.0 * self.w + dst.1) * n + at.0 * self.w + at.1];
        debug_assert_ne!(code, PORT_NONE, "routing toward unreachable dst {dst:?}");
        code as usize
    }

    pub fn reachable(&self, src: (usize, usize), dst: (usize, usize)) -> bool {
        let n = self.h * self.w;
        self.dist[(dst.0 * self.w + dst.1) * n + src.0 * self.w + src.1] != u32::MAX
    }

    /// Path length in hops, `None` when disconnected.
    pub fn hops(&self, src: (usize, usize), dst: (usize, usize)) -> Option<usize> {
        let n = self.h * self.w;
        match self.dist[(dst.0 * self.w + dst.1) * n + src.0 * self.w + src.1] {
            u32::MAX => None,
            d => Some(d as usize),
        }
    }

    /// Allocation-free traversal of the table route (the fault-path
    /// counterpart of [`for_each_link_xy`]).
    pub fn for_each_link(
        &self,
        src: (usize, usize),
        dst: (usize, usize),
        mut f: impl FnMut(LinkId),
    ) -> Result<(), RouteError> {
        if !self.reachable(src, dst) {
            return Err(RouteError::Disconnected { src, dst });
        }
        let mut cur = src;
        while cur != dst {
            let dir = match self.port_index(cur, dst) {
                0 => Dir::East,
                1 => Dir::West,
                2 => Dir::South,
                3 => Dir::North,
                p => unreachable!("non-mesh port {p} mid-route"),
            };
            let l = LinkId {
                row: cur.0,
                col: cur.1,
                dir,
            };
            f(l);
            cur = l.downstream();
        }
        Ok(())
    }

    /// Materialized route (convenience; hot paths use [`Self::for_each_link`]).
    pub fn route(
        &self,
        src: (usize, usize),
        dst: (usize, usize),
    ) -> Result<Vec<LinkId>, RouteError> {
        let mut links = Vec::new();
        self.for_each_link(src, dst, |l| links.push(l))?;
        Ok(links)
    }
}

impl std::fmt::Debug for RouteTable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "RouteTable({}x{})", self.h, self.w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn route_length_is_manhattan() {
        let path = route_xy((0, 0), (3, 4));
        assert_eq!(path.len(), 7);
        assert_eq!(hops((0, 0), (3, 4)), 7);
    }

    #[test]
    fn route_is_contiguous_and_x_first() {
        let path = route_xy((2, 5), (4, 1));
        // X-first: all E/W links precede S/N links.
        let first_y = path
            .iter()
            .position(|l| matches!(l.dir, Dir::South | Dir::North))
            .unwrap();
        assert!(path[..first_y]
            .iter()
            .all(|l| matches!(l.dir, Dir::East | Dir::West)));
        // Contiguity: each link's downstream is the next link's router.
        let mut cur = (2, 5);
        for l in &path {
            assert_eq!((l.row, l.col), cur);
            cur = l.downstream();
        }
        assert_eq!(cur, (4, 1));
    }

    #[test]
    fn self_route_empty() {
        assert!(route_xy((3, 3), (3, 3)).is_empty());
    }

    #[test]
    fn link_index_dense_unique() {
        let w = 6;
        let mut seen = std::collections::HashSet::new();
        for r in 0..4 {
            for c in 0..w {
                for dir in [Dir::East, Dir::West, Dir::South, Dir::North] {
                    let idx = link_index(LinkId { row: r, col: c, dir }, w);
                    assert!(seen.insert(idx), "collision at {idx}");
                    assert!(idx < 4 * w * NUM_DIRS);
                }
            }
        }
    }

    #[test]
    fn table_matches_xy_lengths_on_pristine_mesh() {
        let map = FaultMap::pristine(5, 7);
        let t = RouteTable::build(&map);
        for src in [(0, 0), (2, 3), (4, 6)] {
            for dst in [(0, 0), (4, 0), (1, 5)] {
                assert_eq!(t.hops(src, dst), Some(hops(src, dst)));
                let path = t.route(src, dst).unwrap();
                assert_eq!(path.len(), hops(src, dst));
            }
        }
    }

    #[test]
    fn table_detours_around_dead_router() {
        // Kill the single middle core of a 3x3 mesh: corner-to-corner
        // routes must detour (same length — Manhattan is preserved on a
        // mesh with one interior hole) and never touch the dead router.
        let mut map = FaultMap::pristine(3, 3);
        map.kill_core(1, 1);
        let t = RouteTable::build(&map);
        let path = t.route((0, 0), (2, 2)).unwrap();
        assert_eq!(path.len(), 4);
        for l in &path {
            assert_ne!((l.row, l.col), (1, 1));
            assert_ne!(l.downstream(), (1, 1));
        }
    }

    #[test]
    fn disconnected_pair_is_a_loud_error() {
        // Sever column 0 from the rest of a 2x2 mesh in both directions.
        let mut map = FaultMap::pristine(2, 2);
        for r in 0..2 {
            map.kill_link(r, 0, Dir::East as usize);
            map.kill_link(r, 1, Dir::West as usize);
        }
        map.kill_link(0, 0, Dir::South as usize);
        map.kill_link(1, 0, Dir::North as usize);
        map.kill_link(0, 1, Dir::South as usize);
        map.kill_link(1, 1, Dir::North as usize);
        // (0,0)-(1,0) still connected? No: their vertical links are dead
        // too, so (0,0) is isolated.
        let t = RouteTable::build(&map);
        assert!(!t.reachable((0, 0), (0, 1)));
        let err = t.route((0, 0), (0, 1)).unwrap_err();
        assert_eq!(
            err,
            RouteError::Disconnected {
                src: (0, 0),
                dst: (0, 1)
            }
        );
        assert!(format!("{err}").contains("disconnects the mesh"), "{err}");
    }

    #[test]
    fn prop_fault_routes_avoid_faults_and_stay_contiguous() {
        // ISSUE 6 satellite: fault-aware routes never traverse a dead link
        // or dead router, stay contiguous, and match the table's distance.
        crate::util::prop::check(
            "fault-aware routes avoid faults",
            |rng| {
                let h = rng.range(2, 8);
                let w = rng.range(2, 8);
                let grid = vec![vec![rng.uniform(0.7, 0.98); w]; h];
                let map = FaultMap::sample(&grid, rng.uniform(0.0, 2.0), rng.next_u64());
                let src = (rng.below(h), rng.below(w));
                let dst = (rng.below(h), rng.below(w));
                (map, src, dst)
            },
            |(map, src, dst)| {
                let t = RouteTable::build(map);
                let path = match t.route(*src, *dst) {
                    Ok(p) => p,
                    Err(RouteError::Disconnected { .. }) => {
                        // Disconnection must be consistent with the map: a
                        // dead endpoint always disconnects.
                        if map.core_ok(src.0, src.1)
                            && map.core_ok(dst.0, dst.1)
                            && src == dst
                        {
                            return Err("self-route on a live core cannot disconnect".into());
                        }
                        return Ok(());
                    }
                };
                if path.len() != t.hops(*src, *dst).unwrap() {
                    return Err("route length != table distance".into());
                }
                if path.len() < hops(*src, *dst) {
                    return Err("shorter than Manhattan".into());
                }
                let mut cur = *src;
                for l in &path {
                    if (l.row, l.col) != cur {
                        return Err("discontiguous".into());
                    }
                    if !map.core_ok(l.row, l.col) {
                        return Err(format!("route through dead router ({}, {})", l.row, l.col));
                    }
                    if !map.link_intact(l.row, l.col, l.dir as usize) {
                        return Err(format!("route over dead link {l:?}"));
                    }
                    cur = l.downstream();
                    if !map.core_ok(cur.0, cur.1) {
                        return Err(format!("route into dead router {cur:?}"));
                    }
                }
                if cur != *dst {
                    return Err("wrong endpoint".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_route_endpoints() {
        crate::util::prop::check(
            "xy route goes src->dst",
            |rng| {
                let h = rng.range(1, 16);
                let w = rng.range(1, 16);
                let src = (rng.below(h), rng.below(w));
                let dst = (rng.below(h), rng.below(w));
                (src, dst)
            },
            |&(src, dst)| {
                let path = route_xy(src, dst);
                if path.len() != hops(src, dst) {
                    return Err("length != manhattan".into());
                }
                let mut cur = src;
                for l in &path {
                    if (l.row, l.col) != cur {
                        return Err("discontiguous".into());
                    }
                    cur = l.downstream();
                }
                if cur != dst {
                    return Err("wrong endpoint".into());
                }
                Ok(())
            },
        );
    }
}
