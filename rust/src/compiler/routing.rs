//! Dimension-order (XY) routing on the 2-D core mesh (paper §VI-A step 4).
//!
//! Links are identified by their *upstream* router and direction, giving a
//! dense index space `core_count × 4` shared by the analytical model, the
//! GNN feature builder and the CA simulator.

/// Link direction out of a router.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dir {
    East = 0,
    West = 1,
    South = 2,
    North = 3,
}

pub const NUM_DIRS: usize = 4;

/// A directed mesh link: from router `(row, col)` toward `dir`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LinkId {
    pub row: usize,
    pub col: usize,
    pub dir: Dir,
}

impl LinkId {
    /// The router this link feeds into.
    pub fn downstream(&self) -> (usize, usize) {
        match self.dir {
            Dir::East => (self.row, self.col + 1),
            Dir::West => (self.row, self.col - 1),
            Dir::South => (self.row + 1, self.col),
            Dir::North => (self.row - 1, self.col),
        }
    }
}

/// Dense index of a link for a mesh of `width` columns.
#[inline]
pub fn link_index(l: LinkId, width: usize) -> usize {
    (l.row * width + l.col) * NUM_DIRS + l.dir as usize
}

/// XY route: traverse X (columns) first, then Y (rows). Returns the ordered
/// list of links; empty when src == dst.
pub fn route_xy(src: (usize, usize), dst: (usize, usize)) -> Vec<LinkId> {
    let mut links = Vec::with_capacity(hops(src, dst));
    for_each_link_xy(src, dst, |l| links.push(l));
    links
}

/// Allocation-free XY route traversal — the op-level evaluator calls this
/// hundreds of thousands of times per DSE iteration (§Perf hot path).
#[inline]
pub fn for_each_link_xy(src: (usize, usize), dst: (usize, usize), mut f: impl FnMut(LinkId)) {
    let (mut r, mut c) = src;
    while c != dst.1 {
        let dir = if dst.1 > c { Dir::East } else { Dir::West };
        f(LinkId { row: r, col: c, dir });
        c = if dst.1 > c { c + 1 } else { c - 1 };
    }
    while r != dst.0 {
        let dir = if dst.0 > r { Dir::South } else { Dir::North };
        f(LinkId { row: r, col: c, dir });
        r = if dst.0 > r { r + 1 } else { r - 1 };
    }
}

/// Manhattan hop count.
pub fn hops(src: (usize, usize), dst: (usize, usize)) -> usize {
    src.0.abs_diff(dst.0) + src.1.abs_diff(dst.1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn route_length_is_manhattan() {
        let path = route_xy((0, 0), (3, 4));
        assert_eq!(path.len(), 7);
        assert_eq!(hops((0, 0), (3, 4)), 7);
    }

    #[test]
    fn route_is_contiguous_and_x_first() {
        let path = route_xy((2, 5), (4, 1));
        // X-first: all E/W links precede S/N links.
        let first_y = path
            .iter()
            .position(|l| matches!(l.dir, Dir::South | Dir::North))
            .unwrap();
        assert!(path[..first_y]
            .iter()
            .all(|l| matches!(l.dir, Dir::East | Dir::West)));
        // Contiguity: each link's downstream is the next link's router.
        let mut cur = (2, 5);
        for l in &path {
            assert_eq!((l.row, l.col), cur);
            cur = l.downstream();
        }
        assert_eq!(cur, (4, 1));
    }

    #[test]
    fn self_route_empty() {
        assert!(route_xy((3, 3), (3, 3)).is_empty());
    }

    #[test]
    fn link_index_dense_unique() {
        let w = 6;
        let mut seen = std::collections::HashSet::new();
        for r in 0..4 {
            for c in 0..w {
                for dir in [Dir::East, Dir::West, Dir::South, Dir::North] {
                    let idx = link_index(LinkId { row: r, col: c, dir }, w);
                    assert!(seen.insert(idx), "collision at {idx}");
                    assert!(idx < 4 * w * NUM_DIRS);
                }
            }
        }
    }

    #[test]
    fn prop_route_endpoints() {
        crate::util::prop::check(
            "xy route goes src->dst",
            |rng| {
                let h = rng.range(1, 16);
                let w = rng.range(1, 16);
                let src = (rng.below(h), rng.below(w));
                let dst = (rng.below(h), rng.below(w));
                (src, dst)
            },
            |&(src, dst)| {
                let path = route_xy(src, dst);
                if path.len() != hops(src, dst) {
                    return Err("length != manhattan".into());
                }
                let mut cur = src;
                for l in &path {
                    if (l.row, l.col) != cur {
                        return Err("discontiguous".into());
                    }
                    cur = l.downstream();
                }
                if cur != dst {
                    return Err("wrong endpoint".into());
                }
                Ok(())
            },
        );
    }
}
