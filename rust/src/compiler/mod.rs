//! Workload Compiler back-end (paper §VI-A steps 2–4, Fig. 6c-d): partition
//! each chunk's operator graph over the chunk's core region, tile operators
//! across cores, map logical cores to the physical array, and XY-route the
//! resulting flows.
//!
//! The output [`CompiledChunk`] feeds every evaluator: the analytical
//! op-level model and the GNN both consume its per-link flow structure, and
//! the cycle-accurate simulator executes its phase/flow schedule directly.

pub mod cache;
pub mod partition;
pub mod routing;

use crate::arch::CoreConfig;
use crate::workload::{OpGraph, OpKind};

pub use cache::{compile_chunk_cached, CachedChunk, ChunkCache};
pub use partition::{grid_for_op, OpPlacement};
pub use routing::{link_index, route_xy, LinkId, NUM_DIRS};

/// A point-to-point transfer between physical cores, attributed to the op
/// edge of the chunk graph (the "communication trace" of §VI-A step 3).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Flow {
    pub src: (usize, usize),
    pub dst: (usize, usize),
    pub bytes: f64,
    /// Index of the producing op (phase) in the chunk graph.
    pub src_op: usize,
    /// Index of the consuming op.
    pub dst_op: usize,
}

/// Per-op compute assignment: which sub-grid runs it and the per-core tile
/// shape handed to tile-level evaluation.
#[derive(Debug, Clone, Copy)]
pub struct OpAssignment {
    pub op: usize,
    pub kind: OpKind,
    pub placement: OpPlacement,
    /// FLOPs per participating core.
    pub flops_per_core: f64,
    /// Input bytes streamed into each participating core (operand feeds).
    pub in_bytes_per_core: f64,
    /// Output bytes produced per participating core.
    pub out_bytes_per_core: f64,
    /// Resident working set per core (weights + stationary tile), bytes.
    pub working_set_bytes: f64,
}

/// Result of compiling one chunk onto an `h × w` core region.
#[derive(Debug, Clone)]
pub struct CompiledChunk {
    pub region_h: usize,
    pub region_w: usize,
    pub assignments: Vec<OpAssignment>,
    /// All inter-core flows, in op (phase) order.
    pub flows: Vec<Flow>,
    /// Op-graph dependency edges (src_op, dst_op) — preserved for critical-
    /// path traversal in op-level evaluation.
    pub deps: Vec<(usize, usize)>,
}

impl CompiledChunk {
    pub fn num_cores(&self) -> usize {
        self.region_h * self.region_w
    }

    /// Total bytes crossing the NoC.
    pub fn total_flow_bytes(&self) -> f64 {
        self.flows.iter().map(|f| f.bytes).sum()
    }

    /// Bytes injected per source core (dense, row-major) — a GNN node
    /// feature computable identically at dataset-generation and DSE time.
    pub fn node_injected_bytes(&self) -> Vec<f64> {
        let mut v = vec![0.0; self.region_h * self.region_w];
        for f in &self.flows {
            v[f.src.0 * self.region_w + f.src.1] += f.bytes;
        }
        v
    }

    /// Accumulate bytes per directed mesh link (for the analytical model
    /// and as GNN edge features). Returns a dense vector indexed by
    /// [`routing::link_index`].
    pub fn link_loads(&self) -> Vec<f64> {
        let mut loads = vec![0.0; self.region_h * self.region_w * NUM_DIRS];
        for f in &self.flows {
            for l in route_xy(f.src, f.dst) {
                loads[link_index(l, self.region_w)] += f.bytes;
            }
        }
        loads
    }
}

/// Compile a chunk graph onto an `h × w` core region of `core` cores
/// (§VI-A steps 2–4).
///
/// Traffic model per op:
/// * operand feeding is systolic — A-tiles relay left-to-right along rows,
///   B-tiles top-to-bottom along columns (neighbor flows);
/// * between dependent ops the output tiles are *redistributed* to the
///   consumer's layout with a transpose-like permutation (layout changes
///   between GEMMs shuffle the data), producing the longer-range flows that
///   create NoC congestion.
pub fn compile_chunk(
    graph: &OpGraph,
    region_h: usize,
    region_w: usize,
    core: &CoreConfig,
) -> CompiledChunk {
    assert!(region_h >= 1 && region_w >= 1);
    let mut assignments = Vec::with_capacity(graph.ops.len());
    let mut flows = Vec::new();

    for op in &graph.ops {
        let placement = grid_for_op(&op.kind, region_h, region_w);
        let cores = placement.num_cores() as f64;
        let kind = op.kind;
        let flops_per_core = kind.flops() / cores;
        let out_bytes_per_core = kind.out_bytes() / cores;

        // Operand volumes (per core) by op type.
        let (in_bytes_per_core, working_set) = operand_footprint(&kind, &placement, core);
        assignments.push(OpAssignment {
            op: op.id,
            kind,
            placement,
            flops_per_core,
            in_bytes_per_core,
            out_bytes_per_core,
            working_set_bytes: working_set,
        });

        // Systolic operand-feed flows along rows/cols of the placement.
        if let OpKind::Matmul { m, k, n } | OpKind::BatchMatmul { m, k, n, .. } = kind {
            let bpe = crate::arch::constants::BYTES_PER_ELEM;
            let gh = placement.grid_h as f64;
            let gw = placement.grid_w as f64;
            let a_tile = (m as f64 / gh) * k as f64 * bpe;
            let b_tile = k as f64 * (n as f64 / gw) * bpe;
            for r in 0..placement.grid_h {
                for c in 0..placement.grid_w {
                    let here = placement.physical(r, c);
                    if c + 1 < placement.grid_w {
                        flows.push(Flow {
                            src: here,
                            dst: placement.physical(r, c + 1),
                            bytes: a_tile,
                            src_op: op.id,
                            dst_op: op.id,
                        });
                    }
                    if r + 1 < placement.grid_h {
                        flows.push(Flow {
                            src: here,
                            dst: placement.physical(r + 1, c),
                            bytes: b_tile,
                            src_op: op.id,
                            dst_op: op.id,
                        });
                    }
                }
            }
        }
    }

    // Redistribution flows along dependency edges.
    let mut deps = Vec::with_capacity(graph.edges.len());
    for e in &graph.edges {
        deps.push((e.src, e.dst));
        let src_p = assignments[e.src].placement;
        let dst_p = assignments[e.dst].placement;
        let per_src = e.bytes / src_p.num_cores() as f64;
        for r in 0..src_p.grid_h {
            for c in 0..src_p.grid_w {
                let src = src_p.physical(r, c);
                // Transpose-like permutation into the consumer grid.
                let dr = c % dst_p.grid_h;
                let dc = r % dst_p.grid_w;
                let dst = dst_p.physical(dr, dc);
                if src != dst {
                    flows.push(Flow {
                        src,
                        dst,
                        bytes: per_src,
                        src_op: e.src,
                        dst_op: e.dst,
                    });
                }
            }
        }
    }

    CompiledChunk {
        region_h,
        region_w,
        assignments,
        flows,
        deps,
    }
}

/// Per-core operand feed volume and resident working set for tile-level
/// evaluation (§VI-B: SRAM capacity bounds data reuse).
fn operand_footprint(kind: &OpKind, placement: &OpPlacement, _core: &CoreConfig) -> (f64, f64) {
    let bpe = crate::arch::constants::BYTES_PER_ELEM;
    let gh = placement.grid_h as f64;
    let gw = placement.grid_w as f64;
    match *kind {
        OpKind::Matmul { m, k, n } => {
            let a = (m as f64 / gh) * k as f64 * bpe;
            let b = k as f64 * (n as f64 / gw) * bpe;
            let out = (m as f64 / gh) * (n as f64 / gw) * bpe;
            (a + b, b + out) // B tile stationary (WS-style), out accumulates
        }
        OpKind::BatchMatmul { batch, m, k, n } => {
            let per = (batch as f64 / (gh * gw)).max(1.0);
            let a = per * m as f64 * k as f64 * bpe;
            let b = per * k as f64 * n as f64 * bpe;
            let out = per * m as f64 * n as f64 * bpe;
            (a + b, b + out)
        }
        OpKind::Softmax { rows, cols } | OpKind::LayerNorm { rows, cols } => {
            let t = rows as f64 * cols as f64 * bpe / (gh * gw);
            (t, t.min(64.0 * 1024.0))
        }
        OpKind::Elementwise { elems } => {
            let t = elems as f64 * bpe / (gh * gw);
            (2.0 * t, 0.0)
        }
        OpKind::KvRead { bytes } => (bytes / (gh * gw), 0.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Dataflow;
    use crate::workload::models::benchmarks;
    use crate::workload::{OpGraph, Phase};

    fn core() -> CoreConfig {
        CoreConfig {
            dataflow: Dataflow::WS,
            mac_num: 512,
            buffer_kb: 128,
            buffer_bw_bits: 256,
            noc_bw_bits: 512,
        }
    }

    fn compiled(h: usize, w: usize) -> CompiledChunk {
        let spec = benchmarks()[0].clone();
        let g = OpGraph::transformer_chunk(&spec, 1, 1, 4, Phase::Prefill, false);
        compile_chunk(&g, h, w, &core())
    }

    #[test]
    fn flows_stay_in_region() {
        let c = compiled(8, 8);
        for f in &c.flows {
            assert!(f.src.0 < 8 && f.src.1 < 8);
            assert!(f.dst.0 < 8 && f.dst.1 < 8);
            assert!(f.bytes > 0.0);
            assert_ne!(f.src, f.dst);
        }
        assert!(!c.flows.is_empty());
    }

    #[test]
    fn every_op_assigned() {
        let spec = benchmarks()[0].clone();
        let g = OpGraph::transformer_chunk(&spec, 1, 1, 4, Phase::Prefill, false);
        let c = compile_chunk(&g, 8, 8, &core());
        assert_eq!(c.assignments.len(), g.ops.len());
        for a in &c.assignments {
            assert!(a.flops_per_core >= 0.0);
            assert!(a.placement.num_cores() >= 1);
        }
    }

    #[test]
    fn flops_conserved_across_cores() {
        let spec = benchmarks()[0].clone();
        let g = OpGraph::transformer_chunk(&spec, 1, 1, 4, Phase::Prefill, false);
        let c = compile_chunk(&g, 8, 8, &core());
        let total: f64 = c
            .assignments
            .iter()
            .map(|a| a.flops_per_core * a.placement.num_cores() as f64)
            .sum();
        let rel = (total - g.total_flops()).abs() / g.total_flops();
        assert!(rel < 1e-9, "rel={rel}");
    }

    #[test]
    fn link_loads_indexable_and_nonnegative() {
        let c = compiled(6, 6);
        let loads = c.link_loads();
        assert_eq!(loads.len(), 6 * 6 * NUM_DIRS);
        assert!(loads.iter().all(|&b| b >= 0.0));
        assert!(loads.iter().sum::<f64>() > 0.0);
    }

    #[test]
    fn bigger_region_spreads_traffic() {
        let small = compiled(4, 4);
        let big = compiled(12, 12);
        // More cores -> more flows (finer tiling).
        assert!(big.flows.len() > small.flows.len());
    }

    #[test]
    fn prop_region_bounds_and_dep_consistency() {
        let spec = benchmarks()[0].clone();
        crate::util::prop::check(
            "compiled flows in-bounds, deps reference ops",
            |r| {
                let h = r.range(1, 12);
                let w = r.range(1, 12);
                let phase = *r.choose(&[Phase::Training, Phase::Prefill, Phase::Decode]);
                (h, w, phase)
            },
            |&(h, w, phase)| {
                let g = OpGraph::transformer_chunk(&spec, 1, 1, 2, phase, false);
                let c = compile_chunk(&g, h, w, &core());
                for f in &c.flows {
                    if f.src.0 >= h || f.src.1 >= w || f.dst.0 >= h || f.dst.1 >= w {
                        return Err(format!("flow out of bounds: {f:?}"));
                    }
                }
                for &(s, d) in &c.deps {
                    if s >= g.ops.len() || d >= g.ops.len() {
                        return Err("dep out of range".into());
                    }
                }
                Ok(())
            },
        );
    }
}
