//! Workload Compiler back-end (paper §VI-A steps 2–4, Fig. 6c-d): partition
//! each chunk's operator graph over the chunk's core region, tile operators
//! across cores, map logical cores to the physical array, and XY-route the
//! resulting flows.
//!
//! The output [`CompiledChunk`] feeds every evaluator: the analytical
//! op-level model and the GNN both consume its per-link flow structure, and
//! the cycle-accurate simulator executes its phase/flow schedule directly.

pub mod cache;
pub mod partition;
pub mod routing;

use std::sync::Arc;

use crate::arch::CoreConfig;
use crate::workload::{OpGraph, OpKind};
use crate::yield_model::faults::FaultMap;

pub use cache::{compile_chunk_cached, CachedChunk, ChunkCache};
pub use partition::{grid_for_op, CoreMap, OpPlacement};
pub use routing::{link_index, route_xy, LinkId, RouteError, RouteTable, NUM_DIRS};

/// A point-to-point transfer between physical cores, attributed to the op
/// edge of the chunk graph (the "communication trace" of §VI-A step 3).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Flow {
    pub src: (usize, usize),
    pub dst: (usize, usize),
    pub bytes: f64,
    /// Index of the producing op (phase) in the chunk graph.
    pub src_op: usize,
    /// Index of the consuming op.
    pub dst_op: usize,
}

/// Per-op compute assignment: which sub-grid runs it and the per-core tile
/// shape handed to tile-level evaluation.
#[derive(Debug, Clone, Copy)]
pub struct OpAssignment {
    pub op: usize,
    pub kind: OpKind,
    pub placement: OpPlacement,
    /// FLOPs per participating core.
    pub flops_per_core: f64,
    /// Input bytes streamed into each participating core (operand feeds).
    pub in_bytes_per_core: f64,
    /// Output bytes produced per participating core.
    pub out_bytes_per_core: f64,
    /// Resident working set per core (weights + stationary tile), bytes.
    pub working_set_bytes: f64,
}

/// Fault state threaded through a degraded-mesh compile: the sampled map,
/// the dense logical grid over survivors, and the shared fault-aware
/// routing table (one `Arc` reaches both NoC engines, keeping the
/// bit-identical `SimStats` contract structural).
#[derive(Debug)]
pub struct FaultTopo {
    pub map: FaultMap,
    pub core_map: CoreMap,
    pub table: Arc<RouteTable>,
}

impl FaultTopo {
    /// Build the degraded topology, verifying that every pair of mapped
    /// cores stays mutually routable — a partitioned mesh is a loud error
    /// here, *before* anything compiles onto it.
    pub fn new(map: FaultMap) -> Result<FaultTopo, RouteError> {
        let core_map = CoreMap::build(&map).ok_or(RouteError::Disconnected {
            src: (0, 0),
            dst: (0, 0),
        })?;
        let table = RouteTable::build(&map);
        let cores = core_map.physical_cores();
        for &a in cores {
            for &b in cores {
                if !table.reachable(a, b) {
                    return Err(RouteError::Disconnected { src: a, dst: b });
                }
            }
        }
        Ok(FaultTopo {
            map,
            core_map,
            table: Arc::new(table),
        })
    }
}

/// Result of compiling one chunk onto an `h × w` core region.
///
/// On a faulted compile ([`compile_chunk_faulted`]) `region_h`/`region_w`
/// stay the *physical* mesh dimensions, `flows` carry physical coordinates
/// (already remapped through the [`CoreMap`]), while `assignments`'
/// placements remain in the dense *logical* grid — [`Self::core_node`]
/// bridges the two. All route-shaped queries must go through the dispatch
/// methods ([`Self::for_each_route_link`], [`Self::route_hops`]) rather
/// than raw XY helpers.
#[derive(Debug, Clone)]
pub struct CompiledChunk {
    pub region_h: usize,
    pub region_w: usize,
    pub assignments: Vec<OpAssignment>,
    /// All inter-core flows, in op (phase) order.
    pub flows: Vec<Flow>,
    /// Op-graph dependency edges (src_op, dst_op) — preserved for critical-
    /// path traversal in op-level evaluation.
    pub deps: Vec<(usize, usize)>,
    /// Degraded-mesh state; `None` on the (bit-identical) pristine path.
    pub fault: Option<Arc<FaultTopo>>,
}

impl CompiledChunk {
    pub fn num_cores(&self) -> usize {
        self.region_h * self.region_w
    }

    /// Cores actually computing: the logical live grid under faults, the
    /// whole region otherwise.
    pub fn compute_cores(&self) -> usize {
        match &self.fault {
            Some(t) => t.core_map.num_cores(),
            None => self.region_h * self.region_w,
        }
    }

    /// Physical node index of a placement coordinate (logical under
    /// faults, physical == logical on the pristine path).
    #[inline]
    pub fn core_node(&self, rc: (usize, usize)) -> usize {
        match &self.fault {
            Some(t) => {
                let (r, c) = t.core_map.physical(rc.0, rc.1);
                r * self.region_w + c
            }
            None => rc.0 * self.region_w + rc.1,
        }
    }

    /// Route traversal for a flow between *physical* endpoints: XY on the
    /// pristine mesh, table-routed detours on a degraded one.
    #[inline]
    pub fn for_each_route_link(
        &self,
        src: (usize, usize),
        dst: (usize, usize),
        f: impl FnMut(LinkId),
    ) {
        match &self.fault {
            Some(t) => {
                // FaultTopo::new verified all-pairs reachability over the
                // mapped cores, so flows cannot hit a disconnected pair.
                t.table
                    .for_each_link(src, dst, f)
                    .expect("flow endpoints verified reachable at FaultTopo build");
            }
            None => routing::for_each_link_xy(src, dst, f),
        }
    }

    /// Hop count along the actual route (Manhattan on the pristine mesh,
    /// detour length on a degraded one).
    #[inline]
    pub fn route_hops(&self, src: (usize, usize), dst: (usize, usize)) -> usize {
        match &self.fault {
            Some(t) => t
                .table
                .hops(src, dst)
                .expect("flow endpoints verified reachable at FaultTopo build"),
            None => routing::hops(src, dst),
        }
    }

    /// Total bytes crossing the NoC.
    pub fn total_flow_bytes(&self) -> f64 {
        self.flows.iter().map(|f| f.bytes).sum()
    }

    /// Bytes injected per source core (dense, row-major) — a GNN node
    /// feature computable identically at dataset-generation and DSE time.
    pub fn node_injected_bytes(&self) -> Vec<f64> {
        let mut v = vec![0.0; self.region_h * self.region_w];
        for f in &self.flows {
            v[f.src.0 * self.region_w + f.src.1] += f.bytes;
        }
        v
    }

    /// Accumulate bytes per directed mesh link (for the analytical model
    /// and as GNN edge features). Returns a dense vector indexed by
    /// [`routing::link_index`].
    pub fn link_loads(&self) -> Vec<f64> {
        let mut loads = vec![0.0; self.region_h * self.region_w * NUM_DIRS];
        for f in &self.flows {
            self.for_each_route_link(f.src, f.dst, |l| {
                loads[link_index(l, self.region_w)] += f.bytes;
            });
        }
        loads
    }
}

/// Compile a chunk graph onto an `h × w` core region of `core` cores
/// (§VI-A steps 2–4).
///
/// Traffic model per op:
/// * operand feeding is systolic — A-tiles relay left-to-right along rows,
///   B-tiles top-to-bottom along columns (neighbor flows);
/// * between dependent ops the output tiles are *redistributed* to the
///   consumer's layout with a transpose-like permutation (layout changes
///   between GEMMs shuffle the data), producing the longer-range flows that
///   create NoC congestion.
pub fn compile_chunk(
    graph: &OpGraph,
    region_h: usize,
    region_w: usize,
    core: &CoreConfig,
) -> CompiledChunk {
    assert!(region_h >= 1 && region_w >= 1);
    let mut assignments = Vec::with_capacity(graph.ops.len());
    let mut flows = Vec::new();

    for op in &graph.ops {
        let placement = grid_for_op(&op.kind, region_h, region_w);
        let cores = placement.num_cores() as f64;
        let kind = op.kind;
        let flops_per_core = kind.flops() / cores;
        let out_bytes_per_core = kind.out_bytes() / cores;

        // Operand volumes (per core) by op type.
        let (in_bytes_per_core, working_set) = operand_footprint(&kind, &placement, core);
        assignments.push(OpAssignment {
            op: op.id,
            kind,
            placement,
            flops_per_core,
            in_bytes_per_core,
            out_bytes_per_core,
            working_set_bytes: working_set,
        });

        // Systolic operand-feed flows along rows/cols of the placement.
        if let OpKind::Matmul { m, k, n } | OpKind::BatchMatmul { m, k, n, .. } = kind {
            let bpe = crate::arch::constants::BYTES_PER_ELEM;
            let gh = placement.grid_h as f64;
            let gw = placement.grid_w as f64;
            let a_tile = (m as f64 / gh) * k as f64 * bpe;
            let b_tile = k as f64 * (n as f64 / gw) * bpe;
            for r in 0..placement.grid_h {
                for c in 0..placement.grid_w {
                    let here = placement.physical(r, c);
                    if c + 1 < placement.grid_w {
                        flows.push(Flow {
                            src: here,
                            dst: placement.physical(r, c + 1),
                            bytes: a_tile,
                            src_op: op.id,
                            dst_op: op.id,
                        });
                    }
                    if r + 1 < placement.grid_h {
                        flows.push(Flow {
                            src: here,
                            dst: placement.physical(r + 1, c),
                            bytes: b_tile,
                            src_op: op.id,
                            dst_op: op.id,
                        });
                    }
                }
            }
        }
    }

    // Redistribution flows along dependency edges.
    let mut deps = Vec::with_capacity(graph.edges.len());
    for e in &graph.edges {
        deps.push((e.src, e.dst));
        let src_p = assignments[e.src].placement;
        let dst_p = assignments[e.dst].placement;
        let per_src = e.bytes / src_p.num_cores() as f64;
        for r in 0..src_p.grid_h {
            for c in 0..src_p.grid_w {
                let src = src_p.physical(r, c);
                // Transpose-like permutation into the consumer grid.
                let dr = c % dst_p.grid_h;
                let dc = r % dst_p.grid_w;
                let dst = dst_p.physical(dr, dc);
                if src != dst {
                    flows.push(Flow {
                        src,
                        dst,
                        bytes: per_src,
                        src_op: e.src,
                        dst_op: e.dst,
                    });
                }
            }
        }
    }

    CompiledChunk {
        region_h,
        region_w,
        assignments,
        flows,
        deps,
        fault: None,
    }
}

/// Compile a chunk onto a *degraded* mesh: partition and tile on the dense
/// logical grid of survivors, then remap every flow endpoint to physical
/// coordinates through the [`CoreMap`]. The result's region dimensions are
/// the physical mesh (routes and simulators run on the real, irregular
/// topology); placements stay logical and reach physical node indices via
/// [`CompiledChunk::core_node`].
pub fn compile_chunk_faulted(
    graph: &OpGraph,
    core: &CoreConfig,
    topo: Arc<FaultTopo>,
) -> CompiledChunk {
    let (lh, lw) = topo.core_map.logical_dims();
    let mut chunk = compile_chunk(graph, lh, lw, core);
    for f in &mut chunk.flows {
        f.src = topo.core_map.physical(f.src.0, f.src.1);
        f.dst = topo.core_map.physical(f.dst.0, f.dst.1);
    }
    let (ph, pw) = topo.map.dims();
    chunk.region_h = ph;
    chunk.region_w = pw;
    chunk.fault = Some(topo);
    chunk
}

/// Per-core operand feed volume and resident working set for tile-level
/// evaluation (§VI-B: SRAM capacity bounds data reuse).
fn operand_footprint(kind: &OpKind, placement: &OpPlacement, _core: &CoreConfig) -> (f64, f64) {
    let bpe = crate::arch::constants::BYTES_PER_ELEM;
    let gh = placement.grid_h as f64;
    let gw = placement.grid_w as f64;
    match *kind {
        OpKind::Matmul { m, k, n } => {
            let a = (m as f64 / gh) * k as f64 * bpe;
            let b = k as f64 * (n as f64 / gw) * bpe;
            let out = (m as f64 / gh) * (n as f64 / gw) * bpe;
            (a + b, b + out) // B tile stationary (WS-style), out accumulates
        }
        OpKind::BatchMatmul { batch, m, k, n } => {
            let per = (batch as f64 / (gh * gw)).max(1.0);
            let a = per * m as f64 * k as f64 * bpe;
            let b = per * k as f64 * n as f64 * bpe;
            let out = per * m as f64 * n as f64 * bpe;
            (a + b, b + out)
        }
        OpKind::Softmax { rows, cols } | OpKind::LayerNorm { rows, cols } => {
            let t = rows as f64 * cols as f64 * bpe / (gh * gw);
            (t, t.min(64.0 * 1024.0))
        }
        OpKind::Elementwise { elems } => {
            let t = elems as f64 * bpe / (gh * gw);
            (2.0 * t, 0.0)
        }
        OpKind::KvRead { bytes } => (bytes / (gh * gw), 0.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Dataflow;
    use crate::workload::models::benchmarks;
    use crate::workload::{OpGraph, Phase};

    fn core() -> CoreConfig {
        CoreConfig {
            dataflow: Dataflow::WS,
            mac_num: 512,
            buffer_kb: 128,
            buffer_bw_bits: 256,
            noc_bw_bits: 512,
        }
    }

    fn compiled(h: usize, w: usize) -> CompiledChunk {
        let spec = benchmarks()[0].clone();
        let g = OpGraph::transformer_chunk(&spec, 1, 1, 4, Phase::Prefill, false);
        compile_chunk(&g, h, w, &core())
    }

    #[test]
    fn flows_stay_in_region() {
        let c = compiled(8, 8);
        for f in &c.flows {
            assert!(f.src.0 < 8 && f.src.1 < 8);
            assert!(f.dst.0 < 8 && f.dst.1 < 8);
            assert!(f.bytes > 0.0);
            assert_ne!(f.src, f.dst);
        }
        assert!(!c.flows.is_empty());
    }

    #[test]
    fn every_op_assigned() {
        let spec = benchmarks()[0].clone();
        let g = OpGraph::transformer_chunk(&spec, 1, 1, 4, Phase::Prefill, false);
        let c = compile_chunk(&g, 8, 8, &core());
        assert_eq!(c.assignments.len(), g.ops.len());
        for a in &c.assignments {
            assert!(a.flops_per_core >= 0.0);
            assert!(a.placement.num_cores() >= 1);
        }
    }

    #[test]
    fn flops_conserved_across_cores() {
        let spec = benchmarks()[0].clone();
        let g = OpGraph::transformer_chunk(&spec, 1, 1, 4, Phase::Prefill, false);
        let c = compile_chunk(&g, 8, 8, &core());
        let total: f64 = c
            .assignments
            .iter()
            .map(|a| a.flops_per_core * a.placement.num_cores() as f64)
            .sum();
        let rel = (total - g.total_flops()).abs() / g.total_flops();
        assert!(rel < 1e-9, "rel={rel}");
    }

    #[test]
    fn link_loads_indexable_and_nonnegative() {
        let c = compiled(6, 6);
        let loads = c.link_loads();
        assert_eq!(loads.len(), 6 * 6 * NUM_DIRS);
        assert!(loads.iter().all(|&b| b >= 0.0));
        assert!(loads.iter().sum::<f64>() > 0.0);
    }

    #[test]
    fn bigger_region_spreads_traffic() {
        let small = compiled(4, 4);
        let big = compiled(12, 12);
        // More cores -> more flows (finer tiling).
        assert!(big.flows.len() > small.flows.len());
    }

    #[test]
    fn faulted_compile_avoids_dead_cores_and_routes_clean() {
        let spec = benchmarks()[0].clone();
        let g = OpGraph::transformer_chunk(&spec, 1, 1, 4, Phase::Prefill, false);
        let mut map = FaultMap::pristine(6, 6);
        map.kill_core(2, 3);
        map.kill_core(4, 0);
        map.kill_link(1, 1, routing::Dir::East as usize);
        let topo = Arc::new(FaultTopo::new(map).expect("mesh stays connected"));
        let c = compile_chunk_faulted(&g, &core(), topo.clone());
        // Physical region dims, logical compute grid.
        assert_eq!((c.region_h, c.region_w), (6, 6));
        assert_eq!(c.compute_cores(), topo.core_map.num_cores());
        assert!(c.compute_cores() < 36);
        for f in &c.flows {
            // Flow endpoints are physical live cores.
            assert!(topo.map.core_ok(f.src.0, f.src.1), "flow from dead core");
            assert!(topo.map.core_ok(f.dst.0, f.dst.1), "flow into dead core");
            // Routes exist and avoid faults (RouteTable guarantees; just
            // exercise the dispatch path end to end).
            let mut hops = 0usize;
            c.for_each_route_link(f.src, f.dst, |l| {
                assert!(topo.map.link_intact(l.row, l.col, l.dir as usize));
                hops += 1;
            });
            assert_eq!(hops, c.route_hops(f.src, f.dst));
        }
        // Dense per-link loads still cover the full physical mesh.
        assert_eq!(c.link_loads().len(), 6 * 6 * NUM_DIRS);
    }

    #[test]
    fn faulted_compile_on_pristine_map_matches_plain_compile() {
        let spec = benchmarks()[0].clone();
        let g = OpGraph::transformer_chunk(&spec, 1, 1, 4, Phase::Prefill, false);
        let topo = Arc::new(FaultTopo::new(FaultMap::pristine(5, 5)).unwrap());
        let faulted = compile_chunk_faulted(&g, &core(), topo);
        let plain = compile_chunk(&g, 5, 5, &core());
        assert_eq!(faulted.flows, plain.flows);
        assert_eq!(faulted.deps, plain.deps);
        assert_eq!(faulted.compute_cores(), plain.compute_cores());
        // Identity core map: logical node indices coincide.
        for a in &faulted.assignments {
            for r in 0..a.placement.grid_h {
                for c2 in 0..a.placement.grid_w {
                    let rc = a.placement.physical(r, c2);
                    assert_eq!(faulted.core_node(rc), plain.core_node(rc));
                }
            }
        }
    }

    #[test]
    fn fault_topo_rejects_partitioned_mesh() {
        // Isolate core (0,0) by killing all four directed links on its
        // boundary in both directions.
        let mut map = FaultMap::pristine(2, 2);
        map.kill_link(0, 0, routing::Dir::East as usize);
        map.kill_link(0, 1, routing::Dir::West as usize);
        map.kill_link(0, 0, routing::Dir::South as usize);
        map.kill_link(1, 0, routing::Dir::North as usize);
        let err = FaultTopo::new(map).unwrap_err();
        assert!(matches!(err, RouteError::Disconnected { .. }));
    }

    #[test]
    fn prop_region_bounds_and_dep_consistency() {
        let spec = benchmarks()[0].clone();
        crate::util::prop::check(
            "compiled flows in-bounds, deps reference ops",
            |r| {
                let h = r.range(1, 12);
                let w = r.range(1, 12);
                let phase = *r.choose(&[Phase::Training, Phase::Prefill, Phase::Decode]);
                (h, w, phase)
            },
            |&(h, w, phase)| {
                let g = OpGraph::transformer_chunk(&spec, 1, 1, 2, phase, false);
                let c = compile_chunk(&g, h, w, &core());
                for f in &c.flows {
                    if f.src.0 >= h || f.src.1 >= w || f.dst.0 >= h || f.dst.1 >= w {
                        return Err(format!("flow out of bounds: {f:?}"));
                    }
                }
                for &(s, d) in &c.deps {
                    if s >= g.ops.len() || d >= g.ops.len() {
                        return Err("dep out of range".into());
                    }
                }
                Ok(())
            },
        );
    }
}
