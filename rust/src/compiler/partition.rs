//! Partition & allocation (paper §VI-A step 2): choose the sub-grid of the
//! chunk's core region each operator runs on, balancing intra-op
//! parallelism against operand granularity (prior-work methodology the
//! paper cites: Tangram/Timeloop-style even partitioning).

use crate::workload::OpKind;

/// Placement of one op on a rectangular sub-grid anchored at `(off_h, off_w)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OpPlacement {
    pub off_h: usize,
    pub off_w: usize,
    pub grid_h: usize,
    pub grid_w: usize,
}

impl OpPlacement {
    pub fn num_cores(&self) -> usize {
        self.grid_h * self.grid_w
    }

    /// Physical coordinates of logical tile (r, c) — §VI-A step 4's
    /// logical→physical mapping is a direct block embedding.
    pub fn physical(&self, r: usize, c: usize) -> (usize, usize) {
        (self.off_h + r, self.off_w + c)
    }
}

/// Pick the op's grid: GEMMs use the whole region (2-D tiled over m × n);
/// small memory-bound ops cap their parallelism so per-core tiles do not
/// degenerate below one row/vector (allocating every core to a tiny
/// LayerNorm just burns NoC bandwidth).
pub fn grid_for_op(kind: &OpKind, region_h: usize, region_w: usize) -> OpPlacement {
    let full = OpPlacement {
        off_h: 0,
        off_w: 0,
        grid_h: region_h,
        grid_w: region_w,
    };
    match *kind {
        OpKind::Matmul { m, n, .. } => shrink_to(full, m, n),
        OpKind::BatchMatmul { batch, m, n, .. } => {
            // Batched products parallelize over batch first.
            shrink_to(full, batch * m, n)
        }
        OpKind::Softmax { rows, .. } | OpKind::LayerNorm { rows, .. } => {
            shrink_to(full, rows, 1)
        }
        OpKind::Elementwise { elems } => shrink_to(full, elems, 1),
        OpKind::KvRead { .. } => full,
    }
}

/// Shrink a grid so it has at most `par_h × par_w`-way useful parallelism.
fn shrink_to(full: OpPlacement, par_h: usize, par_w: usize) -> OpPlacement {
    let gh = full.grid_h.min(par_h.max(1));
    let gw = if par_w <= 1 {
        // 1-D parallel op: use the whole region linearized by rows.
        full.grid_w.min((par_h / gh).max(1))
    } else {
        full.grid_w.min(par_w)
    };
    OpPlacement {
        off_h: 0,
        off_w: 0,
        grid_h: gh.max(1),
        grid_w: gw.max(1),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_uses_full_region() {
        let p = grid_for_op(
            &OpKind::Matmul {
                m: 2048,
                k: 2304,
                n: 2304,
            },
            8,
            8,
        );
        assert_eq!((p.grid_h, p.grid_w), (8, 8));
    }

    #[test]
    fn tiny_op_shrinks() {
        let p = grid_for_op(&OpKind::LayerNorm { rows: 3, cols: 64 }, 8, 8);
        assert!(p.num_cores() <= 3, "cores={}", p.num_cores());
    }

    #[test]
    fn never_zero_cores() {
        for kind in [
            OpKind::Matmul { m: 1, k: 1, n: 1 },
            OpKind::Softmax { rows: 1, cols: 1 },
            OpKind::Elementwise { elems: 1 },
        ] {
            let p = grid_for_op(&kind, 16, 16);
            assert!(p.num_cores() >= 1);
        }
    }

    #[test]
    fn physical_maps_into_region() {
        let p = grid_for_op(
            &OpKind::Matmul {
                m: 512,
                k: 64,
                n: 512,
            },
            5,
            7,
        );
        for r in 0..p.grid_h {
            for c in 0..p.grid_w {
                let (pr, pc) = p.physical(r, c);
                assert!(pr < 5 && pc < 7);
            }
        }
    }
}
