//! Partition & allocation (paper §VI-A step 2): choose the sub-grid of the
//! chunk's core region each operator runs on, balancing intra-op
//! parallelism against operand granularity (prior-work methodology the
//! paper cites: Tangram/Timeloop-style even partitioning).
//!
//! On degraded meshes, [`CoreMap`] extracts the largest regular logical
//! grid from the surviving cores (Cerebras-style row remap: each kept row
//! contributes its leftmost live cores), so the partitioner keeps placing
//! on a dense rectangle while the placement skips dead cores physically.

use crate::workload::OpKind;
use crate::yield_model::faults::FaultMap;

/// Placement of one op on a rectangular sub-grid anchored at `(off_h, off_w)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OpPlacement {
    pub off_h: usize,
    pub off_w: usize,
    pub grid_h: usize,
    pub grid_w: usize,
}

impl OpPlacement {
    pub fn num_cores(&self) -> usize {
        self.grid_h * self.grid_w
    }

    /// Physical coordinates of logical tile (r, c) — §VI-A step 4's
    /// logical→physical mapping is a direct block embedding.
    pub fn physical(&self, r: usize, c: usize) -> (usize, usize) {
        (self.off_h + r, self.off_w + c)
    }
}

/// Dense logical grid over the live cores of a faulty mesh.
///
/// Construction keeps every physical row with enough live cores and packs
/// each kept row's leftmost live cores into logical columns. The logical
/// width is chosen to maximize usable cores (`width × #rows-with-≥width
/// -live`, ties to the wider grid) — a deterministic rule that is monotone
/// in the live set: reviving cores can only grow the usable-core count.
#[derive(Debug, Clone, PartialEq)]
pub struct CoreMap {
    h: usize,
    w: usize,
    /// Physical coordinates per logical core, row-major.
    phys: Vec<(usize, usize)>,
}

impl CoreMap {
    /// `None` when the map has no live cores at all.
    pub fn build(map: &FaultMap) -> Option<CoreMap> {
        let (ph, pw) = map.dims();
        let live: Vec<Vec<usize>> = (0..ph)
            .map(|r| (0..pw).filter(|&c| map.core_ok(r, c)).collect())
            .collect();
        let mut best_used = 0usize;
        let mut best_w = 0usize;
        for cand_w in 1..=pw {
            let rows = live.iter().filter(|cols| cols.len() >= cand_w).count();
            let used = cand_w * rows;
            if used > best_used || (used == best_used && cand_w > best_w) {
                best_used = used;
                best_w = cand_w;
            }
        }
        if best_used == 0 {
            return None;
        }
        let w = best_w;
        let mut phys = Vec::with_capacity(best_used);
        let mut h = 0usize;
        for (r, cols) in live.iter().enumerate() {
            if cols.len() < w {
                continue;
            }
            phys.extend(cols[..w].iter().map(|&c| (r, c)));
            h += 1;
        }
        Some(CoreMap { h, w, phys })
    }

    pub fn logical_dims(&self) -> (usize, usize) {
        (self.h, self.w)
    }

    pub fn num_cores(&self) -> usize {
        self.h * self.w
    }

    /// Physical coordinates of logical core (r, c).
    pub fn physical(&self, r: usize, c: usize) -> (usize, usize) {
        self.phys[r * self.w + c]
    }

    /// All mapped physical cores, logical row-major order.
    pub fn physical_cores(&self) -> &[(usize, usize)] {
        &self.phys
    }
}

/// Pick the op's grid: GEMMs use the whole region (2-D tiled over m × n);
/// small memory-bound ops cap their parallelism so per-core tiles do not
/// degenerate below one row/vector (allocating every core to a tiny
/// LayerNorm just burns NoC bandwidth).
pub fn grid_for_op(kind: &OpKind, region_h: usize, region_w: usize) -> OpPlacement {
    let full = OpPlacement {
        off_h: 0,
        off_w: 0,
        grid_h: region_h,
        grid_w: region_w,
    };
    match *kind {
        OpKind::Matmul { m, n, .. } => shrink_to(full, m, n),
        OpKind::BatchMatmul { batch, m, n, .. } => {
            // Batched products parallelize over batch first.
            shrink_to(full, batch * m, n)
        }
        OpKind::Softmax { rows, .. } | OpKind::LayerNorm { rows, .. } => {
            shrink_to(full, rows, 1)
        }
        OpKind::Elementwise { elems } => shrink_to(full, elems, 1),
        OpKind::KvRead { .. } => full,
    }
}

/// Shrink a grid so it has at most `par_h × par_w`-way useful parallelism.
fn shrink_to(full: OpPlacement, par_h: usize, par_w: usize) -> OpPlacement {
    let gh = full.grid_h.min(par_h.max(1));
    let gw = if par_w <= 1 {
        // 1-D parallel op: use the whole region linearized by rows.
        full.grid_w.min((par_h / gh).max(1))
    } else {
        full.grid_w.min(par_w)
    };
    OpPlacement {
        off_h: 0,
        off_w: 0,
        grid_h: gh.max(1),
        grid_w: gw.max(1),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_uses_full_region() {
        let p = grid_for_op(
            &OpKind::Matmul {
                m: 2048,
                k: 2304,
                n: 2304,
            },
            8,
            8,
        );
        assert_eq!((p.grid_h, p.grid_w), (8, 8));
    }

    #[test]
    fn tiny_op_shrinks() {
        let p = grid_for_op(&OpKind::LayerNorm { rows: 3, cols: 64 }, 8, 8);
        assert!(p.num_cores() <= 3, "cores={}", p.num_cores());
    }

    #[test]
    fn never_zero_cores() {
        for kind in [
            OpKind::Matmul { m: 1, k: 1, n: 1 },
            OpKind::Softmax { rows: 1, cols: 1 },
            OpKind::Elementwise { elems: 1 },
        ] {
            let p = grid_for_op(&kind, 16, 16);
            assert!(p.num_cores() >= 1);
        }
    }

    #[test]
    fn core_map_pristine_is_identity() {
        let map = FaultMap::pristine(4, 6);
        let cm = CoreMap::build(&map).unwrap();
        assert_eq!(cm.logical_dims(), (4, 6));
        for r in 0..4 {
            for c in 0..6 {
                assert_eq!(cm.physical(r, c), (r, c));
            }
        }
    }

    #[test]
    fn core_map_skips_dead_cores_and_keeps_rows_dense() {
        let mut map = FaultMap::pristine(3, 4);
        map.kill_core(1, 1); // row 1 has 3 live cores
        map.kill_core(2, 0);
        map.kill_core(2, 3); // row 2 has 2 live cores
        let cm = CoreMap::build(&map).unwrap();
        // Width 3 keeps rows 0 and 1 (6 cores); width 2 keeps all rows
        // (6 cores); tie resolves to the wider grid.
        assert_eq!(cm.logical_dims(), (2, 3));
        let mut seen = std::collections::HashSet::new();
        for r in 0..2 {
            for c in 0..3 {
                let (pr, pc) = cm.physical(r, c);
                assert!(map.core_ok(pr, pc), "mapped a dead core ({pr}, {pc})");
                assert!(seen.insert((pr, pc)), "duplicate physical core");
            }
        }
        // Row 1 skips the dead column 1.
        assert_eq!(cm.physical(1, 1), (1, 2));
    }

    #[test]
    fn core_map_none_when_everything_dead() {
        let mut map = FaultMap::pristine(2, 2);
        for r in 0..2 {
            for c in 0..2 {
                map.kill_core(r, c);
            }
        }
        assert!(CoreMap::build(&map).is_none());
    }

    #[test]
    fn physical_maps_into_region() {
        let p = grid_for_op(
            &OpKind::Matmul {
                m: 512,
                k: 64,
                n: 512,
            },
            5,
            7,
        );
        for r in 0..p.grid_h {
            for c in 0..p.grid_w {
                let (pr, pc) = p.physical(r, c);
                assert!(pr < 5 && pc < 7);
            }
        }
    }
}
