//! Bounded, thread-safe memo of compiled chunks (§Perf: the DSE inner loop
//! recompiled a near-identical [`CompiledChunk`] for every strategy probe).
//!
//! # Signature scheme
//!
//! Entries are keyed by a 64-bit structural signature covering everything
//! `compile_chunk` reads: the op graph's shape (op kinds with their exact
//! dims, edge endpoints and byte counts, in order) plus the region dims and
//! the full [`CoreConfig`]. Floats are hashed by their IEEE bit patterns,
//! so two graphs hash equal iff they are structurally identical inputs to
//! the compiler — and compilation is deterministic, so equal signatures
//! yield equal chunks. A 64-bit hash can collide in principle; every hit is
//! therefore re-checked against cheap invariants (op count, region dims)
//! and a mismatch is treated as a miss that overwrites the stale entry.
//!
//! # Thread-safety contract
//!
//! The cache is shared by reference across the evaluation pool
//! ([`crate::util::pool`]). Lookups and inserts take a single internal
//! mutex; **compilation runs outside the lock**, so concurrent misses on
//! the same signature may compile the same chunk twice (last insert wins —
//! harmless because compilation is deterministic) but never serialize the
//! pool on compile time. Hit/miss counters are relaxed atomics: exact
//! under quiescence (as read by benches/tests), approximate mid-flight.
//!
//! Entries are `Arc`ed so evaluators can hold a chunk + its
//! [`ChunkTopology`] without cloning or blocking eviction. Eviction is
//! least-recently-used via a monotonic use-tick — O(1) recency refresh on
//! the hit path, with the O(len) evict-min scan paid only on eviction —
//! bounded by `THESEUS_COMPILE_CACHE` (env, default 256 entries; 0
//! disables caching entirely).

use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::arch::CoreConfig;
use crate::compiler::{compile_chunk, CompiledChunk};
use crate::eval::op_level::ChunkTopology;
use crate::workload::{OpGraph, OpKind};

/// A compiled chunk bundled with its evaluation topology (built once,
/// reused by every [`crate::eval::op_level::chunk_latency_with_topo`]
/// call on the chunk).
#[derive(Debug, Clone)]
pub struct CachedChunk {
    pub chunk: CompiledChunk,
    pub topo: ChunkTopology,
    /// Structural signature of the compile input ([`chunk_signature`]) —
    /// the key the batched sweep dedupes on and the delta cache
    /// ([`crate::eval::chunk`]) memoizes per-chunk estimator results
    /// under. `0` = unkeyed: a compile whose inputs the signature does
    /// not cover (fault-injected regions carry a sampled fault map), so
    /// it must never be deduped against or delta-cached.
    pub sig: u64,
}

impl CachedChunk {
    /// Compile + index a chunk without touching any cache.
    pub fn build(graph: &OpGraph, region_h: usize, region_w: usize, core: &CoreConfig) -> CachedChunk {
        let sig = chunk_signature(graph, region_h, region_w, core);
        let chunk = compile_chunk(graph, region_h, region_w, core);
        let topo = ChunkTopology::new(&chunk);
        CachedChunk { chunk, topo, sig }
    }

    /// Bundle an already-compiled chunk as **unkeyed** (`sig` 0): for
    /// compiles the structural signature cannot represent, e.g.
    /// fault-injected regions. Unkeyed chunks are never signature-deduped
    /// or delta-cached.
    pub fn unkeyed(chunk: CompiledChunk) -> CachedChunk {
        let topo = ChunkTopology::new(&chunk);
        CachedChunk { chunk, topo, sig: 0 }
    }
}

fn hash_f64<H: Hasher>(h: &mut H, v: f64) {
    h.write_u64(v.to_bits());
}

fn hash_kind<H: Hasher>(h: &mut H, k: &OpKind) {
    match *k {
        OpKind::Matmul { m, k: kk, n } => {
            h.write_u8(0);
            h.write_usize(m);
            h.write_usize(kk);
            h.write_usize(n);
        }
        OpKind::BatchMatmul { batch, m, k: kk, n } => {
            h.write_u8(1);
            h.write_usize(batch);
            h.write_usize(m);
            h.write_usize(kk);
            h.write_usize(n);
        }
        OpKind::Softmax { rows, cols } => {
            h.write_u8(2);
            h.write_usize(rows);
            h.write_usize(cols);
        }
        OpKind::LayerNorm { rows, cols } => {
            h.write_u8(3);
            h.write_usize(rows);
            h.write_usize(cols);
        }
        OpKind::Elementwise { elems } => {
            h.write_u8(4);
            h.write_usize(elems);
        }
        OpKind::KvRead { bytes } => {
            h.write_u8(5);
            hash_f64(h, bytes);
        }
    }
}

/// Structural signature of one `compile_chunk` input (see module docs).
pub fn chunk_signature(
    graph: &OpGraph,
    region_h: usize,
    region_w: usize,
    core: &CoreConfig,
) -> u64 {
    let mut h = std::collections::hash_map::DefaultHasher::new();
    region_h.hash(&mut h);
    region_w.hash(&mut h);
    h.write_u8(core.dataflow as u8);
    h.write_usize(core.mac_num);
    h.write_usize(core.buffer_kb);
    h.write_usize(core.buffer_bw_bits);
    h.write_usize(core.noc_bw_bits);
    h.write_usize(graph.ops.len());
    for op in &graph.ops {
        h.write_usize(op.id);
        hash_kind(&mut h, &op.kind);
    }
    h.write_usize(graph.edges.len());
    for e in &graph.edges {
        h.write_usize(e.src);
        h.write_usize(e.dst);
        hash_f64(&mut h, e.bytes);
    }
    h.finish()
}

/// Point-in-time cache counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub len: usize,
    pub capacity: usize,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct Entry {
    chunk: Arc<CachedChunk>,
    /// Tick of the most recent hit/insert (monotonic; evict-min = LRU).
    last_used: u64,
}

struct CacheMap {
    entries: HashMap<u64, Entry>,
    tick: u64,
}

/// The memo itself. Construct directly for an isolated cache (tests) or
/// use [`global`] for the process-wide instance shared by the evaluators.
pub struct ChunkCache {
    map: Mutex<CacheMap>,
    hits: AtomicU64,
    misses: AtomicU64,
    capacity: usize,
}

impl ChunkCache {
    pub fn new(capacity: usize) -> ChunkCache {
        ChunkCache {
            map: Mutex::new(CacheMap {
                entries: HashMap::new(),
                tick: 0,
            }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            capacity,
        }
    }

    /// Fetch the compiled chunk for `(graph, region, core)`, compiling on
    /// miss. Compilation happens outside the lock (see module docs).
    pub fn get_or_compile(
        &self,
        graph: &OpGraph,
        region_h: usize,
        region_w: usize,
        core: &CoreConfig,
    ) -> Arc<CachedChunk> {
        if self.capacity == 0 {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return Arc::new(CachedChunk::build(graph, region_h, region_w, core));
        }
        let sig = chunk_signature(graph, region_h, region_w, core);
        let cached: Option<Arc<CachedChunk>> = {
            let mut m = self.map.lock().unwrap();
            m.tick += 1;
            let tick = m.tick;
            // Collision guard: a 64-bit signature match must also agree on
            // the cheap structural invariants.
            match m.entries.get_mut(&sig) {
                Some(e)
                    if e.chunk.chunk.region_h == region_h
                        && e.chunk.chunk.region_w == region_w
                        && e.chunk.chunk.assignments.len() == graph.ops.len() =>
                {
                    e.last_used = tick;
                    Some(e.chunk.clone())
                }
                _ => None,
            }
        };
        if let Some(hit) = cached {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return hit;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let built = Arc::new(CachedChunk::build(graph, region_h, region_w, core));
        let mut m = self.map.lock().unwrap();
        m.tick += 1;
        let tick = m.tick;
        m.entries.insert(
            sig,
            Entry {
                chunk: built.clone(),
                last_used: tick,
            },
        );
        while m.entries.len() > self.capacity {
            let Some((&old, _)) = m.entries.iter().min_by_key(|(_, e)| e.last_used) else {
                break;
            };
            m.entries.remove(&old);
        }
        built
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            len: self.map.lock().unwrap().entries.len(),
            capacity: self.capacity,
        }
    }

    /// Drop all entries and zero the counters (bench isolation).
    pub fn clear(&self) {
        let mut m = self.map.lock().unwrap();
        m.entries.clear();
        m.tick = 0;
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

static GLOBAL: OnceLock<ChunkCache> = OnceLock::new();

/// Process-wide cache; sized by `THESEUS_COMPILE_CACHE` (entries, default
/// 256, 0 = disable) read once at first use.
pub fn global() -> &'static ChunkCache {
    GLOBAL.get_or_init(|| ChunkCache::new(crate::util::cli::env_usize("THESEUS_COMPILE_CACHE", 256)))
}

/// Convenience wrapper over [`global`].
pub fn compile_chunk_cached(
    graph: &OpGraph,
    region_h: usize,
    region_w: usize,
    core: &CoreConfig,
) -> Arc<CachedChunk> {
    global().get_or_compile(graph, region_h, region_w, core)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Dataflow;
    use crate::eval::op_level::{chunk_latency, chunk_latency_with_topo, NocModel};
    use crate::workload::models::benchmarks;
    use crate::workload::{OpGraph, Phase};

    fn core(noc_bw: usize) -> CoreConfig {
        CoreConfig {
            dataflow: Dataflow::WS,
            mac_num: 512,
            buffer_kb: 128,
            buffer_bw_bits: 256,
            noc_bw_bits: noc_bw,
        }
    }

    fn graph(seq: usize) -> OpGraph {
        let mut spec = benchmarks()[0].clone();
        spec.seq_len = seq;
        OpGraph::transformer_chunk(&spec, 1, 1, 8, Phase::Prefill, false)
    }

    #[test]
    fn cached_chunk_latency_identical_to_fresh() {
        let cache = ChunkCache::new(8);
        let g = graph(64);
        let c = core(512);
        let miss = cache.get_or_compile(&g, 4, 4, &c);
        let hit = cache.get_or_compile(&g, 4, 4, &c);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert!(Arc::ptr_eq(&miss, &hit));

        let fresh = crate::compiler::compile_chunk(&g, 4, 4, &c);
        // Analytical mode.
        let a = chunk_latency(&fresh, &c, 1.0, NocModel::Analytical);
        let b = chunk_latency_with_topo(&hit.chunk, &hit.topo, &c, 1.0, NocModel::Analytical);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.compute_cycles, b.compute_cycles);
        assert_eq!(a.comm_cycles, b.comm_cycles);
        assert_eq!(a.sram_bytes, b.sram_bytes);
        assert_eq!(a.byte_hops, b.byte_hops);
        // LinkWaits mode.
        let waits = vec![2.5; 4 * 4 * 4];
        let aw = chunk_latency(&fresh, &c, 1.0, NocModel::LinkWaits(&waits));
        let bw = chunk_latency_with_topo(&hit.chunk, &hit.topo, &c, 1.0, NocModel::LinkWaits(&waits));
        assert_eq!(aw.cycles, bw.cycles);
    }

    #[test]
    fn signature_distinguishes_inputs() {
        let g64 = graph(64);
        let g128 = graph(128);
        let c512 = core(512);
        let c256 = core(256);
        let base = chunk_signature(&g64, 4, 4, &c512);
        assert_ne!(base, chunk_signature(&g128, 4, 4, &c512), "graph dims");
        assert_ne!(base, chunk_signature(&g64, 5, 4, &c512), "region dims");
        assert_ne!(base, chunk_signature(&g64, 4, 4, &c256), "core config");
        assert_eq!(base, chunk_signature(&graph(64), 4, 4, &core(512)), "deterministic");
    }

    #[test]
    fn eviction_respects_size_bound() {
        let cache = ChunkCache::new(2);
        let g = graph(32);
        let c = core(512);
        cache.get_or_compile(&g, 3, 3, &c); // A
        cache.get_or_compile(&g, 4, 4, &c); // B
        cache.get_or_compile(&g, 5, 5, &c); // C evicts A (LRU)
        assert_eq!(cache.stats().len, 2);
        // B and C still hit...
        cache.get_or_compile(&g, 4, 4, &c);
        cache.get_or_compile(&g, 5, 5, &c);
        assert_eq!(cache.stats().hits, 2);
        // ...while A was evicted and misses again.
        cache.get_or_compile(&g, 3, 3, &c);
        assert_eq!(cache.stats().misses, 4);
        assert_eq!(cache.stats().len, 2);
    }

    #[test]
    fn lru_refresh_on_hit() {
        let cache = ChunkCache::new(2);
        let g = graph(32);
        let c = core(512);
        cache.get_or_compile(&g, 3, 3, &c); // A
        cache.get_or_compile(&g, 4, 4, &c); // B
        cache.get_or_compile(&g, 3, 3, &c); // touch A -> B is now LRU
        cache.get_or_compile(&g, 5, 5, &c); // C evicts B
        cache.get_or_compile(&g, 3, 3, &c); // A still cached
        assert_eq!(cache.stats().hits, 2);
    }

    #[test]
    fn zero_capacity_disables_caching() {
        let cache = ChunkCache::new(0);
        let g = graph(32);
        let c = core(512);
        cache.get_or_compile(&g, 3, 3, &c);
        cache.get_or_compile(&g, 3, 3, &c);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.len), (0, 2, 0));
    }
}
