//! Scenario campaign engine: one-command reproduction of the paper's full
//! DSE evaluation matrix (§IX).
//!
//! A [`Scenario`] is a declarative spec — model × phase (training /
//! prefill / decode) × inference batch × wafer count × explorer × fidelity
//! × BO budget — serializable to/from JSON. Phases and fidelities parse
//! through the same registries as every other entry point
//! ([`crate::workload::Phase`], [`Fidelity`]); a scenario is just an
//! [`EvalSpec`] plus an explorer and budget, and [`run_scenario`] drives
//! it through the coordinator's single explorer-dispatch path
//! ([`crate::coordinator::explore`]). Any (phase × fidelity) pair runs —
//! decode scenarios ride the CA simulator or the (pseudo-)GNN exactly
//! like training ones.
//!
//! [`paper_suite`] mirrors the §IX matrix (every Table II model ×
//! training + inference × {random, mobo, mfmobo}); [`run_campaign`] fans
//! scenarios over the thread pool while the compile-chunk
//! ([`crate::compiler::cache`]) and tile ([`crate::eval::tile`]) memo
//! caches — process-wide singletons — stay shared across scenarios.
//!
//! # Determinism contract
//!
//! Each scenario's RNG seed is derived as
//! `scenario_seed(campaign_seed, scenario.key())` — FNV-1a over the key
//! string, XORed into the campaign seed and finalized with SplitMix64 —
//! so a scenario's trace depends only on the campaign seed and its own
//! spec, never on sibling scenarios, worker interleaving, or position in
//! the matrix. Two runs with the same campaign seed produce byte-identical
//! artifacts (enforced by `rust/tests/campaign.rs`); adding or removing
//! scenarios does not perturb the survivors.
//!
//! # Resume
//!
//! With [`CampaignConfig::resume_from`] set (CLI: `theseus campaign
//! --resume`), a scenario whose `scenarios/<key>.json` already exists
//! under the artifact dir is not re-evaluated: the parsed artifact stands
//! in for the trace ([`Outcome::Resumed`]) and the summary records the
//! row as `resumed`. Because per-scenario seeds are position-independent,
//! a killed-then-resumed campaign writes byte-identical scenario
//! artifacts to an uninterrupted one (the `resumed` status marker in
//! `campaign.json` is the only difference — enforced by
//! `rust/tests/campaign.rs`). Only **finished** work is skipped: a
//! recorded error row is retried fresh (a failure is not a result — e.g.
//! the `gnn` fidelity heals on resume once its artifacts are installed).
//! An artifact that exists but cannot be trusted (unparseable, recorded
//! under a different derived seed because `--seed` changed, or recording
//! a different scenario spec — budgets are invisible in the key, so they
//! are compared explicitly) records a loud error row instead of being
//! silently re-run or silently reused, and [`write_artifacts`] leaves
//! the untrusted file untouched on disk; delete it to re-run that
//! scenario.
//!
//! # Failure isolation
//!
//! A failing scenario (unknown model key, unavailable fidelity backend,
//! panic in the evaluation stack) records an error row instead of
//! aborting the campaign; `campaign.json` reports per-row status.

use std::panic::AssertUnwindSafe;

use crate::baselines::{h100_infer_eval, h100_train_eval};
use crate::coordinator::{explore, ref_power_for, Explorer};
use crate::eval::engine::EvalSpec;
use crate::explorer::{BoConfig, Trace, TracePoint};
use crate::util::json::Json;
use crate::util::pool;
use crate::workload::{models, LlmSpec, Phase};

pub use crate::eval::engine::Fidelity;

/// Explorer budget (the BO knobs of [`BoConfig`] plus MFMOBO's split).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Budget {
    /// Evaluations after initialization.
    pub iters: usize,
    /// Initial design set size.
    pub init: usize,
    /// Candidate pool per BO iteration.
    pub pool: usize,
    /// Monte-Carlo EHVI samples.
    pub mc: usize,
    /// MFMOBO low-fidelity trials.
    pub n1: usize,
    /// MFMOBO guided-handoff iterations.
    pub k: usize,
}

impl Default for Budget {
    /// The paper's §VIII-C / §IX search budget (also the `theseus dse`
    /// CLI defaults).
    fn default() -> Budget {
        Budget {
            iters: 40,
            init: 6,
            pool: 96,
            mc: 64,
            n1: 40,
            k: 8,
        }
    }
}

/// One declarative DSE scenario of a campaign.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    /// Model key for [`models::find`] (index or name fragment).
    pub model: String,
    pub phase: Phase,
    /// Inference batch (sequences in flight); 0 for training scenarios
    /// (the training batch comes from the model spec).
    pub batch: usize,
    /// Fixed wafer count; `None` = area-matched to the model's GPU
    /// cluster (§VIII-A).
    pub wafers: Option<usize>,
    pub explorer: Explorer,
    pub fidelity: Fidelity,
    pub budget: Budget,
    /// Free-form disambiguator, appended to [`Scenario::key`] when
    /// non-empty. Budget-only variations (e.g. an iteration-count sweep)
    /// don't show up in the key, so give each variant a distinct tag —
    /// [`run_campaign`] rejects campaigns with colliding keys (they would
    /// share a derived seed and overwrite each other's artifact file).
    pub tag: String,
}

fn slugify(s: &str) -> String {
    s.to_lowercase()
        .chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || c == '.' || c == '-' {
                c
            } else {
                '-'
            }
        })
        .collect()
}

impl Scenario {
    /// Stable identifier: artifact filename and seed-derivation input.
    pub fn key(&self) -> String {
        let wafers = match self.wafers {
            Some(n) => n.to_string(),
            None => "auto".to_string(),
        };
        let mut key = format!(
            "{}-{}-{}-{}-b{}-w{}",
            slugify(&self.model),
            self.phase.name(),
            self.explorer.name(),
            self.fidelity.name(),
            self.batch,
            wafers
        );
        if !self.tag.is_empty() {
            key.push('-');
            key.push_str(&slugify(&self.tag));
        }
        key
    }

    /// The engine spec this scenario evaluates (the explorer/budget are
    /// the campaign's contribution on top).
    pub fn eval_spec(&self, spec: &LlmSpec) -> EvalSpec {
        EvalSpec {
            model: spec.clone(),
            phase: self.phase,
            batch: self.batch,
            mqa: false,
            wafers: self.wafers,
            fidelity: self.fidelity,
        }
    }

    /// Flat JSON form (the schema pinned by
    /// `rust/tests/golden/campaign_suite.json`).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("model", Json::Str(self.model.clone()))
            .set("phase", Json::Str(self.phase.name().to_string()))
            .set("batch", Json::Num(self.batch as f64))
            .set(
                "wafers",
                match self.wafers {
                    Some(n) => Json::Num(n as f64),
                    None => Json::Null,
                },
            )
            .set("explorer", Json::Str(self.explorer.name().to_string()))
            .set("fidelity", Json::Str(self.fidelity.name().to_string()))
            .set("iters", Json::Num(self.budget.iters as f64))
            .set("init", Json::Num(self.budget.init as f64))
            .set("pool", Json::Num(self.budget.pool as f64))
            .set("mc", Json::Num(self.budget.mc as f64))
            .set("n1", Json::Num(self.budget.n1 as f64))
            .set("k", Json::Num(self.budget.k as f64))
            .set("tag", Json::Str(self.tag.clone()));
        o
    }

    /// Every field [`Scenario::from_json`] accepts — anything else is
    /// rejected (a typo like `iter` silently falling back to the
    /// 40-iteration paper budget would burn hours across a matrix).
    pub const FIELDS: [&'static str; 13] = [
        "batch", "explorer", "fidelity", "init", "iters", "k", "mc", "model", "n1", "phase",
        "pool", "tag", "wafers",
    ];

    /// Decode one scenario object. `model`, `phase` and `explorer` are
    /// required; everything else defaults (fidelity analytical, batch 0 /
    /// 32 by phase, wafers auto, paper budget, empty tag). Unknown fields
    /// are errors, not silent fallbacks; phase and fidelity values parse
    /// through the shared registries, so the error lists exactly the
    /// names every other entry point accepts.
    pub fn from_json(j: &Json) -> Result<Scenario, String> {
        let obj = j
            .as_obj()
            .ok_or_else(|| "scenario must be a JSON object".to_string())?;
        for field in obj.keys() {
            if !Scenario::FIELDS.iter().any(|f| *f == field.as_str()) {
                return Err(format!(
                    "unknown scenario field '{field}' — valid: {}",
                    Scenario::FIELDS.join(", ")
                ));
            }
        }
        let str_field = |key: &str| -> Result<String, String> {
            j.get(key)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| format!("scenario missing string field '{key}'"))
        };
        let usize_field = |key: &str, default: usize| -> Result<usize, String> {
            match j.get(key) {
                None | Some(Json::Null) => Ok(default),
                Some(v) => v
                    .as_f64()
                    .filter(|x| *x >= 0.0 && x.fract() == 0.0)
                    .map(|x| x as usize)
                    .ok_or_else(|| format!("scenario field '{key}' must be a non-negative integer")),
            }
        };
        let phase = Phase::parse_or_usage(&str_field("phase")?)?;
        let explorer = Explorer::parse_or_usage(&str_field("explorer")?)?;
        let fidelity = match j.get("fidelity") {
            None | Some(Json::Null) => Fidelity::Analytical,
            Some(_) => Fidelity::parse_or_usage(&str_field("fidelity")?)?,
        };
        let default_budget = Budget::default();
        let scenario = Scenario {
            model: str_field("model")?,
            phase,
            batch: usize_field("batch", if phase.is_inference() { 32 } else { 0 })?,
            wafers: match j.get("wafers") {
                None | Some(Json::Null) => None,
                Some(_) => Some(usize_field("wafers", 1)?),
            },
            explorer,
            fidelity,
            budget: Budget {
                iters: usize_field("iters", default_budget.iters)?,
                init: usize_field("init", default_budget.init)?,
                pool: usize_field("pool", default_budget.pool)?,
                mc: usize_field("mc", default_budget.mc)?,
                n1: usize_field("n1", default_budget.n1)?,
                k: usize_field("k", default_budget.k)?,
            },
            tag: match j.get("tag") {
                None | Some(Json::Null) => String::new(),
                Some(_) => str_field("tag")?,
            },
        };
        if scenario.phase.is_inference() && scenario.batch == 0 {
            return Err(format!(
                "scenario '{}': inference phases need batch >= 1",
                scenario.key()
            ));
        }
        Ok(scenario)
    }
}

/// Serialize a scenario list as `{"scenarios": [...]}` (the campaign-file
/// format; also the golden-pinned form of [`paper_suite`]).
pub fn suite_to_json(scenarios: &[Scenario]) -> Json {
    let mut doc = Json::obj();
    doc.set(
        "scenarios",
        Json::Arr(scenarios.iter().map(Scenario::to_json).collect()),
    );
    doc
}

/// Decode a campaign file: either `{"scenarios": [...]}` or a bare array.
pub fn scenarios_from_json(j: &Json) -> Result<Vec<Scenario>, String> {
    let arr = match j.get("scenarios") {
        Some(v) => v,
        None => j,
    };
    let arr = arr
        .as_arr()
        .ok_or_else(|| "campaign file must be a JSON array of scenarios or {\"scenarios\": [...]}".to_string())?;
    arr.iter()
        .enumerate()
        .map(|(i, s)| Scenario::from_json(s).map_err(|e| format!("scenario {i}: {e}")))
        .collect()
}

/// The §IX evaluation matrix: every Table II benchmark × {training,
/// decode inference} × {random, mobo, mfmobo}, analytical fidelity,
/// area-matched sizing, the paper's search budget — 96 scenarios.
pub fn paper_suite() -> Vec<Scenario> {
    let budget = Budget::default();
    let mut out = Vec::new();
    for m in models::benchmarks() {
        for phase in [Phase::Training, Phase::Decode] {
            for explorer in [Explorer::Random, Explorer::Mobo, Explorer::Mfmobo] {
                out.push(Scenario {
                    model: m.name.clone(),
                    phase,
                    batch: if phase.is_inference() { 32 } else { 0 },
                    wafers: None,
                    explorer,
                    fidelity: Fidelity::Analytical,
                    budget,
                    tag: String::new(),
                });
            }
        }
    }
    out
}

/// Derive a scenario's RNG seed from the campaign seed and the scenario
/// key: FNV-1a(key) XOR campaign seed, finalized with SplitMix64. The
/// derivation is position-independent — adding or removing sibling
/// scenarios never changes a surviving scenario's stream.
pub fn scenario_seed(campaign_seed: u64, key: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in key.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    let mut z = campaign_seed ^ h;
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// A campaign: scenarios + the seed every scenario seed derives from +
/// the fan-out width + the optional resume source.
#[derive(Debug, Clone)]
pub struct CampaignConfig {
    pub scenarios: Vec<Scenario>,
    pub seed: u64,
    /// Concurrent scenarios (0 = thread-pool default). Each scenario's
    /// evaluation fans strategies over its own pool, so a small `jobs`
    /// bounds oversubscription.
    pub jobs: usize,
    /// `Some(dir)`: skip scenarios whose `scenarios/<key>.json` already
    /// exists under `dir`, recording them as resumed rows (the
    /// `theseus campaign --resume` contract; see the module docs).
    pub resume_from: Option<std::path::PathBuf>,
}

/// How a scenario's row came to be.
#[derive(Debug)]
pub enum Outcome {
    /// Evaluated in this run: the trace, or the error that isolated it.
    Done(Result<Trace, String>),
    /// Skipped under `--resume`: the parsed pre-existing
    /// `scenarios/<key>.json` artifact stands in for the trace
    /// ([`resume_artifact`] guarantees its status is `ok`).
    Resumed(Json),
    /// `--resume` found an artifact it can neither stand in nor safely
    /// overwrite (wrong seed, wrong spec, unparseable): a loud error row,
    /// and [`write_artifacts`] leaves the pre-existing file untouched so
    /// the user can inspect it before deleting.
    ResumeConflict(String),
}

impl Outcome {
    /// The in-memory trace, when this run evaluated the scenario.
    pub fn trace(&self) -> Option<&Trace> {
        match self {
            Outcome::Done(Ok(t)) => Some(t),
            _ => None,
        }
    }

    /// The isolating error of this row, if any.
    pub fn error(&self) -> Option<String> {
        match self {
            Outcome::Done(Ok(_)) => None,
            Outcome::Done(Err(e)) => Some(e.clone()),
            // resume_artifact only stands in finished (status ok)
            // artifacts; failures and conflicts take the other variants.
            Outcome::Resumed(_) => None,
            Outcome::ResumeConflict(e) => Some(e.clone()),
        }
    }

    pub fn is_resumed(&self) -> bool {
        matches!(self, Outcome::Resumed(_))
    }
}

/// One scenario's outcome row.
#[derive(Debug)]
pub struct ScenarioResult {
    pub scenario: Scenario,
    pub seed: u64,
    pub outcome: Outcome,
}

#[derive(Debug)]
pub struct CampaignResult {
    pub campaign_seed: u64,
    pub rows: Vec<ScenarioResult>,
}

impl CampaignResult {
    pub fn n_errors(&self) -> usize {
        self.rows.iter().filter(|r| r.outcome.error().is_some()).count()
    }

    pub fn n_resumed(&self) -> usize {
        self.rows.iter().filter(|r| r.outcome.is_resumed()).count()
    }
}

fn bo_config(s: &Scenario, spec: &LlmSpec, seed: u64) -> BoConfig {
    BoConfig {
        iters: s.budget.iters,
        init: s.budget.init,
        pool: s.budget.pool,
        mc_samples: s.budget.mc,
        ref_power: ref_power_for(spec),
        seed,
        sample_tries: 4000,
    }
}

/// Run one scenario at its derived seed: resolve the model, build the
/// engine spec, and drive the coordinator's shared explorer dispatch.
/// Works for any (phase × fidelity) pair the engine supports; an
/// unavailable backend (e.g. `gnn` without artifacts) is the isolating
/// error of this row.
pub fn run_scenario(s: &Scenario, seed: u64) -> Result<Trace, String> {
    let spec = models::find_or_usage(&s.model)?;
    let cfg = bo_config(s, &spec, seed);
    explore(&s.eval_spec(&spec), s.explorer, &cfg, s.budget.n1, s.budget.k)
}

fn panic_message(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        format!("panic: {s}")
    } else if let Some(s) = p.downcast_ref::<String>() {
        format!("panic: {s}")
    } else {
        "panic: (non-string payload)".to_string()
    }
}

/// Probe the resume dir for a scenario's artifact. `None` = no finished
/// artifact, run fresh — including a recorded **error** row: a failure is
/// not finished work, so resume retries it (e.g. the `gnn` fidelity after
/// its artifacts were installed). `Some(Ok(doc))` = trustworthy finished
/// artifact (parses, seed matches the derivation, and the recorded
/// scenario spec — budgets included, which are invisible in the key —
/// matches this campaign's), stand it in. `Some(Err(e))` = the artifact
/// exists but cannot be trusted — a loud error row (never a silent
/// re-run, which would mix seeds/specs in one artifact dir; never a
/// silent reuse of wrong-seed or wrong-budget results).
fn resume_artifact(dir: &std::path::Path, s: &Scenario, seed: u64) -> Option<Result<Json, String>> {
    let path = dir.join("scenarios").join(format!("{}.json", s.key()));
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return None,
        Err(e) => return Some(Err(format!("resume: cannot read {}: {e}", path.display()))),
    };
    let doc = match Json::parse(&text) {
        Ok(d) => d,
        Err(e) => {
            return Some(Err(format!(
                "resume: cannot parse {}: {e}; delete it to re-run",
                path.display()
            )))
        }
    };
    match doc.get("status").and_then(Json::as_str) {
        Some("ok") => {}
        // A recorded failure did not finish: retry it fresh (the retry
        // overwrites the error artifact with whatever happens this time).
        Some("error") => return None,
        _ => {
            return Some(Err(format!(
                "resume: {} has no status field; delete it to re-run",
                path.display()
            )))
        }
    }
    match doc.get("seed").and_then(Json::as_str) {
        Some(recorded) if recorded == seed.to_string() => {}
        Some(recorded) => {
            return Some(Err(format!(
                "resume: {} was recorded at derived seed {recorded} but this campaign derives \
                 {seed} (--seed changed?); delete it to re-run",
                path.display()
            )))
        }
        None => {
            return Some(Err(format!(
                "resume: {} has no seed field; delete it to re-run",
                path.display()
            )))
        }
    }
    // The key (and so the seed) is blind to budget-only differences; the
    // artifact records the full scenario, so compare the whole spec.
    let expected = s.to_json();
    if doc.get("scenario") != Some(&expected) {
        return Some(Err(format!(
            "resume: {} was produced by a different scenario spec (budget or tag \
             changed?); delete it to re-run",
            path.display()
        )));
    }
    Some(Ok(doc))
}

/// Execute every scenario (fanned over the pool, `cfg.jobs` wide); a
/// failing scenario records an error row instead of sinking the campaign,
/// and with `resume_from` set, scenarios whose artifact already exists
/// are stood in from disk instead of re-evaluated.
///
/// Errors up front — before any evaluation — if two scenarios share a
/// [`Scenario::key`]: colliding keys would derive the same RNG seed and
/// overwrite each other's `scenarios/<key>.json` artifact. Give
/// budget-only variants distinct `tag`s.
pub fn run_campaign(cfg: &CampaignConfig) -> Result<CampaignResult, String> {
    let mut seen: std::collections::BTreeMap<String, usize> = std::collections::BTreeMap::new();
    for (i, s) in cfg.scenarios.iter().enumerate() {
        if let Some(first) = seen.insert(s.key(), i) {
            return Err(format!(
                "duplicate scenario key '{}' (scenarios {first} and {i}) — keys must be \
                 unique (shared derived seed + artifact overwrite); set a distinct \"tag\"",
                s.key()
            ));
        }
    }
    let rows = pool::par_map_workers(&cfg.scenarios, cfg.jobs, |s| {
        let seed = scenario_seed(cfg.seed, &s.key());
        let outcome = match cfg
            .resume_from
            .as_deref()
            .and_then(|dir| resume_artifact(dir, s, seed))
        {
            Some(Ok(doc)) => Outcome::Resumed(doc),
            Some(Err(e)) => Outcome::ResumeConflict(e),
            None => Outcome::Done(
                std::panic::catch_unwind(AssertUnwindSafe(|| run_scenario(s, seed)))
                    .unwrap_or_else(|p| Err(panic_message(p))),
            ),
        };
        ScenarioResult {
            scenario: s.clone(),
            seed,
            outcome,
        }
    });
    Ok(CampaignResult {
        campaign_seed: cfg.seed,
        rows,
    })
}

/// Pareto front of a trace in deterministic order: throughput descending,
/// ties by power ascending then config summary.
pub fn sorted_front(trace: &Trace) -> Vec<&TracePoint> {
    let mut front = trace.pareto();
    front.sort_by(|a, b| {
        b.objective
            .throughput
            .partial_cmp(&a.objective.throughput)
            .unwrap()
            .then(a.objective.power_w.partial_cmp(&b.objective.power_w).unwrap())
            .then_with(|| a.point.wsc.summary().cmp(&b.point.wsc.summary()))
    });
    front
}

/// GPU-cluster reference for a scenario, in the scenario's own throughput
/// metric: `(throughput, power_w)` of the area-matched H100 cluster.
pub fn gpu_reference(s: &Scenario, spec: &LlmSpec) -> Option<(f64, f64)> {
    match s.phase {
        Phase::Training => {
            h100_train_eval(spec, spec.gpu_num).map(|r| (r.tokens_per_sec, r.power_w))
        }
        Phase::Prefill => h100_infer_eval(spec, spec.gpu_num, s.batch.max(1), false)
            .map(|r| ((s.batch.max(1) * spec.seq_len) as f64 / r.prefill_s, r.power_w)),
        Phase::Decode => h100_infer_eval(spec, spec.gpu_num, s.batch.max(1), false)
            .map(|r| (s.batch.max(1) as f64 / r.decode_step_s, r.power_w)),
    }
}

/// Per-row digest — the single source of truth for "best Pareto point",
/// the GPU comparison and the row status, shared by [`summary_json`] and
/// the [`crate::figures::campaign`] table so the two renderings cannot
/// drift.
#[derive(Debug, Clone)]
pub struct RowSummary {
    pub key: String,
    /// `Some(message)` for error rows (all metric fields then empty).
    pub error: Option<String>,
    /// Row stood in from a pre-existing artifact (`--resume`).
    pub resumed: bool,
    pub points: usize,
    pub final_hv: f64,
    pub best_throughput: Option<f64>,
    pub best_power_w: Option<f64>,
    pub gpu_throughput: Option<f64>,
    pub gpu_power_w: Option<f64>,
    pub speedup_vs_gpu: Option<f64>,
}

impl RowSummary {
    /// Row status string (`campaign.json` and the summary table).
    pub fn status(&self) -> &'static str {
        if self.error.is_some() {
            "error"
        } else if self.resumed {
            "resumed"
        } else {
            "ok"
        }
    }
}

fn error_summary(key: String, e: String, resumed: bool) -> RowSummary {
    RowSummary {
        key,
        error: Some(e),
        resumed,
        points: 0,
        final_hv: 0.0,
        best_throughput: None,
        best_power_w: None,
        gpu_throughput: None,
        gpu_power_w: None,
        speedup_vs_gpu: None,
    }
}

pub fn summarize_row(r: &ScenarioResult) -> RowSummary {
    let key = r.scenario.key();
    if let Some(e) = r.outcome.error() {
        return error_summary(key, e, r.outcome.is_resumed());
    }
    // The GPU reference is recomputed (deterministically) from the
    // scenario spec, so resumed rows digest to the same bytes as fresh
    // ones.
    let gpu = models::find(&r.scenario.model).and_then(|spec| gpu_reference(&r.scenario, &spec));
    let (points, final_hv, best) = match &r.outcome {
        Outcome::Done(Ok(trace)) => {
            let front = sorted_front(trace);
            (
                trace.points.len(),
                trace.final_hv(),
                front
                    .first()
                    .map(|p| (p.objective.throughput, p.objective.power_w)),
            )
        }
        Outcome::Resumed(doc) => {
            // The artifact stores exactly the digest fields summary rows
            // need (sorted front first, hv, point count).
            let best = doc
                .get("pareto")
                .and_then(Json::as_arr)
                .and_then(|a| a.first())
                .and_then(|p| {
                    Some((
                        p.get("throughput").and_then(Json::as_f64)?,
                        p.get("power_w").and_then(Json::as_f64)?,
                    ))
                });
            (
                doc.get("points").and_then(Json::as_f64).unwrap_or(0.0) as usize,
                doc.get("final_hv").and_then(Json::as_f64).unwrap_or(0.0),
                best,
            )
        }
        Outcome::Done(Err(_)) | Outcome::ResumeConflict(_) => {
            unreachable!("error rows returned above")
        }
    };
    RowSummary {
        key,
        error: None,
        resumed: r.outcome.is_resumed(),
        points,
        final_hv,
        best_throughput: best.map(|b| b.0),
        best_power_w: best.map(|b| b.1),
        gpu_throughput: gpu.map(|g| g.0),
        gpu_power_w: gpu.map(|g| g.1),
        speedup_vs_gpu: match (best, gpu) {
            (Some(b), Some(g)) => Some(b.0 / g.0),
            _ => None,
        },
    }
}

/// Per-scenario artifact: spec + seed + trace + Pareto front +
/// hypervolume (or the error row). Excludes wall-clock so artifacts are
/// byte-identical across same-seed runs. Resumed rows re-emit their
/// pre-existing artifact verbatim (parse → serialize is byte-stable).
pub fn scenario_result_json(r: &ScenarioResult) -> Json {
    if let Outcome::Resumed(artifact) = &r.outcome {
        return artifact.clone();
    }
    let mut doc = Json::obj();
    doc.set("key", Json::Str(r.scenario.key()))
        .set("scenario", r.scenario.to_json())
        // Seeds are full-width u64; JSON numbers are f64, so keep exact.
        .set("seed", Json::Str(r.seed.to_string()));
    match &r.outcome {
        Outcome::Resumed(_) => unreachable!("returned above"),
        Outcome::Done(Ok(trace)) => {
            let mut pareto = Vec::new();
            for p in sorted_front(trace) {
                let mut o = Json::obj();
                o.set("throughput", Json::Num(p.objective.throughput))
                    .set("power_w", Json::Num(p.objective.power_w))
                    .set("fidelity", Json::Str(p.fidelity.to_string()))
                    .set("config", Json::Str(p.point.wsc.summary()));
                pareto.push(o);
            }
            doc.set("status", Json::Str("ok".to_string()))
                .set("trace", super::trace_to_json(trace))
                .set("pareto", Json::Arr(pareto))
                .set("final_hv", Json::Num(trace.final_hv()))
                .set("points", Json::Num(trace.points.len() as f64));
        }
        Outcome::Done(Err(e)) | Outcome::ResumeConflict(e) => {
            doc.set("status", Json::Str("error".to_string()))
                .set("error", Json::Str(e.clone()));
        }
    }
    doc
}

/// Cross-scenario summary (the `campaign.json` artifact): one row per
/// scenario with final hypervolume, the best point, and the
/// throughput/power comparison against the [`crate::baselines::gpu`]
/// reference (Fig. 11–13 in spirit).
pub fn summary_json(result: &CampaignResult) -> Json {
    let opt_num = |x: Option<f64>| x.map_or(Json::Null, Json::Num);
    let mut rows = Vec::new();
    for r in &result.rows {
        let s = summarize_row(r);
        let status = s.status();
        let mut o = Json::obj();
        o.set("key", Json::Str(s.key))
            .set("model", Json::Str(r.scenario.model.clone()))
            .set("phase", Json::Str(r.scenario.phase.name().to_string()))
            .set("explorer", Json::Str(r.scenario.explorer.name().to_string()))
            .set("fidelity", Json::Str(r.scenario.fidelity.name().to_string()))
            .set("seed", Json::Str(r.seed.to_string()))
            .set("status", Json::Str(status.to_string()));
        match s.error {
            None => {
                o.set("points", Json::Num(s.points as f64))
                    .set("final_hv", Json::Num(s.final_hv))
                    .set("best_throughput", opt_num(s.best_throughput))
                    .set("best_power_w", opt_num(s.best_power_w))
                    .set("gpu_throughput", opt_num(s.gpu_throughput))
                    .set("gpu_power_w", opt_num(s.gpu_power_w))
                    .set("speedup_vs_gpu", opt_num(s.speedup_vs_gpu));
            }
            Some(e) => {
                o.set("error", Json::Str(e));
            }
        }
        rows.push(o);
    }
    let mut doc = Json::obj();
    doc.set("campaign_seed", Json::Str(result.campaign_seed.to_string()))
        .set("n_scenarios", Json::Num(result.rows.len() as f64))
        .set("n_errors", Json::Num(result.n_errors() as f64))
        .set("scenarios", Json::Arr(rows));
    doc
}

/// Write the results store under `out`: `campaign.json` (cross-scenario
/// summary) + `scenarios/<key>.json` (per-scenario trace / Pareto front /
/// hypervolume or error row). All files are deterministic in the campaign
/// seed; resumed rows rewrite their pre-existing artifact byte-identically,
/// and resume-conflict rows write **nothing** — the untrusted pre-existing
/// artifact stays on disk for the user to inspect and delete.
pub fn write_artifacts(result: &CampaignResult, out: &std::path::Path) -> std::io::Result<()> {
    let scen_dir = out.join("scenarios");
    std::fs::create_dir_all(&scen_dir)?;
    for r in &result.rows {
        if matches!(r.outcome, Outcome::ResumeConflict(_)) {
            continue;
        }
        std::fs::write(
            scen_dir.join(format!("{}.json", r.scenario.key())),
            scenario_result_json(r).to_pretty() + "\n",
        )?;
    }
    std::fs::write(
        out.join("campaign.json"),
        summary_json(result).to_pretty() + "\n",
    )?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fresh_cfg(scenarios: Vec<Scenario>, seed: u64, jobs: usize) -> CampaignConfig {
        CampaignConfig {
            scenarios,
            seed,
            jobs,
            resume_from: None,
        }
    }

    #[test]
    fn paper_suite_shape() {
        let suite = paper_suite();
        // 16 models × {training, decode} × {random, mobo, mfmobo}.
        assert_eq!(suite.len(), 96);
        let mut keys: Vec<String> = suite.iter().map(Scenario::key).collect();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), 96, "scenario keys must be unique");
        assert!(suite.iter().all(|s| s.fidelity == Fidelity::Analytical));
        assert!(suite
            .iter()
            .filter(|s| s.phase == Phase::Training)
            .all(|s| s.batch == 0));
        assert!(suite
            .iter()
            .filter(|s| s.phase.is_inference())
            .all(|s| s.batch == 32));
    }

    #[test]
    fn scenario_json_roundtrip() {
        for s in [
            paper_suite()[0].clone(),
            Scenario {
                model: "GPT-175B".to_string(),
                phase: Phase::Prefill,
                batch: 8,
                wafers: Some(4),
                explorer: Explorer::Mobo,
                fidelity: Fidelity::GnnTest,
                budget: Budget {
                    iters: 3,
                    init: 2,
                    pool: 8,
                    mc: 16,
                    n1: 2,
                    k: 1,
                },
                tag: "Budget Sweep A".to_string(),
            },
        ] {
            let j = s.to_json();
            let back = Scenario::from_json(&j).unwrap();
            assert_eq!(back, s);
            // And through the text form.
            let reparsed = Json::parse(&j.to_string()).unwrap();
            assert_eq!(Scenario::from_json(&reparsed).unwrap(), s);
        }
    }

    #[test]
    fn from_json_defaults_and_errors_list_options() {
        let minimal = Json::parse(
            r#"{"model": "1.7", "phase": "decode", "explorer": "random"}"#,
        )
        .unwrap();
        let s = Scenario::from_json(&minimal).unwrap();
        assert_eq!(s.batch, 32);
        assert_eq!(s.wafers, None);
        assert_eq!(s.fidelity, Fidelity::Analytical);
        assert_eq!(s.budget, Budget::default());
        assert_eq!(s.tag, "");

        let bad_phase =
            Json::parse(r#"{"model": "1.7", "phase": "serving", "explorer": "random"}"#).unwrap();
        let e = Scenario::from_json(&bad_phase).unwrap_err();
        assert!(e.contains("training, prefill, decode"), "{e}");

        let bad_explorer =
            Json::parse(r#"{"model": "1.7", "phase": "decode", "explorer": "grid"}"#).unwrap();
        let e = Scenario::from_json(&bad_explorer).unwrap_err();
        assert!(e.contains("random, mobo, mfmobo"), "{e}");

        // The fidelity error lists the registry names — the same list
        // `theseus dse --fidelity` prints.
        let bad_fidelity = Json::parse(
            r#"{"model": "1.7", "phase": "training", "explorer": "mobo", "fidelity": "oracle"}"#,
        )
        .unwrap();
        let e = Scenario::from_json(&bad_fidelity).unwrap_err();
        assert!(e.contains("analytical, ca, gnn, gnn-test"), "{e}");

        // The legacy "cycle-accurate" alias still parses to the CA entry.
        let legacy = Json::parse(
            r#"{"model": "1.7", "phase": "training", "explorer": "mobo",
                "fidelity": "cycle-accurate"}"#,
        )
        .unwrap();
        assert_eq!(
            Scenario::from_json(&legacy).unwrap().fidelity,
            Fidelity::CycleAccurate
        );

        let zero_batch = Json::parse(
            r#"{"model": "1.7", "phase": "decode", "explorer": "random", "batch": 0}"#,
        )
        .unwrap();
        assert!(Scenario::from_json(&zero_batch)
            .unwrap_err()
            .contains("batch >= 1"));
    }

    #[test]
    fn scenarios_from_json_accepts_both_shapes() {
        let arr = Json::parse(r#"[{"model": "1.7", "phase": "training", "explorer": "random"}]"#)
            .unwrap();
        assert_eq!(scenarios_from_json(&arr).unwrap().len(), 1);
        let wrapped = suite_to_json(&paper_suite());
        assert_eq!(scenarios_from_json(&wrapped).unwrap(), paper_suite());
        let bad = Json::parse(r#"{"model": "x"}"#).unwrap();
        assert!(scenarios_from_json(&bad).is_err());
    }

    #[test]
    fn seed_derivation_is_stable_and_key_sensitive() {
        let a = scenario_seed(2024, "gpt-1.7b-training-random-analytical-b0-wauto");
        assert_eq!(
            a,
            scenario_seed(2024, "gpt-1.7b-training-random-analytical-b0-wauto")
        );
        assert_ne!(
            a,
            scenario_seed(2024, "gpt-1.7b-training-mobo-analytical-b0-wauto")
        );
        assert_ne!(
            a,
            scenario_seed(2025, "gpt-1.7b-training-random-analytical-b0-wauto")
        );
        // Every paper-suite scenario gets a distinct stream.
        let mut seeds: Vec<u64> = paper_suite()
            .iter()
            .map(|s| scenario_seed(7, &s.key()))
            .collect();
        seeds.sort();
        seeds.dedup();
        assert_eq!(seeds.len(), 96);
    }

    #[test]
    fn tag_disambiguates_keys_and_duplicates_are_rejected() {
        let mut a = paper_suite()[0].clone();
        let mut b = a.clone();
        b.budget.iters = 10; // budget-only difference: invisible in the key
        assert_eq!(a.key(), b.key());
        let cfg = fresh_cfg(vec![a.clone(), b.clone()], 1, 1);
        let e = run_campaign(&cfg).unwrap_err();
        assert!(e.contains("duplicate scenario key"), "{e}");
        assert!(e.contains(&a.key()), "{e}");
        // A tag restores uniqueness (and is slugged into the key).
        a.tag = "iters 40".to_string();
        b.tag = "iters10".to_string();
        assert_ne!(a.key(), b.key());
        assert!(a.key().ends_with("-iters-40"), "{}", a.key());
    }

    #[test]
    fn from_json_rejects_unknown_fields() {
        let typo = Json::parse(
            r#"{"model": "1.7", "phase": "training", "explorer": "mobo", "iter": 1}"#,
        )
        .unwrap();
        let e = Scenario::from_json(&typo).unwrap_err();
        assert!(e.contains("unknown scenario field 'iter'"), "{e}");
        assert!(e.contains("iters"), "must list the valid fields: {e}");
        assert!(Scenario::from_json(&Json::Num(3.0))
            .unwrap_err()
            .contains("JSON object"));
    }

    #[test]
    fn unknown_model_scenario_is_an_error_not_a_fallback() {
        let s = Scenario {
            model: "no-such-model".to_string(),
            phase: Phase::Training,
            batch: 0,
            wafers: None,
            explorer: Explorer::Random,
            fidelity: Fidelity::Analytical,
            budget: Budget::default(),
            tag: String::new(),
        };
        let e = run_scenario(&s, 1).unwrap_err();
        assert!(e.contains("unknown model 'no-such-model'"), "{e}");
        assert!(e.contains("GPT-175B"), "error must list valid models: {e}");
    }

    #[test]
    fn decode_scenarios_run_at_any_registry_fidelity() {
        // The engine API removed the inference = analytical-only
        // restriction: a gnn-test decode scenario runs end to end and its
        // trace points carry the gnn-test fidelity label (ISSUE 5
        // acceptance).
        let s = Scenario {
            model: "GPT-1.7B".to_string(),
            phase: Phase::Decode,
            batch: 4,
            wafers: None,
            explorer: Explorer::Random,
            fidelity: Fidelity::GnnTest,
            budget: Budget {
                iters: 1,
                init: 1,
                pool: 8,
                mc: 8,
                n1: 0,
                k: 0,
            },
            tag: String::new(),
        };
        let trace = run_scenario(&s, 11).expect("gnn-test decode scenario runs");
        assert!(!trace.points.is_empty());
        assert!(trace.points.iter().all(|p| p.fidelity == "gnn-test"));
    }
}
