//! Scenario campaign engine: one-command reproduction of the paper's full
//! DSE evaluation matrix (§IX).
//!
//! A [`Scenario`] is a declarative spec — model × phase (training /
//! prefill / decode) × inference batch × wafer count × explorer × fidelity
//! × BO budget — serializable to/from JSON. [`paper_suite`] mirrors the
//! §IX matrix (every Table II model × training + inference × {random,
//! mobo, mfmobo}); [`run_campaign`] fans scenarios over the thread pool
//! while the compile-chunk ([`crate::compiler::cache`]) and tile
//! ([`crate::eval::tile`]) memo caches — process-wide singletons — stay
//! shared across scenarios, so identical regions compiled by one scenario
//! are cache hits for the next.
//!
//! # Determinism contract
//!
//! Each scenario's RNG seed is derived as
//! `scenario_seed(campaign_seed, scenario.key())` — FNV-1a over the key
//! string, XORed into the campaign seed and finalized with SplitMix64 —
//! so a scenario's trace depends only on the campaign seed and its own
//! spec, never on sibling scenarios, worker interleaving, or position in
//! the matrix. Two runs with the same campaign seed produce byte-identical
//! artifacts (enforced by `rust/tests/campaign.rs`); adding or removing
//! scenarios does not perturb the survivors.
//!
//! # Failure isolation
//!
//! A failing scenario (unknown model key, unsupported fidelity, panic in
//! the evaluation stack) records an error row instead of aborting the
//! campaign; `campaign.json` reports per-row status.

use std::panic::AssertUnwindSafe;

use crate::baselines::{h100_infer_eval, h100_train_eval};
use crate::coordinator::{ref_power_for, AnalyticalTraining, Explorer, TrainingObjective};
use crate::design_space::Validated;
use crate::eval::{self, Analytical};
use crate::explorer::{
    mfmobo, mobo, random_search, random_search_par, BoConfig, DesignEval, MfConfig, Objective,
    Trace, TracePoint,
};
use crate::util::json::Json;
use crate::util::pool;
use crate::workload::{models, LlmSpec};

use super::objective::system_for;

/// Which workload phase a scenario optimizes for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScenarioPhase {
    Training,
    /// Inference prompt processing: throughput = prompt tokens/s.
    Prefill,
    /// Inference generation: throughput = generated tokens/s across the
    /// batch (the §IX-D serving metric).
    Decode,
}

impl ScenarioPhase {
    pub fn parse(s: &str) -> Option<ScenarioPhase> {
        match s {
            "training" => Some(ScenarioPhase::Training),
            "prefill" => Some(ScenarioPhase::Prefill),
            "decode" => Some(ScenarioPhase::Decode),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ScenarioPhase::Training => "training",
            ScenarioPhase::Prefill => "prefill",
            ScenarioPhase::Decode => "decode",
        }
    }

    pub fn is_inference(&self) -> bool {
        !matches!(self, ScenarioPhase::Training)
    }
}

/// Evaluation fidelity of a scenario's objective.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fidelity {
    /// Closed-form NoC model (§VI-C, low fidelity).
    Analytical,
    /// Deterministic pseudo-GNN ([`crate::runtime::TestBackend`]) through
    /// the batched inference path — the high-fidelity stage in builds
    /// without PJRT artifacts.
    GnnTest,
    /// Cycle-accurate NoC simulation (ground truth; expensive).
    CycleAccurate,
}

impl Fidelity {
    pub fn parse(s: &str) -> Option<Fidelity> {
        match s {
            "analytical" => Some(Fidelity::Analytical),
            "gnn-test" => Some(Fidelity::GnnTest),
            "cycle-accurate" => Some(Fidelity::CycleAccurate),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Fidelity::Analytical => "analytical",
            Fidelity::GnnTest => "gnn-test",
            Fidelity::CycleAccurate => "cycle-accurate",
        }
    }
}

/// Explorer budget (the BO knobs of [`BoConfig`] plus MFMOBO's split).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Budget {
    /// Evaluations after initialization.
    pub iters: usize,
    /// Initial design set size.
    pub init: usize,
    /// Candidate pool per BO iteration.
    pub pool: usize,
    /// Monte-Carlo EHVI samples.
    pub mc: usize,
    /// MFMOBO low-fidelity trials.
    pub n1: usize,
    /// MFMOBO guided-handoff iterations.
    pub k: usize,
}

impl Default for Budget {
    /// The paper's §VIII-C / §IX search budget (also the `theseus dse`
    /// CLI defaults).
    fn default() -> Budget {
        Budget {
            iters: 40,
            init: 6,
            pool: 96,
            mc: 64,
            n1: 40,
            k: 8,
        }
    }
}

/// One declarative DSE scenario of a campaign.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    /// Model key for [`models::find`] (index or name fragment).
    pub model: String,
    pub phase: ScenarioPhase,
    /// Inference batch (sequences in flight); 0 for training scenarios
    /// (the training batch comes from the model spec).
    pub batch: usize,
    /// Fixed wafer count; `None` = area-matched to the model's GPU
    /// cluster (§VIII-A).
    pub wafers: Option<usize>,
    pub explorer: Explorer,
    pub fidelity: Fidelity,
    pub budget: Budget,
    /// Free-form disambiguator, appended to [`Scenario::key`] when
    /// non-empty. Budget-only variations (e.g. an iteration-count sweep)
    /// don't show up in the key, so give each variant a distinct tag —
    /// [`run_campaign`] rejects campaigns with colliding keys (they would
    /// share a derived seed and overwrite each other's artifact file).
    pub tag: String,
}

fn slugify(s: &str) -> String {
    s.to_lowercase()
        .chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || c == '.' || c == '-' {
                c
            } else {
                '-'
            }
        })
        .collect()
}

impl Scenario {
    /// Stable identifier: artifact filename and seed-derivation input.
    pub fn key(&self) -> String {
        let wafers = match self.wafers {
            Some(n) => n.to_string(),
            None => "auto".to_string(),
        };
        let mut key = format!(
            "{}-{}-{}-{}-b{}-w{}",
            slugify(&self.model),
            self.phase.name(),
            self.explorer.name(),
            self.fidelity.name(),
            self.batch,
            wafers
        );
        if !self.tag.is_empty() {
            key.push('-');
            key.push_str(&slugify(&self.tag));
        }
        key
    }

    /// Flat JSON form (the schema pinned by
    /// `rust/tests/golden/campaign_suite.json`).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("model", Json::Str(self.model.clone()))
            .set("phase", Json::Str(self.phase.name().to_string()))
            .set("batch", Json::Num(self.batch as f64))
            .set(
                "wafers",
                match self.wafers {
                    Some(n) => Json::Num(n as f64),
                    None => Json::Null,
                },
            )
            .set("explorer", Json::Str(self.explorer.name().to_string()))
            .set("fidelity", Json::Str(self.fidelity.name().to_string()))
            .set("iters", Json::Num(self.budget.iters as f64))
            .set("init", Json::Num(self.budget.init as f64))
            .set("pool", Json::Num(self.budget.pool as f64))
            .set("mc", Json::Num(self.budget.mc as f64))
            .set("n1", Json::Num(self.budget.n1 as f64))
            .set("k", Json::Num(self.budget.k as f64))
            .set("tag", Json::Str(self.tag.clone()));
        o
    }

    /// Every field [`Scenario::from_json`] accepts — anything else is
    /// rejected (a typo like `iter` silently falling back to the
    /// 40-iteration paper budget would burn hours across a matrix).
    pub const FIELDS: [&'static str; 13] = [
        "batch", "explorer", "fidelity", "init", "iters", "k", "mc", "model", "n1", "phase",
        "pool", "tag", "wafers",
    ];

    /// Decode one scenario object. `model`, `phase` and `explorer` are
    /// required; everything else defaults (fidelity analytical, batch 0 /
    /// 32 by phase, wafers auto, paper budget, empty tag). Unknown fields
    /// are errors, not silent fallbacks.
    pub fn from_json(j: &Json) -> Result<Scenario, String> {
        let obj = j
            .as_obj()
            .ok_or_else(|| "scenario must be a JSON object".to_string())?;
        for field in obj.keys() {
            if !Scenario::FIELDS.iter().any(|f| *f == field.as_str()) {
                return Err(format!(
                    "unknown scenario field '{field}' — valid: {}",
                    Scenario::FIELDS.join(", ")
                ));
            }
        }
        let str_field = |key: &str| -> Result<String, String> {
            j.get(key)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| format!("scenario missing string field '{key}'"))
        };
        let usize_field = |key: &str, default: usize| -> Result<usize, String> {
            match j.get(key) {
                None | Some(Json::Null) => Ok(default),
                Some(v) => v
                    .as_f64()
                    .filter(|x| *x >= 0.0 && x.fract() == 0.0)
                    .map(|x| x as usize)
                    .ok_or_else(|| format!("scenario field '{key}' must be a non-negative integer")),
            }
        };
        let phase_s = str_field("phase")?;
        let phase = ScenarioPhase::parse(&phase_s)
            .ok_or_else(|| format!("unknown phase '{phase_s}' — valid: training, prefill, decode"))?;
        let explorer_s = str_field("explorer")?;
        let explorer = Explorer::parse(&explorer_s)
            .ok_or_else(|| format!("unknown explorer '{explorer_s}' — valid: random, mobo, mfmobo"))?;
        let fidelity_s = match j.get("fidelity") {
            None | Some(Json::Null) => Fidelity::Analytical.name().to_string(),
            Some(_) => str_field("fidelity")?,
        };
        let fidelity = Fidelity::parse(&fidelity_s).ok_or_else(|| {
            format!("unknown fidelity '{fidelity_s}' — valid: analytical, gnn-test, cycle-accurate")
        })?;
        let default_budget = Budget::default();
        let scenario = Scenario {
            model: str_field("model")?,
            phase,
            batch: usize_field("batch", if phase.is_inference() { 32 } else { 0 })?,
            wafers: match j.get("wafers") {
                None | Some(Json::Null) => None,
                Some(_) => Some(usize_field("wafers", 1)?),
            },
            explorer,
            fidelity,
            budget: Budget {
                iters: usize_field("iters", default_budget.iters)?,
                init: usize_field("init", default_budget.init)?,
                pool: usize_field("pool", default_budget.pool)?,
                mc: usize_field("mc", default_budget.mc)?,
                n1: usize_field("n1", default_budget.n1)?,
                k: usize_field("k", default_budget.k)?,
            },
            tag: match j.get("tag") {
                None | Some(Json::Null) => String::new(),
                Some(_) => str_field("tag")?,
            },
        };
        if scenario.phase.is_inference() && scenario.batch == 0 {
            return Err(format!(
                "scenario '{}': inference phases need batch >= 1",
                scenario.key()
            ));
        }
        Ok(scenario)
    }
}

/// Serialize a scenario list as `{"scenarios": [...]}` (the campaign-file
/// format; also the golden-pinned form of [`paper_suite`]).
pub fn suite_to_json(scenarios: &[Scenario]) -> Json {
    let mut doc = Json::obj();
    doc.set(
        "scenarios",
        Json::Arr(scenarios.iter().map(Scenario::to_json).collect()),
    );
    doc
}

/// Decode a campaign file: either `{"scenarios": [...]}` or a bare array.
pub fn scenarios_from_json(j: &Json) -> Result<Vec<Scenario>, String> {
    let arr = match j.get("scenarios") {
        Some(v) => v,
        None => j,
    };
    let arr = arr
        .as_arr()
        .ok_or_else(|| "campaign file must be a JSON array of scenarios or {\"scenarios\": [...]}".to_string())?;
    arr.iter()
        .enumerate()
        .map(|(i, s)| Scenario::from_json(s).map_err(|e| format!("scenario {i}: {e}")))
        .collect()
}

/// The §IX evaluation matrix: every Table II benchmark × {training,
/// decode inference} × {random, mobo, mfmobo}, analytical fidelity,
/// area-matched sizing, the paper's search budget — 96 scenarios.
pub fn paper_suite() -> Vec<Scenario> {
    let budget = Budget::default();
    let mut out = Vec::new();
    for m in models::benchmarks() {
        for phase in [ScenarioPhase::Training, ScenarioPhase::Decode] {
            for explorer in [Explorer::Random, Explorer::Mobo, Explorer::Mfmobo] {
                out.push(Scenario {
                    model: m.name.clone(),
                    phase,
                    batch: if phase.is_inference() { 32 } else { 0 },
                    wafers: None,
                    explorer,
                    fidelity: Fidelity::Analytical,
                    budget,
                    tag: String::new(),
                });
            }
        }
    }
    out
}

/// Derive a scenario's RNG seed from the campaign seed and the scenario
/// key: FNV-1a(key) XOR campaign seed, finalized with SplitMix64. The
/// derivation is position-independent — adding or removing sibling
/// scenarios never changes a surviving scenario's stream.
pub fn scenario_seed(campaign_seed: u64, key: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in key.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    let mut z = campaign_seed ^ h;
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// A campaign: scenarios + the seed every scenario seed derives from +
/// the fan-out width.
#[derive(Debug, Clone)]
pub struct CampaignConfig {
    pub scenarios: Vec<Scenario>,
    pub seed: u64,
    /// Concurrent scenarios (0 = thread-pool default). Each scenario's
    /// evaluation fans strategies over its own pool, so a small `jobs`
    /// bounds oversubscription.
    pub jobs: usize,
}

/// One scenario's outcome: the trace, or the error that isolated it.
#[derive(Debug)]
pub struct ScenarioResult {
    pub scenario: Scenario,
    pub seed: u64,
    pub outcome: Result<Trace, String>,
}

#[derive(Debug)]
pub struct CampaignResult {
    pub campaign_seed: u64,
    pub rows: Vec<ScenarioResult>,
}

impl CampaignResult {
    pub fn n_errors(&self) -> usize {
        self.rows.iter().filter(|r| r.outcome.is_err()).count()
    }
}

/// Phase-aware inference objective: throughput is the phase's serving
/// metric (prompt tokens/s for prefill, generated tokens/s for decode),
/// power the steady-state draw. Analytical fidelity only — `Sync`, so
/// random search fans over the pool.
struct PhaseInference {
    spec: LlmSpec,
    batch: usize,
    phase: ScenarioPhase,
    wafers: Option<usize>,
}

impl DesignEval for PhaseInference {
    fn eval(&self, v: &Validated) -> Option<Objective> {
        let sys = system_for(v, self.spec.gpu_num, self.wafers);
        let r = eval::eval_inference(&self.spec, &sys, self.batch, false, &Analytical)?;
        let throughput = match self.phase {
            ScenarioPhase::Prefill => (self.batch * self.spec.seq_len) as f64 / r.prefill_s,
            _ => self.batch as f64 / r.decode_step_s,
        };
        if !throughput.is_finite() {
            return None;
        }
        Some(Objective {
            throughput,
            power_w: r.power_w,
        })
    }

    fn name(&self) -> &'static str {
        match self.phase {
            ScenarioPhase::Prefill => "inference-prefill",
            _ => "inference-decode",
        }
    }
}

fn bo_config(s: &Scenario, spec: &LlmSpec, seed: u64) -> BoConfig {
    BoConfig {
        iters: s.budget.iters,
        init: s.budget.init,
        pool: s.budget.pool,
        mc_samples: s.budget.mc,
        ref_power: ref_power_for(spec),
        seed,
        sample_tries: 4000,
    }
}

fn mf_config(s: &Scenario, cfg: &BoConfig) -> MfConfig {
    MfConfig {
        base: cfg.clone(),
        n1: s.budget.n1,
        d0: cfg.init,
        d1: cfg.init,
        k: s.budget.k,
    }
}

fn run_training(s: &Scenario, spec: &LlmSpec, cfg: &BoConfig) -> Trace {
    let high: Box<dyn DesignEval> = match s.fidelity {
        Fidelity::Analytical => {
            Box::new(TrainingObjective::analytical(spec.clone()).with_wafers(s.wafers))
        }
        Fidelity::GnnTest => {
            Box::new(TrainingObjective::pseudo_gnn(spec.clone()).with_wafers(s.wafers))
        }
        Fidelity::CycleAccurate => {
            Box::new(TrainingObjective::cycle_accurate(spec.clone()).with_wafers(s.wafers))
        }
    };
    match s.explorer {
        // Analytical random search is Sync: fan evaluations over the pool
        // (forked per-slot RNG streams keep it deterministic in the seed).
        Explorer::Random if s.fidelity == Fidelity::Analytical => random_search_par(
            &AnalyticalTraining {
                spec: spec.clone(),
                wafers: s.wafers,
            },
            cfg,
        ),
        Explorer::Random => random_search(high.as_ref(), cfg),
        Explorer::Mobo => mobo(high.as_ref(), cfg),
        Explorer::Mfmobo => {
            let low = TrainingObjective::analytical(spec.clone()).with_wafers(s.wafers);
            mfmobo(high.as_ref(), &low, &mf_config(s, cfg))
        }
    }
}

fn run_inference(s: &Scenario, spec: &LlmSpec, cfg: &BoConfig) -> Result<Trace, String> {
    if s.fidelity != Fidelity::Analytical {
        return Err(format!(
            "inference scenarios support fidelity 'analytical' only (got '{}')",
            s.fidelity.name()
        ));
    }
    let obj = PhaseInference {
        spec: spec.clone(),
        batch: s.batch.max(1),
        phase: s.phase,
        wafers: s.wafers,
    };
    Ok(match s.explorer {
        Explorer::Random => random_search_par(&obj, cfg),
        Explorer::Mobo => mobo(&obj, cfg),
        // Inference has a single fidelity; MFMOBO degenerates to the same
        // objective at both levels (the budget split still applies).
        Explorer::Mfmobo => mfmobo(&obj, &obj, &mf_config(s, cfg)),
    })
}

/// Run one scenario at its derived seed.
pub fn run_scenario(s: &Scenario, seed: u64) -> Result<Trace, String> {
    let spec = models::find_or_usage(&s.model)?;
    let cfg = bo_config(s, &spec, seed);
    match s.phase {
        ScenarioPhase::Training => Ok(run_training(s, &spec, &cfg)),
        _ => run_inference(s, &spec, &cfg),
    }
}

fn panic_message(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        format!("panic: {s}")
    } else if let Some(s) = p.downcast_ref::<String>() {
        format!("panic: {s}")
    } else {
        "panic: (non-string payload)".to_string()
    }
}

/// Execute every scenario (fanned over the pool, `cfg.jobs` wide); a
/// failing scenario records an error row instead of sinking the campaign.
///
/// Errors up front — before any evaluation — if two scenarios share a
/// [`Scenario::key`]: colliding keys would derive the same RNG seed and
/// overwrite each other's `scenarios/<key>.json` artifact. Give
/// budget-only variants distinct `tag`s.
pub fn run_campaign(cfg: &CampaignConfig) -> Result<CampaignResult, String> {
    let mut seen: std::collections::BTreeMap<String, usize> = std::collections::BTreeMap::new();
    for (i, s) in cfg.scenarios.iter().enumerate() {
        if let Some(first) = seen.insert(s.key(), i) {
            return Err(format!(
                "duplicate scenario key '{}' (scenarios {first} and {i}) — keys must be \
                 unique (shared derived seed + artifact overwrite); set a distinct \"tag\"",
                s.key()
            ));
        }
    }
    let rows = pool::par_map_workers(&cfg.scenarios, cfg.jobs, |s| {
        let seed = scenario_seed(cfg.seed, &s.key());
        let outcome = std::panic::catch_unwind(AssertUnwindSafe(|| run_scenario(s, seed)))
            .unwrap_or_else(|p| Err(panic_message(p)));
        ScenarioResult {
            scenario: s.clone(),
            seed,
            outcome,
        }
    });
    Ok(CampaignResult {
        campaign_seed: cfg.seed,
        rows,
    })
}

/// Pareto front of a trace in deterministic order: throughput descending,
/// ties by power ascending then config summary.
pub fn sorted_front(trace: &Trace) -> Vec<&TracePoint> {
    let mut front = trace.pareto();
    front.sort_by(|a, b| {
        b.objective
            .throughput
            .partial_cmp(&a.objective.throughput)
            .unwrap()
            .then(a.objective.power_w.partial_cmp(&b.objective.power_w).unwrap())
            .then_with(|| a.point.wsc.summary().cmp(&b.point.wsc.summary()))
    });
    front
}

/// GPU-cluster reference for a scenario, in the scenario's own throughput
/// metric: `(throughput, power_w)` of the area-matched H100 cluster.
pub fn gpu_reference(s: &Scenario, spec: &LlmSpec) -> Option<(f64, f64)> {
    match s.phase {
        ScenarioPhase::Training => {
            h100_train_eval(spec, spec.gpu_num).map(|r| (r.tokens_per_sec, r.power_w))
        }
        ScenarioPhase::Prefill => h100_infer_eval(spec, spec.gpu_num, s.batch.max(1), false)
            .map(|r| ((s.batch.max(1) * spec.seq_len) as f64 / r.prefill_s, r.power_w)),
        ScenarioPhase::Decode => h100_infer_eval(spec, spec.gpu_num, s.batch.max(1), false)
            .map(|r| (s.batch.max(1) as f64 / r.decode_step_s, r.power_w)),
    }
}

/// Per-row digest — the single source of truth for "best Pareto point"
/// and the GPU comparison, shared by [`summary_json`] and the
/// [`crate::figures::campaign`] table so the two renderings cannot drift.
#[derive(Debug, Clone)]
pub struct RowSummary {
    pub key: String,
    /// `Some(message)` for error rows (all metric fields then empty).
    pub error: Option<String>,
    pub points: usize,
    pub final_hv: f64,
    pub best_throughput: Option<f64>,
    pub best_power_w: Option<f64>,
    pub gpu_throughput: Option<f64>,
    pub gpu_power_w: Option<f64>,
    pub speedup_vs_gpu: Option<f64>,
}

pub fn summarize_row(r: &ScenarioResult) -> RowSummary {
    let key = r.scenario.key();
    match &r.outcome {
        Err(e) => RowSummary {
            key,
            error: Some(e.clone()),
            points: 0,
            final_hv: 0.0,
            best_throughput: None,
            best_power_w: None,
            gpu_throughput: None,
            gpu_power_w: None,
            speedup_vs_gpu: None,
        },
        Ok(trace) => {
            let front = sorted_front(trace);
            let best = front
                .first()
                .map(|p| (p.objective.throughput, p.objective.power_w));
            let gpu = models::find(&r.scenario.model)
                .and_then(|spec| gpu_reference(&r.scenario, &spec));
            RowSummary {
                key,
                error: None,
                points: trace.points.len(),
                final_hv: trace.final_hv(),
                best_throughput: best.map(|b| b.0),
                best_power_w: best.map(|b| b.1),
                gpu_throughput: gpu.map(|g| g.0),
                gpu_power_w: gpu.map(|g| g.1),
                speedup_vs_gpu: match (best, gpu) {
                    (Some(b), Some(g)) => Some(b.0 / g.0),
                    _ => None,
                },
            }
        }
    }
}

/// Per-scenario artifact: spec + seed + trace + Pareto front +
/// hypervolume (or the error row). Excludes wall-clock so artifacts are
/// byte-identical across same-seed runs.
pub fn scenario_result_json(r: &ScenarioResult) -> Json {
    let mut doc = Json::obj();
    doc.set("key", Json::Str(r.scenario.key()))
        .set("scenario", r.scenario.to_json())
        // Seeds are full-width u64; JSON numbers are f64, so keep exact.
        .set("seed", Json::Str(r.seed.to_string()));
    match &r.outcome {
        Ok(trace) => {
            let mut pareto = Vec::new();
            for p in sorted_front(trace) {
                let mut o = Json::obj();
                o.set("throughput", Json::Num(p.objective.throughput))
                    .set("power_w", Json::Num(p.objective.power_w))
                    .set("fidelity", Json::Str(p.fidelity.to_string()))
                    .set("config", Json::Str(p.point.wsc.summary()));
                pareto.push(o);
            }
            doc.set("status", Json::Str("ok".to_string()))
                .set("trace", super::trace_to_json(trace))
                .set("pareto", Json::Arr(pareto))
                .set("final_hv", Json::Num(trace.final_hv()))
                .set("points", Json::Num(trace.points.len() as f64));
        }
        Err(e) => {
            doc.set("status", Json::Str("error".to_string()))
                .set("error", Json::Str(e.clone()));
        }
    }
    doc
}

/// Cross-scenario summary (the `campaign.json` artifact): one row per
/// scenario with final hypervolume, the best point, and the
/// throughput/power comparison against the [`crate::baselines::gpu`]
/// reference (Fig. 11–13 in spirit).
pub fn summary_json(result: &CampaignResult) -> Json {
    let opt_num = |x: Option<f64>| x.map_or(Json::Null, Json::Num);
    let mut rows = Vec::new();
    for r in &result.rows {
        let s = summarize_row(r);
        let mut o = Json::obj();
        o.set("key", Json::Str(s.key))
            .set("model", Json::Str(r.scenario.model.clone()))
            .set("phase", Json::Str(r.scenario.phase.name().to_string()))
            .set("explorer", Json::Str(r.scenario.explorer.name().to_string()))
            .set("fidelity", Json::Str(r.scenario.fidelity.name().to_string()))
            .set("seed", Json::Str(r.seed.to_string()));
        match s.error {
            None => {
                o.set("status", Json::Str("ok".to_string()))
                    .set("points", Json::Num(s.points as f64))
                    .set("final_hv", Json::Num(s.final_hv))
                    .set("best_throughput", opt_num(s.best_throughput))
                    .set("best_power_w", opt_num(s.best_power_w))
                    .set("gpu_throughput", opt_num(s.gpu_throughput))
                    .set("gpu_power_w", opt_num(s.gpu_power_w))
                    .set("speedup_vs_gpu", opt_num(s.speedup_vs_gpu));
            }
            Some(e) => {
                o.set("status", Json::Str("error".to_string()))
                    .set("error", Json::Str(e));
            }
        }
        rows.push(o);
    }
    let mut doc = Json::obj();
    doc.set("campaign_seed", Json::Str(result.campaign_seed.to_string()))
        .set("n_scenarios", Json::Num(result.rows.len() as f64))
        .set("n_errors", Json::Num(result.n_errors() as f64))
        .set("scenarios", Json::Arr(rows));
    doc
}

/// Write the results store under `out`: `campaign.json` (cross-scenario
/// summary) + `scenarios/<key>.json` (per-scenario trace / Pareto front /
/// hypervolume or error row). All files are deterministic in the campaign
/// seed.
pub fn write_artifacts(result: &CampaignResult, out: &std::path::Path) -> std::io::Result<()> {
    let scen_dir = out.join("scenarios");
    std::fs::create_dir_all(&scen_dir)?;
    for r in &result.rows {
        std::fs::write(
            scen_dir.join(format!("{}.json", r.scenario.key())),
            scenario_result_json(r).to_pretty() + "\n",
        )?;
    }
    std::fs::write(
        out.join("campaign.json"),
        summary_json(result).to_pretty() + "\n",
    )?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_suite_shape() {
        let suite = paper_suite();
        // 16 models × {training, decode} × {random, mobo, mfmobo}.
        assert_eq!(suite.len(), 96);
        let mut keys: Vec<String> = suite.iter().map(Scenario::key).collect();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), 96, "scenario keys must be unique");
        assert!(suite.iter().all(|s| s.fidelity == Fidelity::Analytical));
        assert!(suite
            .iter()
            .filter(|s| s.phase == ScenarioPhase::Training)
            .all(|s| s.batch == 0));
        assert!(suite
            .iter()
            .filter(|s| s.phase.is_inference())
            .all(|s| s.batch == 32));
    }

    #[test]
    fn scenario_json_roundtrip() {
        for s in [
            paper_suite()[0].clone(),
            Scenario {
                model: "GPT-175B".to_string(),
                phase: ScenarioPhase::Prefill,
                batch: 8,
                wafers: Some(4),
                explorer: Explorer::Mobo,
                fidelity: Fidelity::GnnTest,
                budget: Budget {
                    iters: 3,
                    init: 2,
                    pool: 8,
                    mc: 16,
                    n1: 2,
                    k: 1,
                },
                tag: "Budget Sweep A".to_string(),
            },
        ] {
            let j = s.to_json();
            let back = Scenario::from_json(&j).unwrap();
            assert_eq!(back, s);
            // And through the text form.
            let reparsed = Json::parse(&j.to_string()).unwrap();
            assert_eq!(Scenario::from_json(&reparsed).unwrap(), s);
        }
    }

    #[test]
    fn from_json_defaults_and_errors_list_options() {
        let minimal = Json::parse(
            r#"{"model": "1.7", "phase": "decode", "explorer": "random"}"#,
        )
        .unwrap();
        let s = Scenario::from_json(&minimal).unwrap();
        assert_eq!(s.batch, 32);
        assert_eq!(s.wafers, None);
        assert_eq!(s.fidelity, Fidelity::Analytical);
        assert_eq!(s.budget, Budget::default());
        assert_eq!(s.tag, "");

        let bad_phase =
            Json::parse(r#"{"model": "1.7", "phase": "serving", "explorer": "random"}"#).unwrap();
        let e = Scenario::from_json(&bad_phase).unwrap_err();
        assert!(e.contains("training, prefill, decode"), "{e}");

        let bad_explorer =
            Json::parse(r#"{"model": "1.7", "phase": "decode", "explorer": "grid"}"#).unwrap();
        let e = Scenario::from_json(&bad_explorer).unwrap_err();
        assert!(e.contains("random, mobo, mfmobo"), "{e}");

        let bad_fidelity = Json::parse(
            r#"{"model": "1.7", "phase": "training", "explorer": "mobo", "fidelity": "oracle"}"#,
        )
        .unwrap();
        let e = Scenario::from_json(&bad_fidelity).unwrap_err();
        assert!(e.contains("analytical, gnn-test, cycle-accurate"), "{e}");

        let zero_batch = Json::parse(
            r#"{"model": "1.7", "phase": "decode", "explorer": "random", "batch": 0}"#,
        )
        .unwrap();
        assert!(Scenario::from_json(&zero_batch)
            .unwrap_err()
            .contains("batch >= 1"));
    }

    #[test]
    fn scenarios_from_json_accepts_both_shapes() {
        let arr = Json::parse(r#"[{"model": "1.7", "phase": "training", "explorer": "random"}]"#)
            .unwrap();
        assert_eq!(scenarios_from_json(&arr).unwrap().len(), 1);
        let wrapped = suite_to_json(&paper_suite());
        assert_eq!(scenarios_from_json(&wrapped).unwrap(), paper_suite());
        let bad = Json::parse(r#"{"model": "x"}"#).unwrap();
        assert!(scenarios_from_json(&bad).is_err());
    }

    #[test]
    fn seed_derivation_is_stable_and_key_sensitive() {
        let a = scenario_seed(2024, "gpt-1.7b-training-random-analytical-b0-wauto");
        assert_eq!(
            a,
            scenario_seed(2024, "gpt-1.7b-training-random-analytical-b0-wauto")
        );
        assert_ne!(
            a,
            scenario_seed(2024, "gpt-1.7b-training-mobo-analytical-b0-wauto")
        );
        assert_ne!(
            a,
            scenario_seed(2025, "gpt-1.7b-training-random-analytical-b0-wauto")
        );
        // Every paper-suite scenario gets a distinct stream.
        let mut seeds: Vec<u64> = paper_suite()
            .iter()
            .map(|s| scenario_seed(7, &s.key()))
            .collect();
        seeds.sort();
        seeds.dedup();
        assert_eq!(seeds.len(), 96);
    }

    #[test]
    fn tag_disambiguates_keys_and_duplicates_are_rejected() {
        let mut a = paper_suite()[0].clone();
        let mut b = a.clone();
        b.budget.iters = 10; // budget-only difference: invisible in the key
        assert_eq!(a.key(), b.key());
        let cfg = CampaignConfig {
            scenarios: vec![a.clone(), b.clone()],
            seed: 1,
            jobs: 1,
        };
        let e = run_campaign(&cfg).unwrap_err();
        assert!(e.contains("duplicate scenario key"), "{e}");
        assert!(e.contains(&a.key()), "{e}");
        // A tag restores uniqueness (and is slugged into the key).
        a.tag = "iters 40".to_string();
        b.tag = "iters10".to_string();
        assert_ne!(a.key(), b.key());
        assert!(a.key().ends_with("-iters-40"), "{}", a.key());
    }

    #[test]
    fn from_json_rejects_unknown_fields() {
        let typo = Json::parse(
            r#"{"model": "1.7", "phase": "training", "explorer": "mobo", "iter": 1}"#,
        )
        .unwrap();
        let e = Scenario::from_json(&typo).unwrap_err();
        assert!(e.contains("unknown scenario field 'iter'"), "{e}");
        assert!(e.contains("iters"), "must list the valid fields: {e}");
        assert!(Scenario::from_json(&Json::Num(3.0))
            .unwrap_err()
            .contains("JSON object"));
    }

    #[test]
    fn unknown_model_scenario_is_an_error_not_a_fallback() {
        let s = Scenario {
            model: "no-such-model".to_string(),
            phase: ScenarioPhase::Training,
            batch: 0,
            wafers: None,
            explorer: Explorer::Random,
            fidelity: Fidelity::Analytical,
            budget: Budget::default(),
            tag: String::new(),
        };
        let e = run_scenario(&s, 1).unwrap_err();
        assert!(e.contains("unknown model 'no-such-model'"), "{e}");
        assert!(e.contains("GPT-175B"), "error must list valid models: {e}");
    }

    #[test]
    fn inference_rejects_non_analytical_fidelity() {
        let s = Scenario {
            model: "1.7".to_string(),
            phase: ScenarioPhase::Decode,
            batch: 8,
            wafers: None,
            explorer: Explorer::Random,
            fidelity: Fidelity::CycleAccurate,
            budget: Budget::default(),
            tag: String::new(),
        };
        let e = run_scenario(&s, 1).unwrap_err();
        assert!(e.contains("analytical"), "{e}");
    }
}
