//! Scenario campaign engine: one-command reproduction of the paper's full
//! DSE evaluation matrix (§IX).
//!
//! A [`Scenario`] is a declarative spec — model × phase (training /
//! prefill / decode) × inference batch × wafer count × explorer × fidelity
//! × BO budget — serializable to/from JSON. Phases and fidelities parse
//! through the same registries as every other entry point
//! ([`crate::workload::Phase`], [`Fidelity`]); a scenario is just an
//! [`EvalSpec`] plus an explorer and budget, and [`run_scenario`] drives
//! it through the coordinator's single explorer-dispatch path
//! ([`crate::coordinator::explore`]). Any (phase × fidelity) pair runs —
//! decode scenarios ride the CA simulator or the (pseudo-)GNN exactly
//! like training ones.
//!
//! [`paper_suite`] mirrors the §IX matrix (every Table II model ×
//! training + inference × {random, mobo, mfmobo}); [`fault_suite`]
//! sweeps the fault-injection degradation matrix (defect-rate multiplier
//! × spare-row redundancy, digesting retained-throughput fraction and
//! perf/W per good-wafer cost per row); [`hetero_suite`] runs the
//! heterogeneous-wafer decode rows across every
//! [`HeteroGranularity`]; [`wafer_sweep_suite`] sweeps fixed wafer
//! counts through the inter-wafer network model
//! ([`crate::arch::interwafer`]), digesting each row's scaling
//! efficiency against the same design on one wafer; [`serving_suite`]
//! evaluates serving traffic ([`crate::serving`]) — each row generates a
//! deterministic request trace from its [`ServingSpec`] at the row's
//! derived seed, replays it through the discrete-event simulator on the
//! row's best searched design, and digests TTFT/latency percentiles,
//! aggregate tok/s and goodput-under-SLO per row. [`run_campaign`] fans
//! scenarios over the thread pool while the compile-chunk
//! ([`crate::compiler::cache`]) and tile ([`crate::eval::tile`]) memo
//! caches — process-wide singletons — stay shared across scenarios;
//! [`run_campaign_with_progress`] additionally reports completion ticks
//! to a caller-supplied hook (the `--progress` stderr lines) without
//! touching any artifact bytes.
//!
//! # Determinism contract
//!
//! Each scenario's RNG seed is derived as
//! `scenario_seed(campaign_seed, scenario.key())` — FNV-1a over the key
//! string, XORed into the campaign seed and finalized with SplitMix64 —
//! so a scenario's trace depends only on the campaign seed and its own
//! spec, never on sibling scenarios, worker interleaving, or position in
//! the matrix. Two runs with the same campaign seed produce byte-identical
//! artifacts (enforced by `rust/tests/campaign.rs`); adding or removing
//! scenarios does not perturb the survivors.
//!
//! # Resume
//!
//! With [`CampaignConfig::resume_from`] set (CLI: `theseus campaign
//! --resume`), a scenario whose `scenarios/<key>.json` already exists
//! under the artifact dir is not re-evaluated: the parsed artifact stands
//! in for the trace ([`Outcome::Resumed`]) and the summary records the
//! row as `resumed`. Because per-scenario seeds are position-independent,
//! a killed-then-resumed campaign writes byte-identical scenario
//! artifacts to an uninterrupted one (the `resumed` status marker in
//! `campaign.json` is the only difference — enforced by
//! `rust/tests/campaign.rs`). Only **finished** work is skipped: a
//! recorded error row is retried fresh (a failure is not a result — e.g.
//! the `gnn` fidelity heals on resume once its artifacts are installed).
//! An artifact that exists but cannot be trusted (unparseable, recorded
//! under a different derived seed because `--seed` changed, or recording
//! a different scenario spec — budgets are invisible in the key, so they
//! are compared explicitly) records a loud error row instead of being
//! silently re-run or silently reused, and [`write_artifacts`] leaves
//! the untrusted file untouched on disk; delete it to re-run that
//! scenario.
//!
//! # Sharding & merge
//!
//! [`CampaignConfig::shard`] (CLI: `theseus campaign --shard K/N`) runs
//! the deterministic subset of scenarios whose index in the full matrix
//! satisfies `i % N == K - 1`; duplicate-key validation still runs over
//! the **full** list so every shard rejects a broken spec identically.
//! Because per-scenario seeds are position-independent, a shard's
//! artifacts are byte-identical to the same scenarios' artifacts from an
//! unsharded run. A shard's `campaign.json` records `"shard": "K/N"` so
//! merge can detect the same shard supplied twice.
//!
//! [`merge_campaign`] (CLI: `--merge DIR,DIR,...`) fuses shard output
//! dirs into one campaign over the full scenario list: each scenario is
//! probed in every dir; exactly one finished artifact → reused verbatim
//! (`resumed` row); found in **more than one** dir → loud
//! `overlapping shards` error (the split was not a partition); found in
//! none, recorded as an error row, or recorded under a **changed spec**
//! (`spec_hash` + full-spec compare) → evaluated fresh. The merged
//! `campaign.json` is byte-identical to the unsharded campaign's modulo
//! the `resumed` status markers (enforced by `rust/tests/campaign.rs`
//! and the `scripts/ci_check.sh` shard smoke leg).
//!
//! # Failure isolation
//!
//! A failing scenario (unknown model key, unavailable fidelity backend,
//! panic in the evaluation stack) records an error row instead of
//! aborting the campaign; `campaign.json` reports per-row status.

use std::panic::AssertUnwindSafe;

use crate::arch::{HeteroConfig, HeteroGranularity, InterWaferNet, InterWaferTopology};
use crate::baselines::{h100_infer_eval, h100_train_eval};
use crate::coordinator::{explore, ref_power_for, Explorer};
use crate::design_space::validate;
use crate::eval::engine::{Engine, EvalSpec};
use crate::explorer::{BoConfig, DesignEval, Trace, TracePoint};
use crate::serving::{ArrivalProcess, SchedulerKind, ServingSpec};
use crate::util::json::Json;
use crate::util::pool;
use crate::workload::{models, LlmSpec, Phase};
use crate::yield_model::faults::FaultSpec;

pub use crate::eval::engine::Fidelity;

/// Explorer budget (the BO knobs of [`BoConfig`] plus MFMOBO's split).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Budget {
    /// Evaluations after initialization.
    pub iters: usize,
    /// Initial design set size.
    pub init: usize,
    /// Candidate pool per BO iteration.
    pub pool: usize,
    /// Monte-Carlo EHVI samples.
    pub mc: usize,
    /// MFMOBO low-fidelity trials.
    pub n1: usize,
    /// MFMOBO guided-handoff iterations.
    pub k: usize,
}

impl Default for Budget {
    /// The paper's §VIII-C / §IX search budget (also the `theseus dse`
    /// CLI defaults).
    fn default() -> Budget {
        Budget {
            iters: 40,
            init: 6,
            pool: 96,
            mc: 64,
            n1: 40,
            k: 8,
        }
    }
}

/// One declarative DSE scenario of a campaign.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    /// Model key for [`models::find`] (index or name fragment).
    pub model: String,
    pub phase: Phase,
    /// Inference batch (sequences in flight); 0 for training scenarios
    /// (the training batch comes from the model spec).
    pub batch: usize,
    /// Multi-query attention for inference scenarios (§IX-D: one KV head
    /// shared across the query heads, shrinking the decode KV cache).
    /// Rejected on training scenarios — MQA here is a serving-time
    /// optimization, not a training-time architecture change.
    pub mqa: bool,
    /// Fixed wafer count; `None` = area-matched to the model's GPU
    /// cluster (§VIII-A).
    pub wafers: Option<usize>,
    pub explorer: Explorer,
    pub fidelity: Fidelity,
    pub budget: Budget,
    /// Fault injection: defect-rate multiplier over the yield model's
    /// baseline (1.0 = nominal process, 0.0 = pristine sampling that
    /// still exercises the fault path). `None` disables injection
    /// entirely — the evaluation stays byte-identical to a pre-fault
    /// campaign. The fault sampling seed is the scenario's derived seed,
    /// so degradation rows inherit the campaign determinism contract.
    pub fault_defect: Option<f64>,
    /// Spare-row override for fault scenarios (Cerebras-style row
    /// redundancy); `None` = each design's own converged per-row
    /// allocation. Only meaningful with `fault_defect`.
    pub fault_spares: Option<usize>,
    /// Prefill/decode heterogeneity override applied to every design
    /// point (§V-B); `None` keeps each point's own setting.
    pub hetero: Option<HeteroConfig>,
    /// Inter-wafer network override ([`crate::arch::interwafer`]) applied
    /// to every design point; `None` keeps each point's own net (the
    /// searched axes / flat-NIC default). Inert at `wafers: 1`.
    pub interwafer: Option<InterWaferNet>,
    /// Serving-traffic workload ([`crate::serving`]): generate a request
    /// trace at the row's derived seed and replay it on the row's best
    /// searched design, digesting TTFT/latency/goodput. Inference phases
    /// only — rejected on training scenarios. `None` keeps the static
    /// single-point evaluation (and every pre-serving artifact byte).
    pub serving: Option<ServingSpec>,
    /// Free-form disambiguator, appended to [`Scenario::key`] when
    /// non-empty. Budget-only variations (e.g. an iteration-count sweep)
    /// don't show up in the key, so give each variant a distinct tag —
    /// [`run_campaign`] rejects campaigns with colliding keys (they would
    /// share a derived seed and overwrite each other's artifact file).
    pub tag: String,
}

fn slugify(s: &str) -> String {
    s.to_lowercase()
        .chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || c == '.' || c == '-' {
                c
            } else {
                '-'
            }
        })
        .collect()
}

impl Scenario {
    /// Stable identifier: artifact filename and seed-derivation input.
    pub fn key(&self) -> String {
        let wafers = match self.wafers {
            Some(n) => n.to_string(),
            None => "auto".to_string(),
        };
        let mut key = format!(
            "{}-{}-{}-{}-b{}-w{}",
            slugify(&self.model),
            self.phase.name(),
            self.explorer.name(),
            self.fidelity.name(),
            self.batch,
            wafers
        );
        // Suffix only when set, so every pre-mqa key (and its derived
        // seed, and its artifact filename) keeps its exact value.
        if self.mqa {
            key.push_str("-mqa");
        }
        if let Some(m) = self.fault_defect {
            key.push_str(&format!("-fd{m}"));
            match self.fault_spares {
                Some(n) => key.push_str(&format!("-fs{n}")),
                None => key.push_str("-fsauto"),
            }
        }
        if let Some(h) = self.hetero {
            key.push_str(&format!("-h{}", h.granularity.name()));
        }
        if let Some(n) = self.interwafer {
            key.push_str(&format!("-iw{}", n.topology.name()));
        }
        if let Some(sv) = self.serving {
            key.push_str(&format!("-sv{}-r{}", sv.arrival.name(), sv.rate_per_s));
            if sv.scheduler != SchedulerKind::Fcfs {
                key.push_str(&format!("-{}", sv.scheduler.name()));
            }
        }
        if !self.tag.is_empty() {
            key.push('-');
            key.push_str(&slugify(&self.tag));
        }
        key
    }

    /// Hash of the **full** scenario spec — FNV-1a over the canonical JSON
    /// text, so it covers the budget and every other field the key is
    /// blind to. Recorded in each artifact (`spec_hash`); shard-merge and
    /// resume probes use it (plus a full-spec comparison as the collision
    /// guard) to decide whether an on-disk artifact still matches this
    /// campaign's spec, so only scenarios whose spec actually changed
    /// re-execute.
    pub fn spec_hash(&self) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        for b in self.to_json().to_string().bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    }

    /// The engine spec this scenario evaluates (the explorer/budget are
    /// the campaign's contribution on top). `seed` is the scenario's
    /// derived seed — it doubles as the fault-map sampling seed so two
    /// same-seed campaigns inject identical defects.
    pub fn eval_spec(&self, spec: &LlmSpec, seed: u64) -> EvalSpec {
        EvalSpec {
            model: spec.clone(),
            phase: self.phase,
            batch: self.batch,
            mqa: self.mqa,
            wafers: self.wafers,
            fidelity: self.fidelity,
            faults: self.fault_defect.map(|m| FaultSpec {
                defect_multiplier: m,
                spares: self.fault_spares,
                seed,
            }),
            hetero: self.hetero,
            interwafer: self.interwafer,
        }
    }

    /// Flat JSON form (the schema pinned by
    /// `rust/tests/golden/campaign_suite.json`).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("model", Json::Str(self.model.clone()))
            .set("phase", Json::Str(self.phase.name().to_string()))
            .set("batch", Json::Num(self.batch as f64))
            .set("mqa", Json::Bool(self.mqa))
            .set(
                "wafers",
                match self.wafers {
                    Some(n) => Json::Num(n as f64),
                    None => Json::Null,
                },
            )
            .set("explorer", Json::Str(self.explorer.name().to_string()))
            .set("fidelity", Json::Str(self.fidelity.name().to_string()))
            .set("iters", Json::Num(self.budget.iters as f64))
            .set("init", Json::Num(self.budget.init as f64))
            .set("pool", Json::Num(self.budget.pool as f64))
            .set("mc", Json::Num(self.budget.mc as f64))
            .set("n1", Json::Num(self.budget.n1 as f64))
            .set("k", Json::Num(self.budget.k as f64))
            .set("tag", Json::Str(self.tag.clone()));
        // Robustness/heterogeneity knobs are emitted only when set, so
        // pre-fault campaign files and goldens keep their exact bytes.
        if let Some(m) = self.fault_defect {
            o.set("fault_defect", Json::Num(m));
            if let Some(n) = self.fault_spares {
                o.set("fault_spares", Json::Num(n as f64));
            }
        }
        if let Some(h) = self.hetero {
            o.set("hetero", Json::Str(h.granularity.name().to_string()))
                .set("hetero_ratio", Json::Num(h.prefill_ratio))
                .set("hetero_decode_bw", Json::Num(h.decode_stack_bw));
        }
        if let Some(n) = self.interwafer {
            o.set("interwafer", Json::Str(n.topology.name().to_string()))
                .set("interwafer_latency", Json::Num(n.link_latency))
                .set("interwafer_link_bw", Json::Num(n.link_bandwidth))
                .set("interwafer_links", Json::Num(n.links_per_wafer as f64));
        }
        if let Some(sv) = self.serving {
            o.set("serving", Json::Str(sv.arrival.name().to_string()))
                .set("serving_output", Json::Num(sv.mean_output as f64))
                .set("serving_prompt", Json::Num(sv.mean_prompt as f64))
                .set("serving_rate", Json::Num(sv.rate_per_s))
                .set("serving_requests", Json::Num(sv.requests as f64))
                .set(
                    "serving_scheduler",
                    Json::Str(sv.scheduler.name().to_string()),
                )
                .set("serving_slo", Json::Num(sv.slo_s));
        }
        o
    }

    /// Every field [`Scenario::from_json`] accepts — anything else is
    /// rejected (a typo like `iter` silently falling back to the
    /// 40-iteration paper budget would burn hours across a matrix).
    pub const FIELDS: [&'static str; 30] = [
        "batch",
        "explorer",
        "fault_defect",
        "fault_spares",
        "fidelity",
        "hetero",
        "hetero_decode_bw",
        "hetero_ratio",
        "init",
        "interwafer",
        "interwafer_latency",
        "interwafer_link_bw",
        "interwafer_links",
        "iters",
        "k",
        "mc",
        "model",
        "mqa",
        "n1",
        "phase",
        "pool",
        "serving",
        "serving_output",
        "serving_prompt",
        "serving_rate",
        "serving_requests",
        "serving_scheduler",
        "serving_slo",
        "tag",
        "wafers",
    ];

    /// Decode one scenario object. `model`, `phase` and `explorer` are
    /// required; everything else defaults (fidelity analytical, batch 0 /
    /// 32 by phase, wafers auto, paper budget, empty tag). Unknown fields
    /// are errors, not silent fallbacks; phase and fidelity values parse
    /// through the shared registries, so the error lists exactly the
    /// names every other entry point accepts.
    pub fn from_json(j: &Json) -> Result<Scenario, String> {
        let obj = j
            .as_obj()
            .ok_or_else(|| "scenario must be a JSON object".to_string())?;
        for field in obj.keys() {
            if !Scenario::FIELDS.iter().any(|f| *f == field.as_str()) {
                return Err(format!(
                    "unknown scenario field '{field}' — valid: {}",
                    Scenario::FIELDS.join(", ")
                ));
            }
        }
        let str_field = |key: &str| -> Result<String, String> {
            j.get(key)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| format!("scenario missing string field '{key}'"))
        };
        let usize_field = |key: &str, default: usize| -> Result<usize, String> {
            match j.get(key) {
                None | Some(Json::Null) => Ok(default),
                Some(v) => v
                    .as_f64()
                    .filter(|x| *x >= 0.0 && x.fract() == 0.0)
                    .map(|x| x as usize)
                    .ok_or_else(|| format!("scenario field '{key}' must be a non-negative integer")),
            }
        };
        let f64_field = |key: &str| -> Result<Option<f64>, String> {
            match j.get(key) {
                None | Some(Json::Null) => Ok(None),
                Some(v) => v
                    .as_f64()
                    .filter(|x| x.is_finite() && *x >= 0.0)
                    .map(Some)
                    .ok_or_else(|| {
                        format!("scenario field '{key}' must be a non-negative number")
                    }),
            }
        };
        let phase = Phase::parse_or_usage(&str_field("phase")?)?;
        let explorer = Explorer::parse_or_usage(&str_field("explorer")?)?;
        let fidelity = match j.get("fidelity") {
            None | Some(Json::Null) => Fidelity::Analytical,
            Some(_) => Fidelity::parse_or_usage(&str_field("fidelity")?)?,
        };
        let fault_defect = f64_field("fault_defect")?;
        let fault_spares = match j.get("fault_spares") {
            None | Some(Json::Null) => None,
            Some(_) => Some(usize_field("fault_spares", 0)?),
        };
        if fault_spares.is_some() && fault_defect.is_none() {
            return Err(
                "scenario field 'fault_spares' needs 'fault_defect' (nothing to repair on a \
                 fault-free evaluation)"
                    .to_string(),
            );
        }
        let hetero = match j.get("hetero") {
            None | Some(Json::Null) => {
                for k in ["hetero_ratio", "hetero_decode_bw"] {
                    if !matches!(j.get(k), None | Some(Json::Null)) {
                        return Err(format!(
                            "scenario field '{k}' needs 'hetero' (the granularity name)"
                        ));
                    }
                }
                None
            }
            Some(_) => {
                let name = str_field("hetero")?;
                let granularity = HeteroGranularity::parse(&name).ok_or_else(|| {
                    let names: Vec<&str> =
                        HeteroGranularity::ALL.iter().map(|g| g.name()).collect();
                    format!(
                        "unknown hetero granularity '{name}' — valid: {}",
                        names.join(", ")
                    )
                })?;
                Some(HeteroConfig {
                    granularity,
                    prefill_ratio: f64_field("hetero_ratio")?.unwrap_or(0.5),
                    decode_stack_bw: f64_field("hetero_decode_bw")?.unwrap_or(0.0),
                })
            }
        };
        let interwafer = match j.get("interwafer") {
            None | Some(Json::Null) => {
                for k in ["interwafer_links", "interwafer_link_bw", "interwafer_latency"] {
                    if !matches!(j.get(k), None | Some(Json::Null)) {
                        return Err(format!(
                            "scenario field '{k}' needs 'interwafer' (the topology name)"
                        ));
                    }
                }
                None
            }
            Some(_) => {
                let name = str_field("interwafer")?;
                let topology = InterWaferTopology::parse(&name).ok_or_else(|| {
                    let names: Vec<&str> =
                        InterWaferTopology::ALL.iter().map(|t| t.name()).collect();
                    format!(
                        "unknown inter-wafer topology '{name}' — valid: {}",
                        names.join(", ")
                    )
                })?;
                // Unspecified axes fall back to the flat-NIC default net
                // (same aggregate bandwidth as the pre-topology model).
                let default = InterWaferNet::default_for(crate::design_space::default_nic_count());
                Some(InterWaferNet {
                    topology,
                    links_per_wafer: usize_field("interwafer_links", default.links_per_wafer)?,
                    link_bandwidth: f64_field("interwafer_link_bw")?
                        .unwrap_or(default.link_bandwidth),
                    link_latency: f64_field("interwafer_latency")?
                        .unwrap_or(default.link_latency),
                })
            }
        };
        let serving = match j.get("serving") {
            None | Some(Json::Null) => {
                for k in [
                    "serving_output",
                    "serving_prompt",
                    "serving_rate",
                    "serving_requests",
                    "serving_scheduler",
                    "serving_slo",
                ] {
                    if !matches!(j.get(k), None | Some(Json::Null)) {
                        return Err(format!(
                            "scenario field '{k}' needs 'serving' (the arrival-process name)"
                        ));
                    }
                }
                None
            }
            Some(_) => {
                let arrival = ArrivalProcess::parse_or_usage(&str_field("serving")?)?;
                let rate_per_s = f64_field("serving_rate")?.unwrap_or(4.0);
                if rate_per_s <= 0.0 {
                    return Err(
                        "scenario field 'serving_rate' must be positive (requests/s)".to_string()
                    );
                }
                let slo_s = f64_field("serving_slo")?.unwrap_or(1.0);
                if slo_s <= 0.0 {
                    return Err(
                        "scenario field 'serving_slo' must be positive (TTFT SLO, seconds)"
                            .to_string(),
                    );
                }
                let scheduler = match j.get("serving_scheduler") {
                    None | Some(Json::Null) => SchedulerKind::Fcfs,
                    Some(_) => SchedulerKind::parse_or_usage(&str_field("serving_scheduler")?)?,
                };
                let requests = usize_field("serving_requests", 64)?;
                let mean_prompt = usize_field("serving_prompt", 512)?;
                let mean_output = usize_field("serving_output", 128)?;
                if requests == 0 || mean_prompt == 0 || mean_output == 0 {
                    return Err(
                        "scenario fields 'serving_requests', 'serving_prompt' and \
                         'serving_output' must be >= 1"
                            .to_string(),
                    );
                }
                Some(ServingSpec {
                    arrival,
                    rate_per_s,
                    requests,
                    mean_prompt,
                    mean_output,
                    slo_s,
                    scheduler,
                })
            }
        };
        if serving.is_some() && !phase.is_inference() {
            return Err(
                "scenario field 'serving' needs an inference phase (a request stream is served \
                 by prefill/decode steps, not by training)"
                    .to_string(),
            );
        }
        let mqa = match j.get("mqa") {
            None | Some(Json::Null) => false,
            Some(v) => v
                .as_bool()
                .ok_or_else(|| "scenario field 'mqa' must be a boolean".to_string())?,
        };
        if mqa && !phase.is_inference() {
            return Err(
                "scenario field 'mqa' needs an inference phase (multi-query attention is a \
                 serving-time KV-cache optimization)"
                    .to_string(),
            );
        }
        let default_budget = Budget::default();
        let scenario = Scenario {
            model: str_field("model")?,
            phase,
            batch: usize_field("batch", if phase.is_inference() { 32 } else { 0 })?,
            mqa,
            wafers: match j.get("wafers") {
                None | Some(Json::Null) => None,
                // 0 used to clamp silently to 1 in system sizing; a fixed
                // wafer count of zero is a spec bug, not a sizing policy.
                Some(_) => match usize_field("wafers", 1)? {
                    0 => {
                        return Err(
                            "scenario field 'wafers' must be >= 1 (omit it or use null \
                             for area-matched sizing)"
                                .to_string(),
                        )
                    }
                    n => Some(n),
                },
            },
            explorer,
            fidelity,
            budget: Budget {
                iters: usize_field("iters", default_budget.iters)?,
                init: usize_field("init", default_budget.init)?,
                pool: usize_field("pool", default_budget.pool)?,
                mc: usize_field("mc", default_budget.mc)?,
                n1: usize_field("n1", default_budget.n1)?,
                k: usize_field("k", default_budget.k)?,
            },
            fault_defect,
            fault_spares,
            hetero,
            interwafer,
            serving,
            tag: match j.get("tag") {
                None | Some(Json::Null) => String::new(),
                Some(_) => str_field("tag")?,
            },
        };
        if scenario.phase.is_inference() && scenario.batch == 0 {
            return Err(format!(
                "scenario '{}': inference phases need batch >= 1",
                scenario.key()
            ));
        }
        Ok(scenario)
    }
}

/// Serialize a scenario list as `{"scenarios": [...]}` (the campaign-file
/// format; also the golden-pinned form of [`paper_suite`]).
pub fn suite_to_json(scenarios: &[Scenario]) -> Json {
    let mut doc = Json::obj();
    doc.set(
        "scenarios",
        Json::Arr(scenarios.iter().map(Scenario::to_json).collect()),
    );
    doc
}

/// Decode a campaign file: either `{"scenarios": [...]}` or a bare array.
pub fn scenarios_from_json(j: &Json) -> Result<Vec<Scenario>, String> {
    let arr = match j.get("scenarios") {
        Some(v) => v,
        None => j,
    };
    let arr = arr
        .as_arr()
        .ok_or_else(|| "campaign file must be a JSON array of scenarios or {\"scenarios\": [...]}".to_string())?;
    arr.iter()
        .enumerate()
        .map(|(i, s)| Scenario::from_json(s).map_err(|e| format!("scenario {i}: {e}")))
        .collect()
}

/// The §IX evaluation matrix: every Table II benchmark × {training,
/// decode inference} × {random, mobo, mfmobo}, analytical fidelity,
/// area-matched sizing, the paper's search budget — 96 scenarios.
pub fn paper_suite() -> Vec<Scenario> {
    let budget = Budget::default();
    let mut out = Vec::new();
    for m in models::benchmarks() {
        for phase in [Phase::Training, Phase::Decode] {
            for explorer in [Explorer::Random, Explorer::Mobo, Explorer::Mfmobo] {
                out.push(Scenario {
                    model: m.name.clone(),
                    phase,
                    batch: if phase.is_inference() { 32 } else { 0 },
                    mqa: false,
                    wafers: None,
                    explorer,
                    fidelity: Fidelity::Analytical,
                    budget,
                    fault_defect: None,
                    fault_spares: None,
                    hetero: None,
                    interwafer: None,
                    serving: None,
                    tag: String::new(),
                });
            }
        }
    }
    out
}

/// Fault-injection degradation matrix: one representative model ×
/// training at a defect-rate-multiplier × spare-row grid. Each row
/// evaluates every candidate design on a yield-realistic defective wafer
/// sampled at the row's defect rate; the per-row artifact carries the
/// `fault` digest (throughput retained vs the same design fault-free, and
/// perf/W per good-wafer cost), so the matrix reads out directly as the
/// degradation curve and the value of row redundancy under worsening
/// process assumptions.
pub fn fault_suite() -> Vec<Scenario> {
    // Random search at a reduced budget: the degradation curve compares
    // rows against each other, not against the paper's full BO budget.
    let budget = Budget {
        iters: 8,
        init: 4,
        pool: 48,
        mc: 32,
        n1: 0,
        k: 0,
    };
    let mut out = Vec::new();
    for defect in [0.0, 1.0, 2.0, 4.0, 8.0] {
        // Spares 0 = no redundancy; auto = the design's own converged
        // per-row allocation — the pairing isolates what redundancy buys.
        for spares in [Some(0), None] {
            out.push(Scenario {
                model: "GPT-1.7B".to_string(),
                phase: Phase::Training,
                batch: 0,
                mqa: false,
                wafers: None,
                explorer: Explorer::Random,
                fidelity: Fidelity::Analytical,
                budget,
                fault_defect: Some(defect),
                fault_spares: spares,
                hetero: None,
                interwafer: None,
                serving: None,
                tag: String::new(),
            });
        }
    }
    out
}

/// Heterogeneous-inference matrix (§V-B / Fig. 4): decode serving on one
/// representative model across every heterogeneity granularity, exercising
/// [`crate::arch::hetero`] end to end through the campaign path (the
/// tested successor of `examples/inference_hetero.rs`).
pub fn hetero_suite() -> Vec<Scenario> {
    let budget = Budget {
        iters: 8,
        init: 4,
        pool: 48,
        mc: 32,
        n1: 0,
        k: 0,
    };
    HeteroGranularity::ALL
        .into_iter()
        .map(|granularity| Scenario {
            model: "GPT-1.7B".to_string(),
            phase: Phase::Decode,
            batch: 32,
            mqa: false,
            wafers: None,
            explorer: Explorer::Random,
            fidelity: Fidelity::Analytical,
            budget,
            fault_defect: None,
            fault_spares: None,
            hetero: Some(HeteroConfig {
                granularity,
                prefill_ratio: 0.5,
                decode_stack_bw: 2.0,
            }),
            interwafer: None,
            serving: None,
            tag: String::new(),
        })
        .collect()
}

/// Wafer-count scaling sweep (`theseus campaign --suite wafer-sweep`):
/// one representative model at fixed wafer counts 1, 2, 4, 8 × {training,
/// decode serving}, exercising the inter-wafer network model
/// ([`crate::arch::interwafer`]) end to end through the campaign path.
/// Each fixed-wafer row's artifact carries the `scaling` digest
/// ([`scaling_row_metrics`]): speedup of the row's best design over the
/// same design on a single wafer, and the scaling efficiency
/// (speedup / wafers) — the matrix reads out directly as the scale-out
/// curve.
pub fn wafer_sweep_suite() -> Vec<Scenario> {
    // Random search at a reduced budget: the scaling curve compares wafer
    // counts against each other, not against the paper's full BO budget.
    let budget = Budget {
        iters: 8,
        init: 4,
        pool: 48,
        mc: 32,
        n1: 0,
        k: 0,
    };
    let mut out = Vec::new();
    for wafers in [1usize, 2, 4, 8] {
        for phase in [Phase::Training, Phase::Decode] {
            out.push(Scenario {
                model: "GPT-1.7B".to_string(),
                phase,
                batch: if phase.is_inference() { 32 } else { 0 },
                mqa: false,
                wafers: Some(wafers),
                explorer: Explorer::Random,
                fidelity: Fidelity::Analytical,
                budget,
                fault_defect: None,
                fault_spares: None,
                hetero: None,
                interwafer: None,
                serving: None,
                tag: String::new(),
            });
        }
    }
    out
}

/// Serving-traffic matrix (`theseus campaign --suite serving`): arrival
/// process × arrival rate × {1, 4} wafers on one representative model,
/// decode phase, exercising the [`crate::serving`] subsystem end to end
/// through the campaign path. Each row generates its trace at the row's
/// derived seed, replays it on the row's best searched design through
/// the discrete-event simulator (multi-wafer rows route KV hand-offs
/// through the inter-wafer network), and carries the `serving` digest
/// ([`serving_row_metrics`]): aggregate tok/s, TTFT/latency P50/P99 and
/// goodput under the SLO — the matrix reads out directly as the
/// saturation curve of a design under load.
pub fn serving_suite() -> Vec<Scenario> {
    // Random search at a reduced budget: the serving curve compares
    // traffic shapes against each other, not against the paper's full BO
    // budget.
    let budget = Budget {
        iters: 8,
        init: 4,
        pool: 48,
        mc: 32,
        n1: 0,
        k: 0,
    };
    let mut out = Vec::new();
    for arrival in ArrivalProcess::ALL {
        for rate in [4.0, 16.0] {
            for wafers in [1usize, 4] {
                out.push(Scenario {
                    model: "GPT-1.7B".to_string(),
                    phase: Phase::Decode,
                    batch: 32,
                    mqa: false,
                    wafers: Some(wafers),
                    explorer: Explorer::Random,
                    fidelity: Fidelity::Analytical,
                    budget,
                    fault_defect: None,
                    fault_spares: None,
                    hetero: None,
                    interwafer: None,
                    serving: Some(ServingSpec {
                        arrival,
                        rate_per_s: rate,
                        requests: 48,
                        mean_prompt: 512,
                        mean_output: 64,
                        slo_s: 0.5,
                        scheduler: SchedulerKind::Fcfs,
                    }),
                    tag: String::new(),
                });
            }
        }
    }
    out
}

/// Derive a scenario's RNG seed from the campaign seed and the scenario
/// key: FNV-1a(key) XOR campaign seed, finalized with SplitMix64. The
/// derivation is position-independent — adding or removing sibling
/// scenarios never changes a surviving scenario's stream.
pub fn scenario_seed(campaign_seed: u64, key: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in key.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    let mut z = campaign_seed ^ h;
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// A campaign: scenarios + the seed every scenario seed derives from +
/// the fan-out width + the optional resume source + the optional shard.
#[derive(Debug, Clone)]
pub struct CampaignConfig {
    pub scenarios: Vec<Scenario>,
    pub seed: u64,
    /// Concurrent scenarios (0 = thread-pool default). Each scenario's
    /// evaluation fans strategies over its own pool, so a small `jobs`
    /// bounds oversubscription.
    pub jobs: usize,
    /// `Some(dir)`: skip scenarios whose `scenarios/<key>.json` already
    /// exists under `dir`, recording them as resumed rows (the
    /// `theseus campaign --resume` contract; see the module docs).
    pub resume_from: Option<std::path::PathBuf>,
    /// `Some((k, n))` — CLI `--shard k/n` — runs only the scenarios at
    /// 0-based index `i` with `i % n == k - 1` (1-based `k`), a
    /// deterministic round-robin slice of the full list. Because derived
    /// seeds are position-independent, shard artifacts are byte-identical
    /// to the same scenarios' artifacts in an unsharded run, and
    /// [`merge_campaign`] fuses disjoint shard outputs back into one
    /// campaign. The shard's `campaign.json` records `"shard": "k/n"`.
    pub shard: Option<(usize, usize)>,
}

impl CampaignConfig {
    /// The deterministic subset this config runs: the full scenario list,
    /// or its `--shard k/n` round-robin slice (see
    /// [`CampaignConfig::shard`]). The slices for `k = 1..=n` partition
    /// the full list exactly.
    pub fn sharded_scenarios(&self) -> Result<Vec<Scenario>, String> {
        match self.shard {
            Some((k, n)) => {
                if k == 0 || n == 0 || k > n {
                    return Err(format!("invalid shard {k}/{n} — need 1 <= K <= N"));
                }
                Ok(self
                    .scenarios
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| i % n == k - 1)
                    .map(|(_, s)| s.clone())
                    .collect())
            }
            None => Ok(self.scenarios.clone()),
        }
    }
}

/// Parse a `--shard k/n` spec (1-based `k`, `1 <= k <= n`).
pub fn parse_shard(s: &str) -> Result<(usize, usize), String> {
    let usage = || format!("invalid shard '{s}' — expected K/N with 1 <= K <= N (e.g. 2/4)");
    let (k, n) = s.split_once('/').ok_or_else(usage)?;
    let k: usize = k.trim().parse().map_err(|_| usage())?;
    let n: usize = n.trim().parse().map_err(|_| usage())?;
    if k == 0 || n == 0 || k > n {
        return Err(usage());
    }
    Ok((k, n))
}

/// How a scenario's row came to be.
#[derive(Debug)]
pub enum Outcome {
    /// Evaluated in this run: the trace, or the error that isolated it.
    Done(Result<Trace, String>),
    /// Skipped under `--resume`: the parsed pre-existing
    /// `scenarios/<key>.json` artifact stands in for the trace
    /// ([`resume_artifact`] guarantees its status is `ok`).
    Resumed(Json),
    /// `--resume` found an artifact it can neither stand in nor safely
    /// overwrite (wrong seed, wrong spec, unparseable): a loud error row,
    /// and [`write_artifacts`] leaves the pre-existing file untouched so
    /// the user can inspect it before deleting.
    ResumeConflict(String),
}

impl Outcome {
    /// The in-memory trace, when this run evaluated the scenario.
    pub fn trace(&self) -> Option<&Trace> {
        match self {
            Outcome::Done(Ok(t)) => Some(t),
            _ => None,
        }
    }

    /// The isolating error of this row, if any.
    pub fn error(&self) -> Option<String> {
        match self {
            Outcome::Done(Ok(_)) => None,
            Outcome::Done(Err(e)) => Some(e.clone()),
            // resume_artifact only stands in finished (status ok)
            // artifacts; failures and conflicts take the other variants.
            Outcome::Resumed(_) => None,
            Outcome::ResumeConflict(e) => Some(e.clone()),
        }
    }

    pub fn is_resumed(&self) -> bool {
        matches!(self, Outcome::Resumed(_))
    }
}

/// One scenario's outcome row.
#[derive(Debug)]
pub struct ScenarioResult {
    pub scenario: Scenario,
    pub seed: u64,
    pub outcome: Outcome,
}

#[derive(Debug)]
pub struct CampaignResult {
    pub campaign_seed: u64,
    /// The shard this result covers (recorded in `campaign.json` so
    /// [`merge_campaign`] can detect two dirs claiming the same shard);
    /// `None` for unsharded and merged campaigns.
    pub shard: Option<(usize, usize)>,
    pub rows: Vec<ScenarioResult>,
}

impl CampaignResult {
    pub fn n_errors(&self) -> usize {
        self.rows.iter().filter(|r| r.outcome.error().is_some()).count()
    }

    pub fn n_resumed(&self) -> usize {
        self.rows.iter().filter(|r| r.outcome.is_resumed()).count()
    }
}

fn bo_config(s: &Scenario, spec: &LlmSpec, seed: u64) -> BoConfig {
    BoConfig {
        iters: s.budget.iters,
        init: s.budget.init,
        pool: s.budget.pool,
        mc_samples: s.budget.mc,
        ref_power: ref_power_for(spec),
        seed,
        sample_tries: 4000,
    }
}

/// Run one scenario at its derived seed: resolve the model, build the
/// engine spec, and drive the coordinator's shared explorer dispatch.
/// Works for any (phase × fidelity) pair the engine supports; an
/// unavailable backend (e.g. `gnn` without artifacts) is the isolating
/// error of this row.
pub fn run_scenario(s: &Scenario, seed: u64) -> Result<Trace, String> {
    let spec = models::find_or_usage(&s.model)?;
    let cfg = bo_config(s, &spec, seed);
    let trace = explore(
        &s.eval_spec(&spec, seed),
        s.explorer,
        &cfg,
        s.budget.n1,
        s.budget.k,
    )?;
    // A fault row where no candidate survived is a finding about the
    // defect rate (every sampled region disconnected / no viable
    // strategy), but an empty trace would silently digest to zero metrics
    // — record it as the loud error the resume contract retries.
    if let Some(mult) = s.fault_defect {
        if trace.points.is_empty() {
            return Err(format!(
                "fault scenario '{}': no design evaluated successfully at defect multiplier \
                 {mult} — every sampled wafer region was disconnected or infeasible",
                s.key(),
            ));
        }
    }
    // A serving row whose trace cannot be simulated (no surviving design,
    // a design the simulator rejects, a wedged schedule) must be a loud
    // error row, not a row that silently lacks its digest — run the digest
    // once here for validation; the artifact/summary paths recompute it
    // deterministically (fault/scaling digest precedent).
    serving_row_digest(s, seed, &trace)?;
    Ok(trace)
}

/// Degradation digest of a fault-injection row: re-evaluate the row's
/// best Pareto design **fault-free** at the same fidelity/seed and report
/// the throughput fraction the defective wafer retains, plus perf/W per
/// good-wafer cost (wafers bought per working system: `n_wafers /
/// wafer_yield`). Deterministic in (scenario, seed), so resumed rows
/// reading this digest back from their artifact match fresh rows byte for
/// byte. `None` for non-fault rows and for rows whose best point cannot
/// be re-validated.
pub fn fault_row_metrics(s: &Scenario, seed: u64, trace: &Trace) -> Option<Json> {
    s.fault_defect?;
    let spec = models::find(&s.model)?;
    let best = sorted_front(trace).into_iter().next()?.clone();
    let v = validate(&best.point).ok()?;
    let free_spec = {
        let mut e = s.eval_spec(&spec, seed);
        e.faults = None;
        e
    };
    let baseline = Engine::new(free_spec.clone()).ok()?.eval(&v)?;
    let retained = if baseline.throughput > 0.0 {
        best.objective.throughput / baseline.throughput
    } else {
        0.0
    };
    // Wafer sizing is fault-blind (faults degrade a bought wafer, they
    // don't change how many are bought), so the fault-free spec sizes it.
    let sys = free_spec.system(&v);
    let wafer_cost = sys.n_wafers as f64 / v.phys.wafer_yield.max(1e-12);
    let perf_per_watt = best.objective.throughput / best.objective.power_w;
    let mut o = Json::obj();
    o.set("fault_free_throughput", Json::Num(baseline.throughput))
        .set("retained_fraction", Json::Num(retained))
        .set("wafer_cost", Json::Num(wafer_cost))
        .set(
            "perf_per_watt_per_wafer",
            Json::Num(perf_per_watt / wafer_cost),
        );
    Some(o)
}

/// Scale-out digest of a fixed-wafer-count row: re-evaluate the row's
/// best Pareto design at **one** wafer (same spec/fidelity/seed) and
/// report the speedup the extra wafers buy plus the scaling efficiency
/// (`speedup / wafers` — the retained fraction of linear scaling).
/// Deterministic in (scenario, seed), so resumed rows reading this digest
/// back from their artifact match fresh rows byte for byte. `None` for
/// area-matched rows and for rows whose best point cannot be
/// re-validated; single-wafer rows digest to efficiency 1 by
/// construction, anchoring the curve.
pub fn scaling_row_metrics(s: &Scenario, seed: u64, trace: &Trace) -> Option<Json> {
    let wafers = s.wafers?;
    let spec = models::find(&s.model)?;
    let best = sorted_front(trace).into_iter().next()?.clone();
    let v = validate(&best.point).ok()?;
    let single_spec = {
        let mut e = s.eval_spec(&spec, seed);
        e.wafers = Some(1);
        e
    };
    let single = Engine::new(single_spec).ok()?.eval(&v)?;
    if single.throughput <= 0.0 {
        return None;
    }
    let speedup = best.objective.throughput / single.throughput;
    let mut o = Json::obj();
    o.set("scaling_efficiency", Json::Num(speedup / wafers.max(1) as f64))
        .set("single_wafer_throughput", Json::Num(single.throughput))
        .set("speedup_vs_single_wafer", Json::Num(speedup));
    Some(o)
}

/// Serving digest of a serving row, with loud failures: generate the
/// row's trace at its derived seed, replay it on the row's best Pareto
/// design through the discrete-event simulator
/// ([`crate::serving::simulate`]), and digest the outcomes
/// ([`crate::serving::ServingMetrics`]). `Ok(None)` for non-serving rows;
/// `Err` when a serving row cannot produce its digest (no surviving
/// design, the simulator rejects the design, a wedged schedule) — the
/// error [`run_scenario`] surfaces as the row's isolating error.
/// Deterministic in (scenario, seed), so resumed rows reading the digest
/// back from their artifact match fresh rows byte for byte.
pub fn serving_row_digest(s: &Scenario, seed: u64, trace: &Trace) -> Result<Option<Json>, String> {
    let Some(sv) = s.serving else {
        return Ok(None);
    };
    let spec = models::find_or_usage(&s.model)?;
    let best = match sorted_front(trace).into_iter().next() {
        Some(p) => p.clone(),
        None => {
            return Err(format!(
                "serving scenario '{}': no design evaluated successfully — nothing to replay \
                 the request trace on",
                s.key()
            ))
        }
    };
    let v = validate(&best.point).map_err(|e| {
        format!(
            "serving scenario '{}': best design failed re-validation: {e}",
            s.key()
        )
    })?;
    let engine = Engine::new(s.eval_spec(&spec, seed))?;
    let sys = engine.system_for(&v);
    let requests = sv.trace(seed);
    let metrics = crate::serving::evaluate(&engine, &sys, &requests, sv.scheduler, sv.slo_s)
        .map_err(|e| format!("serving scenario '{}': {e}", s.key()))?;
    Ok(Some(metrics.to_json()))
}

/// [`serving_row_digest`] as the digest-shaped `Option` the artifact and
/// summary writers consume ([`fault_row_metrics`] convention). Real
/// failures were already surfaced loudly by [`run_scenario`]'s digest
/// validation, so flattening them away here cannot hide one.
pub fn serving_row_metrics(s: &Scenario, seed: u64, trace: &Trace) -> Option<Json> {
    serving_row_digest(s, seed, trace).ok().flatten()
}

fn panic_message(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        format!("panic: {s}")
    } else if let Some(s) = p.downcast_ref::<String>() {
        format!("panic: {s}")
    } else {
        "panic: (non-string payload)".to_string()
    }
}

/// Probe the resume dir for a scenario's artifact. `None` = no finished
/// artifact, run fresh — including a recorded **error** row: a failure is
/// not finished work, so resume retries it (e.g. the `gnn` fidelity after
/// its artifacts were installed). `Some(Ok(doc))` = trustworthy finished
/// artifact (parses, seed matches the derivation, and the recorded
/// scenario spec — budgets included, which are invisible in the key —
/// matches this campaign's), stand it in. `Some(Err(e))` = the artifact
/// exists but cannot be trusted — a loud error row (never a silent
/// re-run, which would mix seeds/specs in one artifact dir; never a
/// silent reuse of wrong-seed or wrong-budget results).
fn resume_artifact(dir: &std::path::Path, s: &Scenario, seed: u64) -> Option<Result<Json, String>> {
    match probe_artifact(dir, s, seed) {
        Probe::Missing | Probe::Retry => None,
        // Under --resume (one dir holding this exact campaign) a changed
        // spec is a conflict, not an implicit re-run: silently mixing
        // specs in one artifact dir is the failure mode the guard exists
        // for. merge_campaign treats the same probe as "stale, run fresh"
        // because the merged output dir is distinct from the probed ones.
        Probe::SpecChanged(e) | Probe::Conflict(e) => Some(Err(e)),
        Probe::Finished(doc) => Some(Ok(doc)),
    }
}

/// What the artifact dir holds for one scenario (shared by the `--resume`
/// and `--merge` probes, which map these states to outcomes differently —
/// see [`resume_artifact`] and [`merge_campaign`]).
enum Probe {
    /// No artifact on disk.
    Missing,
    /// A recorded **error** row: not finished work, run it fresh (the
    /// retry overwrites the error artifact with whatever happens now).
    Retry,
    /// A finished artifact recording a different scenario spec
    /// (`spec_hash` and/or the full recorded spec differ).
    SpecChanged(String),
    /// An artifact that exists but cannot be trusted: unreadable,
    /// unparseable, missing fields, or recorded at a different derived
    /// seed.
    Conflict(String),
    /// A trustworthy finished artifact (status ok, seed and spec match).
    Finished(Json),
}

fn probe_artifact(dir: &std::path::Path, s: &Scenario, seed: u64) -> Probe {
    let path = dir.join("scenarios").join(format!("{}.json", s.key()));
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Probe::Missing,
        Err(e) => return Probe::Conflict(format!("resume: cannot read {}: {e}", path.display())),
    };
    let doc = match Json::parse(&text) {
        Ok(d) => d,
        Err(e) => {
            return Probe::Conflict(format!(
                "resume: cannot parse {}: {e}; delete it to re-run",
                path.display()
            ))
        }
    };
    match doc.get("status").and_then(Json::as_str) {
        Some("ok") => {}
        Some("error") => return Probe::Retry,
        _ => {
            return Probe::Conflict(format!(
                "resume: {} has no status field; delete it to re-run",
                path.display()
            ))
        }
    }
    match doc.get("seed").and_then(Json::as_str) {
        Some(recorded) if recorded == seed.to_string() => {}
        Some(recorded) => {
            return Probe::Conflict(format!(
                "resume: {} was recorded at derived seed {recorded} but this campaign derives \
                 {seed} (--seed changed?); delete it to re-run",
                path.display()
            ))
        }
        None => {
            return Probe::Conflict(format!(
                "resume: {} has no seed field; delete it to re-run",
                path.display()
            ))
        }
    }
    // The key (and so the seed) is blind to budget-only differences. The
    // recorded spec_hash is the fast check; the full recorded scenario is
    // the collision guard (and covers pre-spec_hash artifacts, which
    // simply lack the field).
    let hash_differs = match doc.get("spec_hash").and_then(Json::as_str) {
        Some(recorded) => recorded != format!("{:016x}", s.spec_hash()),
        None => false,
    };
    if hash_differs || doc.get("scenario") != Some(&s.to_json()) {
        return Probe::SpecChanged(format!(
            "resume: {} was produced by a different scenario spec (budget or tag \
             changed?); delete it to re-run",
            path.display()
        ));
    }
    Probe::Finished(doc)
}

/// Execute every scenario (fanned over the pool, `cfg.jobs` wide); a
/// failing scenario records an error row instead of sinking the campaign,
/// and with `resume_from` set, scenarios whose artifact already exists
/// are stood in from disk instead of re-evaluated.
///
/// Errors up front — before any evaluation — if two scenarios share a
/// [`Scenario::key`]: colliding keys would derive the same RNG seed and
/// overwrite each other's `scenarios/<key>.json` artifact. Give
/// budget-only variants distinct `tag`s.
pub fn run_campaign(cfg: &CampaignConfig) -> Result<CampaignResult, String> {
    run_campaign_with_progress(cfg, None)
}

/// [`run_campaign`] with a completion hook: `progress(done, total, key)`
/// fires after each scenario finishes (evaluated, resumed or conflicted),
/// from whichever pool worker finished it. The hook is side-channel only
/// — it never touches rows or artifacts, so `--progress` runs stay
/// byte-identical to silent ones (the ci smoke leg diffs them). Callers
/// print from the hook (the campaign layer itself never writes stderr —
/// loud-failure convention).
pub fn run_campaign_with_progress(
    cfg: &CampaignConfig,
    progress: Option<&(dyn Fn(usize, usize, &str) + Sync)>,
) -> Result<CampaignResult, String> {
    check_unique_keys(&cfg.scenarios)?;
    // The duplicate-key guard above runs on the FULL list — a collision is
    // a campaign-spec bug even when the colliding pair lands in different
    // shards. The shard filter is a deterministic round-robin over list
    // position; derived seeds are position-independent, so the subset's
    // artifacts match the unsharded run's byte for byte.
    let selected = cfg.sharded_scenarios()?;
    let total = selected.len();
    let done = std::sync::atomic::AtomicUsize::new(0);
    let rows = pool::par_map_workers(&selected, cfg.jobs, |s| {
        let key = s.key();
        let seed = scenario_seed(cfg.seed, &key);
        let outcome = match cfg
            .resume_from
            .as_deref()
            .and_then(|dir| resume_artifact(dir, s, seed))
        {
            Some(Ok(doc)) => Outcome::Resumed(doc),
            Some(Err(e)) => Outcome::ResumeConflict(e),
            None => Outcome::Done(
                std::panic::catch_unwind(AssertUnwindSafe(|| run_scenario(s, seed)))
                    .unwrap_or_else(|p| Err(panic_message(p))),
            ),
        };
        if let Some(cb) = progress {
            let n = done.fetch_add(1, std::sync::atomic::Ordering::Relaxed) + 1;
            cb(n, total, &key);
        }
        ScenarioResult {
            scenario: s.clone(),
            seed,
            outcome,
        }
    });
    Ok(CampaignResult {
        campaign_seed: cfg.seed,
        shard: cfg.shard,
        rows,
    })
}

fn check_unique_keys(scenarios: &[Scenario]) -> Result<(), String> {
    let mut seen: std::collections::BTreeMap<String, usize> = std::collections::BTreeMap::new();
    for (i, s) in scenarios.iter().enumerate() {
        if let Some(first) = seen.insert(s.key(), i) {
            return Err(format!(
                "duplicate scenario key '{}' (scenarios {first} and {i}) — keys must be \
                 unique (shared derived seed + artifact overwrite); set a distinct \"tag\"",
                s.key()
            ));
        }
    }
    Ok(())
}

/// Fuse disjoint shard outputs (plus any pre-existing artifacts) back
/// into one campaign over the **full** scenario list — the
/// `theseus campaign --merge DIR,DIR,...` contract:
///
/// * Two merge dirs whose `campaign.json` declares the same `"shard"`
///   string are a loud **duplicate-shard** error (a copy-paste that would
///   otherwise masquerade as clean coverage).
/// * A scenario whose artifact exists in two or more dirs is a loud
///   **overlapping-shards** error — shards are disjoint by construction,
///   so overlap means the dirs don't come from one consistent split.
/// * A trustworthy finished artifact in exactly one dir stands in
///   ([`Outcome::Resumed`]), byte-identically re-emitted.
/// * A scenario missing everywhere, recorded as an error row, or recorded
///   under a **changed spec** (detected by `spec_hash` + full-spec
///   comparison) runs fresh here — the incremental re-run contract: only
///   work that is absent, failed, or stale re-executes.
/// * An artifact that exists but cannot be trusted (unparseable, wrong
///   derived seed) stays a loud conflict row, exactly as under
///   `--resume`.
///
/// The merged result carries no shard marker; modulo `"resumed"` status
/// markers its `campaign.json` is byte-identical to an unsharded run's.
pub fn merge_campaign(
    cfg: &CampaignConfig,
    dirs: &[std::path::PathBuf],
) -> Result<CampaignResult, String> {
    if dirs.is_empty() {
        return Err("--merge needs at least one shard directory".to_string());
    }
    check_unique_keys(&cfg.scenarios)?;
    // Duplicate-shard guard over the dirs' own campaign.json declarations.
    let mut shards_seen: std::collections::BTreeMap<String, &std::path::Path> =
        std::collections::BTreeMap::new();
    for d in dirs {
        let Ok(text) = std::fs::read_to_string(d.join("campaign.json")) else {
            continue; // a partial shard (killed before its summary) is fine
        };
        let Ok(doc) = Json::parse(&text) else {
            continue;
        };
        if let Some(sh) = doc.get("shard").and_then(Json::as_str) {
            if let Some(prev) = shards_seen.insert(sh.to_string(), d) {
                return Err(format!(
                    "duplicate shard {sh}: both {} and {} declare it — merge dirs must come \
                     from distinct shards",
                    prev.display(),
                    d.display()
                ));
            }
        }
    }
    // Plan serially (cheap disk probes + loud overlap errors), run the
    // fresh remainder over the pool.
    enum Plan {
        Resumed(Json),
        Conflict(String),
        Fresh,
    }
    let mut plans: Vec<Plan> = Vec::with_capacity(cfg.scenarios.len());
    for s in &cfg.scenarios {
        let seed = scenario_seed(cfg.seed, &s.key());
        let mut hits: Vec<(&std::path::Path, Probe)> = Vec::new();
        for d in dirs {
            match probe_artifact(d, s, seed) {
                Probe::Missing => {}
                p => hits.push((d, p)),
            }
        }
        if hits.len() > 1 {
            let where_ = hits
                .iter()
                .map(|(d, _)| d.display().to_string())
                .collect::<Vec<_>>()
                .join(", ");
            return Err(format!(
                "overlapping shards: scenario '{}' has artifacts in {} merge dirs ({where_}) — \
                 shard outputs must be disjoint",
                s.key(),
                hits.len()
            ));
        }
        plans.push(match hits.pop() {
            Some((_, Probe::Finished(doc))) => Plan::Resumed(doc),
            Some((_, Probe::Conflict(e))) => Plan::Conflict(e),
            // Stale spec or recorded failure: run fresh (incremental
            // re-run). Missing everywhere: run fresh too.
            Some((_, Probe::SpecChanged(_) | Probe::Retry)) | None => Plan::Fresh,
            // lint: allow(panic) hits retains only non-Missing probes: filtered in the loop above
            Some((_, Probe::Missing)) => unreachable!("Missing is filtered above"),
        });
    }
    let indexed: Vec<usize> = (0..cfg.scenarios.len()).collect();
    let rows = pool::par_map_workers(&indexed, cfg.jobs, |&i| {
        let s = &cfg.scenarios[i];
        let seed = scenario_seed(cfg.seed, &s.key());
        let outcome = match &plans[i] {
            Plan::Resumed(doc) => Outcome::Resumed(doc.clone()),
            Plan::Conflict(e) => Outcome::ResumeConflict(e.clone()),
            Plan::Fresh => Outcome::Done(
                std::panic::catch_unwind(AssertUnwindSafe(|| run_scenario(s, seed)))
                    .unwrap_or_else(|p| Err(panic_message(p))),
            ),
        };
        ScenarioResult {
            scenario: s.clone(),
            seed,
            outcome,
        }
    });
    Ok(CampaignResult {
        campaign_seed: cfg.seed,
        shard: None,
        rows,
    })
}

/// Pareto front of a trace in deterministic order: throughput descending,
/// ties by power ascending then config summary.
pub fn sorted_front(trace: &Trace) -> Vec<&TracePoint> {
    let mut front = trace.pareto();
    front.sort_by(|a, b| {
        b.objective
            .throughput
            .total_cmp(&a.objective.throughput)
            .then(a.objective.power_w.total_cmp(&b.objective.power_w))
            .then_with(|| a.point.wsc.summary().cmp(&b.point.wsc.summary()))
    });
    front
}

/// GPU-cluster reference for a scenario, in the scenario's own throughput
/// metric: `(throughput, power_w)` of the area-matched H100 cluster.
pub fn gpu_reference(s: &Scenario, spec: &LlmSpec) -> Option<(f64, f64)> {
    match s.phase {
        Phase::Training => {
            h100_train_eval(spec, spec.gpu_num).map(|r| (r.tokens_per_sec, r.power_w))
        }
        Phase::Prefill => h100_infer_eval(spec, spec.gpu_num, s.batch.max(1), false)
            .map(|r| ((s.batch.max(1) * spec.seq_len) as f64 / r.prefill_s, r.power_w)),
        Phase::Decode => h100_infer_eval(spec, spec.gpu_num, s.batch.max(1), false)
            .map(|r| (s.batch.max(1) as f64 / r.decode_step_s, r.power_w)),
    }
}

/// Per-row digest — the single source of truth for "best Pareto point",
/// the GPU comparison and the row status, shared by [`summary_json`] and
/// the [`crate::figures::campaign`] table so the two renderings cannot
/// drift.
#[derive(Debug, Clone)]
pub struct RowSummary {
    pub key: String,
    /// `Some(message)` for error rows (all metric fields then empty).
    pub error: Option<String>,
    /// Row stood in from a pre-existing artifact (`--resume`).
    pub resumed: bool,
    pub points: usize,
    pub final_hv: f64,
    pub best_throughput: Option<f64>,
    pub best_power_w: Option<f64>,
    pub gpu_throughput: Option<f64>,
    pub gpu_power_w: Option<f64>,
    pub speedup_vs_gpu: Option<f64>,
    /// Fault-injection rows only: throughput fraction the defective wafer
    /// retains vs the same best design fault-free.
    pub retained_fraction: Option<f64>,
    /// Fault-injection rows only: perf/W divided by the good-wafer cost
    /// (`n_wafers / wafer_yield`).
    pub perf_per_watt_per_wafer: Option<f64>,
    /// Fixed-wafer-count rows only: speedup over the same best design on
    /// a single wafer, divided by the wafer count.
    pub scaling_efficiency: Option<f64>,
    /// Serving rows only: aggregate output tokens/s over the simulated
    /// trace's makespan.
    pub serving_tokens_per_sec: Option<f64>,
    /// Serving rows only: P99 time-to-first-token, seconds.
    pub serving_ttft_p99: Option<f64>,
    /// Serving rows only: requests/s whose TTFT met the SLO.
    pub serving_goodput: Option<f64>,
}

impl RowSummary {
    /// Row status string (`campaign.json` and the summary table).
    pub fn status(&self) -> &'static str {
        if self.error.is_some() {
            "error"
        } else if self.resumed {
            "resumed"
        } else {
            "ok"
        }
    }
}

fn error_summary(key: String, e: String, resumed: bool) -> RowSummary {
    RowSummary {
        key,
        error: Some(e),
        resumed,
        points: 0,
        final_hv: 0.0,
        best_throughput: None,
        best_power_w: None,
        gpu_throughput: None,
        gpu_power_w: None,
        speedup_vs_gpu: None,
        retained_fraction: None,
        perf_per_watt_per_wafer: None,
        scaling_efficiency: None,
        serving_tokens_per_sec: None,
        serving_ttft_p99: None,
        serving_goodput: None,
    }
}

pub fn summarize_row(r: &ScenarioResult) -> RowSummary {
    let key = r.scenario.key();
    if let Some(e) = r.outcome.error() {
        return error_summary(key, e, r.outcome.is_resumed());
    }
    // The GPU reference is recomputed (deterministically) from the
    // scenario spec, so resumed rows digest to the same bytes as fresh
    // ones.
    let gpu = models::find(&r.scenario.model).and_then(|spec| gpu_reference(&r.scenario, &spec));
    let (points, final_hv, best, fault, scaling, serving) = match &r.outcome {
        Outcome::Done(Ok(trace)) => {
            let front = sorted_front(trace);
            let best = front
                .first()
                .map(|p| (p.objective.throughput, p.objective.power_w));
            (
                trace.points.len(),
                trace.final_hv(),
                best,
                fault_row_metrics(&r.scenario, r.seed, trace),
                scaling_row_metrics(&r.scenario, r.seed, trace),
                serving_row_metrics(&r.scenario, r.seed, trace),
            )
        }
        Outcome::Resumed(doc) => {
            // The artifact stores exactly the digest fields summary rows
            // need (sorted front first, hv, point count, fault digest).
            let best = doc
                .get("pareto")
                .and_then(Json::as_arr)
                .and_then(|a| a.first())
                .and_then(|p| {
                    Some((
                        p.get("throughput").and_then(Json::as_f64)?,
                        p.get("power_w").and_then(Json::as_f64)?,
                    ))
                });
            (
                doc.get("points").and_then(Json::as_f64).unwrap_or(0.0) as usize,
                doc.get("final_hv").and_then(Json::as_f64).unwrap_or(0.0),
                best,
                doc.get("fault").cloned(),
                doc.get("scaling").cloned(),
                doc.get("serving").cloned(),
            )
        }
        Outcome::Done(Err(_)) | Outcome::ResumeConflict(_) => {
            // lint: allow(panic) both error arms early-return a row at the top of this function
            unreachable!("error rows returned above")
        }
    };
    let fault_f64 = |field: &str| {
        fault
            .as_ref()
            .and_then(|f| f.get(field))
            .and_then(Json::as_f64)
    };
    let scaling_f64 = |field: &str| {
        scaling
            .as_ref()
            .and_then(|f| f.get(field))
            .and_then(Json::as_f64)
    };
    let serving_f64 = |field: &str| {
        serving
            .as_ref()
            .and_then(|f| f.get(field))
            .and_then(Json::as_f64)
    };
    RowSummary {
        key,
        error: None,
        resumed: r.outcome.is_resumed(),
        points,
        final_hv,
        best_throughput: best.map(|b| b.0),
        best_power_w: best.map(|b| b.1),
        gpu_throughput: gpu.map(|g| g.0),
        gpu_power_w: gpu.map(|g| g.1),
        speedup_vs_gpu: match (best, gpu) {
            (Some(b), Some(g)) => Some(b.0 / g.0),
            _ => None,
        },
        retained_fraction: fault_f64("retained_fraction"),
        perf_per_watt_per_wafer: fault_f64("perf_per_watt_per_wafer"),
        scaling_efficiency: scaling_f64("scaling_efficiency"),
        serving_tokens_per_sec: serving_f64("tokens_per_sec"),
        serving_ttft_p99: serving_f64("ttft_p99_s"),
        serving_goodput: serving_f64("goodput_per_sec"),
    }
}

/// Per-scenario artifact: spec + seed + trace + Pareto front +
/// hypervolume (or the error row). Excludes wall-clock so artifacts are
/// byte-identical across same-seed runs. Resumed rows re-emit their
/// pre-existing artifact verbatim (parse → serialize is byte-stable).
pub fn scenario_result_json(r: &ScenarioResult) -> Json {
    if let Outcome::Resumed(artifact) = &r.outcome {
        return artifact.clone();
    }
    let mut doc = Json::obj();
    doc.set("key", Json::Str(r.scenario.key()))
        .set("scenario", r.scenario.to_json())
        // Seeds are full-width u64; JSON numbers are f64, so keep exact.
        .set("seed", Json::Str(r.seed.to_string()))
        // Fast spec-equality probe for --resume / --merge; the recorded
        // full scenario above remains the collision guard.
        .set(
            "spec_hash",
            Json::Str(format!("{:016x}", r.scenario.spec_hash())),
        );
    match &r.outcome {
        // lint: allow(panic) the Resumed arm early-returns the recorded doc before this match
        Outcome::Resumed(_) => unreachable!("returned above"),
        Outcome::Done(Ok(trace)) => {
            let mut pareto = Vec::new();
            for p in sorted_front(trace) {
                let mut o = Json::obj();
                o.set("throughput", Json::Num(p.objective.throughput))
                    .set("power_w", Json::Num(p.objective.power_w))
                    .set("fidelity", Json::Str(p.fidelity.to_string()))
                    .set("config", Json::Str(p.point.wsc.summary()));
                pareto.push(o);
            }
            doc.set("status", Json::Str("ok".to_string()))
                .set("trace", super::trace_to_json(trace))
                .set("pareto", Json::Arr(pareto))
                .set("final_hv", Json::Num(trace.final_hv()))
                .set("points", Json::Num(trace.points.len() as f64));
            // Fault rows carry their degradation digest so resumed rows
            // (which never re-run the engine) summarize identically.
            if let Some(f) = fault_row_metrics(&r.scenario, r.seed, trace) {
                doc.set("fault", f);
            }
            // Fixed-wafer rows carry their scale-out digest for the same
            // reason: resumed rows never re-run the engine.
            if let Some(sc) = scaling_row_metrics(&r.scenario, r.seed, trace) {
                doc.set("scaling", sc);
            }
            // Serving rows carry their traffic digest for the same reason:
            // resumed rows never re-run the simulator.
            if let Some(sv) = serving_row_metrics(&r.scenario, r.seed, trace) {
                doc.set("serving", sv);
            }
        }
        Outcome::Done(Err(e)) | Outcome::ResumeConflict(e) => {
            doc.set("status", Json::Str("error".to_string()))
                .set("error", Json::Str(e.clone()));
        }
    }
    doc
}

/// Cross-scenario summary (the `campaign.json` artifact): one row per
/// scenario with final hypervolume, the best point, and the
/// throughput/power comparison against the [`crate::baselines::gpu`]
/// reference (Fig. 11–13 in spirit).
pub fn summary_json(result: &CampaignResult) -> Json {
    let opt_num = |x: Option<f64>| x.map_or(Json::Null, Json::Num);
    let mut rows = Vec::new();
    for r in &result.rows {
        let s = summarize_row(r);
        let status = s.status();
        let mut o = Json::obj();
        o.set("key", Json::Str(s.key))
            .set("model", Json::Str(r.scenario.model.clone()))
            .set("phase", Json::Str(r.scenario.phase.name().to_string()))
            .set("explorer", Json::Str(r.scenario.explorer.name().to_string()))
            .set("fidelity", Json::Str(r.scenario.fidelity.name().to_string()))
            .set("seed", Json::Str(r.seed.to_string()))
            .set("status", Json::Str(status.to_string()));
        match s.error {
            None => {
                o.set("points", Json::Num(s.points as f64))
                    .set("final_hv", Json::Num(s.final_hv))
                    .set("best_throughput", opt_num(s.best_throughput))
                    .set("best_power_w", opt_num(s.best_power_w))
                    .set("gpu_throughput", opt_num(s.gpu_throughput))
                    .set("gpu_power_w", opt_num(s.gpu_power_w))
                    .set("speedup_vs_gpu", opt_num(s.speedup_vs_gpu));
                // Emitted only for fault rows: non-fault campaigns keep
                // their exact pre-fault summary bytes.
                if let Some(rf) = s.retained_fraction {
                    o.set("retained_fraction", Json::Num(rf));
                }
                if let Some(p) = s.perf_per_watt_per_wafer {
                    o.set("perf_per_watt_per_wafer", Json::Num(p));
                }
                // Likewise fixed-wafer rows only: area-matched campaigns
                // keep their exact pre-sweep summary bytes.
                if let Some(se) = s.scaling_efficiency {
                    o.set("scaling_efficiency", Json::Num(se));
                }
                // Likewise serving rows only: static campaigns keep their
                // exact pre-serving summary bytes.
                if let Some(g) = s.serving_goodput {
                    o.set("serving_goodput", Json::Num(g));
                }
                if let Some(tps) = s.serving_tokens_per_sec {
                    o.set("serving_tokens_per_sec", Json::Num(tps));
                }
                if let Some(t) = s.serving_ttft_p99 {
                    o.set("serving_ttft_p99", Json::Num(t));
                }
            }
            Some(e) => {
                o.set("error", Json::Str(e));
            }
        }
        rows.push(o);
    }
    let mut doc = Json::obj();
    doc.set("campaign_seed", Json::Str(result.campaign_seed.to_string()))
        .set("n_scenarios", Json::Num(result.rows.len() as f64))
        .set("n_errors", Json::Num(result.n_errors() as f64))
        .set("scenarios", Json::Arr(rows));
    // Only shard runs declare themselves; unsharded and merged campaigns
    // keep their exact pre-shard summary bytes (this is what makes the
    // merged campaign.json byte-comparable to the unsharded one).
    if let Some((k, n)) = result.shard {
        doc.set("shard", Json::Str(format!("{k}/{n}")));
    }
    doc
}

/// Write the results store under `out`: `campaign.json` (cross-scenario
/// summary) + `scenarios/<key>.json` (per-scenario trace / Pareto front /
/// hypervolume or error row). All files are deterministic in the campaign
/// seed; resumed rows rewrite their pre-existing artifact byte-identically,
/// and resume-conflict rows write **nothing** — the untrusted pre-existing
/// artifact stays on disk for the user to inspect and delete.
pub fn write_artifacts(result: &CampaignResult, out: &std::path::Path) -> std::io::Result<()> {
    let scen_dir = out.join("scenarios");
    std::fs::create_dir_all(&scen_dir)?;
    for r in &result.rows {
        if matches!(r.outcome, Outcome::ResumeConflict(_)) {
            continue;
        }
        std::fs::write(
            scen_dir.join(format!("{}.json", r.scenario.key())),
            scenario_result_json(r).to_pretty() + "\n",
        )?;
    }
    std::fs::write(
        out.join("campaign.json"),
        summary_json(result).to_pretty() + "\n",
    )?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fresh_cfg(scenarios: Vec<Scenario>, seed: u64, jobs: usize) -> CampaignConfig {
        CampaignConfig {
            scenarios,
            seed,
            jobs,
            resume_from: None,
            shard: None,
        }
    }

    #[test]
    fn paper_suite_shape() {
        let suite = paper_suite();
        // 16 models × {training, decode} × {random, mobo, mfmobo}.
        assert_eq!(suite.len(), 96);
        let mut keys: Vec<String> = suite.iter().map(Scenario::key).collect();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), 96, "scenario keys must be unique");
        assert!(suite.iter().all(|s| s.fidelity == Fidelity::Analytical));
        assert!(suite
            .iter()
            .filter(|s| s.phase == Phase::Training)
            .all(|s| s.batch == 0));
        assert!(suite
            .iter()
            .filter(|s| s.phase.is_inference())
            .all(|s| s.batch == 32));
    }

    #[test]
    fn scenario_json_roundtrip() {
        for s in [
            paper_suite()[0].clone(),
            Scenario {
                model: "GPT-175B".to_string(),
                phase: Phase::Prefill,
                batch: 8,
                mqa: true,
                wafers: Some(4),
                explorer: Explorer::Mobo,
                fidelity: Fidelity::GnnTest,
                budget: Budget {
                    iters: 3,
                    init: 2,
                    pool: 8,
                    mc: 16,
                    n1: 2,
                    k: 1,
                },
                fault_defect: None,
                fault_spares: None,
                hetero: None,
                interwafer: Some(InterWaferNet {
                    topology: InterWaferTopology::Ring,
                    links_per_wafer: 8,
                    link_bandwidth: 50.0e9,
                    link_latency: 2.0e-6,
                }),
                serving: None,
                tag: "Budget Sweep A".to_string(),
            },
            fault_suite()[3].clone(),
            hetero_suite()[2].clone(),
        ] {
            let j = s.to_json();
            let back = Scenario::from_json(&j).unwrap();
            assert_eq!(back, s);
            // And through the text form.
            let reparsed = Json::parse(&j.to_string()).unwrap();
            assert_eq!(Scenario::from_json(&reparsed).unwrap(), s);
        }
    }

    #[test]
    fn from_json_defaults_and_errors_list_options() {
        let minimal = Json::parse(
            r#"{"model": "1.7", "phase": "decode", "explorer": "random"}"#,
        )
        .unwrap();
        let s = Scenario::from_json(&minimal).unwrap();
        assert_eq!(s.batch, 32);
        assert_eq!(s.wafers, None);
        assert_eq!(s.fidelity, Fidelity::Analytical);
        assert_eq!(s.budget, Budget::default());
        assert_eq!(s.tag, "");

        let bad_phase =
            Json::parse(r#"{"model": "1.7", "phase": "serving", "explorer": "random"}"#).unwrap();
        let e = Scenario::from_json(&bad_phase).unwrap_err();
        assert!(e.contains("training, prefill, decode"), "{e}");

        let bad_explorer =
            Json::parse(r#"{"model": "1.7", "phase": "decode", "explorer": "grid"}"#).unwrap();
        let e = Scenario::from_json(&bad_explorer).unwrap_err();
        assert!(e.contains("random, mobo, mfmobo"), "{e}");

        // The fidelity error lists the registry names — the same list
        // `theseus dse --fidelity` prints.
        let bad_fidelity = Json::parse(
            r#"{"model": "1.7", "phase": "training", "explorer": "mobo", "fidelity": "oracle"}"#,
        )
        .unwrap();
        let e = Scenario::from_json(&bad_fidelity).unwrap_err();
        assert!(e.contains("analytical, ca, gnn, gnn-test"), "{e}");

        // The legacy "cycle-accurate" alias still parses to the CA entry.
        let legacy = Json::parse(
            r#"{"model": "1.7", "phase": "training", "explorer": "mobo",
                "fidelity": "cycle-accurate"}"#,
        )
        .unwrap();
        assert_eq!(
            Scenario::from_json(&legacy).unwrap().fidelity,
            Fidelity::CycleAccurate
        );

        let zero_batch = Json::parse(
            r#"{"model": "1.7", "phase": "decode", "explorer": "random", "batch": 0}"#,
        )
        .unwrap();
        assert!(Scenario::from_json(&zero_batch)
            .unwrap_err()
            .contains("batch >= 1"));
    }

    #[test]
    fn scenarios_from_json_accepts_both_shapes() {
        let arr = Json::parse(r#"[{"model": "1.7", "phase": "training", "explorer": "random"}]"#)
            .unwrap();
        assert_eq!(scenarios_from_json(&arr).unwrap().len(), 1);
        let wrapped = suite_to_json(&paper_suite());
        assert_eq!(scenarios_from_json(&wrapped).unwrap(), paper_suite());
        let bad = Json::parse(r#"{"model": "x"}"#).unwrap();
        assert!(scenarios_from_json(&bad).is_err());
    }

    #[test]
    fn seed_derivation_is_stable_and_key_sensitive() {
        let a = scenario_seed(2024, "gpt-1.7b-training-random-analytical-b0-wauto");
        assert_eq!(
            a,
            scenario_seed(2024, "gpt-1.7b-training-random-analytical-b0-wauto")
        );
        assert_ne!(
            a,
            scenario_seed(2024, "gpt-1.7b-training-mobo-analytical-b0-wauto")
        );
        assert_ne!(
            a,
            scenario_seed(2025, "gpt-1.7b-training-random-analytical-b0-wauto")
        );
        // Every paper-suite scenario gets a distinct stream.
        let mut seeds: Vec<u64> = paper_suite()
            .iter()
            .map(|s| scenario_seed(7, &s.key()))
            .collect();
        seeds.sort();
        seeds.dedup();
        assert_eq!(seeds.len(), 96);
    }

    #[test]
    fn tag_disambiguates_keys_and_duplicates_are_rejected() {
        let mut a = paper_suite()[0].clone();
        let mut b = a.clone();
        b.budget.iters = 10; // budget-only difference: invisible in the key
        assert_eq!(a.key(), b.key());
        let cfg = fresh_cfg(vec![a.clone(), b.clone()], 1, 1);
        let e = run_campaign(&cfg).unwrap_err();
        assert!(e.contains("duplicate scenario key"), "{e}");
        assert!(e.contains(&a.key()), "{e}");
        // A tag restores uniqueness (and is slugged into the key).
        a.tag = "iters 40".to_string();
        b.tag = "iters10".to_string();
        assert_ne!(a.key(), b.key());
        assert!(a.key().ends_with("-iters-40"), "{}", a.key());
    }

    #[test]
    fn from_json_rejects_unknown_fields() {
        let typo = Json::parse(
            r#"{"model": "1.7", "phase": "training", "explorer": "mobo", "iter": 1}"#,
        )
        .unwrap();
        let e = Scenario::from_json(&typo).unwrap_err();
        assert!(e.contains("unknown scenario field 'iter'"), "{e}");
        assert!(e.contains("iters"), "must list the valid fields: {e}");
        assert!(Scenario::from_json(&Json::Num(3.0))
            .unwrap_err()
            .contains("JSON object"));
    }

    #[test]
    fn unknown_model_scenario_is_an_error_not_a_fallback() {
        let s = Scenario {
            model: "no-such-model".to_string(),
            phase: Phase::Training,
            batch: 0,
            mqa: false,
            wafers: None,
            explorer: Explorer::Random,
            fidelity: Fidelity::Analytical,
            budget: Budget::default(),
            fault_defect: None,
            fault_spares: None,
            hetero: None,
            interwafer: None,
            serving: None,
            tag: String::new(),
        };
        let e = run_scenario(&s, 1).unwrap_err();
        assert!(e.contains("unknown model 'no-such-model'"), "{e}");
        assert!(e.contains("GPT-175B"), "error must list valid models: {e}");
    }

    #[test]
    fn decode_scenarios_run_at_any_registry_fidelity() {
        // The engine API removed the inference = analytical-only
        // restriction: a gnn-test decode scenario runs end to end and its
        // trace points carry the gnn-test fidelity label (ISSUE 5
        // acceptance).
        let s = Scenario {
            model: "GPT-1.7B".to_string(),
            phase: Phase::Decode,
            batch: 4,
            mqa: false,
            wafers: None,
            explorer: Explorer::Random,
            fidelity: Fidelity::GnnTest,
            budget: Budget {
                iters: 1,
                init: 1,
                pool: 8,
                mc: 8,
                n1: 0,
                k: 0,
            },
            fault_defect: None,
            fault_spares: None,
            hetero: None,
            interwafer: None,
            serving: None,
            tag: String::new(),
        };
        let trace = run_scenario(&s, 11).expect("gnn-test decode scenario runs");
        assert!(!trace.points.is_empty());
        assert!(trace.points.iter().all(|p| p.fidelity == "gnn-test"));
    }

    #[test]
    fn fault_and_hetero_suites_shape() {
        let faults = fault_suite();
        assert_eq!(faults.len(), 10); // 5 defect multipliers × {0, auto} spares
        assert!(faults.iter().all(|s| s.fault_defect.is_some()));
        let het = hetero_suite();
        assert_eq!(het.len(), HeteroGranularity::ALL.len());
        assert!(het.iter().all(|s| s.hetero.is_some()));
        // Keys stay unique without tags — the fd/fs/h suffixes carry the
        // distinction (and so distinct derived seeds + artifact files).
        let mut keys: Vec<String> = faults
            .iter()
            .chain(het.iter())
            .map(Scenario::key)
            .collect();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), faults.len() + het.len());
        // The suffix grammar is part of the artifact-file contract.
        assert!(faults[0].key().ends_with("-fd0-fs0"), "{}", faults[0].key());
        assert!(faults[1].key().ends_with("-fd0-fsauto"), "{}", faults[1].key());
        assert!(het[0].key().ends_with("-hnone"), "{}", het[0].key());
    }

    #[test]
    fn wafer_sweep_suite_shape_and_scaling_digest() {
        let suite = wafer_sweep_suite();
        assert_eq!(suite.len(), 8); // wafers {1, 2, 4, 8} × {training, decode}
        assert!(suite.iter().all(|s| s.wafers.is_some()));
        let mut keys: Vec<String> = suite.iter().map(Scenario::key).collect();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), suite.len(), "wafer-sweep keys must be unique");

        // A small multi-wafer row end to end: the artifact carries the
        // scale-out digest with efficiency == speedup / wafers.
        let mut s = suite[2].clone();
        assert_eq!(s.wafers, Some(2));
        s.budget = Budget {
            iters: 1,
            init: 2,
            pool: 8,
            mc: 8,
            n1: 0,
            k: 0,
        };
        let seed = scenario_seed(2024, &s.key());
        let trace = run_scenario(&s, seed).expect("wafer-sweep scenario runs");
        assert!(!trace.points.is_empty());
        let digest = scaling_row_metrics(&s, seed, &trace).expect("fixed-wafer rows digest");
        let eff = digest
            .get("scaling_efficiency")
            .and_then(Json::as_f64)
            .unwrap();
        let speedup = digest
            .get("speedup_vs_single_wafer")
            .and_then(Json::as_f64)
            .unwrap();
        assert!(eff > 0.0, "scaling efficiency {eff} out of range");
        assert_eq!(eff.to_bits(), (speedup / 2.0).to_bits());
        assert!(
            digest
                .get("single_wafer_throughput")
                .and_then(Json::as_f64)
                .unwrap()
                > 0.0
        );
        // Same seed → byte-identical digest (the determinism contract
        // extends through the single-wafer re-evaluation).
        let trace2 = run_scenario(&s, seed).expect("rerun");
        assert_eq!(
            scaling_row_metrics(&s, seed, &trace2).unwrap().to_string(),
            digest.to_string()
        );
        // Area-matched rows never grow a digest.
        assert!(scaling_row_metrics(&paper_suite()[0], seed, &trace).is_none());
    }

    #[test]
    fn from_json_rejects_zero_wafers_and_orphan_interwafer_fields() {
        // wafers: 0 used to clamp silently to 1 in system sizing — now a
        // loud spec error (null/omitted means area-matched).
        let zero = Json::parse(
            r#"{"model": "1.7", "phase": "training", "explorer": "random", "wafers": 0}"#,
        )
        .unwrap();
        let e = Scenario::from_json(&zero).unwrap_err();
        assert!(e.contains("'wafers' must be >= 1"), "{e}");
        // null still means area-matched, not an error.
        let auto = Json::parse(
            r#"{"model": "1.7", "phase": "training", "explorer": "random", "wafers": null}"#,
        )
        .unwrap();
        assert_eq!(Scenario::from_json(&auto).unwrap().wafers, None);

        let orphan = Json::parse(
            r#"{"model": "1.7", "phase": "training", "explorer": "random",
                "interwafer_links": 8}"#,
        )
        .unwrap();
        let e = Scenario::from_json(&orphan).unwrap_err();
        assert!(e.contains("'interwafer_links' needs 'interwafer'"), "{e}");

        let bad_topo = Json::parse(
            r#"{"model": "1.7", "phase": "training", "explorer": "random",
                "interwafer": "torus"}"#,
        )
        .unwrap();
        let e = Scenario::from_json(&bad_topo).unwrap_err();
        assert!(e.contains("ring, mesh2d, switched"), "{e}");
    }

    #[test]
    fn interwafer_axis_keys_and_defaults() {
        let net = InterWaferNet {
            topology: InterWaferTopology::Ring,
            links_per_wafer: 8,
            link_bandwidth: 50.0e9,
            link_latency: 2.0e-6,
        };
        let mut s = wafer_sweep_suite()[2].clone();
        let base = s.key();
        assert!(!base.contains("-iw"));
        s.interwafer = Some(net);
        assert_eq!(s.key(), format!("{base}-iwring"));
        assert_ne!(
            scenario_seed(2024, &s.key()),
            scenario_seed(2024, &base),
            "interwafer rows get their own seed stream"
        );
        // JSON roundtrip preserves every net field.
        assert_eq!(Scenario::from_json(&s.to_json()).unwrap(), s);
        // Unspecified net axes default to the flat-NIC model.
        let partial = Scenario::from_json(
            &Json::parse(
                r#"{"model": "1.7", "phase": "training", "explorer": "random",
                    "wafers": 4, "interwafer": "mesh2d"}"#,
            )
            .unwrap(),
        )
        .unwrap();
        let d = InterWaferNet::default_for(crate::design_space::default_nic_count());
        let n = partial.interwafer.unwrap();
        assert_eq!(n.topology, InterWaferTopology::Mesh2d);
        assert_eq!(n.links_per_wafer, d.links_per_wafer);
        assert_eq!(n.link_bandwidth, d.link_bandwidth);
        assert_eq!(n.link_latency, d.link_latency);
    }

    #[test]
    fn from_json_rejects_orphan_fault_and_hetero_fields() {
        let orphan_spares = Json::parse(
            r#"{"model": "1.7", "phase": "training", "explorer": "random", "fault_spares": 2}"#,
        )
        .unwrap();
        let e = Scenario::from_json(&orphan_spares).unwrap_err();
        assert!(e.contains("'fault_spares' needs 'fault_defect'"), "{e}");

        let orphan_ratio = Json::parse(
            r#"{"model": "1.7", "phase": "decode", "explorer": "random", "hetero_ratio": 0.5}"#,
        )
        .unwrap();
        let e = Scenario::from_json(&orphan_ratio).unwrap_err();
        assert!(e.contains("'hetero_ratio' needs 'hetero'"), "{e}");

        let bad_gran = Json::parse(
            r#"{"model": "1.7", "phase": "decode", "explorer": "random", "hetero": "chiplet"}"#,
        )
        .unwrap();
        let e = Scenario::from_json(&bad_gran).unwrap_err();
        assert!(e.contains("none, core, reticle, wafer"), "{e}");

        let negative = Json::parse(
            r#"{"model": "1.7", "phase": "training", "explorer": "random", "fault_defect": -1}"#,
        )
        .unwrap();
        assert!(Scenario::from_json(&negative)
            .unwrap_err()
            .contains("non-negative"));
    }

    #[test]
    fn fault_scenario_runs_and_digests_degradation() {
        // A small fault row end to end: the trace evaluates under
        // injected faults, and the artifact carries the degradation
        // digest with a sane retained fraction.
        let mut s = fault_suite()[0].clone();
        s.fault_defect = Some(2.0);
        s.fault_spares = Some(0);
        s.budget = Budget {
            iters: 1,
            init: 2,
            pool: 8,
            mc: 8,
            n1: 0,
            k: 0,
        };
        let seed = scenario_seed(2024, &s.key());
        let trace = run_scenario(&s, seed).expect("fault scenario runs");
        assert!(!trace.points.is_empty());
        let digest = fault_row_metrics(&s, seed, &trace).expect("fault rows digest");
        let retained = digest
            .get("retained_fraction")
            .and_then(Json::as_f64)
            .unwrap();
        assert!(
            retained > 0.0 && retained <= 1.0 + 1e-9,
            "retained fraction {retained} out of range"
        );
        assert!(
            digest
                .get("perf_per_watt_per_wafer")
                .and_then(Json::as_f64)
                .unwrap()
                > 0.0
        );
        assert!(digest.get("wafer_cost").and_then(Json::as_f64).unwrap() >= 1.0);
        // Same seed → byte-identical digest (the determinism contract
        // extends through the fault sampler and the re-evaluation).
        let trace2 = run_scenario(&s, seed).expect("rerun");
        assert_eq!(
            fault_row_metrics(&s, seed, &trace2).unwrap().to_string(),
            digest.to_string()
        );
        // Non-fault rows never grow a digest.
        assert!(fault_row_metrics(&paper_suite()[0], seed, &trace).is_none());
    }

    #[test]
    fn hetero_scenario_runs_through_campaign_path() {
        // The tested successor of `examples/inference_hetero.rs`: a
        // reticle-granularity decode row drives arch::hetero through the
        // same dispatch as every other scenario.
        let mut s = hetero_suite()[2].clone();
        assert_eq!(
            s.hetero.unwrap().granularity,
            HeteroGranularity::Reticle
        );
        s.budget = Budget {
            iters: 1,
            init: 2,
            pool: 8,
            mc: 8,
            n1: 0,
            k: 0,
        };
        let seed = scenario_seed(7, &s.key());
        let trace = run_scenario(&s, seed).expect("hetero decode scenario runs");
        assert!(!trace.points.is_empty());
        assert!(trace
            .points
            .iter()
            .all(|p| p.objective.throughput > 0.0 && p.objective.power_w > 0.0));
    }

    #[test]
    fn mqa_axis_keys_parses_and_rejects_training() {
        // The suffix sits between the base and the fault/hetero/tag
        // suffixes; pre-mqa scenario keys keep their exact values.
        let mut s = paper_suite()
            .into_iter()
            .find(|s| s.phase == Phase::Decode)
            .unwrap();
        let base = s.key();
        assert!(!base.contains("-mqa"));
        s.mqa = true;
        assert_eq!(s.key(), format!("{base}-mqa"));
        assert_ne!(
            scenario_seed(2024, &s.key()),
            scenario_seed(2024, &base),
            "mqa rows get their own seed stream"
        );

        // JSON: defaults to false, parses as a boolean, survives roundtrip.
        let parsed = Scenario::from_json(
            &Json::parse(r#"{"model": "1.7", "phase": "decode", "explorer": "random", "mqa": true}"#)
                .unwrap(),
        )
        .unwrap();
        assert!(parsed.mqa);
        assert_eq!(Scenario::from_json(&parsed.to_json()).unwrap(), parsed);
        let e = Scenario::from_json(
            &Json::parse(r#"{"model": "1.7", "phase": "decode", "explorer": "random", "mqa": 1}"#)
                .unwrap(),
        )
        .unwrap_err();
        assert!(e.contains("must be a boolean"), "{e}");

        // Training rejects the serving-time axis loudly.
        let e = Scenario::from_json(
            &Json::parse(r#"{"model": "1.7", "phase": "training", "explorer": "random", "mqa": true}"#)
                .unwrap(),
        )
        .unwrap_err();
        assert!(e.contains("inference phase"), "{e}");
    }

    #[test]
    fn serving_axis_keys_and_json_roundtrip() {
        // The suffix sits after the interwafer suffix; pre-serving
        // scenario keys keep their exact values.
        let mut s = paper_suite()
            .into_iter()
            .find(|s| s.phase == Phase::Decode)
            .unwrap();
        let base = s.key();
        assert!(!base.contains("-sv"));
        s.serving = Some(ServingSpec {
            arrival: ArrivalProcess::Poisson,
            rate_per_s: 4.0,
            requests: 48,
            mean_prompt: 512,
            mean_output: 64,
            slo_s: 0.5,
            scheduler: SchedulerKind::Fcfs,
        });
        assert_eq!(s.key(), format!("{base}-svpoisson-r4"));
        // A non-default scheduler is part of the key (distinct artifacts).
        let mut pp = s.clone();
        pp.serving = Some(ServingSpec {
            scheduler: SchedulerKind::PrefillPriority,
            ..pp.serving.unwrap()
        });
        assert_eq!(pp.key(), format!("{base}-svpoisson-r4-prefill-priority"));
        assert_ne!(
            scenario_seed(2024, &s.key()),
            scenario_seed(2024, &base),
            "serving rows get their own seed stream"
        );

        // JSON roundtrip through the object and the text form.
        for sc in [s.clone(), pp] {
            let j = sc.to_json();
            assert_eq!(Scenario::from_json(&j).unwrap(), sc);
            let reparsed = Json::parse(&j.to_string()).unwrap();
            assert_eq!(Scenario::from_json(&reparsed).unwrap(), sc);
        }
    }

    #[test]
    fn serving_fields_parse_defaults_and_reject_loudly() {
        // Defaults: only the arrival-process name is required.
        let parsed = Scenario::from_json(
            &Json::parse(
                r#"{"model": "1.7", "phase": "decode", "explorer": "random",
                    "serving": "poisson"}"#,
            )
            .unwrap(),
        )
        .unwrap();
        let sv = parsed.serving.unwrap();
        assert_eq!(sv.arrival, ArrivalProcess::Poisson);
        assert_eq!(sv.rate_per_s, 4.0);
        assert_eq!(sv.requests, 64);
        assert_eq!(sv.mean_prompt, 512);
        assert_eq!(sv.mean_output, 128);
        assert_eq!(sv.slo_s, 1.0);
        assert_eq!(sv.scheduler, SchedulerKind::Fcfs);

        // Orphan serving_* without the arrival process is a loud error.
        let orphan = Json::parse(
            r#"{"model": "1.7", "phase": "decode", "explorer": "random",
                "serving_rate": 8}"#,
        )
        .unwrap();
        let e = Scenario::from_json(&orphan).unwrap_err();
        assert!(e.contains("needs 'serving'"), "{e}");

        // Unknown arrival processes and schedulers list the registries.
        let bad_arrival = Json::parse(
            r#"{"model": "1.7", "phase": "decode", "explorer": "random",
                "serving": "diurnal"}"#,
        )
        .unwrap();
        let e = Scenario::from_json(&bad_arrival).unwrap_err();
        assert!(e.contains("poisson, bursty"), "{e}");
        let bad_sched = Json::parse(
            r#"{"model": "1.7", "phase": "decode", "explorer": "random",
                "serving": "poisson", "serving_scheduler": "lifo"}"#,
        )
        .unwrap();
        let e = Scenario::from_json(&bad_sched).unwrap_err();
        assert!(e.contains("fcfs, prefill-priority"), "{e}");

        // Non-positive rate/SLO are spec errors, not silent clamps.
        for (field, msg) in [
            ("serving_rate", "'serving_rate' must be positive"),
            ("serving_slo", "'serving_slo' must be positive"),
        ] {
            let bad = Json::parse(&format!(
                r#"{{"model": "1.7", "phase": "decode", "explorer": "random",
                    "serving": "poisson", "{field}": 0}}"#,
            ))
            .unwrap();
            let e = Scenario::from_json(&bad).unwrap_err();
            assert!(e.contains(msg), "{field}: {e}");
        }

        // Training rejects the serving axis loudly (a request stream is
        // served by prefill/decode steps).
        let training = Json::parse(
            r#"{"model": "1.7", "phase": "training", "explorer": "random",
                "serving": "poisson"}"#,
        )
        .unwrap();
        let e = Scenario::from_json(&training).unwrap_err();
        assert!(e.contains("inference phase"), "{e}");
    }

    #[test]
    fn serving_suite_shape() {
        let suite = serving_suite();
        assert_eq!(suite.len(), 8); // 2 arrivals × 2 rates × {1, 4} wafers
        assert!(suite.iter().all(|s| s.serving.is_some()));
        assert!(suite.iter().all(|s| s.phase == Phase::Decode));
        assert!(suite
            .iter()
            .all(|s| matches!(s.wafers, Some(1) | Some(4))));
        let mut keys: Vec<String> = suite.iter().map(Scenario::key).collect();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), suite.len(), "serving keys must be unique");
    }

    #[test]
    fn parse_shard_accepts_k_of_n_and_rejects_nonsense() {
        assert_eq!(parse_shard("1/1").unwrap(), (1, 1));
        assert_eq!(parse_shard(" 2/4 ").unwrap(), (2, 4));
        for bad in ["", "3", "0/2", "3/2", "2/0", "a/b", "1/2/3", "-1/2"] {
            let e = parse_shard(bad).unwrap_err();
            assert!(e.contains("expected K/N"), "{bad:?}: {e}");
        }
    }

    #[test]
    fn shards_partition_the_scenario_matrix() {
        // Union of 1/3 + 2/3 + 3/3 covers the suite exactly once, in a
        // deterministic index-stride split.
        let suite = paper_suite();
        let mut seen: Vec<String> = Vec::new();
        for k in 1..=3usize {
            let cfg = CampaignConfig {
                shard: Some((k, 3)),
                ..fresh_cfg(suite.clone(), 5, 1)
            };
            seen.extend(cfg.sharded_scenarios().unwrap().iter().map(Scenario::key));
        }
        seen.sort();
        let mut all: Vec<String> = suite.iter().map(Scenario::key).collect();
        all.sort();
        assert_eq!(seen, all);
    }

    #[test]
    fn spec_hash_tracks_budget_and_mqa() {
        let a = paper_suite()[0].clone();
        assert_eq!(a.spec_hash(), a.clone().spec_hash());
        let mut b = a.clone();
        b.budget.iters += 1; // invisible in the key, visible in the hash
        assert_eq!(a.key(), b.key());
        assert_ne!(a.spec_hash(), b.spec_hash());
        let mut c = paper_suite()
            .into_iter()
            .find(|s| s.phase == Phase::Decode)
            .unwrap();
        let before = c.spec_hash();
        c.mqa = true;
        assert_ne!(before, c.spec_hash());
    }
}
