//! Objective adapters: wrap the evaluation engine as [`DesignEval`]
//! functions at the explorer's fidelity levels (paper §VII: analytical =
//! low fidelity, GNN = high fidelity; CA simulation pluggable the same
//! way).

use std::sync::Arc;

use crate::baselines::H100_DIE_MM2;
use crate::design_space::Validated;
use crate::eval::{self, Analytical, SystemConfig};
use crate::explorer::{DesignEval, Objective};
use crate::workload::LlmSpec;

/// Hypervolume reference power (paper §VII: "the peak power threshold of
/// the WSC system"): 15 kW per wafer × the largest plausible area-matched
/// wafer count (smallest committed wafer area we accept ≈ 15 000 mm²).
pub fn ref_power_for(spec: &LlmSpec) -> f64 {
    let gpu_area = spec.gpu_num as f64 * H100_DIE_MM2;
    let wafers = (gpu_area / 15_000.0).ceil().max(1.0);
    crate::arch::constants::WAFER_POWER_LIMIT_W * wafers
}

/// System sizing shared by every objective: a fixed wafer count when the
/// scenario pins one (multi-wafer sweeps), else area-matched to the
/// model's GPU-cluster baseline (§VIII-A).
pub fn system_for(v: &Validated, gpu_num: usize, wafers: Option<usize>) -> SystemConfig {
    match wafers {
        Some(n) => SystemConfig {
            validated: v.clone(),
            n_wafers: n.max(1),
        },
        None => SystemConfig::area_matched(v.clone(), gpu_num),
    }
}

/// Training-throughput objective at a chosen NoC fidelity.
pub struct TrainingObjective {
    spec: LlmSpec,
    noc: NocBackend,
    /// Fixed wafer count; `None` = area-matched (the default).
    wafers: Option<usize>,
}

enum NocBackend {
    Analytical,
    Gnn(Arc<crate::runtime::GnnModel>),
    /// Deterministic in-process pseudo-GNN ([`crate::runtime::TestBackend`])
    /// — exercises the batched high-fidelity sweep in builds without PJRT.
    PseudoGnn(crate::runtime::TestBackend),
    CycleAccurate,
}

impl TrainingObjective {
    pub fn analytical(spec: LlmSpec) -> Self {
        TrainingObjective {
            spec,
            noc: NocBackend::Analytical,
            wafers: None,
        }
    }

    pub fn gnn(spec: LlmSpec, model: Arc<crate::runtime::GnnModel>) -> Self {
        TrainingObjective {
            spec,
            noc: NocBackend::Gnn(model),
            wafers: None,
        }
    }

    /// GNN-fidelity objective backed by the closed-form pseudo-GNN — the
    /// batched inference path end to end, no artifacts required.
    pub fn pseudo_gnn(spec: LlmSpec) -> Self {
        TrainingObjective {
            spec,
            noc: NocBackend::PseudoGnn(crate::runtime::TestBackend::new()),
            wafers: None,
        }
    }

    pub fn cycle_accurate(spec: LlmSpec) -> Self {
        TrainingObjective {
            spec,
            noc: NocBackend::CycleAccurate,
            wafers: None,
        }
    }

    /// Pin the system to a fixed wafer count (campaign multi-wafer
    /// scenarios); `None` restores area matching.
    pub fn with_wafers(mut self, wafers: Option<usize>) -> Self {
        self.wafers = wafers;
        self
    }
}

impl DesignEval for TrainingObjective {
    fn eval(&self, v: &Validated) -> Option<Objective> {
        let sys = system_for(v, self.spec.gpu_num, self.wafers);
        // The Sync fidelities fan the strategy sweep out over the thread
        // pool; the GNN's PJRT handle is thread-confined, so that fidelity
        // amortizes per-call dispatch by *batching* link-wait inference
        // across the sweep instead (runtime::batch::GnnBatcher).
        let batch = crate::runtime::batch::gnn_batch_size();
        let r = match &self.noc {
            NocBackend::Analytical => eval::eval_training_par(&self.spec, &sys, &Analytical)?,
            NocBackend::CycleAccurate => {
                eval::eval_training_par(&self.spec, &sys, &eval::CycleAccurate::default())?
            }
            NocBackend::Gnn(m) => {
                eval::eval_training_gnn_batched(&self.spec, &sys, m.as_ref(), batch)?
            }
            NocBackend::PseudoGnn(b) => {
                eval::eval_training_gnn_batched(&self.spec, &sys, b, batch)?
            }
        };
        Some(Objective {
            throughput: r.tokens_per_sec,
            power_w: r.power_w,
        })
    }

    fn name(&self) -> &'static str {
        match self.noc {
            NocBackend::Analytical => "analytical",
            NocBackend::Gnn(_) => "gnn",
            NocBackend::PseudoGnn(_) => "gnn-test",
            NocBackend::CycleAccurate => "cycle-accurate",
        }
    }
}

/// Always-`Sync` analytical training objective for the pooled explorers
/// ([`crate::explorer::random_search_par`]). [`TrainingObjective`] cannot
/// be `Sync` in PJRT builds (its GNN variant holds a thread-confined
/// executable), so pooled call sites use this concrete type instead.
pub struct AnalyticalTraining {
    pub spec: LlmSpec,
    /// Fixed wafer count; `None` = area-matched.
    pub wafers: Option<usize>,
}

impl DesignEval for AnalyticalTraining {
    fn eval(&self, v: &Validated) -> Option<Objective> {
        let sys = system_for(v, self.spec.gpu_num, self.wafers);
        let r = eval::eval_training(&self.spec, &sys, &Analytical)?;
        Some(Objective {
            throughput: r.tokens_per_sec,
            power_w: r.power_w,
        })
    }

    fn name(&self) -> &'static str {
        "analytical"
    }
}

/// Inference objective (throughput vs power at fixed batch; §IX-D/E).
pub struct InferenceObjective {
    pub spec: LlmSpec,
    pub batch: usize,
    pub mqa: bool,
}

impl DesignEval for InferenceObjective {
    fn eval(&self, v: &Validated) -> Option<Objective> {
        let sys = SystemConfig::area_matched(v.clone(), self.spec.gpu_num);
        let r = eval::eval_inference(&self.spec, &sys, self.batch, self.mqa, &Analytical)?;
        Some(Objective {
            throughput: r.tokens_per_sec,
            power_w: r.power_w,
        })
    }

    fn name(&self) -> &'static str {
        "inference-analytical"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design_space::{reference_point, validate};
    use crate::workload::models::benchmarks;

    #[test]
    fn training_objective_evaluates_reference() {
        let spec = benchmarks()[0].clone();
        let obj = TrainingObjective::analytical(spec);
        let v = validate(&reference_point()).unwrap();
        let o = obj.eval(&v).expect("reference point evaluable");
        assert!(o.throughput > 0.0);
        assert!(o.power_w > 0.0);
    }

    #[test]
    fn inference_objective_evaluates_reference() {
        let spec = benchmarks()[0].clone();
        let obj = InferenceObjective {
            spec,
            batch: 32,
            mqa: false,
        };
        let v = validate(&reference_point()).unwrap();
        let o = obj.eval(&v).expect("evaluable");
        assert!(o.throughput > 0.0);
    }

    #[test]
    fn wafer_override_pins_system_sizing() {
        let spec = benchmarks()[0].clone();
        let v = validate(&reference_point()).unwrap();
        assert_eq!(system_for(&v, spec.gpu_num, Some(3)).n_wafers, 3);
        assert_eq!(system_for(&v, spec.gpu_num, Some(0)).n_wafers, 1);
        let auto = system_for(&v, spec.gpu_num, None);
        assert_eq!(
            auto.n_wafers,
            SystemConfig::area_matched(v.clone(), spec.gpu_num).n_wafers
        );
        // And the objective rides the override end to end.
        let obj = TrainingObjective::analytical(spec).with_wafers(Some(1));
        let o = obj.eval(&v).expect("single-wafer point evaluable");
        assert!(o.throughput > 0.0 && o.power_w > 0.0);
    }

    #[test]
    fn ref_power_scales_with_model() {
        let small = ref_power_for(&benchmarks()[0]);
        let big = ref_power_for(&benchmarks()[9]);
        assert!(big > small * 10.0);
    }

    #[test]
    fn pseudo_gnn_objective_evaluates_reference() {
        // The batched GNN-fidelity sweep end to end on the default build
        // (TestBackend — no PJRT artifacts needed).
        let spec = benchmarks()[0].clone();
        let obj = TrainingObjective::pseudo_gnn(spec);
        let v = validate(&reference_point()).unwrap();
        let o = obj.eval(&v).expect("reference point evaluable");
        assert!(o.throughput > 0.0);
        assert!(o.power_w > 0.0);
        assert_eq!(obj.name(), "gnn-test");
    }

    #[test]
    fn mfmobo_high_fidelity_rides_the_batched_gnn_sweep() {
        // Miniature MFMOBO with the pseudo-GNN as f0: the high-fidelity
        // stage must produce trace points tagged with the batched GNN
        // fidelity (the Algo. 1 handoff runs through GnnBatcher).
        use crate::explorer::{mfmobo, BoConfig, MfConfig};
        let spec = benchmarks()[0].clone();
        let hi = TrainingObjective::pseudo_gnn(spec.clone());
        let lo = TrainingObjective::analytical(spec.clone());
        let mf = MfConfig {
            base: BoConfig {
                iters: 2,
                init: 1,
                pool: 8,
                mc_samples: 8,
                ref_power: ref_power_for(&spec),
                seed: 9,
                sample_tries: 2000,
            },
            n1: 1,
            d0: 1,
            d1: 1,
            k: 1,
        };
        let t = mfmobo(&hi, &lo, &mf);
        assert!(
            t.points.iter().any(|p| p.fidelity == "gnn-test"),
            "no high-fidelity (batched GNN) evaluations in the trace"
        );
        assert!(t.points.iter().any(|p| p.fidelity == "analytical"));
    }
}
