//! DSE coordinator (paper Fig. 2): wires the design space, evaluation
//! engine (at the explorer-requested fidelity) and Space Explorer into the
//! iterative loop; owns result persistence and reporting.
//!
//! This is Layer 3's event loop: evaluations fan out over the thread pool,
//! traces checkpoint to JSON, and the Pareto set prints as a table.
//!
//! # Scenario campaigns ([`campaign`])
//!
//! One `theseus dse` invocation runs a single `(model, phase, explorer)`
//! tuple; the [`campaign`] subsystem batches the paper's whole §IX matrix:
//!
//! ```text
//! # the built-in §IX suite (96 scenarios), 4 at a time:
//! theseus campaign --suite paper --out artifacts/campaign --seed 2024 --jobs 4
//! # or a custom matrix from a JSON file (see campaign::scenarios_from_json):
//! theseus campaign --scenarios my_sweep.json --out artifacts/sweep
//! ```
//!
//! Each scenario's RNG seed derives as `scenario_seed(campaign_seed,
//! scenario.key())` — FNV-1a over the scenario key folded into the
//! campaign seed and SplitMix64-finalized — so results are reproducible
//! per scenario (independent of sibling scenarios and worker
//! interleaving), and two same-seed campaign runs write byte-identical
//! artifacts (`campaign.json` + `scenarios/<key>.json`).

pub mod campaign;
pub mod objective;

use std::sync::Arc;

use crate::explorer::{self, BoConfig, MfConfig, Trace};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::table::Table;
use crate::workload::models;

pub use objective::{ref_power_for, AnalyticalTraining, InferenceObjective, TrainingObjective};

/// Which explorer to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Explorer {
    Random,
    Mobo,
    Mfmobo,
}

impl Explorer {
    pub fn parse(s: &str) -> Option<Explorer> {
        match s {
            "random" => Some(Explorer::Random),
            "mobo" => Some(Explorer::Mobo),
            "mfmobo" => Some(Explorer::Mfmobo),
            _ => None,
        }
    }

    /// [`Explorer::parse`] with a human-oriented error naming the valid
    /// explorers — CLI call sites print this and exit 1 instead of
    /// silently falling back.
    pub fn parse_or_usage(s: &str) -> Result<Explorer, String> {
        Explorer::parse(s)
            .ok_or_else(|| format!("unknown explorer '{s}' — valid: random, mobo, mfmobo"))
    }

    pub fn name(&self) -> &'static str {
        match self {
            Explorer::Random => "random",
            Explorer::Mobo => "mobo",
            Explorer::Mfmobo => "mfmobo",
        }
    }
}

/// A full DSE run description.
pub struct DseRun {
    pub spec: crate::workload::LlmSpec,
    pub explorer: Explorer,
    pub cfg: BoConfig,
    /// Low-fidelity trials for MFMOBO (paper: 100).
    pub n1: usize,
    pub k: usize,
    /// Use the GNN runtime as the high fidelity when available.
    pub use_gnn: bool,
}

/// Execute a DSE run; returns the trace.
pub fn run(run: &DseRun) -> Trace {
    let gnn: Option<Arc<crate::runtime::GnnModel>> = if run.use_gnn {
        match crate::runtime::GnnModel::load_default() {
            Ok(m) => Some(Arc::new(m)),
            Err(e) => {
                eprintln!("note: GNN unavailable ({e}); high fidelity = analytical");
                None
            }
        }
    } else {
        None
    };

    let low = TrainingObjective::analytical(run.spec.clone());
    let high: Box<dyn explorer::DesignEval> = match &gnn {
        Some(m) => Box::new(TrainingObjective::gnn(run.spec.clone(), m.clone())),
        None => Box::new(TrainingObjective::analytical(run.spec.clone())),
    };

    match run.explorer {
        // Without the GNN, random search fans design-point evaluations out
        // over the thread pool (the GNN's PJRT handle is thread-confined,
        // so that fidelity keeps the serial path).
        Explorer::Random if gnn.is_none() => explorer::random_search_par(
            &AnalyticalTraining {
                spec: run.spec.clone(),
                wafers: None,
            },
            &run.cfg,
        ),
        Explorer::Random => explorer::random_search(high.as_ref(), &run.cfg),
        Explorer::Mobo => explorer::mobo(high.as_ref(), &run.cfg),
        Explorer::Mfmobo => explorer::mfmobo(
            high.as_ref(),
            &low,
            &MfConfig {
                base: run.cfg.clone(),
                n1: run.n1,
                d0: run.cfg.init,
                d1: run.cfg.init,
                k: run.k,
            },
        ),
    }
}

/// Serialize a trace (checkpoint / bench consumption).
pub fn trace_to_json(trace: &Trace) -> Json {
    let mut points = Vec::new();
    for p in &trace.points {
        let mut o = Json::obj();
        o.set("summary", Json::Str(p.point.wsc.summary()))
            .set("throughput", Json::Num(p.objective.throughput))
            .set("power_w", Json::Num(p.objective.power_w))
            .set("fidelity", Json::Str(p.fidelity.to_string()))
            .set(
                "stacking",
                Json::Bool(p.point.wsc.reticle.memory.is_stacking()),
            );
        points.push(o);
    }
    let mut doc = Json::obj();
    doc.set("points", Json::Arr(points))
        .set("hv_history", Json::from_f64_slice(&trace.hv_history));
    doc
}

/// CLI entry (the `theseus dse` subcommand). Unknown `--model` /
/// `--explorer` keys exit 1 listing the valid options (never a silent
/// fallback to a default).
pub fn run_from_cli(args: &Args) {
    let model = args.str("model", "175b");
    let spec = models::find_or_usage(&model).unwrap_or_else(|e| {
        eprintln!("dse: {e}");
        std::process::exit(1);
    });
    let explorer = Explorer::parse_or_usage(&args.str("explorer", "mfmobo")).unwrap_or_else(|e| {
        eprintln!("dse: {e}");
        std::process::exit(1);
    });
    let cfg = BoConfig {
        iters: args.usize("iters", 40),
        init: args.usize("init", 6),
        pool: args.usize("pool", 96),
        mc_samples: args.usize("mc", 64),
        ref_power: args.f64("ref-power", ref_power_for(&spec)),
        seed: args.u64("seed", 0),
        sample_tries: 4000,
    };
    let dse = DseRun {
        spec: spec.clone(),
        explorer,
        cfg,
        n1: args.usize("n1", 40),
        k: args.usize("k", 8),
        use_gnn: !args.bool("no-gnn", false),
    };
    eprintln!(
        "DSE: {} on {} ({} iters, seed {})",
        explorer.name(),
        spec.name,
        dse.cfg.iters,
        dse.cfg.seed
    );
    let t0 = std::time::Instant::now();
    let trace = run(&dse);
    eprintln!(
        "explored {} points in {:.1}s; final hypervolume {:.4e}",
        trace.points.len(),
        t0.elapsed().as_secs_f64(),
        trace.final_hv()
    );

    let mut table = Table::new(
        &format!("Pareto set — {} training", spec.name),
        &["tokens/s", "power(kW)", "fidelity", "config"],
    );
    let mut front = trace.pareto();
    front.sort_by(|a, b| b.objective.throughput.partial_cmp(&a.objective.throughput).unwrap());
    for p in front {
        table.row(&[
            format!("{:.1}", p.objective.throughput),
            format!("{:.1}", p.objective.power_w / 1e3),
            p.fidelity.to_string(),
            p.point.wsc.summary(),
        ]);
    }
    table.print();

    if let Some(out) = args.opt_str("out") {
        std::fs::write(&out, trace_to_json(&trace).to_pretty()).expect("write trace");
        eprintln!("trace written to {out}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::benchmarks;

    #[test]
    fn explorer_parse() {
        assert_eq!(Explorer::parse("mfmobo"), Some(Explorer::Mfmobo));
        assert_eq!(Explorer::parse("nope"), None);
    }

    #[test]
    fn explorer_parse_or_usage_lists_options() {
        assert_eq!(Explorer::parse_or_usage("mobo"), Ok(Explorer::Mobo));
        let e = Explorer::parse_or_usage("grid").unwrap_err();
        assert!(e.contains("unknown explorer 'grid'"), "{e}");
        assert!(e.contains("random, mobo, mfmobo"), "{e}");
    }

    #[test]
    fn tiny_random_dse_end_to_end() {
        let spec = benchmarks()[0].clone();
        let run_cfg = DseRun {
            spec: spec.clone(),
            explorer: Explorer::Random,
            cfg: BoConfig {
                iters: 2,
                init: 2,
                pool: 8,
                mc_samples: 8,
                ref_power: ref_power_for(&spec),
                seed: 3,
                sample_tries: 2000,
            },
            n1: 0,
            k: 0,
            use_gnn: false,
        };
        let trace = run(&run_cfg);
        assert!(!trace.points.is_empty());
        let json = trace_to_json(&trace);
        assert!(json.get("points").unwrap().as_arr().unwrap().len() >= 1);
        // Round-trips through the JSON substrate.
        let parsed = crate::util::json::Json::parse(&json.to_string()).unwrap();
        assert_eq!(parsed, json);
    }
}
