//! DSE coordinator (paper Fig. 2): wires the design space, the unified
//! evaluation engine ([`crate::eval::engine`]) and the Space Explorer into
//! the iterative loop; owns result persistence and reporting.
//!
//! This is Layer 3's event loop. A [`DseRun`] names one (model × phase ×
//! fidelity × explorer) tuple; [`run`] builds the [`Engine`] for it (plus
//! the analytical low-fidelity twin for MFMOBO's Algo. 1 pair) and drives
//! the explorer through [`explore`] — the single explorer-dispatch path
//! shared with the campaign runner. How evaluations are dispatched is the
//! *engine backend's* capability, never a coordinator decision, at three
//! levels (the dispatch rule in `eval::engine`): **serial** per-point
//! `eval` when the backend is thread-confined (the PJRT GNN batches
//! link-wait inference instead), **pooled** strategy fan-out via the
//! `Sync` view ([`Engine::to_sync`]), and **batched** `eval_batch` — one
//! fused cross-candidate strategy sweep with compile dedup — which
//! explorers hand whole candidate slices to
//! ([`crate::explorer::random_search_par`] rounds, MOBO proposals). All
//! three produce bit-identical objectives; a fallback from batched to
//! serial warns once, never silently.
//!
//! Fidelity names (`analytical`, `ca`, `gnn`, `gnn-test`) come from the
//! [`Fidelity`] registry — `theseus dse --fidelity`, campaign scenario
//! JSON and MFMOBO's pair all parse through the same list, and unknown
//! names exit 1 listing it.
//!
//! # Scenario campaigns ([`campaign`])
//!
//! One `theseus dse` invocation runs a single scenario; the [`campaign`]
//! subsystem batches the paper's whole §IX matrix:
//!
//! ```text
//! # the built-in §IX suite (96 scenarios), 4 at a time:
//! theseus campaign --suite paper --out artifacts/campaign --seed 2024 --jobs 4
//! # or a custom matrix from a JSON file (see campaign::scenarios_from_json):
//! theseus campaign --scenarios my_sweep.json --out artifacts/sweep
//! # skip scenarios whose artifact already exists under --out:
//! theseus campaign --suite paper --out artifacts/campaign --resume
//! # split the matrix across machines, then fuse the outputs:
//! theseus campaign --suite paper --shard 1/2 --out artifacts/shard1
//! theseus campaign --suite paper --shard 2/2 --out artifacts/shard2
//! theseus campaign --suite paper --merge artifacts/shard1,artifacts/shard2 \
//!     --out artifacts/campaign
//! ```
//!
//! Each scenario's RNG seed derives as `scenario_seed(campaign_seed,
//! scenario.key())` — FNV-1a over the scenario key folded into the
//! campaign seed and SplitMix64-finalized — so results are reproducible
//! per scenario (independent of sibling scenarios and worker
//! interleaving), and two same-seed campaign runs write byte-identical
//! artifacts (`campaign.json` + `scenarios/<key>.json`).

pub mod campaign;

use crate::eval::engine::{Engine, EvalSpec, Fidelity};
use crate::explorer::{self, BoConfig, MfConfig, Trace};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::table::Table;
use crate::workload::{models, Phase};

pub use crate::eval::engine::ref_power_for;

/// Which explorer to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Explorer {
    Random,
    Mobo,
    Mfmobo,
}

impl Explorer {
    pub fn parse(s: &str) -> Option<Explorer> {
        match s {
            "random" => Some(Explorer::Random),
            "mobo" => Some(Explorer::Mobo),
            "mfmobo" => Some(Explorer::Mfmobo),
            _ => None,
        }
    }

    /// [`Explorer::parse`] with a human-oriented error naming the valid
    /// explorers — CLI call sites print this and exit 1 instead of
    /// silently falling back.
    pub fn parse_or_usage(s: &str) -> Result<Explorer, String> {
        Explorer::parse(s)
            .ok_or_else(|| format!("unknown explorer '{s}' — valid: random, mobo, mfmobo"))
    }

    pub fn name(&self) -> &'static str {
        match self {
            Explorer::Random => "random",
            Explorer::Mobo => "mobo",
            Explorer::Mfmobo => "mfmobo",
        }
    }
}

/// A full DSE run description: one evaluation spec plus the explorer and
/// its budget.
pub struct DseRun {
    pub spec: crate::workload::LlmSpec,
    /// Workload phase under optimization (training / prefill / decode).
    pub phase: Phase,
    /// Inference batch (ignored for training).
    pub batch: usize,
    /// Multi-query attention for the inference phases.
    pub mqa: bool,
    /// Fixed wafer count; `None` = area-matched (§VIII-A).
    pub wafers: Option<usize>,
    /// Evaluation fidelity ([`Fidelity`] registry). For MFMOBO this is
    /// the *high* fidelity; the low fidelity is always analytical.
    pub fidelity: Fidelity,
    pub explorer: Explorer,
    pub cfg: BoConfig,
    /// Low-fidelity trials for MFMOBO (paper: 100).
    pub n1: usize,
    /// MFMOBO guided-handoff iterations.
    pub k: usize,
    /// Fault injection ([`crate::yield_model::faults`]): evaluate every
    /// candidate on a yield-realistic defective wafer. `None` keeps the
    /// bit-identical fault-free path.
    pub faults: Option<crate::yield_model::faults::FaultSpec>,
}

impl DseRun {
    fn eval_spec(&self) -> EvalSpec {
        EvalSpec {
            model: self.spec.clone(),
            phase: self.phase,
            batch: self.batch,
            mqa: self.mqa,
            wafers: self.wafers,
            fidelity: self.fidelity,
            faults: self.faults,
            hetero: None,
            interwafer: None,
        }
    }
}

/// Drive one explorer over an evaluation spec — the single dispatch path
/// behind `theseus dse` and every campaign scenario. Errors when the
/// spec's fidelity backend is unavailable (e.g. `gnn` without artifacts)
/// instead of silently substituting another fidelity.
pub fn explore(
    spec: &EvalSpec,
    explorer: Explorer,
    cfg: &BoConfig,
    n1: usize,
    k: usize,
) -> Result<Trace, String> {
    let engine = Engine::new(spec.clone())?;
    Ok(match explorer {
        // Random search fans whole design points over the pool when the
        // backend is Sync; the thread-confined GNN keeps the serial drive
        // (its sweep already batches inference).
        Explorer::Random => match engine.to_sync() {
            Some(sync) => explorer::random_search_par(&sync, cfg),
            None => explorer::random_search(&engine, cfg),
        },
        Explorer::Mobo => explorer::mobo(&engine, cfg),
        Explorer::Mfmobo => {
            // lint: allow(panic) Engine::new only errs for Fidelity::Gnn without a model; fidelity forced Analytical
            let low = Engine::new(spec.clone().with_fidelity(Fidelity::Analytical))
                .expect("analytical backend is always available");
            explorer::mfmobo(
                &engine,
                &low,
                &MfConfig {
                    base: cfg.clone(),
                    n1,
                    d0: cfg.init,
                    d1: cfg.init,
                    k,
                },
            )
        }
    })
}

/// Execute a DSE run; returns the trace (or the engine-construction
/// error, e.g. an unavailable fidelity backend).
pub fn run(run: &DseRun) -> Result<Trace, String> {
    explore(&run.eval_spec(), run.explorer, &run.cfg, run.n1, run.k)
}

/// Serialize a trace (checkpoint / bench consumption).
pub fn trace_to_json(trace: &Trace) -> Json {
    let mut points = Vec::new();
    for p in &trace.points {
        let mut o = Json::obj();
        o.set("summary", Json::Str(p.point.wsc.summary()))
            .set("throughput", Json::Num(p.objective.throughput))
            .set("power_w", Json::Num(p.objective.power_w))
            .set("fidelity", Json::Str(p.fidelity.to_string()))
            .set(
                "stacking",
                Json::Bool(p.point.wsc.reticle.memory.is_stacking()),
            );
        points.push(o);
    }
    let mut doc = Json::obj();
    doc.set("points", Json::Arr(points))
        .set("hv_history", Json::from_f64_slice(&trace.hv_history));
    doc
}

/// CLI entry (the `theseus dse` subcommand). Unknown `--model` /
/// `--phase` / `--fidelity` / `--explorer` keys exit 1 listing the valid
/// options from their registries (never a silent fallback to a default),
/// and an unwritable `--out` path exits 1 instead of panicking.
pub fn run_from_cli(args: &Args) {
    fn usage_exit(e: String) -> ! {
        // lint: allow(loud-failure) CLI usage error on the documented exit-1 path, not a library fallback
        eprintln!("dse: {e}");
        std::process::exit(1);
    }
    let model = args.str("model", "175b");
    let spec = models::find_or_usage(&model).unwrap_or_else(|e| usage_exit(e));
    let phase =
        Phase::parse_or_usage(&args.str("phase", "training")).unwrap_or_else(|e| usage_exit(e));
    let fidelity = Fidelity::parse_or_usage(&args.str("fidelity", "analytical"))
        .unwrap_or_else(|e| usage_exit(e));
    let explorer = Explorer::parse_or_usage(&args.str("explorer", "mfmobo"))
        .unwrap_or_else(|e| usage_exit(e));
    let cfg = BoConfig {
        iters: args.usize("iters", 40),
        init: args.usize("init", 6),
        pool: args.usize("pool", 96),
        mc_samples: args.usize("mc", 64),
        ref_power: args.f64("ref-power", ref_power_for(&spec)),
        seed: args.u64("seed", 0),
        sample_tries: 4000,
    };
    let dse = DseRun {
        spec: spec.clone(),
        phase,
        batch: args.usize("batch", if phase.is_inference() { 32 } else { 0 }),
        mqa: args.bool("mqa", false),
        wafers: if args.has("wafers") {
            Some(args.usize("wafers", 1))
        } else {
            None
        },
        fidelity,
        explorer,
        cfg,
        n1: args.usize("n1", 40),
        k: args.usize("k", 8),
        // --fault-defect enables fault injection at a defect-rate
        // multiplier; --fault-spares overrides the per-row redundancy
        // (default: the design's own converged allocation);
        // --fault-seed decouples the wafer sample from the search seed.
        faults: if args.has("fault-defect") {
            Some(crate::yield_model::faults::FaultSpec {
                defect_multiplier: args.f64("fault-defect", 1.0),
                spares: if args.has("fault-spares") {
                    Some(args.usize("fault-spares", 0))
                } else {
                    None
                },
                seed: args.u64("fault-seed", args.u64("seed", 0)),
            })
        } else {
            None
        },
    };
    // lint: allow(loud-failure) CLI progress banner on stderr, unconditional (not a fallback)
    eprintln!(
        "DSE: {} on {} {} at fidelity {} ({} iters, seed {})",
        explorer.name(),
        spec.name,
        phase.name(),
        fidelity.name(),
        dse.cfg.iters,
        dse.cfg.seed
    );
    if let Some(f) = &dse.faults {
        // lint: allow(loud-failure) CLI progress banner on stderr, echoes explicit flags (not a fallback)
        eprintln!(
            "fault injection: defect multiplier {} / spares {} / seed {}",
            f.defect_multiplier,
            f.spares.map_or("auto".to_string(), |n| n.to_string()),
            f.seed
        );
    }
    // lint: allow(determinism) elapsed-time reporting to stderr only — never written into a trace/artifact
    let t0 = std::time::Instant::now();
    let trace = run(&dse).unwrap_or_else(|e| usage_exit(e));
    // lint: allow(loud-failure) CLI completion summary on stderr (elapsed + hypervolume), not a fallback
    eprintln!(
        "explored {} points in {:.1}s; final hypervolume {:.4e}",
        trace.points.len(),
        t0.elapsed().as_secs_f64(),
        trace.final_hv()
    );

    let mut table = Table::new(
        &format!("Pareto set — {} {}", spec.name, phase.name()),
        &["tokens/s", "power(kW)", "fidelity", "config"],
    );
    let mut front = trace.pareto();
    front.sort_by(|a, b| b.objective.throughput.total_cmp(&a.objective.throughput));
    for p in front {
        table.row(&[
            format!("{:.1}", p.objective.throughput),
            format!("{:.1}", p.objective.power_w / 1e3),
            p.fidelity.to_string(),
            p.point.wsc.summary(),
        ]);
    }
    table.print();

    if let Some(out) = args.opt_str("out") {
        // The loud-exit CLI contract: an unwritable --out is a user
        // error, not a panic.
        match std::fs::write(&out, trace_to_json(&trace).to_pretty()) {
            // lint: allow(loud-failure) CLI confirmation of the user's --out path on stderr
            Ok(()) => eprintln!("trace written to {out}"),
            Err(e) => {
                // lint: allow(loud-failure) CLI exit-1 path for an unwritable --out, per the doc comment
                eprintln!("dse: cannot write trace to {out}: {e}");
                std::process::exit(1);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::benchmarks;

    #[test]
    fn explorer_parse() {
        assert_eq!(Explorer::parse("mfmobo"), Some(Explorer::Mfmobo));
        assert_eq!(Explorer::parse("nope"), None);
    }

    #[test]
    fn explorer_parse_or_usage_lists_options() {
        assert_eq!(Explorer::parse_or_usage("mobo"), Ok(Explorer::Mobo));
        let e = Explorer::parse_or_usage("grid").unwrap_err();
        assert!(e.contains("unknown explorer 'grid'"), "{e}");
        assert!(e.contains("random, mobo, mfmobo"), "{e}");
    }

    #[test]
    fn tiny_random_dse_end_to_end() {
        let spec = benchmarks()[0].clone();
        let run_cfg = DseRun {
            spec: spec.clone(),
            phase: Phase::Training,
            batch: 0,
            mqa: false,
            wafers: None,
            fidelity: Fidelity::Analytical,
            explorer: Explorer::Random,
            cfg: BoConfig {
                iters: 2,
                init: 2,
                pool: 8,
                mc_samples: 8,
                ref_power: ref_power_for(&spec),
                seed: 3,
                sample_tries: 2000,
            },
            n1: 0,
            k: 0,
            faults: None,
        };
        let trace = run(&run_cfg).expect("analytical run never fails to build");
        assert!(!trace.points.is_empty());
        let json = trace_to_json(&trace);
        assert!(json.get("points").unwrap().as_arr().unwrap().len() >= 1);
        // Round-trips through the JSON substrate.
        let parsed = crate::util::json::Json::parse(&json.to_string()).unwrap();
        assert_eq!(parsed, json);
    }

    #[cfg(not(theseus_pjrt))]
    #[test]
    fn gnn_fidelity_run_errors_loudly_offline() {
        // `--fidelity gnn` without artifacts must be a loud error from
        // the engine registry, not a silent analytical substitution.
        let spec = benchmarks()[0].clone();
        let run_cfg = DseRun {
            spec: spec.clone(),
            phase: Phase::Training,
            batch: 0,
            mqa: false,
            wafers: None,
            fidelity: Fidelity::Gnn,
            explorer: Explorer::Random,
            cfg: BoConfig {
                iters: 1,
                init: 1,
                pool: 4,
                mc_samples: 4,
                ref_power: ref_power_for(&spec),
                seed: 1,
                sample_tries: 100,
            },
            n1: 0,
            k: 0,
            faults: None,
        };
        let e = run(&run_cfg).unwrap_err();
        assert!(e.contains("fidelity 'gnn' unavailable"), "{e}");
    }
}
