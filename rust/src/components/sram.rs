//! SRAM macro model (paper §VI-E: "SRAM compiler" outputs).
//!
//! CACTI-class scaling at 14 nm ssg/0.9 V: area grows linearly with
//! capacity plus a banking overhead for bandwidth (each 32 KB bank
//! contributes one 64-bit port); per-bit access energy grows with the
//! fourth root of capacity (longer wires); leakage is linear in capacity.
//! The "SRAM constraint" of §V-E is [`feasible`]: the compiler cannot
//! produce more ports than banks.

use crate::arch::constants as k;

/// Bank granularity assumed by the macro generator.
pub const BANK_KB: usize = 32;
/// Port width contributed by one bank (bits/cycle).
pub const BANK_PORT_BITS: usize = 64;

/// SRAM-compiler feasibility (paper §V-E "SRAM Constraint"): requested
/// bandwidth must not exceed one 64-bit port per 32 KB bank.
pub fn feasible(capacity_kb: usize, bw_bits: usize) -> bool {
    let banks = capacity_kb / BANK_KB;
    banks >= 1 && bw_bits <= banks * BANK_PORT_BITS
}

/// Generated macro characteristics.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SramMacro {
    pub area_mm2: f64,
    /// Dynamic energy per bit accessed (read ≈ write at this node), pJ.
    pub energy_pj_per_bit: f64,
    /// Leakage, W.
    pub leak_w: f64,
}

/// Characterize a macro of `capacity_kb` with `bw_bits` per cycle.
/// Callers must have checked [`feasible`]; infeasible requests are clamped
/// to the max feasible bandwidth so the estimator never panics mid-DSE.
pub fn sram_macro(capacity_kb: usize, bw_bits: usize) -> SramMacro {
    let banks = (capacity_kb / BANK_KB).max(1);
    let bw = bw_bits.min(banks * BANK_PORT_BITS);
    let mb = capacity_kb as f64 / 1024.0;

    // Banking overhead: wide aggregate ports need more peripheral logic
    // and routing per bank. 6 % area per doubling of active ports.
    let active_ports = (bw as f64 / BANK_PORT_BITS as f64).max(1.0);
    let banking_overhead = 1.0 + 0.06 * active_ports.log2().max(0.0);
    let area_mm2 = k::SRAM_MM2_PER_MB * mb * banking_overhead;

    // Wire-length energy scaling ~ capacity^(1/4), normalized at 128 KB.
    let cap_scale = (capacity_kb as f64 / 128.0).powf(0.25);
    let energy_pj_per_bit = k::SRAM_ENERGY_PJ_PER_BIT * cap_scale;

    let leak_w = k::SRAM_LEAK_W_PER_MB * mb;

    SramMacro {
        area_mm2,
        energy_pj_per_bit,
        leak_w,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn feasibility_diagonal() {
        assert!(feasible(32, 32));
        assert!(feasible(32, 64));
        assert!(!feasible(32, 128)); // 1 bank -> max 64 bits
        assert!(feasible(2048, 4096)); // 64 banks -> 4096 bits
        assert!(!feasible(1024, 4096)); // 32 banks -> max 2048 bits
    }

    #[test]
    fn area_scales_linearly_in_capacity() {
        let a = sram_macro(128, 64).area_mm2;
        let b = sram_macro(256, 64).area_mm2;
        assert!((b / a - 2.0).abs() < 0.05, "ratio={}", b / a);
    }

    #[test]
    fn bandwidth_costs_area() {
        let narrow = sram_macro(2048, 64).area_mm2;
        let wide = sram_macro(2048, 4096).area_mm2;
        assert!(wide > narrow * 1.2, "narrow={narrow} wide={wide}");
    }

    #[test]
    fn energy_grows_with_capacity() {
        assert!(sram_macro(2048, 64).energy_pj_per_bit > sram_macro(32, 64).energy_pj_per_bit);
        // Normalized point: 128 KB hits the base constant.
        assert!(
            (sram_macro(128, 64).energy_pj_per_bit - crate::arch::constants::SRAM_ENERGY_PJ_PER_BIT)
                .abs()
                < 1e-12
        );
    }

    #[test]
    fn infeasible_clamped_not_panic() {
        let m = sram_macro(32, 4096);
        assert!(m.area_mm2 > 0.0);
    }

    #[test]
    fn prop_positive_outputs() {
        crate::util::prop::check(
            "sram outputs positive and monotone in capacity",
            |r| {
                let kb = 32 * (1 << r.below(7)); // 32..2048
                let bw = 32 * (1 << r.below(8)); // 32..4096
                (kb, bw)
            },
            |&(kb, bw)| {
                let m = sram_macro(kb, bw);
                if m.area_mm2 <= 0.0 || m.energy_pj_per_bit <= 0.0 || m.leak_w <= 0.0 {
                    return Err(format!("non-positive: {m:?}"));
                }
                if kb < 2048 {
                    let bigger = sram_macro(kb * 2, bw);
                    if bigger.area_mm2 <= m.area_mm2 {
                        return Err("area not monotone in capacity".into());
                    }
                }
                Ok(())
            },
        );
    }
}
