//! Inter-reticle PHY and DRAM interface models (paper §VI-E, §VIII-A).
//!
//! Area: the paper quotes 3900 µm²/Gbps for RDL (InFO-SoW SerDes) and
//! 1300 µm²/Gbps for offset exposure (die stitching) — used verbatim.
//! Energy: offset exposure is near-wire (Cerebras fabric class), RDL is
//! GRS-class SerDes.

use crate::arch::constants as k;
use crate::arch::{IntegrationStyle, MemoryKind, ReticleConfig};

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PhyBudget {
    /// Total PHY area on one reticle for its inter-reticle links, mm².
    pub area_mm2: f64,
    /// Signalling energy, pJ per bit crossing a reticle boundary.
    pub energy_pj_per_bit: f64,
}

/// PHY budget for a reticle: four edges, each carrying
/// [`ReticleConfig::inter_reticle_bytes_per_sec`].
pub fn inter_reticle_phy(ret: &ReticleConfig, style: IntegrationStyle) -> PhyBudget {
    let per_edge_gbps = ret.inter_reticle_bytes_per_sec() * 8.0 / 1e9;
    let total_gbps = 4.0 * per_edge_gbps;
    let (um2_per_gbps, energy) = match style {
        IntegrationStyle::InfoSoW => (k::PHY_AREA_UM2_PER_GBPS_RDL, k::PHY_ENERGY_PJ_PER_BIT_RDL),
        IntegrationStyle::DieStitching => (
            k::PHY_AREA_UM2_PER_GBPS_STITCH,
            k::PHY_ENERGY_PJ_PER_BIT_STITCH,
        ),
    };
    PhyBudget {
        area_mm2: total_gbps * um2_per_gbps / 1e6,
        energy_pj_per_bit: energy,
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct TsvBudget {
    pub tsv_count: usize,
    /// Floorplan footprint of the TSV field (pitch-sized cells), mm² —
    /// displaces compute area.
    pub area_mm2: f64,
    /// Drilled hole (via) area, mm² — what the §V-E stress cap bounds.
    pub hole_area_mm2: f64,
    /// Fraction of the §V-E stress budget consumed (1.0 = at the 1.5 % cap).
    pub stress_utilization: f64,
}

/// TSV field needed to feed a reticle's stacked DRAM at its configured
/// bandwidth density over `reticle_area_mm2` (paper: 1 Gbps/TSV, 5 µm via,
/// 15 µm pitch). Off-chip designs need none.
pub fn tsv_budget(ret: &ReticleConfig, reticle_area_mm2: f64) -> TsvBudget {
    match ret.memory {
        MemoryKind::OffChip => TsvBudget::default(),
        MemoryKind::Stacking { .. } => {
            let bytes_per_sec = ret.stacking_bytes_per_sec(reticle_area_mm2);
            let bits_per_sec = bytes_per_sec * 8.0;
            let tsv_count = (bits_per_sec / k::TSV_BW_BITS_PER_SEC).ceil() as usize;
            let cell_mm2 = (k::TSV_PITCH_UM / 1e3).powi(2);
            let hole_mm2 = (k::TSV_VIA_UM / 1e3).powi(2);
            let area_mm2 = tsv_count as f64 * cell_mm2;
            let hole_area_mm2 = tsv_count as f64 * hole_mm2;
            let cap = k::TSV_AREA_RATIO_MAX * reticle_area_mm2;
            TsvBudget {
                tsv_count,
                area_mm2,
                hole_area_mm2,
                stress_utilization: if cap > 0.0 {
                    hole_area_mm2 / cap
                } else {
                    f64::INFINITY
                },
            }
        }
    }
}

/// DRAM access energy per bit for the reticle's memory system.
pub fn dram_energy_pj_per_bit(mem: MemoryKind) -> f64 {
    match mem {
        MemoryKind::OffChip => k::DRAM_ENERGY_PJ_PER_BIT_OFFCHIP,
        MemoryKind::Stacking { .. } => k::DRAM_ENERGY_PJ_PER_BIT_STACKED,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{CoreConfig, Dataflow};

    fn reticle(bw_ratio: f64, mem: MemoryKind) -> ReticleConfig {
        ReticleConfig {
            core: CoreConfig {
                dataflow: Dataflow::WS,
                mac_num: 512,
                buffer_kb: 128,
                buffer_bw_bits: 512,
                noc_bw_bits: 512,
            },
            array_h: 10,
            array_w: 10,
            inter_reticle_bw_ratio: bw_ratio,
            memory: mem,
        }
    }

    #[test]
    fn rdl_costs_more_area_than_stitch() {
        let r = reticle(1.0, MemoryKind::OffChip);
        let rdl = inter_reticle_phy(&r, IntegrationStyle::InfoSoW);
        let stitch = inter_reticle_phy(&r, IntegrationStyle::DieStitching);
        assert!((rdl.area_mm2 / stitch.area_mm2 - 3900.0 / 1300.0).abs() < 1e-9);
        assert!(rdl.energy_pj_per_bit > stitch.energy_pj_per_bit);
    }

    #[test]
    fn phy_area_matches_paper_constant() {
        // bisection = 10 links * 64 B/cycle * 1 GHz = 640 GB/s; ratio 1.0
        // -> per edge 640 GB/s = 5120 Gbps; 4 edges = 20480 Gbps.
        let r = reticle(1.0, MemoryKind::OffChip);
        let phy = inter_reticle_phy(&r, IntegrationStyle::InfoSoW);
        assert!((phy.area_mm2 - 20480.0 * 3900.0 / 1e6).abs() < 1e-6);
    }

    #[test]
    fn tsv_count_from_bandwidth() {
        let r = reticle(
            1.0,
            MemoryKind::Stacking {
                bw_tbps_per_100mm2: 1.0,
                capacity_gb: 16.0,
            },
        );
        let t = tsv_budget(&r, 500.0);
        // 1 TB/s/100mm² × 500 mm² = 5 TB/s = 4e13 bits/s -> 40000 TSVs.
        assert_eq!(t.tsv_count, 40_000);
        // Footprint: 40000 × (15µm)² = 9 mm²; holes: 40000 × (5µm)² = 1 mm².
        assert!((t.area_mm2 - 9.0).abs() < 1e-9);
        assert!((t.hole_area_mm2 - 1.0).abs() < 1e-9);
        // cap = 1.5% × 500 = 7.5 mm² -> hole utilization 1/7.5 ≈ 0.133.
        assert!((t.stress_utilization - 1.0 / 7.5).abs() < 1e-9);
    }

    #[test]
    fn stress_cap_binds_only_beyond_table_range() {
        // The Table I sweep (0.25–4 TB/s/100mm²) stays within the stress
        // cap (paper Fig. 11b sweeps the full range), but ~7.5 TB/s/100mm²
        // would trip it.
        for bw in [0.25, 1.0, 4.0] {
            let r = reticle(
                1.0,
                MemoryKind::Stacking {
                    bw_tbps_per_100mm2: bw,
                    capacity_gb: 16.0,
                },
            );
            let t = tsv_budget(&r, 400.0);
            assert!(t.stress_utilization <= 1.0, "bw={bw} util={}", t.stress_utilization);
        }
        let r = reticle(
            1.0,
            MemoryKind::Stacking {
                bw_tbps_per_100mm2: 8.0,
                capacity_gb: 8.0,
            },
        );
        assert!(tsv_budget(&r, 400.0).stress_utilization > 1.0);
    }

    #[test]
    fn offchip_needs_no_tsvs() {
        let r = reticle(1.0, MemoryKind::OffChip);
        let t = tsv_budget(&r, 500.0);
        assert_eq!(t.tsv_count, 0);
        assert_eq!(t.area_mm2, 0.0);
    }
}
