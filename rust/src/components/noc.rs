//! NoC router model (paper §VI-E, Orion 3.0-class).
//!
//! A 5-port mesh router with 8 VCs × 4-flit buffers (paper §VIII-A): buffer
//! area is linear in flit-width × buffering, the crossbar grows
//! quadratically in flit width — the second leg of the paper's "module
//! efficiency" argument against very high-bandwidth routers.

use crate::arch::constants as k;

pub const ROUTER_PORTS: usize = 5; // N/S/E/W + local

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Router {
    pub area_mm2: f64,
    /// Energy per bit traversing the router (buffer rd/wr + crossbar +
    /// arbitration amortized), pJ.
    pub energy_pj_per_bit: f64,
    pub leak_w: f64,
}

/// Characterize a router of `flit_bits` datapath width.
pub fn router(flit_bits: usize) -> Router {
    let fb = flit_bits as f64;

    // Buffers: ports × VCs × depth × flit width.
    let buffer_um2 =
        k::NOC_AREA_UM2_PER_BIT_ENTRY * fb * (ROUTER_PORTS * k::NOC_VCS * k::NOC_BUFS_PER_VC) as f64
            / ROUTER_PORTS as f64; // per-port entry constant is folded in
    // Crossbar: ~quadratic in datapath width (wiring dominated).
    let crossbar_um2 = 0.015 * fb * fb;
    // Allocators/arbiters: fixed + log factor.
    let ctrl_um2 = 3000.0 + 500.0 * fb.log2();

    let area_mm2 = (buffer_um2 + crossbar_um2 + ctrl_um2) / 1e6;

    // Energy per bit: base constant plus a width-dependent crossbar term
    // (longer crossbar wires), normalized at 512-bit flits.
    let energy_pj_per_bit = k::NOC_ROUTER_ENERGY_PJ_PER_BIT * (1.0 + 0.15 * (fb / 512.0).log2().max(-1.0));

    let peak_dyn_w = fb * energy_pj_per_bit * 1e-12 * k::CLOCK_HZ * ROUTER_PORTS as f64;
    let leak_w = k::LOGIC_LEAK_FRAC * peak_dyn_w;

    Router {
        area_mm2,
        energy_pj_per_bit,
        leak_w,
    }
}

/// Link traversal energy for one bit over `dist_mm` of wire.
pub fn link_energy_pj_per_bit(dist_mm: f64) -> f64 {
    k::NOC_LINK_ENERGY_PJ_PER_BIT_MM * dist_mm
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crossbar_quadratic_dominates_at_width() {
        // At the top of the range the quadratic crossbar term dominates:
        // 4x width -> more than 4x area.
        let r1k = router(1024);
        let r4k = router(4096);
        assert!(
            r4k.area_mm2 / r1k.area_mm2 > 4.0,
            "ratio={}",
            r4k.area_mm2 / r1k.area_mm2
        );
    }

    #[test]
    fn energy_mildly_increasing_in_width() {
        assert!(router(4096).energy_pj_per_bit > router(512).energy_pj_per_bit);
        assert!(router(4096).energy_pj_per_bit < 3.0 * router(512).energy_pj_per_bit);
    }

    #[test]
    fn positive_outputs() {
        for bits in [32usize, 128, 1024, 4096] {
            let r = router(bits);
            assert!(r.area_mm2 > 0.0 && r.energy_pj_per_bit > 0.0 && r.leak_w > 0.0);
        }
    }

    #[test]
    fn link_energy_linear_in_distance() {
        assert!((link_energy_pj_per_bit(2.0) - 2.0 * k::NOC_LINK_ENERGY_PJ_PER_BIT_MM).abs() < 1e-15);
    }
}
