//! MAC-array datapath model (paper §VI-E: Chisel MAC arrays in different
//! dataflows, synthesized and placed; here a parametric model at 14 nm).
//!
//! Dataflow affects the per-MAC register/control overhead: weight- and
//! input-stationary arrays keep one stationary operand register per MAC;
//! output-stationary keeps a (wider) accumulator per MAC. The differences
//! are a few percent — module efficiency at *large* array sizes is what the
//! paper's core-granularity tradeoff hinges on (control fanout and operand
//! distribution networks grow superlinearly).

use crate::arch::constants as k;
use crate::arch::Dataflow;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MacArray {
    pub area_mm2: f64,
    /// Energy per MAC operation, pJ.
    pub energy_pj_per_mac: f64,
    pub leak_w: f64,
}

/// Dataflow-specific per-MAC overhead factors (area, energy).
fn dataflow_factors(df: Dataflow) -> (f64, f64) {
    match df {
        // 16-bit stationary weight register.
        Dataflow::WS => (1.00, 1.00),
        // Input-stationary: same register cost, slightly busier operand
        // network for weights streaming.
        Dataflow::IS => (1.01, 1.02),
        // Output-stationary: 32-bit accumulator per MAC, cheaper operand
        // movement (psums stay put).
        Dataflow::OS => (1.06, 0.97),
    }
}

/// Characterize an array of `mac_num` MACs in dataflow `df`.
pub fn mac_array(mac_num: usize, df: Dataflow) -> MacArray {
    let (fa, fe) = dataflow_factors(df);

    // Operand distribution + reduction networks: ~4 % area per doubling
    // beyond a 64-MAC tile (H-tree fanout), normalized so a 64-MAC tile has
    // zero overhead. This makes very large monolithic arrays less
    // area-efficient, one leg of the paper's "module efficiency" argument.
    let fanout = 1.0 + 0.04 * ((mac_num as f64 / 64.0).log2()).max(0.0);

    let area_um2 = k::MAC_AREA_UM2 * mac_num as f64 * fa * fanout;
    let area_mm2 = area_um2 / 1e6;
    let energy_pj_per_mac = k::MAC_ENERGY_PJ * fe * fanout.sqrt();

    // Leakage proportional to area-implied peak dynamic power.
    let peak_dyn_w = mac_num as f64 * energy_pj_per_mac * 1e-12 * k::CLOCK_HZ;
    let leak_w = k::LOGIC_LEAK_FRAC * peak_dyn_w;

    MacArray {
        area_mm2,
        energy_pj_per_mac,
        leak_w,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn area_roughly_linear_small() {
        let a = mac_array(64, Dataflow::WS);
        assert!((a.area_mm2 - 64.0 * 600.0 / 1e6).abs() < 1e-9);
    }

    #[test]
    fn superlinear_fanout_at_scale() {
        let small = mac_array(64, Dataflow::WS);
        let big = mac_array(4096, Dataflow::WS);
        let per_mac_small = small.area_mm2 / 64.0;
        let per_mac_big = big.area_mm2 / 4096.0;
        assert!(per_mac_big > per_mac_small * 1.1);
    }

    #[test]
    fn os_bigger_cheaper_energy() {
        let ws = mac_array(256, Dataflow::WS);
        let os = mac_array(256, Dataflow::OS);
        assert!(os.area_mm2 > ws.area_mm2);
        assert!(os.energy_pj_per_mac < ws.energy_pj_per_mac);
    }

    #[test]
    fn leakage_positive_fraction() {
        let m = mac_array(1024, Dataflow::IS);
        let peak_w = 1024.0 * m.energy_pj_per_mac * 1e-12 * 1e9;
        assert!((m.leak_w / peak_w - k::LOGIC_LEAK_FRAC).abs() < 1e-12);
    }
}
