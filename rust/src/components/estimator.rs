//! Component Estimator (paper §VI-E, Fig. 2): assembles the per-module
//! area/power models into core / reticle / wafer physical characteristics,
//! memoizing core geometry (the paper builds an area-power table of basic
//! modules for exactly this reason — it sits on the DSE hot path).

use std::sync::OnceLock;

use crate::arch::constants as k;
use crate::arch::{CoreConfig, IntegrationStyle, MemoryKind, ReticleConfig, WscConfig};
use crate::components::{mac, noc, phy, sram};
use crate::util::memo::Memo;
use crate::yield_model::{self, redundancy, YieldInputs};

/// Physical characterization of one core.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CoreGeom {
    pub area_mm2: f64,
    /// Square-ish floorplan edge lengths.
    pub width_mm: f64,
    pub height_mm: f64,
    /// Per-action energies (pJ).
    pub e_mac_pj: f64,
    pub e_sram_pj_per_bit: f64,
    pub e_noc_router_pj_per_bit: f64,
    /// Static (leakage) power of the core, W.
    pub leak_w: f64,
}

type CoreKey = (u8, usize, usize, usize, usize);

fn core_key(c: &CoreConfig) -> CoreKey {
    (
        c.dataflow as u8,
        c.mac_num,
        c.buffer_kb,
        c.buffer_bw_bits,
        c.noc_bw_bits,
    )
}

static CORE_CACHE: OnceLock<Memo<CoreKey, CoreGeom>> = OnceLock::new();

fn core_cache() -> &'static Memo<CoreKey, CoreGeom> {
    // The design-space grid holds ~thousands of distinct cores; epoch
    // eviction (see util::memo) keeps degenerate sweeps bounded.
    CORE_CACHE.get_or_init(|| Memo::new(4096))
}

/// Characterize a core (memoized on [`Memo`], shared with the tile-level
/// evaluation cache substrate).
pub fn core_geom(c: &CoreConfig) -> CoreGeom {
    core_cache().get_or_insert_with(core_key(c), || core_geom_uncached(c))
}

fn core_geom_uncached(c: &CoreConfig) -> CoreGeom {
    let m = mac::mac_array(c.mac_num, c.dataflow);
    let s = sram::sram_macro(c.buffer_kb, c.buffer_bw_bits);
    let r = noc::router(c.noc_bw_bits);

    let area_mm2 = m.area_mm2 + s.area_mm2 + r.area_mm2 + k::CTRL_AREA_UM2 / 1e6;
    let edge = area_mm2.sqrt();
    let leak_w = m.leak_w + s.leak_w + r.leak_w + k::CTRL_STATIC_W;

    CoreGeom {
        area_mm2,
        width_mm: edge,
        height_mm: edge,
        e_mac_pj: m.energy_pj_per_mac,
        e_sram_pj_per_bit: s.energy_pj_per_bit,
        e_noc_router_pj_per_bit: r.energy_pj_per_bit
            + noc::link_energy_pj_per_bit(edge), // hop = router + one core-pitch of link
        leak_w,
    }
}

/// Why a design fails physical assembly (feeds the §V-E validator).
#[derive(Debug, Clone, PartialEq)]
pub enum PhysError {
    SramInfeasible { kb: usize, bw: usize },
    ReticleOverflow { w: f64, h: f64 },
    YieldUnreachable { target: f64 },
    StressViolation { need: f64, cap: f64 },
    WaferOverflow { w: f64, h: f64, lim: f64 },
}

impl std::fmt::Display for PhysError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PhysError::SramInfeasible { kb, bw } => {
                write!(f, "SRAM config infeasible: {kb} KB @ {bw} bit/cyc")
            }
            PhysError::ReticleOverflow { w, h } => write!(
                f,
                "core array ({w:.1} x {h:.1} mm) exceeds reticle limit even without redundancy"
            ),
            PhysError::YieldUnreachable { target } => {
                write!(f, "yield target {target} unreachable within redundancy budget")
            }
            PhysError::StressViolation { need, cap } => {
                write!(f, "TSV field needs {need:.2} mm2 but stress cap is {cap:.2} mm2")
            }
            PhysError::WaferOverflow { w, h, lim } => {
                write!(f, "reticle array ({w:.0} x {h:.0} mm) exceeds wafer ({lim:.0} mm)")
            }
        }
    }
}

impl std::error::Error for PhysError {}

/// Physical characterization of one reticle, with redundancy resolved.
#[derive(Debug, Clone)]
pub struct ReticlePhys {
    pub core: CoreGeom,
    /// Logical (operational) array.
    pub array_h: usize,
    pub array_w: usize,
    /// Spare cores appended per row (Cerebras-style row redundancy).
    pub red_per_row: usize,
    /// Reticle bounding box including PHY ring and TSV field, mm.
    pub width_mm: f64,
    pub height_mm: f64,
    pub area_mm2: f64,
    pub phy: phy::PhyBudget,
    pub tsv: phy::TsvBudget,
    pub reticle_yield: f64,
    pub wafer_yield: f64,
    /// Stacked DRAM bandwidth for this reticle, bytes/s.
    pub stack_bytes_per_sec: f64,
    /// Static power of the whole reticle (cores incl. spares + DRAM), W.
    pub leak_w: f64,
}

impl ReticlePhys {
    pub fn operational_cores(&self) -> usize {
        self.array_h * self.array_w
    }

    pub fn physical_cores(&self) -> usize {
        self.array_h * (self.array_w + self.red_per_row)
    }

    /// Area overhead fraction spent on redundancy.
    pub fn redundancy_overhead(&self) -> f64 {
        self.red_per_row as f64 / (self.array_w + self.red_per_row) as f64
    }
}

/// Assemble a reticle: floorplan cores (+ redundancy), PHY ring, TSV field;
/// check reticle-limit fit and stress cap; resolve the minimum redundancy
/// meeting [`k::YIELD_TARGET`] at wafer level.
pub fn reticle_phys(
    ret: &ReticleConfig,
    style: IntegrationStyle,
    num_reticles: usize,
) -> Result<ReticlePhys, PhysError> {
    if !sram::feasible(ret.core.buffer_kb, ret.core.buffer_bw_bits) {
        return Err(PhysError::SramInfeasible {
            kb: ret.core.buffer_kb,
            bw: ret.core.buffer_bw_bits,
        });
    }
    let core = core_geom(&ret.core);
    let phy_budget = phy::inter_reticle_phy(ret, style);

    // Floorplan with n spares per row; returns the bbox if it fits the
    // reticle limit in either orientation, along with the TSV budget.
    let floorplan = |n_red: usize| -> Option<(f64, f64, phy::TsvBudget, f64)> {
        let cols = ret.array_w + n_red;
        let rows = ret.array_h;
        // Extra reroute connections for redundancy: 3 % of row width per
        // spare (bypass muxes + wiring), Cerebras-style.
        let conn_factor = 1.0 + 0.03 * n_red as f64;
        let array_w_mm = cols as f64 * core.width_mm * conn_factor;
        let array_h_mm = rows as f64 * core.height_mm;
        let array_area = array_w_mm * array_h_mm;

        // PHY ring distributed along the perimeter; TSV field interleaved.
        let base_area = array_area + phy_budget.area_mm2;
        let tsv = phy::tsv_budget(ret, base_area);
        let total_area = base_area + tsv.area_mm2;

        // Grow the bbox isotropically to absorb PHY + TSV area.
        let scale = (total_area / array_area).sqrt();
        let (w, h) = (array_w_mm * scale, array_h_mm * scale);
        let fits = (w <= k::RETICLE_W_MM && h <= k::RETICLE_H_MM)
            || (w <= k::RETICLE_H_MM && h <= k::RETICLE_W_MM);
        if fits {
            Some((w, h, tsv, total_area))
        } else {
            None
        }
    };

    // Must fit at least without spares, otherwise the point is dead.
    let Some((w0, h0, tsv0, _)) = floorplan(0) else {
        let cols = ret.array_w;
        return Err(PhysError::ReticleOverflow {
            w: cols as f64 * core.width_mm,
            h: ret.array_h as f64 * core.height_mm,
        });
    };

    // Stress constraint (§V-E): the zero-redundancy TSV field already tells
    // us whether the bandwidth density is physical.
    if tsv0.stress_utilization > 1.0 {
        let cap = tsv0.area_mm2 / tsv0.stress_utilization;
        return Err(PhysError::StressViolation {
            need: tsv0.area_mm2,
            cap,
        });
    }

    let _ = (w0, h0);

    // Redundancy selection: per-core yield grid over the *physical* array.
    let grid_for = |n_red: usize| -> Option<Vec<Vec<f64>>> {
        let (w, h, tsv, _) = floorplan(n_red)?;
        let inp = YieldInputs {
            array_h: ret.array_h,
            array_w: ret.array_w + n_red,
            core_w_mm: core.width_mm,
            core_h_mm: core.height_mm,
            core_area_cm2: core.area_mm2 / 100.0,
            reticle_w_mm: w,
            reticle_h_mm: h,
            tsv_stress_utilization: tsv.stress_utilization,
        };
        Some(yield_model::yield_grid(&inp))
    };
    let max_red = (ret.array_w / 2).max(2).min(8);
    let plan = redundancy::choose_redundancy(
        k::YIELD_TARGET,
        num_reticles,
        style,
        max_red,
        grid_for,
    )
    .ok_or(PhysError::YieldUnreachable {
        target: k::YIELD_TARGET,
    })?;

    let (w, h, tsv, area) = floorplan(plan.per_row).expect("plan floorplan fits");
    let physical_cores = ret.array_h * (ret.array_w + plan.per_row);
    let stack_bps = ret.stacking_bytes_per_sec(area);
    let dram_static = match ret.memory {
        MemoryKind::OffChip => 0.0,
        MemoryKind::Stacking { capacity_gb, .. } => capacity_gb * k::DRAM_STATIC_W_PER_GB,
    };
    let leak_w = physical_cores as f64 * core.leak_w + dram_static;

    Ok(ReticlePhys {
        core,
        array_h: ret.array_h,
        array_w: ret.array_w,
        red_per_row: plan.per_row,
        width_mm: w,
        height_mm: h,
        area_mm2: area,
        phy: phy_budget,
        tsv,
        reticle_yield: plan.reticle_yield,
        wafer_yield: plan.wafer_yield,
        stack_bytes_per_sec: stack_bps,
        leak_w,
    })
}

/// Physical characterization of the whole wafer.
#[derive(Debug, Clone)]
pub struct WaferPhys {
    pub reticle: ReticlePhys,
    pub reticle_h: usize,
    pub reticle_w: usize,
    /// Total silicon area committed, mm².
    pub area_mm2: f64,
    /// Effective peak FLOP/s (operational cores only).
    pub peak_flops: f64,
    /// Worst-case (all-units-active) power, W — checked against the 15 kW cap.
    pub peak_power_w: f64,
    pub wafer_yield: f64,
}

/// Assemble a wafer: tile reticles at their physical pitch and check the
/// wafer fit; compute peak power for the §V-E power constraint.
pub fn wafer_phys(wsc: &WscConfig) -> Result<WaferPhys, PhysError> {
    let ret = reticle_phys(&wsc.reticle, wsc.integration, wsc.num_reticles())?;

    let (rw, rh) = (ret.width_mm, ret.height_mm);
    let w1 = wsc.reticle_w as f64 * rw;
    let h1 = wsc.reticle_h as f64 * rh;
    let w2 = wsc.reticle_w as f64 * rh;
    let h2 = wsc.reticle_h as f64 * rw;
    let fits = (w1 <= k::WAFER_EDGE_MM && h1 <= k::WAFER_EDGE_MM)
        || (w2 <= k::WAFER_EDGE_MM && h2 <= k::WAFER_EDGE_MM);
    if !fits {
        return Err(PhysError::WaferOverflow {
            w: w1.min(w2),
            h: h1.max(h2),
            lim: k::WAFER_EDGE_MM,
        });
    }

    let n_ret = wsc.num_reticles() as f64;
    let area = n_ret * ret.area_mm2;
    let peak_flops = n_ret * ret.operational_cores() as f64 * wsc.reticle.core.peak_flops();
    let peak_power_w = peak_power(wsc, &ret);
    let wafer_yield = ret.wafer_yield;

    Ok(WaferPhys {
        reticle: ret,
        reticle_h: wsc.reticle_h,
        reticle_w: wsc.reticle_w,
        area_mm2: area,
        peak_flops,
        peak_power_w,
        wafer_yield,
    })
}

/// Like [`wafer_phys`], but for *existing* baseline designs (§IX-F): if the
/// yield target is unreachable, fall back to one spare per row and accept
/// the resulting yield (the paper likewise waives yield for baselines).
pub fn wafer_phys_relaxed(wsc: &WscConfig) -> Result<WaferPhys, PhysError> {
    match wafer_phys(wsc) {
        Ok(w) => Ok(w),
        Err(PhysError::YieldUnreachable { .. }) => {
            let ret = reticle_phys_fixed_red(&wsc.reticle, wsc.integration, wsc.num_reticles(), 1)?;
            let n_ret = wsc.num_reticles() as f64;
            let area = n_ret * ret.area_mm2;
            let peak_flops =
                n_ret * ret.operational_cores() as f64 * wsc.reticle.core.peak_flops();
            let peak_power_w = peak_power(wsc, &ret);
            let wafer_yield = ret.wafer_yield;
            Ok(WaferPhys {
                reticle: ret,
                reticle_h: wsc.reticle_h,
                reticle_w: wsc.reticle_w,
                area_mm2: area,
                peak_flops,
                peak_power_w,
                wafer_yield,
            })
        }
        Err(e) => Err(e),
    }
}

/// Reticle characterization with a *fixed* per-row redundancy (no target
/// search). Shares the floorplan logic with [`reticle_phys`].
fn reticle_phys_fixed_red(
    ret: &ReticleConfig,
    style: IntegrationStyle,
    num_reticles: usize,
    n_red: usize,
) -> Result<ReticlePhys, PhysError> {
    let core = core_geom(&ret.core);
    let phy_budget = phy::inter_reticle_phy(ret, style);
    let cols = ret.array_w + n_red;
    let conn_factor = 1.0 + 0.03 * n_red as f64;
    let array_w_mm = cols as f64 * core.width_mm * conn_factor;
    let array_h_mm = ret.array_h as f64 * core.height_mm;
    let array_area = array_w_mm * array_h_mm;
    let base_area = array_area + phy_budget.area_mm2;
    let tsv = phy::tsv_budget(ret, base_area);
    let total_area = base_area + tsv.area_mm2;
    let scale = (total_area / array_area).sqrt();
    let (w, h) = (array_w_mm * scale, array_h_mm * scale);

    let inp = YieldInputs {
        array_h: ret.array_h,
        array_w: cols,
        core_w_mm: core.width_mm,
        core_h_mm: core.height_mm,
        core_area_cm2: core.area_mm2 / 100.0,
        reticle_w_mm: w,
        reticle_h_mm: h,
        tsv_stress_utilization: tsv.stress_utilization,
    };
    let grid = yield_model::yield_grid(&inp);
    let ry = redundancy::reticle_yield_rows(&grid, n_red);
    let wy = redundancy::wafer_yield(ry, num_reticles, style);
    let physical_cores = ret.array_h * cols;
    let dram_static = match ret.memory {
        MemoryKind::OffChip => 0.0,
        MemoryKind::Stacking { capacity_gb, .. } => capacity_gb * k::DRAM_STATIC_W_PER_GB,
    };
    Ok(ReticlePhys {
        core,
        array_h: ret.array_h,
        array_w: ret.array_w,
        red_per_row: n_red,
        width_mm: w,
        height_mm: h,
        area_mm2: total_area,
        phy: phy_budget,
        tsv,
        reticle_yield: ry,
        wafer_yield: wy,
        stack_bytes_per_sec: ret.stacking_bytes_per_sec(total_area),
        leak_w: physical_cores as f64 * core.leak_w + dram_static,
    })
}

/// Worst-case power: every MAC, SRAM port, NoC link, inter-reticle lane and
/// DRAM channel active each cycle, plus leakage. The §V-E power constraint
/// uses a 70 % concurrent-activity derate (real workloads never saturate
/// all structures simultaneously; matches how TDP relates to peak).
pub fn peak_power(wsc: &WscConfig, ret: &ReticlePhys) -> f64 {
    const ACTIVITY: f64 = 0.7;
    let core = &ret.core;
    let c = &wsc.reticle.core;
    let n_cores = (wsc.num_reticles() * ret.operational_cores()) as f64;

    let mac_w = n_cores * c.mac_num as f64 * core.e_mac_pj * 1e-12 * k::CLOCK_HZ;
    let sram_w =
        n_cores * c.buffer_bw_bits as f64 * core.e_sram_pj_per_bit * 1e-12 * k::CLOCK_HZ;
    let noc_w =
        n_cores * c.noc_bw_bits as f64 * core.e_noc_router_pj_per_bit * 1e-12 * k::CLOCK_HZ;

    let n_ret = wsc.num_reticles() as f64;
    let ir_bits = wsc.reticle.inter_reticle_bytes_per_sec() * 8.0 * 4.0; // 4 edges
    let ir_w = n_ret * ir_bits * ret.phy.energy_pj_per_bit * 1e-12;

    let dram_w = match wsc.reticle.memory {
        MemoryKind::OffChip => {
            wsc.off_chip_bytes_per_sec() * 8.0 * k::DRAM_ENERGY_PJ_PER_BIT_OFFCHIP * 1e-12
        }
        MemoryKind::Stacking { .. } => {
            n_ret * ret.stack_bytes_per_sec * 8.0 * k::DRAM_ENERGY_PJ_PER_BIT_STACKED * 1e-12
        }
    };

    let leak = n_ret * ret.leak_w;
    ACTIVITY * (mac_w + sram_w + noc_w + ir_w + dram_w) + leak
}

/// Clear the core-geometry memo (test isolation).
pub fn clear_cache() {
    core_cache().clear();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Dataflow;

    fn core() -> CoreConfig {
        CoreConfig {
            dataflow: Dataflow::WS,
            mac_num: 512,
            buffer_kb: 128,
            buffer_bw_bits: 256,
            noc_bw_bits: 512,
        }
    }

    fn reticle() -> ReticleConfig {
        ReticleConfig {
            core: core(),
            array_h: 12,
            array_w: 12,
            inter_reticle_bw_ratio: 1.0,
            memory: MemoryKind::Stacking {
                bw_tbps_per_100mm2: 1.0,
                capacity_gb: 16.0,
            },
        }
    }

    #[test]
    fn core_geom_composes_components() {
        let g = core_geom(&core());
        assert!(g.area_mm2 > 0.3 && g.area_mm2 < 5.0, "area={}", g.area_mm2);
        assert!((g.width_mm * g.height_mm - g.area_mm2).abs() < 1e-9);
        assert!(g.e_mac_pj > 0.0 && g.e_sram_pj_per_bit > 0.0);
    }

    #[test]
    fn core_geom_cached() {
        let a = core_geom(&core());
        let b = core_geom(&core());
        assert_eq!(a, b);
    }

    #[test]
    fn reticle_assembles_with_redundancy() {
        let r = reticle_phys(&reticle(), IntegrationStyle::InfoSoW, 54).unwrap();
        assert_eq!(r.operational_cores(), 144);
        assert!(r.physical_cores() >= 144);
        assert!(r.wafer_yield >= 0.9, "yield={}", r.wafer_yield);
        assert!(r.width_mm <= 33.0 && r.height_mm <= 33.0);
        assert!(r.tsv.tsv_count > 0);
        assert!(r.tsv.stress_utilization <= 1.0);
    }

    #[test]
    fn die_stitching_needs_more_redundancy() {
        let info = reticle_phys(&reticle(), IntegrationStyle::InfoSoW, 54).unwrap();
        let stitch = reticle_phys(&reticle(), IntegrationStyle::DieStitching, 54);
        match stitch {
            Ok(s) => assert!(
                s.red_per_row >= info.red_per_row,
                "stitch={} info={}",
                s.red_per_row,
                info.red_per_row
            ),
            // Or the yield target is simply unreachable — also consistent
            // with the paper's Takeaway 2.
            Err(PhysError::YieldUnreachable { .. }) => {}
            Err(e) => panic!("unexpected: {e}"),
        }
    }

    #[test]
    fn sram_constraint_enforced() {
        let mut r = reticle();
        r.core.buffer_kb = 32;
        r.core.buffer_bw_bits = 4096;
        let e = reticle_phys(&r, IntegrationStyle::InfoSoW, 54).unwrap_err();
        assert!(matches!(e, PhysError::SramInfeasible { .. }));
    }

    #[test]
    fn huge_array_overflows_reticle() {
        let mut r = reticle();
        r.array_h = 40;
        r.array_w = 40;
        let e = reticle_phys(&r, IntegrationStyle::InfoSoW, 54).unwrap_err();
        assert!(matches!(e, PhysError::ReticleOverflow { .. }));
    }

    #[test]
    fn stress_constraint_trips_at_extreme_bandwidth() {
        // Table I's max (4 TB/s/100mm²) is stress-feasible...
        let mut r = reticle();
        r.memory = MemoryKind::Stacking {
            bw_tbps_per_100mm2: 4.0,
            capacity_gb: 8.0,
        };
        let ok = reticle_phys(&r, IntegrationStyle::InfoSoW, 54).unwrap();
        assert!(ok.tsv.stress_utilization <= 1.0);
        // ...but an out-of-range 10 TB/s/100mm² trips the 1.5 % hole cap.
        r.memory = MemoryKind::Stacking {
            bw_tbps_per_100mm2: 10.0,
            capacity_gb: 8.0,
        };
        let e = reticle_phys(&r, IntegrationStyle::InfoSoW, 54).unwrap_err();
        assert!(matches!(e, PhysError::StressViolation { .. }), "got {e}");
    }

    #[test]
    fn wafer_assembly_and_power() {
        let wsc = WscConfig {
            reticle: reticle(),
            reticle_h: 6,
            reticle_w: 6,
            integration: IntegrationStyle::InfoSoW,
            mem_ctrl_count: 16,
            nic_count: 8,
        };
        let w = wafer_phys(&wsc).unwrap();
        assert!(w.peak_flops > 0.0);
        assert!(w.peak_power_w > 100.0, "power={}", w.peak_power_w);
        assert!(w.area_mm2 <= k::WAFER_AREA_MM2);
        assert_eq!(w.wafer_yield, w.reticle.wafer_yield);
    }

    #[test]
    fn wafer_overflow_detected() {
        let wsc = WscConfig {
            reticle: reticle(),
            reticle_h: 20,
            reticle_w: 20,
            integration: IntegrationStyle::InfoSoW,
            mem_ctrl_count: 16,
            nic_count: 8,
        };
        assert!(matches!(
            wafer_phys(&wsc),
            Err(PhysError::WaferOverflow { .. })
        ));
    }
}
