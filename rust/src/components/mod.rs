//! Component Estimator (paper §VI-E): parametric area/power models for WSC
//! basic modules — SRAM macros, MAC arrays, NoC routers, inter-reticle PHYs,
//! TSV fields — plus the [`estimator`] that assembles them into core /
//! reticle / wafer physical characterizations with yield + redundancy
//! resolved. All numbers at the paper's 14 nm reference node
//! ([`crate::arch::constants`]).

pub mod estimator;
pub mod mac;
pub mod noc;
pub mod phy;
pub mod sram;

pub use estimator::{core_geom, reticle_phys, wafer_phys, CoreGeom, PhysError, ReticlePhys, WaferPhys};
