//! Behavioral tests for the cycle-accurate simulator: zero-load latency,
//! contention, saturation shape (the canonical load-latency curve), drain
//! and determinism.

use super::*;
use crate::compiler::routing::NUM_DIRS;

/// Build a bare simulator with hand-written programs.
fn sim(h: usize, w: usize, progs: Vec<Vec<Instr>>) -> Simulator {
    let programs = progs
        .into_iter()
        .map(|instrs| CoreProgram {
            instrs,
            flit_bytes: 64.0, // 512-bit flits
        })
        .collect();
    Simulator::new(h, w, programs)
}

fn idle(n: usize) -> Vec<Vec<Instr>> {
    (0..n).map(|_| Vec::new()).collect()
}

#[test]
fn single_packet_zero_load_latency() {
    // One 4-flit packet from (0,0) to (0,3): hops=3, serialization=4.
    // Inject (1/cycle) + per-hop traversal + ejection — latency must be
    // close to hops + flits, and certainly within 2x.
    let mut progs = idle(16);
    progs[0] = vec![Instr::Send {
        dst: (0, 3),
        bytes: 4.0 * 64.0,
        tag: 0,
    }];
    progs[3] = vec![Instr::Recv { tag: 0, packets: 1 }];
    let stats = sim(4, 4, progs).run(10_000);
    assert_eq!(stats.packets_done, 1);
    let lat = stats.avg_packet_latency();
    assert!(lat >= 5.0, "too fast: {lat}");
    assert!(lat <= 16.0, "too slow: {lat}");
}

#[test]
fn east_links_carry_the_flits() {
    let mut progs = idle(16);
    progs[0] = vec![Instr::Send {
        dst: (0, 3),
        bytes: 8.0 * 64.0,
        tag: 0,
    }];
    progs[3] = vec![Instr::Recv { tag: 0, packets: 1 }];
    let stats = sim(4, 4, progs).run(10_000);
    // Links (0,0)E, (0,1)E, (0,2)E each carried 8 flits.
    for col in 0..3 {
        let idx = (0 * 4 + col) * NUM_DIRS + 0; // East = 0
        assert_eq!(stats.link_flits[idx], 8, "col {col}");
    }
    // No other link carried anything.
    let total: u64 = stats.link_flits.iter().sum();
    assert_eq!(total, 24);
}

#[test]
fn contention_creates_waiting() {
    // Two cores stream to the same destination column through the shared
    // link (1,1)->(1,2): (1,0) and (1,1) both send to (1,3).
    let mut progs = idle(16);
    let big = 64.0 * 64.0; // 64 flits each
    progs[4] = vec![Instr::Send { dst: (1, 3), bytes: big, tag: 0 }];
    progs[5] = vec![Instr::Send { dst: (1, 3), bytes: big, tag: 0 }];
    progs[7] = vec![Instr::Recv { tag: 0, packets: 8 }]; // 64 flits = 4 pkts each
    let stats = sim(4, 4, progs).run(100_000);
    let shared = (1 * 4 + 1) * NUM_DIRS + 0; // (1,1) East
    assert!(stats.link_flits[shared] >= 128);
    assert!(
        stats.link_wait[shared] > 0,
        "shared link should record waiting"
    );
}

#[test]
fn no_contention_no_waiting() {
    // Disjoint row flows: no link shared, waiting stays ~0.
    let mut progs = idle(16);
    progs[0] = vec![Instr::Send { dst: (0, 3), bytes: 32.0 * 64.0, tag: 0 }];
    progs[4] = vec![Instr::Send { dst: (1, 3), bytes: 32.0 * 64.0, tag: 0 }];
    progs[3] = vec![Instr::Recv { tag: 0, packets: 2 }];
    progs[7] = vec![Instr::Recv { tag: 0, packets: 2 }];
    let stats = sim(4, 4, progs).run(100_000);
    let total_wait: u64 = stats.link_wait.iter().sum();
    assert_eq!(total_wait, 0, "disjoint flows must not wait");
}

#[test]
fn compute_serializes_with_recv() {
    // (0,1) waits for a packet, computes 100 cycles; total cycles must
    // exceed 100 + transfer.
    let mut progs = idle(4);
    progs[0] = vec![Instr::Send { dst: (0, 1), bytes: 64.0, tag: 0 }];
    progs[1] = vec![
        Instr::Recv { tag: 0, packets: 1 },
        Instr::Compute { cycles: 100 },
    ];
    let stats = sim(2, 2, progs).run(10_000);
    assert!(stats.cycles >= 100, "cycles={}", stats.cycles);
    assert!(stats.cycles < 200, "cycles={}", stats.cycles);
}

#[test]
fn deterministic_runs() {
    let mk = || {
        let mut progs = idle(16);
        for i in 0..8 {
            progs[i] = vec![Instr::Send {
                dst: (3, 3 - (i % 4)),
                bytes: (i as f64 + 1.0) * 200.0,
                tag: 0,
            }];
        }
        progs[15] = vec![Instr::Recv { tag: 0, packets: 1 }];
        sim(4, 4, progs).run(1_000_000)
    };
    let a = mk();
    let b = mk();
    assert_eq!(a.cycles, b.cycles);
    assert_eq!(a.link_flits, b.link_flits);
    assert_eq!(a.link_wait, b.link_wait);
}

#[test]
fn load_latency_curve_saturates() {
    // Uniform-random traffic at increasing load: average packet latency
    // must rise monotonically-ish and blow up near saturation — the
    // canonical NoC load-latency shape that validates the router model.
    let mut latencies = Vec::new();
    for &npkts in &[2usize, 8, 24] {
        let mut rng = crate::util::rng::Rng::new(5);
        let h = 4;
        let w = 4;
        let mut progs = idle(h * w);
        let mut expected = vec![0u32; h * w];
        for core in 0..h * w {
            for _ in 0..npkts {
                let dst = (rng.below(h), rng.below(w));
                let dst_core = dst.0 * w + dst.1;
                if dst_core == core {
                    continue;
                }
                progs[core].push(Instr::Send {
                    dst,
                    bytes: 4.0 * 64.0,
                    tag: 0,
                });
                expected[dst_core] += 1;
            }
        }
        for core in 0..h * w {
            if expected[core] > 0 {
                progs[core].push(Instr::Recv {
                    tag: 0,
                    packets: expected[core],
                });
            }
        }
        let stats = sim(h, w, progs).run(10_000_000);
        latencies.push(stats.avg_packet_latency());
    }
    assert!(
        latencies[2] > latencies[0],
        "latency must grow with load: {latencies:?}"
    );
}

#[test]
fn chunk_simulation_end_to_end() {
    use crate::arch::{CoreConfig, Dataflow};
    use crate::compiler::compile_chunk;
    use crate::workload::models::benchmarks;
    use crate::workload::{OpGraph, Phase};

    let mut spec = benchmarks()[0].clone();
    spec.seq_len = 32;
    let g = OpGraph::transformer_chunk(&spec, 1, 1, 8, Phase::Prefill, false);
    let core = CoreConfig {
        dataflow: Dataflow::WS,
        mac_num: 512,
        buffer_kb: 128,
        buffer_bw_bits: 256,
        noc_bw_bits: 512,
    };
    let chunk = compile_chunk(&g, 4, 4, &core);
    let stats = simulate_chunk(
        &chunk,
        512,
        &|op| naive_compute_cycles(chunk.assignments[op].flops_per_core, 512),
        80_000_000,
    );
    assert!(stats.cycles > 0);
    assert!(stats.packets_done > 0);
    // Compute must dominate at this scale: cycles >= the largest op tile.
    let max_compute = chunk
        .assignments
        .iter()
        .map(|a| naive_compute_cycles(a.flops_per_core, 512))
        .max()
        .unwrap();
    assert!(stats.cycles >= max_compute);
}
