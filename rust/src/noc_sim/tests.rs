//! Behavioral tests for the cycle-accurate simulator: zero-load latency,
//! contention, saturation shape (the canonical load-latency curve), drain
//! and determinism — plus the reference-oracle equivalence suite proving
//! the event-driven engine bit-identical to the frozen per-cycle stepper.

use super::*;
use crate::compiler::routing::NUM_DIRS;

/// Build a bare simulator with hand-written programs.
fn sim(h: usize, w: usize, progs: Vec<Vec<Instr>>) -> Simulator {
    let programs = progs
        .into_iter()
        .map(|instrs| CoreProgram {
            instrs,
            flit_bytes: 64.0, // 512-bit flits
        })
        .collect();
    Simulator::new(h, w, programs)
}

fn idle(n: usize) -> Vec<Vec<Instr>> {
    (0..n).map(|_| Vec::new()).collect()
}

#[test]
fn single_packet_zero_load_latency() {
    // One 4-flit packet from (0,0) to (0,3): hops=3, serialization=4.
    // Inject (1/cycle) + per-hop traversal + ejection — latency must be
    // close to hops + flits, and certainly within 2x.
    let mut progs = idle(16);
    progs[0] = vec![Instr::Send {
        dst: (0, 3),
        bytes: 4.0 * 64.0,
        tag: 0,
    }];
    progs[3] = vec![Instr::Recv { tag: 0, packets: 1 }];
    let stats = sim(4, 4, progs).try_run(10_000).expect("completes within budget");
    assert_eq!(stats.packets_done, 1);
    let lat = stats.avg_packet_latency();
    assert!(lat >= 5.0, "too fast: {lat}");
    assert!(lat <= 16.0, "too slow: {lat}");
}

#[test]
fn east_links_carry_the_flits() {
    let mut progs = idle(16);
    progs[0] = vec![Instr::Send {
        dst: (0, 3),
        bytes: 8.0 * 64.0,
        tag: 0,
    }];
    progs[3] = vec![Instr::Recv { tag: 0, packets: 1 }];
    let stats = sim(4, 4, progs).try_run(10_000).expect("completes within budget");
    // Links (0,0)E, (0,1)E, (0,2)E each carried 8 flits.
    for col in 0..3 {
        let idx = (0 * 4 + col) * NUM_DIRS + 0; // East = 0
        assert_eq!(stats.link_flits[idx], 8, "col {col}");
    }
    // No other link carried anything.
    let total: u64 = stats.link_flits.iter().sum();
    assert_eq!(total, 24);
}

#[test]
fn contention_creates_waiting() {
    // Two cores stream to the same destination column through the shared
    // link (1,1)->(1,2): (1,0) and (1,1) both send to (1,3).
    let mut progs = idle(16);
    let big = 64.0 * 64.0; // 64 flits each
    progs[4] = vec![Instr::Send { dst: (1, 3), bytes: big, tag: 0 }];
    progs[5] = vec![Instr::Send { dst: (1, 3), bytes: big, tag: 0 }];
    progs[7] = vec![Instr::Recv { tag: 0, packets: 8 }]; // 64 flits = 4 pkts each
    let stats = sim(4, 4, progs).try_run(100_000).expect("completes within budget");
    let shared = (1 * 4 + 1) * NUM_DIRS + 0; // (1,1) East
    assert!(stats.link_flits[shared] >= 128);
    assert!(
        stats.link_wait[shared] > 0,
        "shared link should record waiting"
    );
}

#[test]
fn no_contention_no_waiting() {
    // Disjoint row flows: no link shared, waiting stays ~0.
    let mut progs = idle(16);
    progs[0] = vec![Instr::Send { dst: (0, 3), bytes: 32.0 * 64.0, tag: 0 }];
    progs[4] = vec![Instr::Send { dst: (1, 3), bytes: 32.0 * 64.0, tag: 0 }];
    progs[3] = vec![Instr::Recv { tag: 0, packets: 2 }];
    progs[7] = vec![Instr::Recv { tag: 0, packets: 2 }];
    let stats = sim(4, 4, progs).try_run(100_000).expect("completes within budget");
    let total_wait: u64 = stats.link_wait.iter().sum();
    assert_eq!(total_wait, 0, "disjoint flows must not wait");
}

#[test]
fn compute_serializes_with_recv() {
    // (0,1) waits for a packet, computes 100 cycles; total cycles must
    // exceed 100 + transfer.
    let mut progs = idle(4);
    progs[0] = vec![Instr::Send { dst: (0, 1), bytes: 64.0, tag: 0 }];
    progs[1] = vec![
        Instr::Recv { tag: 0, packets: 1 },
        Instr::Compute { cycles: 100 },
    ];
    let stats = sim(2, 2, progs).try_run(10_000).expect("completes within budget");
    assert!(stats.cycles >= 100, "cycles={}", stats.cycles);
    assert!(stats.cycles < 200, "cycles={}", stats.cycles);
}

#[test]
fn deterministic_runs() {
    let mk = || {
        let mut progs = idle(16);
        for i in 0..8 {
            progs[i] = vec![Instr::Send {
                dst: (3, 3 - (i % 4)),
                bytes: (i as f64 + 1.0) * 200.0,
                tag: 0,
            }];
        }
        progs[15] = vec![Instr::Recv { tag: 0, packets: 1 }];
        sim(4, 4, progs)
            .try_run(1_000_000)
            .expect("completes within budget")
    };
    let a = mk();
    let b = mk();
    assert_eq!(a.cycles, b.cycles);
    assert_eq!(a.link_flits, b.link_flits);
    assert_eq!(a.link_wait, b.link_wait);
}

#[test]
fn load_latency_curve_saturates() {
    // Uniform-random traffic at increasing load: average packet latency
    // must rise monotonically-ish and blow up near saturation — the
    // canonical NoC load-latency shape that validates the router model.
    let mut latencies = Vec::new();
    for &npkts in &[2usize, 8, 24] {
        let mut rng = crate::util::rng::Rng::new(5);
        let h = 4;
        let w = 4;
        let mut progs = idle(h * w);
        let mut expected = vec![0u32; h * w];
        for core in 0..h * w {
            for _ in 0..npkts {
                let dst = (rng.below(h), rng.below(w));
                let dst_core = dst.0 * w + dst.1;
                if dst_core == core {
                    continue;
                }
                progs[core].push(Instr::Send {
                    dst,
                    bytes: 4.0 * 64.0,
                    tag: 0,
                });
                expected[dst_core] += 1;
            }
        }
        for core in 0..h * w {
            if expected[core] > 0 {
                progs[core].push(Instr::Recv {
                    tag: 0,
                    packets: expected[core],
                });
            }
        }
        let stats = sim(h, w, progs)
            .try_run(10_000_000)
            .expect("completes within budget");
        latencies.push(stats.avg_packet_latency());
    }
    assert!(
        latencies[2] > latencies[0],
        "latency must grow with load: {latencies:?}"
    );
}

#[test]
fn deadlock_returns_bounded_error() {
    // A RECV whose packets are never sent: certain deadlock. try_run must
    // return (not panic) with a diagnostic that stays small even though it
    // describes the whole stuck state.
    let mut progs = idle(4);
    progs[0] = vec![Instr::Recv { tag: 0, packets: 1 }];
    let err = sim(2, 2, progs).try_run(10_000).unwrap_err();
    assert!(err.deadlock, "no pending events -> deadlock");
    assert!(err.cycle > 10_000);
    assert_eq!(err.unfinished_cores, 1);
    assert_eq!(err.sample_blocked, vec![(0, 0)]);
    assert!(err.sample_stuck.is_empty(), "network is drained");
    let msg = err.to_string();
    assert!(msg.len() < 1000, "diagnostic must stay bounded: {} bytes", msg.len());
}

#[test]
fn undersized_budget_is_error_not_hang() {
    // Live traffic with a far-too-small budget: the error reports in-flight
    // state (not a deadlock) and bounded samples.
    let mut progs = idle(16);
    progs[0] = vec![Instr::Send { dst: (3, 3), bytes: 64.0 * 64.0, tag: 0 }];
    progs[15] = vec![Instr::Recv { tag: 0, packets: 4 }];
    let err = sim(4, 4, progs).try_run(3).unwrap_err();
    assert!(!err.deadlock, "traffic was still moving");
    assert!(err.flits_in_network > 0 || err.nic_backlog > 0);
    assert!(err.sample_stuck.len() <= SimError::MAX_DIAG);
    assert!(err.sample_blocked.len() <= SimError::MAX_DIAG);
}

/// Reference-oracle equivalence: the event-driven engine must produce
/// bit-identical [`SimStats`] to [`reference::Simulator`] on every program
/// that completes within budget (module docs: the reference-oracle
/// contract).
mod equivalence {
    use super::super::program::{packets_for, validate_programs};
    use super::super::*;
    use crate::util::rng::Rng;
    use std::collections::HashMap;

    fn programs_of(progs: &[Vec<Instr>]) -> Vec<CoreProgram> {
        progs
            .iter()
            .map(|instrs| CoreProgram {
                instrs: instrs.clone(),
                flit_bytes: 64.0, // 512-bit flits
            })
            .collect()
    }

    /// Run both engines on the same programs; both must complete. (The
    /// frozen oracle keeps its legacy panicking `run`; the event engine
    /// propagates the budget overrun as `SimError`.)
    fn run_both(h: usize, w: usize, progs: &[Vec<Instr>], budget: u64) -> (SimStats, SimStats) {
        let ev = Simulator::new(h, w, programs_of(progs))
            .try_run(budget)
            .expect("event engine completes within budget");
        let rf = reference::Simulator::new(h, w, programs_of(progs)).run(budget);
        (ev, rf)
    }

    /// Random terminating workload: flows with random sizes and tags,
    /// computes interleaved before sends and after receives. All of a
    /// core's receives are sequenced after its sends, so the only blocking
    /// is network-side — no instruction-ordering deadlocks. `congested`
    /// funnels every flow into one hotspot core.
    fn random_programs(rng: &mut Rng, h: usize, w: usize, congested: bool) -> Vec<Vec<Instr>> {
        let n = h * w;
        let mut progs: Vec<Vec<Instr>> = vec![Vec::new(); n];
        let mut expected: HashMap<(usize, u32), u32> = HashMap::new();
        let n_flows = rng.range(3, (2 * n).max(4));
        let hotspot = rng.below(n);
        for fi in 0..n_flows {
            let src = rng.below(n);
            let dst = if congested { hotspot } else { rng.below(n) };
            if dst == src {
                continue;
            }
            let bytes = rng.uniform(1.0, 64.0 * 40.0); // up to ~40 flits
            let tag = (fi % 3) as u32;
            if rng.bool(0.5) {
                progs[src].push(Instr::Compute {
                    cycles: rng.range(1, 200) as u64,
                });
            }
            progs[src].push(Instr::Send {
                dst: (dst / w, dst % w),
                bytes,
                tag,
            });
            *expected.entry((dst, tag)).or_default() += packets_for(bytes, 64.0);
        }
        // Receives after all sends, sorted by tag for determinism.
        let mut by_core: Vec<Vec<(u32, u32)>> = vec![Vec::new(); n];
        for (&(core, tag), &pkts) in &expected {
            by_core[core].push((tag, pkts));
        }
        for core in 0..n {
            by_core[core].sort_unstable();
            for &(tag, pkts) in &by_core[core] {
                progs[core].push(Instr::Recv { tag, packets: pkts });
            }
            if rng.bool(0.3) {
                progs[core].push(Instr::Compute {
                    cycles: rng.range(1, 50) as u64,
                });
            }
        }
        progs
    }

    #[test]
    fn randomized_equivalence_vs_reference() {
        // >= 20 randomized meshes/programs, congestion included (every
        // third seed funnels all flows into one hotspot).
        for seed in 0..24u64 {
            let mut rng = Rng::new(1000 + seed);
            let h = rng.range(2, 6);
            let w = rng.range(2, 6);
            let congested = seed % 3 == 0;
            let progs = random_programs(&mut rng, h, w, congested);
            validate_programs(&programs_of(&progs), h, w).expect("generator soundness");
            let (ev, rf) = run_both(h, w, &progs, 2_000_000);
            assert_eq!(ev, rf, "seed {seed} ({h}x{w}, congested={congested})");
        }
    }

    #[test]
    fn randomized_equivalence_on_faulty_meshes() {
        // The reference-oracle contract extends to irregular (faulty)
        // meshes: random dead cores + dead links, random flows between
        // live cores routed through the shared fault-aware table — both
        // engines must stay bit-identical. Disconnected samples are
        // skipped (FaultTopo::new rejects them loudly by design).
        use crate::compiler::FaultTopo;
        use crate::yield_model::faults::FaultMap;
        let mut done = 0u32;
        let mut attempt = 0u64;
        while done < 12 {
            attempt += 1;
            assert!(attempt < 200, "too many disconnected samples");
            let mut rng = Rng::new(7000 + attempt);
            let h = rng.range(3, 7);
            let w = rng.range(3, 7);
            let mut map = FaultMap::pristine(h, w);
            for _ in 0..rng.range(1, 4) {
                map.kill_core(rng.below(h), rng.below(w));
            }
            for _ in 0..rng.range(1, 4) {
                map.kill_link(rng.below(h), rng.below(w), rng.below(4));
            }
            let Ok(topo) = FaultTopo::new(map) else {
                continue; // partitioned sample — covered by routing tests
            };
            let live: Vec<usize> = topo
                .core_map
                .physical_cores()
                .iter()
                .map(|&(r, c)| r * w + c)
                .collect();
            if live.len() < 2 {
                continue;
            }
            let n = h * w;
            let mut progs: Vec<Vec<Instr>> = vec![Vec::new(); n];
            let mut expected: HashMap<(usize, u32), u32> = HashMap::new();
            let n_flows = rng.range(3, 2 * live.len());
            for fi in 0..n_flows {
                let src = live[rng.below(live.len())];
                let dst = live[rng.below(live.len())];
                if src == dst {
                    continue;
                }
                let bytes = rng.uniform(1.0, 64.0 * 24.0);
                let tag = (fi % 3) as u32;
                progs[src].push(Instr::Send {
                    dst: (dst / w, dst % w),
                    bytes,
                    tag,
                });
                *expected.entry((dst, tag)).or_default() += packets_for(bytes, 64.0);
            }
            let mut by_core: Vec<Vec<(u32, u32)>> = vec![Vec::new(); n];
            for (&(core, tag), &pkts) in &expected {
                by_core[core].push((tag, pkts));
            }
            for core in 0..n {
                by_core[core].sort_unstable();
                for &(tag, pkts) in &by_core[core] {
                    progs[core].push(Instr::Recv { tag, packets: pkts });
                }
            }
            let ev = Simulator::with_table(h, w, programs_of(&progs), Some(topo.table.clone()))
                .try_run(2_000_000)
                .expect("event engine completes within budget");
            let rf = reference::Simulator::with_table(
                h,
                w,
                programs_of(&progs),
                Some(topo.table.clone()),
            )
            .run(2_000_000);
            assert_eq!(ev, rf, "attempt {attempt} ({h}x{w} faulty mesh)");
            done += 1;
        }
    }

    #[test]
    fn faulted_compiled_chunk_equivalence() {
        // End-to-end on the production path: a chunk compiled onto a
        // degraded mesh, simulated by both engines through the table the
        // chunk carries — and simulate_chunk_result must pick that table
        // up by itself.
        use crate::arch::{CoreConfig, Dataflow};
        use crate::compiler::{compile_chunk_faulted, FaultTopo};
        use crate::workload::models::benchmarks;
        use crate::workload::{OpGraph, Phase};
        use crate::yield_model::faults::FaultMap;
        use std::sync::Arc;
        let mut spec = benchmarks()[0].clone();
        spec.seq_len = 32;
        let g = OpGraph::transformer_chunk(&spec, 1, 1, 8, Phase::Prefill, false);
        let core = CoreConfig {
            dataflow: Dataflow::WS,
            mac_num: 512,
            buffer_kb: 128,
            buffer_bw_bits: 256,
            noc_bw_bits: 512,
        };
        let mut map = FaultMap::pristine(4, 4);
        map.kill_core(1, 2);
        map.kill_link(2, 1, 0); // East
        let topo = Arc::new(FaultTopo::new(map).expect("mesh stays connected"));
        let chunk = compile_chunk_faulted(&g, &core, topo.clone());
        let cycles = |op: usize| naive_compute_cycles(chunk.assignments[op].flops_per_core, 512);
        let programs = build_programs(&chunk, 512, &cycles);
        let ev = Simulator::with_table(4, 4, programs.clone(), Some(topo.table.clone()))
            .try_run(200_000_000)
            .expect("completes within budget");
        let rf = reference::Simulator::with_table(4, 4, programs, Some(topo.table.clone()))
            .run(200_000_000);
        assert_eq!(ev, rf, "faulted chunk diverged from the oracle");
        let via_chunk = simulate_chunk_result(&chunk, 512, &cycles, 200_000_000)
            .expect("completes within budget");
        assert_eq!(via_chunk, ev, "simulate_chunk_result must ride the chunk's table");
    }

    #[test]
    fn pipeline_chain_equivalence() {
        // Recv-then-send forwarding chain along a row: exercises dormant
        // cores woken by ejections, with computes between hops. This is the
        // pattern the old all-or-nothing skip could never fast-forward
        // (always at least one core blocked on RECV).
        let (h, w) = (3, 5);
        let bytes = 64.0 * 24.0;
        let pkts = packets_for(bytes, 64.0);
        let mut progs: Vec<Vec<Instr>> = vec![Vec::new(); h * w];
        progs[0] = vec![
            Instr::Compute { cycles: 10 },
            Instr::Send { dst: (0, 1), bytes, tag: 0 },
        ];
        for c in 1..w - 1 {
            progs[c] = vec![
                Instr::Recv { tag: 0, packets: pkts },
                Instr::Compute { cycles: 37 },
                Instr::Send { dst: (0, c + 1), bytes, tag: 0 },
            ];
        }
        progs[w - 1] = vec![
            Instr::Recv { tag: 0, packets: pkts },
            Instr::Compute { cycles: 5 },
        ];
        let (ev, rf) = run_both(h, w, &progs, 1_000_000);
        assert_eq!(ev, rf);
        assert_eq!(ev.packets_done as u32, pkts * (w as u32 - 1));
    }

    #[test]
    fn compiled_chunk_equivalence() {
        // The GNN-label path: real compiled chunks through build_programs.
        use crate::arch::{CoreConfig, Dataflow};
        use crate::compiler::compile_chunk;
        use crate::workload::models::benchmarks;
        use crate::workload::{OpGraph, Phase};
        let fast = crate::util::cli::env_flag("THESEUS_TEST_FAST");
        let cases: &[(usize, usize, usize)] = if fast {
            &[(32, 3, 256)]
        } else {
            &[(32, 3, 256), (32, 4, 512)]
        };
        for &(seq, region, bw) in cases {
            let mut spec = benchmarks()[0].clone();
            spec.seq_len = seq;
            let g = OpGraph::transformer_chunk(&spec, 1, 1, 8, Phase::Prefill, false);
            let core = CoreConfig {
                dataflow: Dataflow::WS,
                mac_num: 512,
                buffer_kb: 128,
                buffer_bw_bits: 256,
                noc_bw_bits: bw,
            };
            let chunk = compile_chunk(&g, region, region, &core);
            let programs = build_programs(&chunk, bw, &|op| {
                naive_compute_cycles(chunk.assignments[op].flops_per_core, 512)
            });
            let ev = Simulator::new(chunk.region_h, chunk.region_w, programs.clone())
                .try_run(200_000_000)
                .expect("completes within budget");
            let rf = reference::Simulator::new(chunk.region_h, chunk.region_w, programs)
                .run(200_000_000);
            assert_eq!(ev, rf, "chunk seq={seq} region={region} bw={bw}");
        }
    }

    #[test]
    fn dense_fallback_equivalence_crosses_threshold_mid_run() {
        // ROADMAP carry-over: the dense-mode switch fallback. Phase 1
        // (sparse) trickles one flow across an otherwise idle mesh while
        // every other core sits in a long COMPUTE; phase 2 (dense) floods
        // a hotspot from all cores at once, pushing the active-router
        // count past half the mesh; the drain then falls back below it.
        // Stats must stay bit-identical to the reference oracle across
        // both regime flips, and both regimes must actually have been
        // visited by the event-driven engine.
        let (h, w) = (4usize, 4usize);
        let n = h * w;
        let hotspot = (h / 2, w / 2);
        let hot_core = hotspot.0 * w + hotspot.1;
        let trickle_bytes = 8.0 * 64.0; // 8 flits = 1 packet
        let flood_bytes = 16.0 * 64.0; // 16 flits = 1 max-size packet
        let mut progs: Vec<Vec<Instr>> = vec![Vec::new(); n];
        // Sparse prelude: corner-to-corner trickle.
        progs[0].push(Instr::Send { dst: (h - 1, w - 1), bytes: trickle_bytes, tag: 1 });
        let mut flood_pkts = 0u32;
        for core in 0..n {
            if core == hot_core {
                continue;
            }
            // The compute keeps the mesh sparse while the trickle crosses
            // it, then every core releases its flood on the same cycle.
            progs[core].push(Instr::Compute { cycles: 400 });
            for _ in 0..4 {
                progs[core].push(Instr::Send { dst: hotspot, bytes: flood_bytes, tag: 0 });
                flood_pkts += packets_for(flood_bytes, 64.0);
            }
        }
        progs[hot_core].push(Instr::Recv { tag: 0, packets: flood_pkts });
        progs[n - 1].push(Instr::Recv {
            tag: 1,
            packets: packets_for(trickle_bytes, 64.0),
        });
        validate_programs(&programs_of(&progs), h, w).expect("generator soundness");

        reset_switch_regimes();
        let ev = Simulator::new(h, w, programs_of(&progs))
            .try_run(5_000_000)
            .expect("completes within budget");
        let (dense, sparse) = switch_regimes();
        assert!(dense > 0, "flood never reached the dense flat-sweep regime");
        assert!(sparse > 0, "prelude never used the sparse active-list regime");

        let rf = reference::Simulator::new(h, w, programs_of(&progs)).run(5_000_000);
        assert_eq!(ev, rf, "dense fallback diverged from the reference oracle");
    }

    #[test]
    fn event_driven_sparse_fast_path_speedup() {
        // Mostly-idle mesh: one corner-to-corner exchange with long compute
        // gaps while every other core idles. The reference stepper pays
        // O(cores) per cycle (and cannot fast-forward: the receiver is
        // blocked on RECV, not COMPUTE); the event-driven engine must be
        // >= 5x faster (the ISSUE 2 acceptance floor — the algorithmic gap
        // is far larger, so this is not timing-sensitive).
        let side = if crate::util::cli::env_flag("THESEUS_TEST_FAST") { 24 } else { 32 };
        let (h, w) = (side, side);
        let rounds = 24u32;
        let bytes = 16.0 * 64.0; // one max-size packet per send
        let mut progs: Vec<Vec<Instr>> = vec![Vec::new(); h * w];
        let mut tx = Vec::new();
        for _ in 0..rounds {
            tx.push(Instr::Compute { cycles: 200 });
            tx.push(Instr::Send { dst: (h - 1, w - 1), bytes, tag: 0 });
        }
        progs[0] = tx;
        progs[h * w - 1] = vec![Instr::Recv { tag: 0, packets: rounds }];

        let budget = 10_000_000;
        // Best-of-3 per engine: the event run is sub-millisecond, so a
        // single scheduler preemption could otherwise inflate it; the min
        // is the noise-robust estimate of true cost.
        let best_of = |f: &dyn Fn() -> SimStats| -> (SimStats, f64) {
            let mut best = f64::INFINITY;
            let mut out = None;
            for _ in 0..3 {
                let t0 = std::time::Instant::now();
                let stats = f();
                best = best.min(t0.elapsed().as_secs_f64());
                out = Some(stats);
            }
            (out.unwrap(), best)
        };
        let (ev, t_event) = best_of(&|| {
            Simulator::new(h, w, programs_of(&progs))
                .try_run(budget)
                .expect("completes within budget")
        });
        let (rf, t_ref) =
            best_of(&|| reference::Simulator::new(h, w, programs_of(&progs)).run(budget));
        assert_eq!(ev, rf);
        let speedup = t_ref / t_event.max(1e-9);
        assert!(
            speedup >= 5.0,
            "sparse fast path only {speedup:.1}x (event {t_event:.5}s vs reference {t_ref:.5}s)"
        );
    }
}

#[test]
fn chunk_simulation_end_to_end() {
    use crate::arch::{CoreConfig, Dataflow};
    use crate::compiler::compile_chunk;
    use crate::workload::models::benchmarks;
    use crate::workload::{OpGraph, Phase};

    let mut spec = benchmarks()[0].clone();
    spec.seq_len = 32;
    let g = OpGraph::transformer_chunk(&spec, 1, 1, 8, Phase::Prefill, false);
    let core = CoreConfig {
        dataflow: Dataflow::WS,
        mac_num: 512,
        buffer_kb: 128,
        buffer_bw_bits: 256,
        noc_bw_bits: 512,
    };
    let chunk = compile_chunk(&g, 4, 4, &core);
    let stats = simulate_chunk_result(
        &chunk,
        512,
        &|op| naive_compute_cycles(chunk.assignments[op].flops_per_core, 512),
        80_000_000,
    )
    .expect("completes within budget");
    assert!(stats.cycles > 0);
    assert!(stats.packets_done > 0);
    // Compute must dominate at this scale: cycles >= the largest op tile.
    let max_compute = chunk
        .assignments
        .iter()
        .map(|a| naive_compute_cycles(a.flops_per_core, 512))
        .max()
        .unwrap();
    assert!(stats.cycles >= max_compute);
}
