//! Cycle-accurate NoC simulator (paper §VIII-A: BookSim2 [22] extended with
//! instruction-driven cores).
//!
//! 2-D mesh, wormhole flow control, 8 virtual channels × 4-flit buffers per
//! input port (§VIII-A router config), credit-based backpressure, XY
//! routing, round-robin switch allocation. Cores execute micro-instruction
//! streams (COMPUTE / SEND / RECV) generated from a [`CompiledChunk`] —
//! compute and memory latency inside cores use analytical estimates, as the
//! paper argues is sound for regular tensor operations.
//!
//! The simulator doubles as the ground-truth generator: per-link mean
//! waiting times ([`SimStats::link_wait`]) are the GNN's regression targets
//! (Eq. 5), and end-to-end chunk cycles validate the analytical model
//! (Fig. 7).

pub mod dataset;
pub mod program;

use std::collections::VecDeque;

use crate::arch::constants as k;
use crate::compiler::routing::{Dir, LinkId, NUM_DIRS};

pub use program::{build_programs, CoreProgram, Instr};

/// Ports on a router: 4 mesh directions + local (NIC).
const PORTS: usize = 5;
const LOCAL: usize = 4;

/// Buffer depth per VC (flits) — paper §VIII-A.
const VC_DEPTH: usize = 4;
/// Virtual channels per input port — paper §VIII-A.
const VCS: usize = 8;

/// Max packet size in flits; larger transfers are segmented (§VI-C's
/// variable packet sizes come from the flow byte volumes).
pub const MAX_PACKET_FLITS: usize = 16;

/// A packet in flight.
#[derive(Debug, Clone, Copy)]
struct Packet {
    dst: (usize, usize),
    size_flits: u32,
    /// Tag = consuming op id (RECV matching).
    tag: u32,
    inject_cycle: u64,
}

/// One flit. Packets are wormhole-switched: body flits follow the head's
/// VC/port allocation.
#[derive(Debug, Clone, Copy)]
struct Flit {
    packet: u32,
    is_head: bool,
    is_tail: bool,
}

/// Per-input-VC state.
#[derive(Debug, Clone, Default)]
struct VcState {
    buf: VecDeque<Flit>,
    /// Allocated output port for the packet currently occupying this VC.
    out_port: Option<u8>,
    /// Allocated VC at the downstream router's input.
    out_vc: Option<u8>,
}

/// One router: input-buffered, 5 ports × VCS VCs.
#[derive(Debug, Clone)]
struct Router {
    vcs: Vec<VcState>, // PORTS * VCS
    /// Credits we hold for each downstream input VC, per output direction.
    credits: [[u8; VCS]; NUM_DIRS],
    /// Round-robin pointers per output port.
    rr: [usize; PORTS],
    /// Buffered flits across all input VCs (§Perf: lets the switch pass
    /// skip idle routers entirely).
    occupancy: u32,
}

impl Router {
    fn new() -> Router {
        Router {
            vcs: (0..PORTS * VCS).map(|_| VcState::default()).collect(),
            credits: [[VC_DEPTH as u8; VCS]; NUM_DIRS],
            rr: [0; PORTS],
            occupancy: 0,
        }
    }

    fn vc(&self, port: usize, vc: usize) -> &VcState {
        &self.vcs[port * VCS + vc]
    }

    fn vc_mut(&mut self, port: usize, vc: usize) -> &mut VcState {
        &mut self.vcs[port * VCS + vc]
    }
}

/// Aggregate simulation statistics.
#[derive(Debug, Clone, Default)]
pub struct SimStats {
    /// Total simulated cycles until drain.
    pub cycles: u64,
    /// Flits that crossed each directed mesh link (dense, `link_index`).
    pub link_flits: Vec<u64>,
    /// Cycles head-of-line flits spent blocked wanting each link.
    pub link_wait: Vec<u64>,
    /// Completed packets and their total latency (inject→eject).
    pub packets_done: u64,
    pub packet_latency_sum: u64,
    /// Per-core injected flits (GNN node feature).
    pub injected_flits: Vec<u64>,
}

impl SimStats {
    /// Mean waiting time per link (the Eq. 5 target); 0 for idle links.
    pub fn link_wait_mean(&self) -> Vec<f64> {
        self.link_flits
            .iter()
            .zip(&self.link_wait)
            .map(|(&f, &w)| if f == 0 { 0.0 } else { w as f64 / f as f64 })
            .collect()
    }

    pub fn avg_packet_latency(&self) -> f64 {
        if self.packets_done == 0 {
            0.0
        } else {
            self.packet_latency_sum as f64 / self.packets_done as f64
        }
    }
}

/// Instruction-driven mesh simulator.
pub struct Simulator {
    pub height: usize,
    pub width: usize,
    routers: Vec<Router>,
    packets: Vec<Packet>,
    programs: Vec<CoreProgram>,
    /// Per-core program counter and state.
    pc: Vec<usize>,
    compute_until: Vec<u64>,
    recv_count: Vec<Vec<u32>>, // [core][tag] received packets
    nic: Vec<VecDeque<(u32, u64)>>, // queued (packet, reserved)
    nic_flits_left: Vec<u32>,
    /// VC on which the current NIC packet is being injected.
    inject_vc: Vec<usize>,
    stats: SimStats,
    cycle: u64,
}

impl Simulator {
    /// Build a simulator for an `height × width` mesh running `programs`
    /// (one per core, row-major; see [`program::build_programs`]).
    pub fn new(height: usize, width: usize, programs: Vec<CoreProgram>) -> Simulator {
        assert_eq!(programs.len(), height * width);
        let n = height * width;
        let max_tag = programs
            .iter()
            .flat_map(|p| p.instrs.iter())
            .map(|i| match i {
                Instr::Recv { tag, .. } => *tag + 1,
                Instr::Send { tag, .. } => *tag + 1,
                _ => 0,
            })
            .max()
            .unwrap_or(1) as usize;
        Simulator {
            height,
            width,
            routers: (0..n).map(|_| Router::new()).collect(),
            packets: Vec::new(),
            programs,
            pc: vec![0; n],
            compute_until: vec![0; n],
            recv_count: vec![vec![0; max_tag]; n],
            nic: (0..n).map(|_| VecDeque::new()).collect(),
            nic_flits_left: vec![0; n],
            inject_vc: vec![0; n],
            stats: SimStats {
                link_flits: vec![0; n * NUM_DIRS],
                link_wait: vec![0; n * NUM_DIRS],
                injected_flits: vec![0; n],
                ..Default::default()
            },
            cycle: 0,
        }
    }

    fn node(&self, r: usize, c: usize) -> usize {
        r * self.width + c
    }

    /// XY output port for a packet at router (r, c).
    fn route(&self, at: (usize, usize), dst: (usize, usize)) -> usize {
        if dst.1 > at.1 {
            Dir::East as usize
        } else if dst.1 < at.1 {
            Dir::West as usize
        } else if dst.0 > at.0 {
            Dir::South as usize
        } else if dst.0 < at.0 {
            Dir::North as usize
        } else {
            LOCAL
        }
    }

    fn link_idx(&self, node: usize, dir: usize) -> usize {
        node * NUM_DIRS + dir
    }

    /// Neighbor node through `dir`, plus the input port on that neighbor.
    fn neighbor(&self, node: usize, dir: usize) -> (usize, usize) {
        let (r, c) = (node / self.width, node % self.width);
        match dir {
            d if d == Dir::East as usize => (self.node(r, c + 1), Dir::West as usize),
            d if d == Dir::West as usize => (self.node(r, c - 1), Dir::East as usize),
            d if d == Dir::South as usize => (self.node(r + 1, c), Dir::North as usize),
            d if d == Dir::North as usize => (self.node(r - 1, c), Dir::South as usize),
            _ => unreachable!(),
        }
    }

    /// Run to completion (all programs finished, network drained).
    /// `max_cycles` guards against deadlock bugs; panics if exceeded.
    pub fn run(mut self, max_cycles: u64) -> SimStats {
        while !self.done() {
            self.step();
            if self.cycle > max_cycles {
                let mut buf_state = String::new();
                for (n, r) in self.routers.iter().enumerate() {
                    for port in 0..PORTS {
                        for vc in 0..VCS {
                            let s = r.vc(port, vc);
                            if !s.buf.is_empty() || s.out_port.is_some() {
                                buf_state.push_str(&format!(
                                    "\n  node {n} port {port} vc {vc}: {} flits head={:?} out_port={:?} out_vc={:?}",
                                    s.buf.len(),
                                    s.buf.front(),
                                    s.out_port,
                                    s.out_vc
                                ));
                            }
                        }
                    }
                    for d in 0..NUM_DIRS {
                        if r.credits[d] != [VC_DEPTH as u8; VCS] {
                            buf_state.push_str(&format!("\n  node {n} credits[{d}]={:?}", r.credits[d]));
                        }
                    }
                }
                panic!(
                    "noc_sim: exceeded {max_cycles} cycles — deadlock or undersized budget \
                     (pc={:?}) nic={:?} state:{}",
                    self.pc
                        .iter()
                        .zip(&self.programs)
                        .map(|(pc, p)| format!("{}/{}", pc, p.instrs.len()))
                        .collect::<Vec<_>>(),
                    self.nic.iter().map(|q| q.len()).collect::<Vec<_>>(),
                    buf_state,
                );
            }
        }
        self.stats.cycles = self.cycle;
        self.stats
    }

    fn done(&self) -> bool {
        self.pc
            .iter()
            .zip(&self.programs)
            .all(|(pc, p)| *pc >= p.instrs.len())
            && self.network_empty()
    }

    fn network_empty(&self) -> bool {
        self.nic.iter().all(|q| q.is_empty()) && self.routers.iter().all(|r| r.occupancy == 0)
    }

    fn step(&mut self) {
        self.advance_cores();
        self.inject();
        self.switch_traversal();
        self.cycle += 1;
        self.maybe_skip_idle();
    }

    /// Fast-forward across compute-only stretches (§Perf): when the network
    /// is drained, no NIC has pending packets, and every unfinished core is
    /// mid-COMPUTE, nothing can happen until the earliest compute ends —
    /// jump straight there. Waiting statistics are unaffected (no flits in
    /// flight by construction).
    fn maybe_skip_idle(&mut self) {
        let mut min_until = u64::MAX;
        for core in 0..self.programs.len() {
            let pc = self.pc[core];
            if pc >= self.programs[core].instrs.len() {
                continue;
            }
            // Mid-compute cores have a nonzero deadline; anything else
            // (pending Send/Recv at the PC) blocks the skip.
            let until = self.compute_until[core];
            if until > self.cycle && matches!(self.programs[core].instrs[pc], Instr::Compute { .. })
            {
                min_until = min_until.min(until);
            } else {
                return;
            }
        }
        if min_until == u64::MAX || min_until <= self.cycle {
            return;
        }
        if !self.network_empty() {
            return;
        }
        self.cycle = min_until;
    }

    /// Progress each core's instruction stream.
    fn advance_cores(&mut self) {
        for core in 0..self.programs.len() {
            loop {
                let pc = self.pc[core];
                if pc >= self.programs[core].instrs.len() {
                    break;
                }
                match self.programs[core].instrs[pc] {
                    Instr::Compute { cycles } => {
                        if self.compute_until[core] == 0 {
                            self.compute_until[core] = self.cycle + cycles;
                        }
                        if self.cycle >= self.compute_until[core] {
                            self.compute_until[core] = 0;
                            self.pc[core] += 1;
                            continue;
                        }
                        break;
                    }
                    Instr::Send { dst, bytes, tag } => {
                        // Segment into packets and queue on the NIC.
                        let flit_bytes = self.programs[core].flit_bytes.max(1.0);
                        let flits = (bytes / flit_bytes).ceil().max(1.0) as usize;
                        let mut left = flits;
                        while left > 0 {
                            let sz = left.min(MAX_PACKET_FLITS) as u32;
                            let id = self.packets.len() as u32;
                            self.packets.push(Packet {
                                dst,
                                size_flits: sz,
                                tag,
                                inject_cycle: self.cycle,
                            });
                            self.nic[core].push_back((id, 0));
                            left -= sz as usize;
                        }
                        self.pc[core] += 1;
                        continue;
                    }
                    Instr::Recv { tag, packets } => {
                        if self.recv_count[core][tag as usize] >= packets {
                            self.pc[core] += 1;
                            continue;
                        }
                        break;
                    }
                }
            }
        }
    }

    /// Inject one flit per core per cycle from the NIC into the local
    /// input port (VC 0..VCS round-robin by packet).
    fn inject(&mut self) {
        for core in 0..self.nic.len() {
            let Some(&(pkt_id, _)) = self.nic[core].front() else {
                continue;
            };
            let pkt = self.packets[pkt_id as usize];
            // Find / keep a local-input VC for this packet.
            let router = &mut self.routers[core];
            // Head flit needs a VC whose buffer is empty and unowned;
            // body flits continue on the packet's VC.
            let progress = self.nic_flits_left[core];
            let vc_slot = if progress == 0 {
                (0..VCS).find(|&v| {
                    let s = router.vc(LOCAL, v);
                    s.buf.is_empty() && s.out_port.is_none()
                })
            } else {
                Some(self.inject_vc[core])
            };
            let Some(vc) = vc_slot else { continue };
            let s = router.vc_mut(LOCAL, vc);
            if s.buf.len() >= VC_DEPTH {
                continue;
            }
            let is_head = progress == 0;
            let is_tail = progress + 1 == pkt.size_flits;
            s.buf.push_back(Flit {
                packet: pkt_id,
                is_head,
                is_tail,
            });
            router.occupancy += 1;
            if is_head {
                self.inject_vc[core] = vc;
            }
            self.stats.injected_flits[core] += 1;
            if is_tail {
                self.nic[core].pop_front();
                self.nic_flits_left[core] = 0;
            } else {
                self.nic_flits_left[core] = progress + 1;
            }
        }
    }

    /// Route computation + VC allocation + switch allocation + traversal,
    /// collapsed into one cycle per hop (aggressive single-stage router).
    fn switch_traversal(&mut self) {
        let n = self.routers.len();
        // (from_node, in_port, in_vc, out_port, flit) moves to apply.
        let mut moves: Vec<(usize, usize, usize, usize, Flit)> = Vec::new();

        for node in 0..n {
            if self.routers[node].occupancy == 0 {
                continue; // §Perf: idle router, nothing to arbitrate
            }
            let at = (node / self.width, node % self.width);
            // Gather head-of-buffer requests per output port (fixed-size
            // scratch — §Perf: no per-cycle heap allocation).
            let mut requests = [[(0u8, 0u8); PORTS * VCS]; PORTS];
            let mut req_len = [0usize; PORTS];
            for port in 0..PORTS {
                for vc in 0..VCS {
                    let s = self.routers[node].vc(port, vc);
                    let Some(f) = s.buf.front() else { continue };
                    let out = if f.is_head {
                        self.route(at, self.packets[f.packet as usize].dst)
                    } else {
                        match s.out_port {
                            Some(p) => p as usize,
                            None => continue, // body before head handled
                        }
                    };
                    requests[out][req_len[out]] = (port as u8, vc as u8);
                    req_len[out] += 1;
                }
            }
            // One grant per output port, round-robin.
            for out in 0..PORTS {
                let len = req_len[out];
                if len == 0 {
                    continue;
                }
                let start = self.routers[node].rr[out];
                let pick = (0..len)
                    .map(|i| requests[out][(start + i) % len])
                    .find(|&(port, vc)| self.can_traverse(node, port as usize, vc as usize, out));
                // Waiting accounting: every requester of a *mesh* link that
                // does not move this cycle accrues one wait cycle.
                if out != LOCAL {
                    let li = self.link_idx(node, out);
                    let waiting = len - usize::from(pick.is_some());
                    self.stats.link_wait[li] += waiting as u64;
                }
                let Some((port, vc)) = pick else { continue };
                let (port, vc) = (port as usize, vc as usize);
                self.routers[node].rr[out] = self.routers[node].rr[out].wrapping_add(1);
                let flit = *self.routers[node].vc(port, vc).buf.front().unwrap();
                moves.push((node, port, vc, out, flit));
            }
        }

        // Apply moves: pop from input VC, push downstream (or eject).
        for (node, port, vc, out, flit) in moves {
            // Read the downstream VC allocation BEFORE the pop clears it on
            // tail flits (regression: tails were misrouted to VC 0).
            let alloc_vc = self.routers[node].vc(port, vc).out_vc;
            // Pop.
            {
                self.routers[node].occupancy -= 1;
                let s = self.routers[node].vc_mut(port, vc);
                s.buf.pop_front();
                if flit.is_head {
                    s.out_port = Some(out as u8);
                }
                if flit.is_tail {
                    s.out_port = None;
                    s.out_vc = None;
                }
            }
            // Return a credit upstream for the freed slot.
            self.return_credit(node, port, vc);

            if out == LOCAL {
                // Ejected at destination.
                let pkt = self.packets[flit.packet as usize];
                if flit.is_tail {
                    let core = node;
                    self.recv_count[core][pkt.tag as usize] += 1;
                    self.stats.packets_done += 1;
                    self.stats.packet_latency_sum += self.cycle - pkt.inject_cycle;
                }
                continue;
            }

            let li = self.link_idx(node, out);
            self.stats.link_flits[li] += 1;
            let (down, down_port) = self.neighbor(node, out);
            // Downstream VC: allocated at the head, held through the tail.
            let dvc = alloc_vc.expect("traversing flit must hold a VC allocation") as usize;
            self.routers[down].occupancy += 1;
            let s = self.routers[down].vc_mut(down_port, dvc);
            s.buf.push_back(flit);
            self.routers[node].credits[out][dvc] -= 1;
        }
    }

    /// Check credits / downstream VC availability; for head flits, also
    /// perform VC allocation (recorded in `out_vc`).
    fn can_traverse(&mut self, node: usize, port: usize, vc: usize, out: usize) -> bool {
        if out == LOCAL {
            return true; // ejection always accepted
        }
        let flit = *self.routers[node].vc(port, vc).buf.front().unwrap();
        let (down, down_port) = self.neighbor(node, out);
        if flit.is_head && self.routers[node].vc(port, vc).out_vc.is_none() {
            // Allocate a downstream VC: must be empty and unowned.
            let free = (0..VCS).find(|&v| {
                self.routers[node].credits[out][v] as usize == VC_DEPTH
                    && self.routers[down].vc(down_port, v).buf.is_empty()
                    && self.routers[down].vc(down_port, v).out_port.is_none()
            });
            match free {
                Some(v) => {
                    self.routers[node].vc_mut(port, vc).out_vc = Some(v as u8);
                }
                None => return false,
            }
        }
        let dvc = match self.routers[node].vc(port, vc).out_vc {
            Some(v) => v as usize,
            None => return false, // body flit before head allocated (shouldn't happen)
        };
        self.routers[node].credits[out][dvc] > 0
    }

    /// Credit return for the input buffer slot freed at (node, port, vc):
    /// the *upstream* router regains a credit. Local-port slots have no
    /// upstream credits (NIC checks buffer occupancy directly).
    fn return_credit(&mut self, node: usize, port: usize, vc: usize) {
        if port == LOCAL {
            return;
        }
        // The upstream router is the neighbor in the direction the flit
        // came *from*: input port X means the link arrives from direction
        // X's neighbor, whose output dir is the opposite port.
        let (up, up_out) = self.neighbor(node, port);
        debug_assert!(up < self.routers.len());
        self.routers[up].credits[up_out][vc] =
            (self.routers[up].credits[up_out][vc] + 1).min(VC_DEPTH as u8);
    }
}

/// Convenience: simulate a compiled chunk with per-op compute cycles given
/// by `cycles_for(op_index)`, on cores with `noc_bw_bits`-wide flits.
pub fn simulate_chunk(
    chunk: &crate::compiler::CompiledChunk,
    noc_bw_bits: usize,
    cycles_for: &dyn Fn(usize) -> u64,
    max_cycles: u64,
) -> SimStats {
    let programs = build_programs(chunk, noc_bw_bits, cycles_for);
    Simulator::new(chunk.region_h, chunk.region_w, programs).run(max_cycles)
}

/// Mean waiting time keyed by [`LinkId`] (GNN dataset convenience).
pub fn link_wait_by_id(stats: &SimStats, width: usize) -> impl Fn(LinkId) -> f64 + '_ {
    move |l: LinkId| {
        let idx = crate::compiler::routing::link_index(l, width);
        let f = stats.link_flits[idx];
        if f == 0 {
            0.0
        } else {
            stats.link_wait[idx] as f64 / f as f64
        }
    }
}

/// Flit width in bytes for a core NoC config.
pub fn flit_bytes(noc_bw_bits: usize) -> f64 {
    noc_bw_bits as f64 / 8.0
}

/// Cycles to serialize `bytes` over one link.
pub fn serialization_cycles(bytes: f64, noc_bw_bits: usize) -> f64 {
    bytes / flit_bytes(noc_bw_bits).max(1.0)
}

/// Compute cycles for an op tile with a trivially analytic model —
/// used by dataset generation where only *relative* compute/comm overlap
/// matters. Real evaluation uses [`crate::eval::tile`].
pub fn naive_compute_cycles(flops: f64, mac_num: usize) -> u64 {
    (flops / (k::FLOPS_PER_MAC * mac_num as f64)).ceil().max(1.0) as u64
}

#[cfg(test)]
mod tests;
