//! Cycle-accurate NoC simulator (paper §VIII-A: BookSim2 [22] extended with
//! instruction-driven cores).
//!
//! 2-D mesh, wormhole flow control, 8 virtual channels × 4-flit buffers per
//! input port (§VIII-A router config), credit-based backpressure, XY
//! routing, round-robin switch allocation. Cores execute micro-instruction
//! streams (COMPUTE / SEND / RECV) generated from a [`CompiledChunk`] —
//! compute and memory latency inside cores use analytical estimates, as the
//! paper argues is sound for regular tensor operations.
//!
//! The simulator doubles as the ground-truth generator: per-link mean
//! waiting times ([`SimStats::link_wait`]) are the GNN's regression targets
//! (Eq. 5), and end-to-end chunk cycles validate the analytical model
//! (Fig. 7).
//!
//! # Event-driven scheduling (§Perf)
//!
//! The default [`Simulator`] is *event-driven*: instead of touching every
//! core and every router every cycle, it maintains
//!
//! * a min-heap of **compute wake times** — a core mid-COMPUTE is dormant
//!   until its deadline pops;
//! * a **runnable-core** set — cores are advanced only when something that
//!   can change their state happened (a compute deadline, a packet tail
//!   ejected at them, or simulation start);
//! * an **active-router** list — only routers holding buffered flits
//!   arbitrate and traverse; idle routers cost zero work per cycle;
//! * a **NIC-backlog** list — only cores with queued packets inject.
//!
//! When every list is empty the simulator jumps straight to the earliest
//! compute deadline (per-entity generalization of the old all-or-nothing
//! `maybe_skip_idle`): idle regions of a large mesh cost *zero* work per
//! cycle rather than O(cores). Cycles in which any flit is buffered are
//! still stepped one by one, because blocked head-of-line flits accrue one
//! [`SimStats::link_wait`] cycle per blocked requester per cycle — exactly
//! as in the per-cycle stepper.
//!
//! Congested meshes are the active-list's constant-factor worst case
//! (active ≈ all routers, so the list buys nothing and its bookkeeping
//! costs extra): when the active-router count reaches half the mesh the
//! switch pass falls back to the dense flat sweep over all routers for
//! that cycle. Per-node switch decisions read only pre-cycle network state
//! plus node-local allocation, so the regime flip cannot change results —
//! the equivalence suite includes a seed that crosses the threshold
//! mid-run in both directions.
//!
//! # Reference-oracle contract
//!
//! The original per-cycle stepper is retained, frozen, as
//! [`reference::Simulator`]. The event-driven engine must produce
//! **bit-identical [`SimStats`]** (cycles, per-link flit/wait counters,
//! packet latencies, injected flits) on every program that completes within
//! budget; `tests::equivalence` proves this over randomized meshes and
//! programs, and compiled-chunk runs. Any future change to the router
//! microarchitecture must be applied to both engines (or the change must be
//! validated against a regenerated oracle) — the GNN training labels and
//! the Fig. 7 validation depend on these exact semantics. The engines may
//! differ only in *failure* behavior: budget overruns surface as
//! [`SimError`] from [`Simulator::try_run`] with a bounded diagnostic
//! (every event-driven call site propagates the error; the legacy
//! panicking `run()` wrapper is gone), while the frozen oracle keeps its
//! original panic.

pub mod dataset;
pub mod program;
pub mod reference;

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};
use std::sync::Arc;

use crate::arch::constants as k;
use crate::compiler::routing::{Dir, LinkId, RouteTable, NUM_DIRS};

pub use program::{build_programs, CoreProgram, Instr};

/// Ports on a router: 4 mesh directions + local (NIC).
const PORTS: usize = 5;
const LOCAL: usize = 4;

/// Buffer depth per VC (flits) — paper §VIII-A.
const VC_DEPTH: usize = 4;
/// Virtual channels per input port — paper §VIII-A.
const VCS: usize = 8;

/// Max packet size in flits; larger transfers are segmented (§VI-C's
/// variable packet sizes come from the flow byte volumes).
pub const MAX_PACKET_FLITS: usize = 16;

/// A packet in flight.
#[derive(Debug, Clone, Copy)]
struct Packet {
    dst: (usize, usize),
    size_flits: u32,
    /// Tag = consuming op id (RECV matching).
    tag: u32,
    inject_cycle: u64,
}

/// One flit. Packets are wormhole-switched: body flits follow the head's
/// VC/port allocation.
#[derive(Debug, Clone, Copy)]
struct Flit {
    packet: u32,
    is_head: bool,
    is_tail: bool,
}

/// Per-input-VC state.
#[derive(Debug, Clone, Default)]
struct VcState {
    buf: VecDeque<Flit>,
    /// Allocated output port for the packet currently occupying this VC.
    out_port: Option<u8>,
    /// Allocated VC at the downstream router's input.
    out_vc: Option<u8>,
}

/// One router: input-buffered, 5 ports × VCS VCs.
#[derive(Debug, Clone)]
struct Router {
    vcs: Vec<VcState>, // PORTS * VCS
    /// Credits we hold for each downstream input VC, per output direction.
    credits: [[u8; VCS]; NUM_DIRS],
    /// Round-robin pointers per output port.
    rr: [usize; PORTS],
    /// Buffered flits across all input VCs (§Perf: lets the switch pass
    /// skip idle routers entirely).
    occupancy: u32,
}

impl Router {
    fn new() -> Router {
        Router {
            vcs: (0..PORTS * VCS).map(|_| VcState::default()).collect(),
            credits: [[VC_DEPTH as u8; VCS]; NUM_DIRS],
            rr: [0; PORTS],
            occupancy: 0,
        }
    }

    fn vc(&self, port: usize, vc: usize) -> &VcState {
        &self.vcs[port * VCS + vc]
    }

    fn vc_mut(&mut self, port: usize, vc: usize) -> &mut VcState {
        &mut self.vcs[port * VCS + vc]
    }
}

/// XY output port for a packet at router coordinates `at`.
fn route_port(at: (usize, usize), dst: (usize, usize)) -> usize {
    if dst.1 > at.1 {
        Dir::East as usize
    } else if dst.1 < at.1 {
        Dir::West as usize
    } else if dst.0 > at.0 {
        Dir::South as usize
    } else if dst.0 < at.0 {
        Dir::North as usize
    } else {
        LOCAL
    }
}

/// Output port under an optional fault-aware routing table: table lookup on
/// degraded meshes (the table's arrived code equals [`LOCAL`]), XY
/// otherwise. Both engines call this from their single route-computation
/// site, so a shared table keeps them on identical irregular-mesh routes —
/// the bit-identical [`SimStats`] contract extends structurally.
fn route_port_with(table: Option<&RouteTable>, at: (usize, usize), dst: (usize, usize)) -> usize {
    match table {
        Some(t) => t.port_index(at, dst),
        None => route_port(at, dst),
    }
}

/// Neighbor node through `dir` on a `width`-wide mesh, plus the input port
/// on that neighbor.
fn neighbor_of(width: usize, node: usize, dir: usize) -> (usize, usize) {
    let (r, c) = (node / width, node % width);
    let at = |r: usize, c: usize| r * width + c;
    match dir {
        d if d == Dir::East as usize => (at(r, c + 1), Dir::West as usize),
        d if d == Dir::West as usize => (at(r, c - 1), Dir::East as usize),
        d if d == Dir::South as usize => (at(r + 1, c), Dir::North as usize),
        d if d == Dir::North as usize => (at(r - 1, c), Dir::South as usize),
        _ => unreachable!(),
    }
}

/// Aggregate simulation statistics.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SimStats {
    /// Total simulated cycles until drain.
    pub cycles: u64,
    /// Flits that crossed each directed mesh link (dense, `link_index`).
    pub link_flits: Vec<u64>,
    /// Cycles head-of-line flits spent blocked wanting each link.
    pub link_wait: Vec<u64>,
    /// Completed packets and their total latency (inject→eject).
    pub packets_done: u64,
    pub packet_latency_sum: u64,
    /// Per-core injected flits (GNN node feature).
    pub injected_flits: Vec<u64>,
}

impl SimStats {
    /// Mean waiting time per link (the Eq. 5 target); 0 for idle links.
    pub fn link_wait_mean(&self) -> Vec<f64> {
        self.link_flits
            .iter()
            .zip(&self.link_wait)
            .map(|(&f, &w)| if f == 0 { 0.0 } else { w as f64 / f as f64 })
            .collect()
    }

    pub fn avg_packet_latency(&self) -> f64 {
        if self.packets_done == 0 {
            0.0
        } else {
            self.packet_latency_sum as f64 / self.packets_done as f64
        }
    }
}

/// Budget overrun (deadlock or undersized `max_cycles`) from
/// [`Simulator::try_run`]. Carries a *bounded* diagnostic — at most
/// [`SimError::MAX_DIAG`] stuck VCs and blocked cores are sampled, so the
/// error stays cheap to build and render even on a 100×100 mesh (the legacy
/// panic rendered every busy VC in the network).
#[derive(Debug, Clone)]
pub struct SimError {
    /// The budget that was exceeded.
    pub max_cycles: u64,
    /// Simulated cycle at which the run was abandoned.
    pub cycle: u64,
    /// True when no event could ever fire again (certain deadlock, e.g. a
    /// RECV whose packets were never sent); false when the budget ran out
    /// with traffic still moving.
    pub deadlock: bool,
    /// Cores that have not finished their instruction stream.
    pub unfinished_cores: usize,
    /// Cores with packets still queued on the NIC.
    pub nic_backlog: usize,
    /// Flits buffered somewhere in the network.
    pub flits_in_network: u64,
    /// Up to [`SimError::MAX_DIAG`] `(node, port, vc, buffered_flits)`
    /// input VCs still holding flits.
    pub sample_stuck: Vec<(usize, usize, usize, usize)>,
    /// Up to [`SimError::MAX_DIAG`] `(core, pc)` unfinished cores.
    pub sample_blocked: Vec<(usize, usize)>,
}

impl SimError {
    /// Cap on each diagnostic sample list.
    pub const MAX_DIAG: usize = 8;
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "exceeded {} cycles at cycle {}{}: {} unfinished core(s), {} NIC backlog(s), \
             {} flit(s) in flight; stuck VCs (node,port,vc,flits) {:?}; blocked cores (core,pc) {:?}",
            self.max_cycles,
            self.cycle,
            if self.deadlock {
                " [deadlock: no pending events]"
            } else {
                ""
            },
            self.unfinished_cores,
            self.nic_backlog,
            self.flits_in_network,
            self.sample_stuck,
            self.sample_blocked,
        )
    }
}

impl std::error::Error for SimError {}

/// Instruction-driven mesh simulator (event-driven engine — see the module
/// docs; [`reference::Simulator`] is the frozen per-cycle oracle).
pub struct Simulator {
    pub height: usize,
    pub width: usize,
    routers: Vec<Router>,
    packets: Vec<Packet>,
    programs: Vec<CoreProgram>,
    /// Per-core program counter and state.
    pc: Vec<usize>,
    compute_until: Vec<u64>,
    recv_count: Vec<Vec<u32>>, // [core][tag] received packets
    nic: Vec<VecDeque<(u32, u64)>>, // queued (packet, reserved)
    nic_flits_left: Vec<u32>,
    /// VC on which the current NIC packet is being injected.
    inject_vc: Vec<usize>,
    stats: SimStats,
    cycle: u64,

    // ---- event-driven scheduler state ----
    /// Cores to advance this cycle (processed in ascending index order so
    /// packet-id assignment matches the reference stepper's 0..n sweep).
    runnable: Vec<u32>,
    runnable_flag: Vec<bool>,
    /// Min-heap of (compute deadline, core).
    wake: BinaryHeap<Reverse<(u64, u32)>>,
    /// Routers holding buffered flits (lazily compacted).
    active_routers: Vec<u32>,
    router_in_list: Vec<bool>,
    /// Cores with NIC backlog (lazily compacted).
    nic_active: Vec<u32>,
    nic_in_list: Vec<bool>,
    /// O(1) `done()` bookkeeping.
    unfinished: usize,
    flits_in_network: u64,
    nic_pending: usize,
    /// Scratch for the switch pass (reused allocation).
    moves: Vec<(usize, usize, usize, usize, Flit)>,
    /// Fault-aware routing table (None = pristine XY mesh).
    table: Option<Arc<RouteTable>>,
}

impl Simulator {
    /// Build a simulator for an `height × width` mesh running `programs`
    /// (one per core, row-major; see [`program::build_programs`]).
    pub fn new(height: usize, width: usize, programs: Vec<CoreProgram>) -> Simulator {
        Simulator::with_table(height, width, programs, None)
    }

    /// Like [`Simulator::new`] but routing through a fault-aware table
    /// (dead cores simply run empty programs; dead links are avoided by
    /// the table's detours).
    pub fn with_table(
        height: usize,
        width: usize,
        programs: Vec<CoreProgram>,
        table: Option<Arc<RouteTable>>,
    ) -> Simulator {
        assert_eq!(programs.len(), height * width);
        if let Some(t) = &table {
            assert_eq!(t.dims(), (height, width), "route table/mesh shape mismatch");
        }
        let n = height * width;
        let max_tag = programs
            .iter()
            .flat_map(|p| p.instrs.iter())
            .map(|i| match i {
                Instr::Recv { tag, .. } => *tag + 1,
                Instr::Send { tag, .. } => *tag + 1,
                _ => 0,
            })
            .max()
            .unwrap_or(1) as usize;
        let unfinished = programs.iter().filter(|p| !p.instrs.is_empty()).count();
        Simulator {
            height,
            width,
            routers: (0..n).map(|_| Router::new()).collect(),
            packets: Vec::new(),
            programs,
            pc: vec![0; n],
            compute_until: vec![0; n],
            recv_count: vec![vec![0; max_tag]; n],
            nic: (0..n).map(|_| VecDeque::new()).collect(),
            nic_flits_left: vec![0; n],
            inject_vc: vec![0; n],
            stats: SimStats {
                link_flits: vec![0; n * NUM_DIRS],
                link_wait: vec![0; n * NUM_DIRS],
                injected_flits: vec![0; n],
                ..Default::default()
            },
            cycle: 0,
            // Every core is runnable at cycle 0 (mirrors the reference
            // stepper's first full advance pass).
            runnable: (0..n as u32).collect(),
            runnable_flag: vec![true; n],
            wake: BinaryHeap::new(),
            active_routers: Vec::new(),
            router_in_list: vec![false; n],
            nic_active: Vec::new(),
            nic_in_list: vec![false; n],
            unfinished,
            flits_in_network: 0,
            nic_pending: 0,
            moves: Vec::new(),
            table,
        }
    }

    /// Run to completion, or return a bounded [`SimError`] diagnostic if
    /// the cycle budget is exceeded (deadlock or undersized budget).
    /// This is the only way to run the event-driven engine — the old
    /// panicking `run()` wrapper had its call sites migrated to error
    /// propagation and was removed.
    pub fn try_run(mut self, max_cycles: u64) -> Result<SimStats, SimError> {
        loop {
            if self.done() {
                break;
            }
            self.wake_due();
            if self.quiescent() {
                // Per-entity fast-forward: no flits buffered, no NIC
                // backlog, no core can act — nothing can change state
                // before the earliest compute deadline.
                match self.wake.peek() {
                    Some(&Reverse((t, _))) => {
                        self.cycle = t;
                        self.wake_due();
                    }
                    None => {
                        // No pending events at all and not done: certain
                        // deadlock. The reference stepper would idle-spin
                        // to the budget; jump straight to the failure.
                        self.cycle = max_cycles + 1;
                        return Err(self.overrun_error(max_cycles, true));
                    }
                }
            }
            self.step_active();
            if self.cycle > max_cycles {
                return Err(self.overrun_error(max_cycles, false));
            }
        }
        self.stats.cycles = self.cycle;
        Ok(self.stats)
    }

    fn done(&self) -> bool {
        self.unfinished == 0 && self.flits_in_network == 0 && self.nic_pending == 0
    }

    fn quiescent(&self) -> bool {
        self.runnable.is_empty() && self.flits_in_network == 0 && self.nic_pending == 0
    }

    /// Pop all compute deadlines due at or before the current cycle.
    fn wake_due(&mut self) {
        while let Some(&Reverse((t, core))) = self.wake.peek() {
            if t > self.cycle {
                break;
            }
            self.wake.pop();
            self.mark_runnable(core as usize);
        }
    }

    fn mark_runnable(&mut self, core: usize) {
        if !self.runnable_flag[core] {
            self.runnable_flag[core] = true;
            self.runnable.push(core as u32);
        }
    }

    fn mark_router(&mut self, node: usize) {
        if !self.router_in_list[node] {
            self.router_in_list[node] = true;
            self.active_routers.push(node as u32);
        }
    }

    fn mark_nic(&mut self, core: usize) {
        if !self.nic_in_list[core] {
            self.nic_in_list[core] = true;
            self.nic_active.push(core as u32);
        }
    }

    /// One simulated cycle touching only active entities. Phase order
    /// matches the reference stepper: cores, then injection, then switch.
    fn step_active(&mut self) {
        self.advance_runnable();
        self.inject_active();
        self.switch_active();
        self.cycle += 1;
    }

    /// Advance every runnable core, in ascending index order (keeps the
    /// `packets` vec — and thus packet ids — identical to the reference
    /// stepper's 0..n sweep; core advancement itself is core-local, so the
    /// *set* of advancing cores is order-independent).
    fn advance_runnable(&mut self) {
        if self.runnable.is_empty() {
            return;
        }
        let mut cores = std::mem::take(&mut self.runnable);
        cores.sort_unstable();
        for &c in &cores {
            self.runnable_flag[c as usize] = false;
            self.advance_core(c as usize);
        }
        cores.clear();
        // Reuse the allocation; wakes generated later this cycle (tail
        // ejections) land here for the next cycle.
        let leftover = std::mem::replace(&mut self.runnable, cores);
        debug_assert!(leftover.is_empty());
    }

    /// Progress one core's instruction stream as far as it can go this
    /// cycle — byte-for-byte the reference stepper's per-core loop, plus
    /// scheduler bookkeeping (wake heap, NIC backlog, unfinished count).
    fn advance_core(&mut self, core: usize) {
        let was_finished = self.pc[core] >= self.programs[core].instrs.len();
        loop {
            let pc = self.pc[core];
            if pc >= self.programs[core].instrs.len() {
                break;
            }
            match self.programs[core].instrs[pc] {
                Instr::Compute { cycles } => {
                    if self.compute_until[core] == 0 {
                        let until = self.cycle + cycles;
                        self.compute_until[core] = until;
                        if until > self.cycle {
                            self.wake.push(Reverse((until, core as u32)));
                        }
                    }
                    if self.cycle >= self.compute_until[core] {
                        self.compute_until[core] = 0;
                        self.pc[core] += 1;
                        continue;
                    }
                    break;
                }
                Instr::Send { dst, bytes, tag } => {
                    // Segment into packets and queue on the NIC.
                    let flit_bytes = self.programs[core].flit_bytes.max(1.0);
                    let flits = (bytes / flit_bytes).ceil().max(1.0) as usize;
                    let was_empty = self.nic[core].is_empty();
                    let mut left = flits;
                    while left > 0 {
                        let sz = left.min(MAX_PACKET_FLITS) as u32;
                        let id = self.packets.len() as u32;
                        self.packets.push(Packet {
                            dst,
                            size_flits: sz,
                            tag,
                            inject_cycle: self.cycle,
                        });
                        self.nic[core].push_back((id, 0));
                        left -= sz as usize;
                    }
                    if was_empty {
                        self.nic_pending += 1;
                        self.mark_nic(core);
                    }
                    self.pc[core] += 1;
                    continue;
                }
                Instr::Recv { tag, packets } => {
                    if self.recv_count[core][tag as usize] >= packets {
                        self.pc[core] += 1;
                        continue;
                    }
                    break;
                }
            }
        }
        if !was_finished && self.pc[core] >= self.programs[core].instrs.len() {
            self.unfinished -= 1;
        }
    }

    /// Inject one flit per backlogged core per cycle from the NIC into the
    /// local input port (VC 0..VCS round-robin by packet).
    fn inject_active(&mut self) {
        let mut i = 0;
        while i < self.nic_active.len() {
            let core = self.nic_active[i] as usize;
            if self.nic[core].is_empty() {
                self.nic_in_list[core] = false;
                self.nic_active.swap_remove(i);
                continue;
            }
            self.try_inject(core);
            if self.nic[core].is_empty() {
                self.nic_in_list[core] = false;
                self.nic_active.swap_remove(i);
                continue;
            }
            i += 1;
        }
    }

    /// Attempt to inject one flit at `core` — the reference stepper's
    /// per-core inject body plus scheduler bookkeeping.
    fn try_inject(&mut self, core: usize) {
        let Some(&(pkt_id, _)) = self.nic[core].front() else {
            return;
        };
        let pkt = self.packets[pkt_id as usize];
        let progress = self.nic_flits_left[core];
        let router = &mut self.routers[core];
        // Head flit needs a VC whose buffer is empty and unowned;
        // body flits continue on the packet's VC.
        let vc_slot = if progress == 0 {
            (0..VCS).find(|&v| {
                let s = router.vc(LOCAL, v);
                s.buf.is_empty() && s.out_port.is_none()
            })
        } else {
            Some(self.inject_vc[core])
        };
        let Some(vc) = vc_slot else { return };
        let s = router.vc_mut(LOCAL, vc);
        if s.buf.len() >= VC_DEPTH {
            return;
        }
        let is_head = progress == 0;
        let is_tail = progress + 1 == pkt.size_flits;
        s.buf.push_back(Flit {
            packet: pkt_id,
            is_head,
            is_tail,
        });
        router.occupancy += 1;
        if is_head {
            self.inject_vc[core] = vc;
        }
        self.stats.injected_flits[core] += 1;
        self.flits_in_network += 1;
        self.mark_router(core);
        if is_tail {
            self.nic[core].pop_front();
            self.nic_flits_left[core] = 0;
            if self.nic[core].is_empty() {
                self.nic_pending -= 1;
            }
        } else {
            self.nic_flits_left[core] = progress + 1;
        }
    }

    /// Route computation + VC allocation + switch allocation + traversal,
    /// collapsed into one cycle per hop (aggressive single-stage router).
    /// Normally walks the active-router list only; when the active count
    /// reaches half the mesh (a congested phase — the list buys nothing
    /// there and its indirection costs extra) it falls back to the dense
    /// flat sweep over all routers for this cycle. Per-node decisions read
    /// only pre-cycle network state plus node-local allocation, so neither
    /// the iteration order nor the regime choice can affect the outcome.
    fn switch_active(&mut self) {
        if self.active_routers.is_empty() {
            return;
        }
        let mut moves = std::mem::take(&mut self.moves);
        debug_assert!(moves.is_empty());

        let n = self.routers.len();
        let dense = 2 * self.active_routers.len() >= n;
        #[cfg(test)]
        note_switch_regime(dense);
        if dense {
            for node in 0..n {
                if self.routers[node].occupancy == 0 {
                    continue;
                }
                self.switch_node(node, &mut moves);
            }
        } else {
            let n_active = self.active_routers.len();
            for ai in 0..n_active {
                let node = self.active_routers[ai] as usize;
                if self.routers[node].occupancy == 0 {
                    continue; // drained earlier; compacted below
                }
                self.switch_node(node, &mut moves);
            }
        }

        // Apply moves: pop from input VC, push downstream (or eject).
        for &(node, port, vc, out, flit) in &moves {
            self.apply_move(node, port, vc, out, flit);
        }
        moves.clear();
        self.moves = moves;

        // Compact: drop routers drained this cycle. (In dense cycles the
        // list is still the membership structure — every router holding
        // flits is on it, so the same compaction applies.)
        let mut i = 0;
        while i < self.active_routers.len() {
            let node = self.active_routers[i] as usize;
            if self.routers[node].occupancy == 0 {
                self.router_in_list[node] = false;
                self.active_routers.swap_remove(i);
            } else {
                i += 1;
            }
        }
    }

    /// One router's switch allocation for this cycle (shared by the sparse
    /// active-list walk and the dense flat sweep).
    fn switch_node(&mut self, node: usize, moves: &mut Vec<(usize, usize, usize, usize, Flit)>) {
        let at = (node / self.width, node % self.width);
        // Gather head-of-buffer requests per output port (fixed-size
        // scratch — §Perf: no per-cycle heap allocation).
        let mut requests = [[(0u8, 0u8); PORTS * VCS]; PORTS];
        let mut req_len = [0usize; PORTS];
        for port in 0..PORTS {
            for vc in 0..VCS {
                let s = self.routers[node].vc(port, vc);
                let Some(f) = s.buf.front() else { continue };
                let out = if f.is_head {
                    route_port_with(
                        self.table.as_deref(),
                        at,
                        self.packets[f.packet as usize].dst,
                    )
                } else {
                    match s.out_port {
                        Some(p) => p as usize,
                        None => continue, // body before head handled
                    }
                };
                requests[out][req_len[out]] = (port as u8, vc as u8);
                req_len[out] += 1;
            }
        }
        // One grant per output port, round-robin.
        for out in 0..PORTS {
            let len = req_len[out];
            if len == 0 {
                continue;
            }
            let start = self.routers[node].rr[out];
            let pick = (0..len)
                .map(|i| requests[out][(start + i) % len])
                .find(|&(port, vc)| self.can_traverse(node, port as usize, vc as usize, out));
            // Waiting accounting: every requester of a *mesh* link that
            // does not move this cycle accrues one wait cycle.
            if out != LOCAL {
                let li = node * NUM_DIRS + out;
                let waiting = len - usize::from(pick.is_some());
                self.stats.link_wait[li] += waiting as u64;
            }
            let Some((port, vc)) = pick else { continue };
            let (port, vc) = (port as usize, vc as usize);
            self.routers[node].rr[out] = self.routers[node].rr[out].wrapping_add(1);
            let flit = *self.routers[node].vc(port, vc).buf.front().unwrap();
            moves.push((node, port, vc, out, flit));
        }
    }

    fn apply_move(&mut self, node: usize, port: usize, vc: usize, out: usize, flit: Flit) {
        // Read the downstream VC allocation BEFORE the pop clears it on
        // tail flits (regression: tails were misrouted to VC 0).
        let alloc_vc = self.routers[node].vc(port, vc).out_vc;
        // Pop.
        {
            self.routers[node].occupancy -= 1;
            let s = self.routers[node].vc_mut(port, vc);
            s.buf.pop_front();
            if flit.is_head {
                s.out_port = Some(out as u8);
            }
            if flit.is_tail {
                s.out_port = None;
                s.out_vc = None;
            }
        }
        // Return a credit upstream for the freed slot.
        self.return_credit(node, port, vc);

        if out == LOCAL {
            // Ejected at destination.
            let pkt = self.packets[flit.packet as usize];
            self.flits_in_network -= 1;
            if flit.is_tail {
                let core = node;
                self.recv_count[core][pkt.tag as usize] += 1;
                self.stats.packets_done += 1;
                self.stats.packet_latency_sum += self.cycle - pkt.inject_cycle;
                // A blocked RECV at this core may now be satisfied.
                self.mark_runnable(core);
            }
            return;
        }

        let li = node * NUM_DIRS + out;
        self.stats.link_flits[li] += 1;
        let (down, down_port) = neighbor_of(self.width, node, out);
        // Downstream VC: allocated at the head, held through the tail.
        let dvc = alloc_vc.expect("traversing flit must hold a VC allocation") as usize;
        self.routers[down].occupancy += 1;
        self.mark_router(down);
        let s = self.routers[down].vc_mut(down_port, dvc);
        s.buf.push_back(flit);
        self.routers[node].credits[out][dvc] -= 1;
    }

    /// Check credits / downstream VC availability; for head flits, also
    /// perform VC allocation (recorded in `out_vc`).
    fn can_traverse(&mut self, node: usize, port: usize, vc: usize, out: usize) -> bool {
        if out == LOCAL {
            return true; // ejection always accepted
        }
        let flit = *self.routers[node].vc(port, vc).buf.front().unwrap();
        let (down, down_port) = neighbor_of(self.width, node, out);
        if flit.is_head && self.routers[node].vc(port, vc).out_vc.is_none() {
            // Allocate a downstream VC: must be empty and unowned.
            let free = (0..VCS).find(|&v| {
                self.routers[node].credits[out][v] as usize == VC_DEPTH
                    && self.routers[down].vc(down_port, v).buf.is_empty()
                    && self.routers[down].vc(down_port, v).out_port.is_none()
            });
            match free {
                Some(v) => {
                    self.routers[node].vc_mut(port, vc).out_vc = Some(v as u8);
                }
                None => return false,
            }
        }
        let dvc = match self.routers[node].vc(port, vc).out_vc {
            Some(v) => v as usize,
            None => return false, // body flit before head allocated (shouldn't happen)
        };
        self.routers[node].credits[out][dvc] > 0
    }

    /// Credit return for the input buffer slot freed at (node, port, vc):
    /// the *upstream* router regains a credit. Local-port slots have no
    /// upstream credits (NIC checks buffer occupancy directly).
    fn return_credit(&mut self, node: usize, port: usize, vc: usize) {
        if port == LOCAL {
            return;
        }
        // The upstream router is the neighbor in the direction the flit
        // came *from*: input port X means the link arrives from direction
        // X's neighbor, whose output dir is the opposite port.
        let (up, up_out) = neighbor_of(self.width, node, port);
        debug_assert!(up < self.routers.len());
        self.routers[up].credits[up_out][vc] =
            (self.routers[up].credits[up_out][vc] + 1).min(VC_DEPTH as u8);
    }

    /// Build the bounded overrun diagnostic (see [`SimError`]).
    fn overrun_error(&self, max_cycles: u64, deadlock: bool) -> SimError {
        let mut sample_stuck = Vec::new();
        'routers: for (node, r) in self.routers.iter().enumerate() {
            if r.occupancy == 0 {
                continue;
            }
            for port in 0..PORTS {
                for vc in 0..VCS {
                    let s = r.vc(port, vc);
                    if !s.buf.is_empty() {
                        sample_stuck.push((node, port, vc, s.buf.len()));
                        if sample_stuck.len() >= SimError::MAX_DIAG {
                            break 'routers;
                        }
                    }
                }
            }
        }
        let mut sample_blocked = Vec::new();
        for (core, p) in self.programs.iter().enumerate() {
            if self.pc[core] < p.instrs.len() {
                sample_blocked.push((core, self.pc[core]));
                if sample_blocked.len() >= SimError::MAX_DIAG {
                    break;
                }
            }
        }
        SimError {
            max_cycles,
            cycle: self.cycle,
            deadlock,
            unfinished_cores: self.unfinished,
            nic_backlog: self.nic_pending,
            flits_in_network: self.flits_in_network,
            sample_stuck,
            sample_blocked,
        }
    }
}

/// Test-only instrumentation: per-thread counters of how many switch
/// cycles ran in the dense flat-sweep vs the sparse active-list regime
/// (the dense-fallback equivalence test asserts both were visited).
#[cfg(test)]
thread_local! {
    static SWITCH_REGIMES: std::cell::Cell<(u64, u64)> = std::cell::Cell::new((0, 0));
}

#[cfg(test)]
fn note_switch_regime(dense: bool) {
    SWITCH_REGIMES.with(|c| {
        let (d, s) = c.get();
        c.set(if dense { (d + 1, s) } else { (d, s + 1) });
    });
}

#[cfg(test)]
pub(crate) fn reset_switch_regimes() {
    SWITCH_REGIMES.with(|c| c.set((0, 0)));
}

/// `(dense_cycles, sparse_cycles)` since the last reset, this thread.
#[cfg(test)]
pub(crate) fn switch_regimes() -> (u64, u64) {
    SWITCH_REGIMES.with(|c| c.get())
}

/// Simulate a compiled chunk with per-op compute cycles given by
/// `cycles_for(op_index)`, on cores with `noc_bw_bits`-wide flits. Budget
/// overruns (deadlock or undersized `max_cycles`) surface as a bounded
/// [`SimError`] — there is no panicking convenience wrapper anymore; every
/// call site propagates or handles the error.
pub fn simulate_chunk_result(
    chunk: &crate::compiler::CompiledChunk,
    noc_bw_bits: usize,
    cycles_for: &dyn Fn(usize) -> u64,
    max_cycles: u64,
) -> Result<SimStats, SimError> {
    let programs = build_programs(chunk, noc_bw_bits, cycles_for);
    // Faulted compiles ship their routing table into the simulator, so the
    // CA fidelity runs on the same irregular topology the compile saw.
    let table = chunk.fault.as_ref().map(|t| t.table.clone());
    Simulator::with_table(chunk.region_h, chunk.region_w, programs, table).try_run(max_cycles)
}

/// Mean waiting time keyed by [`LinkId`] (GNN dataset convenience).
pub fn link_wait_by_id(stats: &SimStats, width: usize) -> impl Fn(LinkId) -> f64 + '_ {
    move |l: LinkId| {
        let idx = crate::compiler::routing::link_index(l, width);
        let f = stats.link_flits[idx];
        if f == 0 {
            0.0
        } else {
            stats.link_wait[idx] as f64 / f as f64
        }
    }
}

/// Flit width in bytes for a core NoC config.
pub fn flit_bytes(noc_bw_bits: usize) -> f64 {
    noc_bw_bits as f64 / 8.0
}

/// Cycles to serialize `bytes` over one link.
pub fn serialization_cycles(bytes: f64, noc_bw_bits: usize) -> f64 {
    bytes / flit_bytes(noc_bw_bits).max(1.0)
}

/// Compute cycles for an op tile with a trivially analytic model —
/// used by dataset generation where only *relative* compute/comm overlap
/// matters. Real evaluation uses [`crate::eval::tile`].
pub fn naive_compute_cycles(flops: f64, mac_num: usize) -> u64 {
    (flops / (k::FLOPS_PER_MAC * mac_num as f64)).ceil().max(1.0) as u64
}

#[cfg(test)]
mod tests;
