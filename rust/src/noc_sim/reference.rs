//! Frozen per-cycle reference stepper — the semantic oracle for the
//! event-driven engine in the parent module.
//!
//! This is the original simulator loop: every cycle advances *every* core,
//! attempts injection at *every* NIC and arbitrates *every* router (with
//! the all-or-nothing `maybe_skip_idle` compute fast-forward). It is O(cores)
//! per cycle and therefore slow on large sparse meshes, but its semantics
//! define the ground truth: the event-driven [`super::Simulator`] must
//! produce bit-identical [`SimStats`] on every program that completes
//! within budget (see `super::tests::equivalence`). Do not optimize this
//! module — change the event-driven engine and prove it against this one.

use std::collections::VecDeque;
use std::sync::Arc;

use super::{
    neighbor_of, route_port_with, CoreProgram, Flit, Instr, Packet, Router, SimStats, LOCAL,
    MAX_PACKET_FLITS, PORTS, VCS, VC_DEPTH,
};
use crate::compiler::routing::{RouteTable, NUM_DIRS};

/// The original per-cycle instruction-driven mesh simulator (oracle).
pub struct Simulator {
    pub height: usize,
    pub width: usize,
    routers: Vec<Router>,
    packets: Vec<Packet>,
    programs: Vec<CoreProgram>,
    pc: Vec<usize>,
    compute_until: Vec<u64>,
    recv_count: Vec<Vec<u32>>,
    nic: Vec<VecDeque<(u32, u64)>>,
    nic_flits_left: Vec<u32>,
    inject_vc: Vec<usize>,
    stats: SimStats,
    cycle: u64,
    /// Fault-aware routing table (None = pristine XY mesh). The table is
    /// the one extension the frozen oracle accepts — route *computation*
    /// swaps from XY to a precomputed lookup at the single
    /// `route_port_with` call site; every other semantic stays frozen.
    table: Option<Arc<RouteTable>>,
}

impl Simulator {
    /// Build an oracle simulator for an `height × width` mesh running
    /// `programs` (one per core, row-major).
    pub fn new(height: usize, width: usize, programs: Vec<CoreProgram>) -> Simulator {
        Simulator::with_table(height, width, programs, None)
    }

    /// Like [`Simulator::new`] but routing through a fault-aware table
    /// (irregular-mesh oracle runs).
    pub fn with_table(
        height: usize,
        width: usize,
        programs: Vec<CoreProgram>,
        table: Option<Arc<RouteTable>>,
    ) -> Simulator {
        assert_eq!(programs.len(), height * width);
        if let Some(t) = &table {
            assert_eq!(t.dims(), (height, width), "route table/mesh shape mismatch");
        }
        let n = height * width;
        let max_tag = programs
            .iter()
            .flat_map(|p| p.instrs.iter())
            .map(|i| match i {
                Instr::Recv { tag, .. } => *tag + 1,
                Instr::Send { tag, .. } => *tag + 1,
                _ => 0,
            })
            .max()
            .unwrap_or(1) as usize;
        Simulator {
            height,
            width,
            routers: (0..n).map(|_| Router::new()).collect(),
            packets: Vec::new(),
            programs,
            pc: vec![0; n],
            compute_until: vec![0; n],
            recv_count: vec![vec![0; max_tag]; n],
            nic: (0..n).map(|_| VecDeque::new()).collect(),
            nic_flits_left: vec![0; n],
            inject_vc: vec![0; n],
            stats: SimStats {
                link_flits: vec![0; n * NUM_DIRS],
                link_wait: vec![0; n * NUM_DIRS],
                injected_flits: vec![0; n],
                ..Default::default()
            },
            cycle: 0,
            table,
        }
    }

    fn link_idx(&self, node: usize, dir: usize) -> usize {
        node * NUM_DIRS + dir
    }

    /// Run to completion (all programs finished, network drained).
    /// `max_cycles` guards against deadlock bugs; panics if exceeded.
    pub fn run(mut self, max_cycles: u64) -> SimStats {
        while !self.done() {
            self.step();
            if self.cycle > max_cycles {
                panic!(
                    "noc_sim::reference: exceeded {max_cycles} cycles at cycle {} — deadlock \
                     or undersized budget ({} core(s) unfinished)",
                    self.cycle,
                    self.pc
                        .iter()
                        .zip(&self.programs)
                        .filter(|(pc, p)| **pc < p.instrs.len())
                        .count(),
                );
            }
        }
        self.stats.cycles = self.cycle;
        self.stats
    }

    fn done(&self) -> bool {
        self.pc
            .iter()
            .zip(&self.programs)
            .all(|(pc, p)| *pc >= p.instrs.len())
            && self.network_empty()
    }

    fn network_empty(&self) -> bool {
        self.nic.iter().all(|q| q.is_empty()) && self.routers.iter().all(|r| r.occupancy == 0)
    }

    fn step(&mut self) {
        self.advance_cores();
        self.inject();
        self.switch_traversal();
        self.cycle += 1;
        self.maybe_skip_idle();
    }

    /// Fast-forward across compute-only stretches: when the network is
    /// drained, no NIC has pending packets, and every unfinished core is
    /// mid-COMPUTE, nothing can happen until the earliest compute ends —
    /// jump straight there. Waiting statistics are unaffected (no flits in
    /// flight by construction). All-or-nothing by design; the event-driven
    /// engine generalizes this per entity.
    fn maybe_skip_idle(&mut self) {
        let mut min_until = u64::MAX;
        for core in 0..self.programs.len() {
            let pc = self.pc[core];
            if pc >= self.programs[core].instrs.len() {
                continue;
            }
            // Mid-compute cores have a nonzero deadline; anything else
            // (pending Send/Recv at the PC) blocks the skip.
            let until = self.compute_until[core];
            if until > self.cycle && matches!(self.programs[core].instrs[pc], Instr::Compute { .. })
            {
                min_until = min_until.min(until);
            } else {
                return;
            }
        }
        if min_until == u64::MAX || min_until <= self.cycle {
            return;
        }
        if !self.network_empty() {
            return;
        }
        self.cycle = min_until;
    }

    /// Progress each core's instruction stream.
    fn advance_cores(&mut self) {
        for core in 0..self.programs.len() {
            loop {
                let pc = self.pc[core];
                if pc >= self.programs[core].instrs.len() {
                    break;
                }
                match self.programs[core].instrs[pc] {
                    Instr::Compute { cycles } => {
                        if self.compute_until[core] == 0 {
                            self.compute_until[core] = self.cycle + cycles;
                        }
                        if self.cycle >= self.compute_until[core] {
                            self.compute_until[core] = 0;
                            self.pc[core] += 1;
                            continue;
                        }
                        break;
                    }
                    Instr::Send { dst, bytes, tag } => {
                        // Segment into packets and queue on the NIC.
                        let flit_bytes = self.programs[core].flit_bytes.max(1.0);
                        let flits = (bytes / flit_bytes).ceil().max(1.0) as usize;
                        let mut left = flits;
                        while left > 0 {
                            let sz = left.min(MAX_PACKET_FLITS) as u32;
                            let id = self.packets.len() as u32;
                            self.packets.push(Packet {
                                dst,
                                size_flits: sz,
                                tag,
                                inject_cycle: self.cycle,
                            });
                            self.nic[core].push_back((id, 0));
                            left -= sz as usize;
                        }
                        self.pc[core] += 1;
                        continue;
                    }
                    Instr::Recv { tag, packets } => {
                        if self.recv_count[core][tag as usize] >= packets {
                            self.pc[core] += 1;
                            continue;
                        }
                        break;
                    }
                }
            }
        }
    }

    /// Inject one flit per core per cycle from the NIC into the local
    /// input port (VC 0..VCS round-robin by packet).
    fn inject(&mut self) {
        for core in 0..self.nic.len() {
            let Some(&(pkt_id, _)) = self.nic[core].front() else {
                continue;
            };
            let pkt = self.packets[pkt_id as usize];
            // Find / keep a local-input VC for this packet.
            let router = &mut self.routers[core];
            // Head flit needs a VC whose buffer is empty and unowned;
            // body flits continue on the packet's VC.
            let progress = self.nic_flits_left[core];
            let vc_slot = if progress == 0 {
                (0..VCS).find(|&v| {
                    let s = router.vc(LOCAL, v);
                    s.buf.is_empty() && s.out_port.is_none()
                })
            } else {
                Some(self.inject_vc[core])
            };
            let Some(vc) = vc_slot else { continue };
            let s = router.vc_mut(LOCAL, vc);
            if s.buf.len() >= VC_DEPTH {
                continue;
            }
            let is_head = progress == 0;
            let is_tail = progress + 1 == pkt.size_flits;
            s.buf.push_back(Flit {
                packet: pkt_id,
                is_head,
                is_tail,
            });
            router.occupancy += 1;
            if is_head {
                self.inject_vc[core] = vc;
            }
            self.stats.injected_flits[core] += 1;
            if is_tail {
                self.nic[core].pop_front();
                self.nic_flits_left[core] = 0;
            } else {
                self.nic_flits_left[core] = progress + 1;
            }
        }
    }

    /// Route computation + VC allocation + switch allocation + traversal,
    /// collapsed into one cycle per hop (aggressive single-stage router).
    fn switch_traversal(&mut self) {
        let n = self.routers.len();
        // (from_node, in_port, in_vc, out_port, flit) moves to apply.
        let mut moves: Vec<(usize, usize, usize, usize, Flit)> = Vec::new();

        for node in 0..n {
            if self.routers[node].occupancy == 0 {
                continue; // idle router, nothing to arbitrate
            }
            let at = (node / self.width, node % self.width);
            // Gather head-of-buffer requests per output port.
            let mut requests = [[(0u8, 0u8); PORTS * VCS]; PORTS];
            let mut req_len = [0usize; PORTS];
            for port in 0..PORTS {
                for vc in 0..VCS {
                    let s = self.routers[node].vc(port, vc);
                    let Some(f) = s.buf.front() else { continue };
                    let out = if f.is_head {
                        route_port_with(
                            self.table.as_deref(),
                            at,
                            self.packets[f.packet as usize].dst,
                        )
                    } else {
                        match s.out_port {
                            Some(p) => p as usize,
                            None => continue, // body before head handled
                        }
                    };
                    requests[out][req_len[out]] = (port as u8, vc as u8);
                    req_len[out] += 1;
                }
            }
            // One grant per output port, round-robin.
            for out in 0..PORTS {
                let len = req_len[out];
                if len == 0 {
                    continue;
                }
                let start = self.routers[node].rr[out];
                let pick = (0..len)
                    .map(|i| requests[out][(start + i) % len])
                    .find(|&(port, vc)| self.can_traverse(node, port as usize, vc as usize, out));
                // Waiting accounting: every requester of a *mesh* link that
                // does not move this cycle accrues one wait cycle.
                if out != LOCAL {
                    let li = self.link_idx(node, out);
                    let waiting = len - usize::from(pick.is_some());
                    self.stats.link_wait[li] += waiting as u64;
                }
                let Some((port, vc)) = pick else { continue };
                let (port, vc) = (port as usize, vc as usize);
                self.routers[node].rr[out] = self.routers[node].rr[out].wrapping_add(1);
                let flit = *self.routers[node].vc(port, vc).buf.front().unwrap();
                moves.push((node, port, vc, out, flit));
            }
        }

        // Apply moves: pop from input VC, push downstream (or eject).
        for (node, port, vc, out, flit) in moves {
            // Read the downstream VC allocation BEFORE the pop clears it on
            // tail flits (regression: tails were misrouted to VC 0).
            let alloc_vc = self.routers[node].vc(port, vc).out_vc;
            // Pop.
            {
                self.routers[node].occupancy -= 1;
                let s = self.routers[node].vc_mut(port, vc);
                s.buf.pop_front();
                if flit.is_head {
                    s.out_port = Some(out as u8);
                }
                if flit.is_tail {
                    s.out_port = None;
                    s.out_vc = None;
                }
            }
            // Return a credit upstream for the freed slot.
            self.return_credit(node, port, vc);

            if out == LOCAL {
                // Ejected at destination.
                let pkt = self.packets[flit.packet as usize];
                if flit.is_tail {
                    let core = node;
                    self.recv_count[core][pkt.tag as usize] += 1;
                    self.stats.packets_done += 1;
                    self.stats.packet_latency_sum += self.cycle - pkt.inject_cycle;
                }
                continue;
            }

            let li = self.link_idx(node, out);
            self.stats.link_flits[li] += 1;
            let (down, down_port) = neighbor_of(self.width, node, out);
            // Downstream VC: allocated at the head, held through the tail.
            let dvc = alloc_vc.expect("traversing flit must hold a VC allocation") as usize;
            self.routers[down].occupancy += 1;
            let s = self.routers[down].vc_mut(down_port, dvc);
            s.buf.push_back(flit);
            self.routers[node].credits[out][dvc] -= 1;
        }
    }

    /// Check credits / downstream VC availability; for head flits, also
    /// perform VC allocation (recorded in `out_vc`).
    fn can_traverse(&mut self, node: usize, port: usize, vc: usize, out: usize) -> bool {
        if out == LOCAL {
            return true; // ejection always accepted
        }
        let flit = *self.routers[node].vc(port, vc).buf.front().unwrap();
        let (down, down_port) = neighbor_of(self.width, node, out);
        if flit.is_head && self.routers[node].vc(port, vc).out_vc.is_none() {
            // Allocate a downstream VC: must be empty and unowned.
            let free = (0..VCS).find(|&v| {
                self.routers[node].credits[out][v] as usize == VC_DEPTH
                    && self.routers[down].vc(down_port, v).buf.is_empty()
                    && self.routers[down].vc(down_port, v).out_port.is_none()
            });
            match free {
                Some(v) => {
                    self.routers[node].vc_mut(port, vc).out_vc = Some(v as u8);
                }
                None => return false,
            }
        }
        let dvc = match self.routers[node].vc(port, vc).out_vc {
            Some(v) => v as usize,
            None => return false, // body flit before head allocated (shouldn't happen)
        };
        self.routers[node].credits[out][dvc] > 0
    }

    /// Credit return for the input buffer slot freed at (node, port, vc).
    fn return_credit(&mut self, node: usize, port: usize, vc: usize) {
        if port == LOCAL {
            return;
        }
        let (up, up_out) = neighbor_of(self.width, node, port);
        debug_assert!(up < self.routers.len());
        self.routers[up].credits[up_out][vc] =
            (self.routers[up].credits[up_out][vc] + 1).min(VC_DEPTH as u8);
    }
}
