//! GNN training-set generation (paper §VIII-A "GNN Training Setup"):
//! random WSC core configs × benchmark workloads → Workload Compiler →
//! CA simulation → per-link mean waiting times as regression targets.
//!
//! Emitted as JSON (consumed by `python/compile/train.py`). Each sample is
//! one chunk execution on an `h × w` mesh: node features (injection rates),
//! edge features (per-link transmitted volume + bandwidth), and labels
//! (per-link mean waiting time in cycles).

use crate::arch::{CoreConfig, Dataflow};
use crate::compiler::{compile_chunk, routing::NUM_DIRS};
use crate::eval::op_level::{chunk_latency, NocModel};
use crate::noc_sim::{naive_compute_cycles, simulate_chunk_result, SimError};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::models::benchmarks;
use crate::workload::{OpGraph, Phase};

/// One dataset sample (matches the Python trainer's expected schema).
pub struct Sample {
    pub height: usize,
    pub width: usize,
    pub noc_bw_bits: usize,
    /// Flits injected per node per cycle.
    pub inject_rate: Vec<f64>,
    /// Bytes routed over each directed link (dense `link_index` order).
    pub link_bytes: Vec<f64>,
    /// Flits observed per link.
    pub link_flits: Vec<f64>,
    /// Label: mean waiting cycles per flit per link.
    pub link_wait: Vec<f64>,
    /// End-to-end chunk cycles (Fig. 7 ground truth).
    pub total_cycles: u64,
    /// Zero-load analytical estimate (feature normalizer shared with the
    /// DSE runtime — see python/compile/features.py).
    pub t0_cycles: f64,
    /// Bytes injected per node (from the compiled flows, not the sim).
    pub node_bytes: Vec<f64>,
}

impl Sample {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("height", Json::Num(self.height as f64))
            .set("width", Json::Num(self.width as f64))
            .set("noc_bw_bits", Json::Num(self.noc_bw_bits as f64))
            .set("inject_rate", Json::from_f64_slice(&self.inject_rate))
            .set("link_bytes", Json::from_f64_slice(&self.link_bytes))
            .set("link_flits", Json::from_f64_slice(&self.link_flits))
            .set("link_wait", Json::from_f64_slice(&self.link_wait))
            .set("total_cycles", Json::Num(self.total_cycles as f64))
            .set("t0_cycles", Json::Num(self.t0_cycles))
            .set("node_bytes", Json::from_f64_slice(&self.node_bytes));
        o
    }
}

/// Generate one sample: a random core config + a random small-benchmark
/// chunk on a random mesh (bounded so CA simulation stays seconds-scale).
/// A budget overrun in the CA simulation propagates as [`SimError`]
/// instead of panicking the whole generation run.
pub fn gen_sample(rng: &mut Rng) -> Result<Sample, SimError> {
    let specs = benchmarks();
    let spec = specs[rng.below(4)].clone(); // the small end of Table II
    let noc_bw_bits = *rng.choose(&[128usize, 256, 512, 1024]);
    let mac_num = *rng.choose(&[128usize, 256, 512, 1024]);
    let core = CoreConfig {
        dataflow: *rng.choose(&Dataflow::ALL),
        mac_num,
        buffer_kb: 128,
        buffer_bw_bits: 256,
        noc_bw_bits,
    };
    let h = rng.range(3, 10);
    let w = rng.range(3, 10);
    let tp = 1 << rng.below(4);
    let phase = *rng.choose(&[Phase::Prefill, Phase::Decode, Phase::Training]);
    // Scale the workload down: a fraction of one layer's sequence keeps
    // flow volumes mesh-sized (labels depend on *relative* load).
    let mut small = spec.clone();
    small.seq_len = *rng.choose(&[32usize, 64, 128]);
    let g = OpGraph::transformer_chunk(&small, 1, 1, tp * 8, phase, false);
    let chunk = compile_chunk(&g, h, w, &core);

    let cycles_for = |op: usize| {
        let a = &chunk.assignments[op];
        naive_compute_cycles(a.flops_per_core, core.mac_num)
            .max((a.in_bytes_per_core / (core.buffer_bw_bits as f64 / 8.0)).ceil() as u64)
    };
    let stats = simulate_chunk_result(&chunk, noc_bw_bits, &cycles_for, 80_000_000)?;
    let zeros = vec![0.0; h * w * NUM_DIRS];
    let t0 = chunk_latency(&chunk, &core, 1.0, NocModel::LinkWaits(&zeros)).cycles;

    let cyc = stats.cycles.max(1) as f64;
    Ok(Sample {
        height: h,
        width: w,
        noc_bw_bits,
        inject_rate: stats
            .injected_flits
            .iter()
            .map(|&f| f as f64 / cyc)
            .collect(),
        link_bytes: chunk.link_loads(),
        link_flits: stats.link_flits.iter().map(|&f| f as f64).collect(),
        link_wait: stats.link_wait_mean(),
        total_cycles: stats.cycles,
        t0_cycles: t0,
        node_bytes: chunk.node_injected_bytes(),
    })
}

/// Per-sample RNG streams: each sample draws from an independent fork of
/// the base seed, so the dataset is identical whether samples are
/// generated serially or fanned out over the pool.
fn sample_streams(n: usize, seed: u64) -> Vec<Rng> {
    let mut base = Rng::new(seed);
    (0..n).map(|i| base.fork(i as u64)).collect()
}

fn dataset_doc(seed: u64, samples: Vec<Json>) -> Json {
    let mut doc = Json::obj();
    doc.set("version", Json::Num(1.0))
        .set("num_dirs", Json::Num(NUM_DIRS as f64))
        .set("seed", Json::Num(seed as f64))
        .set("samples", Json::Arr(samples));
    doc
}

/// Generate `n` samples into the dataset JSON document, fanning the
/// independent CA simulations out over [`crate::util::pool`]. The first
/// CA budget overrun (by sample index) propagates as [`SimError`].
pub fn gen_dataset(n: usize, seed: u64) -> Result<Json, SimError> {
    let rngs = sample_streams(n, seed);
    let samples: Result<Vec<Json>, SimError> = crate::util::pool::par_map(&rngs, |rng| {
        let mut rng = rng.clone();
        gen_sample(&mut rng).map(|s| s.to_json())
    })
    .into_iter()
    .collect();
    Ok(dataset_doc(seed, samples?))
}

/// Serial [`gen_dataset`] — identical output, one sample at a time. Kept
/// for single-core environments and as the baseline the `perf_hotpath`
/// bench measures the pooled fan-out against.
pub fn gen_dataset_serial(n: usize, seed: u64) -> Result<Json, SimError> {
    let samples: Result<Vec<Json>, SimError> = sample_streams(n, seed)
        .into_iter()
        .map(|mut rng| gen_sample(&mut rng).map(|s| s.to_json()))
        .collect();
    Ok(dataset_doc(seed, samples?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_shapes_consistent() {
        let mut rng = Rng::new(99);
        let s = gen_sample(&mut rng).expect("CA simulation within budget");
        let n = s.height * s.width;
        assert_eq!(s.inject_rate.len(), n);
        assert_eq!(s.link_bytes.len(), n * NUM_DIRS);
        assert_eq!(s.link_wait.len(), n * NUM_DIRS);
        assert_eq!(s.node_bytes.len(), n);
        assert!(s.total_cycles > 0);
        assert!(s.t0_cycles > 0.0);
        // Some traffic must have flowed.
        assert!(s.link_flits.iter().sum::<f64>() > 0.0);
        // Loaded links correlate: every link with waiting also saw flits.
        for (i, &w) in s.link_wait.iter().enumerate() {
            if w > 0.0 {
                assert!(s.link_flits[i] > 0.0, "wait without flits at {i}");
            }
        }
    }

    #[test]
    fn dataset_deterministic_and_serial_matches_parallel() {
        // Pooled generation must emit byte-identical JSON to the serial
        // path (per-sample forked RNG streams + bit-identical simulator).
        let a = gen_dataset(2, 7).expect("within budget").to_string();
        let b = gen_dataset_serial(2, 7).expect("within budget").to_string();
        assert_eq!(a, b);
    }

    #[test]
    fn dataset_json_roundtrip() {
        let d = gen_dataset(2, 11).expect("within budget");
        let parsed = Json::parse(&d.to_string()).unwrap();
        let samples = parsed.get("samples").unwrap().as_arr().unwrap();
        assert_eq!(samples.len(), 2);
        assert!(samples[0].get("link_wait").unwrap().as_f64_vec().is_some());
    }
}
