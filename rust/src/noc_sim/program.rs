//! Micro-instruction generation (paper §VIII-A: "We design a series of
//! instructions and micro-instructions to describe the compute, memory
//! access and communication of WSC cores").
//!
//! A [`CompiledChunk`] becomes one [`CoreProgram`] per core of the region:
//! per op (in topological order) the core sends its intra-op systolic
//! feeds, waits for its expected input packets, computes the analytic tile
//! latency, then sends the redistribution flows to downstream ops.

use std::collections::HashMap;

use crate::compiler::CompiledChunk;
use crate::noc_sim::MAX_PACKET_FLITS;

/// Core micro-instructions.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Instr {
    /// Busy for `cycles` (analytic compute + local memory estimate).
    Compute { cycles: u64 },
    /// Send `bytes` to core `dst`, tagged with the consuming op.
    Send {
        dst: (usize, usize),
        bytes: f64,
        tag: u32,
    },
    /// Block until `packets` packets tagged `tag` have arrived.
    Recv { tag: u32, packets: u32 },
}

/// One core's instruction stream.
#[derive(Debug, Clone, Default)]
pub struct CoreProgram {
    pub instrs: Vec<Instr>,
    /// Flit payload in bytes (from the core's NoC width).
    pub flit_bytes: f64,
}

/// Packets a flow of `bytes` becomes (must match the simulator's
/// segmentation).
pub fn packets_for(bytes: f64, flit_bytes: f64) -> u32 {
    let flits = (bytes / flit_bytes.max(1.0)).ceil().max(1.0) as u64;
    flits.div_ceil(MAX_PACKET_FLITS as u64) as u32
}

/// Static satisfiability check for hand-built programs (test generators,
/// bench scenarios): every SEND destination must be on the mesh, and every
/// RECV must be coverable by the packets addressed to its core and tag
/// (`recv_count` is cumulative, so the requirement per (core, tag) is the
/// *max* RECV threshold, which the total sent packets must reach). This
/// catches the common never-satisfiable-RECV deadlock; it cannot rule out
/// ordering cycles (a SEND sequenced after a RECV that transitively waits
/// on it).
pub fn validate_programs(programs: &[CoreProgram], h: usize, w: usize) -> Result<(), String> {
    if programs.len() != h * w {
        return Err(format!("{} programs for a {h}x{w} mesh", programs.len()));
    }
    let mut sent: HashMap<(usize, u32), u64> = HashMap::new();
    let mut need: HashMap<(usize, u32), u64> = HashMap::new();
    for (core, p) in programs.iter().enumerate() {
        for i in &p.instrs {
            match *i {
                Instr::Send { dst, bytes, tag } => {
                    if dst.0 >= h || dst.1 >= w {
                        return Err(format!("core {core}: send to off-mesh dst {dst:?}"));
                    }
                    let dst_core = dst.0 * w + dst.1;
                    *sent.entry((dst_core, tag)).or_default() +=
                        packets_for(bytes, p.flit_bytes) as u64;
                }
                Instr::Recv { tag, packets } => {
                    let e = need.entry((core, tag)).or_default();
                    *e = (*e).max(packets as u64);
                }
                Instr::Compute { .. } => {}
            }
        }
    }
    for (&(core, tag), &n) in &need {
        let s = sent.get(&(core, tag)).copied().unwrap_or(0);
        if s < n {
            return Err(format!(
                "core {core} tag {tag}: recv expects {n} packet(s) but only {s} addressed to it"
            ));
        }
    }
    Ok(())
}

/// Build per-core programs. `cycles_for(op)` supplies the per-core compute
/// latency of each op (tile-level analytic estimate).
pub fn build_programs(
    chunk: &CompiledChunk,
    noc_bw_bits: usize,
    cycles_for: &dyn Fn(usize) -> u64,
) -> Vec<CoreProgram> {
    let flit_bytes = crate::noc_sim::flit_bytes(noc_bw_bits);
    let n = chunk.region_h * chunk.region_w;
    let mut programs = vec![
        CoreProgram {
            instrs: Vec::new(),
            flit_bytes,
        };
        n
    ];
    let node = |rc: (usize, usize)| rc.0 * chunk.region_w + rc.1;

    // Index flows by (src core, producing op) and count expected packets
    // per (dst core, consuming op).
    let mut sends: HashMap<(usize, usize), Vec<usize>> = HashMap::new();
    let mut expected: HashMap<(usize, usize), u32> = HashMap::new();
    for (i, f) in chunk.flows.iter().enumerate() {
        sends.entry((node(f.src), f.src_op)).or_default().push(i);
        *expected.entry((node(f.dst), f.dst_op)).or_default() +=
            packets_for(f.bytes, flit_bytes);
    }

    for a in &chunk.assignments {
        let op = a.op;
        let cycles = cycles_for(op).max(1);
        for r in 0..a.placement.grid_h {
            for c in 0..a.placement.grid_w {
                // Placement coordinates are logical on faulted compiles —
                // core_node maps them onto the physical mesh (identity on
                // the pristine path). Flow endpoints are already physical.
                let core = chunk.core_node(a.placement.physical(r, c));
                let prog = &mut programs[core];
                // 1. Intra-op systolic feeds (sent eagerly, non-blocking).
                if let Some(flow_ids) = sends.get(&(core, op)) {
                    for &fi in flow_ids {
                        let f = chunk.flows[fi];
                        if f.dst_op == op {
                            prog.instrs.push(Instr::Send {
                                dst: f.dst,
                                bytes: f.bytes,
                                tag: f.dst_op as u32,
                            });
                        }
                    }
                }
                // 2. Wait for all inputs of this op.
                if let Some(&pkts) = expected.get(&(core, op)) {
                    prog.instrs.push(Instr::Recv {
                        tag: op as u32,
                        packets: pkts,
                    });
                }
                // 3. Compute.
                prog.instrs.push(Instr::Compute { cycles });
                // 4. Redistribution sends to downstream ops.
                if let Some(flow_ids) = sends.get(&(core, op)) {
                    for &fi in flow_ids {
                        let f = chunk.flows[fi];
                        if f.dst_op != op {
                            prog.instrs.push(Instr::Send {
                                dst: f.dst,
                                bytes: f.bytes,
                                tag: f.dst_op as u32,
                            });
                        }
                    }
                }
            }
        }
    }
    programs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{CoreConfig, Dataflow};
    use crate::compiler::compile_chunk;
    use crate::workload::models::benchmarks;
    use crate::workload::{OpGraph, Phase};

    fn chunk() -> CompiledChunk {
        let spec = benchmarks()[0].clone();
        let g = OpGraph::transformer_chunk(&spec, 1, 1, 8, Phase::Prefill, false);
        let core = CoreConfig {
            dataflow: Dataflow::WS,
            mac_num: 512,
            buffer_kb: 128,
            buffer_bw_bits: 256,
            noc_bw_bits: 512,
        };
        compile_chunk(&g, 4, 4, &core)
    }

    #[test]
    fn program_per_core() {
        let c = chunk();
        let progs = build_programs(&c, 512, &|_| 10);
        assert_eq!(progs.len(), 16);
        assert!(progs.iter().any(|p| !p.instrs.is_empty()));
    }

    #[test]
    fn sends_match_flows() {
        let c = chunk();
        let progs = build_programs(&c, 512, &|_| 10);
        let sent: f64 = progs
            .iter()
            .flat_map(|p| &p.instrs)
            .filter_map(|i| match i {
                Instr::Send { bytes, .. } => Some(*bytes),
                _ => None,
            })
            .sum();
        let rel = (sent - c.total_flow_bytes()).abs() / c.total_flow_bytes();
        assert!(rel < 1e-9, "rel={rel}");
    }

    #[test]
    fn recv_counts_match_send_packets() {
        let c = chunk();
        let progs = build_programs(&c, 512, &|_| 10);
        let flit_bytes = crate::noc_sim::flit_bytes(512);
        // Per tag: total packets sent == total packets expected by Recvs.
        let mut sent: HashMap<u32, u32> = HashMap::new();
        let mut recv: HashMap<u32, u32> = HashMap::new();
        for p in &progs {
            for i in &p.instrs {
                match *i {
                    Instr::Send { bytes, tag, .. } => {
                        *sent.entry(tag).or_default() += packets_for(bytes, flit_bytes)
                    }
                    Instr::Recv { tag, packets } => *recv.entry(tag).or_default() += packets,
                    _ => {}
                }
            }
        }
        assert_eq!(sent, recv);
    }

    #[test]
    fn packets_for_segmentation() {
        let fb = 64.0;
        assert_eq!(packets_for(1.0, fb), 1);
        assert_eq!(packets_for(64.0 * 16.0, fb), 1); // exactly one max packet
        assert_eq!(packets_for(64.0 * 16.0 + 1.0, fb), 2);
    }
}
