//! Design Point Validator (paper §V-E, Fig. 2).
//!
//! Checks, in order: SRAM-compiler feasibility, reticle area, TSV stress
//! cap, yield reachability (with redundancy), wafer area, and the 15 kW
//! power ceiling. Successful validation returns the physical
//! characterization so downstream evaluation never recomputes it.

use crate::arch::constants as k;
use crate::components::{wafer_phys, PhysError, WaferPhys};
use crate::design_space::DesignPoint;

/// Constraint violations (§V-E). `Phys` wraps assembly-level failures from
/// the component estimator; `Power` is checked here against the wafer cap.
#[derive(Debug, Clone, PartialEq)]
pub enum Violation {
    Phys(PhysError),
    Power { power_w: f64, limit_w: f64 },
    HeteroRatio(f64),
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Violation::Phys(e) => e.fmt(f),
            Violation::Power { power_w, limit_w } => {
                write!(f, "peak power {power_w:.0} W exceeds wafer limit {limit_w:.0} W")
            }
            Violation::HeteroRatio(r) => write!(f, "prefill ratio {r} outside (0, 1)"),
        }
    }
}

impl std::error::Error for Violation {}

impl From<PhysError> for Violation {
    fn from(e: PhysError) -> Violation {
        Violation::Phys(e)
    }
}

/// A validated design point with its physical characterization.
#[derive(Debug, Clone)]
pub struct Validated {
    pub point: DesignPoint,
    pub phys: WaferPhys,
}

/// Run the full §V-E constraint chain.
pub fn validate(point: &DesignPoint) -> Result<Validated, Violation> {
    if !(point.hetero.prefill_ratio > 0.0 && point.hetero.prefill_ratio < 1.0) {
        return Err(Violation::HeteroRatio(point.hetero.prefill_ratio));
    }
    let phys = wafer_phys(&point.wsc)?;
    if phys.peak_power_w > k::WAFER_POWER_LIMIT_W {
        return Err(Violation::Power {
            power_w: phys.peak_power_w,
            limit_w: k::WAFER_POWER_LIMIT_W,
        });
    }
    Ok(Validated {
        point: *point,
        phys,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{CoreConfig, Dataflow, IntegrationStyle, MemoryKind, ReticleConfig, WscConfig};
    use crate::design_space::{self, DesignPoint};

    fn big_hot_point() -> DesignPoint {
        // Max everything: should trip the power constraint (or area).
        DesignPoint::homogeneous(WscConfig {
            reticle: ReticleConfig {
                core: CoreConfig {
                    dataflow: Dataflow::WS,
                    mac_num: 4096,
                    buffer_kb: 2048,
                    buffer_bw_bits: 4096,
                    noc_bw_bits: 4096,
                },
                array_h: 8,
                array_w: 8,
                inter_reticle_bw_ratio: 2.0,
                memory: MemoryKind::OffChip,
            },
            reticle_h: 8,
            reticle_w: 8,
            integration: IntegrationStyle::InfoSoW,
            mem_ctrl_count: 24,
            nic_count: 16,
        })
    }

    #[test]
    fn reference_validates_and_hot_point_fails() {
        assert!(validate(&design_space::reference_point()).is_ok());
        let err = validate(&big_hot_point());
        assert!(err.is_err(), "max-config point should violate something");
    }

    #[test]
    fn hetero_ratio_bounds() {
        let mut p = design_space::reference_point();
        p.hetero.prefill_ratio = 0.0;
        assert!(matches!(validate(&p), Err(Violation::HeteroRatio(_))));
        p.hetero.prefill_ratio = 1.0;
        assert!(matches!(validate(&p), Err(Violation::HeteroRatio(_))));
    }

    #[test]
    fn prop_validated_points_satisfy_all_constraints() {
        crate::util::prop::check(
            "validated => constraints hold",
            |r| {
                let mut rng = r.fork(0);
                design_space::sample_valid(&mut rng, 3000)
            },
            |v| {
                let Some(v) = v else { return Ok(()) }; // rare: no point found
                let phys = &v.phys;
                if phys.peak_power_w > crate::arch::constants::WAFER_POWER_LIMIT_W {
                    return Err(format!("power {}", phys.peak_power_w));
                }
                if phys.wafer_yield < crate::arch::constants::YIELD_TARGET {
                    return Err(format!("yield {}", phys.wafer_yield));
                }
                if phys.reticle.tsv.stress_utilization > 1.0 {
                    return Err("stress violated".into());
                }
                if phys.reticle.width_mm > 33.0 + 1e-9 || phys.reticle.height_mm > 33.0 + 1e-9 {
                    return Err("reticle overflow".into());
                }
                Ok(())
            },
        );
    }
}
