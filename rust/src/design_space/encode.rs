//! Unit-cube encoding of design points for the GP surrogate (paper §VII).
//!
//! Discrete power-of-two grids are log-scaled; categorical parameters map
//! to evenly spaced levels. `decode(encode(p))` snaps back to the nearest
//! grid values, so the explorer can move in continuous space while only
//! ever evaluating legal grid points.

use crate::arch::constants::INTER_WAFER_LINK_LATENCY_S;
use crate::arch::{
    CoreConfig, Dataflow, IntegrationStyle, InterWaferNet, InterWaferTopology, MemoryKind,
    ReticleConfig, WscConfig,
};
use crate::design_space::{candidates, default_mem_ctrl_count, default_nic_count, stack_capacity_gb, DesignPoint};

/// Encoded dimensionality.
pub const DIMS: usize = 15;

fn log_unit(x: f64, lo: f64, hi: f64) -> f64 {
    ((x.ln() - lo.ln()) / (hi.ln() - lo.ln())).clamp(0.0, 1.0)
}

fn unit_log(u: f64, lo: f64, hi: f64) -> f64 {
    (lo.ln() + u.clamp(0.0, 1.0) * (hi.ln() - lo.ln())).exp()
}

fn lin_unit(x: f64, lo: f64, hi: f64) -> f64 {
    ((x - lo) / (hi - lo)).clamp(0.0, 1.0)
}

fn unit_lin(u: f64, lo: f64, hi: f64) -> f64 {
    lo + u.clamp(0.0, 1.0) * (hi - lo)
}

fn nearest_usize(grid: &[usize], target: f64) -> usize {
    *grid
        .iter()
        .min_by(|a, b| {
            let da = (**a as f64 - target).abs();
            let db = (**b as f64 - target).abs();
            da.partial_cmp(&db).unwrap()
        })
        .unwrap()
}

fn nearest_f64(grid: &[f64], target: f64) -> f64 {
    *grid
        .iter()
        .min_by(|a, b| {
            (*a - target)
                .abs()
                .partial_cmp(&(*b - target).abs())
                .unwrap()
        })
        .unwrap()
}

/// Encode into [0,1]^DIMS:
/// [dataflow, log mac, log buf_kb, log buf_bw, log noc_bw, ir_ratio,
///  mem_kind, log stack_bw, array_h, array_w, reticle_h, reticle_w,
///  iw_topology, log iw_link_bw, log iw_links]
/// (integration style rides on `mem_kind`'s fractional band — see decode).
pub fn encode(p: &DesignPoint) -> [f64; DIMS] {
    let c = &p.wsc.reticle.core;
    let r = &p.wsc.reticle;
    let df = match c.dataflow {
        Dataflow::WS => 0.0,
        Dataflow::IS => 0.5,
        Dataflow::OS => 1.0,
    };
    let (mem_kind, stack_bw): (f64, f64) = match r.memory {
        MemoryKind::OffChip => (0.25, candidates::STACK_BW[0]),
        MemoryKind::Stacking {
            bw_tbps_per_100mm2, ..
        } => (0.75, bw_tbps_per_100mm2),
    };
    // Integration is folded into mem_kind's quadrant: [0,0.5) offchip,
    // [0.5,1] stacking; within each half, lower quarter = DieStitching.
    let integ_shift = match p.wsc.integration {
        IntegrationStyle::DieStitching => -0.125,
        IntegrationStyle::InfoSoW => 0.125,
    };
    let iw = &p.interwafer;
    let iw_topo = match iw.topology {
        InterWaferTopology::Ring => 0.0,
        InterWaferTopology::Mesh2d => 0.5,
        InterWaferTopology::Switched => 1.0,
    };
    [
        df,
        log_unit(c.mac_num as f64, 8.0, 4096.0),
        log_unit(c.buffer_kb as f64, 32.0, 2048.0),
        log_unit(c.buffer_bw_bits as f64, 32.0, 4096.0),
        log_unit(c.noc_bw_bits as f64, 32.0, 4096.0),
        lin_unit(r.inter_reticle_bw_ratio, 0.2, 2.0),
        (mem_kind + integ_shift).clamp(0.0, 1.0),
        log_unit(stack_bw, 0.25, 4.0),
        lin_unit(r.array_h as f64, 1.0, candidates::MAX_ARRAY_DIM as f64),
        lin_unit(r.array_w as f64, 1.0, candidates::MAX_ARRAY_DIM as f64),
        lin_unit(p.wsc.reticle_h as f64, 1.0, candidates::MAX_RETICLE_DIM as f64),
        lin_unit(p.wsc.reticle_w as f64, 1.0, candidates::MAX_RETICLE_DIM as f64),
        iw_topo,
        log_unit(iw.link_bandwidth, 25.0e9, 400.0e9),
        log_unit(iw.links_per_wafer as f64, 4.0, 32.0),
    ]
}

/// Decode from the unit cube, snapping to the candidate grids. Always
/// produces a *syntactically* legal point; §V-E validity still requires
/// [`super::validate`].
pub fn decode(x: &[f64; DIMS]) -> DesignPoint {
    let dataflow = if x[0] < 1.0 / 3.0 {
        Dataflow::WS
    } else if x[0] < 2.0 / 3.0 {
        Dataflow::IS
    } else {
        Dataflow::OS
    };
    let mac_num = nearest_usize(&candidates::MAC_NUM, unit_log(x[1], 8.0, 4096.0));
    let buffer_kb = nearest_usize(&candidates::BUFFER_KB, unit_log(x[2], 32.0, 2048.0));
    let buffer_bw_bits = nearest_usize(&candidates::BUFFER_BW, unit_log(x[3], 32.0, 4096.0));
    let noc_bw_bits = nearest_usize(&candidates::NOC_BW, unit_log(x[4], 32.0, 4096.0));
    let ir = nearest_f64(&candidates::INTER_RETICLE_RATIO, unit_lin(x[5], 0.2, 2.0));

    let stacking = x[6] >= 0.5;
    let quarter = if stacking { x[6] - 0.5 } else { x[6] } * 4.0; // 0..2 within half
    let integration = if quarter < 1.0 {
        IntegrationStyle::DieStitching
    } else {
        IntegrationStyle::InfoSoW
    };
    let memory = if stacking {
        let bw = nearest_f64(&candidates::STACK_BW, unit_log(x[7], 0.25, 4.0));
        MemoryKind::Stacking {
            bw_tbps_per_100mm2: bw,
            capacity_gb: stack_capacity_gb(bw),
        }
    } else {
        MemoryKind::OffChip
    };

    let snap_dim = |u: f64, max: usize| -> usize {
        (unit_lin(u, 1.0, max as f64).round() as usize).clamp(1, max)
    };

    let mut p = DesignPoint::homogeneous(WscConfig {
        reticle: ReticleConfig {
            core: CoreConfig {
                dataflow,
                mac_num,
                buffer_kb,
                buffer_bw_bits,
                noc_bw_bits,
            },
            array_h: snap_dim(x[8], candidates::MAX_ARRAY_DIM),
            array_w: snap_dim(x[9], candidates::MAX_ARRAY_DIM),
            inter_reticle_bw_ratio: ir,
            memory,
        },
        reticle_h: snap_dim(x[10], candidates::MAX_RETICLE_DIM),
        reticle_w: snap_dim(x[11], candidates::MAX_RETICLE_DIM),
        integration,
        mem_ctrl_count: default_mem_ctrl_count(),
        nic_count: default_nic_count(),
    });
    p.interwafer = InterWaferNet {
        topology: if x[12] < 1.0 / 3.0 {
            InterWaferTopology::Ring
        } else if x[12] < 2.0 / 3.0 {
            InterWaferTopology::Mesh2d
        } else {
            InterWaferTopology::Switched
        },
        links_per_wafer: nearest_usize(&candidates::IW_LINKS, unit_log(x[14], 4.0, 32.0)),
        link_bandwidth: nearest_f64(&candidates::IW_LINK_BW, unit_log(x[13], 25.0e9, 400.0e9)),
        link_latency: INTER_WAFER_LINK_LATENCY_S,
    };
    p
}

/// Squared Euclidean distance in encoded space (used by the explorer for
/// candidate dedup).
pub fn dist2(a: &[f64; DIMS], b: &[f64; DIMS]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design_space::{reference_point, sample_raw};
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_reference() {
        let p = reference_point();
        let x = encode(&p);
        let q = decode(&x);
        assert_eq!(p.wsc, q.wsc);
        assert_eq!(p.interwafer, q.interwafer);
    }

    #[test]
    fn prop_encode_decode_fixpoint() {
        // decode(encode(p)) == p for all grid points (snapping is exact on
        // grid values).
        crate::util::prop::check(
            "encode/decode is a fixpoint on grid points",
            |r| {
                let mut rng = r.fork(0);
                sample_raw(&mut rng)
            },
            |p| {
                let q = decode(&encode(p));
                if q.wsc != p.wsc {
                    return Err(format!("decoded {:?}\n != {:?}", q.wsc, p.wsc));
                }
                if q.interwafer != p.interwafer {
                    return Err(format!(
                        "decoded net {:?}\n != {:?}",
                        q.interwafer, p.interwafer
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_decode_total_on_cube() {
        // Any point of the cube decodes to a syntactically legal config.
        crate::util::prop::check(
            "decode total",
            |r| {
                let mut x = [0.0; DIMS];
                for v in &mut x {
                    *v = r.f64();
                }
                x
            },
            |x| {
                let p = decode(x);
                let c = &p.wsc.reticle.core;
                if !candidates::MAC_NUM.contains(&c.mac_num) {
                    return Err("mac off grid".into());
                }
                if !candidates::BUFFER_KB.contains(&c.buffer_kb) {
                    return Err("buffer off grid".into());
                }
                if p.wsc.reticle.array_h == 0 || p.wsc.reticle_h == 0 {
                    return Err("zero dim".into());
                }
                if !candidates::IW_LINKS.contains(&p.interwafer.links_per_wafer) {
                    return Err("iw links off grid".into());
                }
                if !candidates::IW_LINK_BW.contains(&p.interwafer.link_bandwidth) {
                    return Err("iw link bw off grid".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn encoded_in_unit_cube() {
        let mut rng = Rng::new(31);
        for _ in 0..100 {
            let p = sample_raw(&mut rng);
            for (i, v) in encode(&p).iter().enumerate() {
                assert!((0.0..=1.0).contains(v), "dim {i} = {v}");
            }
        }
    }

    #[test]
    fn dist2_zero_iff_same() {
        let p = reference_point();
        let x = encode(&p);
        assert_eq!(dist2(&x, &x), 0.0);
    }
}
