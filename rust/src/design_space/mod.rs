//! Design space construction (paper §V, Table I).
//!
//! Candidate grids for every WSC architecture parameter, random sampling of
//! *validated* design points, the unit-cube encoding consumed by the GP
//! surrogate, and the Design Point Validator (§V-E constraints).

pub mod encode;
pub mod validator;

use crate::arch::constants::INTER_WAFER_LINK_LATENCY_S;
use crate::arch::{
    CoreConfig, Dataflow, HeteroConfig, IntegrationStyle, InterWaferNet, InterWaferTopology,
    MemoryKind, ReticleConfig, WscConfig,
};
use crate::util::rng::Rng;

pub use encode::{decode, encode, DIMS};
pub use validator::{validate, Validated, Violation};

/// Candidate values (Table I). Power-of-two grids for the core parameters,
/// a linear grid for the inter-reticle ratio, a log grid for stacking
/// bandwidth density.
pub mod candidates {
    pub const MAC_NUM: [usize; 10] = [8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096];
    pub const BUFFER_KB: [usize; 7] = [32, 64, 128, 256, 512, 1024, 2048];
    pub const BUFFER_BW: [usize; 8] = [32, 64, 128, 256, 512, 1024, 2048, 4096];
    pub const NOC_BW: [usize; 8] = [32, 64, 128, 256, 512, 1024, 2048, 4096];
    pub const INTER_RETICLE_RATIO: [f64; 10] =
        [0.2, 0.4, 0.6, 0.8, 1.0, 1.2, 1.4, 1.6, 1.8, 2.0];
    /// TB/s per 100 mm² (Table I: 0.25–4).
    pub const STACK_BW: [f64; 9] = [0.25, 0.35, 0.5, 0.7, 1.0, 1.4, 2.0, 2.8, 4.0];
    /// Core/reticle array dims range from 1 to the max fitting the area
    /// constraints; we cap enumeration at these bounds.
    pub const MAX_ARRAY_DIM: usize = 32;
    pub const MAX_RETICLE_DIM: usize = 16;
    /// Inter-wafer scale-out axes (§VIII-A): external links per wafer and
    /// a log grid of per-link bandwidth around the paper's 100 GB/s NIC.
    pub const IW_LINKS: [usize; 4] = [4, 8, 16, 32];
    pub const IW_LINK_BW: [f64; 5] = [25.0e9, 50.0e9, 100.0e9, 200.0e9, 400.0e9];
}

/// Stacked-DRAM capacity implied by bandwidth density (paper §VIII-A:
/// linear fit over existing stacked-memory configurations — capacity and
/// bandwidth trade off). Clamped to Table I's 8–40 GB.
pub fn stack_capacity_gb(bw_tbps_per_100mm2: f64) -> f64 {
    (42.0 - 8.5 * bw_tbps_per_100mm2).clamp(8.0, 40.0)
}

/// Wafer-edge interface provisioning: one memory controller / NIC per
/// ~25 mm of wafer perimeter (fixed, not searched — Table I fixes the
/// per-interface bandwidths).
pub fn default_mem_ctrl_count() -> usize {
    24
}

pub fn default_nic_count() -> usize {
    16
}

/// A design point: the wafer config plus (for inference studies) the
/// heterogeneity configuration and (for multi-wafer systems) the
/// inter-wafer network. The net is inert at `wafers: 1` — single-wafer
/// evaluations never consult it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DesignPoint {
    pub wsc: WscConfig,
    pub hetero: HeteroConfig,
    pub interwafer: InterWaferNet,
}

impl DesignPoint {
    pub fn homogeneous(wsc: WscConfig) -> DesignPoint {
        let interwafer = InterWaferNet::default_for(wsc.nic_count);
        DesignPoint {
            wsc,
            hetero: HeteroConfig::homogeneous(),
            interwafer,
        }
    }
}

/// Number of feasible-grid combinations before constraint filtering —
/// the headline "design space size" (paper: 8.4e14 for their grids; ours
/// differs by grid resolution but lands in the same regime).
pub fn cardinality() -> f64 {
    let core = 3.0
        * candidates::MAC_NUM.len() as f64
        * candidates::BUFFER_KB.len() as f64
        * candidates::BUFFER_BW.len() as f64
        * candidates::NOC_BW.len() as f64;
    let reticle = candidates::MAX_ARRAY_DIM as f64
        * candidates::MAX_ARRAY_DIM as f64
        * candidates::INTER_RETICLE_RATIO.len() as f64
        * (1.0 + candidates::STACK_BW.len() as f64); // off-chip or one of the stack grids
    let wafer =
        candidates::MAX_RETICLE_DIM as f64 * candidates::MAX_RETICLE_DIM as f64 * 2.0;
    // Heterogeneity: 4 granularities × prefill-ratio grid (20) × decode-bw grid.
    let hetero = 4.0 * 20.0 * candidates::STACK_BW.len() as f64;
    // Inter-wafer network: topology × link count × link bandwidth.
    let interwafer = 3.0
        * candidates::IW_LINKS.len() as f64
        * candidates::IW_LINK_BW.len() as f64;
    core * reticle * wafer * hetero * interwafer
}

/// Sample a raw (unvalidated) design point uniformly over the grids.
pub fn sample_raw(rng: &mut Rng) -> DesignPoint {
    let core = CoreConfig {
        dataflow: *rng.choose(&Dataflow::ALL),
        mac_num: *rng.choose(&candidates::MAC_NUM),
        buffer_kb: *rng.choose(&candidates::BUFFER_KB),
        buffer_bw_bits: *rng.choose(&candidates::BUFFER_BW),
        noc_bw_bits: *rng.choose(&candidates::NOC_BW),
    };
    let memory = if rng.bool(0.5) {
        MemoryKind::OffChip
    } else {
        let bw = *rng.choose(&candidates::STACK_BW);
        MemoryKind::Stacking {
            bw_tbps_per_100mm2: bw,
            capacity_gb: stack_capacity_gb(bw),
        }
    };
    let reticle = ReticleConfig {
        core,
        array_h: rng.range(1, candidates::MAX_ARRAY_DIM),
        array_w: rng.range(1, candidates::MAX_ARRAY_DIM),
        inter_reticle_bw_ratio: *rng.choose(&candidates::INTER_RETICLE_RATIO),
        memory,
    };
    let wsc = WscConfig {
        reticle,
        reticle_h: rng.range(1, candidates::MAX_RETICLE_DIM),
        reticle_w: rng.range(1, candidates::MAX_RETICLE_DIM),
        integration: *rng.choose(&IntegrationStyle::ALL),
        mem_ctrl_count: default_mem_ctrl_count(),
        nic_count: default_nic_count(),
    };
    // Inter-wafer draws come *after* every on-wafer draw so the RNG stream
    // for the existing axes is unchanged at a given seed.
    let mut p = DesignPoint::homogeneous(wsc);
    p.interwafer = InterWaferNet {
        topology: *rng.choose(&InterWaferTopology::ALL),
        links_per_wafer: *rng.choose(&candidates::IW_LINKS),
        link_bandwidth: *rng.choose(&candidates::IW_LINK_BW),
        link_latency: INTER_WAFER_LINK_LATENCY_S,
    };
    p
}

/// Rejection-sample a *validated* design point. Returns the point plus its
/// physical characterization. `max_tries` bounds the loop (the space is
/// heavily constrained; ~2–10 % of raw samples validate).
pub fn sample_valid(rng: &mut Rng, max_tries: usize) -> Option<Validated> {
    for _ in 0..max_tries {
        let p = sample_raw(rng);
        if let Ok(v) = validate(&p) {
            return Some(v);
        }
    }
    None
}

/// A canonical known-good design point used by tests, examples and docs:
/// close to the paper's Fig. 13 best configuration (1 TFLOPS cores with
/// 128 KB SRAM, 12×12 cores/reticle, stacked DRAM, InFO-SoW).
pub fn reference_point() -> DesignPoint {
    let bw = 1.0;
    DesignPoint::homogeneous(WscConfig {
        reticle: ReticleConfig {
            core: CoreConfig {
                dataflow: Dataflow::WS,
                mac_num: 512,
                buffer_kb: 128,
                buffer_bw_bits: 256,
                noc_bw_bits: 512,
            },
            array_h: 12,
            array_w: 12,
            inter_reticle_bw_ratio: 1.0,
            memory: MemoryKind::Stacking {
                bw_tbps_per_100mm2: bw,
                capacity_gb: stack_capacity_gb(bw),
            },
        },
        reticle_h: 9,
        reticle_w: 6,
        integration: IntegrationStyle::InfoSoW,
        mem_ctrl_count: default_mem_ctrl_count(),
        nic_count: default_nic_count(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cardinality_is_huge() {
        let c = cardinality();
        assert!(c > 1e12, "cardinality={c:e}");
    }

    #[test]
    fn capacity_bandwidth_tradeoff() {
        assert!(stack_capacity_gb(0.25) > stack_capacity_gb(4.0));
        assert!(stack_capacity_gb(0.25) <= 40.0);
        assert!(stack_capacity_gb(4.0) >= 8.0);
    }

    #[test]
    fn reference_point_validates() {
        let v = validate(&reference_point()).expect("reference point must be valid");
        assert!(v.phys.wafer_yield >= 0.9);
        assert!(v.phys.peak_power_w <= crate::arch::constants::WAFER_POWER_LIMIT_W);
    }

    #[test]
    fn sampling_finds_valid_points() {
        let mut rng = Rng::new(2024);
        let v = sample_valid(&mut rng, 5000).expect("should find a valid point");
        assert!(v.phys.peak_flops > 0.0);
    }

    #[test]
    fn raw_samples_cover_grids() {
        let mut rng = Rng::new(7);
        let mut saw_offchip = false;
        let mut saw_stack = false;
        let mut saw_stitch = false;
        let mut topologies = std::collections::BTreeSet::new();
        for _ in 0..200 {
            let p = sample_raw(&mut rng);
            match p.wsc.reticle.memory {
                MemoryKind::OffChip => saw_offchip = true,
                MemoryKind::Stacking { .. } => saw_stack = true,
            }
            if p.wsc.integration == IntegrationStyle::DieStitching {
                saw_stitch = true;
            }
            topologies.insert(p.interwafer.topology.name());
            assert!(candidates::IW_LINKS.contains(&p.interwafer.links_per_wafer));
            assert!(candidates::IW_LINK_BW.contains(&p.interwafer.link_bandwidth));
        }
        assert!(saw_offchip && saw_stack && saw_stitch);
        assert_eq!(topologies.len(), InterWaferTopology::ALL.len());
    }
}
