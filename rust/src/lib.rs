//! # Theseus
//!
//! Reproduction of *"Theseus: Towards High-Efficiency Wafer-Scale Chip
//! Design Space Exploration for Large Language Models"* (Zhu et al., 2024)
//! as a three-layer Rust + JAX + Pallas stack — see DESIGN.md for the
//! system inventory and the per-experiment index.
//!
//! Layer 3 (this crate) is the whole DSE framework: design-space
//! construction and validation ([`design_space`], [`arch`], [`yield_model`],
//! [`components`]), the workload compiler ([`workload`], [`compiler`]), the
//! hierarchical evaluation engine ([`eval`]) backed by a cycle-accurate NoC
//! simulator ([`noc_sim`]) and an AOT-compiled GNN congestion model executed
//! via PJRT ([`runtime`]), a discrete-event serving-traffic simulator atop
//! the engine ([`serving`]), and the multi-fidelity multi-objective Bayesian
//! explorer ([`explorer`]) orchestrated by [`coordinator`].

// The whole crate is safe Rust by construction (in-tree json/rng/pool
// substrates instead of FFI-bearing deps); forbid — not deny — so no
// module can opt back in with an allow.
#![forbid(unsafe_code)]

pub mod arch;
pub mod baselines;
pub mod bench;
pub mod compiler;
pub mod components;
pub mod design_space;
pub mod coordinator;
pub mod eval;
pub mod explorer;
pub mod figures;
pub mod noc_sim;
pub mod runtime;
pub mod serving;
pub mod util;
pub mod workload;
pub mod yield_model;
