//! Benchmark LLMs (paper Table II): GPT-style models from 1.7 B to 32.4 T
//! parameters, with the Megatron-LM scaling table for Nos. 0–6/8–10, GPT-3
//! for No. 7, and the paper's extrapolated giants for Nos. 11–15.
//! `gpu_num` is the paper's H100-cluster sizing used to match total silicon
//! area between WSC and GPU baselines (§VIII-A).

use super::LlmSpec;

/// The sixteen benchmark models of Table II, indexed 0..=15.
pub fn benchmarks() -> Vec<LlmSpec> {
    // (name, layers, hidden, heads, gpus, global batch)
    let rows: [(&str, usize, usize, usize, usize, usize); 16] = [
        ("GPT-1.7B", 24, 2304, 24, 32, 512),
        ("GPT-3.6B", 30, 3072, 32, 64, 512),
        ("GPT-7.5B", 36, 4096, 32, 128, 512),
        ("GPT-18.4B", 40, 6144, 48, 256, 1024),
        ("GPT-39.1B", 48, 8192, 64, 512, 1536),
        ("GPT-76.1B", 60, 10240, 80, 1024, 1792),
        ("GPT-145.6B", 80, 12288, 96, 1536, 2304),
        ("GPT-175B", 96, 12288, 96, 1000, 2048),
        ("GPT-310.1B", 96, 16384, 128, 1920, 2160),
        ("GPT-529.6B", 105, 20480, 128, 2520, 2520),
        ("GPT-1008.0B", 128, 25600, 160, 3072, 3072),
        ("GPT-2244.5B", 192, 32768, 256, 6000, 3072),
        ("GPT-4066.6B", 192, 43008, 432, 12000, 5500),
        ("GPT-9588.2B", 195, 65536, 512, 30000, 10000),
        ("GPT-18436.5B", 240, 81920, 620, 60000, 15000),
        ("GPT-32405.7B", 270, 102400, 850, 100000, 20000),
    ];
    rows.iter()
        .map(|&(name, layers, hidden, heads, gpus, batch)| LlmSpec {
            name: name.to_string(),
            layers,
            hidden,
            heads,
            gpu_num: gpus,
            batch_size: batch,
            seq_len: 2048,
            vocab: 51200,
        })
        .collect()
}

/// Lookup by index or (case-insensitive) name fragment, e.g. "175b".
pub fn find(key: &str) -> Option<LlmSpec> {
    let all = benchmarks();
    if let Ok(i) = key.parse::<usize>() {
        return all.get(i).cloned();
    }
    let lower = key.to_lowercase();
    all.into_iter()
        .find(|m| m.name.to_lowercase().contains(&lower))
}

/// [`find`] with a human-oriented error naming every valid key — CLI call
/// sites print this and exit 1 instead of silently falling back (same
/// convention as the malformed-env-var warnings in `util::cli`).
pub fn find_or_usage(key: &str) -> Result<LlmSpec, String> {
    find(key).ok_or_else(|| {
        let all = benchmarks();
        let names: Vec<&str> = all.iter().map(|m| m.name.as_str()).collect();
        format!(
            "unknown model '{key}' — valid: an index 0..{} or a name fragment of: {}",
            all.len() - 1,
            names.join(", ")
        )
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sixteen_models() {
        assert_eq!(benchmarks().len(), 16);
    }

    #[test]
    fn parameter_counts_match_names() {
        // Each model's computed parameter count must match the billions in
        // its name: within 10 % for the published rows (0–10); the paper's
        // extrapolated giants (11–15, "32k"-style rounded hidden sizes) get
        // 12 %.
        for (i, m) in benchmarks().iter().enumerate() {
            let name_b: f64 = m
                .name
                .trim_start_matches("GPT-")
                .trim_end_matches('B')
                .parse()
                .unwrap();
            let computed_b = m.param_count() / 1e9;
            let rel = (computed_b - name_b).abs() / name_b;
            let tol = if i <= 10 { 0.10 } else { 0.12 };
            assert!(
                rel < tol,
                "{}: computed {:.1}B vs name {:.1}B",
                m.name,
                computed_b,
                name_b
            );
        }
    }

    #[test]
    fn table_2_explicit_rows() {
        let b = benchmarks();
        // No. 7 = GPT-3 175B exactly as in Table II.
        assert_eq!(b[7].layers, 96);
        assert_eq!(b[7].hidden, 12288);
        assert_eq!(b[7].heads, 96);
        assert_eq!(b[7].gpu_num, 1000);
        assert_eq!(b[7].batch_size, 2048);
        // No. 15 = 32.4T giant.
        assert_eq!(b[15].layers, 270);
        assert_eq!(b[15].hidden, 102400);
        assert_eq!(b[15].gpu_num, 100000);
    }

    #[test]
    fn find_by_fragment_and_index() {
        assert_eq!(find("175b").unwrap().layers, 96);
        assert_eq!(find("7").unwrap().name, "GPT-175B");
        assert_eq!(find("1.7").unwrap().name, "GPT-1.7B");
        assert!(find("nonexistent").is_none());
    }

    #[test]
    fn find_or_usage_lists_valid_options() {
        assert_eq!(find_or_usage("175b").unwrap().name, "GPT-175B");
        let err = find_or_usage("gpt-nonexistent").unwrap_err();
        assert!(err.contains("unknown model 'gpt-nonexistent'"), "{err}");
        // The error names the index range and every model, so a typo is
        // immediately correctable.
        assert!(err.contains("0..15"), "{err}");
        for m in benchmarks() {
            assert!(err.contains(&m.name), "missing {} in: {err}", m.name);
        }
    }

    #[test]
    fn monotone_scale() {
        let b = benchmarks();
        for i in 1..b.len() {
            assert!(
                b[i].param_count() > b[i - 1].param_count() * 0.9,
                "non-monotone at {i}"
            );
        }
    }
}
